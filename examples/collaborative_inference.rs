//! End-to-end driver (DESIGN.md F5/H1): the full collaborative-inference
//! system on a real workload — both dataset versions, real PJRT
//! inference, mAP evaluation, byte accounting, energy share, and serving
//! latency/throughput.  Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example collaborative_inference -- [--scenes N]

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;
use tiansuan::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let scenes = args.opt_usize("scenes", 10);
    let rt = Runtime::open(args.opt_or("artifacts", "artifacts"))?;
    rt.warmup()?;
    rt.calibrate()?; // cost-based batch planning (EXPERIMENTS.md §Perf)
    let cfg = Config::default();

    println!("=== satellite-ground collaborative inference (Fig 5 workflow) ===");
    println!("platform {}  scenes/version {}  scene {}x{} px  fragment {} px", rt.platform(),
             scenes, cfg.scene_cells * 64, cfg.scene_cells * 64, cfg.fragment_px);

    let mut improvements = Vec::new();
    for version in [Version::V1, Version::V2] {
        let pipeline = Pipeline::new(&rt, cfg.clone());
        let t0 = std::time::Instant::now();
        let r = pipeline.run_scenario(version, scenes)?;
        let wall = t0.elapsed().as_secs_f64();
        improvements.push(r.accuracy_improvement());
        println!("\n--- dataset {} ---", r.version);
        println!("tiles            : {} total, {} filtered ({:.1}%)",
                 r.tiles_total, r.tiles_filtered, 100.0 * r.filter_rate());
        println!("routing          : {} onboard-final, {} offloaded ({:.1}%), {} confidently-empty",
                 r.router.onboard_final, r.router.offloaded,
                 100.0 * r.router.offload_fraction(), r.router.confidently_empty);
        println!("accuracy (mAP)   : in-orbit {:.3} -> collaborative {:.3}  (+{:.0}%)",
                 r.map_inorbit, r.map_collab, 100.0 * r.accuracy_improvement());
        println!("downlink         : bent-pipe {} B -> collaborative {} B  ({:.1}% reduction)",
                 r.bentpipe_bytes, r.collab_bytes, 100.0 * r.data_reduction());
        println!("energy           : computing share {:.1}% of onboard total (duty {:.2})",
                 100.0 * r.energy_compute_share, r.compute_duty);
        println!("serving          : {:.1} tiles/s end-to-end wall, {:.1} tiles/s PJRT, mean conf {:.2}",
                 r.tiles_total as f64 / wall,
                 (r.tiles_total - r.tiles_filtered) as f64 / r.wall_infer_s.max(1e-9),
                 r.mean_confidence);
    }
    println!("\naverage accuracy improvement: {:.0}%  (paper: +44%/+52%, ≈50%)",
             100.0 * improvements.iter().sum::<f64>() / improvements.len() as f64);
    Ok(())
}
