//! IncrementalLearning protocol demo (paper §3.4): the drift monitor
//! watches onboard confidence; when it degrades, the satellite pulls the
//! incrementally-retrained `tinydet_v2` over the uplink and hot-swaps it,
//! measurably improving onboard mAP on the same workload.
//!
//!     cargo run --release --example incremental -- [--scenes N]

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::runtime::{Model, Runtime};
use tiansuan::sedna::incremental::{step, DriftMonitor, ModelSlot};
use tiansuan::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let scenes = args.opt_usize("scenes", 6);
    let rt = Runtime::open(args.opt_or("artifacts", "artifacts"))?;
    let cfg = Config::default();

    // Phase 1: serve with the original onboard model and monitor drift.
    let mut p = Pipeline::new(&rt, cfg.clone());
    p.onboard_model = Model::Tiny;
    let before = p.run_scenario(Version::V2, scenes)?;
    println!("phase 1 (tinydet v1): onboard mAP {:.3}, mean confidence {:.2}, offload {:.1}%",
             before.map_inorbit, before.mean_confidence,
             100.0 * before.router.offload_fraction());

    // Drift monitor consumes the confidence stream; the weak model's low
    // confidence triggers an update request.
    // Update policy: the operator wants onboard confidence ≥0.85; the
    // v1 model's drift below that triggers the incremental update.
    let mut monitor = DriftMonitor::new(0.85);
    let mut slot = ModelSlot::new();
    let weight_bytes = std::fs::metadata("artifacts/weights_tiny_v2.npz").map(|m| m.len()).unwrap_or(57_930);
    let mut uplinked = None;
    for _ in 0..monitor.min_obs + 5 {
        if let Some(b) = step(&mut monitor, &mut slot, before.mean_confidence, weight_bytes) {
            uplinked = Some(b);
        }
    }
    match uplinked {
        Some(bytes) => {
            let mut link = Link::new(LinkConfig::uplink(LossProfile::stable()), 5);
            let t = link.transmit(bytes, 1e9);
            println!("drift detected (ema {:.2} < {:.2}): uplinked {} B of weights in {:.1} s; hot-swapped to {:?} v{}",
                     monitor.ema(), monitor.threshold, bytes, t.elapsed_s, slot.current, slot.version);
        }
        None => println!("no drift trigger (ema {:.2}) — model already adequate", monitor.ema()),
    }

    // Phase 2: serve with whatever the slot now holds.
    let mut p2 = Pipeline::new(&rt, cfg);
    p2.onboard_model = slot.current;
    let after = p2.run_scenario(Version::V2, scenes)?;
    println!("phase 2 ({:?}): onboard mAP {:.3}, mean confidence {:.2}, offload {:.1}%",
             slot.current, after.map_inorbit, after.mean_confidence,
             100.0 * after.router.offload_fraction());
    println!("incremental update uplift: onboard mAP {:+.1}% (collab {:.3} -> {:.3})",
             100.0 * (after.map_inorbit - before.map_inorbit) / before.map_inorbit.max(1e-9),
             before.map_collab, after.map_collab);
    Ok(())
}
