//! FederatedLearning protocol demo (paper §3.4): four satellites with
//! non-IID private data train a shared classifier; only weights cross the
//! 0.1–1 Mbps uplink; the Sedna GlobalManager tracks the task lifecycle.
//!
//!     cargo run --release --example federated -- [--rounds N] [--workers W]

use std::collections::BTreeMap;

use tiansuan::cluster::NodeId;
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::sedna::federated::{make_shard, run_federated, accuracy, LinearModel, local_train};
use tiansuan::sedna::{GlobalManager, TaskKind, TaskPhase, TaskSpec};
use tiansuan::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let rounds = args.opt_usize("rounds", 15);
    let workers = args.opt_usize("workers", 4);
    let dim = 8;

    // Sedna task lifecycle
    let node_ids: Vec<NodeId> = (0..workers).map(|i| NodeId::new(format!("sat-{i}"))).collect();
    let mut gm = GlobalManager::new();
    gm.create(TaskSpec {
        name: "fl-landcover".into(),
        kind: TaskKind::FederatedLearning,
        workers: node_ids.clone(),
        params: BTreeMap::from([("rounds".to_string(), rounds.to_string())]),
    })?;
    for n in &node_ids {
        gm.report("fl-landcover", n, TaskPhase::Running)?;
    }

    println!("=== federated learning across {workers} satellites, {rounds} rounds ===");
    let (global, acc_history, uplink_bytes) = run_federated(workers, rounds, 400, dim, 7);
    for (r, a) in acc_history.iter().enumerate() {
        println!("round {:>2}: global test accuracy {:.3}", r + 1, a);
    }

    // uplink cost through the actual link model (0.5 Mbps midpoint)
    let mut link = Link::new(LinkConfig::uplink(LossProfile::stable()), 11);
    let t = link.transmit(uplink_bytes, 1e9);
    println!("\nuplink: {} B of weights total; {:.2} s of 0.5 Mbps uplink airtime ({} retransmissions)",
             uplink_bytes, t.elapsed_s, link.stats.retransmissions);

    // privacy framing: compare with shipping the raw shards
    let raw_bytes = (workers * 400 * dim * 4) as u64;
    println!("raw data NOT shipped: {} B stays on the satellites ({}x the weight traffic)",
             raw_bytes, raw_bytes / uplink_bytes.max(1));

    // federated vs solo on a skewed shard
    let test = make_shard(7 + 10_000, 2000, dim, 0.0);
    let solo = local_train(&LinearModel::zeros(dim), &make_shard(7, 400, dim, -1.0), 2 * rounds, 0.05, 3);
    println!("federated accuracy {:.3} vs best-effort solo (most-skewed worker) {:.3}",
             accuracy(&global, &test), accuracy(&solo, &test));

    for n in &node_ids {
        gm.report("fl-landcover", n, TaskPhase::Completed)?;
    }
    let (_, status) = gm.get("fl-landcover").unwrap();
    println!("sedna task phase: {:?}", status.phase);
    assert_eq!(status.phase, TaskPhase::Completed);
    Ok(())
}
