//! Quickstart: load the AOT artifacts, capture one synthetic scene, run
//! the full collaborative-inference path, print what happened.
//!
//!     make artifacts && cargo run --release --example quickstart

use tiansuan::config::Config;
use tiansuan::coordinator::{Pipeline, TileFate};
use tiansuan::coordinator::router::RouterStats;
use tiansuan::data::{SceneGen, Version, CLASS_NAMES};
use tiansuan::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. open the artifacts produced by `make artifacts`
    let rt = Runtime::open("artifacts")?;
    println!(
        "PJRT platform: {}; models: {:?}; onboard batch {}",
        rt.platform(),
        rt.manifest.models.keys().collect::<Vec<_>>(),
        rt.max_batch()
    );

    // 2. capture one Earth-Observation scene (the satellite camera)
    let mut cfg = Config::default();
    cfg.scene_cells = 4; // 256x256 px
    let mut gen = SceneGen::new(cfg.seed, Version::V2.spec(), cfg.scene_cells, cfg.scene_cells);
    let scene = gen.capture();
    println!(
        "captured scene {}: {}x{} px, {} ground-truth objects",
        scene.id,
        scene.width,
        scene.height,
        scene.boxes.len()
    );

    // 3. run the Fig-5 workflow: split → cloud filter → TinyDet →
    //    confidence routing → HeavyDet on the ground for offloads
    let pipeline = Pipeline::new(&rt, cfg);
    let mut router = RouterStats::default();
    let (processed, n_filtered, wall) = pipeline.process_scene(&scene, &mut router)?;

    println!(
        "tiles: {} filtered (cloud), {} onboard-final, {} offloaded ({:.0} ms PJRT)",
        n_filtered,
        router.onboard_final,
        router.offloaded,
        wall * 1e3
    );

    // 4. print the detections the ground segment receives
    for p in &processed {
        let (dets, src) = match (&p.fate, &p.ground_dets) {
            (TileFate::Offloaded, Some(g)) => (g, "ground/HeavyDet"),
            _ => (&p.onboard_dets, "onboard/TinyDet"),
        };
        for d in dets {
            let (sx, sy) = p.tile.to_scene_xy(d.cx, d.cy);
            println!(
                "  {:<14} score {:.2} at scene ({:>5.1},{:>5.1}) via {src}",
                CLASS_NAMES[d.class], d.score, sx, sy
            );
        }
    }
    Ok(())
}
