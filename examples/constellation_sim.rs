//! Constellation simulation, two parts:
//!
//! 1. The coordinator's constellation runner (`run_constellation`): N
//!    satellites with their own staged pipelines and contact-window-gated
//!    downlinks sharing one ground segment, scheduled as a Sedna
//!    JointInference task, reporting aggregate throughput and per-stage
//!    latency telemetry.
//! 2. A 24-hour mission timeline for Baoyun + Chuangxingleishen over the
//!    Beijing ground station, integrating the orbital mechanics, contact
//!    windows, lossy downlink, the KubeEdge-like cluster substrate
//!    (heartbeats, offline autonomy, reconcile), and the
//!    collaborative-inference pipeline.
//!
//!     cargo run --release --example constellation_sim -- [--hours H] [--loss stable|weak|makersat]
//!                                                        [--sats N] [--scenes N]
//!                                                        [--battery-wh W] [--soc0 F] [--power]
//!                                                        [--federated] [--round-interval-s S]
//!                                                        [--trace-out PATH] [--trace-chrome PATH]
//!
//! `--power` enables the power subsystem (solar array + battery +
//! governor) for part 1; `--battery-wh` / `--soc0` size the battery and
//! its initial state of charge.  `--federated` schedules federated
//! training rounds as a mission workload (SoC-gated when `--power` is
//! also on), with weights contending for downlink airtime.
//! `--trace-out` / `--trace-chrome` enable the flight recorder for
//! part 1 and write the merged virtual-time trace as JSONL / Chrome
//! `trace_event` JSON (load the latter in `chrome://tracing` or
//! Perfetto), printing a per-kind record summary.

use tiansuan::cluster::metastore::{EdgeReplica, MetaStore};
use tiansuan::cluster::orchestrator::{AppSpec, Orchestrator, Placement};
use tiansuan::cluster::registry::{NodeStatus, Registry};
use tiansuan::cluster::{NodeId, NodeRole};
use tiansuan::config::Config;
use tiansuan::coordinator::downlink::{DownlinkItem, DownlinkQueue, ItemKind};
use tiansuan::coordinator::{run_constellation, Pipeline, TileFate};
use tiansuan::coordinator::router::RouterStats;
use tiansuan::data::{SceneGen, Version};
use tiansuan::detect::Detection;
use tiansuan::energy::EnergyMeter;
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::orbit::{baoyun, beijing_station, chuangxingleishen, contact_windows};
use tiansuan::runtime::Runtime;
use tiansuan::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let hours = args.opt_f64("hours", 24.0);
    let loss = match args.opt_or("loss", "stable") {
        "weak" => LossProfile::weak(),
        "makersat" => LossProfile::makersat_incident(),
        _ => LossProfile::stable(),
    };
    let horizon = hours * 3600.0;
    let rt = Runtime::open(args.opt_or("artifacts", "artifacts"))?;
    let gs = beijing_station();

    // Part 1: the coordinator's constellation runner.
    let mut ccfg = Config::default();
    ccfg.scene_cells = args.opt_usize("cells", 4);
    ccfg.constellation.satellites = args.opt_usize("sats", 3);
    ccfg.constellation.scenes_per_satellite = args.opt_usize("scenes", 2);
    ccfg.power.enabled = args.flag("power");
    ccfg.power.battery_wh = args.opt_f64("battery-wh", ccfg.power.battery_wh);
    ccfg.power.initial_soc = args.opt_f64("soc0", ccfg.power.initial_soc);
    ccfg.federated.enabled = args.flag("federated");
    ccfg.federated.round_interval_s =
        args.opt_f64("round-interval-s", ccfg.federated.round_interval_s);
    let trace_out = args.opt("trace-out");
    let trace_chrome = args.opt("trace-chrome");
    ccfg.trace.enabled = trace_out.is_some() || trace_chrome.is_some();
    println!(
        "=== run_constellation: {} satellites × {} scenes, shared ground segment{}{} ===",
        ccfg.constellation.satellites,
        ccfg.constellation.scenes_per_satellite,
        if ccfg.power.enabled {
            format!(", power governor on ({} Wh battery)", ccfg.power.battery_wh)
        } else {
            String::new()
        },
        if ccfg.federated.enabled {
            format!(", federated rounds every {} s", ccfg.federated.round_interval_s)
        } else {
            String::new()
        }
    );
    let report = run_constellation(&rt, &ccfg, Version::V2)?;
    for sat in &report.satellites {
        println!(
            "{}: {} tiles ({} filtered, {} offloaded), mAP {:.3}->{:.3}, {} passes / {:.0} s contact / {:.0} s sunlit, downlink {} delivered / {} dropped ({} B lost), compute {:.1}% of energy",
            sat.name,
            sat.result.tiles_total,
            sat.result.tiles_filtered,
            sat.result.router.offloaded,
            sat.result.map_inorbit,
            sat.result.map_collab,
            sat.windows,
            sat.contact_s,
            sat.sunlit_s,
            sat.downlink.items_delivered,
            sat.downlink.items_dropped,
            sat.downlink.bytes_dropped,
            100.0 * sat.result.energy_compute_share,
        );
        if let Some(p) = &sat.power {
            println!(
                "    power: SoC min {:.0}% / mean {:.0}% / final {:.0}%, {:.1} Wh generated / {:.1} Wh consumed ({:.2} Wh training), {} scenes deferred / {} shed, {:.2} Wh unmet",
                100.0 * p.min_soc_frac,
                100.0 * p.mean_soc_frac(),
                100.0 * p.final_soc_frac,
                p.generated_wh,
                p.consumed_wh,
                p.training_wh,
                p.scenes_deferred,
                p.scenes_shed,
                p.shortfall_wh,
            );
            println!(
                "    battery: {:.1} Wh cumulative discharge = {:.2} cycle equivalents",
                p.discharge_wh, p.cycle_equivalents,
            );
        }
        if let Some(f) = &sat.federated {
            println!(
                "    federated: {}/{} rounds trained, {} skipped for power, {} B weights queued / {} B delivered",
                f.rounds_completed,
                f.rounds_scheduled,
                f.rounds_skipped_power,
                f.uplink_bytes,
                sat.downlink.weights_bytes,
            );
        }
    }
    if let Some(fl) = &report.federated {
        println!(
            "federated fleet: final accuracy {:.3} over {} rounds ({} aggregated / {} held), {} B weights uplinked",
            fl.final_accuracy(),
            fl.acc_history.len(),
            fl.rounds_aggregated,
            fl.rounds_held,
            fl.uplink_bytes,
        );
    }
    println!(
        "aggregate: {} tiles in {:.2} s wall = {:.1} tiles/s; sedna task completed: {}",
        report.tiles_total,
        report.wall_s,
        report.aggregate_tiles_per_s(),
        report.task_completed
    );
    println!("--- per-stage telemetry ---\n{}", report.telemetry);
    if let Some(trace) = &report.trace {
        let mut summary = String::new();
        for (kind, n) in trace.kind_counts() {
            summary.push_str(&format!(" {}={n}", kind.name()));
        }
        println!(
            "--- flight recorder: {} records ({} evicted) ---{summary}",
            trace.len(),
            trace.evicted(),
        );
        if let Some(path) = trace_out {
            std::fs::write(path, trace.to_jsonl())?;
            println!("trace JSONL written to {path}");
        }
        if let Some(path) = trace_chrome {
            std::fs::write(path, trace.to_chrome())?;
            println!("chrome trace_event JSON written to {path} (open in chrome://tracing)");
        }
    }

    // Part 2: the 24-hour two-satellite mission timeline.

    // cluster bring-up: CloudCore + two EdgeCores
    let mut registry = Registry::new(60_000, 600_000);
    registry.register(NodeId::new("ground-1"), NodeRole::Cloud, 64_000, 262_144, 0);
    registry.register(NodeId::new("baoyun"), NodeRole::Edge, 4_000, 8_192, 0);
    registry.register(NodeId::new("cxls"), NodeRole::Edge, 4_000, 8_192, 0);
    let mut orch = Orchestrator::new();
    orch.apply(AppSpec { name: "tinydet".into(), image: "tinydet:v1".into(), replicas: 2, placement: Placement::Edge });
    orch.apply(AppSpec { name: "heavydet".into(), image: "heavydet:v1".into(), replicas: 1, placement: Placement::Cloud });
    orch.reconcile(&registry, 0);
    let mut cloud_meta = MetaStore::new();
    let mut edge_meta = EdgeReplica::new();
    edge_meta.sync(&mut cloud_meta);
    edge_meta.disconnect();

    println!("=== constellation sim: {hours:.0} h, loss profile {:?} ===", args.opt_or("loss", "stable"));
    for (name, sat) in [("Baoyun", baoyun()), ("Chuangxingleishen", chuangxingleishen())] {
        let windows = contact_windows(&sat, &gs, 0.0, horizon, 10.0);
        let contact: f64 = windows.iter().map(|w| w.duration_s()).sum();
        println!("\n--- {name}: {} passes, {:.0} s total contact ({:.2}% of timeline) ---",
                 windows.len(), contact, 100.0 * contact / horizon);

        let cfg = Config::default();
        let pipeline = Pipeline::new(&rt, cfg.clone());
        let mut gen = SceneGen::new(cfg.seed + name.len() as u64, Version::V2.spec(),
                                    cfg.scene_cells, cfg.scene_cells);
        let mut queue = DownlinkQueue::new();
        let mut link = Link::new(LinkConfig::downlink(loss), cfg.seed);
        let mut router = RouterStats::default();
        let mut energy = EnergyMeter::new();
        let mut captures = 0u64;
        let mut t = 0.0;
        let capture_period = 180.0; // one scene every 3 minutes on the sunlit side
        let mut next_window = 0usize;

        while t < horizon {
            // capture + onboard processing (virtual time advances by the
            // modeled onboard service time)
            let scene = gen.capture();
            captures += 1;
            let (processed, _nf, _wall) = pipeline.process_scene(&scene, &mut router)?;
            let busy: f64 = processed.len() as f64
                * tiansuan::coordinator::pipeline::ONBOARD_S_PER_TILE;
            for p in &processed {
                let ready = t + busy;
                match p.fate {
                    TileFate::OnboardFinal => queue.push(DownlinkItem {
                        kind: ItemKind::Results,
                        bytes: 8 + Detection::WIRE_BYTES * p.onboard_dets.len() as u64,
                        ready_at: ready,
                        tag: p.tile.scene_id,
                    }),
                    TileFate::Offloaded => queue.push(DownlinkItem {
                        kind: ItemKind::Image,
                        bytes: p.tile.raw_bytes(),
                        ready_at: ready,
                        tag: p.tile.scene_id,
                    }),
                    TileFate::Filtered => {}
                }
            }

            // heartbeats + metadata sync only possible in contact; edge
            // stays autonomous otherwise
            let in_contact = windows.iter().any(|w| w.contains(t));
            let now_ms = (t * 1000.0) as u64;
            if in_contact {
                registry.heartbeat(&NodeId::new(name_to_node(name)), now_ms);
                edge_meta.sync(&mut cloud_meta);
                edge_meta.disconnect();
            } else {
                edge_meta.put(None, &format!("telemetry/{captures}"), &format!("{:.2}", t));
            }
            orch.reconcile(&registry, now_ms);

            // drain any windows that opened since the previous capture
            while next_window < windows.len() && windows[next_window].aos < t + capture_period {
                queue.drain_window(&mut link, &windows[next_window]);
                next_window += 1;
            }

            energy.advance(capture_period, busy / capture_period,
                           if in_contact { 1.0 } else { 0.0 }, 0.1);
            t += capture_period;
        }

        let status = registry.status(&NodeId::new(name_to_node(name)), (horizon * 1000.0) as u64);
        println!("captures {captures}  tiles routed {} (offload {:.1}%)",
                 router.total(), 100.0 * router.offload_fraction());
        println!("downlink: {} items delivered, {} dropped, {} B results + {} B images, mean latency {:.0} s",
                 queue.stats.items_delivered, queue.stats.items_dropped,
                 queue.stats.results_bytes, queue.stats.image_bytes,
                 queue.stats.mean_latency_s());
        println!("link: {:.2}% packet loss, {} retransmissions, goodput {:.1} Mbps while busy",
                 100.0 * link.stats.loss_rate(), link.stats.retransmissions,
                 link.stats.goodput_bps() / 1e6);
        println!("energy: computing {:.1}% of onboard total; cloud-side node status at end: {:?} (expected NotReady/Offline outside contact)",
                 100.0 * energy.compute_share(), status);
        println!("offline autonomy: {} staged metadata writes pending next contact; pods running: tinydet {} heavydet {}",
                 edge_meta.staged_count(), orch.running("tinydet"), orch.running("heavydet"));
        assert_eq!(status.map(|s| s != NodeStatus::Ready), Some(true),
                   "edge should look non-ready to the cloud outside contact");
    }
    Ok(())
}

fn name_to_node(name: &str) -> &'static str {
    if name == "Baoyun" {
        "baoyun"
    } else {
        "cxls"
    }
}
