//! Shard-count invariance of the fleet event scheduler, artifact-free.
//!
//! `fleet.shards` and `fleet.max_events_in_flight` are parallelism
//! dials: they decide which worker steps which satellite and how many
//! machines are live at once, never what any machine computes.  These
//! tests drive the scheduler with [`StubSat`] machines (real
//! [`Timeline`]s, synthetic workload, no inference artifacts) and
//! bit-compare the full report set across shard counts and admission
//! caps, including an order-sensitive checksum over every machine's
//! event sequence.

use tiansuan::sim::{run_sharded, StubReport, StubSat};

fn fleet(n: usize, shards: usize, cap: usize, seed: u64) -> Vec<StubReport> {
    let (reports, _) =
        run_sharded(n, shards, cap, |id| Ok(StubSat::new(id, seed, 5, 43_200.0))).unwrap();
    reports
}

#[test]
fn shard_count_is_a_pure_parallelism_dial() {
    let baseline = fleet(50, 1, 0, 7);
    assert_eq!(baseline.len(), 50);
    for shards in [2, 3, 7, 16, 50] {
        assert_eq!(baseline, fleet(50, shards, 0, 7), "shards={shards}");
    }
}

#[test]
fn admission_cap_is_a_pure_memory_dial() {
    let baseline = fleet(50, 4, 0, 7);
    for cap in [1, 2, 5, 64] {
        assert_eq!(baseline, fleet(50, 4, cap, 7), "max_events_in_flight={cap}");
    }
}

#[test]
fn admission_cap_actually_bounds_live_machines() {
    let (_, uncapped) = run_sharded(64, 4, 0, |id| Ok(StubSat::new(id, 3, 4, 43_200.0))).unwrap();
    let (_, capped) = run_sharded(64, 4, 2, |id| Ok(StubSat::new(id, 3, 4, 43_200.0))).unwrap();
    assert!(capped.peak_live <= 4 * 2, "peak_live {} exceeds shards*cap", capped.peak_live);
    assert!(uncapped.peak_live > capped.peak_live, "cap had no effect");
    assert_eq!(uncapped.events, capped.events, "same missions, same event count");
}

#[test]
fn different_seeds_produce_different_missions() {
    // sanity that the invariance above isn't comparing constants
    let a = fleet(10, 2, 0, 7);
    let b = fleet(10, 2, 0, 8);
    assert_ne!(a, b, "seed must reach every machine's RNG stream");
}
