//! Staged-engine correctness: the concurrent stage graph must reproduce
//! the sequential facade's `ScenarioResult` bit-for-bit for identical
//! config + seed, and the constellation runner must complete with ≥ 3
//! satellites and report per-stage telemetry.

use tiansuan::config::Config;
use tiansuan::coordinator::{run_constellation, Pipeline, StagedEngine};
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg
}

/// Everything except `wall_infer_s` (genuine wallclock) must match
/// exactly — tile conservation, filter rate, router counts, mAP bits,
/// byte accounting, confidence, duty cycle, energy share.
fn assert_bit_identical(staged: &tiansuan::coordinator::ScenarioResult, seq: &tiansuan::coordinator::ScenarioResult) {
    assert_eq!(staged.version, seq.version);
    assert_eq!(staged.fragment_px, seq.fragment_px);
    assert_eq!(staged.scenes, seq.scenes);
    assert_eq!(staged.tiles_total, seq.tiles_total);
    assert_eq!(staged.tiles_filtered, seq.tiles_filtered);
    assert_eq!(staged.router.onboard_final, seq.router.onboard_final);
    assert_eq!(staged.router.offloaded, seq.router.offloaded);
    assert_eq!(staged.router.confidently_empty, seq.router.confidently_empty);
    assert_eq!(staged.map_inorbit.to_bits(), seq.map_inorbit.to_bits());
    assert_eq!(staged.map_collab.to_bits(), seq.map_collab.to_bits());
    assert_eq!(staged.report_inorbit.gt_total, seq.report_inorbit.gt_total);
    assert_eq!(staged.report_inorbit.det_total, seq.report_inorbit.det_total);
    assert_eq!(staged.report_collab.det_total, seq.report_collab.det_total);
    assert_eq!(staged.bentpipe_bytes, seq.bentpipe_bytes);
    assert_eq!(staged.collab_bytes, seq.collab_bytes);
    assert_eq!(staged.mean_confidence.to_bits(), seq.mean_confidence.to_bits());
    assert_eq!(staged.compute_duty.to_bits(), seq.compute_duty.to_bits());
    assert_eq!(
        staged.energy_compute_share.to_bits(),
        seq.energy_compute_share.to_bits()
    );
}

#[test]
fn staged_engine_matches_sequential_facade() {
    let Some(rt) = rt() else { return };
    for version in [Version::V1, Version::V2] {
        let p = Pipeline::new(&rt, small_cfg());
        let seq = p.run_scenario(version, 4).unwrap();
        for workers in [2usize, 4] {
            let staged = StagedEngine::new(&p)
                .with_workers(workers)
                .run_scenario(version, 4)
                .unwrap();
            assert_bit_identical(&staged, &seq);
        }
    }
}

#[test]
fn staged_engine_matches_across_seeds() {
    let Some(rt) = rt() else { return };
    for seed in [1u64, 20231207] {
        let mut cfg = small_cfg();
        cfg.seed = seed;
        let p = Pipeline::new(&rt, cfg);
        let seq = p.run_scenario(Version::V2, 3).unwrap();
        let staged = p.run_scenario_staged(Version::V2, 3).unwrap();
        assert_bit_identical(&staged, &seq);
    }
}

#[test]
fn constellation_three_satellites_complete() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 3;
    cfg.constellation.scenes_per_satellite = 2;
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();

    assert_eq!(report.satellites.len(), 3);
    assert!(report.task_completed, "sedna task should aggregate to Completed");
    assert!(report.tiles_total > 0);
    assert!(report.aggregate_tiles_per_s() > 0.0);
    for sat in &report.satellites {
        assert_eq!(sat.result.scenes, 2);
        // tile conservation holds per satellite
        assert_eq!(
            sat.result.tiles_total,
            sat.result.tiles_filtered
                + sat.result.router.onboard_final as usize
                + sat.result.router.offloaded as usize
        );
        assert!((0.0..=1.0).contains(&sat.result.energy_compute_share));
        // the timeline's illumination event source is wired through
        assert!(sat.sunlit_s > 0.0 && sat.sunlit_s <= 21_600.0, "sunlit_s {}", sat.sunlit_s);
    }
    // per-stage latency telemetry is present (capture + onboard stages
    // run on the staged per-satellite engine since the sim refactor)
    assert!(report.telemetry.contains("counter constellation.capture.items 6"), "{}", report.telemetry);
    assert!(report.telemetry.contains("counter constellation.onboard.items 6"), "{}", report.telemetry);
    assert!(report.telemetry.contains("histogram constellation.onboard.service_s"), "{}", report.telemetry);
    assert!(report.telemetry.contains("histogram constellation.onboard.queue_wait_s"), "{}", report.telemetry);
    assert!(report.telemetry.contains("histogram constellation.ground.queue_wait_s"), "{}", report.telemetry);
    assert!(report.telemetry.contains("counter constellation.ground.tiles"), "{}", report.telemetry);
}

#[test]
fn constellation_satellites_see_distinct_workloads() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 2;
    cfg.constellation.scenes_per_satellite = 2;
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let a = &report.satellites[0].result;
    let b = &report.satellites[1].result;
    // distinct per-satellite seeds: byte accounting should differ
    assert!(
        a.collab_bytes != b.collab_bytes || a.router.offloaded != b.router.offloaded,
        "satellites unexpectedly produced identical workloads"
    );
}
