//! Failure-injection integration tests for the cluster substrate (P1 in
//! DESIGN.md): the §3.2 claims under disconnection, the ref-[12] 80%%
//! packet-loss incident, and pod crashes during an outage.

use tiansuan::cluster::metastore::{EdgeReplica, MetaStore};
use tiansuan::cluster::msgbus::Channel;
use tiansuan::cluster::orchestrator::{AppSpec, Orchestrator, Placement};
use tiansuan::cluster::registry::{NodeStatus, Registry};
use tiansuan::cluster::{NodeId, NodeRole};
use tiansuan::link::{Link, LinkConfig, LossProfile};

fn two_node_cluster() -> (Registry, NodeId, NodeId) {
    let mut reg = Registry::new(30_000, 300_000);
    let edge = NodeId::new("baoyun");
    let cloud = NodeId::new("ground");
    reg.register(edge.clone(), NodeRole::Edge, 4000, 8192, 0);
    reg.register(cloud.clone(), NodeRole::Cloud, 64_000, 262_144, 0);
    (reg, edge, cloud)
}

#[test]
fn full_outage_and_recovery_cycle() {
    // A complete contact-gap cycle: connected -> 90 min silent -> contact.
    let (mut reg, edge, _) = two_node_cluster();
    let mut cloud_meta = MetaStore::new();
    let mut edge_meta = EdgeReplica::new();
    cloud_meta.put("app/detector/image", "tinydet:v1");
    edge_meta.sync(&mut cloud_meta);

    // outage begins
    edge_meta.disconnect();
    let outage_end = 90 * 60 * 1000u64;
    assert_eq!(reg.status(&edge, outage_end), Some(NodeStatus::Offline));

    // edge keeps serving from its snapshot and staging telemetry
    assert_eq!(edge_meta.get("app/detector/image"), Some("tinydet:v1"));
    for i in 0..50 {
        edge_meta.put(None, &format!("telemetry/{i}"), "ok");
    }
    assert_eq!(edge_meta.staged_count(), 50);

    // meanwhile the cloud rolls the app forward
    cloud_meta.put("app/detector/image", "tinydet:v2");

    // contact: heartbeat + bidirectional sync
    assert!(reg.heartbeat(&edge, outage_end));
    edge_meta.sync(&mut cloud_meta);
    assert_eq!(reg.status(&edge, outage_end + 1), Some(NodeStatus::Ready));
    assert_eq!(edge_meta.get("app/detector/image"), Some("tinydet:v2"));
    assert_eq!(cloud_meta.get("telemetry/49"), Some("ok"));
    assert_eq!(edge_meta.staged_count(), 0);
}

#[test]
fn pods_survive_cloud_side_outage() {
    // Cloud can't see the edge; the EDGE's own reconcile (its own
    // registry view, kept fresh by local heartbeats) keeps pods running.
    let (mut cloud_reg, edge, _) = two_node_cluster();
    let mut edge_reg = Registry::new(30_000, 300_000);
    edge_reg.register(edge.clone(), NodeRole::Edge, 4000, 8192, 0);

    let mut orch = Orchestrator::new();
    orch.apply(AppSpec {
        name: "detector".into(),
        image: "tinydet:v1".into(),
        replicas: 1,
        placement: Placement::Edge,
    });
    orch.reconcile(&edge_reg, 0);
    assert_eq!(orch.running("detector"), 1);

    // deep into the outage, the pod crashes (radiation upset)
    let t = 60 * 60 * 1000u64;
    assert_eq!(cloud_reg.status(&edge, t), Some(NodeStatus::Offline));
    orch.fail_pod("detector", 0);
    edge_reg.heartbeat(&edge, t); // local kubelet-equivalent is alive
    let acts = orch.reconcile(&edge_reg, t + 1);
    assert_eq!(acts.restarted, 1, "offline autonomy must restart the pod locally");
    assert_eq!(orch.running("detector"), 1);
    let _ = cloud_reg;
}

#[test]
fn makersat_80pct_loss_still_delivers_messages() {
    // ref [12]: a mission lost 80% of packets; §3.2 claims reliable
    // delivery regardless.  ARQ + queueing must deliver everything
    // (albeit slowly) as long as windows keep coming.
    let mut ch = Channel::new();
    let mut link = Link::new(LinkConfig::downlink(LossProfile::makersat_incident()), 99);
    for i in 0..30 {
        ch.send("telemetry", vec![0u8; 2_000], i);
    }
    let mut windows = 0;
    while ch.pending() > 0 && windows < 500 {
        ch.pump(&mut link, 2.0);
        windows += 1;
    }
    assert_eq!(ch.pending(), 0, "undelivered after {windows} windows");
    assert_eq!(ch.stats.delivered, 30);
    assert!(link.stats.loss_rate() > 0.4, "incident profile should actually lose packets: {}", link.stats.loss_rate());
    assert!(link.stats.retransmissions > 20);
}

#[test]
fn rolling_update_waits_for_contact() {
    // Image update applied cloud-side mid-outage reaches the edge's
    // orchestrator only after metadata sync, then a reconcile swaps it.
    let (_, _edge, _) = two_node_cluster();
    let mut cloud_meta = MetaStore::new();
    let mut edge_meta = EdgeReplica::new();
    cloud_meta.put("app/detector/image", "tinydet:v1");
    edge_meta.sync(&mut cloud_meta);
    edge_meta.disconnect();

    let mut edge_reg = Registry::new(30_000, 300_000);
    edge_reg.register(NodeId::new("baoyun"), NodeRole::Edge, 4000, 8192, 0);
    let mut orch = Orchestrator::new();
    let spec_of = |edge_meta: &EdgeReplica| AppSpec {
        name: "detector".into(),
        image: edge_meta.get("app/detector/image").unwrap().to_string(),
        replicas: 1,
        placement: Placement::Edge,
    };
    orch.apply(spec_of(&edge_meta));
    orch.reconcile(&edge_reg, 0);

    cloud_meta.put("app/detector/image", "tinydet:v2");
    // still offline: reconcile keeps v1
    edge_reg.heartbeat(&NodeId::new("baoyun"), 1000);
    orch.apply(spec_of(&edge_meta));
    orch.reconcile(&edge_reg, 1001);
    assert_eq!(orch.pods("detector")[0].image, "tinydet:v1");

    // contact: sync + reconcile applies the update
    edge_meta.sync(&mut cloud_meta);
    orch.apply(spec_of(&edge_meta));
    edge_reg.heartbeat(&NodeId::new("baoyun"), 2000);
    let acts = orch.reconcile(&edge_reg, 2001);
    assert_eq!(acts.updated, 1);
    assert_eq!(orch.pods("detector")[0].image, "tinydet:v2");
}
