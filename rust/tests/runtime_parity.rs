//! Numeric parity between the python (JAX/Pallas) build path and the rust
//! (PJRT) serving path: the same input batch must produce the same
//! decoded rows through both stacks.  Fixtures are dumped by aot.py.

use std::path::Path;

use tiansuan::runtime::{Model, Runtime};

fn artifacts() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !Path::new(dir).join("fixture_input_b1.bin").exists() {
        eprintln!("skipping: artifacts/fixtures not built");
        return None;
    }
    Some(Runtime::open(dir).expect("open artifacts"))
}

fn read_f32(path: &str) -> Vec<f32> {
    let bytes = std::fs::read(
        Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).join(path),
    )
    .unwrap_or_else(|e| panic!("{path}: {e}"));
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst < tol, "{what}: max abs err {worst} >= {tol}");
}

#[test]
fn tinydet_matches_python() {
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let want = read_f32("fixture_tinydet_b1_out.bin");
    let got = rt.execute_exact(Model::Tiny, 1, &input).unwrap();
    assert_close(&got, &want, 2e-3, "tinydet");
}

#[test]
fn tinydet_v2_matches_python() {
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let want = read_f32("fixture_tinydet_v2_b1_out.bin");
    let got = rt.execute_exact(Model::TinyV2, 1, &input).unwrap();
    assert_close(&got, &want, 2e-3, "tinydet_v2");
}

#[test]
fn heavydet_matches_python() {
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let want = read_f32("fixture_heavydet_b1_out.bin");
    let got = rt.execute_exact(Model::Heavy, 1, &input).unwrap();
    assert_close(&got, &want, 2e-3, "heavydet");
}

#[test]
fn cloudscore_matches_python() {
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let want = read_f32("fixture_cloudscore_b1_out.bin");
    let got = rt.execute_exact(Model::CloudScore, 1, &input).unwrap();
    assert_close(&got, &want, 1e-4, "cloudscore");
}

#[test]
fn tiny_and_v2_actually_differ() {
    // incremental learning is only meaningful if the artifacts differ
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let a = rt.execute_exact(Model::Tiny, 1, &input).unwrap();
    let b = rt.execute_exact(Model::TinyV2, 1, &input).unwrap();
    let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-3, "tiny and tiny_v2 look identical (sum abs diff {diff})");
}

#[test]
fn deterministic_across_calls() {
    let Some(rt) = artifacts() else { return };
    let input = read_f32("fixture_input_b1.bin");
    let a = rt.execute_exact(Model::Tiny, 1, &input).unwrap();
    let b = rt.execute_exact(Model::Tiny, 1, &input).unwrap();
    assert_eq!(a, b, "PJRT execution must be deterministic");
}
