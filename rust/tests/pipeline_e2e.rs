//! End-to-end pipeline integration tests over the real artifacts:
//! cross-cutting invariants that only hold when all layers compose.

use tiansuan::config::Config;
use tiansuan::coordinator::router::RouterStats;
use tiansuan::coordinator::{Pipeline, TileFate};
use tiansuan::data::{SceneGen, Version};
use tiansuan::runtime::{Model, Runtime};

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg
}

#[test]
fn every_offloaded_tile_gets_ground_detections() {
    let Some(rt) = rt() else { return };
    let p = Pipeline::new(&rt, small_cfg());
    let mut stats = RouterStats::default();
    let mut gen = SceneGen::new(77, Version::V2.spec(), 4, 4);
    for _ in 0..3 {
        let scene = gen.capture();
        let (processed, _, _) = p.process_scene(&scene, &mut stats).unwrap();
        for t in &processed {
            match t.fate {
                TileFate::Offloaded => assert!(t.ground_dets.is_some()),
                _ => assert!(t.ground_dets.is_none()),
            }
        }
    }
}

#[test]
fn higher_confidence_threshold_offloads_more() {
    let Some(rt) = rt() else { return };
    let mut lo = small_cfg();
    lo.policy.confidence_threshold = 0.2;
    let mut hi = small_cfg();
    hi.policy.confidence_threshold = 0.9;
    let r_lo = Pipeline::new(&rt, lo).run_scenario(Version::V2, 3).unwrap();
    let r_hi = Pipeline::new(&rt, hi).run_scenario(Version::V2, 3).unwrap();
    assert!(
        r_hi.router.offload_fraction() >= r_lo.router.offload_fraction(),
        "{} < {}",
        r_hi.router.offload_fraction(),
        r_lo.router.offload_fraction()
    );
    // more offload -> more bytes downlinked
    assert!(r_hi.collab_bytes >= r_lo.collab_bytes);
}

#[test]
fn offload_everything_equals_heavy_everywhere() {
    // threshold > 1.0 forces every kept tile to the ground model; the
    // collaborative mAP must then equal a heavy-only pipeline's mAP.
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.policy.confidence_threshold = 1.1;
    let mut p = Pipeline::new(&rt, cfg.clone());
    p.policy.empty_objectness = -1.0; // empty tiles offload too
    let r = p.run_scenario(Version::V2, 3).unwrap();
    assert_eq!(r.router.onboard_final, 0);

    let mut p_heavy = Pipeline::new(&rt, cfg);
    p_heavy.onboard_model = Model::Heavy;
    p_heavy.policy.confidence_threshold = -1.0; // nothing offloads
    let r_heavy = p_heavy.run_scenario(Version::V2, 3).unwrap();
    assert!(
        (r.map_collab - r_heavy.map_inorbit).abs() < 1e-9,
        "{} vs {}",
        r.map_collab,
        r_heavy.map_inorbit
    );
}

#[test]
fn incremental_model_improves_onboard_map() {
    let Some(rt) = rt() else { return };
    let cfg = small_cfg();
    let mut p1 = Pipeline::new(&rt, cfg.clone());
    p1.onboard_model = Model::Tiny;
    let mut p2 = Pipeline::new(&rt, cfg);
    p2.onboard_model = Model::TinyV2;
    let r1 = p1.run_scenario(Version::V2, 5).unwrap();
    let r2 = p2.run_scenario(Version::V2, 5).unwrap();
    assert!(
        r2.map_inorbit > r1.map_inorbit,
        "tiny_v2 {} should beat tiny {}",
        r2.map_inorbit,
        r1.map_inorbit
    );
}

#[test]
fn fragment_size_sweep_preserves_conservation() {
    let Some(rt) = rt() else { return };
    for frag in [32usize, 64, 128] {
        let mut cfg = small_cfg();
        cfg.fragment_px = frag;
        let p = Pipeline::new(&rt, cfg);
        let r = p.run_scenario(Version::V1, 2).unwrap();
        assert_eq!(
            r.tiles_total,
            r.tiles_filtered + r.router.total() as usize,
            "frag {frag}"
        );
        assert!(r.collab_bytes <= r.bentpipe_bytes, "frag {frag}");
    }
}

#[test]
fn deterministic_given_seed() {
    let Some(rt) = rt() else { return };
    let a = Pipeline::new(&rt, small_cfg()).run_scenario(Version::V1, 2).unwrap();
    let b = Pipeline::new(&rt, small_cfg()).run_scenario(Version::V1, 2).unwrap();
    assert_eq!(a.map_collab, b.map_collab);
    assert_eq!(a.collab_bytes, b.collab_bytes);
    assert_eq!(a.tiles_filtered, b.tiles_filtered);
}
