//! Multi-threaded `Runtime::execute` smoke test: N threads hammer mixed
//! models and ragged batch sizes concurrently, guarding the
//! `Mutex<HashMap>` caches (compiled executables, calibration costs) and
//! the per-model execution locks.  Every thread's results must match a
//! single-threaded reference run.

use tiansuan::runtime::{Model, Runtime};

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn input(rt: &Runtime, n: usize, seed: u64) -> Vec<f32> {
    let t = rt.manifest.tile;
    let mut rng = tiansuan::util::rng::Rng::new(seed);
    (0..n * t * t * 3).map(|_| rng.f32()).collect()
}

fn out_cols(rt: &Runtime, model: Model) -> usize {
    match model {
        Model::CloudScore => 3,
        _ => rt.manifest.grid * rt.manifest.grid * rt.manifest.head_d,
    }
}

#[test]
fn concurrent_execute_mixed_models_and_batches() {
    let Some(rt) = rt() else { return };
    let models = [Model::Tiny, Model::Heavy, Model::CloudScore];
    let batch_ns = [1usize, 3, 5];

    // single-threaded reference, computed cold (compiles cache entries)
    let mut reference = Vec::new();
    for (mi, &model) in models.iter().enumerate() {
        for (ni, &n) in batch_ns.iter().enumerate() {
            let inp = input(&rt, n, (mi * 10 + ni) as u64 + 1);
            let out = rt.execute(model, n, &inp).unwrap();
            assert_eq!(out.len(), n * out_cols(&rt, model));
            reference.push(out);
        }
    }

    // 8 threads × every (model, n) combination, interleaved
    let rt_ref = &rt;
    let reference = &reference;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for thread in 0..8usize {
            handles.push(s.spawn(move || {
                for round in 0..3usize {
                    for step in 0..models.len() {
                        for (ni, &n) in batch_ns.iter().enumerate() {
                            // skew the order per thread so lock acquisition interleaves
                            let mi = (step + thread + round) % models.len();
                            let model = models[mi];
                            let inp = input(rt_ref, n, (mi * 10 + ni) as u64 + 1);
                            let out = rt_ref.execute(model, n, &inp).unwrap();
                            let want = &reference[mi * batch_ns.len() + ni];
                            assert_eq!(out.len(), want.len());
                            for (a, b) in out.iter().zip(want) {
                                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn concurrent_calibrate_and_execute() {
    // calibrate mutates the costs cache while executes are in flight;
    // plans may change between calls but results must stay correct.
    let Some(rt) = rt() else { return };
    let rt_ref = &rt;
    let n = 5usize;
    let inp = input(&rt, n, 42);
    let want = rt.execute(Model::Tiny, n, &inp).unwrap();
    let inp = &inp;
    let want = &want;
    std::thread::scope(|s| {
        let cal = s.spawn(move || rt_ref.calibrate().unwrap());
        for _ in 0..4 {
            s.spawn(move || {
                for _ in 0..4 {
                    let out = rt_ref.execute(Model::Tiny, n, inp).unwrap();
                    for (a, b) in out.iter().zip(want) {
                        assert!((a - b).abs() < 1e-4);
                    }
                }
            });
        }
        cal.join().unwrap();
    });
}
