//! Power subsystem invariants (ISSUE 3 acceptance):
//!
//! * SoC always stays within `[0, capacity]`, whatever the flows;
//! * an eclipse-heavy mission on an undersized battery shows the
//!   governor deferring drains and shedding captures, and that keeps
//!   the battery out of brownout where the ungoverned mission empties
//!   it;
//! * an oversized battery never intervenes, and through the
//!   constellation runner reproduces the unconstrained mission
//!   scene-for-scene.
//!
//! The flight-profile tests are artifact-free (they exercise
//! `power::fly_mission` over a real orbital [`Timeline`]); the
//! constellation tests need `rust/artifacts/` like every other
//! integration test and skip when it is absent.

use tiansuan::config::{Config, EnergyConfig, PowerConfig, TimingConfig};
use tiansuan::coordinator::run_constellation;
use tiansuan::data::Version;
use tiansuan::orbit::{baoyun, beijing_station};
use tiansuan::power::{fly_mission, PowerState};
use tiansuan::runtime::Runtime;
use tiansuan::sim::{DutyCycles, Timeline};

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

/// Baoyun over Beijing: ~38% of every revolution in Earth's shadow.
fn orbital_timeline(horizon_s: f64) -> Timeline {
    Timeline::orbital(&TimingConfig::default(), &baoyun(), &beijing_station(), horizon_s, 10.0)
}

fn active() -> DutyCycles {
    DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 }
}

/// Low-idle hardware (the configurable floors exist exactly for this):
/// always-on platform + science ≈ 37.7 W idle vs ≈ 52 W at full duty.
fn low_idle() -> EnergyConfig {
    EnergyConfig { pi_idle_floor: 0.0, comm_idle_floor: 0.0 }
}

/// Undersized for the full-duty mission: 95 W × 0.8 derate generates
/// ~76 W sunlit, below the ~55 W full-duty battery draw averaged over
/// the ~38% eclipse — sustainable only if the governor intervenes.
fn eclipse_heavy_power(battery_wh: f64) -> PowerConfig {
    PowerConfig {
        enabled: true,
        battery_wh,
        panel_w: 95.0,
        cosine_derate: 0.8,
        charge_eff: 0.95,
        discharge_eff: 0.95,
        initial_soc: 0.4,
        soc_defer: 0.6,
        soc_critical: 0.3,
        defer_tighten: 0.2,
    }
}

#[test]
fn soc_always_within_bounds() {
    // batteries from absurdly small to oversized: SoC must clamp at
    // both rails, never wrap or overshoot
    let tl = orbital_timeline(30_000.0);
    for battery_wh in [0.5, 5.0, 60.0, 5_000.0] {
        let mut s = PowerState::new(&eclipse_heavy_power(battery_wh), &low_idle());
        fly_mission(&mut s, &tl, active(), 30.0);
        assert!(
            (0.0..=1.0).contains(&s.soc_frac()),
            "battery {battery_wh} Wh ended at soc {}",
            s.soc_frac()
        );
        assert!((0.0..=1.0).contains(&s.stats.min_soc_frac));
        assert!((0.0..=1.0).contains(&s.stats.mean_soc_frac()));
        assert!(s.stats.min_soc_frac <= s.stats.mean_soc_frac() + 1e-12);
        assert!(s.stats.generated_wh >= 0.0 && s.stats.consumed_wh > 0.0);
    }
}

#[test]
fn governor_defers_and_sheds_to_protect_soc() {
    // ~4 revolutions of an eclipse-heavy orbit on an undersized battery:
    // the governor must visibly defer and shed, and doing so must keep
    // the battery out of brownout.
    //
    // Semantics note: "min SoC stays at soc_critical" cannot hold
    // literally in this load model — shedding only idles the camera,
    // compute, and transmitter, while the always-on platform + science
    // payloads (~37.7 W here) keep draining through eclipse, so SoC
    // necessarily dips below the shed threshold before sunrise.  The
    // guarantee the governor *can* make, and the one asserted here, is
    // that no capture executes below soc_critical and the battery never
    // browns out (shortfall_wh == 0) where the ungoverned mission empties
    // it.
    let tl = orbital_timeline(23_000.0);
    let mut governed = PowerState::new(&eclipse_heavy_power(60.0), &low_idle());
    fly_mission(&mut governed, &tl, active(), 30.0);
    assert!(governed.stats.scenes_deferred > 0, "defer band never entered");
    assert!(governed.stats.scenes_shed > 0, "shed band never entered");
    assert_eq!(governed.stats.shortfall_wh, 0.0, "governor must prevent brownout");
    assert!(
        governed.stats.min_soc_frac > 0.03,
        "governed min SoC collapsed: {}",
        governed.stats.min_soc_frac
    );

    // same battery, governor disabled (thresholds at zero): the
    // full-duty mission overruns it
    let mut blind_cfg = eclipse_heavy_power(60.0);
    blind_cfg.soc_defer = 0.0;
    blind_cfg.soc_critical = 0.0;
    let mut blind = PowerState::new(&blind_cfg, &low_idle());
    fly_mission(&mut blind, &tl, active(), 30.0);
    assert_eq!(blind.stats.scenes_shed, 0);
    assert_eq!(blind.stats.scenes_deferred, 0);
    assert!(blind.stats.shortfall_wh > 0.0, "the ungoverned mission must brown out");
    assert!(blind.stats.min_soc_frac < 0.01);
    assert!(governed.stats.min_soc_frac > blind.stats.min_soc_frac);
}

#[test]
fn oversized_battery_never_intervenes() {
    let tl = orbital_timeline(23_000.0);
    let mut cfg = eclipse_heavy_power(100_000.0);
    cfg.initial_soc = 1.0;
    let mut s = PowerState::new(&cfg, &low_idle());
    fly_mission(&mut s, &tl, active(), 30.0);
    assert_eq!(s.stats.scenes_deferred, 0);
    assert_eq!(s.stats.scenes_shed, 0);
    assert_eq!(s.stats.shortfall_wh, 0.0);
    assert!(s.stats.min_soc_frac > cfg.soc_defer, "oversized battery barely moves");
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 1;
    cfg.constellation.scenes_per_satellite = 3;
    cfg.loss_profile = "lossless".into();
    cfg
}

#[test]
fn oversized_battery_reproduces_unconstrained_mission() {
    // With an oversized battery the governor is Nominal at every capture,
    // so the run must match the power-disabled mission scene-for-scene.
    let Some(rt) = rt() else { return };
    let cfg = small_cfg();
    let base = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let mut pcfg = cfg.clone();
    pcfg.power.enabled = true;
    pcfg.power.battery_wh = 1_000_000.0;
    pcfg.power.initial_soc = 1.0;
    let powered = run_constellation(&rt, &pcfg, Version::V2).unwrap();

    let (a, b) = (&base.satellites[0], &powered.satellites[0]);
    assert_eq!(b.result.scenes, a.result.scenes);
    assert_eq!(b.result.tiles_total, a.result.tiles_total);
    assert_eq!(b.result.tiles_filtered, a.result.tiles_filtered);
    assert_eq!(b.result.router.onboard_final, a.result.router.onboard_final);
    assert_eq!(b.result.router.offloaded, a.result.router.offloaded);
    assert_eq!(b.result.map_inorbit.to_bits(), a.result.map_inorbit.to_bits());
    assert_eq!(b.result.map_collab.to_bits(), a.result.map_collab.to_bits());
    assert_eq!(b.result.bentpipe_bytes, a.result.bentpipe_bytes);
    assert_eq!(b.result.collab_bytes, a.result.collab_bytes);
    assert_eq!(
        b.result.energy_compute_share.to_bits(),
        a.result.energy_compute_share.to_bits()
    );
    assert_eq!(b.downlink.items_delivered, a.downlink.items_delivered);

    // power stats exist only on the powered run, and show no intervention
    assert!(a.power.is_none() && a.result.power.is_none());
    let p = b.power.expect("power stats present when enabled");
    assert_eq!(p.scenes_shed, 0);
    assert_eq!(p.scenes_deferred, 0);
    assert_eq!(p.shortfall_wh, 0.0);
    assert!(b.result.power.is_some());
}

#[test]
fn dead_battery_sheds_every_capture() {
    // No panel, empty battery: the governor sheds every capture; the
    // run still completes, folds zero scenes, and accounts them as shed.
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.power.enabled = true;
    cfg.power.battery_wh = 10.0;
    cfg.power.panel_w = 0.0;
    cfg.power.initial_soc = 0.0;
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let sat = &report.satellites[0];
    let p = sat.power.expect("power stats present");
    assert_eq!(p.scenes_shed, 3, "every capture shed");
    assert_eq!(sat.result.scenes, 0);
    assert_eq!(sat.result.tiles_total, 0);
    assert_eq!(sat.downlink.items_delivered, 0);
    assert_eq!(p.min_soc_frac, 0.0);
    assert!(
        report.telemetry.contains("counter power.scenes_shed 3"),
        "{}",
        report.telemetry
    );
}

#[test]
fn deferral_delays_drains_and_tightens_router() {
    // Mid-band SoC with a huge battery: every capture defers.  With
    // ideal contact + lossless link and zero tighten step the routing
    // and byte accounting match the unconstrained run exactly, and the
    // deferred drains all land in the mission tail — every item still
    // arrives, just later; with a real tighten step the router offloads
    // no more than the unconstrained policy did.
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.ideal_contact = true;
    let base = run_constellation(&rt, &cfg, Version::V2).unwrap();

    let mut defer_cfg = cfg.clone();
    defer_cfg.power.enabled = true;
    defer_cfg.power.battery_wh = 1_000_000.0;
    defer_cfg.power.initial_soc = 0.5;
    defer_cfg.power.soc_defer = 0.9;
    defer_cfg.power.soc_critical = 0.0;
    defer_cfg.power.defer_tighten = 0.0;
    let deferred = run_constellation(&rt, &defer_cfg, Version::V2).unwrap();
    let (a, d) = (&base.satellites[0], &deferred.satellites[0]);
    let p = d.power.expect("power stats present");
    assert_eq!(p.scenes_deferred, 3, "every capture deferred");
    assert_eq!(p.scenes_shed, 0);
    assert_eq!(d.result.scenes, a.result.scenes);
    assert_eq!(d.result.router.offloaded, a.result.router.offloaded);
    assert_eq!(d.result.collab_bytes, a.result.collab_bytes);
    assert_eq!(d.downlink.items_delivered, a.downlink.items_delivered);
    assert!(
        d.downlink.mean_latency_s() >= a.downlink.mean_latency_s(),
        "deferred drains cannot arrive earlier: {} vs {}",
        d.downlink.mean_latency_s(),
        a.downlink.mean_latency_s()
    );

    let mut tight_cfg = defer_cfg.clone();
    tight_cfg.power.defer_tighten = 0.5;
    let tightened = run_constellation(&rt, &tight_cfg, Version::V2).unwrap();
    let t = &tightened.satellites[0];
    assert_eq!(t.power.expect("power stats").scenes_deferred, 3);
    assert!(
        t.result.router.offloaded <= a.result.router.offloaded,
        "a tightened threshold cannot offload more"
    );
    assert_eq!(t.result.tiles_total, a.result.tiles_total);
}
