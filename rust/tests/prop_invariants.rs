//! Property-based tests over coordinator + substrate invariants.
//!
//! proptest is not in the offline vendor set, so these use the repo's
//! deterministic RNG with many random cases per property (shrinking is
//! traded for a printed failing seed).

use tiansuan::coordinator::batcher::Batcher;
use tiansuan::coordinator::router::{route, RouterPolicy, RouterStats};
use tiansuan::coordinator::TileFate;
use tiansuan::data::{split_scene, GtBox, SceneGen, Tile, Version};
use tiansuan::detect::{average_precision, iou_xywh, nms, Detection};
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::orbit::{baoyun, contact_windows, GroundStation, Satellite};
use tiansuan::util::json::Json;
use tiansuan::util::rng::Rng;

const CASES: usize = 200;

fn rand_det(rng: &mut Rng) -> Detection {
    Detection {
        cx: rng.range_f32(0.0, 64.0),
        cy: rng.range_f32(0.0, 64.0),
        w: rng.range_f32(1.0, 30.0),
        h: rng.range_f32(1.0, 30.0),
        score: rng.f32(),
        class: rng.below(8) as usize,
    }
}

#[test]
fn prop_iou_bounds_and_symmetry() {
    let mut rng = Rng::new(1);
    for case in 0..CASES {
        let a = rand_det(&mut rng);
        let b = rand_det(&mut rng);
        let ab = iou_xywh((a.cx, a.cy, a.w, a.h), (b.cx, b.cy, b.w, b.h));
        let ba = iou_xywh((b.cx, b.cy, b.w, b.h), (a.cx, a.cy, a.w, a.h));
        assert!((0.0..=1.0).contains(&ab), "case {case}: iou {ab}");
        assert!((ab - ba).abs() < 1e-6, "case {case}: asymmetric {ab} vs {ba}");
        let aa = iou_xywh((a.cx, a.cy, a.w, a.h), (a.cx, a.cy, a.w, a.h));
        assert!((aa - 1.0).abs() < 1e-6, "case {case}: self-iou {aa}");
    }
}

#[test]
fn prop_nms_no_same_class_overlap_and_sorted() {
    let mut rng = Rng::new(2);
    for case in 0..CASES {
        let n = rng.range_usize(0, 40);
        let dets: Vec<Detection> = (0..n).map(|_| rand_det(&mut rng)).collect();
        let thresh = rng.range_f32(0.1, 0.9);
        let kept = nms(dets.clone(), thresh);
        assert!(kept.len() <= dets.len());
        for i in 0..kept.len() {
            for j in (i + 1)..kept.len() {
                assert!(kept[i].score >= kept[j].score, "case {case}: not sorted");
                if kept[i].class == kept[j].class {
                    let iou = kept[i].iou(&kept[j]);
                    assert!(iou <= thresh + 1e-6, "case {case}: kept overlap {iou} > {thresh}");
                }
            }
        }
    }
}

#[test]
fn prop_ap_in_unit_interval() {
    let mut rng = Rng::new(3);
    for case in 0..CASES {
        let n = rng.range_usize(0, 30);
        let gt = rng.range_usize(0, 20);
        // valid record streams have at most `gt` true positives (the
        // Evaluator matches each ground-truth box at most once)
        let mut tp_left = gt;
        let recs: Vec<(f32, bool)> = (0..n)
            .map(|_| {
                let tp = tp_left > 0 && rng.bool(0.5);
                if tp {
                    tp_left -= 1;
                }
                (rng.f32(), tp)
            })
            .collect();
        let ap = average_precision(&recs, gt);
        assert!((0.0..=1.0).contains(&ap), "case {case}: ap {ap} (gt {gt}, n {n})");
    }
}

#[test]
fn prop_router_conservation() {
    // every routed tile lands in exactly one bucket; stats add up
    let mut rng = Rng::new(4);
    let policy = RouterPolicy::default();
    let mut stats = RouterStats::default();
    let mut total = 0u64;
    for _ in 0..CASES {
        let n = rng.range_usize(0, 5);
        let dets: Vec<Detection> = (0..n).map(|_| rand_det(&mut rng)).collect();
        let best = rng.f32();
        let fate = route(&policy, &dets, best, &mut stats);
        total += 1;
        assert!(matches!(fate, TileFate::OnboardFinal | TileFate::Offloaded));
    }
    assert_eq!(stats.total(), total);
    assert!(stats.confidently_empty <= stats.onboard_final);
}

#[test]
fn prop_batcher_bounds_and_conservation() {
    let mut rng = Rng::new(5);
    for case in 0..50 {
        let max_b = rng.range_usize(1, 10);
        let mut b = Batcher::new(max_b, rng.range_f64(0.1, 5.0));
        let n = rng.range_usize(0, 40);
        let mut now = 0.0;
        let mut popped = 0usize;
        let mut delays = Vec::new();
        for _ in 0..n {
            b.push(
                Tile { scene_id: 0, x0: 0, y0: 0, frag: 64, pixels: vec![].into(), gt: vec![] },
                now,
            );
            now += rng.range_f64(0.0, 1.0);
            if let Some(tiles) = b.pop(now, false, &mut delays) {
                assert!(tiles.len() <= max_b, "case {case}: batch too big");
                assert!(!tiles.is_empty());
                assert_eq!(delays.len(), tiles.len(), "case {case}: delays refilled per pop");
                assert!(delays.iter().all(|&d| d >= 0.0));
                popped += tiles.len();
            }
        }
        while let Some(tiles) = b.pop(now, true, &mut delays) {
            popped += tiles.len();
        }
        assert_eq!(popped, n, "case {case}: tiles lost or duplicated");
    }
}

#[test]
fn prop_link_byte_conservation() {
    let mut rng = Rng::new(6);
    for case in 0..40 {
        let profile = *rng.choose(&[
            LossProfile::stable(),
            LossProfile::weak(),
            LossProfile::makersat_incident(),
        ]);
        let mut link = Link::new(LinkConfig { rate_bps: 40e6, mtu: 1400, loss: profile, max_tries: 4 }, case);
        let mut offered = 0u64;
        for _ in 0..rng.range_usize(1, 20) {
            let bytes = rng.below(200_000) + 1;
            offered += bytes;
            let t = link.transmit(bytes, rng.range_f64(0.001, 2.0));
            assert!(t.bytes_delivered <= t.bytes_requested);
            assert!(t.elapsed_s >= 0.0);
        }
        assert_eq!(link.stats.bytes_offered, offered, "case {case}");
        assert!(link.stats.bytes_delivered <= offered);
        assert!(link.stats.packets_lost <= link.stats.packets_sent);
    }
}

#[test]
fn prop_contact_windows_disjoint_for_random_geometry() {
    let mut rng = Rng::new(7);
    for case in 0..12 {
        let sat = Satellite {
            name: format!("sat{case}"),
            altitude_km: rng.range_f64(400.0, 800.0),
            inclination_rad: rng.range_f64(0.5, 1.8),
            raan_rad: rng.range_f64(0.0, 6.28),
            phase_rad: rng.range_f64(0.0, 6.28),
        };
        let gs = GroundStation {
            name: "g".into(),
            lat_deg: rng.range_f64(-60.0, 60.0),
            lon_deg: rng.range_f64(-180.0, 180.0),
            min_elevation_deg: rng.range_f64(5.0, 20.0),
        };
        let windows = contact_windows(&sat, &gs, 0.0, 43_200.0, 10.0);
        for pair in windows.windows(2) {
            assert!(pair[0].los <= pair[1].aos, "case {case}: overlap {pair:?}");
        }
        for w in &windows {
            assert!(w.duration_s() > 0.0 && w.aos >= 0.0 && w.los <= 43_200.0 + 1e-6);
        }
    }
}

#[test]
fn prop_split_conserves_ground_truth() {
    let mut rng = Rng::new(8);
    for case in 0..10 {
        let cells = rng.range_usize(2, 7);
        let mut gen = SceneGen::new(case as u64, Version::V2.spec(), cells, cells);
        let scene = gen.capture();
        for frag in [32usize, 64, 128] {
            if (cells * 64) % frag != 0 {
                continue;
            }
            let tiles = split_scene(&scene, frag);
            let total: usize = tiles.iter().map(|t| t.gt.len()).sum();
            assert_eq!(total, scene.boxes.len(), "case {case} frag {frag}");
            for t in &tiles {
                for b in &t.gt {
                    let in_bounds = |b: &GtBox| b.cx >= 0.0 && b.cx <= 64.0 && b.cy >= 0.0 && b.cy <= 64.0;
                    assert!(in_bounds(b), "case {case}: gt escaped tile: {b:?}");
                }
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    let mut rng = Rng::new(9);
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0 * 0.5).round() / 8.0),
            3 => Json::Str((0..rng.range_usize(0, 12)).map(|_| {
                *rng.choose(&['a', 'b', '"', '\\', '\n', '字', ' ', '0'])
            }).collect()),
            4 => Json::Arr((0..rng.range_usize(0, 5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.range_usize(0, 5)).map(|i| {
                (format!("k{i}"), gen_value(rng, depth - 1))
            }).collect()),
        }
    }
    for case in 0..CASES {
        let v = gen_value(&mut rng, 3);
        let text = v.to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(parsed, v, "case {case}: roundtrip mismatch\n{text}");
    }
}

#[test]
fn prop_orbit_radius_invariant_under_time() {
    let sat = baoyun();
    let mut rng = Rng::new(10);
    let a = sat.semi_major_axis_km();
    for _ in 0..CASES {
        let t = rng.range_f64(0.0, 1e6);
        let p = sat.position_eci(t);
        let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
        assert!((r - a).abs() < 1e-6, "t={t}: r={r}");
    }
}
