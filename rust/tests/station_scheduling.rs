//! Multi-station contact plane: scheduling + byte-attribution invariants.
//!
//! Two invariant families, explicitly gated by `ci.sh`:
//!
//! * **Structural** (artifact-free): the planned merged track is sorted,
//!   pairwise-disjoint, station-tagged, and free of zero-length slices —
//!   so "one satellite never transmits to two stations simultaneously"
//!   holds by construction, for circular and TLE-propagated geometry
//!   alike.  The default single-station configuration plans to the
//!   identity and keeps the legacy timeline bit-for-bit.
//! * **Accounting** (engine runs, skipped without `artifacts/`): every
//!   satellite's per-station delivered bytes sum to its
//!   `DownlinkStats` total in both constellation engines, the two
//!   engines agree on the attribution, and the fleet engine's
//!   attribution is invariant under the shard count.

use tiansuan::config::{Config, StationConfig};
use tiansuan::coordinator::downlink::{DownlinkItem, DownlinkQueue, ItemKind};
use tiansuan::coordinator::{
    mission_timeline, plane_satellite, run_constellation, run_fleet, station_network,
    ConstellationReport, ContactScheduler, CONTACT_SCAN_STEP_S,
};
use tiansuan::data::Version;
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::orbit::{beijing_station, ContactWindow, Tle, TlePropagator};
use tiansuan::runtime::Runtime;
use tiansuan::sim::Timeline;

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn station(name: &str, lat: f64, lon: f64, mask: f64) -> StationConfig {
    StationConfig { name: name.into(), lat_deg: lat, lon_deg: lon, min_elevation_deg: mask }
}

/// Beijing plus two well-separated Chinese stations — a ground segment
/// with both disjoint passes and genuine overlap windows.
fn three_station_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.stations = vec![
        StationConfig::default(),
        station("Kashi", 39.47, 75.98, 10.0),
        station("Sanya", 18.23, 109.5, 10.0),
    ];
    cfg
}

fn assert_disjoint_tagged(windows: &[ContactWindow], n_stations: usize, ctx: &str) {
    for w in windows {
        assert!(w.station_id < n_stations, "{ctx}: untagged window {w:?}");
        assert!(w.duration_s() > 0.0, "{ctx}: zero-length slice {w:?}");
    }
    for pair in windows.windows(2) {
        assert!(
            pair[1].aos >= pair[0].los,
            "{ctx}: overlapping commitments {:?} / {:?} — one transmitter, two stations",
            pair[0],
            pair[1]
        );
    }
}

#[test]
fn planned_track_never_overlaps_for_any_geometry() {
    let cfg = three_station_cfg();
    let net = station_network(&cfg);
    for index in 0..4 {
        let sat = plane_satellite(&cfg, index, &format!("sat-{index}"));
        let tracks = net.contact_tracks(&sat, 0.0, 86_400.0, CONTACT_SCAN_STEP_S);
        let (plan, stats) = ContactScheduler::greedy().plan(&tracks);
        assert_disjoint_tagged(&plan, 3, &format!("sat {index}"));
        assert!(!plan.is_empty(), "sat {index}: a day over 3 stations must have passes");
        assert_eq!(stats.decisions as usize, plan.len());
        // the plan covers at least as much airtime as the best single
        // station and at most the raw union
        let best = tracks
            .iter()
            .map(|t| t.iter().map(|w| w.duration_s()).sum::<f64>())
            .fold(0.0, f64::max);
        let sum: f64 = tracks.iter().flatten().map(|w| w.duration_s()).sum();
        let planned: f64 = plan.iter().map(|w| w.duration_s()).sum();
        assert!(planned >= best - 1e-9, "sat {index}: planned {planned} < best single {best}");
        assert!(planned <= sum + 1e-9, "sat {index}: planned {planned} > union bound {sum}");
    }
}

#[test]
fn tle_geometry_schedules_cleanly_too() {
    // The scheduler must be propagator-agnostic: plan a day of the ISS
    // (canonical TLE) over the three-station segment.
    let tle = Tle::parse(
        "ISS (ZARYA)",
        "1 25544U 98067A   08264.51782528 -.00002182  00000-0 -11606-4 0  2927",
        "2 25544  51.6416 247.4627 0006703 130.5360 325.0288 15.72125391563537",
    )
    .unwrap();
    let prop = TlePropagator::new(&tle);
    let cfg = three_station_cfg();
    let net = station_network(&cfg);
    let tracks = net.contact_tracks(&prop, 0.0, 86_400.0, CONTACT_SCAN_STEP_S);
    let (plan, _) = ContactScheduler::greedy().plan(&tracks);
    assert_disjoint_tagged(&plan, 3, "iss");
    assert!(!plan.is_empty(), "an ISS day over China must contain passes");
}

#[test]
fn colocated_overlapping_stations_produce_no_zero_length_slices() {
    // Regression: a co-located wide-mask pair sees near-identical passes;
    // the shared boundaries must not leak zero-length slivers into the
    // plan or the consumed slices.
    let mut cfg = Config::default();
    cfg.stations = vec![
        StationConfig::default(),
        station("Beijing-wide", 39.96, 116.35, 5.0),
    ];
    cfg.constellation.horizon_s = 86_400.0;
    let sat = plane_satellite(&cfg, 0, "colocated");
    let net = station_network(&cfg);
    let mut tl = mission_timeline(&cfg, &sat, &net);
    let slices = tl.remaining_contacts();
    assert!(!slices.is_empty());
    for s in &slices {
        assert!(s.window.duration_s() > 0.0, "zero-length slice {:?}", s.window);
        assert!(s.window.station_id < 2);
    }
    for pair in slices.windows(2) {
        assert!(pair[0].window.los <= pair[1].window.aos, "overlap: {pair:?}");
    }
}

#[test]
fn default_single_station_timeline_is_bit_identical_to_legacy() {
    let cfg = Config::default();
    assert_eq!(cfg.stations.len(), 1, "default ground segment is Beijing alone");
    let sat = plane_satellite(&cfg, 2, "parity-sat");
    let net = station_network(&cfg);
    let tl = mission_timeline(&cfg, &sat, &net);
    let legacy = Timeline::orbital(
        &cfg.timing,
        &sat,
        &beijing_station(),
        cfg.constellation.horizon_s,
        10.0,
    );
    assert_eq!(tl.n_contacts(), legacy.n_contacts());
    assert_eq!(tl.contact_total_s().to_bits(), legacy.contact_total_s().to_bits());
    assert_eq!(
        tl.sunlit_s(0.0, cfg.constellation.horizon_s).to_bits(),
        legacy.sunlit_s(0.0, cfg.constellation.horizon_s).to_bits()
    );
}

#[test]
fn synthetic_drains_attribute_bytes_per_station_exactly() {
    // Station attribution at the queue level, no engines involved: items
    // drain through windows tagged with different stations; per-station
    // bytes must partition the delivered total.
    let win = |aos: f64, los: f64, id: usize| ContactWindow {
        aos,
        los,
        max_elevation_deg: 45.0,
        truncated: false,
        station_id: id,
    };
    let mut q = DownlinkQueue::new();
    let mut link = Link::new(LinkConfig::downlink(LossProfile::stable()), 42);
    for i in 0..30u64 {
        q.push(DownlinkItem {
            kind: if i % 3 == 0 { ItemKind::Results } else { ItemKind::Image },
            bytes: 40_000 + i * 1000,
            ready_at: 0.0,
            tag: i,
        });
    }
    // alternate short passes over stations 0/1/2 until the queue is dry
    // (~0.08 s at 40 Mbps ≈ 400 KB: a handful of items per pass, so the
    // backlog visibly spreads across the segment)
    let mut t = 0.0;
    let mut pass = 0usize;
    while q.pending() > 0 && pass < 60 {
        q.drain_window(&mut link, &win(t, t + 0.08, pass % 3));
        t += 600.0;
        pass += 1;
    }
    assert_eq!(q.pending(), 0, "queue must drain within the allotted passes");
    let total_attributed: u64 = q.stats.station_bytes.iter().sum();
    assert_eq!(total_attributed, q.stats.total_bytes(), "attribution must partition the total");
    let used = q.stats.station_bytes.iter().filter(|&&b| b > 0).count();
    assert!(used >= 2, "alternating passes must touch several stations, got {used}");
}

// ---- engine-level accounting (needs artifacts/) ----------------------

fn multi_station_cfg() -> Config {
    let mut cfg = three_station_cfg();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 3;
    cfg.constellation.scenes_per_satellite = 2;
    cfg
}

fn assert_station_accounting(report: &ConstellationReport, n_stations: usize, ctx: &str) {
    for sat in &report.satellites {
        let dl = &sat.downlink;
        assert!(
            dl.station_bytes.len() <= n_stations,
            "{ctx} sat {}: attribution to unknown station {:?}",
            sat.index,
            dl.station_bytes
        );
        let sum: u64 = dl.station_bytes.iter().sum();
        assert_eq!(
            sum,
            dl.total_bytes(),
            "{ctx} sat {}: per-station bytes must sum to the delivered total",
            sat.index
        );
    }
}

#[test]
fn thread_engine_station_bytes_sum_to_totals() {
    let Some(rt) = rt() else { return };
    let cfg = multi_station_cfg();
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    assert_station_accounting(&report, 3, "threads");
}

#[test]
fn fleet_engine_matches_thread_engine_station_attribution() {
    let Some(rt) = rt() else { return };
    let cfg = multi_station_cfg();
    let threads = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let fleet = run_fleet(&rt, &cfg, Version::V2).unwrap();
    assert_station_accounting(&fleet, 3, "fleet");
    assert_eq!(threads.satellites.len(), fleet.satellites.len());
    for (a, b) in threads.satellites.iter().zip(&fleet.satellites) {
        assert_eq!(a.downlink.items_delivered, b.downlink.items_delivered, "sat {}", a.index);
        assert_eq!(a.downlink.total_bytes(), b.downlink.total_bytes(), "sat {}", a.index);
        assert_eq!(
            a.downlink.station_bytes, b.downlink.station_bytes,
            "sat {}: engines disagree on station attribution",
            a.index
        );
        assert_eq!(a.windows, b.windows, "sat {}", a.index);
        assert_eq!(a.contact_s.to_bits(), b.contact_s.to_bits(), "sat {}", a.index);
    }
}

#[test]
fn fleet_station_attribution_is_invariant_under_shard_count() {
    let Some(rt) = rt() else { return };
    let mut cfg = multi_station_cfg();
    cfg.constellation.satellites = 4;
    cfg.fleet.shards = 1;
    let one = run_fleet(&rt, &cfg, Version::V2).unwrap();
    assert_station_accounting(&one, 3, "1-shard");
    for shards in [2, 4, 8] {
        cfg.fleet.shards = shards;
        let many = run_fleet(&rt, &cfg, Version::V2).unwrap();
        for (a, b) in one.satellites.iter().zip(&many.satellites) {
            assert_eq!(
                a.downlink.station_bytes, b.downlink.station_bytes,
                "sat {}: attribution changed with shards={shards}",
                a.index
            );
            assert_eq!(a.downlink.total_bytes(), b.downlink.total_bytes(), "sat {}", a.index);
        }
    }
}

#[test]
fn default_config_reports_are_unchanged_by_the_station_refactor() {
    // The whole refactor rides behind the default single-Beijing config:
    // both engines must produce single-entry (or empty) station vectors
    // whose one entry is the total.
    let Some(rt) = rt() else { return };
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 2;
    cfg.constellation.scenes_per_satellite = 2;
    for report in [
        run_constellation(&rt, &cfg, Version::V2).unwrap(),
        run_fleet(&rt, &cfg, Version::V2).unwrap(),
    ] {
        for sat in &report.satellites {
            assert!(sat.downlink.station_bytes.len() <= 1, "sat {}", sat.index);
            assert_eq!(sat.downlink.station_bytes(0), sat.downlink.total_bytes());
        }
    }
}
