//! Zero-copy hot-data-path correctness.
//!
//! The row-sliced tiler and pooled buffers must be *byte-for-byte*
//! equivalent to the pre-refactor per-pixel implementation:
//! `data::reference_cut` is that implementation, retained verbatim and
//! frozen in `data::tiler` (one copy, shared with the perf baseline in
//! `benches/perf_datapath.rs`), and every
//! `split_scene`/`split_scene_pooled` output is pinned against it
//! (pixels by f32 bit pattern + FNV checksum, ground truth exactly).
//! The pool tests assert the ISSUE's steady-state invariant: after
//! warmup, scene processing performs zero per-tile pixel-buffer
//! allocations.

use tiansuan::config::Config;
use tiansuan::coordinator::cloudfilter::{
    is_redundant_f32, is_redundant_quant, quant_threshold, quantize_pixels, white_count_quant,
    white_frac_f32, QUANT_SCALE,
};
use tiansuan::coordinator::router::RouterStats;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::{
    reference_cut, split_scene, split_scene_pooled, SceneGen, Version, TILE_PX,
};
use tiansuan::runtime::Runtime;
use tiansuan::util::buffer::PixelPool;

/// FNV-1a over the f32 bit patterns — the "golden checksum".
fn checksum(pixels: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for p in pixels {
        for b in p.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[test]
fn split_scene_matches_naive_reference_byte_for_byte() {
    for (version, seed) in [(Version::V1, 3u64), (Version::V2, 9), (Version::V2, 41)] {
        let scene = SceneGen::new(seed, version.spec(), 4, 4).capture();
        let pool = PixelPool::new(TILE_PX);
        // every kernel shape: deep upsample (16→64), 2× both ways, the
        // identity copy, and deep box filter (256→64) — all byte-for-byte
        for frag in [16usize, 32, 64, 128, 256] {
            let plain = split_scene(&scene, frag);
            let pooled = split_scene_pooled(&scene, frag, &pool);
            let mut i = 0;
            for y0 in (0..scene.height).step_by(frag) {
                for x0 in (0..scene.width).step_by(frag) {
                    let (want_px, want_gt) = reference_cut(&scene, x0, y0, frag);
                    for t in [&plain[i], &pooled[i]] {
                        assert_eq!(
                            checksum(&t.pixels),
                            checksum(&want_px),
                            "{} seed {seed} frag {frag} tile ({x0},{y0}): checksum diverged",
                            version.name()
                        );
                        assert!(
                            t.pixels.iter().zip(&want_px).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "{} seed {seed} frag {frag} tile ({x0},{y0}): pixels diverged",
                            version.name()
                        );
                        assert_eq!(t.gt, want_gt, "frag {frag} tile ({x0},{y0}): gt rescale");
                    }
                    i += 1;
                }
            }
            assert_eq!(i, plain.len());
        }
    }
}

#[test]
fn pool_checkout_return_balance_and_clearing() {
    let pool = PixelPool::new(TILE_PX);
    let scene = SceneGen::new(5, Version::V2.spec(), 4, 4).capture();
    {
        let mut tiles = split_scene_pooled(&scene, 64, &pool);
        // dirty one buffer beyond what the next split will overwrite is
        // impossible (cut writes every element) — dirty it anyway to
        // prove checkout clears reused storage
        tiles[0].pixels.fill(42.0);
        let s = pool.stats();
        assert_eq!(s.checkouts, 16);
        assert_eq!(s.live(), 16);
    }
    let s = pool.stats();
    assert_eq!(s.returns, 16, "dropped tiles must return their buffers");
    assert_eq!(s.free, 16);
    let buf = pool.checkout();
    assert!(buf.iter().all(|&v| v == 0.0), "reused checkout must be zeroed");
}

#[test]
fn steady_state_split_performs_zero_allocations() {
    let pool = PixelPool::new(TILE_PX);
    let mut gen = SceneGen::new(11, Version::V2.spec(), 4, 4);
    let warmed = {
        let warm = gen.capture();
        drop(split_scene_pooled(&warm, 32, &pool)); // 64 tiles: the high-water mark
        pool.stats().allocs
    }; // warm scene drops here, returning the generator's buffer
    for _ in 0..3 {
        let scene = gen.capture();
        for frag in [32usize, 64, 128] {
            drop(split_scene_pooled(&scene, frag, &pool));
        }
    }
    let s = pool.stats();
    assert_eq!(s.allocs, warmed, "warm pool allocated on the steady-state path");
    assert_eq!(s.checkouts - warmed, s.hits());
    // scene buffers are pooled too: captures beyond the first in-flight
    // scene reuse the generator's buffer
    assert_eq!(gen.pool_stats().allocs, 1, "scene buffer must be reused across captures");
}

// ---- quantized-filter equivalence (artifact-free) ----
//
// Decision tolerance (see DESIGN.md and coordinator::cloudfilter): the
// integer tile rule `count > floor(t·n)` is *exactly* the f32 rule
// `count/n > t` for equal counts, so the i8 and f32 keep/drop decisions
// can differ only when per-pixel whiteness flips across quantization —
// pixels whose min channel lies within one quantization step (1/127) of
// the white threshold.  A disagreeing tile must therefore (a) contain
// such ambiguous pixels and (b) have its white fraction within
// `ambiguous/n` of the decision threshold.

/// The CloudScore kernel's white threshold
/// (python/compile/kernels/cloudscore.py, mirrored in the manifest).
const WHITE: f32 = 0.72;

/// Pixels whose min channel is within one quantization step of WHITE —
/// the only pixels whose whiteness may differ between f32 and i8.
fn ambiguous_pixels(pixels: &[f32]) -> usize {
    pixels
        .chunks_exact(3)
        .filter(|p| {
            let m = p[0].min(p[1]).min(p[2]);
            (m - WHITE).abs() <= 1.0 / QUANT_SCALE
        })
        .count()
}

fn tile_decisions(pixels: &[f32], threshold: f32) -> (bool, bool) {
    let f = is_redundant_f32(white_frac_f32(pixels, WHITE), threshold);
    let mut q = vec![0i8; pixels.len()];
    quantize_pixels(pixels, &mut q);
    let white = white_count_quant(&q, quant_threshold(WHITE));
    let i = is_redundant_quant(white, pixels.len() / 3, threshold);
    (f, i)
}

#[test]
fn i8_decisions_match_f32_within_the_quantization_band() {
    for (version, seed) in
        [(Version::V1, 7u64), (Version::V1, 19), (Version::V2, 23), (Version::V2, 57)]
    {
        let scene = SceneGen::new(seed, version.spec(), 4, 4).capture();
        for threshold in [0.3f32, 0.5, 0.72] {
            for (ti, tile) in split_scene(&scene, 64).iter().enumerate() {
                let (f, i) = tile_decisions(&tile.pixels, threshold);
                if f == i {
                    continue;
                }
                // disagreement is only legal inside the documented band
                let amb = ambiguous_pixels(&tile.pixels);
                let n = (tile.pixels.len() / 3) as f32;
                let wf = white_frac_f32(&tile.pixels, WHITE);
                assert!(
                    amb > 0 && (wf - threshold).abs() <= amb as f32 / n,
                    "{} seed {seed} thr {threshold} tile {ti}: paths disagree \
                     (f32 {f}, i8 {i}) outside the tolerance (wf {wf}, ambiguous {amb})",
                    version.name()
                );
            }
        }
    }
}

#[test]
fn threshold_straddling_tiles_diverge_only_inside_the_band() {
    // 4096-pixel tile, decision threshold 0.5: 2048 solid-white pixels
    // plus one probe pixel decide the tile.
    let n = 4096usize;
    let build = |probe: f32| {
        let mut px = vec![0.1f32; n * 3];
        for p in px[..2048 * 3].iter_mut() {
            *p = 1.0;
        }
        px[2048 * 3..2049 * 3].fill(probe);
        px
    };
    // probe inside the band: > WHITE for f32 but quantizes to
    // round(0.7202·127) = 91 = floor(WHITE·127), not > — the one legal
    // divergence, and the tile's wf sits exactly at the threshold edge
    let px = build(0.7202);
    let (f, i) = tile_decisions(&px, 0.5);
    assert!(f && !i, "band probe must drop on f32 (2049/4096) and keep on i8 (2048/4096)");
    assert_eq!(ambiguous_pixels(&px), 1);
    // probes clear of the band agree on both sides
    let (f, i) = tile_decisions(&build(0.73), 0.5);
    assert!(f && i, "clearly-white probe must drop on both paths");
    let (f, i) = tile_decisions(&build(0.71), 0.5);
    assert!(!f && !i, "clearly-grey probe must keep on both paths");
}

// ---- artifact-gated: the full onboard path over the real runtime ----

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

#[test]
fn onboard_scene_is_allocation_free_after_warmup() {
    let Some(rt) = rt() else { return };
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    let p = Pipeline::new(&rt, cfg);
    let mut gen = p.scene_gen(Version::V2);
    let mut router = RouterStats::default();

    // warmup: first scene populates the tile pool; the marshal scratch
    // is pre-warmed to its single-thread worst case (a gather checkout
    // and an execute tail-pad checkout live at once — whether a scene
    // hits the ragged-tail path depends on its kept-tile count)
    drop((rt.scratch_buf(), rt.scratch_buf()));
    let warm = gen.capture();
    drop(p.onboard_scene(&warm, &mut router).unwrap());
    let tile_warm = p.tile_pool_stats().allocs;
    let scratch_warm = rt.scratch_stats().allocs;
    let rows_warm = rt.rows_stats().allocs;

    for _ in 0..3 {
        let scene = gen.capture();
        let (processed, _, _) = p.onboard_scene(&scene, &mut router).unwrap();
        drop(processed); // fold done; tiles return to the pool
        assert_eq!(
            p.tile_pool_stats().allocs,
            tile_warm,
            "steady-state onboard_scene allocated a tile buffer"
        );
        assert_eq!(
            rt.scratch_stats().allocs,
            scratch_warm,
            "steady-state marshalling allocated a scratch buffer"
        );
        assert_eq!(
            rt.rows_stats().allocs,
            rows_warm,
            "steady-state execute allocated an output-row buffer"
        );
    }
    let s = p.tile_pool_stats();
    assert_eq!(s.checkouts - s.allocs, s.hits());
    assert!(s.hit_rate() > 0.5, "tile pool hit rate {}", s.hit_rate());
}
