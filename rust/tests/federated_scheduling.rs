//! Power-aware federated scheduling invariants (ISSUE 4 acceptance):
//!
//! * round counters reconcile per satellite
//!   (`rounds_completed + rounds_skipped_power == rounds_scheduled`);
//! * an eclipse-heavy mission on an undersized battery skips rounds for
//!   power and still completes others once sunlight recovers the SoC;
//! * with `federated.enabled = false` (the default) no federated state
//!   exists anywhere in the reports or telemetry;
//! * federated uplink bytes appear in the downlink/link accounting when
//!   rounds run through the constellation.
//!
//! The flight-profile tests are artifact-free (they exercise
//! `power::fly_federated_mission` over a real orbital [`Timeline`]); the
//! constellation tests need `rust/artifacts/` like every other
//! integration test and skip when it is absent.

use tiansuan::config::{Config, EnergyConfig, FederatedConfig, PowerConfig, TimingConfig};
use tiansuan::coordinator::run_constellation;
use tiansuan::data::Version;
use tiansuan::orbit::{baoyun, beijing_station};
use tiansuan::power::{fly_federated_mission, PowerState};
use tiansuan::runtime::Runtime;
use tiansuan::sedna::federated::{self, FedScheduler};
use tiansuan::sim::{DutyCycles, Timeline};

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

/// Baoyun over Beijing: ~38% of every revolution in Earth's shadow.
fn orbital_timeline(horizon_s: f64) -> Timeline {
    Timeline::orbital(&TimingConfig::default(), &baoyun(), &beijing_station(), horizon_s, 10.0)
}

/// Undersized for the full-duty mission (same profile as the power
/// invariant tests): the governor and the SoC gate must both bite.
fn eclipse_heavy_power(battery_wh: f64) -> PowerConfig {
    PowerConfig {
        enabled: true,
        battery_wh,
        panel_w: 95.0,
        cosine_derate: 0.8,
        charge_eff: 0.95,
        discharge_eff: 0.95,
        initial_soc: 0.4,
        soc_defer: 0.6,
        soc_critical: 0.3,
        defer_tighten: 0.2,
    }
}

fn low_idle() -> EnergyConfig {
    EnergyConfig { pi_idle_floor: 0.0, comm_idle_floor: 0.0 }
}

fn fed_cfg(round_interval_s: f64, min_soc: f64) -> FederatedConfig {
    FederatedConfig { enabled: true, round_interval_s, min_soc, ..FederatedConfig::default() }
}

#[test]
fn soc_gate_skips_rounds_in_eclipse_and_counters_reconcile() {
    let horizon = 23_000.0; // ~4 revolutions
    let tl = orbital_timeline(horizon);
    let fed = fed_cfg(600.0, 0.6);
    let train_s = federated::train_seconds(fed.epochs, fed.samples_per_node);
    let mut state = PowerState::new(&eclipse_heavy_power(60.0), &low_idle());
    let mut sched = FedScheduler::new(&fed, horizon);
    let active = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
    fly_federated_mission(&mut state, &mut sched, &tl, active, 30.0, train_s);

    let s = &sched.stats;
    assert_eq!(s.rounds_scheduled, 38, "23000 s / 600 s rounds");
    assert_eq!(s.rounds_completed + s.rounds_skipped_power, s.rounds_scheduled);
    assert_eq!(s.participated.len() as u64, s.rounds_scheduled);
    assert!(
        s.rounds_skipped_power > 0,
        "an undersized battery through eclipse must skip rounds (completed {})",
        s.rounds_completed
    );
    assert!(
        s.rounds_completed > 0,
        "sunlit recovery above min_soc must complete rounds (skipped {})",
        s.rounds_skipped_power
    );
    assert_eq!(s.uplink_bytes, s.rounds_completed * sched.wire_bytes());
    assert!(state.stats.training_wh > 0.0, "completed rounds must draw training energy");
    // the training draw is part of total consumption, not beside it
    assert!(state.stats.consumed_wh > state.stats.training_wh);
}

#[test]
fn federated_mission_is_deterministic() {
    let horizon = 12_000.0;
    let tl = orbital_timeline(horizon);
    let fed = fed_cfg(700.0, 0.55);
    let train_s = federated::train_seconds(fed.epochs, fed.samples_per_node);
    let active = DutyCycles { compute: 0.9, comm: 0.1, camera: 0.1 };
    let fly = || {
        let mut state = PowerState::new(&eclipse_heavy_power(40.0), &low_idle());
        let mut sched = FedScheduler::new(&fed, horizon);
        fly_federated_mission(&mut state, &mut sched, &tl, active, 30.0, train_s);
        (sched.stats.participated.clone(), state.stats.final_soc_frac.to_bits())
    };
    assert_eq!(fly(), fly(), "participation and SoC must be pure mission-time functions");
}

#[test]
fn oversized_battery_never_skips_a_round() {
    let horizon = 23_000.0;
    let tl = orbital_timeline(horizon);
    let fed = fed_cfg(600.0, 0.6);
    let train_s = federated::train_seconds(fed.epochs, fed.samples_per_node);
    let mut power = eclipse_heavy_power(100_000.0);
    power.initial_soc = 1.0;
    let mut state = PowerState::new(&power, &low_idle());
    let mut sched = FedScheduler::new(&fed, horizon);
    let active = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
    fly_federated_mission(&mut state, &mut sched, &tl, active, 30.0, train_s);
    assert_eq!(sched.stats.rounds_skipped_power, 0);
    assert_eq!(sched.stats.rounds_completed, sched.stats.rounds_scheduled);
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 1;
    cfg.constellation.scenes_per_satellite = 3;
    cfg.loss_profile = "lossless".into();
    cfg
}

#[test]
fn disabled_federated_reports_nothing() {
    let Some(rt) = rt() else { return };
    let report = run_constellation(&rt, &small_cfg(), Version::V2).unwrap();
    assert!(report.federated.is_none());
    let sat = &report.satellites[0];
    assert!(sat.federated.is_none());
    assert!(sat.result.federated.is_none());
    assert_eq!(sat.downlink.weights_bytes, 0);
    assert!(!report.telemetry.contains("federated."), "{}", report.telemetry);
}

#[test]
fn constellation_rounds_reconcile_and_weights_cross_the_link() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 2;
    cfg.constellation.ideal_contact = true; // every queued weight gets airtime
    cfg.federated.enabled = true;
    // 21 rounds, the last due 600 s before the horizon so its weights
    // are ready while the window is still open
    cfg.federated.round_interval_s = 1000.0;
    let rounds =
        FedScheduler::rounds_in(cfg.constellation.horizon_s, cfg.federated.round_interval_s);
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();

    let fleet = report.federated.as_ref().expect("fleet training report");
    assert_eq!(fleet.acc_history.len(), rounds);
    assert_eq!(fleet.rounds_aggregated + fleet.rounds_held, rounds);
    assert!(
        fleet.final_accuracy() > 0.5,
        "two honest workers must beat a coin flip: {}",
        fleet.final_accuracy()
    );
    let wire = federated::wire_bytes_for_dim(cfg.federated.dim);
    for sat in &report.satellites {
        let f = sat.federated.as_ref().expect("per-sat federated stats");
        assert_eq!(f.rounds_scheduled as usize, rounds);
        assert_eq!(f.rounds_completed + f.rounds_skipped_power, f.rounds_scheduled);
        assert_eq!(f.rounds_skipped_power, 0, "power disabled: nothing skips");
        assert_eq!(f.uplink_bytes, f.rounds_completed * wire);
        // federated uplink shows up in the link books
        assert_eq!(sat.downlink.weights_bytes, f.uplink_bytes);
        assert_eq!(
            sat.downlink.total_bytes(),
            sat.downlink.results_bytes + sat.downlink.image_bytes + sat.downlink.weights_bytes
        );
        assert_eq!(sat.result.federated.as_ref().unwrap().rounds_completed, f.rounds_completed);
    }
    assert!(report.telemetry.contains("federated.rounds.sat-0"), "{}", report.telemetry);
    assert!(report.telemetry.contains("gauge federated.accuracy_pct"), "{}", report.telemetry);
}

#[test]
fn eclipse_heavy_constellation_skips_rounds_for_power() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.federated.enabled = true;
    cfg.federated.round_interval_s = 600.0;
    cfg.federated.min_soc = 0.6;
    cfg.power = eclipse_heavy_power(60.0);
    cfg.energy = low_idle();
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let sat = &report.satellites[0];
    let f = sat.federated.as_ref().expect("per-sat federated stats");
    assert!(
        f.rounds_skipped_power > 0,
        "eclipse-heavy undersized mission must report rounds_skipped_power"
    );
    assert_eq!(f.rounds_completed + f.rounds_skipped_power, f.rounds_scheduled);
    assert!(report.telemetry.contains("federated.skipped_power.sat-0"), "{}", report.telemetry);
    let fleet = report.federated.as_ref().expect("fleet report");
    assert_eq!(
        fleet.rounds_aggregated + fleet.rounds_held,
        f.rounds_scheduled as usize,
        "every scheduled round is either aggregated or held"
    );
}
