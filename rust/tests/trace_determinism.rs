//! Flight-recorder determinism, artifact-free.
//!
//! The trace tentpole's contract: `fleet.shards` and
//! `fleet.max_events_in_flight` are parallelism dials, and the merged
//! trace — like the mission reports — must be **byte-for-byte**
//! identical across them.  Each satellite records into the single-writer
//! ring of the shard that steps it; the post-join merge concatenates the
//! rings and stably sorts by `(t_start, sat_id, kind)`, so as long as no
//! ring evicted, the stream is a pure function of the missions.  These
//! tests drive [`StubSat`] fleets (real [`Timeline`]s, synthetic
//! workload, no inference artifacts) and pin the JSONL export across
//! shard counts and admission caps, and pin that tracing itself never
//! perturbs results.

use std::sync::Arc;

use tiansuan::sim::{run_sharded, StubReport, StubSat};
use tiansuan::telemetry::trace::{SpanKind, TraceSink};

const N_SATS: usize = 52;
const SCENES: usize = 6;
const HORIZON_S: f64 = 43_200.0;
const SEED: u64 = 7;

fn plain_fleet(shards: usize, cap: usize) -> Vec<StubReport> {
    let (reports, _) =
        run_sharded(N_SATS, shards, cap, |id| Ok(StubSat::new(id, SEED, SCENES, HORIZON_S)))
            .unwrap();
    reports
}

fn traced_fleet(shards: usize, cap: usize) -> (Vec<StubReport>, Arc<TraceSink>) {
    // ring-per-shard, exactly as run_fleet sizes it (clamped shard count)
    let shards_effective = shards.max(1).min(N_SATS);
    let sink = Arc::new(TraceSink::new(shards_effective, 1 << 16));
    let sink_ref = &sink;
    let (reports, _) = run_sharded(N_SATS, shards, cap, |id| {
        Ok(StubSat::new(id, SEED, SCENES, HORIZON_S).with_trace(sink_ref.tracer(id, id)))
    })
    .unwrap();
    (reports, sink)
}

#[test]
fn merged_trace_is_bit_identical_across_shards_and_caps() {
    let (base_reports, base_sink) = traced_fleet(1, 0);
    let base = base_sink.merge();
    assert_eq!(base.evicted(), 0, "rings must not evict at this ring_cap");
    assert!(!base.is_empty(), "a 52-sat mission must record something");
    let base_jsonl = base.to_jsonl();
    let base_chrome = base.to_chrome();
    for shards in [1usize, 4, 13] {
        for cap in [1usize, 64] {
            let (reports, sink) = traced_fleet(shards, cap);
            let log = sink.merge();
            assert_eq!(log.evicted(), 0, "shards={shards} cap={cap}");
            assert_eq!(
                base_jsonl,
                log.to_jsonl(),
                "merged JSONL diverged at shards={shards} cap={cap}"
            );
            assert_eq!(
                base_chrome,
                log.to_chrome(),
                "chrome export diverged at shards={shards} cap={cap}"
            );
            assert_eq!(base_reports, reports, "reports diverged at shards={shards} cap={cap}");
        }
    }
}

#[test]
fn tracing_is_result_neutral() {
    // trace-off (no tracer attached) and trace-on missions are
    // bit-identical in their reports, at every shard count
    for shards in [1usize, 4, 13] {
        let plain = plain_fleet(shards, 0);
        let (traced, _) = traced_fleet(shards, 0);
        assert_eq!(plain, traced, "tracing perturbed results at shards={shards}");
    }
}

#[test]
fn trace_off_records_nothing() {
    // a sink nobody was handed stays empty — the zero-record guarantee
    // behind the `trace.enabled=false` default
    let sink = Arc::new(TraceSink::new(4, 1 << 10));
    let _ = plain_fleet(4, 0);
    let log = sink.merge();
    assert!(log.is_empty());
    assert_eq!(log.evicted(), 0);
    assert_eq!(log.to_jsonl(), "");
}

#[test]
fn merged_stream_accounts_for_every_mission() {
    let (_, sink) = traced_fleet(4, 0);
    let log = sink.merge();
    // every (kind, count) pair sums back to the stream length
    let counts = log.kind_counts();
    let total: usize = counts.iter().map(|(_, n)| n).sum();
    assert_eq!(total, log.len());
    // one Capture event per scene per satellite
    let captures = counts
        .iter()
        .find(|(k, _)| *k == SpanKind::Capture)
        .map(|(_, n)| *n)
        .unwrap_or(0);
    assert_eq!(captures, N_SATS * SCENES);
    // contact passes recorded for the whole fleet
    let slices = counts
        .iter()
        .find(|(k, _)| *k == SpanKind::DownlinkSlice)
        .map(|(_, n)| *n)
        .unwrap_or(0);
    assert!(slices > 0, "12 h of mission must include downlink slices");
    // JSONL is one line per record
    assert_eq!(log.to_jsonl().lines().count(), log.len());
}
