//! Constellation ↔ single-satellite parity: the constellation runner's
//! only honest differences from `run_scenario` are the lossy windowed
//! link and the energy duties it derives from it.  Remove those — one
//! satellite, lossless link, contact covering the whole horizon
//! (`constellation.ideal_contact`) — and the per-satellite result must
//! reproduce the sequential facade's mAP and tile accounting exactly.

use tiansuan::config::Config;
use tiansuan::coordinator::{run_constellation, Pipeline};
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn ideal_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 1;
    cfg.constellation.scenes_per_satellite = 3;
    cfg.constellation.ideal_contact = true;
    cfg.loss_profile = "lossless".into();
    cfg
}

#[test]
fn one_satellite_ideal_contact_matches_run_scenario() {
    let Some(rt) = rt() else { return };
    let cfg = ideal_cfg();
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    assert_eq!(report.satellites.len(), 1);
    let sat = &report.satellites[0];

    // the constellation derives per-satellite seeds; reproduce sat 0's
    let mut single = cfg.clone();
    single.seed = cfg.seed.wrapping_add(1);
    let p = Pipeline::new(&rt, single);
    let seq = p.run_scenario(Version::V2, cfg.constellation.scenes_per_satellite).unwrap();

    // mAP: every offloaded tile crossed the ideal link and was
    // ground-inferred, exactly like the sequential facade
    assert_eq!(sat.result.map_inorbit.to_bits(), seq.map_inorbit.to_bits());
    assert_eq!(sat.result.map_collab.to_bits(), seq.map_collab.to_bits());
    assert_eq!(sat.result.report_collab.det_total, seq.report_collab.det_total);

    // tile accounting
    assert_eq!(sat.result.scenes, seq.scenes);
    assert_eq!(sat.result.tiles_total, seq.tiles_total);
    assert_eq!(sat.result.tiles_filtered, seq.tiles_filtered);
    assert_eq!(sat.result.router.onboard_final, seq.router.onboard_final);
    assert_eq!(sat.result.router.offloaded, seq.router.offloaded);
    assert_eq!(sat.result.router.confidently_empty, seq.router.confidently_empty);

    // byte accounting: nominal collab bytes match; the ideal link
    // delivered every queued byte and dropped none
    assert_eq!(sat.result.bentpipe_bytes, seq.bentpipe_bytes);
    assert_eq!(sat.result.collab_bytes, seq.collab_bytes);
    assert_eq!(sat.downlink.total_bytes(), sat.result.collab_bytes);
    assert_eq!(sat.downlink.items_dropped, 0);
    assert_eq!(sat.downlink.bytes_dropped, 0);
    assert_eq!(sat.link.packets_lost, 0);
}

#[test]
fn lossy_constellation_diverges_only_in_delivery() {
    // Sanity for the "honest difference": with the MakerSat-grade link
    // the nominal accounting still matches the single-satellite run, but
    // delivery falls short and collaborative accuracy can only shrink.
    let Some(rt) = rt() else { return };
    let mut cfg = ideal_cfg();
    cfg.loss_profile = "makersat".into();
    let report = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let sat = &report.satellites[0];

    let mut single = cfg.clone();
    single.seed = cfg.seed.wrapping_add(1);
    let p = Pipeline::new(&rt, single);
    let seq = p.run_scenario(Version::V2, cfg.constellation.scenes_per_satellite).unwrap();

    assert_eq!(sat.result.tiles_total, seq.tiles_total);
    assert_eq!(sat.result.collab_bytes, seq.collab_bytes, "nominal bytes are link-independent");
    assert!(sat.link.packets_lost > 0, "the MakerSat profile must actually lose packets");
    // every queued byte is delivered, dropped, or still pending — never
    // more than queued, and dropped bytes no longer vanish
    assert!(
        sat.downlink.total_bytes() + sat.downlink.bytes_dropped <= sat.result.collab_bytes,
        "delivered {} + dropped {} exceeds queued {}",
        sat.downlink.total_bytes(),
        sat.downlink.bytes_dropped,
        sat.result.collab_bytes
    );
}
