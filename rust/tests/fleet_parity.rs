//! Fleet engine ↔ thread driver parity.
//!
//! `run_fleet` must reproduce `run_constellation`'s report for the same
//! config, and must itself be invariant under `fleet.shards` /
//! `fleet.max_events_in_flight` (pure parallelism dials).
//!
//! Comparison discipline:
//!
//! * **Always bitwise**: every integer (tiles, router, downlink, link
//!   packet counts, windows, round counts) and every virtual-time f64
//!   (mAP, mean confidence, duties, link airtime, contact/sunlit
//!   seconds) — these are pure functions of mission time.
//! * **Never compared**: wallclock fields (`wall_s`, `wall_infer_s`,
//!   ground service wall) and the rendered telemetry string.
//! * **Energy/power f64s**: bit-compared between the two engines only
//!   when `federated.enabled` is off — with rounds on, the thread
//!   driver's accumulator interleaves training folds with scene folds
//!   in ground-reply wallclock order, so its energy bits are not even
//!   reproducible run-to-run.  Fleet-vs-fleet (shard invariance) they
//!   are always bit-compared: virtual time has no wallclock anywhere.

use tiansuan::config::Config;
use tiansuan::coordinator::{run_constellation, run_fleet, ConstellationReport, SatelliteReport};
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 3;
    cfg.constellation.scenes_per_satellite = 2;
    cfg
}

/// Compare the deterministic surface of two per-satellite reports.
/// `energy_bits` additionally bit-compares the energy/power-derived
/// f64s (see module doc for when that is sound).
fn assert_sat_parity(a: &SatelliteReport, b: &SatelliteReport, energy_bits: bool, ctx: &str) {
    assert_eq!(a.index, b.index, "{ctx}: index");
    assert_eq!(a.name, b.name, "{ctx}: name");

    // scenario fold: integers + detection-derived f64s, bitwise
    let (ra, rb) = (&a.result, &b.result);
    assert_eq!(ra.scenes, rb.scenes, "{ctx}: scenes");
    assert_eq!(ra.tiles_total, rb.tiles_total, "{ctx}: tiles_total");
    assert_eq!(ra.tiles_filtered, rb.tiles_filtered, "{ctx}: tiles_filtered");
    assert_eq!(ra.router.onboard_final, rb.router.onboard_final, "{ctx}: onboard_final");
    assert_eq!(ra.router.offloaded, rb.router.offloaded, "{ctx}: offloaded");
    assert_eq!(
        ra.router.confidently_empty, rb.router.confidently_empty,
        "{ctx}: confidently_empty"
    );
    assert_eq!(ra.map_inorbit.to_bits(), rb.map_inorbit.to_bits(), "{ctx}: map_inorbit");
    assert_eq!(ra.map_collab.to_bits(), rb.map_collab.to_bits(), "{ctx}: map_collab");
    assert_eq!(ra.report_inorbit.det_total, rb.report_inorbit.det_total, "{ctx}: inorbit dets");
    assert_eq!(ra.report_collab.det_total, rb.report_collab.det_total, "{ctx}: collab dets");
    assert_eq!(ra.bentpipe_bytes, rb.bentpipe_bytes, "{ctx}: bentpipe_bytes");
    assert_eq!(ra.collab_bytes, rb.collab_bytes, "{ctx}: collab_bytes");
    assert_eq!(
        ra.mean_confidence.to_bits(),
        rb.mean_confidence.to_bits(),
        "{ctx}: mean_confidence"
    );

    // downlink + link: virtual-time accounting, bitwise
    assert_eq!(a.downlink.items_delivered, b.downlink.items_delivered, "{ctx}: dl delivered");
    assert_eq!(a.downlink.items_dropped, b.downlink.items_dropped, "{ctx}: dl dropped");
    assert_eq!(a.downlink.bytes_dropped, b.downlink.bytes_dropped, "{ctx}: dl bytes_dropped");
    assert_eq!(a.downlink.total_bytes(), b.downlink.total_bytes(), "{ctx}: dl bytes");
    assert_eq!(a.link.packets_sent, b.link.packets_sent, "{ctx}: packets_sent");
    assert_eq!(a.link.packets_lost, b.link.packets_lost, "{ctx}: packets_lost");
    assert_eq!(a.link.bytes_delivered, b.link.bytes_delivered, "{ctx}: link bytes");
    assert_eq!(a.link.busy_s.to_bits(), b.link.busy_s.to_bits(), "{ctx}: link busy_s");

    // ARQ + injected-fault ledgers: all integers, bitwise.  Both are
    // zero with chaos off, so this also pins default-off inertness.
    assert_eq!(a.link.frames_corrupted, b.link.frames_corrupted, "{ctx}: frames_corrupted");
    assert_eq!(a.link.frames_truncated, b.link.frames_truncated, "{ctx}: frames_truncated");
    assert_eq!(a.link.retries, b.link.retries, "{ctx}: arq retries");
    assert_eq!(a.link.gave_up, b.link.gave_up, "{ctx}: arq gave_up");
    assert_eq!(a.link.bytes_rejected, b.link.bytes_rejected, "{ctx}: bytes_rejected");
    assert_eq!(a.chaos.is_some(), b.chaos.is_some(), "{ctx}: chaos presence");
    if let (Some(ca), Some(cb)) = (&a.chaos, &b.chaos) {
        assert_eq!(ca, cb, "{ctx}: chaos fault ledger");
    }

    // timeline geometry, bitwise
    assert_eq!(a.windows, b.windows, "{ctx}: windows");
    assert_eq!(a.contact_s.to_bits(), b.contact_s.to_bits(), "{ctx}: contact_s");
    assert_eq!(a.sunlit_s.to_bits(), b.sunlit_s.to_bits(), "{ctx}: sunlit_s");

    // federated round accounting (integers + participation sets)
    assert_eq!(a.federated.is_some(), b.federated.is_some(), "{ctx}: fed presence");
    if let (Some(fa), Some(fb)) = (&a.federated, &b.federated) {
        assert_eq!(fa.rounds_scheduled, fb.rounds_scheduled, "{ctx}: rounds_scheduled");
        assert_eq!(fa.rounds_completed, fb.rounds_completed, "{ctx}: rounds_completed");
        assert_eq!(fa.rounds_skipped_power, fb.rounds_skipped_power, "{ctx}: rounds_skipped");
        assert_eq!(fa.rounds_skipped_crash, fb.rounds_skipped_crash, "{ctx}: rounds_crashed");
        assert_eq!(fa.participated, fb.participated, "{ctx}: participation");
    }

    assert_eq!(a.power.is_some(), b.power.is_some(), "{ctx}: power presence");
    if let (Some(pa), Some(pb)) = (&a.power, &b.power) {
        assert_eq!(pa.scenes_deferred, pb.scenes_deferred, "{ctx}: scenes_deferred");
        assert_eq!(pa.scenes_shed, pb.scenes_shed, "{ctx}: scenes_shed");
        if energy_bits {
            assert_eq!(pa.min_soc_frac.to_bits(), pb.min_soc_frac.to_bits(), "{ctx}: min_soc");
            assert_eq!(
                pa.final_soc_frac.to_bits(),
                pb.final_soc_frac.to_bits(),
                "{ctx}: final_soc"
            );
            assert_eq!(pa.generated_wh.to_bits(), pb.generated_wh.to_bits(), "{ctx}: generated");
            assert_eq!(pa.consumed_wh.to_bits(), pb.consumed_wh.to_bits(), "{ctx}: consumed");
            assert_eq!(pa.discharge_wh.to_bits(), pb.discharge_wh.to_bits(), "{ctx}: discharge");
            assert_eq!(
                pa.capacity_wh_now.to_bits(),
                pb.capacity_wh_now.to_bits(),
                "{ctx}: capacity_now"
            );
        }
    }
    if energy_bits {
        assert_eq!(ra.compute_duty.to_bits(), rb.compute_duty.to_bits(), "{ctx}: compute_duty");
        assert_eq!(
            ra.energy_compute_share.to_bits(),
            rb.energy_compute_share.to_bits(),
            "{ctx}: energy_compute_share"
        );
    }
}

fn assert_report_parity(a: &ConstellationReport, b: &ConstellationReport, energy_bits: bool) {
    assert_eq!(a.satellites.len(), b.satellites.len(), "fleet size");
    for (sa, sb) in a.satellites.iter().zip(&b.satellites) {
        assert_sat_parity(sa, sb, energy_bits, &format!("sat {}", sa.index));
    }
    assert_eq!(a.tiles_total, b.tiles_total, "tiles_total");
    assert_eq!(a.task_completed, b.task_completed, "task_completed");
    assert_eq!(a.federated.is_some(), b.federated.is_some(), "fed report presence");
    if let (Some(fa), Some(fb)) = (&a.federated, &b.federated) {
        assert_eq!(
            fa.final_accuracy().to_bits(),
            fb.final_accuracy().to_bits(),
            "fleet FedAvg accuracy"
        );
    }
}

#[test]
fn one_satellite_ideal_contact_fleet_matches_thread_driver() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 1;
    cfg.constellation.scenes_per_satellite = 3;
    cfg.constellation.ideal_contact = true;
    cfg.loss_profile = "lossless".into();
    let threads = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let fleet = run_fleet(&rt, &cfg, Version::V2).unwrap();
    assert_report_parity(&threads, &fleet, true);
}

#[test]
fn orbital_lossy_multisat_fleet_matches_thread_driver() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.loss_profile = "makersat".into();
    let threads = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let fleet = run_fleet(&rt, &cfg, Version::V2).unwrap();
    // fed off, power off: the fold order is pinned in both engines, so
    // the energy f64s must match bitwise too
    assert_report_parity(&threads, &fleet, true);
}

#[test]
fn governed_federated_fleet_matches_thread_driver() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.power.enabled = true;
    cfg.federated.enabled = true;
    let threads = run_constellation(&rt, &cfg, Version::V2).unwrap();
    let fleet = run_fleet(&rt, &cfg, Version::V2).unwrap();
    // rounds interleave the thread driver's accumulator in reply-order,
    // so energy bits are not comparable across engines — everything
    // else (integers, mAP, participation, SoC-governed round skips) is
    assert_report_parity(&threads, &fleet, false);
}

#[test]
fn fleet_report_is_invariant_under_shard_count_and_admission_cap() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 4;
    cfg.power.enabled = true;
    cfg.federated.enabled = true;
    cfg.fleet.shards = 1;
    cfg.fleet.max_events_in_flight = 0;
    let one = run_fleet(&rt, &cfg, Version::V2).unwrap();
    for (shards, cap) in [(2, 0), (4, 0), (3, 1), (8, 2)] {
        cfg.fleet.shards = shards;
        cfg.fleet.max_events_in_flight = cap;
        let many = run_fleet(&rt, &cfg, Version::V2).unwrap();
        // fleet-vs-fleet is wallclock-free: full bit parity, energy
        // f64s included, at every shard count and admission cap
        assert_report_parity(&one, &many, true);
    }
}
