//! Chaos-engine invariants: seeded fault injection must be
//! deterministic, byte-reconciled, and bounded.
//!
//! Two tiers:
//!
//! * **Primitive properties** (always run, no artifacts): fault plans
//!   are pure functions of `(seed, sat index)`; the ARQ transfer loop
//!   reconciles every byte it touches and terminates inside its window
//!   budget; a faulted pass replays unacknowledged items without
//!   double-counting; SEU strikes are reproducible; suppressed
//!   heartbeats walk the registry → orchestrator chain to an
//!   exactly-once failover.
//! * **Whole-engine laws** (gated on `artifacts/` like the rest of the
//!   integration suite): a zero-rate chaos run is bit-identical to a
//!   disabled one on both engines; the same seed reproduces the same
//!   fault ledger across engines and shard counts; scene and round
//!   ledgers conserve (`folded + shed + lost_to_crash == scenes`); the
//!   flight recorder's fault events match the chaos ledger count for
//!   count.

use tiansuan::cluster::orchestrator::{AppSpec, Orchestrator, Placement, ReconcileActions};
use tiansuan::cluster::registry::{NodeStatus, Registry};
use tiansuan::cluster::{NodeId, NodeRole};
use tiansuan::config::{ChaosConfig, Config};
use tiansuan::coordinator::downlink::{DownlinkItem, DownlinkQueue, ItemKind};
use tiansuan::coordinator::{run_constellation, run_fleet, SatelliteReport};
use tiansuan::data::Version;
use tiansuan::link::{ArqPolicy, FrameFault, Link, LinkConfig, LossProfile};
use tiansuan::orbit::ContactWindow;
use tiansuan::runtime::Runtime;
use tiansuan::sim::{apply_seu, ChaosStats, FaultPlan};
use tiansuan::telemetry::trace::SpanKind;
use tiansuan::util::rng::Rng;

const CASES: usize = 200;

/// A chaos config with every fault class live, for plan-level
/// properties and the fault-heavy engine runs.
fn chaos_on() -> ChaosConfig {
    ChaosConfig {
        enabled: true,
        seed: 0xC4A05,
        crash_rate_per_hour: 1.5,
        crash_recovery_s: 400.0,
        frame_corrupt_rate: 0.2,
        frame_truncate_rate: 0.1,
        seu_rate: 0.3,
        seu_flips: 3,
        dropout_rate_per_hour: 2.0,
        dropout_silence_s: 120.0,
        ..ChaosConfig::default()
    }
}

// ---------------------------------------------------------------------
// Primitive properties: no artifacts needed.
// ---------------------------------------------------------------------

#[test]
fn fault_plan_is_a_pure_function_of_seed_and_sat_index() {
    let mut rng = Rng::new(11);
    let mut diverged = 0usize;
    for _ in 0..CASES {
        let mut cfg = chaos_on();
        cfg.seed = rng.next_u64();
        let sat = rng.range_usize(0, 64);
        let horizon = rng.range_f64(1800.0, 86_400.0);
        let scenes = rng.range_usize(1, 40);
        let mut a = FaultPlan::compile(&cfg, sat, horizon, scenes);
        let mut b = FaultPlan::compile(&cfg, sat, horizon, scenes);
        assert_eq!(a.crash_windows(), b.crash_windows(), "crash schedule not reproducible");
        assert_eq!(a.dropout_windows(), b.dropout_windows(), "dropout schedule not reproducible");
        assert_eq!(a.seu_flips(), b.seu_flips());
        for i in 0..scenes {
            assert_eq!(a.seu_for_scene(i), b.seu_for_scene(i), "SEU schedule not reproducible");
        }
        // a prefix of the frame-fault stream, draw for draw
        for _ in 0..32 {
            assert_eq!(a.next_frame_fault(), b.next_frame_fault(), "frame stream diverged");
        }
        // out-of-range scene indices are None, never a panic
        assert_eq!(a.seu_for_scene(scenes + 7), None);
        // a neighbouring satellite must not share the schedule
        let c = FaultPlan::compile(&cfg, sat + 1, horizon, scenes);
        if c.crash_windows() != b.crash_windows() || c.dropout_windows() != b.dropout_windows() {
            diverged += 1;
        }
    }
    assert!(diverged > CASES / 2, "neighbouring sats share fault plans too often: {diverged}");
}

#[test]
fn fault_windows_are_sorted_disjoint_and_inside_the_horizon() {
    let mut rng = Rng::new(12);
    for _ in 0..CASES {
        let mut cfg = chaos_on();
        cfg.seed = rng.next_u64();
        let horizon = rng.range_f64(3600.0, 43_200.0);
        let plan = FaultPlan::compile(&cfg, rng.range_usize(0, 16), horizon, 8);
        for windows in [plan.crash_windows(), plan.dropout_windows()] {
            for w in windows {
                assert!(w.0 >= 0.0 && w.0 < horizon, "start {} outside [0, {horizon})", w.0);
                assert!(w.1 > w.0, "empty window {w:?}");
            }
            for pair in windows.windows(2) {
                assert!(
                    pair[0].1 <= pair[1].0,
                    "windows overlap after merge: {:?} then {:?}",
                    pair[0],
                    pair[1]
                );
            }
        }
    }
}

#[test]
fn arq_backoff_is_monotone_and_capped() {
    let arq = ArqPolicy { max_retries: 10, backoff_initial_s: 0.05, backoff_cap_s: 1.0 };
    let mut prev = 0.0;
    for r in 0..200 {
        let b = arq.backoff_s(r);
        assert!(b >= prev, "backoff not monotone at retry {r}: {b} < {prev}");
        assert!(b <= arq.backoff_cap_s, "backoff exceeds cap at retry {r}: {b}");
        prev = b;
    }
    assert_eq!(arq.backoff_s(0), 0.05);
    assert_eq!(arq.backoff_s(1), 0.1);
    // the retry exponent saturates: huge counts cap out, never overflow
    assert_eq!(arq.backoff_s(1000), 1.0);
}

#[test]
fn transmit_checked_reconciles_bytes_and_bounds_retries() {
    let arq = ArqPolicy { max_retries: 4, backoff_initial_s: 0.01, backoff_cap_s: 0.1 };
    let bytes = 200_000u64;
    for k in 0..=arq.max_retries + 1 {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 3);
        let mut faults_left = k;
        let t = link.transmit_checked(bytes, 60.0, &arq, || {
            if faults_left > 0 {
                faults_left -= 1;
                Some(FrameFault::Corrupt)
            } else {
                None
            }
        });
        let s = &link.stats;
        assert_eq!(s.frames_corrupted, k as u64, "k={k}: every fault is a rejected frame");
        assert_eq!(s.bytes_rejected, k as u64 * bytes, "k={k}: rejected bytes");
        if k <= arq.max_retries {
            assert!(t.completed, "k={k}: should complete after {k} retries");
            assert_eq!(t.bytes_delivered, bytes, "k={k}");
            assert_eq!(s.retries, k as u64, "k={k}: one retry per rejected frame");
            assert_eq!(s.gave_up, 0, "k={k}");
            assert_eq!(s.bytes_delivered, bytes, "k={k}: net delivered is the final good frame");
        } else {
            assert!(!t.completed, "k={k}: retry budget exhausted");
            assert_eq!(t.bytes_delivered, 0, "k={k}: a give-up acknowledges nothing");
            assert_eq!(s.retries, arq.max_retries as u64, "k={k}");
            assert_eq!(s.gave_up, 1, "k={k}");
            assert_eq!(s.bytes_delivered, 0, "k={k}: delivered rolls back on every rejection");
        }
    }
}

#[test]
fn zero_fault_checked_transfers_match_plain_transmit_bitwise() {
    // the zero-fault lane of the ARQ loop must be the identity wrapper:
    // same RNG consumption, same stats, same transfer outcomes
    let arq = ArqPolicy { max_retries: 4, backoff_initial_s: 0.05, backoff_cap_s: 1.0 };
    let mut rng = Rng::new(21);
    let mut plain = Link::new(LinkConfig::downlink(LossProfile::stable()), 99);
    let mut checked = Link::new(LinkConfig::downlink(LossProfile::stable()), 99);
    for _ in 0..CASES {
        let bytes = rng.below(400_000) + 1;
        let budget = rng.range_f64(0.001, 0.5);
        let a = plain.transmit(bytes, budget);
        let b = checked.transmit_checked(bytes, budget, &arq, || None);
        assert_eq!(a.bytes_delivered, b.bytes_delivered);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.elapsed_s.to_bits(), b.elapsed_s.to_bits());
    }
    let (a, b) = (&plain.stats, &checked.stats);
    assert_eq!(a.bytes_offered, b.bytes_offered);
    assert_eq!(a.bytes_delivered, b.bytes_delivered);
    assert_eq!(a.packets_sent, b.packets_sent);
    assert_eq!(a.packets_lost, b.packets_lost);
    assert_eq!(a.retransmissions, b.retransmissions);
    assert_eq!(a.transfers_aborted, b.transfers_aborted);
    assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits());
    assert_eq!(b.frames_corrupted, 0);
    assert_eq!(b.frames_truncated, 0);
    assert_eq!(b.retries, 0);
    assert_eq!(b.gave_up, 0);
    assert_eq!(b.bytes_rejected, 0);
}

#[test]
fn arq_gives_up_within_the_window_budget() {
    // an always-faulting stream can never complete, but it must also
    // never hang or overrun the window: bounded progress
    let arq = ArqPolicy { max_retries: u32::MAX, backoff_initial_s: 0.05, backoff_cap_s: 1.0 };
    let mut rng = Rng::new(31);
    for _ in 0..CASES {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 5);
        let bytes = rng.below(100_000) + 1;
        let budget = rng.range_f64(0.01, 2.0);
        let t = link.transmit_checked(bytes, budget, &arq, || Some(FrameFault::Truncate));
        assert!(!t.completed, "an always-faulting frame stream can never complete");
        assert!(t.elapsed_s <= budget + 1e-9, "elapsed {} overran budget {budget}", t.elapsed_s);
        // every rejected frame rolled back; only a final budget-starved
        // partial attempt (never checksummed, so never rejected) remains
        assert_eq!(link.stats.bytes_delivered, t.bytes_delivered, "delivered-bytes ledger");
        assert_eq!(link.stats.frames_truncated, link.stats.retries + link.stats.gave_up);
    }
}

#[test]
fn faulted_pass_replays_items_without_double_count() {
    let arq = ArqPolicy { max_retries: 2, backoff_initial_s: 0.01, backoff_cap_s: 0.1 };
    let mut queue = DownlinkQueue::new();
    let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 17);
    let sizes = [40_000u64, 9_000, 120_000, 3_500, 64_000];
    for (i, bytes) in sizes.iter().enumerate() {
        let kind = if i % 2 == 0 { ItemKind::Results } else { ItemKind::Image };
        queue.push(DownlinkItem { kind, bytes: *bytes, ready_at: 0.0, tag: i as u64 });
    }

    // pass 1: every frame rejected — the ARQ gives up on the head,
    // nothing is acknowledged, nothing leaves the queue
    let w1 = ContactWindow {
        aos: 0.0,
        los: 120.0,
        max_elevation_deg: 45.0,
        truncated: false,
        station_id: 0,
    };
    let got =
        queue.drain_window_sliced_chaos(&mut link, &w1, true, None, &arq, &mut || {
            Some(FrameFault::Corrupt)
        });
    assert!(got.is_empty(), "a give-up must not acknowledge the item");
    assert_eq!(queue.pending(), sizes.len(), "unacked items stay queued for replay");
    assert_eq!(queue.stats.items_delivered, 0);
    assert_eq!(link.stats.bytes_delivered, 0, "rejected bytes roll back out of delivered");
    assert!(link.stats.bytes_rejected > 0, "the channel did carry (and reject) frames");
    assert_eq!(link.stats.gave_up, 1, "only the head item is charged the failed pass");

    // pass 2: clean link — every item delivered exactly once
    let w2 = ContactWindow {
        aos: 200.0,
        los: 320.0,
        max_elevation_deg: 50.0,
        truncated: false,
        station_id: 1,
    };
    let got = queue.drain_window_sliced_chaos(&mut link, &w2, true, None, &arq, &mut || None);
    let mut tags: Vec<u64> = got.iter().map(|d| d.item.tag).collect();
    tags.sort_unstable();
    assert_eq!(tags, vec![0, 1, 2, 3, 4], "each item delivered exactly once after the replay");
    assert_eq!(queue.pending(), 0);
    assert_eq!(queue.stats.items_delivered, sizes.len() as u64);
    assert_eq!(queue.stats.items_dropped, 0, "one failed pass is under the drop threshold");
    let total: u64 = sizes.iter().sum();
    assert_eq!(queue.stats.total_bytes(), total, "queue books carry exactly the payload bytes");
    assert_eq!(queue.stats.station_bytes(1), total, "replayed bytes land on the replay station");
    assert_eq!(link.stats.bytes_delivered, total, "link books net out to acknowledged bytes");
}

#[test]
fn seu_strikes_are_deterministic_and_buffer_safe() {
    let base: Vec<f32> = (0..256).map(|i| i as f32 * 0.5 - 17.0).collect();
    let (mut a, mut b, mut c) = (base.clone(), base.clone(), base.clone());
    apply_seu(&mut a, 42, 3);
    apply_seu(&mut b, 42, 3);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a), bits(&b), "same seed must strike the same bits");
    apply_seu(&mut c, 43, 3);
    assert_ne!(bits(&a), bits(&c), "a different seed must strike differently");
    // at most `flips` lanes change (an odd flip count can never fully
    // cancel, so at least one lane must differ), the rest are untouched
    let changed = a.iter().zip(&base).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    assert!((1..=3).contains(&changed), "3 flips touched {changed} lanes");
    // degenerate buffers must not panic
    let mut empty: Vec<f32> = Vec::new();
    apply_seu(&mut empty, 42, 3);
    let mut one = vec![1.0f32];
    apply_seu(&mut one, 42, 64);
}

#[test]
fn crash_silence_walks_the_registry_to_exactly_once_failover_and_recovery() {
    // hunt (deterministically) for a plan whose first long crash window
    // outlasts the eviction threshold and has clean margins
    let mut cfg = chaos_on();
    cfg.crash_rate_per_hour = 2.0;
    cfg.crash_recovery_s = 900.0;
    let horizon = 4.0 * 3600.0;
    let mut found = None;
    'hunt: for seed in 0..512u64 {
        cfg.seed = seed;
        let plan = FaultPlan::compile(&cfg, 0, horizon, 4);
        let ws = plan.crash_windows();
        for (i, &(s, e)) in ws.iter().enumerate() {
            let next_start = ws.get(i + 1).map(|w| w.0).unwrap_or(f64::INFINITY);
            if e - s >= 700.0 && s > 120.0 && e + 60.0 < horizon && next_start > e + 60.0 {
                found = Some((seed, s, e));
                break 'hunt;
            }
        }
    }
    let (seed, s, e) = found.expect("no seed in 0..512 yields a long crash window");
    cfg.seed = seed;
    let plan = FaultPlan::compile(&cfg, 0, horizon, 4);
    assert!(plan.crashed_at(s + 300.0), "mid-window the satellite is dark");
    assert!(plan.heartbeat_suppressed_at(s + 300.0), "a dark satellite sends no heartbeats");

    // two edge nodes; the app's single replica lands on one of them
    let mut reg = Registry::new(60_000, 600_000);
    let now_pre = ((s - 5.0) * 1000.0) as u64;
    reg.register(NodeId::new("sat-a"), NodeRole::Edge, 4000, 8192, now_pre);
    reg.register(NodeId::new("sat-b"), NodeRole::Edge, 4000, 8192, now_pre);
    let mut orch = Orchestrator::new();
    orch.apply(AppSpec {
        name: "joint-inference".into(),
        image: "v2".into(),
        replicas: 1,
        placement: Placement::Edge,
    });
    let first = orch.reconcile(&reg, now_pre);
    assert_eq!(first.started, 1);
    let crashed = orch.pods("joint-inference")[0].node.clone();
    let healthy = if crashed == NodeId::new("sat-a") {
        NodeId::new("sat-b")
    } else {
        NodeId::new("sat-a")
    };

    // mid-outage: the dark node has missed more than eviction_ms of
    // heartbeats while the healthy one kept beating
    let now_mid = ((s + 610.0) * 1000.0) as u64;
    reg.heartbeat(&healthy, now_mid);
    assert_eq!(reg.status(&crashed, now_mid), Some(NodeStatus::Offline));
    assert_eq!(reg.status(&healthy, now_mid), Some(NodeStatus::Ready));
    let acts = orch.reconcile(&reg, now_mid);
    assert_eq!(acts.failed_over, 1, "eviction fails the pod over exactly once");
    assert_eq!(acts.started, 1, "the same pass restarts it on the surviving node");
    assert_eq!(orch.running("joint-inference"), 1);
    assert_eq!(orch.pods("joint-inference")[0].node, healthy);
    // idempotent: a second pass with no state change does nothing
    assert_eq!(orch.reconcile(&reg, now_mid), ReconcileActions::default());

    // recovery: the node comes back Ready, and the pod does not flap back
    let now_post = ((e + 5.0) * 1000.0) as u64;
    reg.heartbeat(&crashed, now_post);
    reg.heartbeat(&healthy, now_post);
    assert_eq!(reg.status(&crashed, now_post), Some(NodeStatus::Ready));
    assert_eq!(orch.reconcile(&reg, now_post), ReconcileActions::default());
    assert_eq!(orch.pods("joint-inference")[0].node, healthy, "no failback flapping");
}

#[test]
fn chaos_config_validation_rejects_bad_knobs() {
    assert!(chaos_on().validate().is_ok());
    let mut c = chaos_on();
    c.crash_rate_per_hour = -1.0;
    assert!(c.validate().is_err(), "negative rate");
    let mut c = chaos_on();
    c.frame_corrupt_rate = 0.7;
    c.frame_truncate_rate = 0.5;
    assert!(c.validate().is_err(), "frame fault probabilities sum past 1");
    let mut c = chaos_on();
    c.seu_rate = 1.5;
    assert!(c.validate().is_err(), "probability above 1");
    let mut c = chaos_on();
    c.crash_recovery_s = 0.0;
    assert!(c.validate().is_err(), "zero recovery interval");
    let mut c = chaos_on();
    c.seu_flips = 0;
    assert!(c.validate().is_err(), "an SEU must flip at least one bit");
    let mut c = chaos_on();
    c.arq_backoff_cap_s = 0.001;
    assert!(c.validate().is_err(), "cap below initial backoff");
    // disabled: nothing is checked, garbage knobs are inert
    let mut c = chaos_on();
    c.enabled = false;
    c.seu_rate = 9.0;
    assert!(c.validate().is_ok());
}

// ---------------------------------------------------------------------
// Whole-engine laws: gated on artifacts/ like the integration suite.
// ---------------------------------------------------------------------

fn rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scene_cells = 4;
    cfg.constellation.satellites = 3;
    cfg.constellation.scenes_per_satellite = 2;
    cfg
}

/// The deterministic per-satellite surface, bitwise.  `energy_bits`
/// follows the same rule as the fleet-parity suite: thread-driver
/// energy f64s are only comparable when federated rounds are off.
fn assert_sat_surface(a: &SatelliteReport, b: &SatelliteReport, energy_bits: bool, ctx: &str) {
    let (ra, rb) = (&a.result, &b.result);
    assert_eq!(ra.scenes, rb.scenes, "{ctx}: scenes");
    assert_eq!(ra.tiles_total, rb.tiles_total, "{ctx}: tiles_total");
    assert_eq!(ra.tiles_filtered, rb.tiles_filtered, "{ctx}: tiles_filtered");
    assert_eq!(ra.router.onboard_final, rb.router.onboard_final, "{ctx}: onboard_final");
    assert_eq!(ra.router.offloaded, rb.router.offloaded, "{ctx}: offloaded");
    assert_eq!(ra.map_collab.to_bits(), rb.map_collab.to_bits(), "{ctx}: map_collab");
    assert_eq!(ra.bentpipe_bytes, rb.bentpipe_bytes, "{ctx}: bentpipe_bytes");
    assert_eq!(ra.collab_bytes, rb.collab_bytes, "{ctx}: collab_bytes");

    assert_eq!(a.downlink.items_delivered, b.downlink.items_delivered, "{ctx}: dl delivered");
    assert_eq!(a.downlink.items_dropped, b.downlink.items_dropped, "{ctx}: dl dropped");
    assert_eq!(a.downlink.bytes_dropped, b.downlink.bytes_dropped, "{ctx}: dl bytes_dropped");
    assert_eq!(a.downlink.total_bytes(), b.downlink.total_bytes(), "{ctx}: dl bytes");
    assert_eq!(a.link.packets_sent, b.link.packets_sent, "{ctx}: packets_sent");
    assert_eq!(a.link.packets_lost, b.link.packets_lost, "{ctx}: packets_lost");
    assert_eq!(a.link.bytes_delivered, b.link.bytes_delivered, "{ctx}: link bytes");
    assert_eq!(a.link.busy_s.to_bits(), b.link.busy_s.to_bits(), "{ctx}: link busy_s");
    assert_eq!(a.link.frames_corrupted, b.link.frames_corrupted, "{ctx}: frames_corrupted");
    assert_eq!(a.link.frames_truncated, b.link.frames_truncated, "{ctx}: frames_truncated");
    assert_eq!(a.link.retries, b.link.retries, "{ctx}: arq retries");
    assert_eq!(a.link.gave_up, b.link.gave_up, "{ctx}: arq gave_up");
    assert_eq!(a.link.bytes_rejected, b.link.bytes_rejected, "{ctx}: bytes_rejected");

    assert_eq!(a.windows, b.windows, "{ctx}: windows");
    assert_eq!(a.contact_s.to_bits(), b.contact_s.to_bits(), "{ctx}: contact_s");

    if let (Some(fa), Some(fb)) = (&a.federated, &b.federated) {
        assert_eq!(fa.rounds_scheduled, fb.rounds_scheduled, "{ctx}: rounds_scheduled");
        assert_eq!(fa.rounds_completed, fb.rounds_completed, "{ctx}: rounds_completed");
        assert_eq!(fa.rounds_skipped_power, fb.rounds_skipped_power, "{ctx}: rounds_skipped");
        assert_eq!(fa.rounds_skipped_crash, fb.rounds_skipped_crash, "{ctx}: rounds_crashed");
        assert_eq!(fa.participated, fb.participated, "{ctx}: participation");
    } else {
        assert_eq!(a.federated.is_some(), b.federated.is_some(), "{ctx}: fed presence");
    }
    if let (Some(pa), Some(pb)) = (&a.power, &b.power) {
        assert_eq!(pa.scenes_deferred, pb.scenes_deferred, "{ctx}: scenes_deferred");
        assert_eq!(pa.scenes_shed, pb.scenes_shed, "{ctx}: scenes_shed");
        if energy_bits {
            assert_eq!(pa.min_soc_frac.to_bits(), pb.min_soc_frac.to_bits(), "{ctx}: min_soc");
            assert_eq!(
                pa.final_soc_frac.to_bits(),
                pb.final_soc_frac.to_bits(),
                "{ctx}: final_soc"
            );
        }
    } else {
        assert_eq!(a.power.is_some(), b.power.is_some(), "{ctx}: power presence");
    }
}

#[test]
fn zero_rate_chaos_is_bit_identical_to_disabled_on_both_engines() {
    let Some(rt) = rt() else { return };
    let mut off = small_cfg();
    off.power.enabled = true;
    off.federated.enabled = true;
    let mut zero = off.clone();
    zero.chaos.enabled = true;
    zero.chaos.seed = 1234;
    // every rate stays 0.0: a plan is compiled but schedules nothing,
    // and the run must not consume one extra random draw anywhere

    let a = run_constellation(&rt, &off, Version::V2).unwrap();
    let b = run_constellation(&rt, &zero, Version::V2).unwrap();
    assert_eq!(a.satellites.len(), b.satellites.len());
    for (sa, sb) in a.satellites.iter().zip(&b.satellites) {
        // thread driver with rounds on: energy bits aren't comparable
        assert_sat_surface(sa, sb, false, &format!("thread sat {}", sa.index));
        assert!(sa.chaos.is_none(), "chaos off ⇒ no ledger");
        assert_eq!(
            sb.chaos,
            Some(ChaosStats::default()),
            "zero-rate chaos ⇒ a ledger of all zeros"
        );
    }

    let a = run_fleet(&rt, &off, Version::V2).unwrap();
    let b = run_fleet(&rt, &zero, Version::V2).unwrap();
    for (sa, sb) in a.satellites.iter().zip(&b.satellites) {
        // fleet runs in pure virtual time: full bit parity
        assert_sat_surface(sa, sb, true, &format!("fleet sat {}", sa.index));
        assert!(sa.chaos.is_none());
        assert_eq!(sb.chaos, Some(ChaosStats::default()));
    }
}

#[test]
fn same_seed_reproduces_the_same_faults_across_engines_and_shards() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 4;
    cfg.constellation.scenes_per_satellite = 4;
    cfg.power.enabled = true;
    cfg.federated.enabled = true;
    cfg.chaos = chaos_on();

    let threads = run_constellation(&rt, &cfg, Version::V2).unwrap();
    cfg.fleet.shards = 1;
    cfg.fleet.max_events_in_flight = 0;
    let one = run_fleet(&rt, &cfg, Version::V2).unwrap();
    assert_eq!(threads.satellites.len(), one.satellites.len());
    for (sa, sb) in threads.satellites.iter().zip(&one.satellites) {
        assert_sat_surface(sa, sb, false, &format!("engine sat {}", sa.index));
        assert_eq!(sa.chaos, sb.chaos, "sat {}: fault ledgers must match bitwise", sa.index);
    }

    // shard count is a pure parallelism dial: the fault ledger (and
    // everything else) is invariant under it
    for shards in [2, 4, 8] {
        cfg.fleet.shards = shards;
        let many = run_fleet(&rt, &cfg, Version::V2).unwrap();
        for (sa, sb) in one.satellites.iter().zip(&many.satellites) {
            assert_sat_surface(sa, sb, true, &format!("{shards}-shard sat {}", sa.index));
            assert_eq!(sa.chaos, sb.chaos, "{shards} shards: fault ledger drifted");
        }
    }

    // with every class live over a multi-hour mission, some fault
    // activity must actually have been scheduled — otherwise this
    // parity run proves nothing
    let agg: u64 = one
        .satellites
        .iter()
        .filter_map(|s| s.chaos.as_ref())
        .map(|c| c.crashes + c.dropouts + c.seu_scenes + c.heartbeats_suppressed)
        .sum();
    assert!(agg > 0, "no fault activity at all — chaos config too tame for this mission");
}

#[test]
fn scene_and_round_ledgers_reconcile_under_faults() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 4;
    cfg.constellation.scenes_per_satellite = 4;
    cfg.power.enabled = true;
    cfg.federated.enabled = true;
    cfg.chaos = chaos_on();
    cfg.chaos.seed = 77;

    for (name, rep) in [
        ("thread", run_constellation(&rt, &cfg, Version::V2).unwrap()),
        ("fleet", run_fleet(&rt, &cfg, Version::V2).unwrap()),
    ] {
        for sat in &rep.satellites {
            let chaos = sat.chaos.as_ref().expect("chaos ledger present when enabled");
            let shed = sat.power.as_ref().map(|p| p.scenes_shed).unwrap_or(0);
            assert_eq!(
                sat.result.scenes as u64 + shed + chaos.lost_to_crash,
                cfg.constellation.scenes_per_satellite as u64,
                "{name} sat {}: folded + shed + lost_to_crash must cover every scene",
                sat.index
            );
            let f = sat.federated.as_ref().expect("fed stats present when enabled");
            assert_eq!(
                f.rounds_completed + f.rounds_skipped_power + f.rounds_skipped_crash,
                f.rounds_scheduled,
                "{name} sat {}: round ledger must reconcile",
                sat.index
            );
            assert!(
                chaos.heartbeats_suppressed >= chaos.slices_blacked_out,
                "{name} sat {}: every blacked-out slice also suppressed its heartbeat",
                sat.index
            );
        }
    }
}

#[test]
fn trace_fault_events_match_the_chaos_ledger() {
    let Some(rt) = rt() else { return };
    let mut cfg = small_cfg();
    cfg.constellation.satellites = 4;
    cfg.constellation.scenes_per_satellite = 4;
    cfg.power.enabled = true;
    cfg.chaos = chaos_on();
    cfg.trace.enabled = true;
    cfg.trace.ring_cap = 1 << 16;

    for (name, rep) in [
        ("thread", run_constellation(&rt, &cfg, Version::V2).unwrap()),
        ("fleet", run_fleet(&rt, &cfg, Version::V2).unwrap()),
    ] {
        let trace = rep.trace.as_ref().expect("flight recorder on");
        assert_eq!(trace.evicted(), 0, "{name}: ring too small — counts would be partial");
        let count = |kind: SpanKind| trace.records().iter().filter(|r| r.kind == kind).count() as u64;
        let (mut lost, mut seu, mut dropouts_fired) = (0u64, 0u64, 0u64);
        for sat in &rep.satellites {
            let c = sat.chaos.as_ref().expect("ledger present");
            lost += c.lost_to_crash;
            seu += c.seu_scenes;
            // per-slice dropouts are the suppressed heartbeats that did
            // NOT come from a crash blackout
            dropouts_fired += c.heartbeats_suppressed - c.slices_blacked_out;
        }
        assert_eq!(count(SpanKind::FaultCrash), lost, "{name}: one crash event per lost scene");
        assert_eq!(count(SpanKind::FaultSeu), seu, "{name}: one SEU event per struck scene");
        assert_eq!(
            count(SpanKind::FaultDropout),
            dropouts_fired,
            "{name}: one dropout event per suppressed (non-blackout) heartbeat"
        );
    }
}
