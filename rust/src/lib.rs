//! # tiansuan — space-ground collaborative intelligence, reproduced
//!
//! Rust L3 coordinator for the Tiansuan cloud-native-satellite case study
//! (Wang et al., China Communications 2023).  The request path is pure
//! rust: AOT-compiled JAX/Pallas detector graphs are loaded from
//! `artifacts/*.hlo.txt` and executed through the PJRT C API ([`runtime`]);
//! python never runs at serving time.
//!
//! Module map (see DESIGN.md for the paper-to-module index):
//!
//! * [`runtime`]   — PJRT client wrapper: load HLO text, execute, marshal.
//! * [`data`]      — SynthDOTA procedural Earth-Observation scenes + tiler.
//! * [`detect`]    — box decode post-processing, NMS, AP/mAP evaluation.
//! * [`orbit`]     — Keplerian propagation, contact windows, eclipse model.
//! * [`link`]      — space-ground link: rate limits, burst loss, ARQ.
//! * [`sim`]       — unified mission-time core: `MissionClock` + `Timeline`
//!                   (scene cadence, contact windows, illumination phases)
//!                   from which every consumer derives its duty cycles.
//! * [`energy`]    — Baoyun power model (Tables 2–3), duty-cycle integration.
//! * [`power`]     — solar array, battery SoC, and the energy-aware
//!                   mission governor (defer / shed verdicts the
//!                   constellation driver applies per scene).
//! * [`cluster`]   — KubeEdge-like substrate: registry, metastore, message
//!                   bus, orchestrator, edgemesh.
//! * [`sedna`]     — collaborative-AI task layer: GlobalManager, workers,
//!                   joint inference / federated / incremental learning.
//! * [`coordinator`] — the paper's contribution: the satellite-ground
//!                   collaborative inference pipeline (Fig 5).  Three
//!                   execution paths: the sequential facade
//!                   (`coordinator::pipeline`), the staged concurrent
//!                   engine (`coordinator::engine` — bounded typed
//!                   channels, bit-identical results), and the
//!                   constellation runner (`coordinator::constellation` —
//!                   N satellites sharing one ground segment behind
//!                   contact-window-gated downlinks).
//! * [`telemetry`] — counters, gauges, histograms, report rendering.
//! * [`config`]    — JSON config system + `configs/*.json` platform files;
//!                   `engine`/`timing`/`constellation` sections drive the
//!                   staged execution paths.
//! * [`util`]      — deterministic RNG, mini-JSON, CLI, bench harness,
//!                   thread pool + scoped stage workers (offline
//!                   substitutes for rand / serde / clap / criterion /
//!                   tokio).

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod detect;
pub mod energy;
pub mod link;
pub mod orbit;
pub mod power;
pub mod runtime;
pub mod sedna;
pub mod sim;
pub mod telemetry;
pub mod util;
// coordinator lands last (depends on everything above).

/// Shared result alias.
pub type Result<T> = anyhow::Result<T>;
