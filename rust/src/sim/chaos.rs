//! Deterministic chaos engine: seeded fault injection for both
//! constellation engines.
//!
//! The paper's Tiansuan deployment survives a hostile environment —
//! lossy downlinks, radiation upsets, nodes that die mid-pass — and the
//! related constellation-scale work (arXiv:2111.12769's power-limited
//! node churn, the On-Orbit Space AI robustness arguments) insists that
//! fleet algorithms be validated under faults, not just nominal runs.
//! This module compiles a per-satellite [`FaultPlan`] at mission start
//! from the validated `chaos` config section and hands both engines the
//! same typed fault schedule.
//!
//! Determinism contract: a plan is a pure function of
//! `(chaos.seed, satellite index, horizon, scene count)` — never of the
//! engine, shard count, or admission cap.  Crash and dropout windows
//! are Poisson-scheduled at compile time; SEU strikes are decided per
//! scene index up front; frame faults are drawn from a dedicated
//! per-satellite stream consumed once per completed transfer attempt,
//! which both engines execute in the identical virtual order.  The
//! same seed therefore reproduces the identical fault plan, trace
//! stream, and report everywhere (`tests/chaos_invariants.rs`).
//!
//! Fault taxonomy ([`FaultKind`]):
//!
//! * `NodeCrash` — the satellite goes dark for `crash_recovery_s`:
//!   captures in the window are lost (counted `lost_to_crash`, the
//!   scene-conservation term), contact slices opening in the window
//!   are skipped without draining *or* charging a window failure (the
//!   queue replays the items in the next healthy window — crash-safe
//!   recovery with no double-count), heartbeats stop (the registry
//!   walks the node through `NotReady` → `Offline` and the
//!   orchestrator fails its pods over), and federated rounds due in
//!   the window are reported as `rounds_skipped_crash`.
//! * `FrameCorrupt` / `FrameTruncate` — a completed downlink transfer
//!   arrives garbled or short; the receiver's transfer checksum rejects
//!   it and [`crate::link::Link::transmit_checked`] retries under the
//!   capped-exponential-backoff ARQ policy.
//! * `SeuBitFlip` — bits flip in the checked-out pixel buffer between
//!   capture and filtering ([`apply_seu`]); the pipeline is NaN-safe
//!   downstream (quantizer maps NaN→0, NMS orders by `total_cmp`), so
//!   the scene still folds.
//! * `RegistryDropout` — heartbeats are suppressed for
//!   `dropout_silence_s` while the data plane keeps flowing; the
//!   control plane sees `NotReady`/eviction and recovery.

use crate::config::ChaosConfig;
use crate::link::{ArqPolicy, FrameFault};
use crate::util::rng::Rng;

/// Typed fault classes the plan schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    NodeCrash,
    FrameCorrupt,
    FrameTruncate,
    SeuBitFlip,
    RegistryDropout,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeCrash => "node_crash",
            FaultKind::FrameCorrupt => "frame_corrupt",
            FaultKind::FrameTruncate => "frame_truncate",
            FaultKind::SeuBitFlip => "seu_bit_flip",
            FaultKind::RegistryDropout => "registry_dropout",
        }
    }
}

/// Per-satellite chaos accounting, surfaced on the satellite report and
/// bit-compared between engines by the parity suite.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Crash windows in this satellite's plan.
    pub crashes: u64,
    /// Scenes never captured because the satellite was dark.
    pub lost_to_crash: u64,
    /// Contact slices skipped (not drained, not failure-charged)
    /// because they opened inside a crash window.
    pub slices_blacked_out: u64,
    /// Scenes whose pixel buffer took an SEU strike.
    pub seu_scenes: u64,
    /// Dropout windows in this satellite's plan.
    pub dropouts: u64,
    /// Heartbeats suppressed by crash or dropout windows.
    pub heartbeats_suppressed: u64,
}

/// Compiled, per-satellite fault schedule.  See the module docs for the
/// determinism contract.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    crash_windows: Vec<(f64, f64)>,
    dropout_windows: Vec<(f64, f64)>,
    /// Per scene index: `Some(seed)` = SEU strike, applied with
    /// [`apply_seu`] right after capture.
    seu: Vec<Option<u64>>,
    seu_flips: u32,
    frame_rng: Rng,
    frame_corrupt_rate: f64,
    frame_truncate_rate: f64,
    /// Transfer-level retry policy for the chaos drain path.
    pub arq: ArqPolicy,
}

/// Poisson-schedule `rate_per_hour` events over the horizon, each
/// lasting `dur_s`, merging overlaps into maximal windows.
fn poisson_windows(rng: &mut Rng, rate_per_hour: f64, horizon_s: f64, dur_s: f64) -> Vec<(f64, f64)> {
    let lambda = rate_per_hour * horizon_s / 3600.0;
    if lambda <= 0.0 || horizon_s <= 0.0 {
        return Vec::new();
    }
    let n = rng.poisson(lambda);
    let mut starts: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, horizon_s)).collect();
    starts.sort_by(f64::total_cmp);
    let mut out: Vec<(f64, f64)> = Vec::new();
    for s in starts {
        let e = s + dur_s;
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

fn in_windows(windows: &[(f64, f64)], t: f64) -> bool {
    // half-open [start, end): a satellite recovers exactly at window end
    windows.iter().any(|&(s, e)| t >= s && t < e)
}

impl FaultPlan {
    /// Compile the plan for one satellite.  Pure in
    /// `(cfg.seed, sat_index, horizon_s, scenes)`; the four fault
    /// classes draw from independent forked streams so changing one
    /// rate never reshuffles another class's schedule.
    pub fn compile(cfg: &ChaosConfig, sat_index: usize, horizon_s: f64, scenes: usize) -> FaultPlan {
        let mut root = Rng::new(
            cfg.seed
                .wrapping_add(0x51_C4A0_5EED)
                .wrapping_add((sat_index as u64).wrapping_mul(0x9E3779B97F4A7C15)),
        );
        let mut crash_rng = root.fork(1);
        let mut dropout_rng = root.fork(2);
        let mut seu_rng = root.fork(3);
        let frame_rng = root.fork(4);
        let crash_windows =
            poisson_windows(&mut crash_rng, cfg.crash_rate_per_hour, horizon_s, cfg.crash_recovery_s);
        let dropout_windows = poisson_windows(
            &mut dropout_rng,
            cfg.dropout_rate_per_hour,
            horizon_s,
            cfg.dropout_silence_s,
        );
        let seu = (0..scenes)
            .map(|_| if seu_rng.bool(cfg.seu_rate) { Some(seu_rng.next_u64()) } else { None })
            .collect();
        FaultPlan {
            crash_windows,
            dropout_windows,
            seu,
            seu_flips: cfg.seu_flips,
            frame_rng,
            frame_corrupt_rate: cfg.frame_corrupt_rate,
            frame_truncate_rate: cfg.frame_truncate_rate,
            arq: ArqPolicy {
                max_retries: cfg.arq_max_retries,
                backoff_initial_s: cfg.arq_backoff_initial_s,
                backoff_cap_s: cfg.arq_backoff_cap_s,
            },
        }
    }

    /// Is the satellite dark at mission time `t`?
    pub fn crashed_at(&self, t: f64) -> bool {
        in_windows(&self.crash_windows, t)
    }

    /// The crash window containing `t`, for trace emission.
    pub fn crash_window_at(&self, t: f64) -> Option<(f64, f64)> {
        self.crash_windows.iter().copied().find(|&(s, e)| t >= s && t < e)
    }

    /// Are heartbeats suppressed at `t`?  True during crashes (the node
    /// is dark) and during pure control-plane dropouts.
    pub fn heartbeat_suppressed_at(&self, t: f64) -> bool {
        self.crashed_at(t) || in_windows(&self.dropout_windows, t)
    }

    /// Is `t` inside a dropout window (control plane only)?
    pub fn dropout_at(&self, t: f64) -> bool {
        in_windows(&self.dropout_windows, t)
    }

    /// SEU seed for scene `idx`, if the plan strikes it.
    pub fn seu_for_scene(&self, idx: usize) -> Option<u64> {
        self.seu.get(idx).copied().flatten()
    }

    /// Bits flipped per SEU strike.
    pub fn seu_flips(&self) -> u32 {
        self.seu_flips
    }

    /// Draw the frame verdict for one completed transfer attempt.
    /// Consumes exactly one stream draw per call; both engines call it
    /// in the same virtual order, keeping the stream aligned.
    pub fn next_frame_fault(&mut self) -> Option<FrameFault> {
        let u = self.frame_rng.f64();
        if u < self.frame_corrupt_rate {
            Some(FrameFault::Corrupt)
        } else if u < self.frame_corrupt_rate + self.frame_truncate_rate {
            Some(FrameFault::Truncate)
        } else {
            None
        }
    }

    pub fn crash_windows(&self) -> &[(f64, f64)] {
        &self.crash_windows
    }

    pub fn dropout_windows(&self) -> &[(f64, f64)] {
        &self.dropout_windows
    }

    /// Scheduled faults as `(time, kind)` pairs — the window starts plus
    /// per-scene SEU indices (frame faults are per-transfer draws, not
    /// pre-scheduled).  For reporting and tests.
    pub fn scheduled(&self) -> Vec<(f64, FaultKind)> {
        let mut out: Vec<(f64, FaultKind)> = self
            .crash_windows
            .iter()
            .map(|&(s, _)| (s, FaultKind::NodeCrash))
            .chain(self.dropout_windows.iter().map(|&(s, _)| (s, FaultKind::RegistryDropout)))
            .collect();
        out.sort_by(|a, b| a.0.total_cmp(&b.0));
        out
    }
}

/// Flip `flips` random bits in a checked-out pixel buffer — the SEU
/// model.  Pure in `(seed, flips, buffer length)`: both engines apply
/// the identical strike to the identical capture.  Flips can produce
/// NaN/inf pixels; downstream consumers are NaN-safe (the i8 quantizer
/// maps NaN→0, NMS sorts with `total_cmp`), so a struck scene degrades
/// instead of wedging the pipeline.
pub fn apply_seu(pixels: &mut [f32], seed: u64, flips: u32) {
    if pixels.is_empty() {
        return;
    }
    let mut rng = Rng::new(seed);
    for _ in 0..flips {
        let i = rng.below(pixels.len() as u64) as usize;
        let bit = rng.below(32) as u32;
        pixels[i] = f32::from_bits(pixels[i].to_bits() ^ (1u32 << bit));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic() -> ChaosConfig {
        ChaosConfig {
            enabled: true,
            seed: 17,
            crash_rate_per_hour: 1.0,
            crash_recovery_s: 400.0,
            frame_corrupt_rate: 0.1,
            frame_truncate_rate: 0.05,
            seu_rate: 0.3,
            seu_flips: 3,
            dropout_rate_per_hour: 2.0,
            dropout_silence_s: 120.0,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn plan_is_a_pure_function_of_seed_and_sat() {
        let cfg = chaotic();
        let mut a = FaultPlan::compile(&cfg, 3, 21_600.0, 16);
        let mut b = FaultPlan::compile(&cfg, 3, 21_600.0, 16);
        assert_eq!(a.crash_windows, b.crash_windows);
        assert_eq!(a.dropout_windows, b.dropout_windows);
        assert_eq!(a.seu, b.seu);
        for _ in 0..200 {
            assert_eq!(a.next_frame_fault(), b.next_frame_fault());
        }
        // a different satellite draws a different plan
        let c = FaultPlan::compile(&cfg, 4, 21_600.0, 16);
        assert!(
            a.crash_windows != c.crash_windows
                || a.dropout_windows != c.dropout_windows
                || a.seu != c.seu,
            "sat 3 and sat 4 drew identical plans"
        );
    }

    #[test]
    fn zero_rates_schedule_nothing() {
        let cfg = ChaosConfig { enabled: true, ..ChaosConfig::default() };
        let mut p = FaultPlan::compile(&cfg, 0, 21_600.0, 32);
        assert!(p.crash_windows().is_empty());
        assert!(p.dropout_windows().is_empty());
        assert!((0..32).all(|i| p.seu_for_scene(i).is_none()));
        for _ in 0..100 {
            assert_eq!(p.next_frame_fault(), None);
        }
        assert!(p.scheduled().is_empty());
    }

    #[test]
    fn crash_windows_are_sorted_disjoint_and_half_open() {
        let cfg = ChaosConfig {
            crash_rate_per_hour: 20.0, // dense: forces merges
            crash_recovery_s: 500.0,
            ..chaotic()
        };
        let p = FaultPlan::compile(&cfg, 1, 43_200.0, 4);
        let w = p.crash_windows();
        assert!(!w.is_empty(), "20/h over 12h must schedule crashes");
        for pair in w.windows(2) {
            assert!(pair[0].1 < pair[1].0, "windows must be disjoint after merge: {pair:?}");
        }
        for &(s, e) in w {
            assert!(e - s >= cfg.crash_recovery_s - 1e-9);
            assert!(p.crashed_at(s), "closed at start");
            assert!(!p.crashed_at(e), "open at end: the sat recovers exactly at window end");
        }
        assert!(!p.crashed_at(-1.0));
    }

    #[test]
    fn heartbeats_suppressed_during_crash_and_dropout() {
        let cfg = chaotic();
        let p = FaultPlan::compile(&cfg, 2, 43_200.0, 4);
        for &(s, _) in p.crash_windows() {
            assert!(p.heartbeat_suppressed_at(s));
        }
        for &(s, _) in p.dropout_windows() {
            assert!(p.heartbeat_suppressed_at(s));
            assert!(p.dropout_at(s));
        }
    }

    #[test]
    fn frame_fault_stream_matches_rates() {
        let mut p = FaultPlan::compile(&chaotic(), 0, 21_600.0, 4);
        let n = 20_000;
        let (mut corrupt, mut truncate) = (0u32, 0u32);
        for _ in 0..n {
            match p.next_frame_fault() {
                Some(FrameFault::Corrupt) => corrupt += 1,
                Some(FrameFault::Truncate) => truncate += 1,
                None => {}
            }
        }
        let (fc, ft) = (corrupt as f64 / n as f64, truncate as f64 / n as f64);
        assert!((fc - 0.1).abs() < 0.01, "corrupt rate {fc}");
        assert!((ft - 0.05).abs() < 0.01, "truncate rate {ft}");
    }

    #[test]
    fn seu_strikes_follow_rate_and_apply_deterministically() {
        let p = FaultPlan::compile(&chaotic(), 5, 21_600.0, 1000);
        let struck = (0..1000).filter(|&i| p.seu_for_scene(i).is_some()).count();
        assert!((200..400).contains(&struck), "seu_rate 0.3 struck {struck}/1000");
        // out-of-range scene index: no strike, no panic
        assert_eq!(p.seu_for_scene(5000), None);

        let seed = p.seu_for_scene((0..1000).find(|&i| p.seu_for_scene(i).is_some()).unwrap());
        let mut a: Vec<f32> = (0..128).map(|i| i as f32 / 128.0).collect();
        let mut b = a.clone();
        apply_seu(&mut a, seed.unwrap(), 3);
        apply_seu(&mut b, seed.unwrap(), 3);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "same seed, same strike"
        );
        let changed = a.iter().zip((0..128).map(|i| i as f32 / 128.0)).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
        assert!(changed >= 1 && changed <= 3, "3 flips touch 1..=3 pixels, got {changed}");
    }

    #[test]
    fn apply_seu_handles_empty_buffer() {
        let mut empty: Vec<f32> = Vec::new();
        apply_seu(&mut empty, 42, 8);
        assert!(empty.is_empty());
    }

    #[test]
    fn fault_kind_names_are_stable() {
        assert_eq!(FaultKind::NodeCrash.name(), "node_crash");
        assert_eq!(FaultKind::FrameCorrupt.name(), "frame_corrupt");
        assert_eq!(FaultKind::FrameTruncate.name(), "frame_truncate");
        assert_eq!(FaultKind::SeuBitFlip.name(), "seu_bit_flip");
        assert_eq!(FaultKind::RegistryDropout.name(), "registry_dropout");
    }

    #[test]
    fn scheduled_lists_window_starts_in_time_order() {
        let p = FaultPlan::compile(&chaotic(), 7, 43_200.0, 4);
        let sched = p.scheduled();
        assert_eq!(sched.len(), p.crash_windows().len() + p.dropout_windows().len());
        for pair in sched.windows(2) {
            assert!(pair[0].0 <= pair[1].0, "out of order: {pair:?}");
        }
    }
}
