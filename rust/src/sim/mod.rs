//! Unified mission-time simulation core.
//!
//! One virtual clock drives every time domain the paper's headline
//! numbers emerge from: scene-capture cadence, orbital contact windows,
//! lossy-link airtime, eclipse phases, and duty-cycled energy.  Before
//! this layer, those domains lived in disconnected modules (the energy
//! meter was fed hardcoded comm/camera duties while the link tracked
//! real busy seconds that never reached it); now every consumer derives
//! its timing from a [`Timeline`] over a [`MissionClock`].
//!
//! * [`MissionClock`] — monotone virtual mission seconds; the one owner
//!   of "now".
//! * [`Timeline`] — event sources over the clock: contact windows,
//!   sunlit/eclipse spans, scene cadence ([`scene_timing`]), and duty
//!   derivation ([`DutyCycles`]).  Degenerate (always-in-contact) for
//!   single-satellite paths, orbital for the constellation.
//! * [`chaos`] — deterministic seeded fault injection: per-satellite
//!   [`FaultPlan`]s (crashes, frame faults, SEUs, registry dropouts)
//!   compiled at mission start and replayed identically by both
//!   engines.
//! * [`fleet`] — the sharded virtual-time event scheduler that steps
//!   [`SatMachine`] state machines (one per satellite) from per-shard
//!   binary heaps, making fleet size a data-structure problem instead
//!   of a thread-count problem.
//!
//! See DESIGN.md §"Mission-time simulation core" for which module
//! derives which duty cycle, and §"Fleet engine" for the scheduler.

mod chaos;
mod clock;
mod fleet;
mod timeline;

pub use chaos::{apply_seu, ChaosStats, FaultKind, FaultPlan};
pub use clock::MissionClock;
pub use fleet::{
    run_sharded, EventKey, EventKind, FleetRunStats, MachineStep, SatMachine, StubReport, StubSat,
    WaitSummary, ADMISSION_WAIT_BUCKETS, ADMISSION_WAIT_FIRST_BOUND_S,
};
pub use timeline::{
    scan_spans, scene_timing, ContactSlice, DutyCycles, Span, Timeline, GROUND_S_PER_TILE,
    ONBOARD_S_PER_TILE,
};
