//! The mission clock: one owner for virtual mission time.
//!
//! Every time domain in the system — scene capture cadence, contact
//! windows, link airtime, energy integration — advances against this
//! clock, so the domains can never desynchronize.  The clock is plain
//! seconds since mission epoch; there is no wallclock anywhere in the
//! simulation core (wallclock exists only in perf telemetry).

/// Monotone virtual mission time, seconds since epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MissionClock {
    now_s: f64,
}

impl MissionClock {
    pub fn new() -> MissionClock {
        MissionClock { now_s: 0.0 }
    }

    /// Start the clock at an arbitrary epoch offset (e.g. a satellite
    /// phased into an already-running mission).
    pub fn starting_at(t0_s: f64) -> MissionClock {
        MissionClock { now_s: t0_s }
    }

    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Advance by a non-negative interval; returns the new time.
    pub fn advance(&mut self, dt_s: f64) -> f64 {
        assert!(dt_s >= 0.0, "mission time is monotone (dt {dt_s})");
        self.now_s += dt_s;
        self.now_s
    }

    /// Jump forward to an absolute time; no-op if `t_s` is in the past
    /// (the clock never rewinds).
    pub fn advance_to(&mut self, t_s: f64) -> f64 {
        if t_s > self.now_s {
            self.now_s = t_s;
        }
        self.now_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero() {
        assert_eq!(MissionClock::new().now_s(), 0.0);
    }

    #[test]
    fn advance_accumulates() {
        let mut c = MissionClock::new();
        c.advance(30.0);
        c.advance(12.5);
        assert!((c.now_s() - 42.5).abs() < 1e-12);
    }

    #[test]
    fn advance_to_never_rewinds() {
        let mut c = MissionClock::starting_at(100.0);
        c.advance_to(50.0);
        assert_eq!(c.now_s(), 100.0);
        c.advance_to(150.0);
        assert_eq!(c.now_s(), 150.0);
    }

    #[test]
    #[should_panic]
    fn negative_advance_panics() {
        MissionClock::new().advance(-1.0);
    }
}
