//! The mission timeline: event sources layered over the [`MissionClock`].
//!
//! A [`Timeline`] owns the virtual clock plus everything that makes a
//! satellite's mission time *structured*: scene-capture cadence (from
//! [`TimingConfig`]), contact windows (from [`crate::orbit`]), and
//! eclipse/illumination phases.  Consumers derive their duty cycles from
//! it instead of hardcoding them:
//!
//! * compute duty — onboard busy seconds per scene period ([`scene_timing`]);
//! * comm duty    — actual [`crate::link::Link`] airtime inside contact
//!                  windows, attributed to the scene period it occurred in;
//! * camera duty  — capture-event duration per scene period.
//!
//! Two flavors:
//!
//! * [`Timeline::degenerate`] — the single-satellite scenario paths:
//!   always in contact, always sunlit, duties at the configured nominal
//!   values.  This preserves the pre-`sim` results bit-for-bit (guarded
//!   by `rust/tests/engine_parity.rs`).
//! * [`Timeline::orbital`] — the constellation path: real contact
//!   windows, eclipse phases from the orbit geometry, observed duties.
//!
//! Contact time is consumed *incrementally*: [`Timeline::due_contacts`]
//! hands back each window span at most once, clipped to the unconsumed
//! part that has elapsed by the caller's mission time, so no downlink can
//! double-spend window airtime.

use crate::config::TimingConfig;
use crate::orbit::{ContactWindow, GroundStation, Propagator};

use super::MissionClock;

/// Modeled onboard service time per tile (Raspberry-Pi-class YOLO-tiny;
/// drives energy duty cycles and orbital-time latency, not wallclock).
pub const ONBOARD_S_PER_TILE: f64 = 0.65;
/// Ground GPU-class service time per tile.
pub const GROUND_S_PER_TILE: f64 = 0.05;

/// Virtual (busy, scene_period) seconds for a scene with `n_kept`
/// processed tiles.  One definition shared by the result fold, the
/// staged engines, and the constellation's downlink `ready_at`/window
/// gating, so the time domains can never desynchronize.
pub fn scene_timing(timing: &TimingConfig, n_kept: usize) -> (f64, f64) {
    let busy = n_kept as f64 * ONBOARD_S_PER_TILE + timing.capture_overhead_s;
    (busy, busy.max(timing.scene_period_floor_s))
}

/// Half-open interval of mission time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub start: f64,
    pub end: f64,
}

impl Span {
    pub fn duration_s(&self) -> f64 {
        (self.end - self.start).max(0.0)
    }

    pub fn contains(&self, t: f64) -> bool {
        t >= self.start && t < self.end
    }

    /// Seconds of overlap with `[t0, t1)`.
    pub fn overlap_s(&self, t0: f64, t1: f64) -> f64 {
        (self.end.min(t1) - self.start.max(t0)).max(0.0)
    }
}

/// Coarse-scan a boolean predicate of mission time into maximal true
/// spans (the eclipse/illumination event source; contact windows use the
/// bisection-refined scan in [`crate::orbit`]).
pub fn scan_spans(pred: impl Fn(f64) -> bool, t0: f64, t1: f64, step_s: f64) -> Vec<Span> {
    assert!(t1 > t0 && step_s > 0.0);
    let mut spans = Vec::new();
    let mut open: Option<f64> = if pred(t0) { Some(t0) } else { None };
    let mut t = t0;
    while t < t1 {
        let tn = (t + step_s).min(t1);
        match (open, pred(tn)) {
            (None, true) => open = Some(tn),
            (Some(s), false) => {
                spans.push(Span { start: s, end: tn });
                open = None;
            }
            _ => {}
        }
        t = tn;
    }
    if let Some(s) = open {
        spans.push(Span { start: s, end: t1 });
    }
    spans
}

/// Per-scene-period duty cycles handed to the energy integrator.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DutyCycles {
    /// Onboard inference busy fraction.
    pub compute: f64,
    /// Transmitter busy fraction (link airtime inside contact windows).
    pub comm: f64,
    /// Camera capture fraction.
    pub camera: f64,
}

/// One drainable chunk of a physical contact window, as handed out by
/// [`Timeline::due_contacts`].
#[derive(Clone, Debug)]
pub struct ContactSlice {
    /// The elapsed, not-yet-consumed span (aos/los clipped).
    pub window: ContactWindow,
    /// True when this slice reaches the physical window's LOS.  Downlink
    /// failure accounting counts a failed *pass* only on such slices —
    /// a transfer that didn't fit a mid-pass slice still has the rest of
    /// the pass ahead of it.
    pub closes_pass: bool,
}

/// One satellite's mission timeline.
///
/// Contact geometry lives in two layers:
///
/// * `tracks` — per-station window lists (index = `station_id`), the raw
///   visibility each station has of this satellite.  Tracks from
///   different stations may overlap in time.
/// * `contacts` — the *scheduled merged view*: one sorted, pairwise
///   disjoint sequence of tagged windows (for a single station this is
///   the track verbatim; for a multi-station network it is the contact
///   scheduler's arbitration of the overlaps).  All consumption
///   (`due_contacts`) and the indexed lookups run against this view.
///
/// Contact consumption is tracked by `consumed_to` alone: merged windows
/// are sorted by AOS and pairwise disjoint (`next.aos >= prev.los`), so a
/// window is fully spent exactly when `los <= consumed_to`, and the
/// resume point is an O(log windows) `partition_point` query instead of
/// a stored linear cursor — what lets a 100k-satellite fleet step
/// without paying O(windows) per event.
#[derive(Clone, Debug)]
pub struct Timeline {
    clock: MissionClock,
    timing: TimingConfig,
    /// Per-station visibility tracks (index = `station_id`).
    tracks: Vec<Vec<ContactWindow>>,
    /// Scheduled merged view: sorted, disjoint, station-tagged.
    contacts: Vec<ContactWindow>,
    /// Contact time at or before this instant has been handed out.
    consumed_to: f64,
    /// Sunlit spans; `None` means always sunlit (degenerate timeline).
    sunlit: Option<Vec<Span>>,
    horizon_s: f64,
}

impl Timeline {
    /// Always-in-contact, always-sunlit timeline: the single-satellite
    /// scenario abstraction (the ground segment is reachable whenever a
    /// result is ready).  Duty cycles come out at the configured nominal
    /// values, which keeps pre-`sim` results bit-identical.
    pub fn degenerate(timing: &TimingConfig, horizon_s: f64) -> Timeline {
        let contacts = vec![ContactWindow {
            aos: 0.0,
            los: horizon_s,
            max_elevation_deg: 90.0,
            truncated: false,
            station_id: 0,
        }];
        Timeline::from_parts(timing, contacts, None, horizon_s)
    }

    /// Timeline for one orbital plane over a single ground station:
    /// contact windows from visibility geometry, illumination phases
    /// from the cylindrical Earth-shadow model.  (Multi-station
    /// timelines go through [`Timeline::from_tracks`] with a scheduler-
    /// arbitrated merged view.)
    pub fn orbital<P: Propagator + ?Sized>(
        timing: &TimingConfig,
        sat: &P,
        gs: &GroundStation,
        horizon_s: f64,
        step_s: f64,
    ) -> Timeline {
        let contacts = crate::orbit::contact_windows(sat, gs, 0.0, horizon_s, step_s);
        let sunlit = scan_spans(|t| !sat.in_eclipse(t), 0.0, horizon_s, step_s);
        Timeline::from_parts(timing, contacts, Some(sunlit), horizon_s)
    }

    /// Build a timeline directly from precomputed parts — the fleet
    /// engine's bulk path: 100k synthetic satellites should not each
    /// rescan orbital geometry.  `contacts` must be sorted by AOS and
    /// pairwise disjoint (`next.aos >= prev.los`), and `sunlit` spans
    /// likewise (use `None` for always-sunlit), matching what
    /// [`crate::orbit::contact_windows`] / [`scan_spans`] produce —
    /// the invariants the indexed lookups rely on.  The windows double
    /// as the single per-station track (`station_id` 0 by convention).
    pub fn from_parts(
        timing: &TimingConfig,
        contacts: Vec<ContactWindow>,
        sunlit: Option<Vec<Span>>,
        horizon_s: f64,
    ) -> Timeline {
        Timeline::from_tracks(timing, vec![contacts.clone()], contacts, sunlit, horizon_s)
    }

    /// The multi-station constructor: per-station visibility `tracks`
    /// (index = `station_id`, overlaps allowed *between* tracks) plus
    /// the scheduler's `merged` arbitration — sorted, pairwise disjoint,
    /// each window tagged with the station it was awarded to.  The
    /// merged view is what `due_contacts` consumes; disjointness is what
    /// makes "one satellite never transmits to two stations at once"
    /// true by construction.
    pub fn from_tracks(
        timing: &TimingConfig,
        tracks: Vec<Vec<ContactWindow>>,
        merged: Vec<ContactWindow>,
        sunlit: Option<Vec<Span>>,
        horizon_s: f64,
    ) -> Timeline {
        debug_assert!(
            merged.windows(2).all(|w| w[1].aos >= w[0].los),
            "merged contact windows must be sorted and disjoint"
        );
        debug_assert!(
            merged.iter().all(|w| w.station_id < tracks.len().max(1)),
            "merged window tagged with an unknown station"
        );
        debug_assert!(
            tracks.iter().all(|t| t.windows(2).all(|w| w[1].aos >= w[0].los)),
            "each per-station track must be sorted and disjoint"
        );
        if let Some(spans) = &sunlit {
            debug_assert!(
                spans.windows(2).all(|w| w[1].start >= w[0].end),
                "sunlit spans must be sorted and disjoint"
            );
        }
        Timeline {
            clock: MissionClock::new(),
            timing: timing.clone(),
            tracks,
            contacts: merged,
            consumed_to: 0.0,
            sunlit,
            horizon_s,
        }
    }

    pub fn timing(&self) -> &TimingConfig {
        &self.timing
    }

    pub fn now_s(&self) -> f64 {
        self.clock.now_s()
    }

    pub fn horizon_s(&self) -> f64 {
        self.horizon_s
    }

    /// Advance mission time by one scene period; returns the new time.
    pub fn advance(&mut self, dt_s: f64) -> f64 {
        self.clock.advance(dt_s)
    }

    /// Windows in the scheduled merged view.
    pub fn n_contacts(&self) -> usize {
        self.contacts.len()
    }

    /// Seconds of scheduled contact (merged view).
    pub fn contact_total_s(&self) -> f64 {
        self.contacts.iter().map(|w| w.duration_s()).sum()
    }

    /// Number of per-station tracks (1 for all single-station paths).
    pub fn n_stations(&self) -> usize {
        self.tracks.len()
    }

    /// Raw visibility track for one station (before scheduling).
    pub fn station_contacts(&self, station_id: usize) -> &[ContactWindow] {
        &self.tracks[station_id]
    }

    /// Seconds of raw visibility for one station.  Across stations these
    /// may sum to more than [`Timeline::contact_total_s`]: overlap the
    /// scheduler arbitrated away is visible here but not in the merged
    /// view.
    pub fn station_contact_total_s(&self, station_id: usize) -> f64 {
        self.tracks[station_id].iter().map(|w| w.duration_s()).sum()
    }

    pub fn in_contact(&self, t: f64) -> bool {
        // Windows are sorted and disjoint: the only candidate is the
        // first window whose LOS lies beyond t.
        let idx = self.contacts.partition_point(|w| w.los <= t);
        self.contacts.get(idx).is_some_and(|w| w.contains(t))
    }

    pub fn sunlit(&self, t: f64) -> bool {
        match &self.sunlit {
            None => true,
            Some(spans) => {
                let idx = spans.partition_point(|s| s.end <= t);
                spans.get(idx).is_some_and(|s| s.contains(t))
            }
        }
    }

    /// Sunlit seconds within `[t0, t1)`.
    pub fn sunlit_s(&self, t0: f64, t1: f64) -> f64 {
        match &self.sunlit {
            None => (t1 - t0).max(0.0),
            Some(spans) => {
                // Sum only spans that can overlap [t0, t1).  Skipped
                // spans would each have contributed exactly +0.0, so
                // the indexed sum is bit-identical to the full scan.
                let lo = spans.partition_point(|s| s.end <= t0);
                let hi = spans.partition_point(|s| s.start < t1);
                spans[lo..hi.max(lo)].iter().map(|s| s.overlap_s(t0, t1)).sum()
            }
        }
    }

    /// Fraction of `[t0, t1)` spent sunlit — what the power model's
    /// solar array integrates per scene period.
    pub fn sunlit_fraction(&self, t0: f64, t1: f64) -> f64 {
        let dt = t1 - t0;
        if dt <= 0.0 {
            return 0.0;
        }
        (self.sunlit_s(t0, t1) / dt).clamp(0.0, 1.0)
    }

    /// Contact spans that have elapsed by mission time `t`, clipped to
    /// the part not yet handed out.  Each returned slice is a drainable
    /// budget: the caller spends it against a [`crate::link::Link`] and
    /// it is never offered again.
    pub fn due_contacts(&mut self, t: f64) -> Vec<ContactSlice> {
        let mut out = Vec::new();
        // Indexed resume point: a window is fully spent exactly when its
        // LOS is at or before `consumed_to` (a closed pass sets
        // `consumed_to` to its clipped LOS, and successors start no
        // earlier), so binary search replaces the linear cursor scan.
        let first = self.contacts.partition_point(|w| w.los <= self.consumed_to);
        for w in &self.contacts[first..] {
            if w.aos >= t {
                break;
            }
            let start = w.aos.max(self.consumed_to);
            let end = w.los.min(t);
            let closes_pass = w.los <= t;
            if end > start {
                out.push(ContactSlice {
                    window: ContactWindow {
                        aos: start,
                        los: end,
                        max_elevation_deg: w.max_elevation_deg,
                        // slices inherit the source pass's flag; being a
                        // mid-pass clip is what `closes_pass` expresses
                        truncated: w.truncated,
                        station_id: w.station_id,
                    },
                    closes_pass,
                });
                self.consumed_to = end;
            }
            if !closes_pass {
                break;
            }
        }
        out
    }

    /// Everything left through the mission horizon (the end-of-mission
    /// tail drain).
    pub fn remaining_contacts(&mut self) -> Vec<ContactSlice> {
        self.due_contacts(self.horizon_s)
    }

    /// Duties for the degenerate timeline: compute from the scene's busy
    /// time, comm/camera at the configured nominal fractions (the
    /// always-in-contact abstraction has no windows to integrate over).
    pub fn nominal_duties(&self, busy_s: f64, period_s: f64) -> DutyCycles {
        DutyCycles {
            compute: busy_s / period_s,
            comm: self.timing.nominal_comm_duty,
            camera: self.timing.nominal_camera_duty,
        }
    }

    /// Duties derived from what actually happened during one scene
    /// period: onboard busy time, link airtime, and capture-event time.
    pub fn observed_duties(
        &self,
        busy_s: f64,
        period_s: f64,
        comm_busy_s: f64,
        camera_busy_s: f64,
    ) -> DutyCycles {
        let p = period_s.max(1e-9);
        DutyCycles {
            compute: (busy_s / p).clamp(0.0, 1.0),
            comm: (comm_busy_s / p).clamp(0.0, 1.0),
            camera: (camera_busy_s / p).clamp(0.0, 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{baoyun, beijing_station};

    fn timing() -> TimingConfig {
        TimingConfig::default()
    }

    #[test]
    fn scene_timing_floor_applies() {
        let t = timing();
        let (busy, period) = scene_timing(&t, 4);
        assert!((busy - (4.0 * ONBOARD_S_PER_TILE + t.capture_overhead_s)).abs() < 1e-12);
        assert_eq!(period, t.scene_period_floor_s);
        let (busy_big, period_big) = scene_timing(&t, 100);
        assert_eq!(busy_big, period_big, "above the floor, period tracks busy");
    }

    #[test]
    fn degenerate_always_in_contact_and_sunlit() {
        let tl = Timeline::degenerate(&timing(), 1000.0);
        assert!(tl.in_contact(0.0) && tl.in_contact(999.0));
        assert!(tl.sunlit(500.0));
        assert_eq!(tl.sunlit_s(0.0, 1000.0), 1000.0);
        assert_eq!(tl.n_contacts(), 1);
    }

    #[test]
    fn degenerate_nominal_duties_are_config_constants() {
        let t = timing();
        let tl = Timeline::degenerate(&t, 1000.0);
        let d = tl.nominal_duties(15.0, 30.0);
        assert_eq!(d.compute, 0.5);
        assert_eq!(d.comm, t.nominal_comm_duty);
        assert_eq!(d.camera, t.nominal_camera_duty);
    }

    #[test]
    fn due_contacts_consumes_incrementally() {
        let mut tl = Timeline::degenerate(&timing(), 100.0);
        let first = tl.due_contacts(30.0);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].window.aos, 0.0);
        assert_eq!(first[0].window.los, 30.0);
        assert!(!first[0].closes_pass, "the pass runs to the horizon");
        // nothing new before time advances
        assert!(tl.due_contacts(30.0).is_empty());
        let second = tl.due_contacts(60.0);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].window.aos, 30.0);
        assert_eq!(second[0].window.los, 60.0);
        let tail = tl.remaining_contacts();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].window.aos, 60.0);
        assert_eq!(tail[0].window.los, 100.0);
        assert!(tail[0].closes_pass, "the horizon closes the pass");
        assert!(tl.remaining_contacts().is_empty());
    }

    /// Two back-to-back physical passes sharing the t = 200 boundary.
    fn two_windows() -> Timeline {
        let w = |aos: f64, los: f64| ContactWindow {
            aos,
            los,
            max_elevation_deg: 45.0,
            truncated: false,
            station_id: 0,
        };
        Timeline::from_parts(&timing(), vec![w(100.0, 200.0), w(200.0, 300.0)], None, 400.0)
    }

    #[test]
    fn due_contacts_at_exact_los_neither_double_spends_nor_drops() {
        let mut tl = two_windows();
        // query exactly at the first window's LOS (half-open [aos, los)):
        // the whole first pass comes out, closed, and none of the second
        let first = tl.due_contacts(200.0);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].window.aos, 100.0);
        assert_eq!(first[0].window.los, 200.0);
        assert!(first[0].closes_pass);
        // same instant again: the shared boundary was consumed exactly once
        assert!(tl.due_contacts(200.0).is_empty());
        // the second pass starts at the shared boundary, intact
        let second = tl.due_contacts(300.0);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].window.aos, 200.0);
        assert_eq!(second[0].window.los, 300.0);
        assert!(second[0].closes_pass);
        assert!(tl.remaining_contacts().is_empty());
    }

    #[test]
    fn back_to_back_windows_conserve_airtime_across_query_patterns() {
        let mut tl = two_windows();
        let mut total = 0.0;
        let mut slices = 0;
        // repeated instants, a query landing on the shared boundary, and
        // mid-pass queries: every slice positive, airtime conserved
        for t in [150.0, 200.0, 200.0, 250.0, 260.0, 400.0] {
            for s in tl.due_contacts(t) {
                assert!(s.window.los > s.window.aos, "zero-length slice handed out");
                total += s.window.duration_s();
                slices += 1;
            }
        }
        assert!((total - 200.0).abs() < 1e-9, "consumed {total} of 200 s");
        assert_eq!(slices, 5);
        assert!(tl.remaining_contacts().is_empty());
    }

    #[test]
    fn due_contacts_never_double_spends() {
        let mut tl = Timeline::degenerate(&timing(), 500.0);
        let mut total = 0.0;
        for t in [100.0, 100.0, 250.0, 400.0] {
            for s in tl.due_contacts(t) {
                total += s.window.duration_s();
            }
        }
        for s in tl.remaining_contacts() {
            total += s.window.duration_s();
        }
        assert!((total - 500.0).abs() < 1e-9, "consumed {total} of 500 s");
    }

    #[test]
    fn indexed_lookup_matches_linear_scan_over_many_windows() {
        // The partition_point resume/lookup must agree with the naive
        // O(n) definitions on a fleet-scale window list, including
        // exact-edge queries at AOS/LOS boundaries.
        let w = |aos: f64, los: f64| ContactWindow {
            aos,
            los,
            max_elevation_deg: 30.0,
            truncated: false,
            station_id: 0,
        };
        let contacts: Vec<ContactWindow> =
            (0..200).map(|i| w(i as f64 * 100.0, i as f64 * 100.0 + 40.0)).collect();
        let spans: Vec<Span> =
            (0..200).map(|i| Span { start: i as f64 * 100.0 + 50.0, end: i as f64 * 100.0 + 90.0 }).collect();
        let tl = Timeline::from_parts(&timing(), contacts.clone(), Some(spans.clone()), 20_000.0);
        for i in 0..400 {
            let t = i as f64 * 50.0; // lands exactly on every boundary
            assert_eq!(tl.in_contact(t), contacts.iter().any(|c| c.contains(t)), "t={t}");
            assert_eq!(tl.sunlit(t), spans.iter().any(|s| s.contains(t)), "t={t}");
            let naive: f64 = spans.iter().map(|s| s.overlap_s(0.0, t)).sum();
            assert_eq!(tl.sunlit_s(0.0, t).to_bits(), naive.to_bits(), "t={t}");
        }
        // incremental consumption across all 200 passes conserves airtime
        let mut tl = tl;
        let mut total = 0.0;
        for i in 0..100 {
            for s in tl.due_contacts(i as f64 * 190.0) {
                assert!(s.window.los > s.window.aos);
                total += s.window.duration_s();
            }
        }
        for s in tl.remaining_contacts() {
            total += s.window.duration_s();
        }
        assert!((total - 200.0 * 40.0).abs() < 1e-9, "consumed {total} of 8000 s");
    }

    #[test]
    fn from_tracks_merged_view_keeps_station_tags_and_tracks() {
        // Two stations with overlapping visibility; the (pre-arbitrated)
        // merged view hands station 1 the middle of station 0's pass.
        let w = |aos: f64, los: f64, id: usize| ContactWindow {
            aos,
            los,
            max_elevation_deg: 40.0,
            truncated: false,
            station_id: id,
        };
        let tracks = vec![
            vec![w(100.0, 300.0, 0), w(500.0, 600.0, 0)],
            vec![w(150.0, 250.0, 1)],
        ];
        let merged =
            vec![w(100.0, 150.0, 0), w(150.0, 250.0, 1), w(250.0, 300.0, 0), w(500.0, 600.0, 0)];
        let mut tl = Timeline::from_tracks(&timing(), tracks, merged, None, 1000.0);

        assert_eq!(tl.n_stations(), 2);
        assert_eq!(tl.station_contacts(0).len(), 2);
        assert_eq!(tl.station_contacts(1).len(), 1);
        assert!((tl.station_contact_total_s(0) - 300.0).abs() < 1e-12);
        assert!((tl.station_contact_total_s(1) - 100.0).abs() < 1e-12);
        // raw visibility exceeds the scheduled merged time: the overlap
        // was arbitrated away, not double-counted
        assert!((tl.contact_total_s() - 300.0).abs() < 1e-12);
        assert_eq!(tl.n_contacts(), 4);

        // consumption walks the merged view, slices keep their tags and
        // never overlap in time (pairwise — the no-double-transmit
        // invariant at the timeline level)
        let mut slices = Vec::new();
        for t in [120.0, 200.0, 275.0, 1000.0] {
            slices.extend(tl.due_contacts(t));
        }
        assert_eq!(slices.len(), 7, "{slices:?}");
        for pair in slices.windows(2) {
            assert!(pair[0].window.los <= pair[1].window.aos, "overlapping slices {pair:?}");
        }
        let by_station = |id: usize| -> f64 {
            slices
                .iter()
                .filter(|s| s.window.station_id == id)
                .map(|s| s.window.duration_s())
                .sum()
        };
        assert!((by_station(0) - 200.0).abs() < 1e-9);
        assert!((by_station(1) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn orbital_timeline_has_windows_and_eclipse() {
        let tl = Timeline::orbital(&timing(), &baoyun(), &beijing_station(), 86_400.0, 10.0);
        assert!(tl.n_contacts() >= 1, "a day of LEO should see the station");
        assert!(tl.contact_total_s() > 0.0);
        let sunlit = tl.sunlit_s(0.0, 86_400.0);
        assert!(
            sunlit > 0.3 * 86_400.0 && sunlit < 86_400.0,
            "sunlit fraction {} should show real eclipse phases",
            sunlit / 86_400.0
        );
    }

    #[test]
    fn orbital_sunlit_spans_contiguous_and_nonoverlapping() {
        // The illumination event source the solar model integrates:
        // sunlit spans must be strictly ordered, non-overlapping, and
        // complementary to the eclipse spans over the same horizon.
        let sat = baoyun();
        let horizon = 2.0 * sat.period_s();
        let sunlit = scan_spans(|t| !sat.in_eclipse(t), 0.0, horizon, 10.0);
        let dark = scan_spans(|t| sat.in_eclipse(t), 0.0, horizon, 10.0);
        for w in sunlit.windows(2) {
            assert!(w[0].end <= w[1].start, "overlap: {:?} then {:?}", w[0], w[1]);
        }
        for s in &sunlit {
            assert!(s.end > s.start, "degenerate span {s:?}");
            for d in &dark {
                assert_eq!(s.overlap_s(d.start, d.end), 0.0, "sunlit {s:?} overlaps dark {d:?}");
            }
        }
        let total: f64 = sunlit.iter().map(|s| s.duration_s()).sum::<f64>()
            + dark.iter().map(|s| s.duration_s()).sum::<f64>();
        assert!((total - horizon).abs() < 1e-6, "spans must tile the horizon: {total}");
    }

    #[test]
    fn sunlit_s_partial_span_integration_exact() {
        // Partial-period integration at span boundaries is exactly the
        // overlap the solar model will charge: querying across a
        // boundary must return precisely the inside part, and chunked
        // queries must sum to the whole (up to f64 summation noise).
        let tl = Timeline::orbital(&timing(), &baoyun(), &beijing_station(), 20_000.0, 10.0);
        let sunlit = scan_spans(|t| !baoyun().in_eclipse(t), 0.0, 20_000.0, 10.0);
        let s = sunlit.iter().find(|s| s.start > 0.0).expect("an interior sunlit span");
        // interval straddling the span start: only the inside half counts
        assert!((tl.sunlit_s(s.start - 7.0, s.start + 13.0) - 13.0).abs() < 1e-9);
        // interval fully inside the span: its whole duration
        let mid = (s.start + s.end) / 2.0;
        assert!((tl.sunlit_s(mid - 1.0, mid + 1.0) - 2.0).abs() < 1e-12);
        // interval straddling the span end
        assert!((tl.sunlit_s(s.end - 5.0, s.end + 20.0) - 5.0).abs() < 1e-9);
        // chunked integration reproduces the total
        let total = tl.sunlit_s(0.0, 20_000.0);
        let mut acc = 0.0;
        let mut t = 0.0;
        while t < 20_000.0 {
            let t1 = (t + 37.0).min(20_000.0); // deliberately uneven chunks
            acc += tl.sunlit_s(t, t1);
            t = t1;
        }
        assert!((acc - total).abs() < 1e-6, "chunked {acc} vs whole {total}");
        assert!(total > 0.0 && total < 20_000.0, "real eclipse phases expected");
    }

    #[test]
    fn sunlit_fraction_bounded_and_degenerate() {
        let tl = Timeline::orbital(&timing(), &baoyun(), &beijing_station(), 20_000.0, 10.0);
        let mut t = 0.0;
        while t < 20_000.0 {
            let f = tl.sunlit_fraction(t, t + 30.0);
            assert!((0.0..=1.0).contains(&f), "fraction {f} at t={t}");
            t += 30.0;
        }
        assert_eq!(tl.sunlit_fraction(100.0, 100.0), 0.0, "empty interval");
        let dg = Timeline::degenerate(&timing(), 1000.0);
        assert_eq!(dg.sunlit_fraction(0.0, 500.0), 1.0);
    }

    #[test]
    fn scan_spans_finds_intervals() {
        let spans = scan_spans(|t| (100.0..200.0).contains(&t), 0.0, 300.0, 10.0);
        assert_eq!(spans.len(), 1);
        assert!((spans[0].start - 100.0).abs() <= 10.0);
        assert!((spans[0].end - 200.0).abs() <= 10.0);
        assert!(spans[0].overlap_s(150.0, 160.0) > 9.9);
    }

    #[test]
    fn observed_duties_clamped() {
        let tl = Timeline::degenerate(&timing(), 100.0);
        let d = tl.observed_duties(40.0, 30.0, 45.0, 2.0);
        assert_eq!(d.compute, 1.0);
        assert_eq!(d.comm, 1.0);
        assert!((d.camera - 2.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn clock_advances_through_timeline() {
        let mut tl = Timeline::degenerate(&timing(), 100.0);
        assert_eq!(tl.now_s(), 0.0);
        tl.advance(30.0);
        tl.advance(30.0);
        assert!((tl.now_s() - 60.0).abs() < 1e-12);
    }
}
