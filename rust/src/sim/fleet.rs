//! Sharded virtual-time event scheduler — the mega-constellation core.
//!
//! The constellation runner used to spawn a capture thread plus onboard
//! stage workers *per satellite*, topping out at tens of sats.  This
//! module makes fleet size a data-structure problem instead: each
//! satellite is a [`SatMachine`] — a virtual-time state machine owning
//! its whole per-sat world (RNG streams, timeline cursor, downlink
//! queue, power state, fold accumulator) — advanced by typed mission
//! events drawn from a per-shard binary heap.
//!
//! # Event taxonomy
//!
//! [`EventKind`] names the four mission event classes:
//!
//! * `Capture` — a scene capture at its virtual capture time, including
//!   the scene-period drains that follow it;
//! * `ContactSlice` — one unconsumed contact-window slice of the
//!   mission tail (plus any federated rounds due by its LOS, which fire
//!   first so their weights can ride the pass);
//! * `RoundBoundary` — a federated round due after the last pass;
//! * `MissionEnd` — the horizon: force-fold, tail energy, report.
//!
//! # Deterministic ordering
//!
//! Heap keys order by `(virtual_time, sat_id, event_kind)` ascending
//! ([`EventKey`]'s `Ord`, using `f64::total_cmp`), so two events at the
//! same instant — a capture coinciding with another satellite's LOS
//! slice, a round boundary coinciding with an AOS — pop in one
//! documented order on every run and every shard count.
//!
//! Each machine keeps exactly ONE event in flight: its handler returns
//! the next event to arm ([`MachineStep::Yield`]) or retires the
//! machine ([`MachineStep::Done`]).  The heap therefore only
//! interleaves *independent* satellites; a satellite's own mission is
//! sequenced by its machine, which is what makes the fleet result
//! bit-identical to the thread-per-sat driver and invariant under shard
//! count (`tests/fleet_determinism.rs`, `tests/fleet_parity.rs`).
//!
//! # Shard ownership
//!
//! Satellites are assigned to shards by `sat_id % shards`; each shard
//! is stepped by one [`crate::util::pool`] scoped worker and owns its
//! machines exclusively — no locks between barriers.  Cross-shard
//! interaction (the shared ground HeavyDet segment, fleet FedAvg,
//! fleet-level gauges) happens only at round barriers: ground calls are
//! value-deterministic per call so their cross-shard interleaving is
//! unobservable, and everything order-sensitive (report sorting, FedAvg
//! replay, gauge aggregation) runs after the shards join, on reports
//! sorted by `sat_id` — which is why the barrier discipline preserves
//! the pinned fold order.
//!
//! `max_events_in_flight` caps concurrently-live machines per shard
//! (one in-flight event each): pending satellites are admitted lazily
//! in `sat_id` order as earlier ones retire, bounding heap and
//! scene-buffer footprint for 100k-sat fleets without changing any
//! result — satellites are independent between barriers.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use anyhow::Result;

use crate::config::TimingConfig;
use crate::orbit::ContactWindow;
use crate::telemetry::trace::{SatTracer, SpanKind, TracePayload};
use crate::telemetry::Histogram;
use crate::util::pool;

use super::timeline::{scene_timing, Span, Timeline};

/// Mission event classes, in documented tie-break order (the `u8`
/// discriminant is the third key of [`EventKey`]'s ordering).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Scene capture at its virtual capture time.
    Capture = 0,
    /// One tail contact-window slice (AOS..LOS drain opportunity).
    ContactSlice = 1,
    /// Federated round boundary after the last pass.
    RoundBoundary = 2,
    /// Mission horizon reached.
    MissionEnd = 3,
}

/// Scheduler heap key: events pop in ascending `(virtual_time, sat_id,
/// event_kind)` order.  `f64::total_cmp` gives a total order (no NaN
/// panics, -0.0 < +0.0), so equal-timestamp events across satellites
/// tie-break on `sat_id` and then on the event taxonomy.
#[derive(Clone, Copy, Debug)]
pub struct EventKey {
    pub time_s: f64,
    pub sat_id: usize,
    pub kind: EventKind,
}

impl PartialEq for EventKey {
    fn eq(&self, other: &EventKey) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for EventKey {}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &EventKey) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventKey {
    fn cmp(&self, other: &EventKey) -> std::cmp::Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then(self.sat_id.cmp(&other.sat_id))
            .then((self.kind as u8).cmp(&(other.kind as u8)))
    }
}

/// What a machine's event handler tells the scheduler to do next.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MachineStep {
    /// Re-arm: the machine's next event fires at this time.
    Yield(f64, EventKind),
    /// Mission complete; the scheduler retires the machine.
    Done,
}

/// One satellite as a virtual-time state machine.  The machine owns all
/// per-satellite state and sequences its own mission: `start` arms the
/// first event, each `on_event` runs one handler and arms the next, and
/// `finish` consumes the machine into its report after `Done`.
///
/// Machines never cross threads (they are built and stepped on their
/// shard's worker), so they need not be `Send` — only the constructor
/// closure and the report do.
pub trait SatMachine: Sized {
    type Report;

    /// First event to arm: `(virtual_time, kind)`.
    fn start(&mut self) -> (f64, EventKind);

    /// Handle the event that just fired.
    fn on_event(&mut self, time_s: f64, kind: EventKind) -> Result<MachineStep>;

    /// Consume the machine into its report (called after `Done`).
    fn finish(self) -> Result<Self::Report>;
}

/// Virtual-time interval between heap-depth / live-machine samples
/// inside a shard loop.  Sampling on checkpoint crossings (rather than
/// every pop) keeps the scheduler's self-observation cost independent
/// of event density.
pub const CHECKPOINT_S: f64 = 600.0;

/// Bucket layout of the admission-wait histogram: first bound 1 ms,
/// doubling across 40 buckets (top bound ≈ 1.7e7 years of virtual
/// time).  Exported so fleet-level registries can allocate a
/// mergeable histogram with the identical layout.
pub const ADMISSION_WAIT_FIRST_BOUND_S: f64 = 1e-3;
/// See [`ADMISSION_WAIT_FIRST_BOUND_S`].
pub const ADMISSION_WAIT_BUCKETS: usize = 40;

/// Fixed-size summary of the admission-wait distribution, computed
/// from the merged per-shard histograms at the join barrier.  One
/// observation per admitted machine: how far virtual time had already
/// advanced past the machine's first event when the in-flight cap let
/// it in (0 for the initial fill).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WaitSummary {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

/// Fleet-run accounting: the bench's throughput and memory-proxy axes,
/// plus the scheduler's self-observation (per-shard event counts,
/// checkpoint-sampled heap depth, admission-wait distribution).
#[derive(Debug)]
pub struct FleetRunStats {
    /// Total mission events processed across all shards.
    pub events: u64,
    /// Sum of per-shard peak live machine counts — an upper bound on
    /// concurrently-materialized satellites (each live machine holds
    /// one in-flight event plus its scene buffers), the RSS proxy
    /// `max_events_in_flight` exists to bound.
    pub peak_live: usize,
    /// Events processed by each shard, indexed by shard id — the
    /// load-balance axis (`sat_id % shards` striping should keep these
    /// within a few percent of each other).
    pub events_per_shard: Vec<u64>,
    /// Deepest per-shard event heap observed at any [`CHECKPOINT_S`]
    /// crossing (including the just-popped event).  With one in-flight
    /// event per machine this is bounded by the admitted-live count.
    pub max_heap_depth: usize,
    /// Merged per-shard admission-wait histogram (layout
    /// [`ADMISSION_WAIT_FIRST_BOUND_S`] × [`ADMISSION_WAIT_BUCKETS`]).
    pub admission_wait_hist: Histogram,
}

impl Default for FleetRunStats {
    fn default() -> FleetRunStats {
        FleetRunStats {
            events: 0,
            peak_live: 0,
            events_per_shard: Vec::new(),
            max_heap_depth: 0,
            admission_wait_hist: Histogram::with_range(
                ADMISSION_WAIT_FIRST_BOUND_S,
                ADMISSION_WAIT_BUCKETS,
            ),
        }
    }
}

impl FleetRunStats {
    /// Summarize the admission-wait histogram (quantiles are log₂
    /// bucket upper bounds clamped to the observed max).
    pub fn admission_wait(&self) -> WaitSummary {
        let h = &self.admission_wait_hist;
        WaitSummary {
            count: h.count(),
            mean_s: h.mean_secs(),
            p50_s: h.quantile_secs(0.5),
            p99_s: h.quantile_secs(0.99),
            max_s: h.max_secs(),
        }
    }
}

/// Step `n_sats` machines to completion on `shards` scoped workers.
///
/// `make(sat_id)` constructs the machine — called lazily on the owning
/// shard's worker at admission time, so a capped fleet never
/// materializes more than `shards * max_in_flight` satellites at once.
/// `max_in_flight == 0` means unbounded.  Reports come back sorted by
/// `sat_id` regardless of shard count or completion order.
pub fn run_sharded<M, F>(
    n_sats: usize,
    shards: usize,
    max_in_flight: usize,
    make: F,
) -> Result<(Vec<M::Report>, FleetRunStats)>
where
    M: SatMachine,
    M::Report: Send,
    F: Fn(usize) -> Result<M> + Sync,
{
    let shards = shards.max(1).min(n_sats.max(1));
    let shard_results = pool::scoped_map(shards, (0..shards).collect(), |shard| {
        run_shard::<M, F>(n_sats, shards, shard, max_in_flight, &make)
    });
    let mut tagged: Vec<(usize, M::Report)> = Vec::with_capacity(n_sats);
    let mut stats = FleetRunStats::default();
    for r in shard_results {
        let shard = r?;
        tagged.extend(shard.reports);
        stats.events += shard.events;
        stats.events_per_shard.push(shard.events);
        stats.peak_live += shard.peak_live;
        stats.max_heap_depth = stats.max_heap_depth.max(shard.max_heap_depth);
        stats.admission_wait_hist.merge(&shard.wait_hist);
    }
    tagged.sort_by_key(|(id, _)| *id);
    Ok((tagged.into_iter().map(|(_, r)| r).collect(), stats))
}

/// What one shard loop hands back at the join barrier.
struct ShardRun<R> {
    reports: Vec<(usize, R)>,
    events: u64,
    peak_live: usize,
    max_heap_depth: usize,
    wait_hist: Histogram,
}

/// One shard's event loop: admit machines in `sat_id` order up to the
/// in-flight cap, then pop-step-rearm until heap and backlog drain.
fn run_shard<M, F>(
    n_sats: usize,
    shards: usize,
    shard: usize,
    max_in_flight: usize,
    make: &F,
) -> Result<ShardRun<M::Report>>
where
    M: SatMachine,
    F: Fn(usize) -> Result<M> + Sync,
{
    let cap = if max_in_flight == 0 { usize::MAX } else { max_in_flight };
    // this shard's satellites, ascending: shard, shard+shards, ...
    let mut backlog = (shard..n_sats).step_by(shards);
    let mut heap: BinaryHeap<Reverse<EventKey>> = BinaryHeap::new();
    let mut live: BTreeMap<usize, M> = BTreeMap::new();
    let mut reports: Vec<(usize, M::Report)> = Vec::new();
    let mut events = 0u64;
    let mut peak = 0usize;
    let mut max_heap_depth = 0usize;
    // Admission wait = how far virtual time already ran past a
    // machine's first event when the cap finally admitted it; the
    // initial fill happens before any event pops, so it observes 0.
    let mut retired_at = 0.0f64;
    let wait_hist = Histogram::with_range(ADMISSION_WAIT_FIRST_BOUND_S, ADMISSION_WAIT_BUCKETS);
    // First pop crosses checkpoint 0 so even sub-checkpoint missions
    // record one heap/live sample.
    let mut next_checkpoint = 0.0f64;
    loop {
        while live.len() < cap {
            let Some(sat_id) = backlog.next() else { break };
            let mut m = make(sat_id)?;
            let (time_s, kind) = m.start();
            wait_hist.observe_secs((retired_at - time_s).max(0.0));
            heap.push(Reverse(EventKey { time_s, sat_id, kind }));
            live.insert(sat_id, m);
            peak = peak.max(live.len());
        }
        let Some(Reverse(key)) = heap.pop() else { break };
        events += 1;
        if key.time_s >= next_checkpoint {
            // +1 counts the event in hand, popped but still in flight
            max_heap_depth = max_heap_depth.max(heap.len() + 1);
            while next_checkpoint <= key.time_s {
                next_checkpoint += CHECKPOINT_S;
            }
        }
        let machine = live.get_mut(&key.sat_id).expect("live machine for queued event");
        match machine.on_event(key.time_s, key.kind)? {
            MachineStep::Yield(time_s, kind) => {
                heap.push(Reverse(EventKey { time_s, sat_id: key.sat_id, kind }));
            }
            MachineStep::Done => {
                let machine = live.remove(&key.sat_id).expect("machine just stepped");
                reports.push((key.sat_id, machine.finish()?));
                retired_at = retired_at.max(key.time_s);
            }
        }
    }
    Ok(ShardRun { reports, events, peak_live: peak, max_heap_depth, wait_hist })
}

/// Artifact-free stub satellite: a [`SatMachine`] over a real
/// [`Timeline`] with a synthetic capture/backlog/drain workload (no
/// pixels, no inference runtime).  Deterministic in `(sat_id, seed)`
/// alone, so it drives the shard-invariance tests and
/// `benches/perf_fleet.rs` at 100k-sat scale.
pub struct StubSat {
    sat_id: usize,
    rng: u64,
    timeline: Timeline,
    scenes_left: usize,
    /// Queued downlink backlog, bytes; drained at `drain_bps` inside
    /// contact slices.
    backlog_bytes: u64,
    drain_bps: f64,
    report: StubReport,
    tail: std::collections::VecDeque<(f64, f64)>,
    /// Flight-recorder handle; `None` (the [`StubSat::new`] default)
    /// emits nothing and leaves every result untouched.
    trace: Option<SatTracer>,
}

/// What a stub mission leaves behind — enough structure to bit-compare
/// across shard counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StubReport {
    pub sat_id: usize,
    pub scenes: usize,
    pub tiles: u64,
    pub queued_bytes: u64,
    pub delivered_bytes: u64,
    pub final_t: f64,
    /// Order-sensitive checksum over the event sequence: any deviation
    /// in event order or arithmetic shows up here.
    pub checksum: u64,
}

impl StubSat {
    /// `horizon_s` of mission with `scenes` captures and periodic
    /// analytic contact windows (no orbital geometry scan — this is the
    /// 100k-sat bulk path [`Timeline::from_parts`] exists for).
    pub fn new(sat_id: usize, seed: u64, scenes: usize, horizon_s: f64) -> StubSat {
        let timing = TimingConfig::default();
        // windows phased per satellite: ~8 min pass every ~95 min
        let period = 5700.0;
        let pass = 480.0;
        let phase = (sat_id as f64 * 131.0) % (period - pass);
        let mut contacts = Vec::new();
        let mut aos = phase;
        while aos < horizon_s {
            contacts.push(ContactWindow {
                aos,
                los: (aos + pass).min(horizon_s),
                max_elevation_deg: 45.0,
                truncated: aos + pass > horizon_s,
                station_id: 0,
            });
            aos += period;
        }
        let sunlit: Vec<Span> = contacts
            .iter()
            .map(|w| Span { start: w.aos, end: w.los + 1200.0_f64.min(horizon_s - w.los) })
            .collect();
        let timeline = Timeline::from_parts(&timing, contacts, Some(sunlit), horizon_s);
        StubSat {
            sat_id,
            rng: seed ^ (sat_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            timeline,
            scenes_left: scenes,
            backlog_bytes: 0,
            drain_bps: 5_000_000.0,
            report: StubReport { sat_id, ..StubReport::default() },
            tail: std::collections::VecDeque::new(),
            trace: None,
        }
    }

    /// Attach a flight-recorder handle: captures become `Capture`
    /// events (batch = tiles) and every drain becomes a `DownlinkSlice`
    /// span (bytes = delivered).  Tracing never touches the report.
    pub fn with_trace(mut self, tracer: SatTracer) -> StubSat {
        self.trace = Some(tracer);
        self
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64*: one private stream per satellite
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn mix(&mut self, v: u64) {
        self.report.checksum = self.report.checksum.rotate_left(7) ^ v;
    }

    fn drain(&mut self, t0: f64, t1: f64) {
        let can = (self.drain_bps * (t1 - t0) / 8.0) as u64;
        let sent = can.min(self.backlog_bytes);
        self.backlog_bytes -= sent;
        self.report.delivered_bytes += sent;
        self.mix(sent);
        if let Some(tr) = &self.trace {
            tr.span(SpanKind::DownlinkSlice, t0, t1, TracePayload::Bytes(sent));
        }
    }

    fn enter_tail(&mut self) -> MachineStep {
        self.tail = self
            .timeline
            .remaining_contacts()
            .into_iter()
            .map(|s| (s.window.aos, s.window.los))
            .collect();
        match self.tail.front() {
            Some(&(aos, _)) => MachineStep::Yield(aos, EventKind::ContactSlice),
            None => MachineStep::Yield(self.timeline.horizon_s(), EventKind::MissionEnd),
        }
    }
}

impl SatMachine for StubSat {
    type Report = StubReport;

    fn start(&mut self) -> (f64, EventKind) {
        if self.scenes_left > 0 {
            (self.timeline.now_s(), EventKind::Capture)
        } else {
            (self.timeline.horizon_s(), EventKind::MissionEnd)
        }
    }

    fn on_event(&mut self, time_s: f64, kind: EventKind) -> Result<MachineStep> {
        match kind {
            EventKind::Capture => {
                let tiles = 8 + (self.next_u64() % 57) as usize; // 8..=64
                let (_, period) = scene_timing(self.timeline.timing(), tiles);
                let bytes = tiles as u64 * 49_152;
                self.backlog_bytes += bytes;
                self.report.scenes += 1;
                self.report.tiles += tiles as u64;
                self.report.queued_bytes += bytes;
                self.mix(tiles as u64);
                if let Some(tr) = &self.trace {
                    tr.event(SpanKind::Capture, time_s, TracePayload::Batch(tiles));
                }
                let t = self.timeline.advance(period);
                for slice in self.timeline.due_contacts(t) {
                    self.drain(slice.window.aos, slice.window.los);
                }
                self.scenes_left -= 1;
                if self.scenes_left > 0 {
                    Ok(MachineStep::Yield(self.timeline.now_s(), EventKind::Capture))
                } else {
                    Ok(self.enter_tail())
                }
            }
            EventKind::ContactSlice => {
                let (aos, los) = self.tail.pop_front().expect("slice event without a slice");
                self.drain(aos, los);
                match self.tail.front() {
                    Some(&(next_aos, _)) => {
                        Ok(MachineStep::Yield(next_aos, EventKind::ContactSlice))
                    }
                    None => {
                        Ok(MachineStep::Yield(self.timeline.horizon_s(), EventKind::MissionEnd))
                    }
                }
            }
            EventKind::RoundBoundary => {
                // the stub schedules no federated rounds; a spurious
                // round event would corrupt the checksum, loudly
                self.mix(u64::MAX);
                Ok(MachineStep::Yield(self.timeline.horizon_s(), EventKind::MissionEnd))
            }
            EventKind::MissionEnd => {
                self.report.final_t = self.timeline.horizon_s();
                self.mix(self.report.delivered_bytes);
                Ok(MachineStep::Done)
            }
        }
    }

    fn finish(self) -> Result<StubReport> {
        Ok(self.report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_keys_order_by_time_then_sat_then_kind() {
        let k = |t: f64, sat: usize, kind: EventKind| EventKey { time_s: t, sat_id: sat, kind };
        // time dominates
        assert!(k(1.0, 9, EventKind::MissionEnd) < k(2.0, 0, EventKind::Capture));
        // equal time: sat_id breaks the tie
        assert!(k(5.0, 0, EventKind::RoundBoundary) < k(5.0, 1, EventKind::Capture));
        // equal time and sat: documented taxonomy order
        assert!(k(5.0, 3, EventKind::Capture) < k(5.0, 3, EventKind::ContactSlice));
        assert!(k(5.0, 3, EventKind::ContactSlice) < k(5.0, 3, EventKind::RoundBoundary));
        assert!(k(5.0, 3, EventKind::RoundBoundary) < k(5.0, 3, EventKind::MissionEnd));
    }

    #[test]
    fn same_timestamp_events_pop_in_documented_order() {
        // capture coinciding with a LOS-slice and a round boundary
        // coinciding with an AOS, all at t = 300 across two satellites:
        // the pop order must be (time, sat_id, kind) ascending.
        let mut heap: BinaryHeap<Reverse<EventKey>> = BinaryHeap::new();
        let push = |h: &mut BinaryHeap<Reverse<EventKey>>, t: f64, sat: usize, kind| {
            h.push(Reverse(EventKey { time_s: t, sat_id: sat, kind }))
        };
        push(&mut heap, 300.0, 1, EventKind::RoundBoundary); // round @ sat 1's AOS
        push(&mut heap, 300.0, 0, EventKind::ContactSlice); // sat 0's LOS slice
        push(&mut heap, 300.0, 0, EventKind::Capture); // capture @ sat 0's LOS
        push(&mut heap, 120.0, 1, EventKind::Capture);
        push(&mut heap, 300.0, 1, EventKind::ContactSlice);
        let mut popped = Vec::new();
        while let Some(Reverse(k)) = heap.pop() {
            popped.push((k.time_s, k.sat_id, k.kind));
        }
        assert_eq!(
            popped,
            vec![
                (120.0, 1, EventKind::Capture),
                (300.0, 0, EventKind::Capture),
                (300.0, 0, EventKind::ContactSlice),
                (300.0, 1, EventKind::ContactSlice),
                (300.0, 1, EventKind::RoundBoundary),
            ]
        );
    }

    fn stub_fleet(n: usize, shards: usize, cap: usize) -> (Vec<StubReport>, FleetRunStats) {
        run_sharded(n, shards, cap, |id| Ok(StubSat::new(id, 42, 6, 21_600.0))).unwrap()
    }

    #[test]
    fn stub_fleet_reports_ordered_and_complete() {
        let (reports, stats) = stub_fleet(17, 4, 0);
        assert_eq!(reports.len(), 17);
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.sat_id, i, "reports sorted by sat_id");
            assert_eq!(r.scenes, 6);
            assert!(r.tiles >= 6 * 8);
            assert!(r.delivered_bytes <= r.queued_bytes);
        }
        // every machine fires at least capture×6 + mission-end
        assert!(stats.events >= 17 * 7, "events {}", stats.events);
        assert!(stats.peak_live >= 1);
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let (one, _) = stub_fleet(23, 1, 0);
        for shards in [2, 3, 8, 23, 64] {
            let (many, _) = stub_fleet(23, shards, 0);
            assert_eq!(one, many, "shards={shards}");
        }
    }

    #[test]
    fn in_flight_cap_bounds_live_machines_without_changing_results() {
        let (uncapped, ustats) = stub_fleet(32, 2, 0);
        let (capped, cstats) = stub_fleet(32, 2, 3);
        assert_eq!(uncapped, capped, "lazy admission must not change any report");
        assert!(cstats.peak_live <= 2 * 3, "peak {} over cap", cstats.peak_live);
        assert!(ustats.peak_live >= cstats.peak_live);
        assert_eq!(ustats.events, cstats.events, "same missions, same event count");
    }

    #[test]
    fn scheduler_self_stats_account_for_the_run() {
        let (_, stats) = stub_fleet(17, 4, 0);
        assert_eq!(stats.events_per_shard.len(), 4);
        assert_eq!(stats.events_per_shard.iter().sum::<u64>(), stats.events);
        assert!(stats.max_heap_depth >= 1);
        assert!(stats.max_heap_depth <= 17);
        // uncapped: every machine admits during the initial fill, so
        // all waits observe as exactly zero
        let w = stats.admission_wait();
        assert_eq!(w.count, 17, "one observation per admitted machine");
        assert_eq!(w.max_s, 0.0);
    }

    #[test]
    fn capped_admission_records_virtual_time_waits() {
        // cap 1: each shard retires a whole mission (at the 21.6 ks
        // horizon) before admitting its next satellite, so late
        // admissions wait essentially the whole mission
        let (_, stats) = stub_fleet(8, 2, 1);
        let w = stats.admission_wait();
        assert_eq!(w.count, 8);
        assert!(w.max_s > 20_000.0, "max wait {}", w.max_s);
        assert!(w.p99_s >= w.p50_s);
        assert!(stats.max_heap_depth <= 1, "cap 1 means one in-flight event");
    }

    #[test]
    fn stub_trace_is_optional_and_result_neutral() {
        use crate::telemetry::trace::TraceSink;
        use std::sync::Arc;
        let (plain, _) = stub_fleet(6, 2, 0);
        let sink = Arc::new(TraceSink::new(2, 4096));
        let (traced, _) = run_sharded(6, 2, 0, |id| {
            Ok(StubSat::new(id, 42, 6, 21_600.0).with_trace(sink.tracer(id % 2, id)))
        })
        .unwrap();
        assert_eq!(plain, traced, "tracing must not perturb any report");
        let log = sink.merge();
        assert_eq!(log.evicted(), 0);
        let counts = log.kind_counts();
        let captures = counts.iter().find(|(k, _)| *k == SpanKind::Capture).unwrap().1;
        assert_eq!(captures, 6 * 6, "one capture event per scene");
        assert!(counts.iter().any(|(k, n)| *k == SpanKind::DownlinkSlice && *n > 0));
    }

    #[test]
    fn zero_scene_machines_still_retire() {
        let (reports, stats) =
            run_sharded(3, 2, 0, |id| Ok(StubSat::new(id, 7, 0, 1000.0))).unwrap();
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.scenes == 0 && r.final_t == 1000.0));
        assert_eq!(stats.events, 3, "one MissionEnd each");
    }

    #[test]
    fn constructor_error_propagates() {
        let r = run_sharded::<StubSat, _>(4, 2, 0, |id| {
            if id == 2 {
                anyhow::bail!("boom at {id}")
            }
            Ok(StubSat::new(id, 1, 1, 1000.0))
        });
        assert!(r.is_err());
    }
}
