//! Config system: JSON files under `configs/` describing satellite
//! platforms (Table 1), ground segment, link, policy, and workload.
//!
//! Everything an experiment varies is a config field, so benches and
//! examples share one loader and the CLI can override single keys.

use std::path::Path;

use anyhow::{Context, Result};

use crate::link::LossProfile;
use crate::util::json::Json;

/// Satellite platform (Table 1 row).
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub name: String,
    pub launch: String,
    pub orbital_altitude_km: f64,
    pub mass_kg: f64,
    pub load_size_u: f64,
    pub size_u: f64,
    pub operating_system: String,
    pub uplink_mbps: (f64, f64),
    pub downlink_mbps: f64,
}

/// Collaborative-inference policy (§IV workflow).
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Detection score below which a tile is offloaded to the ground.
    pub confidence_threshold: f32,
    /// Best raw objectness below which an empty tile is confidently empty
    /// (router keeps it onboard instead of offloading).
    pub empty_objectness: f32,
    /// Cloud white-fraction above which a tile is dropped as redundant.
    pub redundancy_threshold: f32,
    /// NMS IoU threshold.
    pub nms_iou: f32,
    /// Detection score threshold applied before NMS.
    pub score_threshold: f32,
    /// Onboard batch target (matches an exported artifact batch size).
    pub batch_size: usize,
    /// Link-aware adaptive routing: consult downlink backlog + recent
    /// loss rate to tighten/relax the offload threshold (the weak-network
    /// and MakerSat-incident regimes).  Off by default — the static
    /// threshold reproduces the paper's policy bit-for-bit.
    pub adaptive: bool,
    /// Queued downlink bytes above which the router tightens (offloads
    /// less).  Default ≈ one second of the Table-1 40 Mbps downlink.
    pub adaptive_backlog_bytes: u64,
    /// Recent link loss rate above which the router tightens.
    pub adaptive_loss_rate: f64,
    /// How far the confidence threshold drops when the link is stressed.
    pub adaptive_tighten: f32,
    /// How far it rises when the link is clearly idle (offload more,
    /// harvesting collaborative accuracy while the window is cheap).
    pub adaptive_relax: f32,
}

impl Default for PolicyConfig {
    fn default() -> PolicyConfig {
        PolicyConfig {
            confidence_threshold: 0.90,
            empty_objectness: 0.25,
            redundancy_threshold: 0.5,
            nms_iou: 0.45,
            score_threshold: 0.20,
            batch_size: 8,
            adaptive: false,
            adaptive_backlog_bytes: 5_000_000,
            adaptive_loss_rate: 0.2,
            adaptive_tighten: 0.2,
            adaptive_relax: 0.05,
        }
    }
}

/// Staged-engine execution knobs ([`crate::coordinator::engine`]).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Stage worker threads; 1 degenerates to the sequential facade.
    pub workers: usize,
    /// Bounded depth of each inter-stage queue (backpressure).
    pub channel_depth: usize,
    /// Batcher deadline (virtual seconds) before a partial batch is
    /// forced out.  Note: the current per-scene flow enqueues a whole
    /// scene at virtual time 0 and drains with flush — which is what
    /// keeps results bit-identical to the sequential facade — so this
    /// deadline only bites once tiles stream into the batcher
    /// asynchronously (streaming capture is future work).
    pub batch_max_wait_s: f64,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { workers: 2, channel_depth: 4, batch_max_wait_s: 5.0 }
    }
}

/// Scenario virtual-time constants (previously hardcoded in
/// `Pipeline::run_scenario`), consumed through [`crate::sim::Timeline`].
#[derive(Clone, Debug)]
pub struct TimingConfig {
    /// At most one scene capture per this many seconds.
    pub scene_period_floor_s: f64,
    /// Per-scene capture + filtering overhead folded into busy time.
    pub capture_overhead_s: f64,
    /// Comm duty assumed by the degenerate always-in-contact timeline
    /// (single-satellite paths; was hardcoded in the scenario fold).
    /// Orbital timelines ignore this and derive comm duty from actual
    /// link airtime inside contact windows.
    pub nominal_comm_duty: f64,
    /// Camera duty assumed by the degenerate timeline; orbital timelines
    /// derive it from capture events instead.
    pub nominal_camera_duty: f64,
}

impl Default for TimingConfig {
    fn default() -> TimingConfig {
        TimingConfig {
            scene_period_floor_s: 30.0,
            capture_overhead_s: 2.0,
            nominal_comm_duty: 0.05,
            nominal_camera_duty: 0.1,
        }
    }
}

/// Constellation runner ([`crate::coordinator::constellation`]).
#[derive(Clone, Debug)]
pub struct ConstellationConfig {
    /// Satellites sharing one ground segment.
    pub satellites: usize,
    /// Scenes each satellite captures.
    pub scenes_per_satellite: usize,
    /// Mission horizon for contact-window computation, seconds.
    pub horizon_s: f64,
    /// RAAN spacing between satellite planes, radians.
    pub raan_step_rad: f64,
    /// Replace each satellite's orbital timeline with the degenerate
    /// always-in-contact one (ground reachable whenever data is ready).
    /// With a lossless link this makes a 1-satellite constellation
    /// reproduce `run_scenario` exactly (`tests/constellation_parity.rs`).
    pub ideal_contact: bool,
}

impl Default for ConstellationConfig {
    fn default() -> ConstellationConfig {
        ConstellationConfig {
            satellites: 3,
            scenes_per_satellite: 4,
            horizon_s: 21_600.0, // 6 h: a few Beijing passes per satellite
            raan_step_rad: 0.35,
            ideal_contact: false,
        }
    }
}

/// Full experiment config.
#[derive(Clone, Debug)]
pub struct Config {
    pub platform: PlatformConfig,
    pub policy: PolicyConfig,
    pub engine: EngineConfig,
    pub timing: TimingConfig,
    pub constellation: ConstellationConfig,
    /// Scene size in 64-px cells.
    pub scene_cells: usize,
    /// Fragment edge length in px for the splitter.
    pub fragment_px: usize,
    pub loss_profile: String,
    pub seed: u64,
}

impl Config {
    pub fn loss(&self) -> LossProfile {
        match self.loss_profile.as_str() {
            "weak" => LossProfile::weak(),
            "makersat" => LossProfile::makersat_incident(),
            "lossless" => LossProfile::lossless(),
            _ => LossProfile::stable(),
        }
    }
}

impl Default for Config {
    fn default() -> Config {
        Config {
            platform: baoyun_platform(),
            policy: PolicyConfig::default(),
            engine: EngineConfig::default(),
            timing: TimingConfig::default(),
            constellation: ConstellationConfig::default(),
            scene_cells: 8,
            fragment_px: 64,
            loss_profile: "stable".into(),
            seed: 20231207, // Baoyun launch date
        }
    }
}

/// Table 1, Baoyun row.
pub fn baoyun_platform() -> PlatformConfig {
    PlatformConfig {
        name: "Baoyun".into(),
        launch: "2021-12-07".into(),
        orbital_altitude_km: 500.0,
        mass_kg: 20.0,
        load_size_u: 0.25,
        size_u: 12.0,
        operating_system: "Ubuntu Server 20.04 arm".into(),
        uplink_mbps: (0.1, 1.0),
        downlink_mbps: 40.0,
    }
}

/// Table 1, Chuangxingleishen row.
pub fn chuangxingleishen_platform() -> PlatformConfig {
    PlatformConfig {
        name: "Chuangxingleishen".into(),
        launch: "2022-02-27".into(),
        orbital_altitude_km: 500.0,
        mass_kg: 20.0,
        load_size_u: 0.25,
        size_u: 6.0,
        operating_system: "Debian Buster with Raspberry Pi".into(),
        uplink_mbps: (0.1, 1.0),
        downlink_mbps: 40.0,
    }
}

fn platform_from_json(j: &Json) -> Result<PlatformConfig> {
    let s = |k: &str| -> Result<String> {
        Ok(j.req(k)?.as_str().context(k.to_string())?.to_string())
    };
    let n = |k: &str| -> Result<f64> { j.req(k)?.as_f64().context(k.to_string()) };
    let up = j.req("uplink_mbps")?.as_arr().context("uplink_mbps")?;
    Ok(PlatformConfig {
        name: s("name")?,
        launch: s("launch")?,
        orbital_altitude_km: n("orbital_altitude_km")?,
        mass_kg: n("mass_kg")?,
        load_size_u: n("load_size_u")?,
        size_u: n("size_u")?,
        operating_system: s("operating_system")?,
        uplink_mbps: (
            up[0].as_f64().context("uplink lo")?,
            up[1].as_f64().context("uplink hi")?,
        ),
        downlink_mbps: n("downlink_mbps")?,
    })
}

impl Config {
    /// Load from a JSON file; missing sections fall back to defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let j = Json::parse(text).context("config json")?;
        let mut cfg = Config::default();
        if let Some(p) = j.get("platform") {
            cfg.platform = platform_from_json(p)?;
        }
        if let Some(p) = j.get("policy") {
            let f = |k: &str, d: f32| p.get(k).and_then(|v| v.as_f64()).map(|x| x as f32).unwrap_or(d);
            cfg.policy = PolicyConfig {
                confidence_threshold: f("confidence_threshold", cfg.policy.confidence_threshold),
                empty_objectness: f("empty_objectness", cfg.policy.empty_objectness),
                redundancy_threshold: f("redundancy_threshold", cfg.policy.redundancy_threshold),
                nms_iou: f("nms_iou", cfg.policy.nms_iou),
                score_threshold: f("score_threshold", cfg.policy.score_threshold),
                batch_size: p
                    .get("batch_size")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.policy.batch_size),
                adaptive: p
                    .get("adaptive")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(cfg.policy.adaptive),
                adaptive_backlog_bytes: p
                    .get("adaptive_backlog_bytes")
                    .and_then(|v| v.as_f64())
                    .map(|x| x as u64)
                    .unwrap_or(cfg.policy.adaptive_backlog_bytes),
                adaptive_loss_rate: p
                    .get("adaptive_loss_rate")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.policy.adaptive_loss_rate),
                adaptive_tighten: f("adaptive_tighten", cfg.policy.adaptive_tighten),
                adaptive_relax: f("adaptive_relax", cfg.policy.adaptive_relax),
            };
        }
        if let Some(e) = j.get("engine") {
            cfg.engine = EngineConfig {
                workers: e.get("workers").and_then(|v| v.as_usize()).unwrap_or(cfg.engine.workers),
                channel_depth: e
                    .get("channel_depth")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.engine.channel_depth),
                batch_max_wait_s: e
                    .get("batch_max_wait_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.engine.batch_max_wait_s),
            };
        }
        if let Some(t) = j.get("timing") {
            cfg.timing = TimingConfig {
                scene_period_floor_s: t
                    .get("scene_period_floor_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.scene_period_floor_s),
                capture_overhead_s: t
                    .get("capture_overhead_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.capture_overhead_s),
                nominal_comm_duty: t
                    .get("nominal_comm_duty")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.nominal_comm_duty),
                nominal_camera_duty: t
                    .get("nominal_camera_duty")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.nominal_camera_duty),
            };
        }
        if let Some(c) = j.get("constellation") {
            cfg.constellation = ConstellationConfig {
                satellites: c
                    .get("satellites")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.constellation.satellites),
                scenes_per_satellite: c
                    .get("scenes_per_satellite")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.constellation.scenes_per_satellite),
                horizon_s: c
                    .get("horizon_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.constellation.horizon_s),
                raan_step_rad: c
                    .get("raan_step_rad")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.constellation.raan_step_rad),
                ideal_contact: c
                    .get("ideal_contact")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(cfg.constellation.ideal_contact),
            };
        }
        if let Some(v) = j.get("scene_cells").and_then(|v| v.as_usize()) {
            cfg.scene_cells = v;
        }
        if let Some(v) = j.get("fragment_px").and_then(|v| v.as_usize()) {
            cfg.fragment_px = v;
        }
        if let Some(v) = j.get("loss_profile").and_then(|v| v.as_str()) {
            cfg.loss_profile = v.to_string();
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_baoyun() {
        let c = Config::default();
        assert_eq!(c.platform.name, "Baoyun");
        assert_eq!(c.platform.downlink_mbps, 40.0);
    }

    #[test]
    fn parse_overrides() {
        let c = Config::parse(
            r#"{"policy": {"confidence_threshold": 0.6, "batch_size": 1},
                "fragment_px": 32, "loss_profile": "weak", "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(c.policy.confidence_threshold, 0.6);
        assert_eq!(c.policy.batch_size, 1);
        assert_eq!(c.fragment_px, 32);
        assert_eq!(c.seed, 7);
        assert!((c.loss().loss_bad - LossProfile::weak().loss_bad).abs() < 1e-12);
    }

    #[test]
    fn parse_engine_timing_constellation_sections() {
        let c = Config::parse(
            r#"{"policy": {"empty_objectness": 0.3},
                "engine": {"workers": 4, "channel_depth": 8, "batch_max_wait_s": 2.5},
                "timing": {"scene_period_floor_s": 45, "capture_overhead_s": 1.5},
                "constellation": {"satellites": 5, "scenes_per_satellite": 2,
                                  "horizon_s": 7200, "raan_step_rad": 0.5}}"#,
        )
        .unwrap();
        assert_eq!(c.policy.empty_objectness, 0.3);
        assert_eq!(c.engine.workers, 4);
        assert_eq!(c.engine.channel_depth, 8);
        assert_eq!(c.engine.batch_max_wait_s, 2.5);
        assert_eq!(c.timing.scene_period_floor_s, 45.0);
        assert_eq!(c.timing.capture_overhead_s, 1.5);
        assert_eq!(c.constellation.satellites, 5);
        assert_eq!(c.constellation.scenes_per_satellite, 2);
        assert_eq!(c.constellation.horizon_s, 7200.0);
        assert_eq!(c.constellation.raan_step_rad, 0.5);
    }

    #[test]
    fn defaults_preserve_legacy_constants() {
        // The staged-engine and sim refactors promoted these from
        // hardcoded values; defaults must keep the pre-refactor
        // behaviour bit-for-bit.
        let c = Config::default();
        assert_eq!(c.policy.empty_objectness, 0.25);
        assert_eq!(c.timing.scene_period_floor_s, 30.0);
        assert_eq!(c.timing.capture_overhead_s, 2.0);
        assert_eq!(c.timing.nominal_comm_duty, 0.05);
        assert_eq!(c.timing.nominal_camera_duty, 0.1);
        assert!(!c.policy.adaptive, "adaptive routing must default off");
        assert!(!c.constellation.ideal_contact);
    }

    #[test]
    fn parse_sim_and_adaptive_sections() {
        let c = Config::parse(
            r#"{"policy": {"adaptive": true, "adaptive_backlog_bytes": 1000000,
                           "adaptive_loss_rate": 0.1, "adaptive_tighten": 0.3,
                           "adaptive_relax": 0.02},
                "timing": {"nominal_comm_duty": 0.08, "nominal_camera_duty": 0.2},
                "constellation": {"ideal_contact": true},
                "loss_profile": "lossless"}"#,
        )
        .unwrap();
        assert!(c.policy.adaptive);
        assert_eq!(c.policy.adaptive_backlog_bytes, 1_000_000);
        assert_eq!(c.policy.adaptive_loss_rate, 0.1);
        assert_eq!(c.policy.adaptive_tighten, 0.3);
        assert_eq!(c.policy.adaptive_relax, 0.02);
        assert_eq!(c.timing.nominal_comm_duty, 0.08);
        assert_eq!(c.timing.nominal_camera_duty, 0.2);
        assert!(c.constellation.ideal_contact);
        assert_eq!(c.loss().stationary_loss(), 0.0);
    }

    #[test]
    fn parse_full_platform() {
        let c = Config::parse(
            r#"{"platform": {"name": "X", "launch": "2022-01-01",
                 "orbital_altitude_km": 550, "mass_kg": 10, "load_size_u": 0.5,
                 "size_u": 6, "operating_system": "linux",
                 "uplink_mbps": [0.1, 1.0], "downlink_mbps": 80}}"#,
        )
        .unwrap();
        assert_eq!(c.platform.name, "X");
        assert_eq!(c.platform.downlink_mbps, 80.0);
    }

    #[test]
    fn repo_config_files_parse() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        for f in ["baoyun.json", "chuangxingleishen.json"] {
            let p = std::path::Path::new(dir).join(f);
            if p.exists() {
                let c = Config::load(&p).unwrap_or_else(|e| panic!("{f}: {e}"));
                assert_eq!(c.platform.downlink_mbps, 40.0);
            }
        }
    }

    #[test]
    fn cxls_differs_from_baoyun_in_size() {
        assert_eq!(baoyun_platform().size_u, 12.0);
        assert_eq!(chuangxingleishen_platform().size_u, 6.0);
    }
}
