//! Config system: JSON files under `configs/` describing satellite
//! platforms (Table 1), ground segment, link, policy, and workload.
//!
//! Everything an experiment varies is a config field, so benches and
//! examples share one loader and the CLI can override single keys.

use std::path::Path;

use anyhow::{Context, Result};

use crate::link::LossProfile;
use crate::util::json::Json;

/// Satellite platform (Table 1 row).
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub name: String,
    pub launch: String,
    pub orbital_altitude_km: f64,
    pub mass_kg: f64,
    pub load_size_u: f64,
    pub size_u: f64,
    pub operating_system: String,
    pub uplink_mbps: (f64, f64),
    pub downlink_mbps: f64,
}

/// Collaborative-inference policy (§IV workflow).
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Detection score below which a tile is offloaded to the ground.
    pub confidence_threshold: f32,
    /// Best raw objectness below which an empty tile is confidently empty
    /// (router keeps it onboard instead of offloading).
    pub empty_objectness: f32,
    /// Cloud white-fraction above which a tile is dropped as redundant.
    pub redundancy_threshold: f32,
    /// NMS IoU threshold.
    pub nms_iou: f32,
    /// Detection score threshold applied before NMS.
    pub score_threshold: f32,
    /// Onboard batch target (matches an exported artifact batch size).
    pub batch_size: usize,
    /// Link-aware adaptive routing: consult downlink backlog + recent
    /// loss rate to tighten/relax the offload threshold (the weak-network
    /// and MakerSat-incident regimes).  Off by default — the static
    /// threshold reproduces the paper's policy bit-for-bit.
    pub adaptive: bool,
    /// Queued downlink bytes above which the router tightens (offloads
    /// less).  Default ≈ one second of the Table-1 40 Mbps downlink.
    pub adaptive_backlog_bytes: u64,
    /// Recent link loss rate above which the router tightens.
    pub adaptive_loss_rate: f64,
    /// How far the confidence threshold drops when the link is stressed.
    pub adaptive_tighten: f32,
    /// How far it rises when the link is clearly idle (offload more,
    /// harvesting collaborative accuracy while the window is cheap).
    pub adaptive_relax: f32,
    /// Cloud-filter numeric path: `"f32"` (default — runs the CloudScore
    /// artifact, every result bit-identical to the pre-quantization
    /// pipeline) or `"i8"` (CPU fixed-point white counts; keep/drop
    /// decisions can differ from f32 only for tiles whose pixels
    /// straddle the white threshold within one quantization step — see
    /// [`crate::coordinator::cloudfilter`]).
    pub filter_precision: String,
}

impl PolicyConfig {
    /// An unknown precision string would silently fall back deep inside
    /// the pipeline; fail at the surface instead, like the other
    /// sections' validators.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.filter_precision.as_str(), "f32" | "i8"),
            "policy.filter_precision must be \"f32\" or \"i8\", got {:?}",
            self.filter_precision
        );
        Ok(())
    }
}

impl Default for PolicyConfig {
    fn default() -> PolicyConfig {
        PolicyConfig {
            confidence_threshold: 0.90,
            empty_objectness: 0.25,
            redundancy_threshold: 0.5,
            nms_iou: 0.45,
            score_threshold: 0.20,
            batch_size: 8,
            adaptive: false,
            adaptive_backlog_bytes: 5_000_000,
            adaptive_loss_rate: 0.2,
            adaptive_tighten: 0.2,
            adaptive_relax: 0.05,
            filter_precision: "f32".into(),
        }
    }
}

/// Staged-engine execution knobs ([`crate::coordinator::engine`]).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Stage worker threads; 1 degenerates to the sequential facade.
    pub workers: usize,
    /// Bounded depth of each inter-stage queue (backpressure).
    pub channel_depth: usize,
    /// Batcher deadline (virtual seconds) before a partial batch is
    /// forced out.  Note: the current per-scene flow enqueues a whole
    /// scene at virtual time 0 and drains with flush — which is what
    /// keeps results bit-identical to the sequential facade — so this
    /// deadline only bites once tiles stream into the batcher
    /// asynchronously (streaming capture is future work).
    pub batch_max_wait_s: f64,
    /// Tile-pool free-list cap ([`crate::util::buffer::Pool::with_cap`]):
    /// parked tile buffers beyond this are freed instead of kept, so
    /// large fleets bound their idle-buffer footprint.  0 (default) is
    /// unbounded — the allocation-pinning behaviour every existing
    /// result was measured under.
    pub tile_pool_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { workers: 2, channel_depth: 4, batch_max_wait_s: 5.0, tile_pool_cap: 0 }
    }
}

/// Idle duty floors for the [`crate::energy::EnergyMeter`] (previously
/// hardcoded in `EnergyMeter::advance`).  Power scenarios model low-idle
/// hardware by lowering these; the defaults reproduce the pre-config
/// integration exactly.
#[derive(Clone, Copy, Debug)]
pub struct EnergyConfig {
    /// Raspberry Pi idle draw as a fraction of active draw (was 0.25).
    pub pi_idle_floor: f64,
    /// Comm subsystem idle draw as a fraction of nameplate (was 0.15).
    pub comm_idle_floor: f64,
}

impl EnergyConfig {
    /// Out-of-range floors would be silently clamped deep inside the
    /// meter; fail at the surface instead, like [`PowerConfig::validate`].
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.pi_idle_floor),
            "energy.pi_idle_floor must be in [0, 1], got {}",
            self.pi_idle_floor
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.comm_idle_floor),
            "energy.comm_idle_floor must be in [0, 1], got {}",
            self.comm_idle_floor
        );
        Ok(())
    }
}

impl Default for EnergyConfig {
    fn default() -> EnergyConfig {
        EnergyConfig { pi_idle_floor: 0.25, comm_idle_floor: 0.15 }
    }
}

/// Power subsystem ([`crate::power`]): solar array, battery, and the
/// energy-aware mission governor.  Disabled by default — every existing
/// result stays bit-identical until a scenario opts in.
#[derive(Clone, Copy, Debug)]
pub struct PowerConfig {
    /// Master switch: off ⇒ no [`crate::power::PowerState`] exists and
    /// the constellation driver never consults a governor.
    pub enabled: bool,
    /// Battery capacity, Wh (12U-microsat class).
    pub battery_wh: f64,
    /// Solar array output at normal incidence, W.
    pub panel_w: f64,
    /// Mean cosine/beta-angle derate applied to `panel_w` while sunlit.
    pub cosine_derate: f64,
    /// Battery charge efficiency (fraction of surplus Wh stored).
    pub charge_eff: f64,
    /// Battery discharge efficiency (Wh drawn per Wh delivered is 1/η).
    pub discharge_eff: f64,
    /// Initial state of charge as a fraction of capacity.
    pub initial_soc: f64,
    /// SoC fraction below which the governor defers downlink drains and
    /// tightens the router threshold.
    pub soc_defer: f64,
    /// SoC fraction below which captures are shed entirely.
    pub soc_critical: f64,
    /// How far the router confidence threshold drops while deferring
    /// (composes with the adaptive path's `RouterPolicy::effective`).
    pub defer_tighten: f32,
    /// Linear battery capacity fade per full-capacity cycle equivalent:
    /// effective capacity is `battery_wh * (1 - fade_per_cycle *
    /// cycle_equivalents)` ([`crate::power::Battery`]).  0.0 (default)
    /// disables fade and keeps every existing result bit-identical.
    pub fade_per_cycle: f64,
}

impl PowerConfig {
    /// Hard invariants, checked at parse time and again at the top of
    /// `run_constellation` — a degenerate battery must fail loudly at
    /// the surface, not as an assert deep inside a satellite thread.
    /// (`soc_critical >= soc_defer` is *not* an error: it is a
    /// shed-only governor with an empty defer band.)
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(
            self.battery_wh > 0.0 && self.battery_wh.is_finite(),
            "power.battery_wh must be positive, got {}",
            self.battery_wh
        );
        anyhow::ensure!(
            self.panel_w >= 0.0 && self.panel_w.is_finite(),
            "power.panel_w must be non-negative, got {}",
            self.panel_w
        );
        anyhow::ensure!(
            self.charge_eff > 0.0 && self.charge_eff <= 1.0,
            "power.charge_eff must be in (0, 1], got {}",
            self.charge_eff
        );
        anyhow::ensure!(
            self.discharge_eff > 0.0 && self.discharge_eff <= 1.0,
            "power.discharge_eff must be in (0, 1], got {}",
            self.discharge_eff
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.cosine_derate),
            "power.cosine_derate must be in [0, 1], got {}",
            self.cosine_derate
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.initial_soc),
            "power.initial_soc must be in [0, 1], got {}",
            self.initial_soc
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.soc_defer) && (0.0..=1.0).contains(&self.soc_critical),
            "power.soc_defer / soc_critical must be in [0, 1], got {} / {}",
            self.soc_defer,
            self.soc_critical
        );
        anyhow::ensure!(
            self.defer_tighten >= 0.0 && self.defer_tighten.is_finite(),
            "power.defer_tighten must be non-negative, got {}",
            self.defer_tighten
        );
        // fade > 1 would let effective capacity shrink faster than the
        // discharge that caused it, breaking the SoC <= capacity invariant
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.fade_per_cycle),
            "power.fade_per_cycle must be in [0, 1], got {}",
            self.fade_per_cycle
        );
        Ok(())
    }
}

impl Default for PowerConfig {
    fn default() -> PowerConfig {
        PowerConfig {
            enabled: false,
            battery_wh: 80.0,
            panel_w: 110.0,
            cosine_derate: 0.65,
            charge_eff: 0.95,
            discharge_eff: 0.95,
            initial_soc: 1.0,
            soc_defer: 0.4,
            soc_critical: 0.2,
            defer_tighten: 0.2,
            fade_per_cycle: 0.0,
        }
    }
}

/// Fleet engine ([`crate::sim::fleet`]): the sharded virtual-time event
/// scheduler that steps 10k–100k satellite state machines on a bounded
/// worker pool instead of a thread per satellite.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Event-scheduler shards (= worker threads).  Satellites are
    /// assigned by `sat_id % shards`; results are invariant under this
    /// knob (`tests/fleet_determinism.rs`), so it is purely a
    /// parallelism/throughput dial.
    pub shards: usize,
    /// Cap on concurrently-live satellite machines per shard; pending
    /// satellites are admitted lazily in `sat_id` order as earlier ones
    /// retire, bounding the shard's event-heap and scene-buffer
    /// footprint.  0 = unbounded.  Results are unchanged — satellites
    /// are independent between barriers.
    pub max_events_in_flight: usize,
}

impl FleetConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shards >= 1, "fleet.shards must be at least 1");
        Ok(())
    }
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig { shards: 4, max_events_in_flight: 64 }
    }
}

/// Power-aware federated learning ([`crate::sedna::federated`]): local
/// training rounds scheduled in mission time, gated on battery SoC, with
/// weights contending for downlink airtime.  Disabled by default — every
/// existing result stays bit-identical until a scenario opts in.
#[derive(Clone, Copy, Debug)]
pub struct FederatedConfig {
    /// Master switch: off ⇒ no scheduler exists and the constellation
    /// driver never fires a round.
    pub enabled: bool,
    /// Virtual seconds between training rounds (round r is due at
    /// `round_interval_s * (r + 1)`).
    pub round_interval_s: f64,
    /// Samples in each satellite's private non-IID shard.
    pub samples_per_node: usize,
    /// Model dimensionality (weights on the wire are `(dim + 1) * 4` B).
    pub dim: usize,
    /// Local SGD epochs per round.
    pub epochs: usize,
    /// Local SGD learning rate.
    pub lr: f32,
    /// SoC fraction below which a satellite skips its round (reported as
    /// `rounds_skipped_power`); inert when the power subsystem is off.
    /// With power on it must sit at or above `power.soc_critical`
    /// ([`Config::validate_cross`]) — training must not fire in periods
    /// where captures are shed.
    pub min_soc: f64,
}

impl FederatedConfig {
    /// Hard invariants, checked at parse time and again at the top of
    /// `run_constellation`, like [`PowerConfig::validate`].
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(
            self.round_interval_s > 0.0 && self.round_interval_s.is_finite(),
            "federated.round_interval_s must be positive, got {}",
            self.round_interval_s
        );
        anyhow::ensure!(self.dim >= 1, "federated.dim must be at least 1");
        anyhow::ensure!(self.epochs >= 1, "federated.epochs must be at least 1");
        anyhow::ensure!(
            self.lr > 0.0 && self.lr.is_finite(),
            "federated.lr must be positive, got {}",
            self.lr
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.min_soc),
            "federated.min_soc must be in [0, 1], got {}",
            self.min_soc
        );
        Ok(())
    }
}

impl Default for FederatedConfig {
    fn default() -> FederatedConfig {
        FederatedConfig {
            enabled: false,
            round_interval_s: 900.0, // a few rounds per revolution
            samples_per_node: 200,
            dim: 8,
            epochs: 2,
            lr: 0.05,
            min_soc: 0.35,
        }
    }
}

/// Mission flight recorder ([`crate::telemetry::trace`]): virtual-time
/// spans/events recorded per satellite and merged at the post-join
/// barrier.  Disabled by default — zero records, one predictable branch
/// per instrumentation site, every existing result bit-identical.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Master switch: off ⇒ no `TraceSink` exists and every tracer
    /// handle is `None`.
    pub enabled: bool,
    /// Per-shard ring-buffer capacity, records.  When a ring fills, the
    /// oldest records evict (counted in `TraceLog::evicted`); evicted
    /// traces are no longer shard-count invariant, so size this to the
    /// mission (records ≈ scenes + slices + rounds per shard).
    pub ring_cap: usize,
}

impl TraceConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(self.ring_cap >= 1, "trace.ring_cap must be at least 1");
        Ok(())
    }
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { enabled: false, ring_cap: 65_536 }
    }
}

/// Deterministic chaos engine ([`crate::sim::chaos`]): seeded fault
/// injection across both constellation engines — node crashes, downlink
/// frame corruption/truncation recovered by the ARQ layer, SEU bit-flips
/// in pixel buffers, and registry heartbeat dropouts.  Disabled by
/// default — no `FaultPlan` is compiled, no chaos RNG stream exists, and
/// every existing result stays bit-identical.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Master switch: off ⇒ no fault plan is compiled and every
    /// injection site is one `Option` branch on `None`.
    pub enabled: bool,
    /// Chaos RNG seed.  Fault plans are a pure function of
    /// `(seed, satellite index)` — independent of engine, shard count,
    /// and admission cap — so the same seed reproduces the identical
    /// fault plan everywhere.
    pub seed: u64,
    /// Expected `NodeCrash` events per satellite per mission hour
    /// (Poisson-scheduled at plan compile time).
    pub crash_rate_per_hour: f64,
    /// Seconds a crashed satellite stays dark (no captures, no drains,
    /// no heartbeats) before it recovers.
    pub crash_recovery_s: f64,
    /// Per-transfer probability that a downlink frame arrives corrupted
    /// (checksum fails, ARQ retries the whole transfer).
    pub frame_corrupt_rate: f64,
    /// Per-transfer probability that a downlink frame arrives truncated
    /// (same receiver-side rejection path as corruption).
    pub frame_truncate_rate: f64,
    /// Per-scene probability of an SEU striking the checked-out pixel
    /// buffer between capture and filtering.
    pub seu_rate: f64,
    /// Bits flipped per SEU event.
    pub seu_flips: u32,
    /// Expected `RegistryDropout` events per satellite per mission hour
    /// (heartbeats suppressed, data plane unaffected).
    pub dropout_rate_per_hour: f64,
    /// Seconds each dropout suppresses heartbeats for.
    pub dropout_silence_s: f64,
    /// Transfer-level ARQ retries after a rejected frame before the
    /// link gives up on the item for this window.
    pub arq_max_retries: u32,
    /// First retry backoff, seconds; doubles per retry.
    pub arq_backoff_initial_s: f64,
    /// Exponential backoff cap, seconds.
    pub arq_backoff_cap_s: f64,
}

impl ChaosConfig {
    /// Hard invariants, checked at parse time and again at the top of
    /// both engines, like [`PowerConfig::validate`].
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        for (k, v) in [
            ("chaos.crash_rate_per_hour", self.crash_rate_per_hour),
            ("chaos.dropout_rate_per_hour", self.dropout_rate_per_hour),
        ] {
            anyhow::ensure!(v >= 0.0 && v.is_finite(), "{k} must be non-negative, got {v}");
        }
        for (k, v) in [
            ("chaos.frame_corrupt_rate", self.frame_corrupt_rate),
            ("chaos.frame_truncate_rate", self.frame_truncate_rate),
            ("chaos.seu_rate", self.seu_rate),
        ] {
            anyhow::ensure!((0.0..=1.0).contains(&v), "{k} must be in [0, 1], got {v}");
        }
        anyhow::ensure!(
            self.frame_corrupt_rate + self.frame_truncate_rate <= 1.0,
            "chaos.frame_corrupt_rate + frame_truncate_rate must not exceed 1, got {}",
            self.frame_corrupt_rate + self.frame_truncate_rate
        );
        anyhow::ensure!(
            self.crash_recovery_s > 0.0 && self.crash_recovery_s.is_finite(),
            "chaos.crash_recovery_s must be positive, got {}",
            self.crash_recovery_s
        );
        anyhow::ensure!(
            self.dropout_silence_s > 0.0 && self.dropout_silence_s.is_finite(),
            "chaos.dropout_silence_s must be positive, got {}",
            self.dropout_silence_s
        );
        anyhow::ensure!(self.seu_flips >= 1, "chaos.seu_flips must be at least 1");
        anyhow::ensure!(
            self.arq_backoff_initial_s > 0.0 && self.arq_backoff_initial_s.is_finite(),
            "chaos.arq_backoff_initial_s must be positive, got {}",
            self.arq_backoff_initial_s
        );
        anyhow::ensure!(
            self.arq_backoff_cap_s >= self.arq_backoff_initial_s,
            "chaos.arq_backoff_cap_s ({}) must be at least arq_backoff_initial_s ({})",
            self.arq_backoff_cap_s,
            self.arq_backoff_initial_s
        );
        Ok(())
    }
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            enabled: false,
            seed: 7,
            crash_rate_per_hour: 0.0,
            crash_recovery_s: 600.0,
            frame_corrupt_rate: 0.0,
            frame_truncate_rate: 0.0,
            seu_rate: 0.0,
            seu_flips: 3,
            dropout_rate_per_hour: 0.0,
            dropout_silence_s: 120.0,
            arq_max_retries: 4,
            arq_backoff_initial_s: 0.05,
            arq_backoff_cap_s: 1.0,
        }
    }
}

/// Telemetry cardinality policy ([`crate::telemetry`]).
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    /// Fleets at or below this size keep exact per-satellite `.<node>`
    /// gauges (the pre-digest output, bit-for-bit); larger fleets record
    /// fixed-size `Digest` aggregates instead, bounding the rendered
    /// metric set at any fleet size.
    pub per_node_limit: usize,
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig { per_node_limit: 64 }
    }
}

/// Scenario virtual-time constants (previously hardcoded in
/// `Pipeline::run_scenario`), consumed through [`crate::sim::Timeline`].
#[derive(Clone, Debug)]
pub struct TimingConfig {
    /// At most one scene capture per this many seconds.
    pub scene_period_floor_s: f64,
    /// Per-scene capture + filtering overhead folded into busy time.
    pub capture_overhead_s: f64,
    /// Comm duty assumed by the degenerate always-in-contact timeline
    /// (single-satellite paths; was hardcoded in the scenario fold).
    /// Orbital timelines ignore this and derive comm duty from actual
    /// link airtime inside contact windows.
    pub nominal_comm_duty: f64,
    /// Camera duty assumed by the degenerate timeline; orbital timelines
    /// derive it from capture events instead.
    pub nominal_camera_duty: f64,
}

impl Default for TimingConfig {
    fn default() -> TimingConfig {
        TimingConfig {
            scene_period_floor_s: 30.0,
            capture_overhead_s: 2.0,
            nominal_comm_duty: 0.05,
            nominal_camera_duty: 0.1,
        }
    }
}

/// Constellation runner ([`crate::coordinator::constellation`]).
#[derive(Clone, Debug)]
pub struct ConstellationConfig {
    /// Satellites sharing one ground segment.
    pub satellites: usize,
    /// Scenes each satellite captures.
    pub scenes_per_satellite: usize,
    /// Mission horizon for contact-window computation, seconds.
    pub horizon_s: f64,
    /// RAAN spacing between satellite planes, radians.
    pub raan_step_rad: f64,
    /// Replace each satellite's orbital timeline with the degenerate
    /// always-in-contact one (ground reachable whenever data is ready).
    /// With a lossless link this makes a 1-satellite constellation
    /// reproduce `run_scenario` exactly (`tests/constellation_parity.rs`).
    pub ideal_contact: bool,
}

impl Default for ConstellationConfig {
    fn default() -> ConstellationConfig {
        ConstellationConfig {
            satellites: 3,
            scenes_per_satellite: 4,
            horizon_s: 21_600.0, // 6 h: a few Beijing passes per satellite
            raan_step_rad: 0.35,
            ideal_contact: false,
        }
    }
}

/// One ground station of the mission's ground segment.  The default is
/// the paper's Beijing station — the single-station network every
/// pre-multi-station result was measured against.
#[derive(Clone, Debug)]
pub struct StationConfig {
    pub name: String,
    pub lat_deg: f64,
    pub lon_deg: f64,
    /// Minimum usable elevation, degrees (terrain + RF mask).
    pub min_elevation_deg: f64,
}

impl StationConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (-90.0..=90.0).contains(&self.lat_deg),
            "station {:?}: lat_deg must be in [-90, 90], got {}",
            self.name,
            self.lat_deg
        );
        anyhow::ensure!(
            (-180.0..=180.0).contains(&self.lon_deg),
            "station {:?}: lon_deg must be in [-180, 180], got {}",
            self.name,
            self.lon_deg
        );
        anyhow::ensure!(
            (0.0..90.0).contains(&self.min_elevation_deg),
            "station {:?}: min_elevation_deg must be in [0, 90), got {}",
            self.name,
            self.min_elevation_deg
        );
        Ok(())
    }
}

impl Default for StationConfig {
    fn default() -> StationConfig {
        // must stay bit-identical to crate::orbit::beijing_station()
        StationConfig {
            name: "Beijing".into(),
            lat_deg: 39.96,
            lon_deg: 116.35,
            min_elevation_deg: 10.0,
        }
    }
}

/// The whole ground segment must validate and be non-empty (a mission
/// with no station has no downlink at all; `StationNetwork::new` would
/// also reject it, but the surface error names the config key).
fn validate_stations(stations: &[StationConfig]) -> Result<()> {
    anyhow::ensure!(!stations.is_empty(), "stations must list at least one ground station");
    for s in stations {
        s.validate()?;
    }
    Ok(())
}

/// Full experiment config.
#[derive(Clone, Debug)]
pub struct Config {
    pub platform: PlatformConfig,
    pub policy: PolicyConfig,
    pub engine: EngineConfig,
    pub timing: TimingConfig,
    pub constellation: ConstellationConfig,
    pub energy: EnergyConfig,
    pub power: PowerConfig,
    pub federated: FederatedConfig,
    pub fleet: FleetConfig,
    pub trace: TraceConfig,
    pub chaos: ChaosConfig,
    pub telemetry: TelemetryConfig,
    /// Ground segment: one entry per station, indexed by `station_id`.
    /// Defaults to the single Beijing station.
    pub stations: Vec<StationConfig>,
    /// Scene size in 64-px cells.
    pub scene_cells: usize,
    /// Fragment edge length in px for the splitter.
    pub fragment_px: usize,
    pub loss_profile: String,
    pub seed: u64,
}

impl Config {
    /// Cross-section invariants no single section can check, enforced at
    /// parse time and again at `run_constellation` entry.
    pub fn validate_cross(&self) -> Result<()> {
        if self.federated.enabled && self.power.enabled {
            anyhow::ensure!(
                self.federated.min_soc >= self.power.soc_critical,
                "federated.min_soc ({}) must be at least power.soc_critical ({}): \
                 training must not fire in periods where captures are shed",
                self.federated.min_soc,
                self.power.soc_critical
            );
        }
        Ok(())
    }

    pub fn loss(&self) -> LossProfile {
        match self.loss_profile.as_str() {
            "weak" => LossProfile::weak(),
            "makersat" => LossProfile::makersat_incident(),
            "lossless" => LossProfile::lossless(),
            _ => LossProfile::stable(),
        }
    }
}

impl Default for Config {
    fn default() -> Config {
        Config {
            platform: baoyun_platform(),
            policy: PolicyConfig::default(),
            engine: EngineConfig::default(),
            timing: TimingConfig::default(),
            constellation: ConstellationConfig::default(),
            energy: EnergyConfig::default(),
            power: PowerConfig::default(),
            federated: FederatedConfig::default(),
            fleet: FleetConfig::default(),
            trace: TraceConfig::default(),
            chaos: ChaosConfig::default(),
            telemetry: TelemetryConfig::default(),
            stations: vec![StationConfig::default()],
            scene_cells: 8,
            fragment_px: 64,
            loss_profile: "stable".into(),
            seed: 20231207, // Baoyun launch date
        }
    }
}

/// Table 1, Baoyun row.
pub fn baoyun_platform() -> PlatformConfig {
    PlatformConfig {
        name: "Baoyun".into(),
        launch: "2021-12-07".into(),
        orbital_altitude_km: 500.0,
        mass_kg: 20.0,
        load_size_u: 0.25,
        size_u: 12.0,
        operating_system: "Ubuntu Server 20.04 arm".into(),
        uplink_mbps: (0.1, 1.0),
        downlink_mbps: 40.0,
    }
}

/// Table 1, Chuangxingleishen row.
pub fn chuangxingleishen_platform() -> PlatformConfig {
    PlatformConfig {
        name: "Chuangxingleishen".into(),
        launch: "2022-02-27".into(),
        orbital_altitude_km: 500.0,
        mass_kg: 20.0,
        load_size_u: 0.25,
        size_u: 6.0,
        operating_system: "Debian Buster with Raspberry Pi".into(),
        uplink_mbps: (0.1, 1.0),
        downlink_mbps: 40.0,
    }
}

fn platform_from_json(j: &Json) -> Result<PlatformConfig> {
    let s = |k: &str| -> Result<String> {
        Ok(j.req(k)?.as_str().context(k.to_string())?.to_string())
    };
    let n = |k: &str| -> Result<f64> { j.req(k)?.as_f64().context(k.to_string()) };
    let up = j.req("uplink_mbps")?.as_arr().context("uplink_mbps")?;
    Ok(PlatformConfig {
        name: s("name")?,
        launch: s("launch")?,
        orbital_altitude_km: n("orbital_altitude_km")?,
        mass_kg: n("mass_kg")?,
        load_size_u: n("load_size_u")?,
        size_u: n("size_u")?,
        operating_system: s("operating_system")?,
        uplink_mbps: (
            up[0].as_f64().context("uplink lo")?,
            up[1].as_f64().context("uplink hi")?,
        ),
        downlink_mbps: n("downlink_mbps")?,
    })
}

impl Config {
    /// Load from a JSON file; missing sections fall back to defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let j = Json::parse(text).context("config json")?;
        let mut cfg = Config::default();
        if let Some(p) = j.get("platform") {
            cfg.platform = platform_from_json(p)?;
        }
        if let Some(p) = j.get("policy") {
            let f = |k: &str, d: f32| p.get(k).and_then(|v| v.as_f64()).map(|x| x as f32).unwrap_or(d);
            cfg.policy = PolicyConfig {
                confidence_threshold: f("confidence_threshold", cfg.policy.confidence_threshold),
                empty_objectness: f("empty_objectness", cfg.policy.empty_objectness),
                redundancy_threshold: f("redundancy_threshold", cfg.policy.redundancy_threshold),
                nms_iou: f("nms_iou", cfg.policy.nms_iou),
                score_threshold: f("score_threshold", cfg.policy.score_threshold),
                batch_size: p
                    .get("batch_size")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.policy.batch_size),
                adaptive: p
                    .get("adaptive")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(cfg.policy.adaptive),
                adaptive_backlog_bytes: p
                    .get("adaptive_backlog_bytes")
                    .and_then(|v| v.as_f64())
                    .map(|x| x as u64)
                    .unwrap_or(cfg.policy.adaptive_backlog_bytes),
                adaptive_loss_rate: p
                    .get("adaptive_loss_rate")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.policy.adaptive_loss_rate),
                adaptive_tighten: f("adaptive_tighten", cfg.policy.adaptive_tighten),
                adaptive_relax: f("adaptive_relax", cfg.policy.adaptive_relax),
                filter_precision: p
                    .get("filter_precision")
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .unwrap_or(cfg.policy.filter_precision),
            };
        }
        if let Some(e) = j.get("engine") {
            cfg.engine = EngineConfig {
                workers: e.get("workers").and_then(|v| v.as_usize()).unwrap_or(cfg.engine.workers),
                channel_depth: e
                    .get("channel_depth")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.engine.channel_depth),
                batch_max_wait_s: e
                    .get("batch_max_wait_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.engine.batch_max_wait_s),
                tile_pool_cap: e
                    .get("tile_pool_cap")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.engine.tile_pool_cap),
            };
        }
        if let Some(t) = j.get("timing") {
            cfg.timing = TimingConfig {
                scene_period_floor_s: t
                    .get("scene_period_floor_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.scene_period_floor_s),
                capture_overhead_s: t
                    .get("capture_overhead_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.capture_overhead_s),
                nominal_comm_duty: t
                    .get("nominal_comm_duty")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.nominal_comm_duty),
                nominal_camera_duty: t
                    .get("nominal_camera_duty")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.timing.nominal_camera_duty),
            };
        }
        if let Some(c) = j.get("constellation") {
            cfg.constellation = ConstellationConfig {
                satellites: c
                    .get("satellites")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.constellation.satellites),
                scenes_per_satellite: c
                    .get("scenes_per_satellite")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.constellation.scenes_per_satellite),
                horizon_s: c
                    .get("horizon_s")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.constellation.horizon_s),
                raan_step_rad: c
                    .get("raan_step_rad")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.constellation.raan_step_rad),
                ideal_contact: c
                    .get("ideal_contact")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(cfg.constellation.ideal_contact),
            };
        }
        if let Some(e) = j.get("energy") {
            cfg.energy = EnergyConfig {
                pi_idle_floor: e
                    .get("pi_idle_floor")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.energy.pi_idle_floor),
                comm_idle_floor: e
                    .get("comm_idle_floor")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(cfg.energy.comm_idle_floor),
            };
        }
        if let Some(p) = j.get("power") {
            let n = |k: &str, d: f64| p.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
            cfg.power = PowerConfig {
                enabled: p.get("enabled").and_then(|v| v.as_bool()).unwrap_or(cfg.power.enabled),
                battery_wh: n("battery_wh", cfg.power.battery_wh),
                panel_w: n("panel_w", cfg.power.panel_w),
                cosine_derate: n("cosine_derate", cfg.power.cosine_derate),
                charge_eff: n("charge_eff", cfg.power.charge_eff),
                discharge_eff: n("discharge_eff", cfg.power.discharge_eff),
                initial_soc: n("initial_soc", cfg.power.initial_soc),
                soc_defer: n("soc_defer", cfg.power.soc_defer),
                soc_critical: n("soc_critical", cfg.power.soc_critical),
                defer_tighten: n("defer_tighten", cfg.power.defer_tighten as f64) as f32,
                fade_per_cycle: n("fade_per_cycle", cfg.power.fade_per_cycle),
            };
        }
        if let Some(f) = j.get("fleet") {
            cfg.fleet = FleetConfig {
                shards: f.get("shards").and_then(|v| v.as_usize()).unwrap_or(cfg.fleet.shards),
                max_events_in_flight: f
                    .get("max_events_in_flight")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.fleet.max_events_in_flight),
            };
        }
        if let Some(f) = j.get("federated") {
            let n = |k: &str, d: f64| f.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
            let u = |k: &str, d: usize| f.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
            cfg.federated = FederatedConfig {
                enabled: f
                    .get("enabled")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(cfg.federated.enabled),
                round_interval_s: n("round_interval_s", cfg.federated.round_interval_s),
                samples_per_node: u("samples_per_node", cfg.federated.samples_per_node),
                dim: u("dim", cfg.federated.dim),
                epochs: u("epochs", cfg.federated.epochs),
                lr: n("lr", cfg.federated.lr as f64) as f32,
                min_soc: n("min_soc", cfg.federated.min_soc),
            };
        }
        if let Some(t) = j.get("trace") {
            cfg.trace = TraceConfig {
                enabled: t.get("enabled").and_then(|v| v.as_bool()).unwrap_or(cfg.trace.enabled),
                ring_cap: t
                    .get("ring_cap")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.trace.ring_cap),
            };
        }
        if let Some(c) = j.get("chaos") {
            let n = |k: &str, d: f64| c.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
            cfg.chaos = ChaosConfig {
                enabled: c.get("enabled").and_then(|v| v.as_bool()).unwrap_or(cfg.chaos.enabled),
                seed: c
                    .get("seed")
                    .and_then(|v| v.as_f64())
                    .map(|x| x as u64)
                    .unwrap_or(cfg.chaos.seed),
                crash_rate_per_hour: n("crash_rate_per_hour", cfg.chaos.crash_rate_per_hour),
                crash_recovery_s: n("crash_recovery_s", cfg.chaos.crash_recovery_s),
                frame_corrupt_rate: n("frame_corrupt_rate", cfg.chaos.frame_corrupt_rate),
                frame_truncate_rate: n("frame_truncate_rate", cfg.chaos.frame_truncate_rate),
                seu_rate: n("seu_rate", cfg.chaos.seu_rate),
                seu_flips: c
                    .get("seu_flips")
                    .and_then(|v| v.as_usize())
                    .map(|x| x as u32)
                    .unwrap_or(cfg.chaos.seu_flips),
                dropout_rate_per_hour: n("dropout_rate_per_hour", cfg.chaos.dropout_rate_per_hour),
                dropout_silence_s: n("dropout_silence_s", cfg.chaos.dropout_silence_s),
                arq_max_retries: c
                    .get("arq_max_retries")
                    .and_then(|v| v.as_usize())
                    .map(|x| x as u32)
                    .unwrap_or(cfg.chaos.arq_max_retries),
                arq_backoff_initial_s: n("arq_backoff_initial_s", cfg.chaos.arq_backoff_initial_s),
                arq_backoff_cap_s: n("arq_backoff_cap_s", cfg.chaos.arq_backoff_cap_s),
            };
        }
        if let Some(t) = j.get("telemetry") {
            cfg.telemetry = TelemetryConfig {
                per_node_limit: t
                    .get("per_node_limit")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(cfg.telemetry.per_node_limit),
            };
        }
        if let Some(arr) = j.get("stations").and_then(|v| v.as_arr()) {
            cfg.stations = arr
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let d = StationConfig::default();
                    StationConfig {
                        name: s
                            .get("name")
                            .and_then(|v| v.as_str())
                            .map(|n| n.to_string())
                            .unwrap_or_else(|| format!("station-{i}")),
                        lat_deg: s.get("lat_deg").and_then(|v| v.as_f64()).unwrap_or(d.lat_deg),
                        lon_deg: s.get("lon_deg").and_then(|v| v.as_f64()).unwrap_or(d.lon_deg),
                        min_elevation_deg: s
                            .get("min_elevation_deg")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(d.min_elevation_deg),
                    }
                })
                .collect();
        }
        if let Some(v) = j.get("scene_cells").and_then(|v| v.as_usize()) {
            cfg.scene_cells = v;
        }
        if let Some(v) = j.get("fragment_px").and_then(|v| v.as_usize()) {
            cfg.fragment_px = v;
        }
        if let Some(v) = j.get("loss_profile").and_then(|v| v.as_str()) {
            cfg.loss_profile = v.to_string();
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            cfg.seed = v as u64;
        }
        cfg.policy.validate().context("policy config")?;
        cfg.energy.validate().context("energy config")?;
        cfg.power.validate().context("power config")?;
        cfg.federated.validate().context("federated config")?;
        cfg.fleet.validate().context("fleet config")?;
        cfg.trace.validate().context("trace config")?;
        cfg.chaos.validate().context("chaos config")?;
        validate_stations(&cfg.stations).context("stations config")?;
        cfg.validate_cross().context("config cross-checks")?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_baoyun() {
        let c = Config::default();
        assert_eq!(c.platform.name, "Baoyun");
        assert_eq!(c.platform.downlink_mbps, 40.0);
    }

    #[test]
    fn parse_overrides() {
        let c = Config::parse(
            r#"{"policy": {"confidence_threshold": 0.6, "batch_size": 1},
                "fragment_px": 32, "loss_profile": "weak", "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(c.policy.confidence_threshold, 0.6);
        assert_eq!(c.policy.batch_size, 1);
        assert_eq!(c.fragment_px, 32);
        assert_eq!(c.seed, 7);
        assert!((c.loss().loss_bad - LossProfile::weak().loss_bad).abs() < 1e-12);
    }

    #[test]
    fn parse_engine_timing_constellation_sections() {
        let c = Config::parse(
            r#"{"policy": {"empty_objectness": 0.3},
                "engine": {"workers": 4, "channel_depth": 8, "batch_max_wait_s": 2.5},
                "timing": {"scene_period_floor_s": 45, "capture_overhead_s": 1.5},
                "constellation": {"satellites": 5, "scenes_per_satellite": 2,
                                  "horizon_s": 7200, "raan_step_rad": 0.5}}"#,
        )
        .unwrap();
        assert_eq!(c.policy.empty_objectness, 0.3);
        assert_eq!(c.engine.workers, 4);
        assert_eq!(c.engine.channel_depth, 8);
        assert_eq!(c.engine.batch_max_wait_s, 2.5);
        assert_eq!(c.timing.scene_period_floor_s, 45.0);
        assert_eq!(c.timing.capture_overhead_s, 1.5);
        assert_eq!(c.constellation.satellites, 5);
        assert_eq!(c.constellation.scenes_per_satellite, 2);
        assert_eq!(c.constellation.horizon_s, 7200.0);
        assert_eq!(c.constellation.raan_step_rad, 0.5);
    }

    #[test]
    fn defaults_preserve_legacy_constants() {
        // The staged-engine and sim refactors promoted these from
        // hardcoded values; defaults must keep the pre-refactor
        // behaviour bit-for-bit.
        let c = Config::default();
        assert_eq!(c.policy.empty_objectness, 0.25);
        assert_eq!(c.timing.scene_period_floor_s, 30.0);
        assert_eq!(c.timing.capture_overhead_s, 2.0);
        assert_eq!(c.timing.nominal_comm_duty, 0.05);
        assert_eq!(c.timing.nominal_camera_duty, 0.1);
        assert!(!c.policy.adaptive, "adaptive routing must default off");
        assert!(!c.constellation.ideal_contact);
        assert_eq!(c.energy.pi_idle_floor, 0.25);
        assert_eq!(c.energy.comm_idle_floor, 0.15);
        assert!(!c.power.enabled, "power subsystem must default off");
        assert!(!c.federated.enabled, "federated scheduling must default off");
        assert!(!c.trace.enabled, "flight recorder must default off");
        assert!(!c.chaos.enabled, "chaos engine must default off");
        assert_eq!(c.telemetry.per_node_limit, 64);
    }

    #[test]
    fn parse_filter_precision_and_pool_cap() {
        let c = Config::parse(
            r#"{"policy": {"filter_precision": "i8"},
                "engine": {"tile_pool_cap": 128}}"#,
        )
        .unwrap();
        assert_eq!(c.policy.filter_precision, "i8");
        assert_eq!(c.engine.tile_pool_cap, 128);
        // defaults: bit-identical f32 path, unbounded pool
        let d = Config::default();
        assert_eq!(d.policy.filter_precision, "f32");
        assert_eq!(d.engine.tile_pool_cap, 0);
        // unknown precision fails at parse, not deep in the pipeline
        assert!(Config::parse(r#"{"policy": {"filter_precision": "fp16"}}"#).is_err());
        assert!(Config::parse(r#"{"policy": {"filter_precision": ""}}"#).is_err());
    }

    #[test]
    fn parse_federated_section() {
        let c = Config::parse(
            r#"{"federated": {"enabled": true, "round_interval_s": 600,
                              "samples_per_node": 300, "dim": 16, "epochs": 3,
                              "lr": 0.02, "min_soc": 0.5}}"#,
        )
        .unwrap();
        assert!(c.federated.enabled);
        assert_eq!(c.federated.round_interval_s, 600.0);
        assert_eq!(c.federated.samples_per_node, 300);
        assert_eq!(c.federated.dim, 16);
        assert_eq!(c.federated.epochs, 3);
        assert_eq!(c.federated.lr, 0.02);
        assert_eq!(c.federated.min_soc, 0.5);
        // partial override keeps the other defaults
        let p = Config::parse(r#"{"federated": {"enabled": true, "dim": 4}}"#).unwrap();
        assert_eq!(p.federated.dim, 4);
        assert_eq!(p.federated.round_interval_s, FederatedConfig::default().round_interval_s);
    }

    #[test]
    fn invalid_federated_section_fails_at_parse() {
        assert!(
            Config::parse(r#"{"federated": {"enabled": true, "round_interval_s": 0}}"#).is_err()
        );
        assert!(Config::parse(r#"{"federated": {"enabled": true, "dim": 0}}"#).is_err());
        assert!(Config::parse(r#"{"federated": {"enabled": true, "epochs": 0}}"#).is_err());
        assert!(Config::parse(r#"{"federated": {"enabled": true, "lr": 0}}"#).is_err());
        assert!(Config::parse(r#"{"federated": {"enabled": true, "min_soc": 1.5}}"#).is_err());
        // disabled federated is never validated: the section is inert
        assert!(Config::parse(r#"{"federated": {"dim": 0}}"#).is_ok());
    }

    #[test]
    fn federated_min_soc_must_cover_shed_band() {
        // a round firing in a shed period would train on a battery the
        // governor just declared critical; the cross-check forbids it
        assert!(Config::parse(
            r#"{"power": {"enabled": true, "soc_critical": 0.3},
                "federated": {"enabled": true, "min_soc": 0.2}}"#
        )
        .is_err());
        // equal is fine, and so is either subsystem alone
        assert!(Config::parse(
            r#"{"power": {"enabled": true, "soc_critical": 0.3},
                "federated": {"enabled": true, "min_soc": 0.3}}"#
        )
        .is_ok());
        assert!(
            Config::parse(r#"{"federated": {"enabled": true, "min_soc": 0.0}}"#).is_ok(),
            "power off: the gate is inert and unconstrained"
        );
    }

    #[test]
    fn parse_energy_and_power_sections() {
        let c = Config::parse(
            r#"{"energy": {"pi_idle_floor": 0.05, "comm_idle_floor": 0.02},
                "power": {"enabled": true, "battery_wh": 30, "panel_w": 90,
                          "cosine_derate": 0.7, "charge_eff": 0.9,
                          "discharge_eff": 0.92, "initial_soc": 0.8,
                          "soc_defer": 0.5, "soc_critical": 0.25,
                          "defer_tighten": 0.3}}"#,
        )
        .unwrap();
        assert_eq!(c.energy.pi_idle_floor, 0.05);
        assert_eq!(c.energy.comm_idle_floor, 0.02);
        assert!(c.power.enabled);
        assert_eq!(c.power.battery_wh, 30.0);
        assert_eq!(c.power.panel_w, 90.0);
        assert_eq!(c.power.cosine_derate, 0.7);
        assert_eq!(c.power.charge_eff, 0.9);
        assert_eq!(c.power.discharge_eff, 0.92);
        assert_eq!(c.power.initial_soc, 0.8);
        assert_eq!(c.power.soc_defer, 0.5);
        assert_eq!(c.power.soc_critical, 0.25);
        assert_eq!(c.power.defer_tighten, 0.3);
    }

    #[test]
    fn invalid_power_section_fails_at_parse() {
        assert!(Config::parse(r#"{"power": {"enabled": true, "battery_wh": 0}}"#).is_err());
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "discharge_eff": 0}}"#).is_err()
        );
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "cosine_derate": -0.5}}"#).is_err()
        );
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "soc_critical": 1.5}}"#).is_err()
        );
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "defer_tighten": -0.1}}"#).is_err()
        );
        // energy floors are validated too (2.5 is a plausible typo for 0.25)
        assert!(Config::parse(r#"{"energy": {"pi_idle_floor": 2.5}}"#).is_err());
        assert!(Config::parse(r#"{"energy": {"comm_idle_floor": -1}}"#).is_err());
        // disabled power is never validated: the section is inert
        assert!(Config::parse(r#"{"power": {"battery_wh": 0}}"#).is_ok());
        // shed-only governor (empty defer band) is legal, not an error
        assert!(Config::parse(
            r#"{"power": {"enabled": true, "soc_defer": 0.2, "soc_critical": 0.5}}"#
        )
        .is_ok());
    }

    #[test]
    fn parse_fleet_section_and_fade() {
        let c = Config::parse(
            r#"{"fleet": {"shards": 8, "max_events_in_flight": 256},
                "power": {"enabled": true, "fade_per_cycle": 0.002}}"#,
        )
        .unwrap();
        assert_eq!(c.fleet.shards, 8);
        assert_eq!(c.fleet.max_events_in_flight, 256);
        assert_eq!(c.power.fade_per_cycle, 0.002);
        // defaults: 4 shards, bounded in-flight, zero fade
        let d = Config::default();
        assert_eq!(d.fleet.shards, 4);
        assert_eq!(d.fleet.max_events_in_flight, 64);
        assert_eq!(d.power.fade_per_cycle, 0.0);
        // partial override keeps the other defaults
        let p = Config::parse(r#"{"fleet": {"shards": 2}}"#).unwrap();
        assert_eq!(p.fleet.shards, 2);
        assert_eq!(p.fleet.max_events_in_flight, 64);
        // zero shards / out-of-range fade fail at parse
        assert!(Config::parse(r#"{"fleet": {"shards": 0}}"#).is_err());
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "fade_per_cycle": 1.5}}"#).is_err()
        );
        assert!(
            Config::parse(r#"{"power": {"enabled": true, "fade_per_cycle": -0.1}}"#).is_err()
        );
        // disabled power: fade is inert and unvalidated, like the rest
        assert!(Config::parse(r#"{"power": {"fade_per_cycle": 9}}"#).is_ok());
    }

    #[test]
    fn parse_trace_and_telemetry_sections() {
        let c = Config::parse(
            r#"{"trace": {"enabled": true, "ring_cap": 1024},
                "telemetry": {"per_node_limit": 8}}"#,
        )
        .unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.ring_cap, 1024);
        assert_eq!(c.telemetry.per_node_limit, 8);
        // partial override keeps the other defaults
        let p = Config::parse(r#"{"trace": {"enabled": true}}"#).unwrap();
        assert!(p.trace.enabled);
        assert_eq!(p.trace.ring_cap, TraceConfig::default().ring_cap);
        // zero-capacity ring fails at parse, but only when tracing is on
        assert!(Config::parse(r#"{"trace": {"enabled": true, "ring_cap": 0}}"#).is_err());
        assert!(Config::parse(r#"{"trace": {"ring_cap": 0}}"#).is_ok());
    }

    #[test]
    fn parse_chaos_section() {
        let c = Config::parse(
            r#"{"chaos": {"enabled": true, "seed": 99, "crash_rate_per_hour": 0.5,
                          "crash_recovery_s": 300, "frame_corrupt_rate": 0.02,
                          "frame_truncate_rate": 0.01, "seu_rate": 0.05,
                          "seu_flips": 5, "dropout_rate_per_hour": 1.5,
                          "dropout_silence_s": 90, "arq_max_retries": 6,
                          "arq_backoff_initial_s": 0.1, "arq_backoff_cap_s": 2}}"#,
        )
        .unwrap();
        assert!(c.chaos.enabled);
        assert_eq!(c.chaos.seed, 99);
        assert_eq!(c.chaos.crash_rate_per_hour, 0.5);
        assert_eq!(c.chaos.crash_recovery_s, 300.0);
        assert_eq!(c.chaos.frame_corrupt_rate, 0.02);
        assert_eq!(c.chaos.frame_truncate_rate, 0.01);
        assert_eq!(c.chaos.seu_rate, 0.05);
        assert_eq!(c.chaos.seu_flips, 5);
        assert_eq!(c.chaos.dropout_rate_per_hour, 1.5);
        assert_eq!(c.chaos.dropout_silence_s, 90.0);
        assert_eq!(c.chaos.arq_max_retries, 6);
        assert_eq!(c.chaos.arq_backoff_initial_s, 0.1);
        assert_eq!(c.chaos.arq_backoff_cap_s, 2.0);
        // partial override keeps the other defaults
        let p = Config::parse(r#"{"chaos": {"enabled": true, "seu_rate": 0.2}}"#).unwrap();
        assert_eq!(p.chaos.seu_rate, 0.2);
        assert_eq!(p.chaos.arq_max_retries, ChaosConfig::default().arq_max_retries);
        assert_eq!(p.chaos.seed, ChaosConfig::default().seed);
    }

    #[test]
    fn invalid_chaos_section_fails_only_when_enabled() {
        assert!(Config::parse(r#"{"chaos": {"enabled": true, "seu_rate": 1.5}}"#).is_err());
        assert!(
            Config::parse(r#"{"chaos": {"enabled": true, "crash_rate_per_hour": -1}}"#).is_err()
        );
        assert!(
            Config::parse(r#"{"chaos": {"enabled": true, "crash_recovery_s": 0}}"#).is_err()
        );
        assert!(Config::parse(r#"{"chaos": {"enabled": true, "seu_flips": 0}}"#).is_err());
        assert!(Config::parse(
            r#"{"chaos": {"enabled": true, "frame_corrupt_rate": 0.6,
                          "frame_truncate_rate": 0.6}}"#
        )
        .is_err());
        assert!(Config::parse(
            r#"{"chaos": {"enabled": true, "arq_backoff_initial_s": 0.5,
                          "arq_backoff_cap_s": 0.1}}"#
        )
        .is_err());
        // disabled chaos is never validated: the section is inert
        assert!(Config::parse(r#"{"chaos": {"seu_rate": 9}}"#).is_ok());
    }

    #[test]
    fn parse_stations_section() {
        let c = Config::parse(
            r#"{"stations": [
                 {"name": "Beijing", "lat_deg": 39.96, "lon_deg": 116.35,
                  "min_elevation_deg": 10},
                 {"name": "Kashi", "lat_deg": 39.47, "lon_deg": 75.98,
                  "min_elevation_deg": 5},
                 {"lat_deg": -33.0, "lon_deg": 151.0}]}"#,
        )
        .unwrap();
        assert_eq!(c.stations.len(), 3);
        assert_eq!(c.stations[1].name, "Kashi");
        assert_eq!(c.stations[1].min_elevation_deg, 5.0);
        // unnamed entries get an index name, missing keys fall back to
        // the Beijing defaults
        assert_eq!(c.stations[2].name, "station-2");
        assert_eq!(c.stations[2].lat_deg, -33.0);
        assert_eq!(c.stations[2].min_elevation_deg, 10.0);
        // the default section is exactly one Beijing station
        let d = Config::default();
        assert_eq!(d.stations.len(), 1);
        assert_eq!(d.stations[0].name, "Beijing");
        assert_eq!(d.stations[0].lat_deg, 39.96);
        assert_eq!(d.stations[0].lon_deg, 116.35);
        assert_eq!(d.stations[0].min_elevation_deg, 10.0);
    }

    #[test]
    fn invalid_stations_fail_at_parse() {
        assert!(Config::parse(r#"{"stations": []}"#).is_err(), "empty ground segment");
        assert!(Config::parse(r#"{"stations": [{"lat_deg": 95}]}"#).is_err());
        assert!(Config::parse(r#"{"stations": [{"lon_deg": 200}]}"#).is_err());
        assert!(Config::parse(r#"{"stations": [{"min_elevation_deg": 90}]}"#).is_err());
        assert!(Config::parse(r#"{"stations": [{"min_elevation_deg": -1}]}"#).is_err());
    }

    #[test]
    fn power_partial_override_keeps_other_defaults() {
        let c = Config::parse(r#"{"power": {"enabled": true, "battery_wh": 12}}"#).unwrap();
        assert!(c.power.enabled);
        assert_eq!(c.power.battery_wh, 12.0);
        let d = PowerConfig::default();
        assert_eq!(c.power.panel_w, d.panel_w);
        assert_eq!(c.power.soc_defer, d.soc_defer);
        assert_eq!(c.power.soc_critical, d.soc_critical);
    }

    #[test]
    fn parse_sim_and_adaptive_sections() {
        let c = Config::parse(
            r#"{"policy": {"adaptive": true, "adaptive_backlog_bytes": 1000000,
                           "adaptive_loss_rate": 0.1, "adaptive_tighten": 0.3,
                           "adaptive_relax": 0.02},
                "timing": {"nominal_comm_duty": 0.08, "nominal_camera_duty": 0.2},
                "constellation": {"ideal_contact": true},
                "loss_profile": "lossless"}"#,
        )
        .unwrap();
        assert!(c.policy.adaptive);
        assert_eq!(c.policy.adaptive_backlog_bytes, 1_000_000);
        assert_eq!(c.policy.adaptive_loss_rate, 0.1);
        assert_eq!(c.policy.adaptive_tighten, 0.3);
        assert_eq!(c.policy.adaptive_relax, 0.02);
        assert_eq!(c.timing.nominal_comm_duty, 0.08);
        assert_eq!(c.timing.nominal_camera_duty, 0.2);
        assert!(c.constellation.ideal_contact);
        assert_eq!(c.loss().stationary_loss(), 0.0);
    }

    #[test]
    fn parse_full_platform() {
        let c = Config::parse(
            r#"{"platform": {"name": "X", "launch": "2022-01-01",
                 "orbital_altitude_km": 550, "mass_kg": 10, "load_size_u": 0.5,
                 "size_u": 6, "operating_system": "linux",
                 "uplink_mbps": [0.1, 1.0], "downlink_mbps": 80}}"#,
        )
        .unwrap();
        assert_eq!(c.platform.name, "X");
        assert_eq!(c.platform.downlink_mbps, 80.0);
    }

    #[test]
    fn repo_config_files_parse() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        for f in ["baoyun.json", "chuangxingleishen.json"] {
            let p = std::path::Path::new(dir).join(f);
            if p.exists() {
                let c = Config::load(&p).unwrap_or_else(|e| panic!("{f}: {e}"));
                assert_eq!(c.platform.downlink_mbps, 40.0);
            }
        }
    }

    #[test]
    fn cxls_differs_from_baoyun_in_size() {
        assert_eq!(baoyun_platform().size_u, 12.0);
        assert_eq!(chuangxingleishen_platform().size_u, 6.0);
    }
}
