//! Power subsystem: solar generation, battery state-of-charge, and the
//! energy-aware mission governor.
//!
//! The paper's H2 headline (in-orbit computing at ≈17% of onboard
//! energy) is an *accounting* result; a real cloud-native satellite
//! closes the loop: the solar array charges a battery while sunlit,
//! eclipse and load drain it, and the platform sheds or defers work
//! when state-of-charge runs low — the energy-constrained scheduling
//! regime of arXiv:2402.01675 (resource-efficient in-orbit detection)
//! and arXiv:2111.12769 (power-limited on-board training).
//!
//! Three parts:
//!
//! * [`SolarArray`] — watts from [`crate::sim::Timeline`] illumination
//!   (sunlit seconds per period) with a cosine/beta-angle derate;
//! * [`Battery`] — Wh capacity with charge/discharge efficiency; SoC is
//!   integrated per scene period from solar input minus the
//!   [`EnergyMeter`] load of that period, clamped to `[0, capacity]`;
//! * [`PowerGovernor`] — the policy the constellation driver consults
//!   at each scene's virtual capture time: below `soc_defer` it defers
//!   downlink drains to the next window and tightens the router
//!   threshold (composing with `RouterPolicy::effective`), below
//!   `soc_critical` it sheds captures entirely (camera + compute idle
//!   for that period).  Verdicts are functions of SoC alone, and SoC is
//!   a function of mission-time history alone, so governed runs stay
//!   deterministic.
//!
//! [`PowerState`] bundles the three with a private load meter (same
//! idle floors as the report's meter, from the `energy` config section)
//! and the SoC trajectory stats that reach `SatelliteReport` /
//! `ScenarioResult`.  [`fly_mission`] is an artifact-free governed
//! flight loop over a [`Timeline`] shared by the invariant tests and
//! `benches/perf_power.rs`.

use crate::config::{EnergyConfig, PowerConfig};
use crate::energy::EnergyMeter;
use crate::sedna::federated::FedScheduler;
use crate::sim::{DutyCycles, Timeline};

/// Solar array: nameplate watts derated by the mean incidence cosine.
#[derive(Clone, Copy, Debug)]
pub struct SolarArray {
    /// Output at normal incidence, W.
    pub panel_w: f64,
    /// Mean cosine/beta-angle derate while sunlit (0, 1].
    pub cosine_derate: f64,
}

impl SolarArray {
    /// Energy generated over `sunlit_s` seconds of illumination, Wh.
    pub fn generation_wh(&self, sunlit_s: f64) -> f64 {
        self.panel_w * self.cosine_derate * sunlit_s.max(0.0) / 3600.0
    }
}

/// Battery with round-trip losses.  Generation feeds the load directly;
/// only the surplus charges (at `charge_eff`) and only the deficit
/// discharges (drawing `1/discharge_eff` per delivered Wh).
#[derive(Clone, Copy, Debug)]
pub struct Battery {
    pub capacity_wh: f64,
    pub charge_eff: f64,
    pub discharge_eff: f64,
    /// Linear capacity fade per full-capacity cycle equivalent (e.g.
    /// 2e-4 ≈ 20% fade after 1000 cycles).  0.0 disables fade and keeps
    /// every pre-fade result bit-identical.
    pub fade_per_cycle: f64,
    soc_wh: f64,
    /// Cumulative energy drawn out of the store, Wh (the
    /// depth-of-discharge ledger; charging never decrements it).
    discharged_wh: f64,
}

impl Battery {
    pub fn new(capacity_wh: f64, charge_eff: f64, discharge_eff: f64, initial_soc: f64) -> Battery {
        assert!(capacity_wh > 0.0, "battery capacity must be positive");
        Battery {
            capacity_wh,
            charge_eff: charge_eff.clamp(0.0, 1.0),
            discharge_eff: discharge_eff.clamp(1e-9, 1.0),
            fade_per_cycle: 0.0,
            soc_wh: capacity_wh * initial_soc.clamp(0.0, 1.0),
            discharged_wh: 0.0,
        }
    }

    /// Builder: enable linear capacity fade (clamped non-negative).
    pub fn with_fade(mut self, fade_per_cycle: f64) -> Battery {
        self.fade_per_cycle = fade_per_cycle.max(0.0);
        self
    }

    pub fn soc_wh(&self) -> f64 {
        self.soc_wh
    }

    /// Capacity after wear: `capacity_wh * (1 - fade_per_cycle *
    /// cycle_equivalents)`, floored at 1% of nameplate so a pathological
    /// fade config degrades gracefully instead of dividing by ~0.  With
    /// `fade_per_cycle == 0.0` the multiplier is exactly 1.0, so every
    /// downstream value is bit-identical to the pre-fade model.
    pub fn effective_capacity_wh(&self) -> f64 {
        self.capacity_wh * (1.0 - self.fade_per_cycle * self.cycle_equivalents()).max(0.01)
    }

    /// State of charge as a fraction of *effective* (faded) capacity, in
    /// [0, 1] — the quantity governor thresholds compare against, so an
    /// aged battery trips Defer/Shed earlier at the same stored Wh.
    pub fn soc_frac(&self) -> f64 {
        self.soc_wh / self.effective_capacity_wh()
    }

    /// Cumulative energy drawn out of the store over the battery's
    /// lifetime, Wh.
    pub fn discharged_wh(&self) -> f64 {
        self.discharged_wh
    }

    /// Cumulative depth of discharge in full-capacity cycle equivalents
    /// (`discharged_wh / capacity_wh`) — the standard battery-wear proxy.
    pub fn cycle_equivalents(&self) -> f64 {
        self.discharged_wh / self.capacity_wh
    }

    /// Apply one period's energy flow: `gen_wh` in from the array,
    /// `load_wh` out to the bus.  SoC stays within `[0, effective
    /// capacity]` (= nameplate while `fade_per_cycle == 0.0`); returns
    /// the unmet load (Wh) clipped when the battery empties — the
    /// brownout indicator a governor exists to keep at zero.
    pub fn step(&mut self, gen_wh: f64, load_wh: f64) -> f64 {
        let net = gen_wh - load_wh;
        if net >= 0.0 {
            self.soc_wh = (self.soc_wh + net * self.charge_eff).min(self.effective_capacity_wh());
            0.0
        } else {
            let need_wh = -net / self.discharge_eff;
            if need_wh <= self.soc_wh {
                self.soc_wh -= need_wh;
                self.discharged_wh += need_wh;
                0.0
            } else {
                let supplied = self.soc_wh * self.discharge_eff;
                self.discharged_wh += self.soc_wh;
                self.soc_wh = 0.0;
                -net - supplied
            }
        }
    }
}

/// What the governor tells the driver to do with the upcoming scene.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerVerdict {
    /// Capture, route, and drain normally.
    Nominal,
    /// Capture and process, but defer downlink drains to the next
    /// window (transmitter off this period) and tighten the router
    /// threshold so fewer raw tiles queue behind a link that isn't
    /// being served.
    Defer,
    /// Skip the capture entirely: camera and compute idle this period.
    Shed,
}

impl PowerVerdict {
    /// Flight-recorder event kind for a governed (non-nominal) verdict:
    /// `Nominal` is the steady state and traces nothing, the governed
    /// verdicts become `Defer`/`Shed` events carrying the SoC that
    /// triggered them.
    pub fn trace_kind(self) -> Option<crate::telemetry::trace::SpanKind> {
        match self {
            PowerVerdict::Nominal => None,
            PowerVerdict::Defer => Some(crate::telemetry::trace::SpanKind::Defer),
            PowerVerdict::Shed => Some(crate::telemetry::trace::SpanKind::Shed),
        }
    }
}

/// SoC-threshold policy.  Thresholds are fractions of capacity;
/// `soc_critical < soc_defer` partitions SoC into Shed / Defer /
/// Nominal bands.
#[derive(Clone, Copy, Debug)]
pub struct PowerGovernor {
    pub soc_defer: f64,
    pub soc_critical: f64,
    /// Confidence-threshold drop applied while deferring (composes with
    /// the adaptive path: tighten whatever `effective()` produced).
    pub defer_tighten: f32,
}

impl PowerGovernor {
    pub fn verdict(&self, soc_frac: f64) -> PowerVerdict {
        if soc_frac < self.soc_critical {
            PowerVerdict::Shed
        } else if soc_frac < self.soc_defer {
            PowerVerdict::Defer
        } else {
            PowerVerdict::Nominal
        }
    }
}

/// SoC trajectory + flow accounting over a mission.
#[derive(Clone, Copy, Debug)]
pub struct PowerStats {
    /// Lowest SoC fraction observed (starts at the initial SoC).
    pub min_soc_frac: f64,
    /// Final SoC fraction at end of mission.
    pub final_soc_frac: f64,
    pub generated_wh: f64,
    pub consumed_wh: f64,
    /// Load the empty battery could not serve (brownout Wh).
    pub shortfall_wh: f64,
    pub scenes_deferred: u64,
    pub scenes_shed: u64,
    /// Federated local-training energy drawn from the battery (already
    /// included in `consumed_wh`; broken out for the H2 ledger).
    pub training_wh: f64,
    /// Cumulative energy drawn out of the battery store, Wh — the
    /// depth-of-discharge ledger (includes conversion losses; charging
    /// never decrements it).
    pub discharge_wh: f64,
    /// `discharge_wh` in full-capacity cycle equivalents — the standard
    /// battery-wear proxy for sizing a mission's battery.
    pub cycle_equivalents: f64,
    /// Effective (fade-degraded) capacity at end of mission, Wh.  Equals
    /// nameplate `battery_wh` while `power.fade_per_cycle` is 0.0.
    pub capacity_wh_now: f64,
    soc_sum: f64,
    soc_n: u64,
}

impl PowerStats {
    fn new(initial_soc_frac: f64, capacity_wh: f64) -> PowerStats {
        PowerStats {
            min_soc_frac: initial_soc_frac,
            final_soc_frac: initial_soc_frac,
            generated_wh: 0.0,
            consumed_wh: 0.0,
            shortfall_wh: 0.0,
            scenes_deferred: 0,
            scenes_shed: 0,
            training_wh: 0.0,
            discharge_wh: 0.0,
            cycle_equivalents: 0.0,
            capacity_wh_now: capacity_wh,
            soc_sum: 0.0,
            soc_n: 0,
        }
    }

    /// Mean SoC fraction over the recorded periods.
    pub fn mean_soc_frac(&self) -> f64 {
        if self.soc_n == 0 {
            self.final_soc_frac
        } else {
            self.soc_sum / self.soc_n as f64
        }
    }
}

/// One satellite's power subsystem: array + battery + governor + a
/// private load meter (so the load of each period is exactly what the
/// H2 energy accounting would integrate for the same duties).
#[derive(Clone, Debug)]
pub struct PowerState {
    array: SolarArray,
    battery: Battery,
    governor: PowerGovernor,
    meter: EnergyMeter,
    pub stats: PowerStats,
}

impl PowerState {
    pub fn new(power: &PowerConfig, energy: &EnergyConfig) -> PowerState {
        let battery = Battery::new(
            power.battery_wh,
            power.charge_eff,
            power.discharge_eff,
            power.initial_soc,
        )
        .with_fade(power.fade_per_cycle);
        PowerState {
            array: SolarArray { panel_w: power.panel_w, cosine_derate: power.cosine_derate },
            stats: PowerStats::new(battery.soc_frac(), battery.effective_capacity_wh()),
            battery,
            governor: PowerGovernor {
                soc_defer: power.soc_defer,
                soc_critical: power.soc_critical,
                defer_tighten: power.defer_tighten,
            },
            meter: EnergyMeter::with_floors(energy.pi_idle_floor, energy.comm_idle_floor),
        }
    }

    pub fn soc_frac(&self) -> f64 {
        self.battery.soc_frac()
    }

    /// SoC as integer percent — the telemetry gauge value.
    pub fn soc_pct(&self) -> i64 {
        (self.soc_frac() * 100.0).round() as i64
    }

    pub fn governor(&self) -> &PowerGovernor {
        &self.governor
    }

    /// Governor verdict at the current SoC (consulted at each scene's
    /// virtual capture time).
    pub fn verdict(&self) -> PowerVerdict {
        self.governor.verdict(self.battery.soc_frac())
    }

    /// Integrate constant duties across `[t0, t1)` of a timeline in
    /// fixed steps, so sun/eclipse transitions clamp the battery at the
    /// right times — one multi-hour step would let SoC swing through
    /// both rails unobserved.  The constellation driver uses this for
    /// inter-pass gaps and the mission tail.
    pub fn advance_chunked(
        &mut self,
        timeline: &Timeline,
        t0: f64,
        t1: f64,
        duties: DutyCycles,
        step_s: f64,
    ) {
        assert!(step_s > 0.0);
        let mut t = t0;
        while t < t1 {
            let next = (t + step_s).min(t1);
            self.advance_period(next - t, duties, timeline.sunlit_s(t, next));
            t = next;
        }
    }

    /// Integrate one period: load from the duty cycles via the meter,
    /// generation from the period's sunlit seconds.  Clamps SoC and
    /// records the trajectory stats.
    pub fn advance_period(&mut self, dt_s: f64, duties: DutyCycles, sunlit_s: f64) {
        let before_j = self.meter.platform_total_j();
        self.meter.advance(dt_s, duties.compute, duties.comm, duties.camera);
        let load_wh = (self.meter.platform_total_j() - before_j) / 3600.0;
        let gen_wh = self.array.generation_wh(sunlit_s.min(dt_s));
        let shortfall = self.battery.step(gen_wh, load_wh);
        self.stats.generated_wh += gen_wh;
        self.stats.consumed_wh += load_wh;
        self.stats.shortfall_wh += shortfall;
        let f = self.battery.soc_frac();
        self.stats.min_soc_frac = self.stats.min_soc_frac.min(f);
        self.stats.final_soc_frac = f;
        self.stats.soc_sum += f;
        self.stats.soc_n += 1;
        self.stats.discharge_wh = self.battery.discharged_wh();
        self.stats.cycle_equivalents = self.battery.cycle_equivalents();
        self.stats.capacity_wh_now = self.battery.effective_capacity_wh();
    }

    /// Charge one federated local-training burst at a round boundary:
    /// `train_s` seconds of the Pi at full active draw, drawn from the
    /// battery through the meter's training ledger line.  The burst is
    /// an additional load at that instant, not additional mission time —
    /// solar input for the surrounding period is integrated by the
    /// normal period advance.
    pub fn charge_training(&mut self, train_s: f64) {
        let wh = self.meter.add_training(train_s) / 3600.0;
        let shortfall = self.battery.step(0.0, wh);
        self.stats.consumed_wh += wh;
        self.stats.training_wh += wh;
        self.stats.shortfall_wh += shortfall;
        let f = self.battery.soc_frac();
        self.stats.min_soc_frac = self.stats.min_soc_frac.min(f);
        self.stats.final_soc_frac = f;
        self.stats.discharge_wh = self.battery.discharged_wh();
        self.stats.cycle_equivalents = self.battery.cycle_equivalents();
        self.stats.capacity_wh_now = self.battery.effective_capacity_wh();
    }
}

/// The duty cycles a governed satellite actually flies this period:
/// Defer switches the transmitter off, Shed idles camera and compute
/// too.  Increments the matching governor stat.
fn governed_duties(state: &mut PowerState, active: DutyCycles) -> DutyCycles {
    match state.verdict() {
        PowerVerdict::Nominal => active,
        PowerVerdict::Defer => {
            state.stats.scenes_deferred += 1;
            DutyCycles { comm: 0.0, ..active }
        }
        PowerVerdict::Shed => {
            state.stats.scenes_shed += 1;
            DutyCycles::default()
        }
    }
}

/// Artifact-free governed flight: march a [`PowerState`] over a
/// [`Timeline`] at a fixed period, applying the governor's verdict to
/// the duty cycles the satellite would have flown (`active`): Defer ⇒
/// transmitter off, Shed ⇒ camera + compute idle too.  Deterministic in
/// mission time; used by the invariant tests and `perf_power`, and the
/// same verdict→duty semantics the constellation driver applies to real
/// scenes.
pub fn fly_mission(state: &mut PowerState, timeline: &Timeline, active: DutyCycles, period_s: f64) {
    assert!(period_s > 0.0);
    let mut t = 0.0;
    while t < timeline.horizon_s() {
        let dt = period_s.min(timeline.horizon_s() - t);
        let duties = governed_duties(state, active);
        state.advance_period(dt, duties, timeline.sunlit_s(t, t + dt));
        t += dt;
    }
}

/// [`fly_mission`] with federated round scheduling layered on: the
/// [`FedScheduler`] is polled at each period boundary with the battery's
/// SoC, rounds at or above its `min_soc` gate charge their training
/// burst ([`PowerState::charge_training`]), rounds below it are skipped
/// and counted.  Artifact-free and deterministic; shared by
/// `benches/perf_federated.rs` and the scheduling invariant tests, and
/// the same decide→charge semantics the constellation driver applies to
/// real scenes and downlink queues.
pub fn fly_federated_mission(
    state: &mut PowerState,
    fed: &mut FedScheduler,
    timeline: &Timeline,
    active: DutyCycles,
    period_s: f64,
    train_s: f64,
) {
    assert!(period_s > 0.0);
    let mut t = 0.0;
    while t < timeline.horizon_s() {
        let dt = period_s.min(timeline.horizon_s() - t);
        let duties = governed_duties(state, active);
        state.advance_period(dt, duties, timeline.sunlit_s(t, t + dt));
        t += dt;
        for d in fed.poll(t, Some(state.soc_frac())) {
            if d.participated {
                state.charge_training(train_s);
            }
        }
    }
    // f64 rounding at the horizon must not strand a scheduled round
    for d in fed.finish(Some(state.soc_frac())) {
        if d.participated {
            state.charge_training(train_s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TimingConfig;
    use crate::orbit::{baoyun, beijing_station};

    fn state(battery_wh: f64) -> PowerState {
        let power = PowerConfig { enabled: true, battery_wh, ..PowerConfig::default() };
        PowerState::new(&power, &EnergyConfig::default())
    }

    #[test]
    fn solar_generation_scales_with_sunlit_time() {
        let a = SolarArray { panel_w: 100.0, cosine_derate: 0.5 };
        assert_eq!(a.generation_wh(3600.0), 50.0);
        assert_eq!(a.generation_wh(0.0), 0.0);
        assert_eq!(a.generation_wh(-5.0), 0.0, "negative sunlit time clamps");
    }

    #[test]
    fn battery_charges_with_efficiency_and_clamps_at_capacity() {
        let mut b = Battery::new(10.0, 0.8, 1.0, 0.5);
        assert_eq!(b.step(5.0, 0.0), 0.0);
        assert!((b.soc_wh() - 9.0).abs() < 1e-12, "5 Wh surplus stores 4 Wh at η=0.8");
        b.step(100.0, 0.0);
        assert_eq!(b.soc_wh(), 10.0, "clamped at capacity");
        assert_eq!(b.soc_frac(), 1.0);
    }

    #[test]
    fn battery_discharges_with_efficiency_and_clamps_at_zero() {
        let mut b = Battery::new(10.0, 1.0, 0.5, 1.0);
        assert_eq!(b.step(0.0, 2.0), 0.0);
        assert!((b.soc_wh() - 6.0).abs() < 1e-12, "2 Wh load draws 4 Wh at η=0.5");
        // 4 Wh more than the battery can deliver: 6 Wh stored delivers 3 Wh
        let shortfall = b.step(0.0, 4.0);
        assert_eq!(b.soc_wh(), 0.0);
        assert!((shortfall - 1.0).abs() < 1e-12, "unmet load is reported, not invented");
    }

    #[test]
    fn depth_of_discharge_accumulates_cycle_equivalents() {
        let mut b = Battery::new(10.0, 1.0, 1.0, 1.0);
        assert_eq!(b.discharged_wh(), 0.0);
        b.step(0.0, 4.0); // drain 4 Wh
        b.step(100.0, 0.0); // recharge to full — DoD must NOT rewind
        b.step(0.0, 6.0); // drain 6 Wh
        assert!((b.discharged_wh() - 10.0).abs() < 1e-12);
        assert!((b.cycle_equivalents() - 1.0).abs() < 1e-12, "10 Wh through a 10 Wh pack = 1 cycle");
        // emptying the pack counts only what was actually stored
        let mut e = Battery::new(10.0, 1.0, 0.5, 0.5);
        e.step(0.0, 100.0);
        assert_eq!(e.soc_wh(), 0.0);
        assert!((e.discharged_wh() - 5.0).abs() < 1e-12, "5 Wh stored is all that can discharge");
    }

    #[test]
    fn power_stats_surface_depth_of_discharge() {
        let mut s = state(80.0);
        let dark = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
        s.advance_period(3600.0, dark, 0.0);
        assert!(s.stats.discharge_wh > 0.0, "an hour of dark full duty must discharge");
        assert!(
            (s.stats.cycle_equivalents - s.stats.discharge_wh / 80.0).abs() < 1e-12,
            "cycle equivalents are discharge over capacity"
        );
        // training bursts land in the same ledger
        let before = s.stats.discharge_wh;
        s.charge_training(3600.0);
        assert!(s.stats.discharge_wh > before);
    }

    #[test]
    fn zero_fade_is_bit_identical_to_prefade_model() {
        // fade_per_cycle = 0.0 must not perturb a single bit of the
        // trajectory: the capacity multiplier is exactly 1.0.
        let mut plain = Battery::new(10.0, 0.9, 0.8, 0.7);
        let mut faded = Battery::new(10.0, 0.9, 0.8, 0.7).with_fade(0.0);
        for (g, l) in [(0.0, 2.0), (5.0, 1.0), (0.0, 7.0), (9.0, 0.5)] {
            assert_eq!(plain.step(g, l).to_bits(), faded.step(g, l).to_bits());
            assert_eq!(plain.soc_wh().to_bits(), faded.soc_wh().to_bits());
            assert_eq!(plain.soc_frac().to_bits(), faded.soc_frac().to_bits());
        }
        assert_eq!(faded.effective_capacity_wh().to_bits(), 10.0f64.to_bits());
    }

    #[test]
    fn fade_shrinks_effective_capacity_with_cycling() {
        let mut b = Battery::new(10.0, 1.0, 1.0, 1.0).with_fade(0.1);
        assert_eq!(b.effective_capacity_wh(), 10.0, "fresh pack at nameplate");
        b.step(0.0, 5.0); // half a cycle equivalent
        assert!((b.cycle_equivalents() - 0.5).abs() < 1e-12);
        assert!((b.effective_capacity_wh() - 9.5).abs() < 1e-12, "10 * (1 - 0.1*0.5)");
        // recharging clamps at the faded capacity, not nameplate
        b.step(100.0, 0.0);
        assert!((b.soc_wh() - 9.5).abs() < 1e-12);
        assert!((b.soc_frac() - 1.0).abs() < 1e-12, "full relative to effective capacity");
        // SoC never exceeds effective capacity as fade progresses
        for _ in 0..20 {
            b.step(0.0, 3.0);
            b.step(100.0, 0.0);
            assert!(b.soc_wh() <= b.effective_capacity_wh() + 1e-12);
        }
        assert!(b.effective_capacity_wh() >= 0.01 * 10.0, "floored at 1% of nameplate");
    }

    #[test]
    fn power_stats_surface_effective_capacity() {
        let power = PowerConfig {
            enabled: true,
            battery_wh: 80.0,
            fade_per_cycle: 0.05,
            ..PowerConfig::default()
        };
        let mut s = PowerState::new(&power, &EnergyConfig::default());
        assert_eq!(s.stats.capacity_wh_now, 80.0);
        let dark = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
        s.advance_period(3600.0, dark, 0.0);
        assert!(s.stats.capacity_wh_now < 80.0, "an hour of dark full duty wears the pack");
        assert!(
            (s.stats.capacity_wh_now - 80.0 * (1.0 - 0.05 * s.stats.cycle_equivalents)).abs()
                < 1e-9
        );
        // a zero-fade state reports nameplate forever
        let mut z = state(80.0);
        z.advance_period(3600.0, dark, 0.0);
        assert_eq!(z.stats.capacity_wh_now, 80.0);
    }

    #[test]
    fn generation_feeds_load_before_the_battery() {
        // gen == load: no round-trip loss at all
        let mut b = Battery::new(10.0, 0.5, 0.5, 0.5);
        b.step(3.0, 3.0);
        assert_eq!(b.soc_wh(), 5.0);
    }

    #[test]
    fn governor_bands_partition_soc() {
        let g = PowerGovernor { soc_defer: 0.4, soc_critical: 0.2, defer_tighten: 0.2 };
        assert_eq!(g.verdict(0.9), PowerVerdict::Nominal);
        assert_eq!(g.verdict(0.4), PowerVerdict::Nominal, "defer threshold is exclusive");
        assert_eq!(g.verdict(0.39), PowerVerdict::Defer);
        assert_eq!(g.verdict(0.2), PowerVerdict::Defer);
        assert_eq!(g.verdict(0.19), PowerVerdict::Shed);
        assert_eq!(g.verdict(0.0), PowerVerdict::Shed);
    }

    #[test]
    fn only_governed_verdicts_trace() {
        use crate::telemetry::trace::SpanKind;
        assert_eq!(PowerVerdict::Nominal.trace_kind(), None);
        assert_eq!(PowerVerdict::Defer.trace_kind(), Some(SpanKind::Defer));
        assert_eq!(PowerVerdict::Shed.trace_kind(), Some(SpanKind::Shed));
    }

    #[test]
    fn state_tracks_min_and_mean_soc() {
        let mut s = state(80.0);
        // full duty in the dark: pure discharge
        let dark = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
        s.advance_period(3600.0, dark, 0.0);
        assert!(s.soc_frac() < 1.0);
        assert_eq!(s.stats.min_soc_frac, s.soc_frac());
        assert_eq!(s.stats.final_soc_frac, s.soc_frac());
        assert!(s.stats.mean_soc_frac() <= 1.0);
        assert!(s.stats.consumed_wh > 40.0, "an hour at full duty is ~52 Wh");
        assert_eq!(s.stats.generated_wh, 0.0);
    }

    #[test]
    fn sunlit_surplus_recharges() {
        let mut s = state(80.0);
        let idle = DutyCycles::default();
        s.advance_period(3600.0, DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 }, 0.0);
        let low = s.soc_frac();
        s.advance_period(3600.0, idle, 3600.0);
        assert!(s.soc_frac() > low, "default panel out-generates the idle load");
        assert!(s.stats.generated_wh > 0.0);
    }

    #[test]
    fn advance_chunked_matches_whole_span_when_flows_are_steady() {
        // always-sunlit degenerate timeline at idle: surplus charging
        // clamps at capacity either way, and the flow accounting agrees
        // regardless of chunking
        let tl = Timeline::degenerate(&TimingConfig::default(), 7200.0);
        let idle = DutyCycles::default();
        let mut chunked = state(80.0);
        chunked.advance_chunked(&tl, 0.0, 7200.0, idle, 30.0);
        let mut whole = state(80.0);
        whole.advance_period(7200.0, idle, 7200.0);
        assert_eq!(chunked.soc_frac(), 1.0);
        assert_eq!(whole.soc_frac(), 1.0);
        assert!((chunked.stats.generated_wh - whole.stats.generated_wh).abs() < 1e-6);
        assert!((chunked.stats.consumed_wh - whole.stats.consumed_wh).abs() < 1e-6);
    }

    #[test]
    fn training_burst_draws_from_battery_and_ledger() {
        let mut s = state(80.0);
        let soc0 = s.soc_frac();
        // one virtual hour of Pi-nameplate training: 8.78 Wh of load
        s.charge_training(3600.0);
        assert!((s.stats.training_wh - 8.78).abs() < 1e-9);
        assert!((s.stats.consumed_wh - 8.78).abs() < 1e-9, "training is consumed load");
        assert!(s.soc_frac() < soc0, "the burst drains the battery");
        assert_eq!(s.stats.min_soc_frac, s.soc_frac());
        assert_eq!(s.stats.shortfall_wh, 0.0);
        // a zero-length burst is free
        let before = s.soc_frac();
        s.charge_training(0.0);
        assert_eq!(s.soc_frac(), before);
    }

    #[test]
    fn fly_mission_over_orbital_timeline_is_deterministic() {
        let tl = Timeline::orbital(
            &TimingConfig::default(),
            &baoyun(),
            &beijing_station(),
            20_000.0,
            10.0,
        );
        let active = DutyCycles { compute: 0.9, comm: 0.1, camera: 0.1 };
        let mut a = state(80.0);
        let mut b = state(80.0);
        fly_mission(&mut a, &tl, active, 30.0);
        fly_mission(&mut b, &tl, active, 30.0);
        assert_eq!(a.soc_frac().to_bits(), b.soc_frac().to_bits());
        assert_eq!(a.stats.scenes_shed, b.stats.scenes_shed);
        assert_eq!(a.stats.generated_wh.to_bits(), b.stats.generated_wh.to_bits());
        assert!(a.stats.min_soc_frac >= 0.0 && a.stats.min_soc_frac <= 1.0);
    }
}
