//! Space-ground link simulator.
//!
//! Models what the paper's offload policy actually experiences: a
//! rate-limited downlink (Table 1: ≥40 Mbps down, 0.1–1 Mbps up) with
//! bursty packet loss (§II: "one satellite task lost 80% of its data
//! packets due to downlink instability", ref [12]) and stop-and-wait-ish
//! ARQ retransmission.  Byte accounting feeds the 90%-data-reduction
//! headline (H1 in DESIGN.md).
//!
//! Loss process: Gilbert–Elliott two-state Markov chain per packet —
//! the standard burst-loss model; a "good" state with near-zero loss and
//! a "bad" (deep-fade) state with high loss.

use crate::util::rng::Rng;

/// Gilbert–Elliott parameters.
#[derive(Clone, Copy, Debug)]
pub struct LossProfile {
    /// P(good -> bad) per packet.
    pub p_gb: f64,
    /// P(bad -> good) per packet.
    pub p_bg: f64,
    /// Loss probability in the good state.
    pub loss_good: f64,
    /// Loss probability in the bad state.
    pub loss_bad: f64,
}

impl LossProfile {
    /// Benign link: rare shallow fades.
    pub fn stable() -> LossProfile {
        LossProfile { p_gb: 0.001, p_bg: 0.2, loss_good: 0.001, loss_bad: 0.1 }
    }

    /// Weak-network scenario from §3.2 ("low bandwidth and serious packet
    /// loss").
    pub fn weak() -> LossProfile {
        LossProfile { p_gb: 0.02, p_bg: 0.1, loss_good: 0.01, loss_bad: 0.5 }
    }

    /// MakerSat-0-like incident (ref [12]): ~80% of packets lost.
    pub fn makersat_incident() -> LossProfile {
        LossProfile { p_gb: 0.5, p_bg: 0.05, loss_good: 0.3, loss_bad: 0.9 }
    }

    /// Ideal channel: no fades, no loss.  Used by parity tests and the
    /// `ideal_contact` constellation regime where the only difference
    /// from the single-satellite path should be the plumbing.
    pub fn lossless() -> LossProfile {
        LossProfile { p_gb: 0.0, p_bg: 1.0, loss_good: 0.0, loss_bad: 0.0 }
    }

    /// Stationary loss rate of the chain (sanity metric for tests).
    pub fn stationary_loss(&self) -> f64 {
        let p_bad = self.p_gb / (self.p_gb + self.p_bg);
        (1.0 - p_bad) * self.loss_good + p_bad * self.loss_bad
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub bytes_offered: u64,
    pub bytes_delivered: u64,
    pub packets_sent: u64,
    pub packets_lost: u64,
    pub retransmissions: u64,
    pub transfers_aborted: u64,
    pub busy_s: f64,
}

impl LinkStats {
    pub fn loss_rate(&self) -> f64 {
        if self.packets_sent == 0 {
            0.0
        } else {
            self.packets_lost as f64 / self.packets_sent as f64
        }
    }

    pub fn goodput_bps(&self) -> f64 {
        if self.busy_s <= 0.0 {
            0.0
        } else {
            self.bytes_delivered as f64 * 8.0 / self.busy_s
        }
    }

    pub fn merge(&mut self, other: &LinkStats) {
        self.bytes_offered += other.bytes_offered;
        self.bytes_delivered += other.bytes_delivered;
        self.packets_sent += other.packets_sent;
        self.packets_lost += other.packets_lost;
        self.retransmissions += other.retransmissions;
        self.transfers_aborted += other.transfers_aborted;
        self.busy_s += other.busy_s;
    }
}

/// Outcome of one transfer attempt.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub bytes_requested: u64,
    pub bytes_delivered: u64,
    pub elapsed_s: f64,
    pub completed: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    pub rate_bps: f64,
    pub mtu: usize,
    pub loss: LossProfile,
    /// Max (re)transmissions per packet before the transfer aborts.
    pub max_tries: u32,
}

impl LinkConfig {
    /// Table 1 downlink: ≥ 40 Mbps.
    pub fn downlink(loss: LossProfile) -> LinkConfig {
        LinkConfig { rate_bps: 40e6, mtu: 1400, loss, max_tries: 8 }
    }

    /// Table 1 uplink: 0.1–1 Mbps; model the midpoint.
    pub fn uplink(loss: LossProfile) -> LinkConfig {
        LinkConfig { rate_bps: 0.5e6, mtu: 512, loss, max_tries: 8 }
    }
}

/// Simulated half-duplex channel.
pub struct Link {
    pub cfg: LinkConfig,
    rng: Rng,
    in_bad_state: bool,
    pub stats: LinkStats,
}

impl Link {
    pub fn new(cfg: LinkConfig, seed: u64) -> Link {
        Link { cfg, rng: Rng::new(seed), in_bad_state: false, stats: LinkStats::default() }
    }

    fn packet_lost(&mut self) -> bool {
        // advance the Markov chain, then draw loss from the current state
        if self.in_bad_state {
            if self.rng.bool(self.cfg.loss.p_bg) {
                self.in_bad_state = false;
            }
        } else if self.rng.bool(self.cfg.loss.p_gb) {
            self.in_bad_state = true;
        }
        let p = if self.in_bad_state { self.cfg.loss.loss_bad } else { self.cfg.loss.loss_good };
        self.rng.bool(p)
    }

    /// Transfer `bytes` within a `budget_s` time budget (e.g. the rest of
    /// the current contact window).  Lost packets are retransmitted up to
    /// `max_tries`; ACK traffic is folded into the per-packet airtime.
    pub fn transmit(&mut self, bytes: u64, budget_s: f64) -> Transfer {
        self.stats.bytes_offered += bytes;
        let packet_time = self.cfg.mtu as f64 * 8.0 / self.cfg.rate_bps;
        let n_packets = bytes.div_ceil(self.cfg.mtu as u64).max(1);
        let mut elapsed = 0.0;
        let mut delivered: u64 = 0;
        for i in 0..n_packets {
            let payload = if i + 1 == n_packets {
                bytes - i * self.cfg.mtu as u64
            } else {
                self.cfg.mtu as u64
            };
            let mut tries = 0;
            loop {
                if elapsed + packet_time > budget_s {
                    self.stats.transfers_aborted += 1;
                    self.stats.busy_s += elapsed;
                    self.stats.bytes_delivered += delivered;
                    return Transfer {
                        bytes_requested: bytes,
                        bytes_delivered: delivered,
                        elapsed_s: elapsed,
                        completed: false,
                    };
                }
                elapsed += packet_time;
                tries += 1;
                self.stats.packets_sent += 1;
                if !self.packet_lost() {
                    delivered += payload;
                    break;
                }
                self.stats.packets_lost += 1;
                if tries >= self.cfg.max_tries {
                    self.stats.transfers_aborted += 1;
                    self.stats.busy_s += elapsed;
                    self.stats.bytes_delivered += delivered;
                    return Transfer {
                        bytes_requested: bytes,
                        bytes_delivered: delivered,
                        elapsed_s: elapsed,
                        completed: false,
                    };
                }
                self.stats.retransmissions += 1;
            }
        }
        self.stats.busy_s += elapsed;
        self.stats.bytes_delivered += delivered;
        Transfer { bytes_requested: bytes, bytes_delivered: delivered, elapsed_s: elapsed, completed: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_transfer_completes_at_line_rate() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 1);
        let t = link.transmit(1_000_000, 10.0);
        assert!(t.completed);
        assert_eq!(t.bytes_delivered, 1_000_000);
        // 1 MB at 40 Mbps ≈ 0.2 s (+ packetization rounding)
        assert!((0.19..0.22).contains(&t.elapsed_s), "{}", t.elapsed_s);
    }

    #[test]
    fn budget_truncates_transfer() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::stable()), 2);
        let t = link.transmit(100_000_000, 0.5); // 100 MB into 0.5 s of 40 Mbps
        assert!(!t.completed);
        assert!(t.bytes_delivered < 100_000_000);
        assert!(t.bytes_delivered > 0);
        assert!(t.elapsed_s <= 0.5 + 1e-6);
    }

    #[test]
    fn byte_conservation() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::weak()), 3);
        for i in 0..50 {
            link.transmit(10_000 + i * 137, 1.0);
        }
        assert!(link.stats.bytes_delivered <= link.stats.bytes_offered);
        assert!(link.stats.packets_lost <= link.stats.packets_sent);
    }

    #[test]
    fn makersat_incident_loses_most_packets() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::makersat_incident()), 4);
        link.transmit(5_000_000, 1e9);
        let rate = link.stats.loss_rate();
        assert!(rate > 0.5, "loss rate {rate} should reflect the ~80% incident");
    }

    #[test]
    fn stationary_loss_matches_empirical_rate() {
        // Every packet attempt advances the Gilbert–Elliott chain exactly
        // one step, so the per-attempt loss rate — retransmissions
        // included — is an unbiased sample of the stationary loss.  Run
        // long lossy transfers with max_tries high enough that ARQ never
        // aborts, and the measured rate must land on the formula.
        for (seed, profile) in [
            (5u64, LossProfile::weak()),
            (6u64, LossProfile::makersat_incident()),
        ] {
            let mut link = Link::new(
                LinkConfig { rate_bps: 1e9, mtu: 1000, loss: profile, max_tries: 10_000 },
                seed,
            );
            for _ in 0..200 {
                let t = link.transmit(250_000, 1e12);
                assert!(t.completed, "max_tries=10000 must never abort");
            }
            assert!(link.stats.packets_sent >= 50_000, "{}", link.stats.packets_sent);
            let emp = link.stats.loss_rate();
            let th = profile.stationary_loss();
            assert!(
                (emp - th).abs() < 0.15 * th + 0.01,
                "empirical {emp} vs stationary {th} (seed {seed})"
            );
        }
    }

    #[test]
    fn lossless_profile_never_loses() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 9);
        let t = link.transmit(5_000_000, 1e9);
        assert!(t.completed);
        assert_eq!(link.stats.packets_lost, 0);
        assert_eq!(LossProfile::lossless().stationary_loss(), 0.0);
    }

    #[test]
    fn retransmissions_recover_when_loss_moderate() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::weak()), 6);
        let t = link.transmit(500_000, 60.0);
        assert!(t.completed, "weak link with ARQ should still deliver");
        assert_eq!(t.bytes_delivered, 500_000);
        assert!(link.stats.retransmissions > 0, "weak link should retransmit");
    }

    #[test]
    fn uplink_much_slower_than_downlink() {
        let mut up = Link::new(LinkConfig::uplink(LossProfile::stable()), 7);
        let mut down = Link::new(LinkConfig::downlink(LossProfile::stable()), 7);
        let tu = up.transmit(100_000, 1e9);
        let td = down.transmit(100_000, 1e9);
        assert!(tu.elapsed_s > 10.0 * td.elapsed_s);
    }

    #[test]
    fn stats_merge_adds_fields() {
        let mut a = LinkStats { bytes_offered: 10, ..Default::default() };
        let b = LinkStats { bytes_offered: 5, packets_sent: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.bytes_offered, 15);
        assert_eq!(a.packets_sent, 2);
    }
}
