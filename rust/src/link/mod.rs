//! Space-ground link simulator.
//!
//! Models what the paper's offload policy actually experiences: a
//! rate-limited downlink (Table 1: ≥40 Mbps down, 0.1–1 Mbps up) with
//! bursty packet loss (§II: "one satellite task lost 80% of its data
//! packets due to downlink instability", ref [12]) and stop-and-wait-ish
//! ARQ retransmission.  Byte accounting feeds the 90%-data-reduction
//! headline (H1 in DESIGN.md).
//!
//! Loss process: Gilbert–Elliott two-state Markov chain per packet —
//! the standard burst-loss model; a "good" state with near-zero loss and
//! a "bad" (deep-fade) state with high loss.

use crate::util::rng::Rng;

/// Gilbert–Elliott parameters.
#[derive(Clone, Copy, Debug)]
pub struct LossProfile {
    /// P(good -> bad) per packet.
    pub p_gb: f64,
    /// P(bad -> good) per packet.
    pub p_bg: f64,
    /// Loss probability in the good state.
    pub loss_good: f64,
    /// Loss probability in the bad state.
    pub loss_bad: f64,
}

impl LossProfile {
    /// Benign link: rare shallow fades.
    pub fn stable() -> LossProfile {
        LossProfile { p_gb: 0.001, p_bg: 0.2, loss_good: 0.001, loss_bad: 0.1 }
    }

    /// Weak-network scenario from §3.2 ("low bandwidth and serious packet
    /// loss").
    pub fn weak() -> LossProfile {
        LossProfile { p_gb: 0.02, p_bg: 0.1, loss_good: 0.01, loss_bad: 0.5 }
    }

    /// MakerSat-0-like incident (ref [12]): ~80% of packets lost.
    pub fn makersat_incident() -> LossProfile {
        LossProfile { p_gb: 0.5, p_bg: 0.05, loss_good: 0.3, loss_bad: 0.9 }
    }

    /// Ideal channel: no fades, no loss.  Used by parity tests and the
    /// `ideal_contact` constellation regime where the only difference
    /// from the single-satellite path should be the plumbing.
    pub fn lossless() -> LossProfile {
        LossProfile { p_gb: 0.0, p_bg: 1.0, loss_good: 0.0, loss_bad: 0.0 }
    }

    /// Stationary loss rate of the chain (sanity metric for tests).
    pub fn stationary_loss(&self) -> f64 {
        let p_bad = self.p_gb / (self.p_gb + self.p_bg);
        (1.0 - p_bad) * self.loss_good + p_bad * self.loss_bad
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub bytes_offered: u64,
    pub bytes_delivered: u64,
    pub packets_sent: u64,
    pub packets_lost: u64,
    pub retransmissions: u64,
    pub transfers_aborted: u64,
    pub busy_s: f64,
    /// Completed transfers whose frame arrived corrupted (receiver
    /// checksum failed; injected by the chaos engine).
    pub frames_corrupted: u64,
    /// Completed transfers whose frame arrived truncated (same
    /// receiver-side rejection path).
    pub frames_truncated: u64,
    /// Transfer-level ARQ retries after a rejected frame
    /// ([`Link::transmit_checked`]).
    pub retries: u64,
    /// Transfers the ARQ layer gave up on — retry budget or window
    /// budget exhausted with the frame still failing its checksum.
    pub gave_up: u64,
    /// Bytes that crossed the channel but failed the transfer checksum
    /// and were rejected by the receiver (moved out of
    /// `bytes_delivered`; the airtime stays in `busy_s`).
    pub bytes_rejected: u64,
}

impl LinkStats {
    pub fn loss_rate(&self) -> f64 {
        if self.packets_sent == 0 {
            0.0
        } else {
            self.packets_lost as f64 / self.packets_sent as f64
        }
    }

    pub fn goodput_bps(&self) -> f64 {
        if self.busy_s <= 0.0 {
            0.0
        } else {
            self.bytes_delivered as f64 * 8.0 / self.busy_s
        }
    }

    pub fn merge(&mut self, other: &LinkStats) {
        self.bytes_offered += other.bytes_offered;
        self.bytes_delivered += other.bytes_delivered;
        self.packets_sent += other.packets_sent;
        self.packets_lost += other.packets_lost;
        self.retransmissions += other.retransmissions;
        self.transfers_aborted += other.transfers_aborted;
        self.busy_s += other.busy_s;
        self.frames_corrupted += other.frames_corrupted;
        self.frames_truncated += other.frames_truncated;
        self.retries += other.retries;
        self.gave_up += other.gave_up;
        self.bytes_rejected += other.bytes_rejected;
    }
}

/// Receiver-side frame verdict an injector can return for a completed
/// transfer: the whole frame arrived, but its transfer checksum fails
/// (corrupted payload) or the byte count comes up short (truncated).
/// Either way the receiver rejects the bytes and the ARQ layer decides
/// whether to retry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameFault {
    Corrupt,
    Truncate,
}

/// Transfer-level ARQ retry policy for [`Link::transmit_checked`]:
/// capped exponential backoff between whole-transfer retries after a
/// rejected frame.  Retry `r` (0-based) waits
/// `min(backoff_initial_s * 2^r, backoff_cap_s)` of window time — the
/// channel is idle during backoff, so it costs budget but not `busy_s`.
#[derive(Clone, Copy, Debug)]
pub struct ArqPolicy {
    pub max_retries: u32,
    pub backoff_initial_s: f64,
    pub backoff_cap_s: f64,
}

impl ArqPolicy {
    pub fn backoff_s(&self, retry: u32) -> f64 {
        (self.backoff_initial_s * f64::powi(2.0, retry.min(62) as i32)).min(self.backoff_cap_s)
    }
}

/// Outcome of one transfer attempt.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub bytes_requested: u64,
    pub bytes_delivered: u64,
    pub elapsed_s: f64,
    pub completed: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    pub rate_bps: f64,
    pub mtu: usize,
    pub loss: LossProfile,
    /// Max (re)transmissions per packet before the transfer aborts.
    pub max_tries: u32,
}

impl LinkConfig {
    /// Table 1 downlink: ≥ 40 Mbps.
    pub fn downlink(loss: LossProfile) -> LinkConfig {
        LinkConfig { rate_bps: 40e6, mtu: 1400, loss, max_tries: 8 }
    }

    /// Table 1 uplink: 0.1–1 Mbps; model the midpoint.
    pub fn uplink(loss: LossProfile) -> LinkConfig {
        LinkConfig { rate_bps: 0.5e6, mtu: 512, loss, max_tries: 8 }
    }
}

/// Simulated half-duplex channel.
pub struct Link {
    pub cfg: LinkConfig,
    rng: Rng,
    in_bad_state: bool,
    pub stats: LinkStats,
}

impl Link {
    pub fn new(cfg: LinkConfig, seed: u64) -> Link {
        Link { cfg, rng: Rng::new(seed), in_bad_state: false, stats: LinkStats::default() }
    }

    fn packet_lost(&mut self) -> bool {
        // advance the Markov chain, then draw loss from the current state
        if self.in_bad_state {
            if self.rng.bool(self.cfg.loss.p_bg) {
                self.in_bad_state = false;
            }
        } else if self.rng.bool(self.cfg.loss.p_gb) {
            self.in_bad_state = true;
        }
        let p = if self.in_bad_state { self.cfg.loss.loss_bad } else { self.cfg.loss.loss_good };
        self.rng.bool(p)
    }

    /// Transfer `bytes` within a `budget_s` time budget (e.g. the rest of
    /// the current contact window).  Lost packets are retransmitted up to
    /// `max_tries`; ACK traffic is folded into the per-packet airtime.
    pub fn transmit(&mut self, bytes: u64, budget_s: f64) -> Transfer {
        self.stats.bytes_offered += bytes;
        let packet_time = self.cfg.mtu as f64 * 8.0 / self.cfg.rate_bps;
        let n_packets = bytes.div_ceil(self.cfg.mtu as u64).max(1);
        let mut elapsed = 0.0;
        let mut delivered: u64 = 0;
        for i in 0..n_packets {
            let payload = if i + 1 == n_packets {
                bytes - i * self.cfg.mtu as u64
            } else {
                self.cfg.mtu as u64
            };
            let mut tries = 0;
            loop {
                if elapsed + packet_time > budget_s {
                    self.stats.transfers_aborted += 1;
                    self.stats.busy_s += elapsed;
                    self.stats.bytes_delivered += delivered;
                    return Transfer {
                        bytes_requested: bytes,
                        bytes_delivered: delivered,
                        elapsed_s: elapsed,
                        completed: false,
                    };
                }
                elapsed += packet_time;
                tries += 1;
                self.stats.packets_sent += 1;
                if !self.packet_lost() {
                    delivered += payload;
                    break;
                }
                self.stats.packets_lost += 1;
                if tries >= self.cfg.max_tries {
                    self.stats.transfers_aborted += 1;
                    self.stats.busy_s += elapsed;
                    self.stats.bytes_delivered += delivered;
                    return Transfer {
                        bytes_requested: bytes,
                        bytes_delivered: delivered,
                        elapsed_s: elapsed,
                        completed: false,
                    };
                }
                self.stats.retransmissions += 1;
            }
        }
        self.stats.busy_s += elapsed;
        self.stats.bytes_delivered += delivered;
        Transfer { bytes_requested: bytes, bytes_delivered: delivered, elapsed_s: elapsed, completed: true }
    }

    /// [`Self::transmit`] with a receiver-side transfer checksum and
    /// transfer-level ARQ.  `inject` is consulted once per completed
    /// transfer attempt (the chaos engine's seeded fault stream; `None`
    /// = frame verifies).  A rejected frame moves its bytes from
    /// `bytes_delivered` to `bytes_rejected` — the airtime was genuinely
    /// spent, the payload was not received — then the transfer retries
    /// after capped exponential backoff until it verifies, the retry
    /// budget runs out, or the window budget cannot fit the backoff
    /// (`gave_up`).  With `inject` always returning `None` this is
    /// byte-for-byte `transmit`: one attempt, same RNG draws, same
    /// stats — the zero-fault lane of a chaos run stays bit-identical
    /// to a chaos-disabled run.
    ///
    /// Underlying packet-level failures (window budget or per-packet
    /// `max_tries` exhausted inside `transmit`) pass through unchanged:
    /// there is no complete frame to checksum and the packet layer
    /// already gave up, so the ARQ layer never masks them.
    pub fn transmit_checked(
        &mut self,
        bytes: u64,
        budget_s: f64,
        arq: &ArqPolicy,
        mut inject: impl FnMut() -> Option<FrameFault>,
    ) -> Transfer {
        let mut elapsed = 0.0;
        let mut retries_used = 0u32;
        loop {
            let t = self.transmit(bytes, budget_s - elapsed);
            elapsed += t.elapsed_s;
            if !t.completed {
                return Transfer {
                    bytes_requested: bytes,
                    bytes_delivered: t.bytes_delivered,
                    elapsed_s: elapsed,
                    completed: false,
                };
            }
            let Some(fault) = inject() else {
                return Transfer {
                    bytes_requested: bytes,
                    bytes_delivered: t.bytes_delivered,
                    elapsed_s: elapsed,
                    completed: true,
                };
            };
            match fault {
                FrameFault::Corrupt => self.stats.frames_corrupted += 1,
                FrameFault::Truncate => self.stats.frames_truncated += 1,
            }
            self.stats.bytes_delivered -= t.bytes_delivered;
            self.stats.bytes_rejected += t.bytes_delivered;
            let backoff = arq.backoff_s(retries_used);
            if retries_used >= arq.max_retries || elapsed + backoff >= budget_s {
                self.stats.gave_up += 1;
                return Transfer {
                    bytes_requested: bytes,
                    bytes_delivered: 0,
                    elapsed_s: elapsed,
                    completed: false,
                };
            }
            elapsed += backoff;
            self.stats.retries += 1;
            retries_used += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_transfer_completes_at_line_rate() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 1);
        let t = link.transmit(1_000_000, 10.0);
        assert!(t.completed);
        assert_eq!(t.bytes_delivered, 1_000_000);
        // 1 MB at 40 Mbps ≈ 0.2 s (+ packetization rounding)
        assert!((0.19..0.22).contains(&t.elapsed_s), "{}", t.elapsed_s);
    }

    #[test]
    fn budget_truncates_transfer() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::stable()), 2);
        let t = link.transmit(100_000_000, 0.5); // 100 MB into 0.5 s of 40 Mbps
        assert!(!t.completed);
        assert!(t.bytes_delivered < 100_000_000);
        assert!(t.bytes_delivered > 0);
        assert!(t.elapsed_s <= 0.5 + 1e-6);
    }

    #[test]
    fn byte_conservation() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::weak()), 3);
        for i in 0..50 {
            link.transmit(10_000 + i * 137, 1.0);
        }
        assert!(link.stats.bytes_delivered <= link.stats.bytes_offered);
        assert!(link.stats.packets_lost <= link.stats.packets_sent);
    }

    #[test]
    fn makersat_incident_loses_most_packets() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::makersat_incident()), 4);
        link.transmit(5_000_000, 1e9);
        let rate = link.stats.loss_rate();
        assert!(rate > 0.5, "loss rate {rate} should reflect the ~80% incident");
    }

    #[test]
    fn stationary_loss_matches_empirical_rate() {
        // Every packet attempt advances the Gilbert–Elliott chain exactly
        // one step, so the per-attempt loss rate — retransmissions
        // included — is an unbiased sample of the stationary loss.  Run
        // long lossy transfers with max_tries high enough that ARQ never
        // aborts, and the measured rate must land on the formula.
        for (seed, profile) in [
            (5u64, LossProfile::weak()),
            (6u64, LossProfile::makersat_incident()),
        ] {
            let mut link = Link::new(
                LinkConfig { rate_bps: 1e9, mtu: 1000, loss: profile, max_tries: 10_000 },
                seed,
            );
            for _ in 0..200 {
                let t = link.transmit(250_000, 1e12);
                assert!(t.completed, "max_tries=10000 must never abort");
            }
            assert!(link.stats.packets_sent >= 50_000, "{}", link.stats.packets_sent);
            let emp = link.stats.loss_rate();
            let th = profile.stationary_loss();
            assert!(
                (emp - th).abs() < 0.15 * th + 0.01,
                "empirical {emp} vs stationary {th} (seed {seed})"
            );
        }
    }

    #[test]
    fn lossless_profile_never_loses() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 9);
        let t = link.transmit(5_000_000, 1e9);
        assert!(t.completed);
        assert_eq!(link.stats.packets_lost, 0);
        assert_eq!(LossProfile::lossless().stationary_loss(), 0.0);
    }

    #[test]
    fn retransmissions_recover_when_loss_moderate() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::weak()), 6);
        let t = link.transmit(500_000, 60.0);
        assert!(t.completed, "weak link with ARQ should still deliver");
        assert_eq!(t.bytes_delivered, 500_000);
        assert!(link.stats.retransmissions > 0, "weak link should retransmit");
    }

    #[test]
    fn uplink_much_slower_than_downlink() {
        let mut up = Link::new(LinkConfig::uplink(LossProfile::stable()), 7);
        let mut down = Link::new(LinkConfig::downlink(LossProfile::stable()), 7);
        let tu = up.transmit(100_000, 1e9);
        let td = down.transmit(100_000, 1e9);
        assert!(tu.elapsed_s > 10.0 * td.elapsed_s);
    }

    #[test]
    fn stats_merge_adds_fields() {
        let mut a = LinkStats { bytes_offered: 10, ..Default::default() };
        let b = LinkStats {
            bytes_offered: 5,
            packets_sent: 2,
            frames_corrupted: 1,
            frames_truncated: 2,
            retries: 3,
            gave_up: 1,
            bytes_rejected: 400,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.bytes_offered, 15);
        assert_eq!(a.packets_sent, 2);
        assert_eq!(a.frames_corrupted, 1);
        assert_eq!(a.frames_truncated, 2);
        assert_eq!(a.retries, 3);
        assert_eq!(a.gave_up, 1);
        assert_eq!(a.bytes_rejected, 400);
    }

    #[test]
    fn empty_stats_rates_are_zero_not_nan() {
        // a chaos run can kill a link before its first transmit; rates
        // over zero frames / zero seconds must be 0.0, never NaN
        let s = LinkStats::default();
        assert_eq!(s.loss_rate(), 0.0);
        assert!(s.loss_rate().is_finite());
        assert_eq!(s.goodput_bps(), 0.0);
        assert!(s.goodput_bps().is_finite());
        // delivered bytes but no recorded airtime (degenerate merge
        // input) must not divide by zero either
        let odd = LinkStats { bytes_delivered: 4096, ..Default::default() };
        assert_eq!(odd.goodput_bps(), 0.0);
        let lossy = LinkStats { packets_lost: 3, ..Default::default() };
        assert_eq!(lossy.loss_rate(), 0.0);
    }

    fn no_fault() -> Option<FrameFault> {
        None
    }

    fn arq() -> ArqPolicy {
        ArqPolicy { max_retries: 4, backoff_initial_s: 0.05, backoff_cap_s: 1.0 }
    }

    #[test]
    fn checked_transmit_without_faults_is_bitwise_transmit() {
        // same seed, same offered sequence: the checked path with a
        // silent injector must reproduce plain transmit exactly —
        // stats, elapsed bits, and RNG stream position
        let mut plain = Link::new(LinkConfig::downlink(LossProfile::weak()), 11);
        let mut checked = Link::new(LinkConfig::downlink(LossProfile::weak()), 11);
        for i in 0..30u64 {
            let bytes = 5_000 + i * 997;
            let a = plain.transmit(bytes, 0.8);
            let b = checked.transmit_checked(bytes, 0.8, &arq(), no_fault);
            assert_eq!(a.bytes_delivered, b.bytes_delivered, "transfer {i}");
            assert_eq!(a.completed, b.completed, "transfer {i}");
            assert_eq!(a.elapsed_s.to_bits(), b.elapsed_s.to_bits(), "transfer {i}");
        }
        assert_eq!(plain.stats.bytes_delivered, checked.stats.bytes_delivered);
        assert_eq!(plain.stats.packets_sent, checked.stats.packets_sent);
        assert_eq!(plain.stats.packets_lost, checked.stats.packets_lost);
        assert_eq!(plain.stats.busy_s.to_bits(), checked.stats.busy_s.to_bits());
        assert_eq!(checked.stats.retries, 0);
        assert_eq!(checked.stats.gave_up, 0);
        assert_eq!(checked.stats.bytes_rejected, 0);
    }

    #[test]
    fn corrupt_frame_retries_then_delivers() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 12);
        let mut faults_left = 2u32;
        let t = link.transmit_checked(100_000, 60.0, &arq(), || {
            if faults_left > 0 {
                faults_left -= 1;
                Some(FrameFault::Corrupt)
            } else {
                None
            }
        });
        assert!(t.completed);
        assert_eq!(t.bytes_delivered, 100_000);
        assert_eq!(link.stats.retries, 2);
        assert_eq!(link.stats.frames_corrupted, 2);
        assert_eq!(link.stats.gave_up, 0);
        // the two rejected attempts moved out of delivered accounting
        assert_eq!(link.stats.bytes_rejected, 200_000);
        assert_eq!(link.stats.bytes_delivered, 100_000);
        // elapsed covers three airtimes plus the two backoffs
        let airtime = 3.0 * (100_000f64 / 1400.0).ceil() * 1400.0 * 8.0 / 40e6;
        let backoffs = 0.05 + 0.10;
        assert!((t.elapsed_s - airtime - backoffs).abs() < 1e-9, "{}", t.elapsed_s);
    }

    #[test]
    fn persistent_faults_exhaust_retries_and_give_up() {
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 13);
        let t = link.transmit_checked(50_000, 600.0, &arq(), || Some(FrameFault::Truncate));
        assert!(!t.completed);
        assert_eq!(t.bytes_delivered, 0);
        assert_eq!(link.stats.gave_up, 1);
        assert_eq!(link.stats.retries, 4);
        assert_eq!(link.stats.frames_truncated, 5); // initial attempt + 4 retries
        assert_eq!(link.stats.bytes_delivered, 0);
        assert_eq!(link.stats.bytes_rejected, 5 * 50_000);
    }

    #[test]
    fn arq_respects_window_budget() {
        // a tight window: the first rejection's backoff does not fit, so
        // the ARQ layer gives up instead of overrunning the contact
        let mut link = Link::new(LinkConfig::downlink(LossProfile::lossless()), 14);
        let airtime = (50_000f64 / 1400.0).ceil() * 1400.0 * 8.0 / 40e6;
        let budget = airtime + 0.01; // < airtime + backoff_initial_s
        let t = link.transmit_checked(50_000, budget, &arq(), || Some(FrameFault::Corrupt));
        assert!(!t.completed);
        assert_eq!(link.stats.gave_up, 1);
        assert_eq!(link.stats.retries, 0);
        assert!(t.elapsed_s <= budget + 1e-9);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = arq();
        assert_eq!(p.backoff_s(0), 0.05);
        assert_eq!(p.backoff_s(1), 0.10);
        assert_eq!(p.backoff_s(2), 0.20);
        assert_eq!(p.backoff_s(10), 1.0, "capped");
        assert_eq!(p.backoff_s(u32::MAX), 1.0, "no overflow at huge retry counts");
    }
}
