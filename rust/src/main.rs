//! tiansuan — leader entrypoint + CLI.
//!
//! Subcommands:
//!   serve                      continuous collaborative-inference loop
//!   report specs               Table 1 platform specifications
//!   report fig6                Fig 6 filter-rate sweep
//!   report fig7                Fig 7 in-orbit vs collaborative mAP
//!   report table2|table3       energy tables (duty-cycled simulation)
//!   report energy              the 17% computing-share headline
//!   report datared             the 90% data-reduction headline
//!   report windows             contact windows over 24 h
//!   report metrics             runtime metric registry dump
//!
//! Common options: --artifacts DIR --config FILE --scenes N --seed S
//!                 --frag PX --version v1|v2

use anyhow::{Context, Result};

use tiansuan::config::{baoyun_platform, chuangxingleishen_platform, Config};
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::energy::{EnergyMeter, Payload, Subsystem};
use tiansuan::orbit::{baoyun, beijing_station, contact_windows};
use tiansuan::runtime::Runtime;
use tiansuan::util::cli::Args;

fn main() {
    let args = Args::parse();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load(args: &Args) -> Result<(Runtime, Config)> {
    let dir = args.opt_or("artifacts", "artifacts");
    let rt = Runtime::open(dir).context("opening artifacts (run `make artifacts` first)")?;
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    if let Some(s) = args.opt("seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(f) = args.opt("frag") {
        cfg.fragment_px = f.parse()?;
    }
    if let Some(c) = args.opt("conf") {
        cfg.policy.confidence_threshold = c.parse()?;
    }
    Ok((rt, cfg))
}

fn version_of(args: &Args) -> Version {
    match args.opt_or("version", "v2") {
        "v1" => Version::V1,
        _ => Version::V2,
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => serve(args),
        Some("report") => match args.positional.first().map(|s| s.as_str()) {
            Some("specs") => report_specs(),
            Some("fig6") => report_fig6(args),
            Some("fig7") => report_fig7(args),
            Some("table2") => report_table2(args),
            Some("table3") => report_table3(args),
            Some("energy") => report_energy(args),
            Some("datared") => report_datared(args),
            Some("windows") => report_windows(),
            other => anyhow::bail!("unknown report {other:?} (see --help text in main.rs)"),
        },
        other => {
            println!("tiansuan — space-ground collaborative inference");
            println!("unknown or missing subcommand {other:?}; try: serve | report <specs|fig6|fig7|table2|table3|energy|datared|windows>");
            Ok(())
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let (rt, cfg) = load(args)?;
    let scenes = args.opt_usize("scenes", 8);
    println!("platform={} onboard batch={} artifacts ok", rt.platform(), rt.max_batch());
    rt.warmup()?;
    rt.calibrate()?; // cost-based batch planning (EXPERIMENTS.md §Perf)
    let pipeline = Pipeline::new(&rt, cfg);
    let version = version_of(args);
    let t0 = std::time::Instant::now();
    let r = pipeline.run_scenario(version, scenes)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {} scenes / {} tiles in {:.2}s wall ({:.1} tiles/s end-to-end, {:.1} tiles/s PJRT)",
        r.scenes,
        r.tiles_total,
        dt,
        r.tiles_total as f64 / dt,
        (r.tiles_total - r.tiles_filtered) as f64 / r.wall_infer_s.max(1e-9),
    );
    println!(
        "filtered {:.1}%  offloaded {:.1}%  mAP in-orbit {:.3} collab {:.3} (+{:.0}%)  data reduction {:.1}%",
        100.0 * r.filter_rate(),
        100.0 * r.router.offload_fraction(),
        r.map_inorbit,
        r.map_collab,
        100.0 * r.accuracy_improvement(),
        100.0 * r.data_reduction(),
    );
    Ok(())
}

fn report_specs() -> Result<()> {
    println!("Table 1 — satellite platform specifications");
    println!("{:<20} {:>10} {:>8} {:>8} {:>6} {:>28} {:>12} {:>10}",
             "Name", "Alt (km)", "Mass", "Load(U)", "Size", "OS", "Uplink", "Downlink");
    for p in [baoyun_platform(), chuangxingleishen_platform()] {
        println!(
            "{:<20} {:>10} {:>8} {:>8} {:>6} {:>28} {:>12} {:>10}",
            p.name,
            format!("{}±50", p.orbital_altitude_km),
            p.mass_kg,
            p.load_size_u,
            p.size_u,
            p.operating_system,
            format!("{}~{} Mbps", p.uplink_mbps.0, p.uplink_mbps.1),
            format!("≥{} Mbps", p.downlink_mbps),
        );
    }
    Ok(())
}

fn report_fig6(args: &Args) -> Result<()> {
    let (rt, cfg) = load(args)?;
    let scenes = args.opt_usize("scenes", 6);
    println!("Fig 6 — filter rate of redundant data in orbit (SynthDOTA)");
    println!("{:<10} {:>10} {:>14} {:>12}", "version", "frag(px)", "tiles", "filter rate");
    for version in [Version::V1, Version::V2] {
        for frag in [32usize, 64, 128] {
            let mut c = cfg.clone();
            c.fragment_px = frag;
            let p = Pipeline::new(&rt, c);
            let r = p.run_scenario(version, scenes)?;
            println!(
                "{:<10} {:>10} {:>14} {:>11.1}%",
                version.name(),
                frag,
                r.tiles_total,
                100.0 * r.filter_rate()
            );
        }
    }
    println!("(paper: ≈90% for DOTA-v1-like, ≈40% for v2-like, invariant to fragment size)");
    Ok(())
}

fn report_fig7(args: &Args) -> Result<()> {
    let (rt, cfg) = load(args)?;
    let scenes = args.opt_usize("scenes", 10);
    println!("Fig 7 — accuracy (mAP) of in-orbit vs collaborative inference");
    println!("{:<10} {:>12} {:>12} {:>14}", "scenario", "in-orbit", "collab", "improvement");
    let mut impr = Vec::new();
    for version in [Version::V1, Version::V2] {
        let p = Pipeline::new(&rt, cfg.clone());
        let r = p.run_scenario(version, scenes)?;
        impr.push(r.accuracy_improvement());
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>13.0}%",
            version.name(),
            r.map_inorbit,
            r.map_collab,
            100.0 * r.accuracy_improvement()
        );
    }
    println!(
        "average improvement {:.0}% (paper: +44%/+52%, ≈50% average)",
        100.0 * impr.iter().sum::<f64>() / impr.len() as f64
    );
    Ok(())
}

fn simulated_meter(args: &Args) -> Result<(EnergyMeter, f64)> {
    let (rt, cfg) = load(args)?;
    let p = Pipeline::new(&rt, cfg);
    let scenes = args.opt_usize("scenes", 6);
    let r = p.run_scenario(version_of(args), scenes)?;
    // integrate two orbits at the measured duty cycle; comm duty from
    // Beijing contact windows over a day (~8 min / day typical)
    let windows = contact_windows(&baoyun(), &beijing_station(), 0.0, 86_400.0, 10.0);
    let contact_s: f64 = windows.iter().map(|w| w.duration_s()).sum();
    let comm_duty = contact_s / 86_400.0;
    let mut m = EnergyMeter::new();
    m.advance(2.0 * baoyun().period_s(), r.compute_duty, comm_duty, 0.1);
    Ok((m, r.compute_duty))
}

fn report_table2(args: &Args) -> Result<()> {
    let (m, _) = simulated_meter(args)?;
    println!("Table 2 — power distribution, duty-cycled simulation (W)");
    println!("{:<14} {:>10} {:>12}", "Item", "Power(W)", "paper (W)");
    let paper = [1.47, 7.00, 5.43, 4.81, 5.43, 26.93];
    for (s, want) in Subsystem::all().iter().zip(paper) {
        let w = m.platform_j(*s) / m.elapsed_s;
        println!("{:<14} {:>10.2} {:>12.2}", s.name(), w, want);
    }
    println!("{:<14} {:>10.2} {:>12.2}", "Sum", m.platform_total_j() / m.elapsed_s, 51.07);
    Ok(())
}

fn report_table3(args: &Args) -> Result<()> {
    let (m, _) = simulated_meter(args)?;
    println!("Table 3 — payload power, duty-cycled simulation (W)");
    println!("{:<14} {:>10} {:>12}", "Item", "Power(W)", "paper (W)");
    let paper = [0.09, 6.26, 5.68, 0.95, 6.12, 8.78];
    for (p, want) in Payload::all().iter().zip(paper) {
        let w = m.payload_j(*p) / m.elapsed_s;
        println!("{:<14} {:>10.2} {:>12.2}", p.name(), w, want);
    }
    Ok(())
}

fn report_energy(args: &Args) -> Result<()> {
    let (m, duty) = simulated_meter(args)?;
    println!(
        "computing share of onboard energy: {:.1}% (paper ≈17%); share of payloads: {:.1}% (paper ≈33%); onboard compute duty {:.2}",
        100.0 * m.compute_share(),
        100.0 * m.compute_share_of_payloads(),
        duty,
    );
    Ok(())
}

fn report_datared(args: &Args) -> Result<()> {
    let (rt, cfg) = load(args)?;
    let scenes = args.opt_usize("scenes", 8);
    let p = Pipeline::new(&rt, cfg);
    let r = p.run_scenario(version_of(args), scenes)?;
    println!(
        "bent-pipe bytes {}  collaborative bytes {}  reduction {:.1}% (paper: 90%)",
        r.bentpipe_bytes,
        r.collab_bytes,
        100.0 * r.data_reduction()
    );
    Ok(())
}

fn report_windows() -> Result<()> {
    let sat = baoyun();
    let gs = beijing_station();
    let windows = contact_windows(&sat, &gs, 0.0, 86_400.0, 10.0);
    println!("contact windows, {} over {} in 24 h:", windows.len(), gs.name);
    for w in &windows {
        println!(
            "  aos {:>8.1}s  los {:>8.1}s  dur {:>5.1}s  max elev {:>5.1}°",
            w.aos,
            w.los,
            w.duration_s(),
            w.max_elevation_deg
        );
    }
    Ok(())
}
