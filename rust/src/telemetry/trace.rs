//! Mission flight recorder: deterministic virtual-time spans and events.
//!
//! The paper's platform layer must "monitor and manage the operational
//! status and applications" in orbit (§3.1).  End-of-run report structs
//! answer *what* happened; this module records *why*: every governed
//! shed, skipped round, drained slice, and dropped byte becomes a typed
//! [`TraceRecord`] keyed by **mission time** — no wallclock anywhere —
//! so a trace is a deterministic function of config + seed.
//!
//! Recording discipline (the same pinned-ordering argument as
//! [`crate::sim::fleet`]):
//!
//! * Each shard worker appends to its own bounded ring buffer behind an
//!   uncontended per-shard mutex ([`TraceSink`]).  A satellite's records
//!   all land in its owning shard, in the satellite's own mission order
//!   (shard workers step each machine's events in virtual-time order).
//! * At the post-join barrier, [`TraceSink::merge`] concatenates the
//!   rings and **stably** sorts by `(t_start, sat_id, kind)`.  The key
//!   orders records of *different* satellites totally; records of the
//!   *same* satellite that tie on the key keep their per-satellite
//!   emission order under the stable sort — which is the satellite's
//!   mission order regardless of which shard held them.  The merged
//!   stream is therefore **bit-identical across shard counts and
//!   admission caps** (pinned by `tests/trace_determinism.rs`), as long
//!   as no ring evicted (eviction is per-shard and shard populations
//!   differ with the shard count; [`TraceLog::evicted`] reports it).
//!
//! Export: JSONL (one [`crate::util::json::Json`] object per line) and
//! the Chrome `trace_event` array format, so a mission renders as a
//! flamegraph in `chrome://tracing` / Perfetto with one track (`tid`)
//! per satellite.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// What kind of mission activity a record describes.  The discriminant
/// is the final tie-break of the merge ordering, so it is explicit and
/// frozen — reordering variants would reorder merged traces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Scene capture (span: capture time → capture + overhead).
    Capture = 0,
    /// Cloud filter outcome for a scene (event; payload = tiles kept).
    Filter = 1,
    /// Onboard inference over a scene's kept tiles (span over busy time).
    OnboardInfer = 2,
    /// Ground re-inference of delivered tiles (event at delivery).
    GroundInfer = 3,
    /// One contact-window drain slice (span: slice start → end).
    DownlinkSlice = 4,
    /// Federated round (span: due → due + training burst).
    TrainingRound = 5,
    /// Governor shed a capture (event; payload = SoC).
    Shed = 6,
    /// Governor deferred downlink drains (event; payload = SoC).
    Defer = 7,
    /// Downlink queue dropped bytes after repeated window failures.
    Drop = 8,
    /// Chaos: a capture lost while the satellite was dark (event at the
    /// capture instant).  Appended after the original kinds —
    /// discriminants are frozen, so chaos-off traces keep their exact
    /// pre-chaos bytes and ordering.
    FaultCrash = 9,
    /// Chaos: ARQ rejected corrupt/truncated frame bytes during a drain
    /// slice (event at LOS; payload = bytes rejected over the slice).
    FaultFrame = 10,
    /// Chaos: SEU bit-flips struck a checked-out pixel buffer (event at
    /// capture; payload = flips applied).
    FaultSeu = 11,
    /// Chaos: a contact-slice heartbeat suppressed by a registry
    /// dropout (event at AOS; the drain itself proceeds).
    FaultDropout = 12,
}

impl SpanKind {
    /// Every kind in discriminant order — the per-kind summary order.
    pub const ALL: [SpanKind; 13] = [
        SpanKind::Capture,
        SpanKind::Filter,
        SpanKind::OnboardInfer,
        SpanKind::GroundInfer,
        SpanKind::DownlinkSlice,
        SpanKind::TrainingRound,
        SpanKind::Shed,
        SpanKind::Defer,
        SpanKind::Drop,
        SpanKind::FaultCrash,
        SpanKind::FaultFrame,
        SpanKind::FaultSeu,
        SpanKind::FaultDropout,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Capture => "capture",
            SpanKind::Filter => "filter",
            SpanKind::OnboardInfer => "onboard_infer",
            SpanKind::GroundInfer => "ground_infer",
            SpanKind::DownlinkSlice => "downlink_slice",
            SpanKind::TrainingRound => "training_round",
            SpanKind::Shed => "shed",
            SpanKind::Defer => "defer",
            SpanKind::Drop => "drop",
            SpanKind::FaultCrash => "fault_crash",
            SpanKind::FaultFrame => "fault_frame",
            SpanKind::FaultSeu => "fault_seu",
            SpanKind::FaultDropout => "fault_dropout",
        }
    }
}

/// Outcome of a federated round, for [`TracePayload::Verdict`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundVerdict {
    Participated,
    SkippedPower,
    /// The satellite was dark (chaos `NodeCrash`) when the round came
    /// due: no training, no uplink, its own skip class.
    SkippedCrash,
}

impl RoundVerdict {
    pub fn name(self) -> &'static str {
        match self {
            RoundVerdict::Participated => "participated",
            RoundVerdict::SkippedPower => "skipped_power",
            RoundVerdict::SkippedCrash => "skipped_crash",
        }
    }
}

/// Small typed payload carried by a record.  One variant per question
/// the chaos/serving layers will ask of a trace; deliberately not a
/// grab-bag map, so records stay `Copy` and rings stay flat.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TracePayload {
    None,
    /// Bytes moved (downlink slices) or lost (drops).
    Bytes(u64),
    /// Bytes moved through a specific ground station (tagged downlink
    /// slices in a multi-station mission).
    StationBytes { station: u32, bytes: u64 },
    /// Battery state of charge, integer percent.
    Soc(i64),
    /// Tile / batch count.
    Batch(usize),
    /// Federated round outcome.
    Verdict(RoundVerdict),
}

/// One span or instantaneous event in mission time.  Events are spans
/// with `t_end == t_start`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    pub kind: SpanKind,
    pub sat_id: usize,
    /// Virtual mission seconds.
    pub t_start: f64,
    pub t_end: f64,
    pub payload: TracePayload,
}

impl TraceRecord {
    fn payload_pairs(&self, pairs: &mut Vec<(&'static str, Json)>) {
        match self.payload {
            TracePayload::None => {}
            TracePayload::Bytes(b) => pairs.push(("bytes", Json::num(b as f64))),
            TracePayload::StationBytes { station, bytes } => {
                pairs.push(("bytes", Json::num(bytes as f64)));
                pairs.push(("station", Json::num(station as f64)));
            }
            TracePayload::Soc(p) => pairs.push(("soc_pct", Json::num(p as f64))),
            TracePayload::Batch(n) => pairs.push(("batch", Json::num(n as f64))),
            TracePayload::Verdict(v) => pairs.push(("verdict", Json::str(v.name()))),
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(self.kind.name())),
            ("sat", Json::num(self.sat_id as f64)),
            ("t0", Json::num(self.t_start)),
            ("t1", Json::num(self.t_end)),
        ];
        self.payload_pairs(&mut pairs);
        Json::obj(pairs)
    }

    /// Chrome `trace_event` complete event: `ts`/`dur` in microseconds,
    /// one `tid` track per satellite.
    fn to_chrome(&self) -> Json {
        let mut args = Vec::new();
        self.payload_pairs(&mut args);
        Json::obj(vec![
            ("name", Json::str(self.kind.name())),
            ("cat", Json::str("mission")),
            ("ph", Json::str("X")),
            ("ts", Json::num(self.t_start * 1e6)),
            ("dur", Json::num((self.t_end - self.t_start).max(0.0) * 1e6)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(self.sat_id as f64)),
            ("args", Json::obj(args)),
        ])
    }
}

struct Ring {
    buf: VecDeque<TraceRecord>,
    evicted: u64,
}

/// Per-shard bounded ring buffers for trace records.  "Lock-free-ish":
/// each ring sits behind its own mutex, and by construction only the
/// shard worker that owns those satellites writes to it — the lock is
/// uncontended until the single merge at the post-join barrier.
pub struct TraceSink {
    shards: Vec<Mutex<Ring>>,
    ring_cap: usize,
}

impl TraceSink {
    /// `shards` ring buffers, each holding at most `ring_cap` records
    /// (oldest evicted first, counted in [`TraceLog::evicted`]).
    pub fn new(shards: usize, ring_cap: usize) -> TraceSink {
        assert!(shards >= 1, "trace sink needs at least one shard");
        assert!(ring_cap >= 1, "trace ring cap must be at least 1");
        TraceSink {
            shards: (0..shards)
                .map(|_| Mutex::new(Ring { buf: VecDeque::new(), evicted: 0 }))
                .collect(),
            ring_cap,
        }
    }

    /// A recording handle for one satellite, writing to `shard`'s ring.
    /// All of a satellite's records must go through one tracer (= one
    /// shard) or the merge-order guarantee above does not hold.
    pub fn tracer(self: &Arc<Self>, shard: usize, sat_id: usize) -> SatTracer {
        SatTracer { sink: Arc::clone(self), shard: shard % self.shards.len(), sat_id }
    }

    fn record(&self, shard: usize, rec: TraceRecord) {
        let mut ring = self.shards[shard].lock().unwrap();
        if ring.buf.len() == self.ring_cap {
            ring.buf.pop_front();
            ring.evicted += 1;
        }
        ring.buf.push_back(rec);
    }

    /// Barrier merge: concatenate every ring, then **stable**-sort by
    /// `(t_start, sat_id, kind)` (`total_cmp` on time, like the event
    /// scheduler).  See the module doc for why the result is invariant
    /// under shard count whenever `evicted == 0`.
    pub fn merge(&self) -> TraceLog {
        let mut records = Vec::new();
        let mut evicted = 0u64;
        for s in &self.shards {
            let ring = s.lock().unwrap();
            records.extend(ring.buf.iter().copied());
            evicted += ring.evicted;
        }
        records.sort_by(|a, b| {
            a.t_start
                .total_cmp(&b.t_start)
                .then_with(|| a.sat_id.cmp(&b.sat_id))
                .then_with(|| (a.kind as u8).cmp(&(b.kind as u8)))
        });
        TraceLog { records, evicted }
    }
}

/// A satellite's recording handle: cheap to clone, `None`-able at every
/// instrumentation site (tracing disabled ⇒ the `Option` is `None` and
/// the site costs one predictable branch).
#[derive(Clone)]
pub struct SatTracer {
    sink: Arc<TraceSink>,
    shard: usize,
    sat_id: usize,
}

impl SatTracer {
    pub fn span(&self, kind: SpanKind, t_start: f64, t_end: f64, payload: TracePayload) {
        self.sink.record(
            self.shard,
            TraceRecord { kind, sat_id: self.sat_id, t_start, t_end, payload },
        );
    }

    /// Instantaneous event: a span with `t_end == t_start`.
    pub fn event(&self, kind: SpanKind, t: f64, payload: TracePayload) {
        self.span(kind, t, t, payload);
    }

    pub fn sat_id(&self) -> usize {
        self.sat_id
    }
}

/// The merged, `(time, sat_id, kind)`-sorted trace of a mission.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    records: Vec<TraceRecord>,
    evicted: u64,
}

impl TraceLog {
    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records lost to ring eviction across all shards.  Non-zero means
    /// the trace is a suffix-ish sample, and shard-count invariance no
    /// longer holds — raise `trace.ring_cap`.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Count per kind, in [`SpanKind::ALL`] order (zeros included, so
    /// summaries are fixed-shape).
    pub fn kind_counts(&self) -> Vec<(SpanKind, usize)> {
        let mut counts = [0usize; SpanKind::ALL.len()];
        for r in &self.records {
            counts[r.kind as usize] += 1;
        }
        SpanKind::ALL.iter().copied().zip(counts).collect()
    }

    /// One JSON object per line, in merged order — the byte stream the
    /// determinism test pins.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Chrome `trace_event` JSON array for chrome://tracing / Perfetto.
    pub fn to_chrome(&self) -> String {
        Json::Arr(self.records.iter().map(|r| r.to_chrome()).collect()).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: SpanKind, sat: usize, t0: f64, t1: f64) -> TraceRecord {
        TraceRecord { kind, sat_id: sat, t_start: t0, t_end: t1, payload: TracePayload::None }
    }

    #[test]
    fn merge_sorts_by_time_sat_kind() {
        let sink = Arc::new(TraceSink::new(2, 64));
        let a = sink.tracer(0, 0);
        let b = sink.tracer(1, 1);
        b.event(SpanKind::Capture, 10.0, TracePayload::None);
        a.event(SpanKind::Capture, 10.0, TracePayload::None);
        a.event(SpanKind::Filter, 10.0, TracePayload::None);
        a.event(SpanKind::Capture, 5.0, TracePayload::None);
        let log = sink.merge();
        let keys: Vec<(f64, usize, SpanKind)> =
            log.records().iter().map(|r| (r.t_start, r.sat_id, r.kind)).collect();
        assert_eq!(
            keys,
            vec![
                (5.0, 0, SpanKind::Capture),
                (10.0, 0, SpanKind::Capture),
                (10.0, 0, SpanKind::Filter),
                (10.0, 1, SpanKind::Capture),
            ]
        );
        assert_eq!(log.evicted(), 0);
    }

    #[test]
    fn same_key_records_keep_emission_order() {
        // Two records of one satellite with identical (t, kind) must
        // keep their emission order (stable sort): payloads tell them
        // apart.
        let sink = Arc::new(TraceSink::new(1, 64));
        let t = sink.tracer(0, 3);
        t.event(SpanKind::Drop, 7.0, TracePayload::Bytes(1));
        t.event(SpanKind::Drop, 7.0, TracePayload::Bytes(2));
        let log = sink.merge();
        assert_eq!(log.records()[0].payload, TracePayload::Bytes(1));
        assert_eq!(log.records()[1].payload, TracePayload::Bytes(2));
    }

    #[test]
    fn ring_eviction_drops_oldest_and_counts() {
        let sink = Arc::new(TraceSink::new(1, 2));
        let t = sink.tracer(0, 0);
        t.event(SpanKind::Capture, 1.0, TracePayload::None);
        t.event(SpanKind::Capture, 2.0, TracePayload::None);
        t.event(SpanKind::Capture, 3.0, TracePayload::None);
        let log = sink.merge();
        assert_eq!(log.len(), 2);
        assert_eq!(log.evicted(), 1);
        assert_eq!(log.records()[0].t_start, 2.0, "oldest record evicted first");
    }

    #[test]
    fn jsonl_format_is_stable() {
        let sink = Arc::new(TraceSink::new(1, 8));
        let t = sink.tracer(0, 2);
        t.span(SpanKind::DownlinkSlice, 100.0, 160.5, {
            TracePayload::StationBytes { station: 1, bytes: 4096 }
        });
        t.event(SpanKind::Shed, 200.0, TracePayload::Soc(19));
        t.event(SpanKind::Drop, 300.0, TracePayload::Bytes(512));
        let log = sink.merge();
        assert_eq!(
            log.to_jsonl(),
            "{\"bytes\":4096,\"kind\":\"downlink_slice\",\"sat\":2,\"station\":1,\"t0\":100,\"t1\":160.5}\n\
             {\"kind\":\"shed\",\"sat\":2,\"soc_pct\":19,\"t0\":200,\"t1\":200}\n\
             {\"bytes\":512,\"kind\":\"drop\",\"sat\":2,\"t0\":300,\"t1\":300}\n"
        );
    }

    #[test]
    fn chrome_export_is_valid_json_with_one_track_per_sat() {
        let sink = Arc::new(TraceSink::new(2, 8));
        sink.tracer(0, 0).span(SpanKind::Capture, 0.0, 2.0, TracePayload::Batch(64));
        sink.tracer(1, 1).span(SpanKind::TrainingRound, 900.0, 930.0, {
            TracePayload::Verdict(RoundVerdict::Participated)
        });
        let log = sink.merge();
        let parsed = Json::parse(&log.to_chrome()).unwrap();
        let events = parsed.as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("tid").unwrap().as_f64(), Some(0.0));
        assert_eq!(events[0].get("dur").unwrap().as_f64(), Some(2e6));
        assert_eq!(events[1].get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            events[1].get("args").unwrap().get("verdict").unwrap().as_str(),
            Some("participated")
        );
    }

    #[test]
    fn kind_counts_are_fixed_shape() {
        let sink = Arc::new(TraceSink::new(1, 8));
        let t = sink.tracer(0, 0);
        t.event(SpanKind::Capture, 1.0, TracePayload::None);
        t.event(SpanKind::Capture, 2.0, TracePayload::None);
        t.event(SpanKind::Drop, 3.0, TracePayload::Bytes(9));
        let counts = sink.merge().kind_counts();
        assert_eq!(counts.len(), SpanKind::ALL.len());
        assert_eq!(counts[0], (SpanKind::Capture, 2));
        assert_eq!(counts[8], (SpanKind::Drop, 1));
        assert_eq!(counts[5], (SpanKind::TrainingRound, 0), "zero kinds still listed");
    }

    #[test]
    fn fault_kinds_are_appended_with_frozen_discriminants() {
        // chaos kinds extend the enum strictly after the original nine:
        // a chaos-off trace's merge ordering (which ties on kind last)
        // cannot change
        assert_eq!(SpanKind::Drop as u8, 8);
        assert_eq!(SpanKind::FaultCrash as u8, 9);
        assert_eq!(SpanKind::FaultFrame as u8, 10);
        assert_eq!(SpanKind::FaultSeu as u8, 11);
        assert_eq!(SpanKind::FaultDropout as u8, 12);
        assert_eq!(SpanKind::ALL.len(), 13);
        assert_eq!(SpanKind::FaultCrash.name(), "fault_crash");
        assert_eq!(SpanKind::FaultFrame.name(), "fault_frame");
        assert_eq!(SpanKind::FaultSeu.name(), "fault_seu");
        assert_eq!(SpanKind::FaultDropout.name(), "fault_dropout");
        assert_eq!(RoundVerdict::SkippedCrash.name(), "skipped_crash");
        // fault records serialize through the same stable jsonl shape
        let sink = Arc::new(TraceSink::new(1, 8));
        let t = sink.tracer(0, 4);
        t.span(SpanKind::FaultCrash, 500.0, 1100.0, TracePayload::Batch(2));
        t.event(SpanKind::FaultFrame, 520.0, TracePayload::Bytes(1400));
        assert_eq!(
            sink.merge().to_jsonl(),
            "{\"batch\":2,\"kind\":\"fault_crash\",\"sat\":4,\"t0\":500,\"t1\":1100}\n\
             {\"bytes\":1400,\"kind\":\"fault_frame\",\"sat\":4,\"t0\":520,\"t1\":520}\n"
        );
    }

    #[test]
    fn merged_stream_invariant_under_shard_split() {
        // The same per-sat record streams pushed through 1-shard and
        // 3-shard sinks must merge to the identical byte stream.
        let emit = |sink: &Arc<TraceSink>, shards: usize| {
            for sat in 0..6usize {
                let t = sink.tracer(sat % shards, sat);
                for i in 0..5 {
                    let at = (i * (sat + 1)) as f64;
                    t.event(SpanKind::Capture, at, TracePayload::Batch(i));
                    t.event(SpanKind::Filter, at, TracePayload::Batch(i / 2));
                }
            }
        };
        let one = Arc::new(TraceSink::new(1, 1024));
        emit(&one, 1);
        let three = Arc::new(TraceSink::new(3, 1024));
        emit(&three, 3);
        assert_eq!(one.merge().to_jsonl(), three.merge().to_jsonl());
    }
}
