//! Telemetry: counters, gauges, histograms, fleet digests + fixed-format
//! report text.
//!
//! The paper's satellites "monitor and manage the operational status and
//! applications" (§3.1); every pipeline stage and substrate reports here.
//! Thread-safe via atomics/mutex so worker threads can record freely.
//! Two cardinality regimes coexist: at small fleet sizes every satellite
//! keeps its exact `.<node>`-suffixed gauges ([`per_node_gauges_enabled`],
//! `telemetry.per_node_limit`); past the cutoff, per-satellite values
//! stream into fixed-size [`Digest`] aggregates instead, so a 100k-sat
//! run renders a bounded metric set.  [`trace`] is the mission flight
//! recorder (virtual-time spans/events) built on the same registry-free
//! discipline.

pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, in-flight batches).  Stored as i64
/// so transient dec-before-inc races in relaxed code can't wrap.
#[derive(Default)]
pub struct Gauge(std::sync::atomic::AtomicI64);

impl Gauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Streaming histogram with fixed log-spaced buckets (µs-scale latencies
/// up to minutes) plus exact count/sum for means.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds: Vec<f64>,
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Default range: 1 µs .. ~17.9 min in 31 log2 buckets — right for
    /// wallclock service latencies, far too short for virtual-time
    /// observations (a contact pass runs minutes, a mission tail hours).
    /// Use [`Histogram::with_range`] for those.
    pub fn new() -> Histogram {
        Self::with_range(1e-6, 31)
    }

    /// Log2 buckets starting at `first_bound_s` seconds: bucket `i`'s
    /// upper bound is `first_bound_s * 2^i`, for `n_buckets` bounds plus
    /// one overflow bucket.  `with_range(1e-6, 31)` is `new()` exactly.
    /// Virtual-time histograms use e.g. `with_range(1e-3, 40)` (1 ms ..
    /// ~17 years), so multi-hour spans resolve instead of saturating the
    /// overflow bucket.
    pub fn with_range(first_bound_s: f64, n_buckets: usize) -> Histogram {
        assert!(
            first_bound_s > 0.0 && first_bound_s.is_finite(),
            "histogram first bound must be positive"
        );
        assert!(n_buckets >= 1, "histogram needs at least one bucket");
        let first_us = first_bound_s * 1e6;
        let bounds: Vec<f64> = (0..n_buckets as i32).map(|i| first_us * 2f64.powi(i)).collect();
        Histogram {
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            bounds,
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }

    /// Fold another histogram's observations into this one (the fleet
    /// barrier merging per-shard admission-wait histograms).  Bucket
    /// layouts must match; counts add, so merging in any order renders
    /// identically.
    pub fn merge(&self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "histogram merge requires identical bucket bounds"
        );
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_micros.fetch_add(other.sum_micros.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_micros.fetch_max(other.max_micros.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn observe_secs(&self, secs: f64) {
        let us = (secs * 1e6).max(0.0);
        let idx = self.bounds.partition_point(|&b| b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us as u64, Ordering::Relaxed);
        self.max_micros.fetch_max(us as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_micros.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
        }
    }

    pub fn max_secs(&self) -> f64 {
        self.max_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile from bucket boundaries (upper bound).  A
    /// bucket's upper bound can overshoot the largest value actually
    /// observed (a single 3 ms sample lands in the 4.096 ms bucket), so
    /// every per-bucket answer — including the overflow bucket's — is
    /// clamped to [`Histogram::max_secs`]: a quantile never exceeds the
    /// true maximum, and p50 of a single observation *is* that
    /// observation (to µs resolution).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                let upper_s = self.bounds.get(i).map(|b| b / 1e6).unwrap_or(f64::INFINITY);
                return upper_s.min(self.max_secs());
            }
        }
        self.max_secs()
    }
}

/// Fleet-scale streaming aggregate: one `Digest` summarizes an
/// i64-valued metric *across satellites* (one observation per node) in
/// fixed space, replacing unbounded `.<node>`-suffixed gauge families
/// past the `telemetry.per_node_limit` cutoff.  min/mean/max are exact;
/// p50/p99 come from log2 buckets clamped to the observed range.  All
/// state is atomic and every update commutes (adds, min, max), so
/// concurrent observation from shard workers renders identically
/// regardless of arrival order — digests are barrier-merge deterministic
/// by construction.
pub struct Digest {
    /// Bucket 0: values ≤ 0; bucket i ≥ 1: `2^(i-1) <= v < 2^i`, with
    /// values ≥ 2^31 clamped into the last bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicI64,
    min: AtomicI64,
    max: AtomicI64,
}

const DIGEST_BUCKETS: usize = 33;

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

impl Digest {
    pub fn new() -> Digest {
        Digest {
            buckets: (0..DIGEST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicI64::new(0),
            min: AtomicI64::new(i64::MAX),
            max: AtomicI64::new(i64::MIN),
        }
    }

    pub fn observe(&self, v: i64) {
        let idx = if v <= 0 {
            0
        } else {
            (64 - (v as u64).leading_zeros() as usize).min(DIGEST_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn min(&self) -> i64 {
        if self.count() == 0 {
            0
        } else {
            self.min.load(Ordering::Relaxed)
        }
    }

    pub fn max(&self) -> i64 {
        if self.count() == 0 {
            0
        } else {
            self.max.load(Ordering::Relaxed)
        }
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile: the target bucket's upper bound, clamped to
    /// the exact observed `[min, max]` — so a single-observation digest
    /// reports that observation at every quantile.
    pub fn quantile(&self, q: f64) -> i64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                let upper = if idx == 0 { 0 } else { (1i64 << idx) - 1 };
                return upper.clamp(self.min(), self.max());
            }
        }
        self.max()
    }
}

/// Whether per-satellite `.<node>`-suffixed gauges should be registered
/// at this fleet size.  At or below the limit (inclusive — exactly
/// `per_node_limit` satellites still get exact gauges) the pre-digest
/// output is preserved bit-for-bit; above it only [`Digest`] aggregates
/// are recorded, so telemetry cardinality stays fixed as fleets scale to
/// 100k satellites.
pub fn per_node_gauges_enabled(n_sats: usize, per_node_limit: usize) -> bool {
    n_sats <= per_node_limit
}

/// Named metric registry.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    digests: Mutex<BTreeMap<String, std::sync::Arc<Digest>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Like [`Registry::histogram`] but a first registration uses the
    /// given [`Histogram::with_range`] layout — for virtual-time metrics
    /// whose spans run hours.  A name already registered keeps its
    /// existing layout (callers must agree, like they must on units).
    pub fn histogram_with_range(
        &self,
        name: &str,
        first_bound_s: f64,
        n_buckets: usize,
    ) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::with_range(first_bound_s, n_buckets)))
            .clone()
    }

    pub fn digest(&self, name: &str) -> std::sync::Arc<Digest> {
        self.digests
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Render all metrics as stable, sorted text (for logs + tests).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, d) in self.digests.lock().unwrap().iter() {
            out.push_str(&format!(
                "digest {name} n={} min={} mean={:.3} max={} p50={} p99={}\n",
                d.count(),
                d.min(),
                d.mean(),
                d.max(),
                d.quantile(0.5),
                d.quantile(0.99)
            ));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "histogram {name} count={} mean={:.6}s p50={:.6}s p99={:.6}s max={:.6}s\n",
                h.count(),
                h.mean_secs(),
                h.quantile_secs(0.5),
                h.quantile_secs(0.99),
                h.max_secs()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_mean_and_max() {
        let h = Histogram::new();
        h.observe_secs(0.001);
        h.observe_secs(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-6);
        assert!((h.max_secs() - 0.003).abs() < 1e-6);
    }

    #[test]
    fn quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..100 {
            h.observe_secs(i as f64 * 0.001);
        }
        assert!(h.quantile_secs(0.5) <= h.quantile_secs(0.9));
        assert!(h.quantile_secs(0.9) <= h.quantile_secs(0.99) + 1e-9);
    }

    #[test]
    fn gauge_tracks_level() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn registry_renders_gauges() {
        let r = Registry::new();
        r.gauge("depth").set(7);
        assert!(r.render().contains("gauge depth 7"));
    }

    #[test]
    fn registry_same_name_same_counter() {
        let r = Registry::new();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
    }

    #[test]
    fn render_is_sorted_and_stable() {
        let r = Registry::new();
        r.counter("b").inc();
        r.counter("a").inc();
        r.histogram("lat").observe_secs(0.5);
        let text = r.render();
        let a_pos = text.find("counter a").unwrap();
        let b_pos = text.find("counter b").unwrap();
        assert!(a_pos < b_pos);
        assert!(text.contains("histogram lat count=1"));
    }

    #[test]
    fn concurrent_counters() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.counter("hits").inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("hits").get(), 8000);
    }

    #[test]
    fn single_sample_quantile_is_exact() {
        // Regression: a single 0.25 s observation lands in the
        // 0.262144 s (2^18 µs) bucket; the per-bucket clamp to max_secs
        // must return the observation itself at every quantile, never
        // the bucket's upper edge.  (0.25 s is exactly representable
        // down through the µs conversion, so equality is exact.)
        let h = Histogram::new();
        h.observe_secs(0.25);
        assert_eq!(h.quantile_secs(0.5), 0.25);
        assert_eq!(h.quantile_secs(0.99), 0.25);
        assert_eq!(h.quantile_secs(1.0), 0.25);
        assert_eq!(h.quantile_secs(0.5), h.max_secs());
    }

    #[test]
    fn quantile_never_exceeds_max() {
        let h = Histogram::new();
        for v in [0.0017, 0.9, 3.3, 700.0] {
            h.observe_secs(v);
        }
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile_secs(q) <= h.max_secs() + 1e-12);
        }
    }

    #[test]
    fn with_range_resolves_two_hour_spans() {
        // Regression for the virtual-time range bug: new()'s 31 log2
        // buckets from 1 µs top out at ~17.9 min, so a 1 h and a 2 h
        // observation both saturate the overflow bucket and p50 == max.
        let short = Histogram::new();
        short.observe_secs(3600.0);
        short.observe_secs(7200.0);
        assert_eq!(short.quantile_secs(0.5), short.max_secs(), "overflow bucket saturates");
        // with_range(1 ms, 40 buckets) reaches ~17 years: the 1 h sample
        // resolves into its own bucket and p50 stops riding the max.
        let long = Histogram::with_range(1e-3, 40);
        long.observe_secs(3600.0);
        long.observe_secs(7200.0);
        let p50 = long.quantile_secs(0.5);
        assert!(p50 >= 3600.0, "p50 at least the smaller sample, got {p50}");
        assert!(p50 < 7200.0, "p50 must resolve below the 2 h max, got {p50}");
        assert_eq!(long.quantile_secs(1.0), 7200.0);
        // default-range equivalence: with_range(1e-6, 31) is new()
        let a = Histogram::new();
        let b = Histogram::with_range(1e-6, 31);
        a.observe_secs(0.25);
        b.observe_secs(0.25);
        assert_eq!(a.quantile_secs(0.5), b.quantile_secs(0.5));
    }

    #[test]
    fn concurrent_histogram_observes_reconcile() {
        // Fleet-load shape: 8 shard workers observing one histogram must
        // reconcile count and sum exactly (atomics, no lost updates).
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    r.histogram("wait").observe_secs(i as f64 * 1e-4);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let h = r.histogram("wait");
        assert_eq!(h.count(), 8000, "no observation lost across 8 threads");
        // every thread observes the same ramp (mean 49.95 ms); the µs
        // quantization in observe_secs allows ≤1 µs per sample
        let expect_mean = 49_950_000.0 / 1000.0 / 1e6;
        assert!((h.mean_secs() - expect_mean).abs() < 1e-5);
        assert!((h.max_secs() - 0.0999).abs() < 1e-6);
    }

    #[test]
    fn histogram_merge_reconciles_shards() {
        let a = Histogram::with_range(1e-3, 40);
        let b = Histogram::with_range(1e-3, 40);
        a.observe_secs(10.0);
        a.observe_secs(20.0);
        b.observe_secs(4000.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.mean_secs() - 4030.0 / 3.0).abs() < 1e-6);
        assert_eq!(a.max_secs(), 4000.0);
        assert!(a.quantile_secs(0.99) <= a.max_secs());
    }

    #[test]
    fn digest_single_observation_is_exact_everywhere() {
        let d = Digest::new();
        d.observe(37);
        assert_eq!(d.count(), 1);
        assert_eq!(d.min(), 37);
        assert_eq!(d.max(), 37);
        assert_eq!(d.mean(), 37.0);
        assert_eq!(d.quantile(0.5), 37, "range clamp makes one sample exact");
        assert_eq!(d.quantile(0.99), 37);
    }

    #[test]
    fn digest_summarizes_spread_and_clamps_quantiles() {
        let d = Digest::new();
        for v in [0, 3, 5, 9, 100] {
            d.observe(v);
        }
        assert_eq!(d.count(), 5);
        assert_eq!(d.min(), 0);
        assert_eq!(d.max(), 100);
        assert!((d.mean() - 23.4).abs() < 1e-12);
        let p50 = d.quantile(0.5);
        assert!((3..=9).contains(&p50), "p50 within the middle buckets, got {p50}");
        assert_eq!(d.quantile(1.0), 100);
        // negatives land in bucket 0 and min stays exact
        let n = Digest::new();
        n.observe(-5);
        n.observe(-2);
        assert_eq!(n.min(), -5);
        assert_eq!(n.quantile(0.5), -2, "bucket-0 upper bound clamps to max");
    }

    #[test]
    fn digest_render_is_order_invariant() {
        // Commuting updates: observing the same multiset in different
        // orders (the shard-arrival nondeterminism) renders identically.
        let values = [12i64, 900, 3, 47, 47, 0, 255];
        let ra = Registry::new();
        let rb = Registry::new();
        for v in values {
            ra.digest("power.soc_pct").observe(v);
        }
        for v in values.iter().rev() {
            rb.digest("power.soc_pct").observe(*v);
        }
        assert_eq!(ra.render(), rb.render());
    }

    #[test]
    fn render_interleaves_digests_stably() {
        let r = Registry::new();
        r.counter("a.count").inc();
        r.gauge("b.depth").set(2);
        r.digest("c.soc").observe(81);
        r.digest("c.soc").observe(40);
        r.histogram("d.lat").observe_secs(0.5);
        let text = r.render();
        // one line type block each, digests between gauges and histograms
        let c_pos = text.find("counter a.count").unwrap();
        let g_pos = text.find("gauge b.depth").unwrap();
        let d_pos = text.find("digest c.soc").unwrap();
        let h_pos = text.find("histogram d.lat").unwrap();
        assert!(c_pos < g_pos && g_pos < d_pos && d_pos < h_pos);
        assert!(text.contains("digest c.soc n=2 min=40 mean=60.500 max=81 p50=40 p99=81"));
        // rendering twice is stable
        assert_eq!(text, r.render());
    }

    #[test]
    fn per_node_cutoff_is_inclusive_at_limit() {
        assert!(per_node_gauges_enabled(64, 64), "exactly at the limit keeps exact gauges");
        assert!(!per_node_gauges_enabled(65, 64), "one past the limit switches to digests");
        assert!(per_node_gauges_enabled(1, 64));
        assert!(!per_node_gauges_enabled(10_000, 64));
        assert!(per_node_gauges_enabled(0, 0));
    }
}
