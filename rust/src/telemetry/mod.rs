//! Telemetry: counters, gauges, histograms + fixed-format report text.
//!
//! The paper's satellites "monitor and manage the operational status and
//! applications" (§3.1); every pipeline stage and substrate reports here.
//! Thread-safe via atomics/mutex so worker threads can record freely.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, in-flight batches).  Stored as i64
/// so transient dec-before-inc races in relaxed code can't wrap.
#[derive(Default)]
pub struct Gauge(std::sync::atomic::AtomicI64);

impl Gauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Streaming histogram with fixed log-spaced buckets (µs-scale latencies
/// up to minutes) plus exact count/sum for means.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds: Vec<f64>,
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        // 1µs .. ~17min in 31 log2 buckets
        let bounds: Vec<f64> = (0..31).map(|i| 1.0_f64 * 2f64.powi(i)).collect();
        Histogram {
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            bounds,
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }

    pub fn observe_secs(&self, secs: f64) {
        let us = (secs * 1e6).max(0.0);
        let idx = self.bounds.partition_point(|&b| b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us as u64, Ordering::Relaxed);
        self.max_micros.fetch_max(us as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_micros.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
        }
    }

    pub fn max_secs(&self) -> f64 {
        self.max_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                let upper = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
                return upper.min(self.max_secs() * 1e6) / 1e6;
            }
        }
        self.max_secs()
    }
}

/// Named metric registry.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Render all metrics as stable, sorted text (for logs + tests).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "histogram {name} count={} mean={:.6}s p50={:.6}s p99={:.6}s max={:.6}s\n",
                h.count(),
                h.mean_secs(),
                h.quantile_secs(0.5),
                h.quantile_secs(0.99),
                h.max_secs()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_mean_and_max() {
        let h = Histogram::new();
        h.observe_secs(0.001);
        h.observe_secs(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-6);
        assert!((h.max_secs() - 0.003).abs() < 1e-6);
    }

    #[test]
    fn quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..100 {
            h.observe_secs(i as f64 * 0.001);
        }
        assert!(h.quantile_secs(0.5) <= h.quantile_secs(0.9));
        assert!(h.quantile_secs(0.9) <= h.quantile_secs(0.99) + 1e-9);
    }

    #[test]
    fn gauge_tracks_level() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn registry_renders_gauges() {
        let r = Registry::new();
        r.gauge("depth").set(7);
        assert!(r.render().contains("gauge depth 7"));
    }

    #[test]
    fn registry_same_name_same_counter() {
        let r = Registry::new();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
    }

    #[test]
    fn render_is_sorted_and_stable() {
        let r = Registry::new();
        r.counter("b").inc();
        r.counter("a").inc();
        r.histogram("lat").observe_secs(0.5);
        let text = r.render();
        let a_pos = text.find("counter a").unwrap();
        let b_pos = text.find("counter b").unwrap();
        assert!(a_pos < b_pos);
        assert!(text.contains("histogram lat count=1"));
    }

    #[test]
    fn concurrent_counters() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.counter("hits").inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("hits").get(), 8000);
    }
}
