//! mAP evaluation (PASCAL VOC all-point interpolation, IoU 0.5).
//!
//! The paper scores in-orbit vs collaborative inference with mAP over the
//! DOTA classes (Fig 7).  The evaluator accumulates (detections, ground
//! truth) pairs per image, then computes per-class AP and the mean.

use std::collections::HashMap;

use super::Detection;
use crate::data::GtBox;

/// Accumulates matched detections across many images.
pub struct Evaluator {
    iou_thresh: f32,
    classes: usize,
    /// per class: (score, is_true_positive)
    records: Vec<Vec<(f32, bool)>>,
    /// per class: number of ground-truth boxes
    gt_counts: Vec<usize>,
    images: usize,
}

#[derive(Debug, Clone)]
pub struct MapReport {
    pub map: f64,
    pub per_class_ap: Vec<f64>,
    pub images: usize,
    pub gt_total: usize,
    pub det_total: usize,
}

impl Evaluator {
    pub fn new(classes: usize, iou_thresh: f32) -> Evaluator {
        Evaluator {
            iou_thresh,
            classes,
            records: vec![Vec::new(); classes],
            gt_counts: vec![0; classes],
            images: 0,
        }
    }

    /// Add one image's detections + ground truth.  Greedy matching in
    /// descending score order; each GT matches at most one detection.
    pub fn add_image(&mut self, dets: &[Detection], gt: &[GtBox]) {
        self.images += 1;
        for g in gt {
            self.gt_counts[g.class] += 1;
        }
        let mut order: Vec<usize> = (0..dets.len()).collect();
        order.sort_by(|&a, &b| {
            dets[b].score.partial_cmp(&dets[a].score).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut used = vec![false; gt.len()];
        for &di in &order {
            let d = &dets[di];
            if d.class >= self.classes {
                continue;
            }
            let mut best: Option<(usize, f32)> = None;
            for (gi, g) in gt.iter().enumerate() {
                if used[gi] || g.class != d.class {
                    continue;
                }
                let iou = d.iou_gt(g);
                if iou >= self.iou_thresh && best.map(|(_, b)| iou > b).unwrap_or(true) {
                    best = Some((gi, iou));
                }
            }
            match best {
                Some((gi, _)) => {
                    used[gi] = true;
                    self.records[d.class].push((d.score, true));
                }
                None => self.records[d.class].push((d.score, false)),
            }
        }
    }

    pub fn report(&self) -> MapReport {
        let mut aps = Vec::with_capacity(self.classes);
        for c in 0..self.classes {
            aps.push(average_precision(&self.records[c], self.gt_counts[c]));
        }
        // Mean over classes that appear in the ground truth (VOC style:
        // absent classes don't dilute the mean).
        let present: Vec<f64> = (0..self.classes)
            .filter(|&c| self.gt_counts[c] > 0)
            .map(|c| aps[c])
            .collect();
        let map = if present.is_empty() {
            0.0
        } else {
            present.iter().sum::<f64>() / present.len() as f64
        };
        MapReport {
            map,
            per_class_ap: aps,
            images: self.images,
            gt_total: self.gt_counts.iter().sum(),
            det_total: self.records.iter().map(|r| r.len()).sum(),
        }
    }
}

/// AP for one class given (score, tp) records and the GT count.
/// All-point interpolation: area under the precision envelope.
pub fn average_precision(records: &[(f32, bool)], gt_count: usize) -> f64 {
    if gt_count == 0 {
        return 0.0;
    }
    let mut recs: Vec<(f32, bool)> = records.to_vec();
    recs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut pr: Vec<(f64, f64)> = Vec::with_capacity(recs.len()); // (recall, precision)
    for (_, is_tp) in recs {
        if is_tp {
            tp += 1;
        } else {
            fp += 1;
        }
        pr.push((tp as f64 / gt_count as f64, tp as f64 / (tp + fp) as f64));
    }
    // precision envelope (monotone non-increasing from the right)
    let mut env = pr.clone();
    for i in (0..env.len().saturating_sub(1)).rev() {
        env[i].1 = env[i].1.max(env[i + 1].1);
    }
    let mut ap = 0.0;
    let mut prev_r = 0.0;
    for (r, p) in env {
        ap += (r - prev_r).max(0.0) * p;
        prev_r = r;
    }
    ap
}

/// Convenience one-shot: mAP of a single (dets, gt) set.
pub fn map_score(per_image: &[(Vec<Detection>, Vec<GtBox>)], classes: usize, iou: f32) -> f64 {
    let mut ev = Evaluator::new(classes, iou);
    for (dets, gt) in per_image {
        ev.add_image(dets, gt);
    }
    ev.report().map
}

#[allow(dead_code)]
fn _type_check(_: HashMap<(), ()>) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, cy: f32, score: f32, class: usize) -> Detection {
        Detection { cx, cy, w: 8.0, h: 8.0, score, class }
    }

    fn gt(cx: f32, cy: f32, class: usize) -> GtBox {
        GtBox { cx, cy, w: 8.0, h: 8.0, class }
    }

    #[test]
    fn perfect_detection_ap_is_one() {
        let mut ev = Evaluator::new(2, 0.5);
        ev.add_image(&[det(10.0, 10.0, 0.9, 0)], &[gt(10.0, 10.0, 0)]);
        let r = ev.report();
        assert!((r.map - 1.0).abs() < 1e-9, "map {}", r.map);
    }

    #[test]
    fn missed_gt_lowers_recall() {
        let mut ev = Evaluator::new(1, 0.5);
        ev.add_image(&[det(10.0, 10.0, 0.9, 0)], &[gt(10.0, 10.0, 0), gt(40.0, 40.0, 0)]);
        let r = ev.report();
        assert!((r.map - 0.5).abs() < 1e-9, "map {}", r.map);
    }

    #[test]
    fn false_positive_lowers_precision() {
        let mut ev = Evaluator::new(1, 0.5);
        ev.add_image(
            &[det(10.0, 10.0, 0.9, 0), det(40.0, 40.0, 0.95, 0)],
            &[gt(10.0, 10.0, 0)],
        );
        let r = ev.report();
        // envelope: the TP comes second at precision 1/2, recall 1
        assert!((r.map - 0.5).abs() < 1e-9, "map {}", r.map);
    }

    #[test]
    fn wrong_class_never_matches() {
        let mut ev = Evaluator::new(2, 0.5);
        ev.add_image(&[det(10.0, 10.0, 0.9, 1)], &[gt(10.0, 10.0, 0)]);
        assert_eq!(ev.report().map, 0.0);
    }

    #[test]
    fn one_gt_matches_at_most_once() {
        let mut ev = Evaluator::new(1, 0.5);
        ev.add_image(
            &[det(10.0, 10.0, 0.9, 0), det(10.5, 10.0, 0.85, 0)],
            &[gt(10.0, 10.0, 0)],
        );
        let r = ev.report();
        // second det is a FP at full recall -> AP stays 1.0 under the
        // envelope (precision drop occurs after recall 1.0).
        assert!((r.map - 1.0).abs() < 1e-9);
        assert_eq!(r.det_total, 2);
    }

    #[test]
    fn absent_classes_dont_dilute() {
        let mut ev = Evaluator::new(8, 0.5);
        ev.add_image(&[det(10.0, 10.0, 0.9, 0)], &[gt(10.0, 10.0, 0)]);
        assert!((ev.report().map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ap_zero_when_no_gt() {
        assert_eq!(average_precision(&[(0.9, false)], 0), 0.0);
    }

    #[test]
    fn envelope_interpolation() {
        // records: TP(0.9), FP(0.8), TP(0.7); gt=2
        let ap = average_precision(&[(0.9, true), (0.8, false), (0.7, true)], 2);
        // recalls: .5, .5, 1.0; precisions: 1, .5, .667; envelope: 1, .667, .667
        let want = 0.5 * 1.0 + 0.5 * (2.0 / 3.0);
        assert!((ap - want).abs() < 1e-9, "{ap} vs {want}");
    }
}
