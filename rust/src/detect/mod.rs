//! Detection post-processing and evaluation.
//!
//! The AOT graphs emit decoded rows `[cx, cy, w, h, obj, p_cls0..]` per
//! grid cell; this module turns them into detections (confidence
//! threshold + class argmax + NMS) and scores them against ground truth
//! with the paper's metric, mAP (mean average precision over classes,
//! PASCAL-style all-point interpolation at IoU 0.5 — ref [30]).

mod eval;
mod nms;

pub use eval::{average_precision, map_score, Evaluator, MapReport};
pub use nms::nms;

use crate::data::GtBox;

/// One detection in tile/model coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct Detection {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
    /// obj * best-class probability.
    pub score: f32,
    pub class: usize,
}

impl Detection {
    pub fn iou(&self, other: &Detection) -> f32 {
        iou_xywh(
            (self.cx, self.cy, self.w, self.h),
            (other.cx, other.cy, other.w, other.h),
        )
    }

    pub fn iou_gt(&self, gt: &GtBox) -> f32 {
        iou_xywh((self.cx, self.cy, self.w, self.h), (gt.cx, gt.cy, gt.w, gt.h))
    }

    /// Compact downlink encoding: the collaborative system returns
    /// *results*, not imagery, for confident tiles.  16 bytes per box
    /// (4×f32-quantized fields: cx, cy, w, h as u16 halves + score u8 +
    /// class u8 + tile tag) — we model it as a flat 16 B.
    pub const WIRE_BYTES: u64 = 16;
}

/// IoU of two (cx, cy, w, h) boxes.
pub fn iou_xywh(a: (f32, f32, f32, f32), b: (f32, f32, f32, f32)) -> f32 {
    let (ax0, ay0, ax1, ay1) = (a.0 - a.2 / 2.0, a.1 - a.3 / 2.0, a.0 + a.2 / 2.0, a.1 + a.3 / 2.0);
    let (bx0, by0, bx1, by1) = (b.0 - b.2 / 2.0, b.1 - b.3 / 2.0, b.0 + b.2 / 2.0, b.1 + b.3 / 2.0);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let union = (ax1 - ax0) * (ay1 - ay0) + (bx1 - bx0) * (by1 - by0) - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// Decode raw model rows for one image into thresholded detections.
///
/// `rows` is `G*G * head_d` f32s; `head_d = 5 + classes`.
pub fn decode_rows(rows: &[f32], head_d: usize, conf_thresh: f32) -> Vec<Detection> {
    assert_eq!(rows.len() % head_d, 0);
    let mut dets = Vec::new();
    for r in rows.chunks_exact(head_d) {
        let obj = r[4];
        if obj < conf_thresh {
            continue; // cheap reject before argmax
        }
        // argmax over the class slice: one bounds check for the whole
        // sweep instead of one per probe; strict `>` keeps the original
        // first-max tie-breaking exactly
        let (mut best_c, mut best_p) = (0usize, f32::MIN);
        for (c, &p) in r[5..].iter().enumerate() {
            if p > best_p {
                best_p = p;
                best_c = c;
            }
        }
        let score = obj * best_p;
        if score >= conf_thresh {
            dets.push(Detection { cx: r[0], cy: r[1], w: r[2], h: r[3], score, class: best_c });
        }
    }
    dets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_identical_is_one() {
        assert!((iou_xywh((10.0, 10.0, 4.0, 4.0), (10.0, 10.0, 4.0, 4.0)) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(iou_xywh((0.0, 0.0, 2.0, 2.0), (10.0, 10.0, 2.0, 2.0)), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // two 2x2 boxes offset by 1 in x: inter 2, union 6
        let v = iou_xywh((1.0, 1.0, 2.0, 2.0), (2.0, 1.0, 2.0, 2.0));
        assert!((v - 2.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn decode_rows_filters_by_confidence() {
        let head_d = 13;
        let mut rows = vec![0.0f32; 2 * head_d];
        // row 0: strong detection of class 3
        rows[0..5].copy_from_slice(&[10.0, 12.0, 8.0, 8.0, 0.9]);
        rows[5 + 3] = 0.8;
        // row 1: weak
        rows[head_d + 4] = 0.05;
        let dets = decode_rows(&rows, head_d, 0.25);
        assert_eq!(dets.len(), 1);
        assert_eq!(dets[0].class, 3);
        assert!((dets[0].score - 0.72).abs() < 1e-6);
    }

    #[test]
    fn decode_rows_obj_gate_before_class() {
        let head_d = 13;
        let mut rows = vec![0.0f32; head_d];
        rows[4] = 0.5;
        rows[5] = 0.3; // score 0.15 < 0.25
        assert!(decode_rows(&rows, head_d, 0.25).is_empty());
    }
}
