//! Greedy per-class non-maximum suppression.

use super::Detection;

/// Standard greedy NMS: sort by score desc, drop boxes overlapping a kept
/// box of the *same class* above `iou_thresh`.  Returns kept detections
/// sorted by descending score.
pub fn nms(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
    let mut kept: Vec<Detection> = Vec::with_capacity(dets.len());
    'outer: for d in dets {
        for k in &kept {
            if k.class == d.class && k.iou(&d) > iou_thresh {
                continue 'outer;
            }
        }
        kept.push(d);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, score: f32, class: usize) -> Detection {
        Detection { cx, cy: 10.0, w: 8.0, h: 8.0, score, class }
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(11.0, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.9);
    }

    #[test]
    fn keeps_overlapping_different_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(11.0, 0.8, 1)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn keeps_distant_same_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(40.0, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn output_sorted_by_score() {
        let kept = nms(vec![det(40.0, 0.5, 0), det(10.0, 0.9, 0), det(25.0, 0.7, 1)], 0.5);
        let scores: Vec<f32> = kept.iter().map(|d| d.score).collect();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(scores, sorted);
    }

    #[test]
    fn empty_input_ok() {
        assert!(nms(Vec::new(), 0.5).is_empty());
    }
}
