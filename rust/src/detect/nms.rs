//! Greedy per-class non-maximum suppression.

use super::Detection;

/// Standard greedy NMS: sort by score desc, drop boxes overlapping a kept
/// box of the *same class* above `iou_thresh`.  Returns kept detections
/// sorted by descending score.
///
/// Scores sort with [`f32::total_cmp`] — NaN scores (a poisoned model
/// output) order first and *deterministically*, where the previous
/// `partial_cmp(..).unwrap_or(Equal)` fallback made the comparator
/// non-transitive and the kept order unspecified.  For finite positive
/// scores (everything `decode_rows` emits) the order is unchanged.
///
/// Candidates are compared only against kept boxes of their own class
/// (class-bucketed suppression), so dense multi-class scenes pay
/// O(n·k_class) IoU checks instead of O(n²) across all classes.  The
/// comparisons that remain are exactly the same-class subset of the
/// naive scan (suppression is an any-overlap test, so iteration order
/// within the bucket is immaterial) and the kept set is identical.
/// Buckets are flat per-class chains through two scratch vectors — no
/// per-class nested allocations on this per-tile hot path.
pub fn nms(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
    const NONE: usize = usize::MAX;
    dets.sort_by(|a, b| b.score.total_cmp(&a.score));
    let mut kept: Vec<Detection> = Vec::with_capacity(dets.len());
    // head[class] = most recently kept index of that class; link[i] =
    // previously kept index of kept[i]'s class (NONE terminates)
    let mut head: Vec<usize> = Vec::new();
    let mut link: Vec<usize> = Vec::with_capacity(dets.len());
    'outer: for d in dets {
        if d.class >= head.len() {
            head.resize(d.class + 1, NONE);
        }
        let mut ki = head[d.class];
        while ki != NONE {
            if kept[ki].iou(&d) > iou_thresh {
                continue 'outer;
            }
            ki = link[ki];
        }
        link.push(head[d.class]);
        head[d.class] = kept.len();
        kept.push(d);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn det(cx: f32, score: f32, class: usize) -> Detection {
        Detection { cx, cy: 10.0, w: 8.0, h: 8.0, score, class }
    }

    /// The pre-bucketing reference: full quadratic scan over kept boxes.
    fn nms_naive(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
        dets.sort_by(|a, b| b.score.total_cmp(&a.score));
        let mut kept: Vec<Detection> = Vec::with_capacity(dets.len());
        'outer: for d in dets {
            for k in &kept {
                if k.class == d.class && k.iou(&d) > iou_thresh {
                    continue 'outer;
                }
            }
            kept.push(d);
        }
        kept
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(11.0, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.9);
    }

    #[test]
    fn keeps_overlapping_different_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(11.0, 0.8, 1)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn keeps_distant_same_class() {
        let kept = nms(vec![det(10.0, 0.9, 0), det(40.0, 0.8, 0)], 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn output_sorted_by_score() {
        let kept = nms(vec![det(40.0, 0.5, 0), det(10.0, 0.9, 0), det(25.0, 0.7, 1)], 0.5);
        let scores: Vec<f32> = kept.iter().map(|d| d.score).collect();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(scores, sorted);
    }

    #[test]
    fn empty_input_ok() {
        assert!(nms(Vec::new(), 0.5).is_empty());
    }

    #[test]
    fn nan_scores_are_ordered_deterministically() {
        // Regression: total_cmp gives NaN a defined slot (first, under
        // descending order) regardless of input permutation; the old
        // Equal fallback left the kept order unspecified.
        let a = vec![det(10.0, 0.9, 0), det(40.0, f32::NAN, 0), det(70.0, 0.8, 0)];
        let b = vec![det(70.0, 0.8, 0), det(40.0, f32::NAN, 0), det(10.0, 0.9, 0)];
        let ka = nms(a, 0.5);
        let kb = nms(b, 0.5);
        assert_eq!(ka.len(), 3);
        assert!(ka[0].score.is_nan(), "NaN must sort first: {ka:?}");
        let order = |k: &[Detection]| k.iter().map(|d| d.cx.to_bits()).collect::<Vec<_>>();
        assert_eq!(order(&ka), order(&kb), "kept order must not depend on input order");
        assert_eq!(ka[1].score, 0.9);
        assert_eq!(ka[2].score, 0.8);
    }

    #[test]
    fn class_buckets_match_naive_quadratic_scan() {
        let mut rng = Rng::new(17);
        for case in 0..100 {
            let n = rng.range_usize(0, 60);
            let dets: Vec<Detection> = (0..n)
                .map(|_| {
                    det(rng.range_f32(0.0, 64.0), rng.f32(), rng.below(8) as usize)
                })
                .collect();
            let thresh = rng.range_f32(0.1, 0.9);
            let fast = nms(dets.clone(), thresh);
            let slow = nms_naive(dets, thresh);
            assert_eq!(fast.len(), slow.len(), "case {case}");
            for (f, s) in fast.iter().zip(&slow) {
                assert_eq!(f, s, "case {case}");
            }
        }
    }
}
