//! SynthDOTA — procedural Earth-Observation scenes (rust serving twin).
//!
//! Mirrors python/compile/data.py: same 8 class signatures, same cloud
//! model, same calibration constants (via artifacts/manifest.json where it
//! matters).  The rust side generates *scenes* (large images the satellite
//! camera captures, as in DOTA) which the coordinator splits into tiles —
//! the python side only ever generated training tiles.
//!
//! Determinism: everything flows from [`crate::util::rng::Rng`] seeds, so
//! experiments are exactly reproducible.

mod scene;
mod tiler;

pub use scene::{Scene, SceneGen, SceneSpec, GtBox, CLASS_NAMES, NUM_CLASSES};
pub use tiler::{gather_pixels, split_scene, split_scene_pooled, Tile, MODEL_TILE, TILE_PX};
#[doc(hidden)]
pub use tiler::reference_cut;

/// A dataset "version" as in Fig 6: v1 ≈ 90% cloud-redundant, v2 ≈ 40%.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Version {
    V1,
    V2,
}

impl Version {
    pub fn spec(self) -> SceneSpec {
        match self {
            // Mirrors python VERSIONS: v1 cloud_prob .93 / lam .9,
            // v2 cloud_prob .45 / lam 1.6 (per-tile equivalents; scenes
            // apply the probability per tile-sized region).
            Version::V1 => SceneSpec { cloud_prob: 0.93, cloud_density: 1.0, objects_lam: 0.9 },
            Version::V2 => SceneSpec { cloud_prob: 0.45, cloud_density: 0.9, objects_lam: 1.6 },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Version::V1 => "v1",
            Version::V2 => "v2",
        }
    }
}
