//! Onboard image splitting (paper §IV, Fig 6).
//!
//! "We propose a strategy to split the images into smaller images before
//! performing in-orbit inference … due to the limited computing power of
//! the satellite, which cannot handle high-resolution images."
//!
//! `split_scene` cuts a captured scene into `frag`-pixel fragments and
//! resamples each to the model's 64-px input (nearest-neighbor up, box
//! filter down) — fragment size is the Fig 6 sweep variable.

use super::scene::{GtBox, Scene};

pub const MODEL_TILE: usize = 64;

/// One fragment, resampled to the 64-px model input.
#[derive(Clone)]
pub struct Tile {
    /// Scene id this tile came from.
    pub scene_id: u64,
    /// Fragment origin in scene pixels.
    pub x0: usize,
    pub y0: usize,
    /// Fragment edge length in scene pixels (before resampling).
    pub frag: usize,
    /// 64×64×3 f32 model input.
    pub pixels: Vec<f32>,
    /// Ground truth whose centers fall inside the fragment, in *model
    /// input* coordinates (scaled by 64/frag).
    pub gt: Vec<GtBox>,
}

impl Tile {
    /// Downlink cost of shipping this tile's raw imagery (8-bit RGB at the
    /// original fragment resolution — what a bent-pipe would transmit).
    pub fn raw_bytes(&self) -> u64 {
        (self.frag * self.frag * 3) as u64
    }

    /// Scale from model coords back to scene coords.
    pub fn to_scene_xy(&self, cx: f32, cy: f32) -> (f32, f32) {
        let s = self.frag as f32 / MODEL_TILE as f32;
        (self.x0 as f32 + cx * s, self.y0 as f32 + cy * s)
    }
}

/// Split `scene` into frag×frag fragments (frag must divide the scene).
pub fn split_scene(scene: &Scene, frag: usize) -> Vec<Tile> {
    assert!(frag > 0 && scene.width % frag == 0 && scene.height % frag == 0,
            "fragment {frag} must divide scene {}x{}", scene.width, scene.height);
    let mut tiles = Vec::with_capacity((scene.width / frag) * (scene.height / frag));
    for y0 in (0..scene.height).step_by(frag) {
        for x0 in (0..scene.width).step_by(frag) {
            tiles.push(cut(scene, x0, y0, frag));
        }
    }
    tiles
}

fn cut(scene: &Scene, x0: usize, y0: usize, frag: usize) -> Tile {
    let scale = frag as f32 / MODEL_TILE as f32;
    let mut pixels = vec![0.0f32; MODEL_TILE * MODEL_TILE * 3];
    if frag >= MODEL_TILE {
        // Box-filter downsample (frag = k * 64 for integer k).
        let k = frag / MODEL_TILE;
        let norm = 1.0 / (k * k) as f32;
        for ty in 0..MODEL_TILE {
            for tx in 0..MODEL_TILE {
                let mut acc = [0.0f32; 3];
                for sy in 0..k {
                    for sx in 0..k {
                        let p = scene.px(x0 + tx * k + sx, y0 + ty * k + sy);
                        for c in 0..3 {
                            acc[c] += p[c];
                        }
                    }
                }
                let i = (ty * MODEL_TILE + tx) * 3;
                for c in 0..3 {
                    pixels[i + c] = acc[c] * norm;
                }
            }
        }
    } else {
        // Nearest-neighbor upsample (frag = 64 / k).
        let k = MODEL_TILE / frag;
        for ty in 0..MODEL_TILE {
            for tx in 0..MODEL_TILE {
                let p = scene.px(x0 + tx / k, y0 + ty / k);
                let i = (ty * MODEL_TILE + tx) * 3;
                pixels[i..i + 3].copy_from_slice(&p);
            }
        }
    }
    let gt = scene
        .boxes
        .iter()
        .filter(|b| {
            b.cx >= x0 as f32 && b.cx < (x0 + frag) as f32
                && b.cy >= y0 as f32 && b.cy < (y0 + frag) as f32
        })
        .map(|b| GtBox {
            cx: (b.cx - x0 as f32) / scale,
            cy: (b.cy - y0 as f32) / scale,
            w: b.w / scale,
            h: b.h / scale,
            class: b.class,
        })
        .collect();
    Tile { scene_id: scene.id, x0, y0, frag, pixels, gt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SceneGen, Version};

    fn scene() -> Scene {
        SceneGen::new(9, Version::V2.spec(), 4, 4).capture() // 256x256
    }

    #[test]
    fn tile_count_matches_fragment_size() {
        let s = scene();
        assert_eq!(split_scene(&s, 64).len(), 16);
        assert_eq!(split_scene(&s, 32).len(), 64);
        assert_eq!(split_scene(&s, 128).len(), 4);
    }

    #[test]
    fn identity_fragment_copies_pixels() {
        let s = scene();
        let tiles = split_scene(&s, 64);
        let t = &tiles[0];
        assert_eq!(t.pixels.len(), 64 * 64 * 3);
        let want = s.px(5, 7);
        let i = (7 * 64 + 5) * 3;
        assert_eq!(&t.pixels[i..i + 3], &want);
    }

    #[test]
    fn gt_conservation_across_split() {
        // Every scene GT box lands in exactly one tile, at every frag size.
        let s = scene();
        for frag in [32, 64, 128] {
            let total: usize = split_scene(&s, frag).iter().map(|t| t.gt.len()).sum();
            assert_eq!(total, s.boxes.len(), "frag={frag}");
        }
    }

    #[test]
    fn gt_coordinates_rescaled_to_model_input() {
        let s = scene();
        for frag in [32, 64, 128] {
            for t in split_scene(&s, frag) {
                for b in &t.gt {
                    assert!(b.cx >= 0.0 && b.cx <= MODEL_TILE as f32, "frag={frag} cx={}", b.cx);
                    assert!(b.cy >= 0.0 && b.cy <= MODEL_TILE as f32);
                }
            }
        }
    }

    #[test]
    fn to_scene_roundtrip() {
        let s = scene();
        let tiles = split_scene(&s, 128);
        let t = &tiles[3];
        let (sx, sy) = t.to_scene_xy(32.0, 32.0);
        // center of model tile = center of fragment
        assert_eq!(sx, t.x0 as f32 + 64.0);
        assert_eq!(sy, t.y0 as f32 + 64.0);
    }

    #[test]
    fn raw_bytes_scale_with_fragment() {
        let s = scene();
        assert_eq!(split_scene(&s, 32)[0].raw_bytes(), 32 * 32 * 3);
        assert_eq!(split_scene(&s, 128)[0].raw_bytes(), 128 * 128 * 3);
    }

    #[test]
    #[should_panic]
    fn non_divisible_fragment_panics() {
        let s = scene();
        split_scene(&s, 48);
    }
}
