//! Onboard image splitting (paper §IV, Fig 6).
//!
//! "We propose a strategy to split the images into smaller images before
//! performing in-orbit inference … due to the limited computing power of
//! the satellite, which cannot handle high-resolution images."
//!
//! `split_scene` cuts a captured scene into `frag`-pixel fragments and
//! resamples each to the model's 64-px input (nearest-neighbor up, box
//! filter down) — fragment size is the Fig 6 sweep variable.
//!
//! Zero-copy hot path: [`split_scene_pooled`] checks tile buffers out of
//! a [`PixelPool`] instead of allocating 48 KB per tile, and `cut`
//! operates on scene *row slices* (one bounds check per row span instead
//! of three per pixel).  The box filter accumulates into a fixed-width
//! channel-lane array (one f32 lane per output channel, swept
//! contiguously per source column offset) so the independent per-lane
//! adds autovectorize; the float accumulation order per lane is pinned
//! to the pre-refactor per-pixel loop — per output pixel, source rows
//! add in `sy` then `sx` order, channels 0..3 — so the resampled pixels
//! are bit-identical to the naive implementation
//! (`tests/datapath_golden.rs` enforces this byte-for-byte).

use super::scene::{GtBox, Scene};
use crate::util::buffer::{PixelBuf, PixelPool};

pub const MODEL_TILE: usize = 64;
/// f32 count of one model-input tile (64×64×3) — the hot-path pool size.
pub const TILE_PX: usize = MODEL_TILE * MODEL_TILE * 3;
/// f32 count of one model-input row (64×3).
const ROW3: usize = MODEL_TILE * 3;

/// One fragment, resampled to the 64-px model input.
#[derive(Clone)]
pub struct Tile {
    /// Scene id this tile came from.
    pub scene_id: u64,
    /// Fragment origin in scene pixels.
    pub x0: usize,
    pub y0: usize,
    /// Fragment edge length in scene pixels (before resampling).
    pub frag: usize,
    /// 64×64×3 f32 model input.  Pooled on the hot path (cloning a
    /// pooled tile draws its pixel copy from the same pool).
    pub pixels: PixelBuf,
    /// Ground truth whose centers fall inside the fragment, in *model
    /// input* coordinates (scaled by 64/frag).
    pub gt: Vec<GtBox>,
}

impl Tile {
    /// Downlink cost of shipping this tile's raw imagery (8-bit RGB at the
    /// original fragment resolution — what a bent-pipe would transmit).
    pub fn raw_bytes(&self) -> u64 {
        (self.frag * self.frag * 3) as u64
    }

    /// Scale from model coords back to scene coords.
    pub fn to_scene_xy(&self, cx: f32, cy: f32) -> (f32, f32) {
        let s = self.frag as f32 / MODEL_TILE as f32;
        (self.x0 as f32 + cx * s, self.y0 as f32 + cy * s)
    }
}

/// Split `scene` into frag×frag fragments (frag must divide the scene),
/// allocating a fresh buffer per tile — the cold-path variant for tests
/// and one-shot callers.
pub fn split_scene(scene: &Scene, frag: usize) -> Vec<Tile> {
    split_with(scene, frag, || PixelBuf::zeroed(TILE_PX))
}

/// Split `scene` with tile buffers checked out of `pool` — the hot-path
/// variant: at steady state (tiles dropped or returned between scenes)
/// no per-tile allocation happens.
pub fn split_scene_pooled(scene: &Scene, frag: usize, pool: &PixelPool) -> Vec<Tile> {
    debug_assert_eq!(pool.buf_len(), TILE_PX);
    // dirty checkout: `cut` writes every output element on every path,
    // so the per-checkout clear would be pure overhead
    split_with(scene, frag, || pool.checkout_dirty())
}

fn split_with(scene: &Scene, frag: usize, mut buf: impl FnMut() -> PixelBuf) -> Vec<Tile> {
    assert!(frag > 0 && scene.width % frag == 0 && scene.height % frag == 0,
            "fragment {frag} must divide scene {}x{}", scene.width, scene.height);
    let mut tiles = Vec::with_capacity((scene.width / frag) * (scene.height / frag));
    for y0 in (0..scene.height).step_by(frag) {
        for x0 in (0..scene.width).step_by(frag) {
            tiles.push(cut(scene, x0, y0, frag, buf()));
        }
    }
    tiles
}

/// Gather `tiles`' pixels contiguously into `scratch` (NHWC batch
/// layout, the PJRT marshalling step); returns the f32 count written.
/// `scratch` must hold at least `tiles.len() * TILE_PX` elements.
pub fn gather_pixels(tiles: &[Tile], scratch: &mut [f32]) -> usize {
    for (i, t) in tiles.iter().enumerate() {
        scratch[i * TILE_PX..(i + 1) * TILE_PX].copy_from_slice(&t.pixels);
    }
    tiles.len() * TILE_PX
}

/// Resample one fragment into `pixels` (a `TILE_PX` buffer whose prior
/// contents are irrelevant) via row slices.  Every output element is
/// written on every path — which is what lets the pooled caller hand in
/// a dirty buffer.
fn cut(scene: &Scene, x0: usize, y0: usize, frag: usize, mut pixels: PixelBuf) -> Tile {
    debug_assert_eq!(pixels.len(), TILE_PX);
    let scale = frag as f32 / MODEL_TILE as f32;
    let w3 = scene.width * 3;
    let src = &scene.pixels[..];
    let out = &mut pixels[..];
    if frag == MODEL_TILE {
        // identity fragment: each output row is a contiguous scene span
        for ty in 0..MODEL_TILE {
            let s = (y0 + ty) * w3 + x0 * 3;
            out[ty * ROW3..(ty + 1) * ROW3].copy_from_slice(&src[s..s + ROW3]);
        }
    } else if frag > MODEL_TILE {
        // Box-filter downsample (frag = k * 64 for integer k) over a
        // channel-lane accumulator: `acc` is the whole output row as 192
        // f32 lanes (64 pixels × 3 channels), and each (sy, sx) pass
        // sweeps the lane array *contiguously* while reading the source
        // at stride k·3 — the autovectorization-friendly layout (the
        // per-lane adds are independent, so LLVM can widen them).
        //
        // Bit-identity: each accumulator lane acc[tx*3+c] receives its
        // addends in (sy asc, then sx asc) order — exactly the
        // (sy, sx, c) order of the pre-refactor per-pixel loop — because
        // swapping the tx/sx loops only interleaves adds between
        // *different* lanes, which never interact until the final
        // normalize.  Enforced byte-for-byte by tests/datapath_golden.rs.
        let k = frag / MODEL_TILE;
        let norm = 1.0 / (k * k) as f32;
        let mut acc = [0.0f32; ROW3];
        for ty in 0..MODEL_TILE {
            acc.fill(0.0);
            for sy in 0..k {
                let s = (y0 + ty * k + sy) * w3 + x0 * 3;
                let row = &src[s..s + frag * 3];
                for sx in 0..k {
                    // chunk tx of `row[sx*3..]` at width k·3 starts at
                    // source pixel tx·k + sx; only its first 3 lanes are
                    // read.  `chunks` (not `_exact`): for sx > 0 the last
                    // chunk is short but still holds ≥ 3 elements.
                    for (a, p) in
                        acc.chunks_exact_mut(3).zip(row[sx * 3..].chunks(k * 3))
                    {
                        a[0] += p[0];
                        a[1] += p[1];
                        a[2] += p[2];
                    }
                }
            }
            for (dst, a) in out[ty * ROW3..(ty + 1) * ROW3].iter_mut().zip(&acc) {
                *dst = a * norm;
            }
        }
    } else {
        // Nearest-neighbor upsample (frag = 64 / k): build the first
        // output row of each source-row group with one contiguous k-wide
        // span per source pixel, then duplicate it k-1 times with
        // whole-row copies.  Pure copies — trivially bit-identical.
        let k = MODEL_TILE / frag;
        for ty in 0..MODEL_TILE {
            let o = ty * ROW3;
            if ty % k != 0 {
                let (prev, cur) = out.split_at_mut(o);
                cur[..ROW3].copy_from_slice(&prev[o - ROW3..]);
                continue;
            }
            let s = (y0 + ty / k) * w3 + x0 * 3;
            let row = &src[s..s + frag * 3];
            let dst = &mut out[o..o + ROW3];
            for (span, p) in dst.chunks_exact_mut(k * 3).zip(row.chunks_exact(3)) {
                for q in span.chunks_exact_mut(3) {
                    q.copy_from_slice(p);
                }
            }
        }
    }
    let gt = scene
        .boxes
        .iter()
        .filter(|b| {
            b.cx >= x0 as f32 && b.cx < (x0 + frag) as f32
                && b.cy >= y0 as f32 && b.cy < (y0 + frag) as f32
        })
        .map(|b| GtBox {
            cx: (b.cx - x0 as f32) / scale,
            cy: (b.cy - y0 as f32) / scale,
            w: b.w / scale,
            h: b.h / scale,
            class: b.class,
        })
        .collect();
    Tile { scene_id: scene.id, x0, y0, frag, pixels, gt }
}

/// The pre-refactor per-pixel `cut`, retained **verbatim and frozen** as
/// the normative reference: `tests/datapath_golden.rs` pins the pooled
/// row-sliced path against it byte-for-byte, and `benches/perf_datapath.rs`
/// uses it as the naive comparison baseline.  One copy, shared by both,
/// so the correctness golden and the perf baseline can never diverge.
/// Not part of the public API surface proper.
#[doc(hidden)]
pub fn reference_cut(scene: &Scene, x0: usize, y0: usize, frag: usize) -> (Vec<f32>, Vec<GtBox>) {
    let scale = frag as f32 / MODEL_TILE as f32;
    let mut pixels = vec![0.0f32; MODEL_TILE * MODEL_TILE * 3];
    if frag >= MODEL_TILE {
        // Box-filter downsample (frag = k * 64 for integer k).
        let k = frag / MODEL_TILE;
        let norm = 1.0 / (k * k) as f32;
        for ty in 0..MODEL_TILE {
            for tx in 0..MODEL_TILE {
                let mut acc = [0.0f32; 3];
                for sy in 0..k {
                    for sx in 0..k {
                        let p = scene.px(x0 + tx * k + sx, y0 + ty * k + sy);
                        for c in 0..3 {
                            acc[c] += p[c];
                        }
                    }
                }
                let i = (ty * MODEL_TILE + tx) * 3;
                for c in 0..3 {
                    pixels[i + c] = acc[c] * norm;
                }
            }
        }
    } else {
        // Nearest-neighbor upsample (frag = 64 / k).
        let k = MODEL_TILE / frag;
        for ty in 0..MODEL_TILE {
            for tx in 0..MODEL_TILE {
                let p = scene.px(x0 + tx / k, y0 + ty / k);
                let i = (ty * MODEL_TILE + tx) * 3;
                pixels[i..i + 3].copy_from_slice(&p);
            }
        }
    }
    let gt = scene
        .boxes
        .iter()
        .filter(|b| {
            b.cx >= x0 as f32 && b.cx < (x0 + frag) as f32
                && b.cy >= y0 as f32 && b.cy < (y0 + frag) as f32
        })
        .map(|b| GtBox {
            cx: (b.cx - x0 as f32) / scale,
            cy: (b.cy - y0 as f32) / scale,
            w: b.w / scale,
            h: b.h / scale,
            class: b.class,
        })
        .collect();
    (pixels, gt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SceneGen, Version};

    fn scene() -> Scene {
        SceneGen::new(9, Version::V2.spec(), 4, 4).capture() // 256x256
    }

    #[test]
    fn tile_count_matches_fragment_size() {
        let s = scene();
        assert_eq!(split_scene(&s, 64).len(), 16);
        assert_eq!(split_scene(&s, 32).len(), 64);
        assert_eq!(split_scene(&s, 128).len(), 4);
    }

    #[test]
    fn identity_fragment_copies_pixels() {
        let s = scene();
        let tiles = split_scene(&s, 64);
        let t = &tiles[0];
        assert_eq!(t.pixels.len(), 64 * 64 * 3);
        let want = s.px(5, 7);
        let i = (7 * 64 + 5) * 3;
        assert_eq!(&t.pixels[i..i + 3], &want);
    }

    #[test]
    fn gt_conservation_across_split() {
        // Every scene GT box lands in exactly one tile, at every frag size.
        let s = scene();
        for frag in [32, 64, 128] {
            let total: usize = split_scene(&s, frag).iter().map(|t| t.gt.len()).sum();
            assert_eq!(total, s.boxes.len(), "frag={frag}");
        }
    }

    #[test]
    fn gt_coordinates_rescaled_to_model_input() {
        let s = scene();
        for frag in [32, 64, 128] {
            for t in split_scene(&s, frag) {
                for b in &t.gt {
                    assert!(b.cx >= 0.0 && b.cx <= MODEL_TILE as f32, "frag={frag} cx={}", b.cx);
                    assert!(b.cy >= 0.0 && b.cy <= MODEL_TILE as f32);
                }
            }
        }
    }

    #[test]
    fn to_scene_roundtrip() {
        let s = scene();
        let tiles = split_scene(&s, 128);
        let t = &tiles[3];
        let (sx, sy) = t.to_scene_xy(32.0, 32.0);
        // center of model tile = center of fragment
        assert_eq!(sx, t.x0 as f32 + 64.0);
        assert_eq!(sy, t.y0 as f32 + 64.0);
    }

    #[test]
    fn raw_bytes_scale_with_fragment() {
        let s = scene();
        assert_eq!(split_scene(&s, 32)[0].raw_bytes(), 32 * 32 * 3);
        assert_eq!(split_scene(&s, 128)[0].raw_bytes(), 128 * 128 * 3);
    }

    #[test]
    #[should_panic]
    fn non_divisible_fragment_panics() {
        let s = scene();
        split_scene(&s, 48);
    }

    #[test]
    fn pooled_split_is_bit_identical_and_reuses_buffers() {
        let s = scene();
        let pool = PixelPool::new(TILE_PX);
        for frag in [32usize, 64, 128] {
            let plain = split_scene(&s, frag);
            let pooled = split_scene_pooled(&s, frag, &pool);
            assert_eq!(plain.len(), pooled.len());
            for (a, b) in plain.iter().zip(&pooled) {
                assert!(b.pixels.is_pooled());
                assert!(
                    a.pixels.iter().zip(b.pixels.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "frag={frag} ({}, {}): pooled pixels diverge",
                    a.x0,
                    a.y0
                );
                assert_eq!(a.gt, b.gt);
            }
        }
        let after_warmup = pool.stats().allocs;
        // steady state: the buffers returned above serve the next scene
        let _again = split_scene_pooled(&s, 64, &pool);
        assert_eq!(pool.stats().allocs, after_warmup, "warm pool must not allocate");
    }

    #[test]
    fn gather_pixels_is_the_concat_of_tiles() {
        let s = scene();
        let tiles = split_scene(&s, 64);
        let chunk = &tiles[..3];
        let mut scratch = vec![0.0f32; 4 * TILE_PX];
        let n = gather_pixels(chunk, &mut scratch);
        assert_eq!(n, 3 * TILE_PX);
        for (i, t) in chunk.iter().enumerate() {
            assert_eq!(&scratch[i * TILE_PX..(i + 1) * TILE_PX], &t.pixels[..]);
        }
        assert!(scratch[n..].iter().all(|&v| v == 0.0));
    }
}
