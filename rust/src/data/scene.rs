//! Scene generation — the satellite camera.
//!
//! A scene is an H×W×3 f32 image assembled from a grid of 64-px cells,
//! each drawn from the same distribution as the python training twin
//! (python/compile/data.py): land/sea background, 0–4 objects from the 8
//! class signatures, and (version-dependent) a dense cloud layer.

use crate::util::buffer::{PixelBuf, PixelPool, PoolStats};
use crate::util::rng::Rng;

pub const CELL: usize = 64;
pub const NUM_CLASSES: usize = 8;
pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "plane", "ship", "storage-tank", "vehicle", "harbor", "bridge", "court", "pool",
];

/// Per-class signature mirrored from python CLASS_SPECS.
struct ClassSpec {
    shape: Shape,
    rgb: [f32; 3],
    size_lo: f32,
    size_hi: f32,
    aspect: f32,
}

#[derive(Clone, Copy, PartialEq)]
enum Shape {
    Cross,
    Rect,
    Disk,
}

const SPECS: [ClassSpec; NUM_CLASSES] = [
    ClassSpec { shape: Shape::Cross, rgb: [0.92, 0.92, 0.95], size_lo: 10.0, size_hi: 18.0, aspect: 1.0 },
    ClassSpec { shape: Shape::Rect, rgb: [0.13, 0.13, 0.18], size_lo: 5.0, size_hi: 7.0, aspect: 3.0 },
    ClassSpec { shape: Shape::Disk, rgb: [0.78, 0.78, 0.74], size_lo: 8.0, size_hi: 14.0, aspect: 1.0 },
    ClassSpec { shape: Shape::Rect, rgb: [0.75, 0.12, 0.10], size_lo: 4.0, size_hi: 7.0, aspect: 1.2 },
    ClassSpec { shape: Shape::Rect, rgb: [0.35, 0.30, 0.28], size_lo: 6.0, size_hi: 9.0, aspect: 2.2 },
    ClassSpec { shape: Shape::Rect, rgb: [0.55, 0.55, 0.58], size_lo: 3.0, size_hi: 4.0, aspect: 6.0 },
    ClassSpec { shape: Shape::Rect, rgb: [0.15, 0.55, 0.20], size_lo: 10.0, size_hi: 16.0, aspect: 1.1 },
    ClassSpec { shape: Shape::Disk, rgb: [0.15, 0.65, 0.80], size_lo: 8.0, size_hi: 14.0, aspect: 1.0 },
];

/// Ground-truth box in scene pixel coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct GtBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
    pub class: usize,
}

/// Generation knobs (per dataset version — see [`super::Version`]).
#[derive(Clone, Copy, Debug)]
pub struct SceneSpec {
    /// Probability that a 64-px cell is hit by a cloud event.
    pub cloud_prob: f64,
    /// Cloud blob scale multiplier.
    pub cloud_density: f32,
    /// Poisson mean of objects per cell.
    pub objects_lam: f64,
}

/// One captured scene.
pub struct Scene {
    pub width: usize,
    pub height: usize,
    /// Row-major H×W×3, f32 in [0, 1].  Checked out of the generator's
    /// buffer pool: dropping the scene returns the storage, so a
    /// generator allocates exactly one buffer per scene *in flight*,
    /// not one per capture.
    pub pixels: PixelBuf,
    pub boxes: Vec<GtBox>,
    /// Scene id (capture counter) for tracing through the pipeline.
    pub id: u64,
}

impl Scene {
    #[inline]
    pub fn px(&self, x: usize, y: usize) -> [f32; 3] {
        let i = (y * self.width + x) * 3;
        [self.pixels[i], self.pixels[i + 1], self.pixels[i + 2]]
    }

    #[inline]
    fn px_mut(&mut self, x: usize, y: usize) -> &mut [f32] {
        let i = (y * self.width + x) * 3;
        &mut self.pixels[i..i + 3]
    }

    pub fn size_bytes(&self) -> u64 {
        // The downlink models raw 8-bit RGB capture (3 bytes per pixel),
        // which is what a bent-pipe satellite would transmit.
        (self.width * self.height * 3) as u64
    }
}

/// Scene generator: deterministic stream of captures.
pub struct SceneGen {
    rng: Rng,
    pub spec: SceneSpec,
    /// Scene dimensions in cells (e.g. 8×8 cells = 512×512 px).
    pub cells_x: usize,
    pub cells_y: usize,
    counter: u64,
    /// Scene-buffer pool: dropped scenes hand their pixel storage back
    /// here, so steady-state capture is allocation-free.
    pool: PixelPool,
}

impl SceneGen {
    pub fn new(seed: u64, spec: SceneSpec, cells_x: usize, cells_y: usize) -> SceneGen {
        let pool = PixelPool::new(cells_x * CELL * cells_y * CELL * 3);
        SceneGen { rng: Rng::new(seed), spec, cells_x, cells_y, counter: 0, pool }
    }

    /// Scene-buffer pool accounting (allocs == max scenes in flight).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Capture the next scene.
    pub fn capture(&mut self) -> Scene {
        let (w, h) = (self.cells_x * CELL, self.cells_y * CELL);
        let id = self.counter;
        self.counter += 1;
        // dirty checkout: draw_background assigns every pixel of every
        // cell before objects/clouds read-modify them, so the clear the
        // zeroed checkout would do is pure overhead
        let pixels = self.pool.checkout_dirty();
        let mut scene = Scene { width: w, height: h, pixels, boxes: Vec::new(), id };
        for cy in 0..self.cells_y {
            for cx in 0..self.cells_x {
                let mut cell_rng = self.rng.fork((cy * self.cells_x + cx) as u64 + 1);
                draw_cell(&mut scene, cx * CELL, cy * CELL, &self.spec, &mut cell_rng);
            }
        }
        scene
    }
}

fn draw_cell(scene: &mut Scene, x0: usize, y0: usize, spec: &SceneSpec, rng: &mut Rng) {
    draw_background(scene, x0, y0, rng);
    let n = (rng.poisson(spec.objects_lam) as usize).min(4);
    for _ in 0..n {
        let class = rng.below(NUM_CLASSES as u64) as usize;
        if let Some(b) = draw_object(scene, x0, y0, class, rng) {
            scene.boxes.push(b);
        }
    }
    if rng.bool(spec.cloud_prob) {
        draw_cloud(scene, x0, y0, spec.cloud_density, rng);
    }
}

fn draw_background(scene: &mut Scene, x0: usize, y0: usize, rng: &mut Rng) {
    let base: [f32; 3] = if rng.bool(0.5) {
        [0.32, 0.38, 0.22] // land
    } else {
        [0.10, 0.22, 0.38] // sea
    };
    let fy = rng.range_f32(0.02, 0.08);
    let fx = rng.range_f32(0.02, 0.08);
    let p0 = rng.range_f32(0.0, std::f32::consts::TAU);
    let p1 = rng.range_f32(0.0, std::f32::consts::TAU);
    for dy in 0..CELL {
        for dx in 0..CELL {
            let tex = 0.05 * ((fy * dy as f32 + p0).sin() + (fx * dx as f32 + p1).cos());
            let px = scene.px_mut(x0 + dx, y0 + dy);
            for c in 0..3 {
                px[c] = (base[c] + tex + rng.normal_f32(0.0, 0.035)).clamp(0.0, 1.0);
            }
        }
    }
}

fn draw_object(scene: &mut Scene, x0: usize, y0: usize, class: usize, rng: &mut Rng) -> Option<GtBox> {
    let s = &SPECS[class];
    let mut w = rng.range_f32(s.size_lo, s.size_hi);
    let mut h = (w * s.aspect * rng.range_f32(0.8, 1.25)).clamp(3.0, CELL as f32 * 0.55);
    if s.shape == Shape::Rect && rng.bool(0.5) {
        std::mem::swap(&mut w, &mut h);
    }
    if w / 2.0 + 1.0 >= CELL as f32 - w / 2.0 - 1.0 || h / 2.0 + 1.0 >= CELL as f32 - h / 2.0 - 1.0 {
        return None;
    }
    let cx = rng.range_f32(w / 2.0 + 1.0, CELL as f32 - w / 2.0 - 1.0);
    let cy = rng.range_f32(h / 2.0 + 1.0, CELL as f32 - h / 2.0 - 1.0);
    let color = [
        s.rgb[0] + rng.normal_f32(0.0, 0.02),
        s.rgb[1] + rng.normal_f32(0.0, 0.02),
        s.rgb[2] + rng.normal_f32(0.0, 0.02),
    ];
    for dy in 0..CELL {
        for dx in 0..CELL {
            let (fx, fy) = (dx as f32, dy as f32);
            let hit = match s.shape {
                Shape::Disk => {
                    let nx = (fx - cx) / (w / 2.0);
                    let ny = (fy - cy) / (h / 2.0);
                    nx * nx + ny * ny <= 1.0
                }
                Shape::Rect => (fx - cx).abs() <= w / 2.0 && (fy - cy).abs() <= h / 2.0,
                Shape::Cross => {
                    let arm = (w / 5.0).max(2.0);
                    ((fx - cx).abs() <= w / 2.0 && (fy - cy).abs() <= arm / 2.0)
                        || ((fx - cx).abs() <= arm / 2.0 && (fy - cy).abs() <= h / 2.0)
                }
            };
            if hit {
                let px = scene.px_mut(x0 + dx, y0 + dy);
                for c in 0..3 {
                    px[c] = (0.75 * color[c] + 0.25 * px[c]).clamp(0.0, 1.0);
                }
            }
        }
    }
    Some(GtBox { cx: x0 as f32 + cx, cy: y0 as f32 + cy, w, h, class })
}

fn draw_cloud(scene: &mut Scene, x0: usize, y0: usize, density: f32, rng: &mut Rng) {
    let t = CELL as f32;
    let n_blobs = rng.range_usize(6, 12);
    let blobs: Vec<(f32, f32, f32, f32, f32)> = (0..n_blobs)
        .map(|_| {
            (
                rng.range_f32(-0.1 * t, 1.1 * t),
                rng.range_f32(-0.1 * t, 1.1 * t),
                rng.range_f32(t * 0.25, t * 0.7) * density,
                rng.range_f32(t * 0.25, t * 0.7) * density,
                rng.range_f32(1.0, 1.8),
            )
        })
        .collect();
    // Separable Gaussian: exp(-(nx²+ny²)) = exp(-nx²)·exp(-ny²).
    // Precomputing per-blob row/column factors removes the exp() from the
    // inner loop (perf pass: scene capture was the v1 pipeline bottleneck
    // after batch-plan calibration — see EXPERIMENTS.md §Perf).
    let col_f: Vec<[f32; CELL]> = blobs
        .iter()
        .map(|&(cx, _, sx, _, amp)| {
            std::array::from_fn(|dx| {
                let nx = (dx as f32 - cx) / sx;
                amp * (-(nx * nx)).exp()
            })
        })
        .collect();
    let row_f: Vec<[f32; CELL]> = blobs
        .iter()
        .map(|&(_, cy, _, sy, _)| {
            std::array::from_fn(|dy| {
                let ny = (dy as f32 - cy) / sy;
                (-(ny * ny)).exp()
            })
        })
        .collect();
    for dy in 0..CELL {
        for dx in 0..CELL {
            let mut alpha = 0.0f32;
            for b in 0..blobs.len() {
                alpha += col_f[b][dx] * row_f[b][dy];
            }
            let alpha = alpha.clamp(0.0, 1.0);
            if alpha > 0.01 {
                let cloud = (0.92 + rng.normal_f32(0.0, 0.02)).clamp(0.0, 1.0);
                let px = scene.px_mut(x0 + dx, y0 + dy);
                for c in px.iter_mut() {
                    *c = alpha * cloud + (1.0 - alpha) * *c;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Version;

    fn gen(version: Version, seed: u64) -> Scene {
        SceneGen::new(seed, version.spec(), 4, 4).capture()
    }

    #[test]
    fn scene_dimensions_and_range() {
        let s = gen(Version::V2, 1);
        assert_eq!(s.width, 256);
        assert_eq!(s.height, 256);
        assert_eq!(s.pixels.len(), 256 * 256 * 3);
        assert!(s.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen(Version::V1, 7);
        let b = gen(Version::V1, 7);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.boxes, b.boxes);
    }

    #[test]
    fn successive_captures_differ() {
        let mut g = SceneGen::new(3, Version::V2.spec(), 2, 2);
        let a = g.capture();
        let b = g.capture();
        assert_eq!(a.id, 0);
        assert_eq!(b.id, 1);
        assert_ne!(a.pixels, b.pixels);
    }

    #[test]
    fn boxes_inside_scene() {
        let s = gen(Version::V2, 5);
        for b in &s.boxes {
            assert!(b.cx >= 0.0 && b.cx <= s.width as f32);
            assert!(b.cy >= 0.0 && b.cy <= s.height as f32);
            assert!(b.class < NUM_CLASSES);
        }
    }

    #[test]
    fn v2_has_objects() {
        let s = gen(Version::V2, 11);
        assert!(!s.boxes.is_empty(), "v2 scene should contain objects");
    }

    #[test]
    fn v1_is_cloudier_than_v2() {
        // Proxy: mean luminance is higher under heavy cloud.
        let lum = |s: &Scene| s.pixels.iter().sum::<f32>() / s.pixels.len() as f32;
        let mut v1 = 0.0;
        let mut v2 = 0.0;
        for seed in 0..8 {
            v1 += lum(&gen(Version::V1, seed));
            v2 += lum(&gen(Version::V2, seed));
        }
        assert!(v1 > v2, "v1 lum {v1} should exceed v2 {v2}");
    }

    #[test]
    fn capture_reuses_the_scene_buffer() {
        let mut g = SceneGen::new(3, Version::V2.spec(), 2, 2);
        drop(g.capture());
        drop(g.capture());
        let s = g.pool_stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.allocs, 1, "second capture must reuse the returned buffer");
    }

    #[test]
    fn size_bytes_is_raw_rgb() {
        let s = gen(Version::V2, 1);
        assert_eq!(s.size_bytes(), 256 * 256 * 3);
    }
}
