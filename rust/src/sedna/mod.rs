//! Sedna-like collaborative-AI task layer (paper §3.3–3.4).
//!
//! Components mirror the paper: **GlobalManager** (cloud-side edge-AI
//! controller managing task CRDs), **LocalController** (edge-side process
//! control + state sync), **Worker** (runs the AI task), and **Lib** (the
//! API the application calls — here, the typed rust interfaces).
//!
//! Task kinds implemented: JointInference (drives the coordinator
//! pipeline), FederatedLearning ([`federated`]: FedAvg over rust-native
//! logistic-regression workers), IncrementalLearning ([`incremental`]:
//! drift-triggered onboard model hot-swap).  LifelongLearning is modeled
//! as IncrementalLearning with a persistent knowledge key in the
//! metastore.

pub mod federated;
pub mod incremental;

use std::collections::BTreeMap;

use crate::cluster::NodeId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    JointInference,
    FederatedLearning,
    IncrementalLearning,
    LifelongLearning,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskPhase {
    Pending,
    Running,
    Completed,
    Failed,
}

/// A Sedna task CRD.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    pub kind: TaskKind,
    /// Worker placements (edge nodes and/or cloud nodes).
    pub workers: Vec<NodeId>,
    /// Free-form parameters (mirrors CRD spec fields).
    pub params: BTreeMap<String, String>,
}

#[derive(Clone, Debug)]
pub struct TaskStatus {
    pub phase: TaskPhase,
    /// Per-worker phase as last reported by LocalControllers.
    pub worker_phase: BTreeMap<NodeId, TaskPhase>,
    pub message: String,
}

/// Cloud-side controller: owns task specs + aggregated status.
#[derive(Default)]
pub struct GlobalManager {
    tasks: BTreeMap<String, (TaskSpec, TaskStatus)>,
}

impl GlobalManager {
    pub fn new() -> GlobalManager {
        GlobalManager::default()
    }

    pub fn create(&mut self, spec: TaskSpec) -> anyhow::Result<()> {
        if self.tasks.contains_key(&spec.name) {
            anyhow::bail!("task {} already exists", spec.name);
        }
        let status = TaskStatus {
            phase: TaskPhase::Pending,
            worker_phase: spec.workers.iter().map(|w| (w.clone(), TaskPhase::Pending)).collect(),
            message: String::new(),
        };
        self.tasks.insert(spec.name.clone(), (spec, status));
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<(&TaskSpec, &TaskStatus)> {
        self.tasks.get(name).map(|(s, st)| (s, st))
    }

    /// LocalController reports a worker-phase transition; the task phase
    /// aggregates: any Failed -> Failed, all Completed -> Completed,
    /// any Running -> Running.
    pub fn report(&mut self, task: &str, worker: &NodeId, phase: TaskPhase) -> anyhow::Result<()> {
        let (_, status) =
            self.tasks.get_mut(task).ok_or_else(|| anyhow::anyhow!("unknown task {task}"))?;
        if !status.worker_phase.contains_key(worker) {
            anyhow::bail!("worker {worker} not in task {task}");
        }
        status.worker_phase.insert(worker.clone(), phase);
        let phases: Vec<TaskPhase> = status.worker_phase.values().copied().collect();
        status.phase = if phases.iter().any(|p| *p == TaskPhase::Failed) {
            TaskPhase::Failed
        } else if phases.iter().all(|p| *p == TaskPhase::Completed) {
            TaskPhase::Completed
        } else if phases.iter().any(|p| *p == TaskPhase::Running) {
            TaskPhase::Running
        } else {
            TaskPhase::Pending
        };
        Ok(())
    }

    pub fn tasks(&self) -> impl Iterator<Item = (&TaskSpec, &TaskStatus)> {
        self.tasks.values().map(|(s, st)| (s, st))
    }
}

/// Edge-side controller: local state machine for the tasks this node runs.
pub struct LocalController {
    pub node: NodeId,
    local: BTreeMap<String, TaskPhase>,
}

impl LocalController {
    pub fn new(node: NodeId) -> LocalController {
        LocalController { node, local: BTreeMap::new() }
    }

    pub fn start(&mut self, task: &str) -> TaskPhase {
        self.local.insert(task.to_string(), TaskPhase::Running);
        TaskPhase::Running
    }

    pub fn finish(&mut self, task: &str, ok: bool) -> TaskPhase {
        let p = if ok { TaskPhase::Completed } else { TaskPhase::Failed };
        self.local.insert(task.to_string(), p);
        p
    }

    pub fn phase(&self, task: &str) -> Option<TaskPhase> {
        self.local.get(task).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, workers: &[&str]) -> TaskSpec {
        TaskSpec {
            name: name.into(),
            kind: TaskKind::JointInference,
            workers: workers.iter().map(|w| NodeId::new(*w)).collect(),
            params: BTreeMap::new(),
        }
    }

    #[test]
    fn create_and_get() {
        let mut gm = GlobalManager::new();
        gm.create(spec("ji", &["baoyun", "ground"])).unwrap();
        let (s, st) = gm.get("ji").unwrap();
        assert_eq!(s.kind, TaskKind::JointInference);
        assert_eq!(st.phase, TaskPhase::Pending);
    }

    #[test]
    fn duplicate_create_rejected() {
        let mut gm = GlobalManager::new();
        gm.create(spec("ji", &["baoyun"])).unwrap();
        assert!(gm.create(spec("ji", &["baoyun"])).is_err());
    }

    #[test]
    fn phase_aggregation() {
        let mut gm = GlobalManager::new();
        gm.create(spec("ji", &["baoyun", "ground"])).unwrap();
        let (b, g) = (NodeId::new("baoyun"), NodeId::new("ground"));
        gm.report("ji", &b, TaskPhase::Running).unwrap();
        assert_eq!(gm.get("ji").unwrap().1.phase, TaskPhase::Running);
        gm.report("ji", &b, TaskPhase::Completed).unwrap();
        assert_eq!(gm.get("ji").unwrap().1.phase, TaskPhase::Pending); // g pending
        gm.report("ji", &g, TaskPhase::Completed).unwrap();
        assert_eq!(gm.get("ji").unwrap().1.phase, TaskPhase::Completed);
    }

    #[test]
    fn any_failure_fails_task() {
        let mut gm = GlobalManager::new();
        gm.create(spec("ji", &["baoyun", "ground"])).unwrap();
        gm.report("ji", &NodeId::new("ground"), TaskPhase::Failed).unwrap();
        assert_eq!(gm.get("ji").unwrap().1.phase, TaskPhase::Failed);
    }

    #[test]
    fn unknown_worker_report_rejected() {
        let mut gm = GlobalManager::new();
        gm.create(spec("ji", &["baoyun"])).unwrap();
        assert!(gm.report("ji", &NodeId::new("ghost"), TaskPhase::Running).is_err());
    }

    #[test]
    fn local_controller_state_machine() {
        let mut lc = LocalController::new(NodeId::new("baoyun"));
        assert_eq!(lc.phase("ji"), None);
        assert_eq!(lc.start("ji"), TaskPhase::Running);
        assert_eq!(lc.finish("ji", true), TaskPhase::Completed);
        assert_eq!(lc.phase("ji"), Some(TaskPhase::Completed));
    }
}
