//! IncrementalLearning protocol (paper §3.4).
//!
//! "Satellites continuously collect newly generated data and train models
//! in the cloud. The satellite nodes regularly fine-tune the model from
//! the cloud to improve accuracy."
//!
//! The heavy lifting (retraining) happened at build time: `tinydet_v2` is
//! the same onboard architecture trained ~3x longer (python/compile/
//! aot.py).  This module is the *protocol*: a drift monitor watches the
//! onboard detector's confidence statistics; when quality degrades below
//! a threshold, it requests a model update; the update "downlinks" the
//! new weights over the uplink channel and hot-swaps the serving model.

use crate::runtime::Model;

/// Exponentially-weighted confidence monitor.
pub struct DriftMonitor {
    /// EMA of mean top-detection confidence per batch.
    ema: f64,
    alpha: f64,
    /// Below this, request an update.
    pub threshold: f64,
    observations: u64,
    /// Minimum observations before a trigger is considered valid.
    pub min_obs: u64,
}

impl DriftMonitor {
    pub fn new(threshold: f64) -> DriftMonitor {
        DriftMonitor { ema: 1.0, alpha: 0.1, threshold, observations: 0, min_obs: 10 }
    }

    pub fn observe(&mut self, mean_confidence: f64) {
        self.observations += 1;
        self.ema = if self.observations == 1 {
            mean_confidence
        } else {
            (1.0 - self.alpha) * self.ema + self.alpha * mean_confidence
        };
    }

    pub fn ema(&self) -> f64 {
        self.ema
    }

    pub fn should_update(&self) -> bool {
        self.observations >= self.min_obs && self.ema < self.threshold
    }
}

/// The onboard model slot: which artifact currently serves.
pub struct ModelSlot {
    pub current: Model,
    pub version: u32,
    pub updates_applied: u32,
}

impl ModelSlot {
    pub fn new() -> ModelSlot {
        ModelSlot { current: Model::Tiny, version: 1, updates_applied: 0 }
    }

    /// Hot-swap to the incrementally-trained artifact.  Returns the bytes
    /// that must cross the uplink (the weight file size) so callers can
    /// account link cost.
    pub fn apply_update(&mut self, weight_bytes: u64) -> u64 {
        self.current = Model::TinyV2;
        self.version += 1;
        self.updates_applied += 1;
        weight_bytes
    }
}

impl Default for ModelSlot {
    fn default() -> Self {
        Self::new()
    }
}

/// One protocol step: observe a batch, maybe trigger + apply an update.
/// Returns Some(uplink_bytes) when an update fired.
pub fn step(
    monitor: &mut DriftMonitor,
    slot: &mut ModelSlot,
    mean_confidence: f64,
    weight_bytes: u64,
) -> Option<u64> {
    monitor.observe(mean_confidence);
    if slot.current == Model::Tiny && monitor.should_update() {
        Some(slot.apply_update(weight_bytes))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_trigger_before_min_obs() {
        let mut m = DriftMonitor::new(0.9);
        for _ in 0..5 {
            m.observe(0.1);
        }
        assert!(!m.should_update(), "needs min_obs");
    }

    #[test]
    fn sustained_low_confidence_triggers() {
        let mut m = DriftMonitor::new(0.5);
        for _ in 0..20 {
            m.observe(0.2);
        }
        assert!(m.should_update());
    }

    #[test]
    fn high_confidence_never_triggers() {
        let mut m = DriftMonitor::new(0.5);
        for _ in 0..100 {
            m.observe(0.8);
        }
        assert!(!m.should_update());
    }

    #[test]
    fn ema_tracks_recent() {
        let mut m = DriftMonitor::new(0.5);
        for _ in 0..30 {
            m.observe(0.9);
        }
        for _ in 0..60 {
            m.observe(0.1);
        }
        assert!(m.ema() < 0.2);
    }

    #[test]
    fn swap_applies_once() {
        let mut mon = DriftMonitor::new(0.5);
        let mut slot = ModelSlot::new();
        let mut total_up = 0;
        for _ in 0..50 {
            if let Some(b) = step(&mut mon, &mut slot, 0.2, 57_930) {
                total_up += b;
            }
        }
        assert_eq!(slot.current, Model::TinyV2);
        assert_eq!(slot.updates_applied, 1, "update must be idempotent");
        assert_eq!(total_up, 57_930);
        assert_eq!(slot.version, 2);
    }
}
