//! FederatedLearning protocol (paper §3.4).
//!
//! "The satellite trains the model and transmits the parameters (i.e.,
//! training weights) to the cloud responsible for parameter aggregation."
//!
//! Real math, rust-native: each satellite worker holds a private,
//! non-IID synthetic dataset and trains a logistic-regression classifier
//! by local SGD; the cloud aggregates with FedAvg (weighted by sample
//! count).  Raw data never leaves the workers — only weights move, which
//! is the privacy property the paper motivates.  The uplink cost of one
//! round is `dim * 4` bytes per worker (weights as f32), which examples
//! account against the 0.1–1 Mbps uplink.
//!
//! Two layers:
//!
//! * the math — [`make_shard`]/[`fleet_shards`], [`local_train`],
//!   [`fedavg`], and [`train_schedule`], the partial-participation
//!   FedAvg loop: each round averages whichever subset of workers
//!   participated, and a round with no contributing samples keeps the
//!   previous global (the divide-by-zero guard in [`fedavg`]);
//! * the schedule — [`FedScheduler`], the mission-time round clock the
//!   constellation driver and `power::fly_federated_mission` poll:
//!   rounds fire every `round_interval_s` of virtual time, each gated on
//!   the satellite's battery state of charge (train only at or above
//!   `min_soc`, the power-limited constraint of arXiv:2111.12769), with
//!   skipped rounds reported in [`FederatedStats::rounds_skipped_power`].

use crate::config::FederatedConfig;
use crate::util::rng::Rng;

/// Modeled Pi-class local-SGD time per (sample × epoch) — ~500 samples/s
/// through an 8-D logistic model.  Drives the training energy burst and
/// the weights' uplink `ready_at`, not wallclock.
pub const TRAIN_S_PER_SAMPLE_EPOCH: f64 = 0.002;

/// Virtual seconds one local round trains for.
pub fn train_seconds(epochs: usize, samples_per_node: usize) -> f64 {
    (epochs * samples_per_node) as f64 * TRAIN_S_PER_SAMPLE_EPOCH
}

/// Logistic-regression model: w (dim) + bias.
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
    pub b: f32,
}

impl LinearModel {
    pub fn zeros(dim: usize) -> LinearModel {
        LinearModel { w: vec![0.0; dim], b: 0.0 }
    }

    pub fn predict(&self, x: &[f32]) -> f32 {
        let z: f32 = self.w.iter().zip(x).map(|(w, x)| w * x).sum::<f32>() + self.b;
        1.0 / (1.0 + (-z).exp())
    }

    pub fn wire_bytes(&self) -> u64 {
        (self.w.len() as u64 + 1) * 4
    }
}

/// Uplink bytes for one round of a `dim`-weight model (weights + bias as
/// f32) — what the constellation charges against the downlink queue
/// without materializing the model first.
pub fn wire_bytes_for_dim(dim: usize) -> u64 {
    (dim as u64 + 1) * 4
}

/// A worker's private shard.
pub struct Shard {
    pub xs: Vec<Vec<f32>>,
    pub ys: Vec<f32>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }
}

/// Generate `n` samples of a `dim`-D two-class problem.  `skew` shifts the
/// class balance and feature means per worker — the non-IID-ness the
/// paper attributes to "satellite data are inconsistently in spatial and
/// temporal distribution".
pub fn make_shard(seed: u64, n: usize, dim: usize, skew: f32) -> Shard {
    let mut rng = Rng::new(seed);
    // Common ground-truth separator shared by every worker's distribution.
    let mut truth = Rng::new(424242);
    let w_true: Vec<f32> = (0..dim).map(|_| truth.normal_f32(0.0, 1.0)).collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    let p_pos = (0.5 + 0.35 * skew).clamp(0.1, 0.9) as f64;
    for _ in 0..n {
        let y = if rng.bool(p_pos) { 1.0f32 } else { 0.0 };
        let x: Vec<f32> = w_true
            .iter()
            .map(|&wt| {
                let mu = if y > 0.5 { 0.8 * wt } else { -0.8 * wt };
                // per-worker covariate shift
                mu + 0.4 * skew + rng.normal_f32(0.0, 1.0)
            })
            .collect();
        xs.push(x);
        ys.push(y);
    }
    Shard { xs, ys }
}

/// One non-IID shard per worker, skew spread linearly across the fleet —
/// the spread [`run_federated`] has always used, factored out so the
/// constellation can seed the identical shards per satellite plane.
pub fn fleet_shards(n_workers: usize, samples_per_worker: usize, dim: usize, seed: u64) -> Vec<Shard> {
    (0..n_workers)
        .map(|i| {
            let skew = if n_workers == 1 {
                0.0
            } else {
                -1.0 + 2.0 * i as f32 / (n_workers - 1) as f32
            };
            make_shard(seed + i as u64, samples_per_worker, dim, skew)
        })
        .collect()
}

/// One worker's local training: `epochs` of SGD from the global weights.
pub fn local_train(global: &LinearModel, shard: &Shard, epochs: usize, lr: f32, seed: u64) -> LinearModel {
    let mut m = global.clone();
    let mut rng = Rng::new(seed);
    let n = shard.len();
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let x = &shard.xs[i];
            let err = m.predict(x) - shard.ys[i];
            for (w, &xi) in m.w.iter_mut().zip(x) {
                *w -= lr * err * xi;
            }
            m.b -= lr * err;
        }
    }
    m
}

/// FedAvg: sample-count-weighted average of the participating worker
/// models.  Returns `None` when there is nothing to average — no
/// participants, or every participating shard is empty (`total == 0`).
/// The old unconditional division poisoned the global with NaNs on such
/// rounds; callers keep the previous global instead, which is
/// load-bearing once power gating can shrink the participant set to
/// nothing.
pub fn fedavg(models: &[(LinearModel, usize)]) -> Option<LinearModel> {
    let total: f32 = models.iter().map(|(_, n)| *n as f32).sum();
    if models.is_empty() || total <= 0.0 {
        return None;
    }
    let dim = models[0].0.w.len();
    let mut out = LinearModel::zeros(dim);
    for (m, n) in models {
        let a = *n as f32 / total;
        for (o, w) in out.w.iter_mut().zip(&m.w) {
            *o += a * w;
        }
        out.b += a * m.b;
    }
    Some(out)
}

pub fn accuracy(m: &LinearModel, shard: &Shard) -> f64 {
    if shard.is_empty() {
        return 0.0;
    }
    let correct = shard
        .xs
        .iter()
        .zip(&shard.ys)
        .filter(|(x, &y)| (m.predict(x) > 0.5) == (y > 0.5))
        .count();
    correct as f64 / shard.len() as f64
}

/// Outcome of [`train_schedule`]: the aggregated global model plus the
/// round-by-round accounting the fleet report surfaces.
#[derive(Clone, Debug)]
pub struct FleetTrainingReport {
    pub global: LinearModel,
    /// Global test accuracy after each round (held rounds repeat the
    /// previous value — the global did not move).
    pub acc_history: Vec<f64>,
    /// Total weight bytes the participating workers uplinked.
    pub uplink_bytes: u64,
    /// Rounds where FedAvg aggregated at least one sample-bearing model.
    pub rounds_aggregated: usize,
    /// Rounds where no participant contributed samples: the previous
    /// global was kept (the [`fedavg`] guard in action).
    pub rounds_held: usize,
}

impl FleetTrainingReport {
    pub fn final_accuracy(&self) -> f64 {
        self.acc_history.last().copied().unwrap_or(0.0)
    }
}

/// Partial-participation FedAvg over `rounds` rounds: worker `w` trains
/// in round `r` only when `participates(r, w)`.  With full participation
/// this is exactly the classic loop [`run_federated`] runs; with a
/// power-gated schedule each round averages whichever subset the
/// satellites' batteries allowed, and an empty round keeps the previous
/// global.
pub fn train_schedule(
    shards: &[Shard],
    test: &Shard,
    rounds: usize,
    mut participates: impl FnMut(usize, usize) -> bool,
    epochs: usize,
    lr: f32,
    dim: usize,
    seed: u64,
) -> FleetTrainingReport {
    let n_workers = shards.len();
    let mut global = LinearModel::zeros(dim);
    let mut acc_history = Vec::with_capacity(rounds);
    let mut uplink_bytes = 0u64;
    let mut rounds_aggregated = 0usize;
    let mut rounds_held = 0usize;
    for r in 0..rounds {
        let locals: Vec<(LinearModel, usize)> = shards
            .iter()
            .enumerate()
            .filter(|(i, _)| participates(r, *i))
            .map(|(i, s)| {
                let m = local_train(&global, s, epochs, lr, seed + 100 + (r * n_workers + i) as u64);
                uplink_bytes += m.wire_bytes();
                (m, s.len())
            })
            .collect();
        match fedavg(&locals) {
            Some(g) => {
                global = g;
                rounds_aggregated += 1;
            }
            None => rounds_held += 1,
        }
        acc_history.push(accuracy(&global, test));
    }
    FleetTrainingReport { global, acc_history, uplink_bytes, rounds_aggregated, rounds_held }
}

/// Run `rounds` of federated training over `n_workers` non-IID shards.
/// Returns (model, per-round test accuracy, total uplink bytes).
pub fn run_federated(
    n_workers: usize,
    rounds: usize,
    samples_per_worker: usize,
    dim: usize,
    seed: u64,
) -> (LinearModel, Vec<f64>, u64) {
    let shards = fleet_shards(n_workers, samples_per_worker, dim, seed);
    let test = make_shard(seed + 10_000, 2000, dim, 0.0);
    let rep = train_schedule(&shards, &test, rounds, |_, _| true, 2, 0.05, dim, seed);
    (rep.global, rep.acc_history, rep.uplink_bytes)
}

/// Per-satellite federated scheduling outcome — the counters that must
/// reconcile (`rounds_completed + rounds_skipped_power +
/// rounds_skipped_crash == rounds_scheduled`) and the per-round
/// participant record the fleet aggregation replays.
#[derive(Clone, Debug, Default)]
pub struct FederatedStats {
    /// Rounds the mission horizon schedules (one per `round_interval_s`).
    pub rounds_scheduled: u64,
    /// Rounds this satellite trained and uplinked weights for.
    pub rounds_completed: u64,
    /// Rounds skipped because SoC sat below the `min_soc` gate.
    pub rounds_skipped_power: u64,
    /// Rounds skipped because the satellite was dark (chaos `NodeCrash`)
    /// when the round came due.  A crashed round never trains and never
    /// uplinks — it is reported as its own skip class rather than
    /// corrupting the global with a partial contribution.
    pub rounds_skipped_crash: u64,
    /// Weight bytes queued for uplink (`wire_bytes` per completed round).
    pub uplink_bytes: u64,
    /// Per-round participation, indexed by round.
    pub participated: Vec<bool>,
}

/// One scheduling decision: round `round` fired at virtual time `due_s`.
#[derive(Clone, Copy, Debug)]
pub struct RoundDecision {
    pub round: usize,
    pub due_s: f64,
    pub participated: bool,
    /// The satellite was crashed at `due_s` — takes precedence over the
    /// power gate (a dark satellite cannot even read its SoC).
    pub crashed: bool,
}

impl RoundDecision {
    /// Flight-recorder verdict for this round: the payload of the
    /// `TrainingRound` span the drivers emit (due time → due + the
    /// training burst for participated rounds, instantaneous for
    /// skipped ones).
    pub fn trace_verdict(&self) -> crate::telemetry::trace::RoundVerdict {
        if self.participated {
            crate::telemetry::trace::RoundVerdict::Participated
        } else if self.crashed {
            crate::telemetry::trace::RoundVerdict::SkippedCrash
        } else {
            crate::telemetry::trace::RoundVerdict::SkippedPower
        }
    }
}

/// Mission-time round clock for one satellite.  Rounds are due at
/// `round_interval_s * (r + 1)`; the caller polls with its current
/// mission time and (when the power subsystem is on) battery SoC, and
/// the scheduler decides every round that has come due: participate at
/// or above `min_soc`, skip below it.  Decisions are functions of
/// mission time and SoC alone, so governed runs stay deterministic.
#[derive(Clone, Debug)]
pub struct FedScheduler {
    interval_s: f64,
    min_soc: f64,
    wire_bytes: u64,
    rounds_scheduled: usize,
    next_round: usize,
    pub stats: FederatedStats,
}

impl FedScheduler {
    pub fn new(fed: &FederatedConfig, horizon_s: f64) -> FedScheduler {
        let rounds_scheduled = Self::rounds_in(horizon_s, fed.round_interval_s);
        FedScheduler {
            interval_s: fed.round_interval_s,
            min_soc: fed.min_soc,
            wire_bytes: wire_bytes_for_dim(fed.dim),
            rounds_scheduled,
            next_round: 0,
            stats: FederatedStats {
                rounds_scheduled: rounds_scheduled as u64,
                ..FederatedStats::default()
            },
        }
    }

    /// Rounds a mission horizon schedules at a given interval — shared
    /// by the scheduler and the fleet aggregation so they can never
    /// disagree on the round count.
    pub fn rounds_in(horizon_s: f64, interval_s: f64) -> usize {
        if interval_s <= 0.0 {
            return 0;
        }
        (horizon_s / interval_s).floor().max(0.0) as usize
    }

    /// Uplink bytes one completed round queues.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes
    }

    /// Due time of the next undecided round, if any remain.
    pub fn due_next(&self) -> Option<f64> {
        if self.next_round < self.rounds_scheduled {
            Some((self.next_round as f64 + 1.0) * self.interval_s)
        } else {
            None
        }
    }

    /// Decide every round due by mission time `t` with the SoC observed
    /// now (`None` = no power subsystem, nothing skips).
    pub fn poll(&mut self, t: f64, soc: Option<f64>) -> Vec<RoundDecision> {
        self.poll_gated(t, soc, |_| false)
    }

    /// [`Self::poll`] with a chaos crash gate: `crashed(due_s)` reports
    /// whether the satellite was dark at the round's due time.  The
    /// per-due-time query (rather than a single flag) keeps decisions a
    /// pure function of mission time, so a poll that flushes several
    /// overdue rounds classifies each against its own due instant.  The
    /// nominal [`Self::poll`] is this with an always-false gate.
    pub fn poll_gated(
        &mut self,
        t: f64,
        soc: Option<f64>,
        crashed: impl Fn(f64) -> bool,
    ) -> Vec<RoundDecision> {
        let mut out = Vec::new();
        while let Some(due) = self.due_next().filter(|d| *d <= t) {
            out.push(self.decide(due, soc, crashed(due)));
        }
        out
    }

    /// Decide every round still outstanding — the end-of-mission flush,
    /// immune to f64 rounding at the horizon boundary.
    pub fn finish(&mut self, soc: Option<f64>) -> Vec<RoundDecision> {
        self.finish_gated(soc, |_| false)
    }

    /// [`Self::finish`] with a chaos crash gate (see
    /// [`Self::poll_gated`]).
    pub fn finish_gated(
        &mut self,
        soc: Option<f64>,
        crashed: impl Fn(f64) -> bool,
    ) -> Vec<RoundDecision> {
        let mut out = Vec::new();
        while let Some(due) = self.due_next() {
            out.push(self.decide(due, soc, crashed(due)));
        }
        out
    }

    fn decide(&mut self, due_s: f64, soc: Option<f64>, crashed: bool) -> RoundDecision {
        // crash precedence: a dark satellite never consults the power
        // gate; `None` soc = no power subsystem, that gate is inert
        let participated = !crashed
            && match soc {
                Some(s) => s >= self.min_soc,
                None => true,
            };
        let round = self.next_round;
        self.next_round += 1;
        self.stats.participated.push(participated);
        if participated {
            self.stats.rounds_completed += 1;
            self.stats.uplink_bytes += self.wire_bytes;
        } else if crashed {
            self.stats.rounds_skipped_crash += 1;
        } else {
            self.stats.rounds_skipped_power += 1;
        }
        RoundDecision { round, due_s, participated, crashed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weighted_mean() {
        let a = LinearModel { w: vec![1.0, 0.0], b: 1.0 };
        let b = LinearModel { w: vec![0.0, 1.0], b: 0.0 };
        let m = fedavg(&[(a, 100), (b, 300)]).unwrap();
        assert!((m.w[0] - 0.25).abs() < 1e-6);
        assert!((m.w[1] - 0.75).abs() < 1e-6);
        assert!((m.b - 0.25).abs() < 1e-6);
    }

    #[test]
    fn fedavg_guards_zero_total() {
        // regression: an empty round or all-empty shards used to divide
        // by zero and fill the global with NaNs
        assert!(fedavg(&[]).is_none());
        let m = LinearModel::zeros(4);
        assert!(fedavg(&[(m.clone(), 0), (m, 0)]).is_none());
    }

    #[test]
    fn empty_participation_keeps_previous_global_nan_free() {
        let shards = fleet_shards(3, 100, 8, 1);
        let test = make_shard(10_001, 500, 8, 0.0);
        // every round skipped: the global never moves and never poisons
        let rep = train_schedule(&shards, &test, 5, |_, _| false, 2, 0.05, 8, 1);
        assert_eq!(rep.rounds_aggregated, 0);
        assert_eq!(rep.rounds_held, 5);
        assert_eq!(rep.uplink_bytes, 0);
        assert!(rep.global.w.iter().all(|w| w.is_finite()) && rep.global.b.is_finite());
        // zero-sample shards participating must not poison either: the
        // models cross the wire but there is nothing to average
        let empty = fleet_shards(3, 0, 8, 1);
        let rep2 = train_schedule(&empty, &test, 3, |_, _| true, 2, 0.05, 8, 1);
        assert_eq!(rep2.rounds_held, 3);
        assert_eq!(rep2.uplink_bytes, 3 * 3 * 36);
        assert!(rep2.global.w.iter().all(|w| w.is_finite()) && rep2.global.b.is_finite());
    }

    #[test]
    fn partial_participation_still_converges() {
        let shards = fleet_shards(4, 400, 8, 7);
        let test = make_shard(7 + 10_000, 2000, 8, 0.0);
        // a rotating worker drops out every round
        let rep = train_schedule(&shards, &test, 12, |r, w| w != r % 4, 2, 0.05, 8, 7);
        assert_eq!(rep.rounds_aggregated, 12);
        assert_eq!(rep.rounds_held, 0);
        let f = rep.final_accuracy();
        assert!(f > 0.8, "partial-participation accuracy {f}");
        // 3 of 4 workers ship weights each round
        assert_eq!(rep.uplink_bytes, 12 * 3 * 36);
    }

    #[test]
    fn scheduler_counters_reconcile() {
        let fed = FederatedConfig {
            enabled: true,
            round_interval_s: 100.0,
            ..FederatedConfig::default()
        };
        let mut s = FedScheduler::new(&fed, 1000.0);
        assert_eq!(s.stats.rounds_scheduled, 10);
        // below the gate for the first half of the mission
        let d1 = s.poll(500.0, Some(fed.min_soc - 0.1));
        assert_eq!(d1.len(), 5);
        assert!(d1.iter().all(|d| !d.participated));
        assert!((d1[0].due_s - 100.0).abs() < 1e-9);
        // nothing new until time moves
        assert!(s.poll(500.0, Some(1.0)).is_empty());
        // above the gate for the rest; finish flushes to the horizon
        let d2 = s.finish(Some(fed.min_soc + 0.1));
        assert_eq!(d2.len(), 5);
        assert!(d2.iter().all(|d| d.participated));
        assert_eq!(
            s.stats.rounds_completed + s.stats.rounds_skipped_power + s.stats.rounds_skipped_crash,
            s.stats.rounds_scheduled
        );
        assert_eq!(s.stats.participated.len() as u64, s.stats.rounds_scheduled);
        assert_eq!(s.stats.uplink_bytes, 5 * s.wire_bytes());
    }

    #[test]
    fn scheduler_without_power_never_skips() {
        let fed = FederatedConfig {
            enabled: true,
            round_interval_s: 500.0,
            min_soc: 0.99,
            ..FederatedConfig::default()
        };
        let mut s = FedScheduler::new(&fed, 5_000.0);
        // soc = None (power subsystem off): the gate is inert
        let d = s.poll(5_000.0, None);
        assert_eq!(d.len(), 10);
        assert!(d.iter().all(|x| x.participated));
        assert!(s.finish(None).is_empty());
        assert_eq!(s.stats.rounds_skipped_power, 0);
    }

    #[test]
    fn round_decisions_map_to_trace_verdicts() {
        use crate::telemetry::trace::RoundVerdict;
        let went = RoundDecision { round: 0, due_s: 100.0, participated: true, crashed: false };
        let skipped = RoundDecision { round: 1, due_s: 200.0, participated: false, crashed: false };
        let dark = RoundDecision { round: 2, due_s: 300.0, participated: false, crashed: true };
        assert_eq!(went.trace_verdict(), RoundVerdict::Participated);
        assert_eq!(skipped.trace_verdict(), RoundVerdict::SkippedPower);
        assert_eq!(dark.trace_verdict(), RoundVerdict::SkippedCrash);
    }

    #[test]
    fn crash_gate_reports_its_own_skip_class() {
        let fed = FederatedConfig {
            enabled: true,
            round_interval_s: 100.0,
            ..FederatedConfig::default()
        };
        let mut s = FedScheduler::new(&fed, 1000.0);
        // dark for rounds due in [200, 500): rounds 2-4 crash-skip even
        // though SoC is healthy; crash takes precedence over power
        let crashed = |due: f64| (200.0..500.0).contains(&due);
        let d = s.poll_gated(600.0, Some(fed.min_soc - 0.1), crashed);
        assert_eq!(d.len(), 6);
        let crash_skipped: Vec<usize> =
            d.iter().filter(|x| x.crashed).map(|x| x.round).collect();
        assert_eq!(crash_skipped, vec![1, 2, 3], "rounds due at 200/300/400 were dark");
        assert!(d.iter().filter(|x| x.crashed).all(|x| !x.participated));
        // healthy SoC for the flush: the remaining rounds participate
        let d2 = s.finish_gated(Some(fed.min_soc + 0.1), |_| false);
        assert_eq!(d2.len(), 4);
        assert!(d2.iter().all(|x| x.participated));
        assert_eq!(s.stats.rounds_skipped_crash, 3);
        assert_eq!(s.stats.rounds_skipped_power, 3, "rounds due at 100/500/600 power-skipped");
        assert_eq!(s.stats.rounds_completed, 4);
        assert_eq!(
            s.stats.rounds_completed + s.stats.rounds_skipped_power + s.stats.rounds_skipped_crash,
            s.stats.rounds_scheduled
        );
        // crashed rounds queue no uplink bytes
        assert_eq!(s.stats.uplink_bytes, 4 * s.wire_bytes());
    }

    #[test]
    fn shards_are_non_iid() {
        let a = make_shard(1, 500, 8, -1.0);
        let b = make_shard(2, 500, 8, 1.0);
        let pos_a = a.ys.iter().filter(|&&y| y > 0.5).count() as f64 / 500.0;
        let pos_b = b.ys.iter().filter(|&&y| y > 0.5).count() as f64 / 500.0;
        assert!(pos_b - pos_a > 0.3, "{pos_a} vs {pos_b}");
    }

    #[test]
    fn federated_training_converges() {
        let (_m, acc, _bytes) = run_federated(4, 12, 400, 8, 7);
        let final_acc = *acc.last().unwrap();
        assert!(final_acc > 0.85, "final accuracy {final_acc}");
        // logistic regression can already converge in round 1 on this
        // problem; require non-degradation rather than strict improvement
        assert!(final_acc >= acc[0] - 0.02, "regressed: {acc:?}");
    }

    #[test]
    fn federated_beats_single_skewed_worker() {
        let (global, _, _) = run_federated(4, 12, 400, 8, 7);
        // a single worker trained only on its skewed shard
        let shard = make_shard(7, 400, 8, -1.0);
        let solo = local_train(&LinearModel::zeros(8), &shard, 24, 0.05, 99);
        let test = make_shard(7 + 10_000, 2000, 8, 0.0);
        assert!(accuracy(&global, &test) > accuracy(&solo, &test));
    }

    #[test]
    fn uplink_accounting() {
        let (_, _, bytes) = run_federated(3, 5, 100, 8, 1);
        // 3 workers * 5 rounds * (8+1)*4 bytes
        assert_eq!(bytes, 3 * 5 * 36);
        assert_eq!(wire_bytes_for_dim(8), 36);
    }

    #[test]
    fn only_weights_cross_the_wire() {
        let m = LinearModel::zeros(16);
        assert_eq!(m.wire_bytes(), 17 * 4);
        assert_eq!(m.wire_bytes(), wire_bytes_for_dim(16));
        // raw shard would be orders of magnitude larger
        let shard_bytes = 400 * 16 * 4;
        assert!(m.wire_bytes() * 100 < shard_bytes);
    }
}
