//! FederatedLearning protocol (paper §3.4).
//!
//! "The satellite trains the model and transmits the parameters (i.e.,
//! training weights) to the cloud responsible for parameter aggregation."
//!
//! Real math, rust-native: each satellite worker holds a private,
//! non-IID synthetic dataset and trains a logistic-regression classifier
//! by local SGD; the cloud aggregates with FedAvg (weighted by sample
//! count).  Raw data never leaves the workers — only weights move, which
//! is the privacy property the paper motivates.  The uplink cost of one
//! round is `dim * 4` bytes per worker (weights as f32), which examples
//! account against the 0.1–1 Mbps uplink.

use crate::util::rng::Rng;

/// Logistic-regression model: w (dim) + bias.
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
    pub b: f32,
}

impl LinearModel {
    pub fn zeros(dim: usize) -> LinearModel {
        LinearModel { w: vec![0.0; dim], b: 0.0 }
    }

    pub fn predict(&self, x: &[f32]) -> f32 {
        let z: f32 = self.w.iter().zip(x).map(|(w, x)| w * x).sum::<f32>() + self.b;
        1.0 / (1.0 + (-z).exp())
    }

    pub fn wire_bytes(&self) -> u64 {
        (self.w.len() as u64 + 1) * 4
    }
}

/// A worker's private shard.
pub struct Shard {
    pub xs: Vec<Vec<f32>>,
    pub ys: Vec<f32>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }
}

/// Generate `n` samples of a `dim`-D two-class problem.  `skew` shifts the
/// class balance and feature means per worker — the non-IID-ness the
/// paper attributes to "satellite data are inconsistently in spatial and
/// temporal distribution".
pub fn make_shard(seed: u64, n: usize, dim: usize, skew: f32) -> Shard {
    let mut rng = Rng::new(seed);
    // Common ground-truth separator shared by every worker's distribution.
    let mut truth = Rng::new(424242);
    let w_true: Vec<f32> = (0..dim).map(|_| truth.normal_f32(0.0, 1.0)).collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    let p_pos = (0.5 + 0.35 * skew).clamp(0.1, 0.9) as f64;
    for _ in 0..n {
        let y = if rng.bool(p_pos) { 1.0f32 } else { 0.0 };
        let x: Vec<f32> = w_true
            .iter()
            .map(|&wt| {
                let mu = if y > 0.5 { 0.8 * wt } else { -0.8 * wt };
                // per-worker covariate shift
                mu + 0.4 * skew + rng.normal_f32(0.0, 1.0)
            })
            .collect();
        xs.push(x);
        ys.push(y);
    }
    Shard { xs, ys }
}

/// One worker's local training: `epochs` of SGD from the global weights.
pub fn local_train(global: &LinearModel, shard: &Shard, epochs: usize, lr: f32, seed: u64) -> LinearModel {
    let mut m = global.clone();
    let mut rng = Rng::new(seed);
    let n = shard.len();
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let x = &shard.xs[i];
            let err = m.predict(x) - shard.ys[i];
            for (w, &xi) in m.w.iter_mut().zip(x) {
                *w -= lr * err * xi;
            }
            m.b -= lr * err;
        }
    }
    m
}

/// FedAvg: sample-count-weighted average of worker models.
pub fn fedavg(models: &[(LinearModel, usize)]) -> LinearModel {
    assert!(!models.is_empty());
    let dim = models[0].0.w.len();
    let total: f32 = models.iter().map(|(_, n)| *n as f32).sum();
    let mut out = LinearModel::zeros(dim);
    for (m, n) in models {
        let a = *n as f32 / total;
        for (o, w) in out.w.iter_mut().zip(&m.w) {
            *o += a * w;
        }
        out.b += a * m.b;
    }
    out
}

pub fn accuracy(m: &LinearModel, shard: &Shard) -> f64 {
    if shard.is_empty() {
        return 0.0;
    }
    let correct = shard
        .xs
        .iter()
        .zip(&shard.ys)
        .filter(|(x, &y)| (m.predict(x) > 0.5) == (y > 0.5))
        .count();
    correct as f64 / shard.len() as f64
}

/// Run `rounds` of federated training over `n_workers` non-IID shards.
/// Returns (model, per-round test accuracy, total uplink bytes).
pub fn run_federated(
    n_workers: usize,
    rounds: usize,
    samples_per_worker: usize,
    dim: usize,
    seed: u64,
) -> (LinearModel, Vec<f64>, u64) {
    let shards: Vec<Shard> = (0..n_workers)
        .map(|i| {
            let skew = if n_workers == 1 {
                0.0
            } else {
                -1.0 + 2.0 * i as f32 / (n_workers - 1) as f32
            };
            make_shard(seed + i as u64, samples_per_worker, dim, skew)
        })
        .collect();
    let test = make_shard(seed + 10_000, 2000, dim, 0.0);
    let mut global = LinearModel::zeros(dim);
    let mut acc_history = Vec::with_capacity(rounds);
    let mut uplink_bytes = 0u64;
    for r in 0..rounds {
        let locals: Vec<(LinearModel, usize)> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let m = local_train(&global, s, 2, 0.05, seed + 100 + (r * n_workers + i) as u64);
                uplink_bytes += m.wire_bytes();
                (m, s.len())
            })
            .collect();
        global = fedavg(&locals);
        acc_history.push(accuracy(&global, &test));
    }
    (global, acc_history, uplink_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weighted_mean() {
        let a = LinearModel { w: vec![1.0, 0.0], b: 1.0 };
        let b = LinearModel { w: vec![0.0, 1.0], b: 0.0 };
        let m = fedavg(&[(a, 100), (b, 300)]);
        assert!((m.w[0] - 0.25).abs() < 1e-6);
        assert!((m.w[1] - 0.75).abs() < 1e-6);
        assert!((m.b - 0.25).abs() < 1e-6);
    }

    #[test]
    fn shards_are_non_iid() {
        let a = make_shard(1, 500, 8, -1.0);
        let b = make_shard(2, 500, 8, 1.0);
        let pos_a = a.ys.iter().filter(|&&y| y > 0.5).count() as f64 / 500.0;
        let pos_b = b.ys.iter().filter(|&&y| y > 0.5).count() as f64 / 500.0;
        assert!(pos_b - pos_a > 0.3, "{pos_a} vs {pos_b}");
    }

    #[test]
    fn federated_training_converges() {
        let (_m, acc, _bytes) = run_federated(4, 12, 400, 8, 7);
        let final_acc = *acc.last().unwrap();
        assert!(final_acc > 0.85, "final accuracy {final_acc}");
        // logistic regression can already converge in round 1 on this
        // problem; require non-degradation rather than strict improvement
        assert!(final_acc >= acc[0] - 0.02, "regressed: {acc:?}");
    }

    #[test]
    fn federated_beats_single_skewed_worker() {
        let (global, _, _) = run_federated(4, 12, 400, 8, 7);
        // a single worker trained only on its skewed shard
        let shard = make_shard(7, 400, 8, -1.0);
        let solo = local_train(&LinearModel::zeros(8), &shard, 24, 0.05, 99);
        let test = make_shard(7 + 10_000, 2000, 8, 0.0);
        assert!(accuracy(&global, &test) > accuracy(&solo, &test));
    }

    #[test]
    fn uplink_accounting() {
        let (_, _, bytes) = run_federated(3, 5, 100, 8, 1);
        // 3 workers * 5 rounds * (8+1)*4 bytes
        assert_eq!(bytes, 3 * 5 * 36);
    }

    #[test]
    fn only_weights_cross_the_wire() {
        let m = LinearModel::zeros(16);
        assert_eq!(m.wire_bytes(), 17 * 4);
        // raw shard would be orders of magnitude larger
        let shard_bytes = 400 * 16 * 4;
        assert!(m.wire_bytes() * 100 < shard_bytes);
    }
}
