//! Orbital mechanics substrate: Keplerian propagation + contact windows.
//!
//! The paper's handover "only occurs during the contact time between the
//! satellite and the ground" (§IV).  The coordinator therefore needs
//! satellite↔ground-station visibility as a function of time.  Two
//! position models live behind the [`Propagator`] trait:
//!
//! * [`Satellite`] — a circular Keplerian orbit at the Baoyun altitude
//!   (500 km, Table 1), which reproduces window cadence and duration to
//!   minutes-level fidelity — sufficient because the offload policy only
//!   observes windows + rates (DESIGN.md substitution table).  This is
//!   the default and keeps every pre-TLE result bit-identical.
//! * [`TlePropagator`] — parsed two-line elements ([`tle`]) propagated
//!   with Kepler + J2 secular drift for real-catalog geometry.
//!
//! Visibility generalizes from one hardcoded station to a
//! [`StationNetwork`]: N [`GroundStation`]s with per-station elevation
//! masks, producing per-station [`ContactWindow`] tracks tagged with
//! `station_id` for the coordinator's contact scheduler.

pub mod tle;
mod window;

pub use tle::{Tle, TlePropagator};
pub use window::{contact_windows, contact_windows_tagged, ContactWindow, StationNetwork};

/// Earth constants (km, s).
pub const EARTH_RADIUS_KM: f64 = 6371.0;
pub const MU_KM3_S2: f64 = 398_600.441_8;
pub const EARTH_ROT_RAD_S: f64 = 7.292_115_9e-5;

/// A position model: anything that can place a spacecraft in ECI
/// coordinates as a function of mission time.  [`GroundStation`]
/// visibility, `contact_windows`, and `sim::Timeline` construction are
/// generic over this, so the circular [`Satellite`] and the TLE-driven
/// [`TlePropagator`] are interchangeable.
pub trait Propagator {
    /// ECI position at time t (seconds since epoch), km.
    fn position_eci(&self, t: f64) -> [f64; 3];

    /// Orbital period, seconds.
    fn period_s(&self) -> f64;

    /// Cylindrical Earth-shadow eclipse test (sun fixed at +X ECI; the
    /// sun moves < 0.05°/h, negligible over mission horizons of hours).
    fn in_eclipse(&self, t: f64) -> bool {
        eclipsed(self.position_eci(t))
    }
}

/// Shared shadow-cylinder test: eclipsed when on the anti-sun side of
/// Earth and inside the shadow cylinder of radius `EARTH_RADIUS_KM`.
fn eclipsed(p: [f64; 3]) -> bool {
    let along_sun = p[0]; // dot(p, sun_dir) with sun_dir = +X
    if along_sun >= 0.0 {
        return false;
    }
    let perp2 = dot(&p, &p) - along_sun * along_sun;
    perp2 < EARTH_RADIUS_KM * EARTH_RADIUS_KM
}

/// Circular-orbit satellite.
#[derive(Clone, Debug)]
pub struct Satellite {
    pub name: String,
    /// Orbit altitude above mean Earth radius, km (Table 1: 500±50).
    pub altitude_km: f64,
    /// Inclination, radians (SSO ≈ 97.4°).
    pub inclination_rad: f64,
    /// Right ascension of ascending node, radians.
    pub raan_rad: f64,
    /// Phase (argument of latitude) at t = 0, radians.
    pub phase_rad: f64,
}

impl Satellite {
    pub fn semi_major_axis_km(&self) -> f64 {
        EARTH_RADIUS_KM + self.altitude_km
    }

    /// Orbital period, seconds (≈ 5677 s at 500 km).
    pub fn period_s(&self) -> f64 {
        let a = self.semi_major_axis_km();
        2.0 * std::f64::consts::PI * (a * a * a / MU_KM3_S2).sqrt()
    }

    /// ECI position at time t (seconds since epoch), km.
    pub fn position_eci(&self, t: f64) -> [f64; 3] {
        let a = self.semi_major_axis_km();
        let n = (MU_KM3_S2 / (a * a * a)).sqrt(); // mean motion
        let u = self.phase_rad + n * t; // argument of latitude
        let (su, cu) = u.sin_cos();
        let (si, ci) = self.inclination_rad.sin_cos();
        let (so, co) = self.raan_rad.sin_cos();
        // r = Rz(Ω) Rx(i) [a cos u, a sin u, 0]
        [
            a * (co * cu - so * su * ci),
            a * (so * cu + co * su * ci),
            a * (su * si),
        ]
    }

    /// Cylindrical Earth-shadow eclipse test — the event source behind
    /// the timeline's illumination phases and duty-cycled camera/solar
    /// modeling.  (Also available through [`Propagator::in_eclipse`].)
    pub fn in_eclipse(&self, t: f64) -> bool {
        eclipsed(self.position_eci(t))
    }
}

impl Propagator for Satellite {
    fn position_eci(&self, t: f64) -> [f64; 3] {
        Satellite::position_eci(self, t)
    }

    fn period_s(&self) -> f64 {
        Satellite::period_s(self)
    }
}

/// Ground station (paper: control center + downlink stations).
#[derive(Clone, Debug)]
pub struct GroundStation {
    pub name: String,
    pub lat_deg: f64,
    pub lon_deg: f64,
    /// Minimum usable elevation, degrees (terrain + RF mask).
    pub min_elevation_deg: f64,
}

impl GroundStation {
    /// ECI position at time t (Earth rotates under the orbit), km.
    pub fn position_eci(&self, t: f64) -> [f64; 3] {
        let lat = self.lat_deg.to_radians();
        let lon = self.lon_deg.to_radians() + EARTH_ROT_RAD_S * t;
        let (slat, clat) = lat.sin_cos();
        let (slon, clon) = lon.sin_cos();
        [
            EARTH_RADIUS_KM * clat * clon,
            EARTH_RADIUS_KM * clat * slon,
            EARTH_RADIUS_KM * slat,
        ]
    }

    /// Elevation angle of `sat` above this station's horizon at t, radians.
    pub fn elevation_rad<P: Propagator + ?Sized>(&self, sat: &P, t: f64) -> f64 {
        let s = sat.position_eci(t);
        let g = self.position_eci(t);
        let rel = [s[0] - g[0], s[1] - g[1], s[2] - g[2]];
        let g_norm = norm(&g);
        let rel_norm = norm(&rel);
        // elevation = 90° - angle(up, rel); up == g/|g| for a sphere
        let cosz = dot(&g, &rel) / (g_norm * rel_norm);
        std::f64::consts::FRAC_PI_2 - cosz.clamp(-1.0, 1.0).acos()
    }

    pub fn visible<P: Propagator + ?Sized>(&self, sat: &P, t: f64) -> bool {
        self.elevation_rad(sat, t) >= self.min_elevation_deg.to_radians()
    }

    /// Slant range to the satellite, km (drives free-space path loss and
    /// thus the achievable downlink rate).
    pub fn slant_range_km<P: Propagator + ?Sized>(&self, sat: &P, t: f64) -> f64 {
        let s = sat.position_eci(t);
        let g = self.position_eci(t);
        norm(&[s[0] - g[0], s[1] - g[1], s[2] - g[2]])
    }
}

fn dot(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

fn norm(a: &[f64; 3]) -> f64 {
    dot(a, a).sqrt()
}

/// The two Tiansuan experimental satellites (Table 1).
pub fn baoyun() -> Satellite {
    Satellite {
        name: "Baoyun".into(),
        altitude_km: 500.0,
        inclination_rad: 97.4f64.to_radians(),
        raan_rad: 0.0,
        phase_rad: 0.0,
    }
}

pub fn chuangxingleishen() -> Satellite {
    Satellite {
        name: "Chuangxingleishen".into(),
        altitude_km: 500.0,
        inclination_rad: 97.4f64.to_radians(),
        raan_rad: 0.35,
        phase_rad: std::f64::consts::PI,
    }
}

/// BUPT-ish ground station (Beijing).
pub fn beijing_station() -> GroundStation {
    GroundStation { name: "Beijing".into(), lat_deg: 39.96, lon_deg: 116.35, min_elevation_deg: 10.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_at_500km_is_about_94_minutes() {
        let p = baoyun().period_s();
        assert!((5600.0..5760.0).contains(&p), "period {p}");
    }

    #[test]
    fn orbit_radius_constant() {
        let sat = baoyun();
        for t in [0.0, 1000.0, 4321.0] {
            let r = sat.position_eci(t);
            let n = (r[0] * r[0] + r[1] * r[1] + r[2] * r[2]).sqrt();
            assert!((n - sat.semi_major_axis_km()).abs() < 1e-6, "t={t} r={n}");
        }
    }

    #[test]
    fn orbit_returns_after_one_period() {
        let sat = baoyun();
        // Position repeats in the inertial frame after one period.
        let a = sat.position_eci(0.0);
        let b = sat.position_eci(sat.period_s());
        for i in 0..3 {
            assert!((a[i] - b[i]).abs() < 1.0, "axis {i}: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn station_on_surface() {
        let gs = beijing_station();
        let p = gs.position_eci(0.0);
        let n = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
        assert!((n - EARTH_RADIUS_KM).abs() < 1e-6);
    }

    #[test]
    fn elevation_bounded() {
        let sat = baoyun();
        let gs = beijing_station();
        for i in 0..200 {
            let e = gs.elevation_rad(&sat, i as f64 * 60.0);
            assert!((-std::f64::consts::FRAC_PI_2..=std::f64::consts::FRAC_PI_2).contains(&e));
        }
    }

    #[test]
    fn satellite_sometimes_visible_over_a_day() {
        let sat = baoyun();
        let gs = beijing_station();
        let visible = (0..8640).any(|i| gs.visible(&sat, i as f64 * 10.0));
        assert!(visible, "no visibility in 24h is implausible for a 97° LEO");
    }

    #[test]
    fn eclipse_fraction_realistic_for_leo() {
        // A 500 km orbit spends roughly a third of each revolution in
        // Earth's shadow (up to ~40% depending on beta angle).
        let sat = baoyun();
        let period = sat.period_s();
        let n = 1000;
        let dark = (0..n)
            .filter(|i| sat.in_eclipse(*i as f64 * period / n as f64))
            .count();
        let frac = dark as f64 / n as f64;
        assert!((0.05..0.5).contains(&frac), "eclipse fraction {frac}");
    }

    #[test]
    fn eclipse_is_single_interval_per_revolution() {
        // The cylindrical shadow is convex and the orbit circular, so
        // the in/out predicate changes exactly twice per period — the
        // structural property behind the timeline's contiguous,
        // non-overlapping sunlit spans (and thus exact solar charging
        // integration).
        let sat = baoyun();
        let period = sat.period_s();
        let n = 5000;
        let mut transitions = 0;
        let mut prev = sat.in_eclipse(0.0);
        for i in 1..=n {
            let cur = sat.in_eclipse(i as f64 * period / n as f64);
            if cur != prev {
                transitions += 1;
                prev = cur;
            }
        }
        assert_eq!(transitions, 2, "one eclipse interval per orbit");
    }

    #[test]
    fn sun_side_never_eclipsed() {
        let sat = baoyun();
        let period = sat.period_s();
        for i in 0..1000 {
            let t = i as f64 * period / 1000.0;
            if sat.position_eci(t)[0] >= 0.0 {
                assert!(!sat.in_eclipse(t), "sun-side eclipse at t={t}");
            }
        }
    }

    #[test]
    fn propagator_trait_matches_inherent_satellite_model() {
        // the trait path is what generic code (windows, timelines) uses;
        // it must be the inherent model verbatim, bit-for-bit
        fn via_trait<P: Propagator>(p: &P, t: f64) -> ([f64; 3], f64, bool) {
            (p.position_eci(t), p.period_s(), p.in_eclipse(t))
        }
        let sat = baoyun();
        for t in [0.0, 977.0, 5000.0, 86_399.0] {
            let (pos, period, ecl) = via_trait(&sat, t);
            assert_eq!(pos, sat.position_eci(t));
            assert_eq!(period.to_bits(), sat.period_s().to_bits());
            assert_eq!(ecl, sat.in_eclipse(t));
        }
    }

    #[test]
    fn slant_range_at_horizon_exceeds_altitude() {
        let sat = baoyun();
        let gs = beijing_station();
        // whenever visible, slant range is between altitude and ~2831 km
        for i in 0..8640 {
            let t = i as f64 * 10.0;
            if gs.visible(&sat, t) {
                let r = gs.slant_range_km(&sat, t);
                assert!(r >= sat.altitude_km - 1.0 && r < 3200.0, "range {r}");
            }
        }
    }
}
