//! TLE two-line element sets: parsing with checksum validation and a
//! simplified SGP4-style propagator.
//!
//! The paper's verification flew real orbits (Baoyun, Chuangxingleishen)
//! tracked by real ground stations; operationally those orbits are
//! distributed as NORAD two-line element sets.  This module parses the
//! standard fixed-column format (mod-10 checksum per line) and propagates
//! the elements with Keplerian motion plus the dominant J2 secular
//! perturbation — nodal regression of RAAN and rotation of the argument
//! of perigee.  That is the part of SGP4 that matters at contact-window
//! fidelity: J2 moves the ground track by whole passes per day, while the
//! periodic terms SGP4 adds on top are sub-kilometre.  Pure Rust, no
//! dependencies beyond `anyhow`.
//!
//! [`TlePropagator`] implements [`Propagator`], so TLE-driven satellites
//! drop into `contact_windows`, `StationNetwork`, and `sim::Timeline`
//! anywhere the circular [`super::Satellite`] does.

use anyhow::{bail, ensure, Context, Result};

use super::{Propagator, Satellite, EARTH_RADIUS_KM, MU_KM3_S2};

/// Earth's second zonal harmonic (oblateness), dimensionless.
pub const J2: f64 = 1.082_626_68e-3;

/// Mod-10 checksum of a TLE line body (columns 1–68): digits add their
/// value, minus signs add one, everything else adds zero.
pub fn line_checksum(body: &str) -> u32 {
    body.chars()
        .map(|c| match c {
            '0'..='9' => c as u32 - '0' as u32,
            '-' => 1,
            _ => 0,
        })
        .sum::<u32>()
        % 10
}

/// A parsed two-line element set (the fields the propagator consumes,
/// plus identity/epoch bookkeeping).
#[derive(Clone, Debug, PartialEq)]
pub struct Tle {
    pub name: String,
    pub catalog_number: u32,
    /// Four-digit epoch year (two-digit years pivot at 57, per NORAD).
    pub epoch_year: u32,
    /// Day of year with fraction.
    pub epoch_day: f64,
    pub inclination_deg: f64,
    pub raan_deg: f64,
    pub eccentricity: f64,
    pub arg_perigee_deg: f64,
    pub mean_anomaly_deg: f64,
    pub mean_motion_rev_day: f64,
    /// SGP4 drag term, 1/Earth-radii (parsed, unused by the simplified
    /// propagator — drag is negligible over mission horizons of hours).
    pub bstar: f64,
}

impl Tle {
    /// Parse a two-line element set.  Both lines are validated: line
    /// numbers, matching catalog numbers, and the mod-10 checksum in
    /// column 69 of each line.
    pub fn parse(name: &str, line1: &str, line2: &str) -> Result<Tle> {
        let l1 = check_line(line1, '1').context("TLE line 1")?;
        let l2 = check_line(line2, '2').context("TLE line 2")?;

        let cat1: u32 = field(l1, 3, 7).trim().parse().context("line 1 catalog number")?;
        let cat2: u32 = field(l2, 3, 7).trim().parse().context("line 2 catalog number")?;
        ensure!(cat1 == cat2, "catalog number mismatch: {cat1} vs {cat2}");

        let yy: u32 = field(l1, 19, 20).trim().parse().context("epoch year")?;
        let epoch_year = if yy < 57 { 2000 + yy } else { 1900 + yy };
        let epoch_day: f64 = field(l1, 21, 32).trim().parse().context("epoch day")?;
        let bstar = implied_decimal_exp(field(l1, 54, 61)).context("bstar")?;

        let inclination_deg: f64 = field(l2, 9, 16).trim().parse().context("inclination")?;
        let raan_deg: f64 = field(l2, 18, 25).trim().parse().context("raan")?;
        let ecc_digits = field(l2, 27, 33).trim();
        let eccentricity: f64 = format!("0.{ecc_digits}").parse().context("eccentricity")?;
        let arg_perigee_deg: f64 = field(l2, 35, 42).trim().parse().context("arg of perigee")?;
        let mean_anomaly_deg: f64 = field(l2, 44, 51).trim().parse().context("mean anomaly")?;
        let mean_motion_rev_day: f64 = field(l2, 53, 63).trim().parse().context("mean motion")?;

        ensure!((0.0..1.0).contains(&eccentricity), "eccentricity {eccentricity} not in [0,1)");
        ensure!(mean_motion_rev_day > 0.0, "mean motion must be positive");

        Ok(Tle {
            name: name.to_string(),
            catalog_number: cat1,
            epoch_year,
            epoch_day,
            inclination_deg,
            raan_deg,
            eccentricity,
            arg_perigee_deg,
            mean_anomaly_deg,
            mean_motion_rev_day,
            bstar,
        })
    }

    /// Mean motion in rad/s.
    pub fn mean_motion_rad_s(&self) -> f64 {
        self.mean_motion_rev_day * std::f64::consts::TAU / 86_400.0
    }

    /// Semi-major axis recovered from the mean motion, km.
    pub fn semi_major_axis_km(&self) -> f64 {
        let n = self.mean_motion_rad_s();
        (MU_KM3_S2 / (n * n)).cbrt()
    }
}

/// Validate line shape + checksum; return the 68-column body.
fn check_line(line: &str, number: char) -> Result<&str> {
    let line = line.trim_end();
    ensure!(line.is_ascii(), "TLE lines must be ASCII");
    ensure!(line.len() >= 69, "line too short: {} columns, need 69", line.len());
    ensure!(
        line.starts_with(number),
        "expected line number '{number}', got '{}'",
        &line[..1]
    );
    let body = &line[..68];
    let want: u32 = line[68..69].parse().map_err(|_| {
        anyhow::anyhow!("checksum column is '{}', not a digit", &line[68..69])
    })?;
    let got = line_checksum(body);
    ensure!(got == want, "checksum mismatch: computed {got}, line says {want}");
    Ok(body)
}

/// One-based inclusive column slice (TLE columns are specified 1-based).
fn field(body: &str, lo: usize, hi: usize) -> &str {
    &body[lo - 1..hi]
}

/// Parse TLE "implied decimal + exponent" notation, e.g. `-11606-4`
/// meaning -0.11606e-4 (used by bstar and the second derivative field).
fn implied_decimal_exp(s: &str) -> Result<f64> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(0.0);
    }
    let (sign, rest) = match s.strip_prefix('-') {
        Some(r) => (-1.0, r),
        None => (1.0, s.strip_prefix('+').unwrap_or(s)),
    };
    // the exponent is a trailing sign + digit(s); split at the last sign
    let Some(split) = rest.rfind(['+', '-']) else {
        bail!("no exponent sign in implied-decimal field '{s}'");
    };
    let (mantissa_digits, exp_str) = rest.split_at(split);
    ensure!(!mantissa_digits.is_empty(), "empty mantissa in '{s}'");
    let mantissa: f64 = format!("0.{mantissa_digits}").parse().context("mantissa")?;
    let exp: i32 = exp_str.parse().context("exponent")?;
    Ok(sign * mantissa * 10f64.powi(exp))
}

/// Keplerian + J2-secular propagator over a parsed TLE.
///
/// Position model: solve Kepler's equation for the eccentric anomaly,
/// place the satellite in the orbital plane at radius `a(1 − e·cos E)`,
/// then rotate by the *time-varying* argument of perigee and RAAN:
///
/// ```text
/// Ω(t) = Ω₀ − (3/2)·J2·(Rₑ/p)²·n·cos i · t          (nodal regression)
/// ω(t) = ω₀ + (3/4)·J2·(Rₑ/p)²·n·(5cos²i − 1) · t   (apsidal rotation)
/// ```
///
/// For e = 0 and J2 ignored this degenerates to exactly the circular
/// [`Satellite`] model, which is what keeps the two interchangeable
/// behind [`Propagator`].
#[derive(Clone, Debug)]
pub struct TlePropagator {
    a_km: f64,
    e: f64,
    inc_rad: f64,
    raan0_rad: f64,
    argp0_rad: f64,
    m0_rad: f64,
    n_rad_s: f64,
    raan_dot_rad_s: f64,
    argp_dot_rad_s: f64,
}

impl TlePropagator {
    pub fn new(tle: &Tle) -> Self {
        let n = tle.mean_motion_rad_s();
        let a = tle.semi_major_axis_km();
        let e = tle.eccentricity;
        let inc = tle.inclination_deg.to_radians();
        // semi-latus rectum; J2 secular rates per Vallado eq. 9-38/9-39
        let p = a * (1.0 - e * e);
        let k = 1.5 * J2 * (EARTH_RADIUS_KM / p).powi(2) * n;
        let ci = inc.cos();
        Self {
            a_km: a,
            e,
            inc_rad: inc,
            raan0_rad: tle.raan_deg.to_radians(),
            argp0_rad: tle.arg_perigee_deg.to_radians(),
            m0_rad: tle.mean_anomaly_deg.to_radians(),
            n_rad_s: n,
            raan_dot_rad_s: -k * ci,
            argp_dot_rad_s: 0.5 * k * (5.0 * ci * ci - 1.0),
        }
    }

    /// RAAN drift rate, rad/s (exposed so tests can check the
    /// sun-synchronous design property: ~+0.9856°/day at 97.4°/500 km).
    pub fn raan_dot_rad_s(&self) -> f64 {
        self.raan_dot_rad_s
    }

    /// The circular-model twin: same plane, same period, e and J2
    /// dropped.  Useful for bounding the simplified propagator against
    /// the long-standing circular baseline (see the round-trip test).
    pub fn circular_twin(&self, name: &str) -> Satellite {
        Satellite {
            name: name.to_string(),
            altitude_km: self.a_km - EARTH_RADIUS_KM,
            inclination_rad: self.inc_rad,
            raan_rad: self.raan0_rad,
            phase_rad: self.argp0_rad + self.m0_rad,
        }
    }

    /// Kepler's equation M = E − e·sin E by Newton iteration; e < 1 and
    /// LEO eccentricities are tiny, so a handful of steps converges to
    /// machine precision.
    fn eccentric_anomaly(&self, m: f64) -> f64 {
        let mut ea = m;
        for _ in 0..8 {
            ea -= (ea - self.e * ea.sin() - m) / (1.0 - self.e * ea.cos());
        }
        ea
    }
}

impl Propagator for TlePropagator {
    fn period_s(&self) -> f64 {
        std::f64::consts::TAU / self.n_rad_s
    }

    fn position_eci(&self, t: f64) -> [f64; 3] {
        let m = self.m0_rad + self.n_rad_s * t;
        let ea = self.eccentric_anomaly(m);
        let (sea, cea) = ea.sin_cos();
        let r = self.a_km * (1.0 - self.e * cea);
        // true anomaly from eccentric anomaly
        let nu = ((1.0 - self.e * self.e).sqrt() * sea).atan2(cea - self.e);
        let u = self.argp0_rad + self.argp_dot_rad_s * t + nu; // argument of latitude
        let raan = self.raan0_rad + self.raan_dot_rad_s * t;
        let (su, cu) = u.sin_cos();
        let (si, ci) = self.inc_rad.sin_cos();
        let (so, co) = raan.sin_cos();
        [
            r * (co * cu - so * su * ci),
            r * (so * cu + co * su * ci),
            r * (su * si),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The canonical ISS element set (public-domain format example).
    const ISS_L1: &str = "1 25544U 98067A   08264.51782528 -.00002182  00000-0 -11606-4 0  2927";
    const ISS_L2: &str = "2 25544  51.6416 247.4627 0006703 130.5360 325.0288 15.72125391563537";

    /// Build a valid TLE pair for an arbitrary element set by formatting
    /// the fixed columns and computing the checksums — so tests are not
    /// hostage to hand-summed digits.
    fn synth_tle(
        inc_deg: f64,
        raan_deg: f64,
        ecc7: u32,
        argp_deg: f64,
        ma_deg: f64,
        mm: f64,
    ) -> (String, String) {
        let body1 = "1 00005U 58002B   00179.78495062  .00000023  00000-0  28098-4 0  469".to_string();
        let body1 = format!("{:<68}", &body1[..68.min(body1.len())]);
        let body2 = format!(
            "2 00005 {inc:8.4} {raan:8.4} {ecc:07} {argp:8.4} {ma:8.4} {mm:11.8}00000",
            inc = inc_deg,
            raan = raan_deg,
            ecc = ecc7,
            argp = argp_deg,
            ma = ma_deg,
            mm = mm,
        );
        let body2 = format!("{:<68}", &body2[..68.min(body2.len())]);
        (
            format!("{body1}{}", line_checksum(&body1)),
            format!("{body2}{}", line_checksum(&body2)),
        )
    }

    #[test]
    fn parses_iss_reference_set() {
        let tle = Tle::parse("ISS", ISS_L1, ISS_L2).unwrap();
        assert_eq!(tle.catalog_number, 25544);
        assert_eq!(tle.epoch_year, 2008);
        assert!((tle.epoch_day - 264.51782528).abs() < 1e-9);
        assert!((tle.inclination_deg - 51.6416).abs() < 1e-9);
        assert!((tle.raan_deg - 247.4627).abs() < 1e-9);
        assert!((tle.eccentricity - 0.0006703).abs() < 1e-12);
        assert!((tle.mean_motion_rev_day - 15.72125391).abs() < 1e-9);
        assert!((tle.bstar - (-0.11606e-4)).abs() < 1e-12);
        // semi-major axis lands in the ISS band
        let a = tle.semi_major_axis_km();
        assert!((6650.0..6850.0).contains(&a), "a = {a}");
    }

    #[test]
    fn checksum_rejects_corruption() {
        // flip one digit in the body: the checksum no longer matches
        let bad = ISS_L1.replace("25544", "25545");
        assert!(Tle::parse("ISS", &bad, ISS_L2).is_err());
        // flip the checksum digit itself
        let mut bad = ISS_L2.to_string();
        bad.replace_range(68..69, "3");
        assert!(Tle::parse("ISS", ISS_L1, &bad).is_err());
    }

    #[test]
    fn rejects_swapped_lines_and_mismatched_catalogs() {
        assert!(Tle::parse("ISS", ISS_L2, ISS_L1).is_err(), "line numbers are validated");
        // valid-checksum lines from different objects
        let (l1, _) = synth_tle(97.4, 10.0, 10, 90.0, 0.0, 15.2);
        assert!(Tle::parse("mix", &l1, ISS_L2).is_err(), "catalog mismatch is rejected");
    }

    #[test]
    fn implied_decimal_notation() {
        assert!((implied_decimal_exp("-11606-4").unwrap() - (-0.11606e-4)).abs() < 1e-15);
        assert!((implied_decimal_exp(" 28098-4").unwrap() - 0.28098e-4).abs() < 1e-15);
        assert_eq!(implied_decimal_exp(" 00000-0").unwrap(), 0.0);
        assert_eq!(implied_decimal_exp(" 00000+0").unwrap(), 0.0);
        assert_eq!(implied_decimal_exp("").unwrap(), 0.0);
    }

    #[test]
    fn synthetic_checksum_roundtrip() {
        let (l1, l2) = synth_tle(97.4, 123.4567, 1234567, 45.0, 315.0, 15.21972000);
        let tle = Tle::parse("synth", &l1, &l2).unwrap();
        assert!((tle.inclination_deg - 97.4).abs() < 1e-3);
        assert!((tle.raan_deg - 123.4567).abs() < 1e-3);
        assert!((tle.eccentricity - 0.1234567).abs() < 1e-9);
        assert!((tle.mean_motion_rev_day - 15.21972).abs() < 1e-6);
    }

    #[test]
    fn propagator_period_matches_mean_motion() {
        let tle = Tle::parse("ISS", ISS_L1, ISS_L2).unwrap();
        let prop = TlePropagator::new(&tle);
        let expect = 86_400.0 / tle.mean_motion_rev_day;
        assert!((prop.period_s() - expect).abs() < 1e-6);
    }

    #[test]
    fn radius_stays_within_eccentric_bounds() {
        let tle = Tle::parse("ISS", ISS_L1, ISS_L2).unwrap();
        let prop = TlePropagator::new(&tle);
        let a = tle.semi_major_axis_km();
        let e = tle.eccentricity;
        for i in 0..500 {
            let t = i as f64 * prop.period_s() / 500.0;
            let p = prop.position_eci(t);
            let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            assert!(
                r >= a * (1.0 - e) - 1e-6 && r <= a * (1.0 + e) + 1e-6,
                "t={t}: r={r} outside [{}, {}]",
                a * (1.0 - e),
                a * (1.0 + e)
            );
        }
    }

    #[test]
    fn sso_raan_drift_is_prograde_about_one_degree_per_day() {
        // A 97.4° / ~500 km orbit is sun-synchronous by design: J2 nodal
        // regression is prograde, ~0.9856°/day, matching the Sun's mean
        // motion.  This is the observable that makes J2 worth modelling
        // at contact-window fidelity.
        let (l1, l2) = synth_tle(97.4, 0.0, 10, 90.0, 0.0, 15.21972000);
        let tle = Tle::parse("sso", &l1, &l2).unwrap();
        let prop = TlePropagator::new(&tle);
        let deg_per_day = prop.raan_dot_rad_s().to_degrees() * 86_400.0;
        assert!((0.5..1.5).contains(&deg_per_day), "RAAN drift {deg_per_day}°/day");
    }

    #[test]
    fn roundtrip_error_vs_circular_model_bounded() {
        // Acceptance gate: parse → propagate one period → position error
        // against the circular model stays bounded.  The divergence
        // budget is eccentricity (≤ 2ae ≈ 9 km for ISS) plus one period
        // of J2 secular drift (tens of km at the orbit radius) — far
        // below the ~400 km scale of one coarse contact-scan step.
        let tle = Tle::parse("ISS", ISS_L1, ISS_L2).unwrap();
        let prop = TlePropagator::new(&tle);
        let twin = prop.circular_twin("ISS-circular");
        assert!((prop.period_s() - twin.period_s()).abs() < 0.5, "periods agree");
        let period = prop.period_s();
        let mut max_err = 0.0f64;
        for i in 0..=200 {
            let t = i as f64 * period / 200.0;
            let a = prop.position_eci(t);
            let b = twin.position_eci(t);
            let d = ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)).sqrt();
            max_err = max_err.max(d);
        }
        assert!(max_err < 100.0, "max divergence over one period: {max_err} km");
        // and at epoch the models are close to within the eccentric offset
        let a0 = prop.position_eci(0.0);
        let b0 = twin.position_eci(0.0);
        let d0 = ((a0[0] - b0[0]).powi(2) + (a0[1] - b0[1]).powi(2) + (a0[2] - b0[2]).powi(2)).sqrt();
        assert!(d0 < 3.0 * tle.semi_major_axis_km() * tle.eccentricity + 1.0, "epoch offset {d0} km");
    }

    #[test]
    fn zero_ecc_zero_j2_matches_circular_exactly() {
        // With e = 0 the Kepler solve is the identity; zeroing the J2
        // rates makes the propagator the circular model verbatim.
        let (l1, l2) = synth_tle(97.4, 20.0, 0, 30.0, 60.0, 15.21972000);
        let tle = Tle::parse("circ", &l1, &l2).unwrap();
        let mut prop = TlePropagator::new(&tle);
        prop.raan_dot_rad_s = 0.0;
        prop.argp_dot_rad_s = 0.0;
        let twin = prop.circular_twin("circ");
        for i in 0..50 {
            let t = i as f64 * 117.0;
            let a = prop.position_eci(t);
            let b = twin.position_eci(t);
            for k in 0..3 {
                assert!((a[k] - b[k]).abs() < 1e-5, "t={t} axis {k}: {} vs {}", a[k], b[k]);
            }
        }
    }
}
