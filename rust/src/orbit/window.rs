//! Contact-window computation: coarse scan + bisection refinement.

use super::{GroundStation, Satellite};

/// One AOS→LOS visibility interval.
#[derive(Clone, Debug, PartialEq)]
pub struct ContactWindow {
    /// Acquisition of signal, seconds since epoch.
    pub aos: f64,
    /// Loss of signal.
    pub los: f64,
    /// Peak elevation during the pass, degrees.
    pub max_elevation_deg: f64,
}

impl ContactWindow {
    pub fn duration_s(&self) -> f64 {
        self.los - self.aos
    }

    pub fn contains(&self, t: f64) -> bool {
        t >= self.aos && t < self.los
    }
}

/// Compute all contact windows in [t0, t1].
///
/// Coarse scan at `step_s` (10 s catches every >20 s pass at LEO angular
/// rates), then bisect each boundary to ±0.1 s.
pub fn contact_windows(
    sat: &Satellite,
    gs: &GroundStation,
    t0: f64,
    t1: f64,
    step_s: f64,
) -> Vec<ContactWindow> {
    assert!(t1 > t0 && step_s > 0.0);
    let mut windows = Vec::new();
    let mut t = t0;
    let mut prev_vis = gs.visible(sat, t0);
    let mut aos = if prev_vis { Some(t0) } else { None };
    while t < t1 {
        let tn = (t + step_s).min(t1);
        let vis = gs.visible(sat, tn);
        if vis && !prev_vis {
            aos = Some(bisect(sat, gs, t, tn));
        } else if !vis && prev_vis {
            let los = bisect(sat, gs, t, tn);
            if let Some(a) = aos.take() {
                windows.push(finish(sat, gs, a, los));
            }
        }
        prev_vis = vis;
        t = tn;
    }
    if let Some(a) = aos {
        windows.push(finish(sat, gs, a, t1));
    }
    windows
}

fn bisect(sat: &Satellite, gs: &GroundStation, mut lo: f64, mut hi: f64) -> f64 {
    // invariant: visibility differs at lo and hi
    let lo_vis = gs.visible(sat, lo);
    while hi - lo > 0.1 {
        let mid = 0.5 * (lo + hi);
        if gs.visible(sat, mid) == lo_vis {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

fn finish(sat: &Satellite, gs: &GroundStation, aos: f64, los: f64) -> ContactWindow {
    let mut max_el = f64::MIN;
    let n = 64;
    for i in 0..=n {
        let t = aos + (los - aos) * i as f64 / n as f64;
        max_el = max_el.max(gs.elevation_rad(sat, t).to_degrees());
    }
    ContactWindow { aos, los, max_elevation_deg: max_el }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{baoyun, beijing_station};

    const DAY: f64 = 86_400.0;

    fn day_windows() -> Vec<ContactWindow> {
        contact_windows(&baoyun(), &beijing_station(), 0.0, DAY, 10.0)
    }

    #[test]
    fn some_passes_per_day() {
        let w = day_windows();
        // A 97° 500 km orbit sees a mid-latitude station ~2-6 times/day.
        assert!((1..=10).contains(&w.len()), "passes {}", w.len());
    }

    #[test]
    fn windows_disjoint_and_ordered() {
        let w = day_windows();
        for pair in w.windows(2) {
            assert!(pair[0].los <= pair[1].aos, "{pair:?}");
        }
        for win in &w {
            assert!(win.duration_s() > 0.0);
        }
    }

    #[test]
    fn pass_durations_realistic() {
        // LEO passes above a 10° mask last roughly 1-12 minutes.
        for win in day_windows() {
            assert!(
                (20.0..800.0).contains(&win.duration_s()),
                "duration {}",
                win.duration_s()
            );
        }
    }

    #[test]
    fn visibility_holds_inside_window() {
        let sat = baoyun();
        let gs = beijing_station();
        for win in day_windows() {
            let mid = 0.5 * (win.aos + win.los);
            assert!(gs.visible(&sat, mid));
            assert!(!gs.visible(&sat, win.aos - 5.0));
            assert!(!gs.visible(&sat, win.los + 5.0));
        }
    }

    #[test]
    fn max_elevation_above_mask() {
        for win in day_windows() {
            assert!(win.max_elevation_deg >= 10.0 - 0.2, "{}", win.max_elevation_deg);
        }
    }
}
