//! Contact-window computation: coarse scan + bisection refinement,
//! generalized over [`Propagator`]s and multi-station networks.

use super::{GroundStation, Propagator};

/// One AOS→LOS visibility interval, tagged with the station that sees it.
#[derive(Clone, Debug, PartialEq)]
pub struct ContactWindow {
    /// Acquisition of signal, seconds since epoch.
    pub aos: f64,
    /// Loss of signal.
    pub los: f64,
    /// Peak elevation during the pass, degrees.  For a `truncated`
    /// window this covers only the scanned span and may sit below the
    /// elevation mask.
    pub max_elevation_deg: f64,
    /// True when the scan clipped this pass at a boundary of `[t0, t1]`
    /// — already open at `t0` or still open at `t1` — or when the
    /// contact scheduler clipped it against another station's pass.  The
    /// clipped end is a clamp time, not a bisected horizon crossing, so
    /// `duration_s` understates the physical pass.
    pub truncated: bool,
    /// Index of the observing station in its [`StationNetwork`] (0 for
    /// the single-station legacy path and stub timelines).
    pub station_id: usize,
}

impl ContactWindow {
    pub fn duration_s(&self) -> f64 {
        self.los - self.aos
    }

    pub fn contains(&self, t: f64) -> bool {
        t >= self.aos && t < self.los
    }
}

/// Compute all contact windows in [t0, t1] for one station, tagged with
/// `station_id: 0` (the single-station legacy path).
///
/// Coarse scan at `step_s` (10 s catches every >20 s pass at LEO angular
/// rates), then bisect each boundary to within [`bisect_tolerance`].
pub fn contact_windows<P: Propagator + ?Sized>(
    sat: &P,
    gs: &GroundStation,
    t0: f64,
    t1: f64,
    step_s: f64,
) -> Vec<ContactWindow> {
    contact_windows_tagged(sat, gs, 0, t0, t1, step_s)
}

/// [`contact_windows`] with an explicit station tag — the per-station
/// building block [`StationNetwork::contact_tracks`] fans out over.
pub fn contact_windows_tagged<P: Propagator + ?Sized>(
    sat: &P,
    gs: &GroundStation,
    station_id: usize,
    t0: f64,
    t1: f64,
    step_s: f64,
) -> Vec<ContactWindow> {
    assert!(t1 > t0 && step_s > 0.0);
    let tol = bisect_tolerance(step_s);
    let mut windows = Vec::new();
    let mut t = t0;
    let mut prev_vis = gs.visible(sat, t0);
    // a pass already open at t0 gets aos = t0 verbatim — a clamp, not a
    // bisected AOS — and must carry the truncation flag
    let mut clipped_at_start = prev_vis;
    let mut aos = if prev_vis { Some(t0) } else { None };
    while t < t1 {
        let tn = (t + step_s).min(t1);
        let vis = gs.visible(sat, tn);
        if vis && !prev_vis {
            aos = Some(bisect(sat, gs, t, tn, tol));
        } else if !vis && prev_vis {
            let los = bisect(sat, gs, t, tn, tol);
            if let Some(a) = aos.take() {
                windows.push(finish(sat, gs, station_id, a, los, clipped_at_start));
                clipped_at_start = false;
            }
        }
        prev_vis = vis;
        t = tn;
    }
    if let Some(a) = aos {
        // still visible at t1: los = t1 is a clamp, not a real LOS
        windows.push(finish(sat, gs, station_id, a, t1, true));
    }
    windows
}

/// Bisection stopping width for a coarse scan step of `step_s`.
///
/// Historically a fixed 0.1 s — fine for 10 s steps, but a sub-second
/// scan (fast TLE passes over a high-mask station) would then refine
/// boundaries *coarser* than its own sampling grid.  Scaling as
/// `step_s / 100` keeps refinement two orders tighter than the scan
/// while the default 10 s step still yields exactly 0.1 (the division
/// rounds to the same f64 as the old literal, preserving every
/// pre-refactor boundary bit-for-bit).
fn bisect_tolerance(step_s: f64) -> f64 {
    (step_s / 100.0).clamp(1e-6, 0.1)
}

fn bisect<P: Propagator + ?Sized>(
    sat: &P,
    gs: &GroundStation,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> f64 {
    // invariant: visibility differs at lo and hi
    let lo_vis = gs.visible(sat, lo);
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        if gs.visible(sat, mid) == lo_vis {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

fn finish<P: Propagator + ?Sized>(
    sat: &P,
    gs: &GroundStation,
    station_id: usize,
    aos: f64,
    los: f64,
    truncated: bool,
) -> ContactWindow {
    let mut max_el = f64::MIN;
    let n = 64;
    for i in 0..=n {
        let t = aos + (los - aos) * i as f64 / n as f64;
        max_el = max_el.max(gs.elevation_rad(sat, t).to_degrees());
    }
    ContactWindow { aos, los, max_elevation_deg: max_el, truncated, station_id }
}

/// A configurable set of ground stations with per-station elevation
/// masks.  `station_id` everywhere in the system is an index into this
/// network's station list.
#[derive(Clone, Debug)]
pub struct StationNetwork {
    stations: Vec<GroundStation>,
}

impl StationNetwork {
    /// A network must have at least one station (the degenerate
    /// zero-station mission has no downlink at all and is rejected at
    /// config validation too).
    pub fn new(stations: Vec<GroundStation>) -> StationNetwork {
        assert!(!stations.is_empty(), "a station network needs at least one station");
        StationNetwork { stations }
    }

    /// The single-station legacy shape.
    pub fn single(gs: GroundStation) -> StationNetwork {
        StationNetwork::new(vec![gs])
    }

    pub fn len(&self) -> usize {
        self.stations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stations.is_empty()
    }

    pub fn stations(&self) -> &[GroundStation] {
        &self.stations
    }

    pub fn station(&self, id: usize) -> &GroundStation {
        &self.stations[id]
    }

    /// Per-station contact tracks over `[t0, t1]`: `tracks[i]` holds the
    /// windows station `i` sees, each tagged `station_id = i`.  Tracks
    /// from different stations may overlap in time — arbitrating who
    /// gets the transmitter is the contact scheduler's job, not the
    /// geometry layer's.
    pub fn contact_tracks<P: Propagator + ?Sized>(
        &self,
        sat: &P,
        t0: f64,
        t1: f64,
        step_s: f64,
    ) -> Vec<Vec<ContactWindow>> {
        self.stations
            .iter()
            .enumerate()
            .map(|(id, gs)| contact_windows_tagged(sat, gs, id, t0, t1, step_s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{baoyun, beijing_station, EARTH_ROT_RAD_S};

    const DAY: f64 = 86_400.0;

    fn day_windows() -> Vec<ContactWindow> {
        contact_windows(&baoyun(), &beijing_station(), 0.0, DAY, 10.0)
    }

    #[test]
    fn some_passes_per_day() {
        let w = day_windows();
        // A 97° 500 km orbit sees a mid-latitude station ~2-6 times/day.
        assert!((1..=10).contains(&w.len()), "passes {}", w.len());
    }

    #[test]
    fn windows_disjoint_and_ordered() {
        let w = day_windows();
        for pair in w.windows(2) {
            assert!(pair[0].los <= pair[1].aos, "{pair:?}");
        }
        for win in &w {
            assert!(win.duration_s() > 0.0);
            assert_eq!(win.station_id, 0, "legacy path tags station 0");
        }
    }

    #[test]
    fn pass_durations_realistic() {
        // LEO passes above a 10° mask last roughly 1-12 minutes.
        for win in day_windows() {
            assert!(
                (20.0..800.0).contains(&win.duration_s()),
                "duration {}",
                win.duration_s()
            );
        }
    }

    #[test]
    fn visibility_holds_inside_window() {
        let sat = baoyun();
        let gs = beijing_station();
        for win in day_windows() {
            let mid = 0.5 * (win.aos + win.los);
            assert!(gs.visible(&sat, mid));
            assert!(!gs.visible(&sat, win.aos - 5.0));
            assert!(!gs.visible(&sat, win.los + 5.0));
        }
    }

    #[test]
    fn max_elevation_above_mask() {
        // only a whole pass guarantees the mask was crossed; a truncated
        // span can peak below it
        for win in day_windows() {
            if !win.truncated {
                assert!(win.max_elevation_deg >= 10.0 - 0.2, "{}", win.max_elevation_deg);
            }
        }
    }

    #[test]
    fn scan_starting_mid_pass_flags_truncation() {
        let sat = baoyun();
        let gs = beijing_station();
        let full = day_windows();
        let w0 = &full[0];
        assert!(!w0.truncated, "the first full-scan pass opens after t0");
        let mid = 0.5 * (w0.aos + w0.los);

        // scan starting mid-pass: the open pass is clamped and flagged
        let clipped = contact_windows(&sat, &gs, mid, DAY, 10.0);
        let first = &clipped[0];
        assert!(first.truncated, "pass open at t0 must be flagged");
        assert_eq!(first.aos, mid, "aos clamps to the scan start");
        assert!((first.los - w0.los).abs() < 0.3, "los is still a bisected crossing");
        assert!(first.duration_s() < w0.duration_s());
        // later passes are unaffected: same boundaries, same flags
        assert_eq!(clipped.len(), full.len());
        for (c, f) in clipped.iter().zip(full.iter()).skip(1) {
            assert!((c.aos - f.aos).abs() < 0.3 && (c.los - f.los).abs() < 0.3);
            assert_eq!(c.truncated, f.truncated);
        }

        // scan ending mid-pass: the still-open pass is clamped at t1
        let endclip = contact_windows(&sat, &gs, 0.0, mid, 10.0);
        let last = endclip.last().expect("the straddled pass is emitted");
        assert!(last.truncated, "pass open at t1 must be flagged");
        assert_eq!(last.los, mid, "los clamps to the scan end");
        assert!((last.aos - w0.aos).abs() < 0.3);
    }

    /// A synthetic propagator with exactly controllable visibility: it
    /// parks directly overhead the reference station during
    /// `[on_at, off_at)` (elevation 90°) and at the antipode otherwise
    /// (elevation −90°) — so AOS/LOS are knowable to machine precision
    /// and bisection accuracy can be asserted exactly.
    struct SquareWavePass {
        on_at: f64,
        off_at: f64,
    }

    impl Propagator for SquareWavePass {
        fn position_eci(&self, t: f64) -> [f64; 3] {
            let g = beijing_station().position_eci(t);
            let k = if t >= self.on_at && t < self.off_at { 2.0 } else { -2.0 };
            [k * g[0], k * g[1], k * g[2]]
        }

        fn period_s(&self) -> f64 {
            std::f64::consts::TAU / EARTH_ROT_RAD_S
        }
    }

    #[test]
    fn bisection_refines_to_tolerance_of_true_edges() {
        let gs = beijing_station();
        // edges deliberately off the coarse grid
        let sat = SquareWavePass { on_at: 95.3, off_at: 173.7 };
        for (step, tol) in [(10.0, 0.1), (2.0, 0.02), (0.5, 0.005)] {
            let w = contact_windows(&sat, &gs, 0.0, 400.0, step);
            assert_eq!(w.len(), 1, "step {step}: {w:?}");
            assert!(!w[0].truncated);
            assert!(
                (w[0].aos - 95.3).abs() <= tol,
                "step {step}: aos {} vs 95.3 (tol {tol})",
                w[0].aos
            );
            assert!(
                (w[0].los - 173.7).abs() <= tol,
                "step {step}: los {} vs 173.7 (tol {tol})",
                w[0].los
            );
        }
    }

    #[test]
    fn edges_on_exact_step_boundaries() {
        // AOS/LOS landing exactly on coarse-scan sample points: the scan
        // samples visibility half-open (visible at 100, dark at 200), so
        // the transition is still bracketed and bisected to tolerance.
        let gs = beijing_station();
        let sat = SquareWavePass { on_at: 100.0, off_at: 200.0 };
        let w = contact_windows(&sat, &gs, 0.0, 400.0, 10.0);
        assert_eq!(w.len(), 1);
        assert!((w[0].aos - 100.0).abs() <= 0.1, "aos {}", w[0].aos);
        assert!((w[0].los - 200.0).abs() <= 0.1, "los {}", w[0].los);
        assert!(!w[0].truncated);

        // scan starting exactly at AOS: clamp semantics, flagged truncated
        let w = contact_windows(&sat, &gs, 100.0, 400.0, 10.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].aos, 100.0);
        assert!(w[0].truncated);

        // scan ending exactly at LOS: the sample at t1 = 200 is already
        // dark (half-open window), so the LOS is a real bisected edge
        let w = contact_windows(&sat, &gs, 0.0, 200.0, 10.0);
        assert_eq!(w.len(), 1);
        assert!((w[0].los - 200.0).abs() <= 0.1, "los {}", w[0].los);
    }

    #[test]
    fn tolerance_scales_with_step_but_keeps_legacy_default() {
        assert_eq!(bisect_tolerance(10.0), 0.1, "default step keeps the historical 0.1 s");
        assert_eq!(bisect_tolerance(1000.0), 0.1, "capped above");
        assert!((bisect_tolerance(1.0) - 0.01).abs() < 1e-15);
        assert_eq!(bisect_tolerance(1e-9), 1e-6, "floored below");
    }

    #[test]
    fn network_tracks_are_tagged_and_positive() {
        // Beijing plus a co-located wide-mask station: every Beijing
        // window nests strictly inside a station-1 window, so the two
        // tracks overlap heavily — the geometry layer must still report
        // both, tagged, each with positive duration.
        let sat = baoyun();
        let wide = GroundStation {
            name: "Beijing-wide".into(),
            lat_deg: 39.96,
            lon_deg: 116.35,
            min_elevation_deg: 5.0,
        };
        let net = StationNetwork::new(vec![beijing_station(), wide]);
        assert_eq!(net.len(), 2);
        let tracks = net.contact_tracks(&sat, 0.0, DAY, 10.0);
        assert_eq!(tracks.len(), 2);
        for (id, track) in tracks.iter().enumerate() {
            assert!(!track.is_empty(), "station {id} sees no passes");
            for w in track {
                assert_eq!(w.station_id, id);
                assert!(w.duration_s() > 0.0, "zero-length window {w:?}");
            }
        }
        // the wider mask sees the satellite for strictly longer
        let t0: f64 = tracks[0].iter().map(ContactWindow::duration_s).sum();
        let t1: f64 = tracks[1].iter().map(ContactWindow::duration_s).sum();
        assert!(t1 > t0, "wide mask {t1} s should exceed 10° mask {t0} s");
        // and every 10°-mask pass is covered by some 5°-mask pass
        for w in &tracks[0] {
            let mid = 0.5 * (w.aos + w.los);
            assert!(
                tracks[1].iter().any(|v| v.contains(mid)),
                "no station-1 window covers t={mid}"
            );
        }
    }

    #[test]
    fn tle_propagator_produces_plausible_windows() {
        // A TLE for the Baoyun-like SSO plane drops into the same scan.
        let sat = baoyun();
        let windows = contact_windows(&sat, &beijing_station(), 0.0, DAY, 10.0);
        let tle_sat = crate::orbit::TlePropagator::new(
            &crate::orbit::Tle::parse(
                "ISS",
                "1 25544U 98067A   08264.51782528 -.00002182  00000-0 -11606-4 0  2927",
                "2 25544  51.6416 247.4627 0006703 130.5360 325.0288 15.72125391563537",
            )
            .unwrap(),
        );
        let tle_windows = contact_windows(&tle_sat, &beijing_station(), 0.0, DAY, 10.0);
        // both orbits pass over a 40°N station a handful of times a day
        assert!((1..=12).contains(&windows.len()));
        assert!((1..=12).contains(&tle_windows.len()), "TLE passes {}", tle_windows.len());
        for w in &tle_windows {
            // grazing passes can be brief; the ceiling is what matters
            assert!(w.duration_s() > 0.0 && w.duration_s() < 900.0, "duration {}", w.duration_s());
        }
    }
}
