//! Contact-window computation: coarse scan + bisection refinement.

use super::{GroundStation, Satellite};

/// One AOS→LOS visibility interval.
#[derive(Clone, Debug, PartialEq)]
pub struct ContactWindow {
    /// Acquisition of signal, seconds since epoch.
    pub aos: f64,
    /// Loss of signal.
    pub los: f64,
    /// Peak elevation during the pass, degrees.  For a `truncated`
    /// window this covers only the scanned span and may sit below the
    /// elevation mask.
    pub max_elevation_deg: f64,
    /// True when the scan clipped this pass at a boundary of `[t0, t1]`
    /// — already open at `t0` or still open at `t1`.  The clipped end is
    /// a clamp time, not a bisected horizon crossing, so `duration_s`
    /// understates the physical pass.
    pub truncated: bool,
}

impl ContactWindow {
    pub fn duration_s(&self) -> f64 {
        self.los - self.aos
    }

    pub fn contains(&self, t: f64) -> bool {
        t >= self.aos && t < self.los
    }
}

/// Compute all contact windows in [t0, t1].
///
/// Coarse scan at `step_s` (10 s catches every >20 s pass at LEO angular
/// rates), then bisect each boundary to ±0.1 s.
pub fn contact_windows(
    sat: &Satellite,
    gs: &GroundStation,
    t0: f64,
    t1: f64,
    step_s: f64,
) -> Vec<ContactWindow> {
    assert!(t1 > t0 && step_s > 0.0);
    let mut windows = Vec::new();
    let mut t = t0;
    let mut prev_vis = gs.visible(sat, t0);
    // a pass already open at t0 gets aos = t0 verbatim — a clamp, not a
    // bisected AOS — and must carry the truncation flag
    let mut clipped_at_start = prev_vis;
    let mut aos = if prev_vis { Some(t0) } else { None };
    while t < t1 {
        let tn = (t + step_s).min(t1);
        let vis = gs.visible(sat, tn);
        if vis && !prev_vis {
            aos = Some(bisect(sat, gs, t, tn));
        } else if !vis && prev_vis {
            let los = bisect(sat, gs, t, tn);
            if let Some(a) = aos.take() {
                windows.push(finish(sat, gs, a, los, clipped_at_start));
                clipped_at_start = false;
            }
        }
        prev_vis = vis;
        t = tn;
    }
    if let Some(a) = aos {
        // still visible at t1: los = t1 is a clamp, not a real LOS
        windows.push(finish(sat, gs, a, t1, true));
    }
    windows
}

fn bisect(sat: &Satellite, gs: &GroundStation, mut lo: f64, mut hi: f64) -> f64 {
    // invariant: visibility differs at lo and hi
    let lo_vis = gs.visible(sat, lo);
    while hi - lo > 0.1 {
        let mid = 0.5 * (lo + hi);
        if gs.visible(sat, mid) == lo_vis {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

fn finish(
    sat: &Satellite,
    gs: &GroundStation,
    aos: f64,
    los: f64,
    truncated: bool,
) -> ContactWindow {
    let mut max_el = f64::MIN;
    let n = 64;
    for i in 0..=n {
        let t = aos + (los - aos) * i as f64 / n as f64;
        max_el = max_el.max(gs.elevation_rad(sat, t).to_degrees());
    }
    ContactWindow { aos, los, max_elevation_deg: max_el, truncated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{baoyun, beijing_station};

    const DAY: f64 = 86_400.0;

    fn day_windows() -> Vec<ContactWindow> {
        contact_windows(&baoyun(), &beijing_station(), 0.0, DAY, 10.0)
    }

    #[test]
    fn some_passes_per_day() {
        let w = day_windows();
        // A 97° 500 km orbit sees a mid-latitude station ~2-6 times/day.
        assert!((1..=10).contains(&w.len()), "passes {}", w.len());
    }

    #[test]
    fn windows_disjoint_and_ordered() {
        let w = day_windows();
        for pair in w.windows(2) {
            assert!(pair[0].los <= pair[1].aos, "{pair:?}");
        }
        for win in &w {
            assert!(win.duration_s() > 0.0);
        }
    }

    #[test]
    fn pass_durations_realistic() {
        // LEO passes above a 10° mask last roughly 1-12 minutes.
        for win in day_windows() {
            assert!(
                (20.0..800.0).contains(&win.duration_s()),
                "duration {}",
                win.duration_s()
            );
        }
    }

    #[test]
    fn visibility_holds_inside_window() {
        let sat = baoyun();
        let gs = beijing_station();
        for win in day_windows() {
            let mid = 0.5 * (win.aos + win.los);
            assert!(gs.visible(&sat, mid));
            assert!(!gs.visible(&sat, win.aos - 5.0));
            assert!(!gs.visible(&sat, win.los + 5.0));
        }
    }

    #[test]
    fn max_elevation_above_mask() {
        // only a whole pass guarantees the mask was crossed; a truncated
        // span can peak below it
        for win in day_windows() {
            if !win.truncated {
                assert!(win.max_elevation_deg >= 10.0 - 0.2, "{}", win.max_elevation_deg);
            }
        }
    }

    #[test]
    fn scan_starting_mid_pass_flags_truncation() {
        let sat = baoyun();
        let gs = beijing_station();
        let full = day_windows();
        let w0 = &full[0];
        assert!(!w0.truncated, "the first full-scan pass opens after t0");
        let mid = 0.5 * (w0.aos + w0.los);

        // scan starting mid-pass: the open pass is clamped and flagged
        let clipped = contact_windows(&sat, &gs, mid, DAY, 10.0);
        let first = &clipped[0];
        assert!(first.truncated, "pass open at t0 must be flagged");
        assert_eq!(first.aos, mid, "aos clamps to the scan start");
        assert!((first.los - w0.los).abs() < 0.3, "los is still a bisected crossing");
        assert!(first.duration_s() < w0.duration_s());
        // later passes are unaffected: same boundaries, same flags
        assert_eq!(clipped.len(), full.len());
        for (c, f) in clipped.iter().zip(full.iter()).skip(1) {
            assert!((c.aos - f.aos).abs() < 0.3 && (c.los - f.los).abs() < 0.3);
            assert_eq!(c.truncated, f.truncated);
        }

        // scan ending mid-pass: the still-open pass is clamped at t1
        let endclip = contact_windows(&sat, &gs, 0.0, mid, 10.0);
        let last = endclip.last().expect("the straddled pass is emitted");
        assert!(last.truncated, "pass open at t1 must be flagged");
        assert_eq!(last.los, mid, "los clamps to the scan end");
        assert!((last.aos - w0.aos).abs() < 0.3);
    }
}
