//! Dynamic batcher for onboard inference.
//!
//! The Pi-class payload amortizes per-invocation overhead by batching up
//! to the largest exported artifact batch; a deadline bounds the latency
//! a tile can sit in the queue (the vLLM-style trade-off, scaled down).

use std::collections::VecDeque;

use crate::data::Tile;

pub struct Batcher {
    queue: VecDeque<(Tile, f64)>, // (tile, enqueue time)
    pub max_batch: usize,
    /// Max seconds a tile may wait before the batch is forced out.
    pub max_wait_s: f64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait_s: f64) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { queue: VecDeque::new(), max_batch, max_wait_s }
    }

    pub fn push(&mut self, tile: Tile, now: f64) {
        self.queue.push_back((tile, now));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop a batch if (a) a full batch is available, or (b) the oldest
    /// tile has waited past the deadline, or (c) `flush` is set.
    ///
    /// Queue delays are returned through the caller-supplied `delays`
    /// (cleared, then one entry per popped tile) so a hot loop that
    /// polls per batch reuses one allocation instead of making — and
    /// immediately discarding — a fresh `Vec` every pop.
    pub fn pop(&mut self, now: f64, flush: bool, delays: &mut Vec<f64>) -> Option<Vec<Tile>> {
        delays.clear();
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now - self.queue.front().unwrap().1;
        if self.queue.len() >= self.max_batch || oldest_wait >= self.max_wait_s || flush {
            let n = self.queue.len().min(self.max_batch);
            let mut tiles = Vec::with_capacity(n);
            delays.reserve(n);
            for _ in 0..n {
                let (t, at) = self.queue.pop_front().unwrap();
                tiles.push(t);
                delays.push(now - at);
            }
            Some(tiles)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile() -> Tile {
        let pixels = vec![0.0; 64 * 64 * 3].into();
        Tile { scene_id: 0, x0: 0, y0: 0, frag: 64, pixels, gt: vec![] }
    }

    #[test]
    fn full_batch_pops_immediately() {
        let mut b = Batcher::new(4, 10.0);
        for _ in 0..4 {
            b.push(tile(), 0.0);
        }
        let mut delays = Vec::new();
        let tiles = b.pop(0.0, false, &mut delays).unwrap();
        assert_eq!(tiles.len(), 4);
        assert_eq!(delays.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits() {
        let mut b = Batcher::new(4, 10.0);
        b.push(tile(), 0.0);
        assert!(b.pop(1.0, false, &mut Vec::new()).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b = Batcher::new(4, 10.0);
        b.push(tile(), 0.0);
        let mut delays = Vec::new();
        let tiles = b.pop(11.0, false, &mut delays).unwrap();
        assert_eq!(tiles.len(), 1);
        assert!(delays[0] >= 10.0);
    }

    #[test]
    fn flush_drains_regardless() {
        let mut b = Batcher::new(4, 10.0);
        b.push(tile(), 0.0);
        b.push(tile(), 0.0);
        let tiles = b.pop(0.1, true, &mut Vec::new()).unwrap();
        assert_eq!(tiles.len(), 2);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = Batcher::new(4, 10.0);
        for _ in 0..9 {
            b.push(tile(), 0.0);
        }
        let mut delays = Vec::new();
        let t1 = b.pop(0.0, false, &mut delays).unwrap();
        assert_eq!(t1.len(), 4);
        assert_eq!(b.pending(), 5);
        let t2 = b.pop(0.0, false, &mut delays).unwrap();
        assert_eq!(t2.len(), 4);
        let t3 = b.pop(0.0, true, &mut delays).unwrap();
        assert_eq!(t3.len(), 1);
        assert_eq!(delays.len(), 1, "delays must be cleared and refilled per pop");
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2, 10.0);
        let mut t1 = tile();
        t1.scene_id = 1;
        let mut t2 = tile();
        t2.scene_id = 2;
        b.push(t1, 0.0);
        b.push(t2, 0.0);
        let tiles = b.pop(0.0, false, &mut Vec::new()).unwrap();
        assert_eq!(tiles[0].scene_id, 1);
        assert_eq!(tiles[1].scene_id, 2);
    }
}
