//! Contact-window-gated downlink queue.
//!
//! "The handover between them only occurs during the contact time between
//! the satellite and the ground" (§IV).  Items (compact results or raw
//! tiles) queue onboard; during each window the queue drains through the
//! lossy [`crate::link::Link`], results first (they're small and
//! time-critical), then images.

use std::collections::VecDeque;

use crate::link::Link;
use crate::orbit::ContactWindow;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ItemKind {
    /// Compact detection results (16 B per box + 8 B tile header).
    Results,
    /// Raw tile imagery for ground re-inference.
    Image,
}

#[derive(Clone, Debug)]
pub struct DownlinkItem {
    pub kind: ItemKind,
    pub bytes: u64,
    /// Virtual time when the item became ready onboard.
    pub ready_at: f64,
    /// Tile tag for latency attribution.
    pub tag: u64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct DownlinkStats {
    pub results_bytes: u64,
    pub image_bytes: u64,
    pub items_delivered: u64,
    pub items_dropped: u64,
    /// Sum + count of (delivery - ready) latencies for delivered items.
    pub latency_sum_s: f64,
    pub latency_count: u64,
}

impl DownlinkStats {
    pub fn total_bytes(&self) -> u64 {
        self.results_bytes + self.image_bytes
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.latency_count == 0 {
            0.0
        } else {
            self.latency_sum_s / self.latency_count as f64
        }
    }
}

/// Delivered item (handed to the ground segment).
#[derive(Clone, Debug)]
pub struct Delivered {
    pub item: DownlinkItem,
    pub at: f64,
}

pub struct DownlinkQueue {
    results: VecDeque<DownlinkItem>,
    images: VecDeque<DownlinkItem>,
    pub stats: DownlinkStats,
    /// Give up on an item after this many failed windows (paper's systems
    /// drop stale observations rather than stall the queue).
    pub max_window_failures: u32,
    failures: u32,
}

impl DownlinkQueue {
    pub fn new() -> DownlinkQueue {
        DownlinkQueue {
            results: VecDeque::new(),
            images: VecDeque::new(),
            stats: DownlinkStats::default(),
            max_window_failures: 3,
            failures: 0,
        }
    }

    pub fn push(&mut self, item: DownlinkItem) {
        match item.kind {
            ItemKind::Results => self.results.push_back(item),
            ItemKind::Image => self.images.push_back(item),
        }
    }

    pub fn pending(&self) -> usize {
        self.results.len() + self.images.len()
    }

    pub fn pending_bytes(&self) -> u64 {
        self.results.iter().chain(self.images.iter()).map(|i| i.bytes).sum()
    }

    /// Drain through `link` during `window`.  Only items ready before the
    /// window closes are eligible.  Returns delivered items.
    pub fn drain_window(&mut self, link: &mut Link, window: &ContactWindow) -> Vec<Delivered> {
        let mut now = window.aos;
        let mut out = Vec::new();
        loop {
            // results before images; within a class, FIFO
            let queue_is_results = !self.results.is_empty();
            let item = if queue_is_results {
                self.results.front()
            } else {
                self.images.front()
            };
            let Some(item) = item else { break };
            if item.ready_at > window.los {
                break; // not yet captured when this window closes
            }
            let start = now.max(item.ready_at);
            let budget = window.los - start;
            if budget <= 0.0 {
                break;
            }
            let t = link.transmit(item.bytes, budget);
            now = start + t.elapsed_s;
            if t.completed {
                let item = if queue_is_results {
                    self.results.pop_front().unwrap()
                } else {
                    self.images.pop_front().unwrap()
                };
                match item.kind {
                    ItemKind::Results => self.stats.results_bytes += item.bytes,
                    ItemKind::Image => self.stats.image_bytes += item.bytes,
                }
                self.stats.items_delivered += 1;
                self.stats.latency_sum_s += now - item.ready_at;
                self.stats.latency_count += 1;
                self.failures = 0;
                out.push(Delivered { item, at: now });
            } else {
                // window exhausted or link hopeless for this item
                self.failures += 1;
                if self.failures >= self.max_window_failures {
                    let item = if queue_is_results {
                        self.results.pop_front().unwrap()
                    } else {
                        self.images.pop_front().unwrap()
                    };
                    let _ = item;
                    self.stats.items_dropped += 1;
                    self.failures = 0;
                }
                break;
            }
        }
        out
    }
}

impl Default for DownlinkQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::{LinkConfig, LossProfile};

    fn win(aos: f64, los: f64) -> ContactWindow {
        ContactWindow { aos, los, max_elevation_deg: 45.0 }
    }

    fn link(seed: u64) -> Link {
        Link::new(LinkConfig::downlink(LossProfile::stable()), seed)
    }

    fn item(kind: ItemKind, bytes: u64, ready: f64, tag: u64) -> DownlinkItem {
        DownlinkItem { kind, bytes, ready_at: ready, tag }
    }

    #[test]
    fn results_drain_before_images() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 10_000, 0.0, 1));
        q.push(item(ItemKind::Results, 100, 0.0, 2));
        let got = q.drain_window(&mut link(1), &win(100.0, 200.0));
        assert_eq!(got[0].item.tag, 2, "results first");
        assert_eq!(got[1].item.tag, 1);
    }

    #[test]
    fn item_not_ready_waits_for_next_window() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 100, 500.0, 1));
        let got = q.drain_window(&mut link(2), &win(100.0, 200.0));
        assert!(got.is_empty());
        assert_eq!(q.pending(), 1);
        let got = q.drain_window(&mut link(2), &win(600.0, 700.0));
        assert_eq!(got.len(), 1);
        // latency counted from ready_at, not from push
        assert!(got[0].at >= 600.0);
    }

    #[test]
    fn window_capacity_limits_bytes() {
        let mut q = DownlinkQueue::new();
        // 40 Mbps * 1 s = 5 MB; queue 20 MB of images
        for i in 0..20 {
            q.push(item(ItemKind::Image, 1_000_000, 0.0, i));
        }
        let got = q.drain_window(&mut link(3), &win(0.0, 1.0));
        assert!(got.len() < 20, "only part of the queue fits one window");
        assert!(!got.is_empty());
        assert!(q.pending() > 0);
    }

    #[test]
    fn repeated_failures_drop_item() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 100_000_000, 0.0, 1)); // never fits
        for k in 0..3 {
            q.drain_window(&mut link(4 + k), &win(k as f64 * 100.0, k as f64 * 100.0 + 1.0));
        }
        assert_eq!(q.pending(), 0);
        assert_eq!(q.stats.items_dropped, 1);
    }

    #[test]
    fn byte_accounting_by_kind() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 160, 0.0, 1));
        q.push(item(ItemKind::Image, 12_288, 0.0, 2));
        q.drain_window(&mut link(5), &win(0.0, 60.0));
        assert_eq!(q.stats.results_bytes, 160);
        assert_eq!(q.stats.image_bytes, 12_288);
        assert_eq!(q.stats.items_delivered, 2);
    }

    #[test]
    fn latency_accumulates() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 100, 0.0, 1));
        q.drain_window(&mut link(6), &win(50.0, 60.0));
        assert!(q.stats.mean_latency_s() >= 50.0);
    }
}
