//! Contact-window-gated downlink queue.
//!
//! "The handover between them only occurs during the contact time between
//! the satellite and the ground" (§IV).  Items (compact results or raw
//! tiles) queue onboard; during each window the queue drains through the
//! lossy [`crate::link::Link`], results first (they're small and
//! time-critical), then images.

use std::collections::VecDeque;

use crate::link::{ArqPolicy, FrameFault, Link, Transfer};
use crate::orbit::ContactWindow;
use crate::telemetry::trace::{SatTracer, SpanKind, TracePayload};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ItemKind {
    /// Compact detection results (16 B per box + 8 B tile header).
    Results,
    /// Raw tile imagery for ground re-inference.
    Image,
    /// Federated model weights ((dim + 1) × 4 B per round).  Small
    /// control-plane traffic: queues with results ahead of imagery, but
    /// is accounted separately so federated uplink is visible in the
    /// link books.
    Weights,
}

#[derive(Clone, Debug)]
pub struct DownlinkItem {
    pub kind: ItemKind,
    pub bytes: u64,
    /// Virtual time when the item became ready onboard.
    pub ready_at: f64,
    /// Tile tag for latency attribution.
    pub tag: u64,
}

#[derive(Clone, Debug, Default)]
pub struct DownlinkStats {
    pub results_bytes: u64,
    pub image_bytes: u64,
    /// Federated weight bytes delivered (the training uplink's share of
    /// pass airtime).
    pub weights_bytes: u64,
    pub items_delivered: u64,
    pub items_dropped: u64,
    /// Bytes of dropped items (they never crossed the link, but they
    /// were queued — without this they vanish from byte accounting).
    pub bytes_dropped: u64,
    /// Sum + count of (delivery - ready) latencies for delivered items.
    pub latency_sum_s: f64,
    pub latency_count: u64,
    /// Delivered bytes by ground station (indexed by `station_id`, grown
    /// on demand).  Invariant: the entries sum to [`Self::total_bytes`].
    pub station_bytes: Vec<u64>,
}

impl DownlinkStats {
    pub fn total_bytes(&self) -> u64 {
        self.results_bytes + self.image_bytes + self.weights_bytes
    }

    /// Bytes delivered through one station (0 for stations this queue
    /// never transmitted to).
    pub fn station_bytes(&self, station_id: usize) -> u64 {
        self.station_bytes.get(station_id).copied().unwrap_or(0)
    }

    fn add_station_bytes(&mut self, station_id: usize, bytes: u64) {
        if self.station_bytes.len() <= station_id {
            self.station_bytes.resize(station_id + 1, 0);
        }
        self.station_bytes[station_id] += bytes;
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.latency_count == 0 {
            0.0
        } else {
            self.latency_sum_s / self.latency_count as f64
        }
    }
}

/// Delivered item (handed to the ground segment).
#[derive(Clone, Debug)]
pub struct Delivered {
    pub item: DownlinkItem,
    pub at: f64,
}

pub struct DownlinkQueue {
    results: VecDeque<DownlinkItem>,
    images: VecDeque<DownlinkItem>,
    pub stats: DownlinkStats,
    /// Give up on an item after this many failed windows (paper's systems
    /// drop stale observations rather than stall the queue).
    pub max_window_failures: u32,
    /// Failed-window counts for the *current head* of each class; a
    /// class's counter resets when its head is delivered or dropped, and
    /// failures in one class never evict the other's head.
    results_failures: u32,
    images_failures: u32,
}

impl DownlinkQueue {
    pub fn new() -> DownlinkQueue {
        DownlinkQueue {
            results: VecDeque::new(),
            images: VecDeque::new(),
            stats: DownlinkStats::default(),
            max_window_failures: 3,
            results_failures: 0,
            images_failures: 0,
        }
    }

    pub fn push(&mut self, item: DownlinkItem) {
        match item.kind {
            // weights share the results class: both are small and
            // time-critical relative to raw imagery
            ItemKind::Results | ItemKind::Weights => self.results.push_back(item),
            ItemKind::Image => self.images.push_back(item),
        }
    }

    pub fn pending(&self) -> usize {
        self.results.len() + self.images.len()
    }

    pub fn pending_bytes(&self) -> u64 {
        self.results.iter().chain(self.images.iter()).map(|i| i.bytes).sum()
    }

    /// Drain through `link` during a full contact `window`.  Only items
    /// ready before the window closes are eligible.  Returns delivered
    /// items.  A failed transfer counts toward the head item's
    /// `max_window_failures` (this is a whole pass).
    pub fn drain_window(&mut self, link: &mut Link, window: &ContactWindow) -> Vec<Delivered> {
        self.drain_window_sliced(link, window, true)
    }

    /// Drain through `link` during one slice of a contact window (the
    /// timeline hands passes out incrementally).  `closes_pass` marks the
    /// slice that reaches the physical window's LOS: only then does a
    /// failed transfer count toward `max_window_failures` — a transfer
    /// that didn't fit a mid-pass slice still has pass time ahead of it.
    ///
    /// The ARQ model has no transfer resume: an interrupted item restarts
    /// from byte zero next time.  A transfer that cannot complete even
    /// loss-free within the slice budget is therefore not started at all
    /// (no airtime burned on a doomed restart); on a pass-closing slice
    /// it is still charged the failed window.
    pub fn drain_window_sliced(
        &mut self,
        link: &mut Link,
        window: &ContactWindow,
        closes_pass: bool,
    ) -> Vec<Delivered> {
        self.drain_core(link, window, closes_pass, |l, bytes, budget| l.transmit(bytes, budget))
    }

    /// The one drain loop, parameterized over the transfer primitive so
    /// the nominal path ([`crate::link::Link::transmit`]) and the chaos
    /// path ([`crate::link::Link::transmit_checked`]) share byte-for-byte
    /// scheduling: head selection, readiness, min-airtime precheck, and
    /// failure charging are identical in both.
    fn drain_core(
        &mut self,
        link: &mut Link,
        window: &ContactWindow,
        closes_pass: bool,
        mut transmit: impl FnMut(&mut Link, u64, f64) -> Transfer,
    ) -> Vec<Delivered> {
        let mut now = window.aos;
        let mut out = Vec::new();
        loop {
            // results before images; within a class, FIFO
            let queue_is_results = !self.results.is_empty();
            let head = if queue_is_results {
                self.results.front()
            } else {
                self.images.front()
            };
            let Some(head) = head else { break };
            let (bytes, ready_at) = (head.bytes, head.ready_at);
            if ready_at > window.los {
                break; // not yet captured when this window closes
            }
            let start = now.max(ready_at);
            let budget = window.los - start;
            if budget <= 0.0 {
                break;
            }
            let packet_time = link.cfg.mtu as f64 * 8.0 / link.cfg.rate_bps;
            let min_airtime = bytes.div_ceil(link.cfg.mtu as u64).max(1) as f64 * packet_time;
            if min_airtime > budget {
                if closes_pass {
                    self.note_failure(queue_is_results);
                }
                break;
            }
            let t = transmit(link, bytes, budget);
            now = start + t.elapsed_s;
            if t.completed {
                let item = if queue_is_results {
                    self.results_failures = 0;
                    self.results.pop_front().unwrap()
                } else {
                    self.images_failures = 0;
                    self.images.pop_front().unwrap()
                };
                match item.kind {
                    ItemKind::Results => self.stats.results_bytes += item.bytes,
                    ItemKind::Image => self.stats.image_bytes += item.bytes,
                    ItemKind::Weights => self.stats.weights_bytes += item.bytes,
                }
                self.stats.add_station_bytes(window.station_id, item.bytes);
                self.stats.items_delivered += 1;
                self.stats.latency_sum_s += now - item.ready_at;
                self.stats.latency_count += 1;
                out.push(Delivered { item, at: now });
            } else {
                // lost packets exhausted the ARQ budget; the failure
                // belongs to this class's head alone, and only a
                // pass-closing slice charges it a failed window
                if closes_pass {
                    self.note_failure(queue_is_results);
                }
                break;
            }
        }
        out
    }

    /// [`Self::drain_window_sliced`] with flight-recorder accounting:
    /// every slice becomes a `DownlinkSlice` span (bytes = delivered by
    /// this slice, straight off the stats delta so the trace can never
    /// disagree with the books), and a slice whose failures dropped
    /// bytes adds a `Drop` event at LOS.  `tracer: None` is exactly the
    /// untraced drain.
    pub fn drain_window_sliced_traced(
        &mut self,
        link: &mut Link,
        window: &ContactWindow,
        closes_pass: bool,
        tracer: Option<&SatTracer>,
    ) -> Vec<Delivered> {
        let Some(tr) = tracer else {
            return self.drain_window_sliced(link, window, closes_pass);
        };
        let delivered_before = self.stats.total_bytes();
        let dropped_before = self.stats.bytes_dropped;
        let out = self.drain_window_sliced(link, window, closes_pass);
        tr.span(
            SpanKind::DownlinkSlice,
            window.aos,
            window.los,
            TracePayload::StationBytes {
                station: window.station_id as u32,
                bytes: self.stats.total_bytes() - delivered_before,
            },
        );
        let dropped = self.stats.bytes_dropped - dropped_before;
        if dropped > 0 {
            tr.event(SpanKind::Drop, window.los, TracePayload::Bytes(dropped));
        }
        out
    }

    /// Chaos-path drain: identical scheduling to
    /// [`Self::drain_window_sliced_traced`], but every transfer goes
    /// through [`crate::link::Link::transmit_checked`] — `inject` draws
    /// the frame verdict for each completed attempt (one draw per
    /// attempt, so both engines consume the fault stream in the same
    /// order) and `arq` bounds the retry/backoff loop inside the
    /// remaining slice budget.  With an inject that always returns
    /// `None`, `transmit_checked` is byte-for-byte `transmit`, so the
    /// zero-fault chaos drain is bit-identical to the nominal drain —
    /// the property `tests/chaos_invariants.rs` pins.
    ///
    /// Crash recovery rides on the no-resume ARQ model for free: a
    /// blacked-out slice is simply never drained, the unacknowledged
    /// heads stay queued (no failure charge — the satellite was dark,
    /// not the channel bad), and the next healthy window replays them
    /// from byte zero with delivery counted exactly once.
    pub fn drain_window_sliced_chaos(
        &mut self,
        link: &mut Link,
        window: &ContactWindow,
        closes_pass: bool,
        tracer: Option<&SatTracer>,
        arq: &ArqPolicy,
        inject: &mut impl FnMut() -> Option<FrameFault>,
    ) -> Vec<Delivered> {
        let delivered_before = self.stats.total_bytes();
        let dropped_before = self.stats.bytes_dropped;
        let out = self.drain_core(link, window, closes_pass, |l, bytes, budget| {
            l.transmit_checked(bytes, budget, arq, &mut *inject)
        });
        if let Some(tr) = tracer {
            tr.span(
                SpanKind::DownlinkSlice,
                window.aos,
                window.los,
                TracePayload::StationBytes {
                    station: window.station_id as u32,
                    bytes: self.stats.total_bytes() - delivered_before,
                },
            );
            let dropped = self.stats.bytes_dropped - dropped_before;
            if dropped > 0 {
                tr.event(SpanKind::Drop, window.los, TracePayload::Bytes(dropped));
            }
        }
        out
    }

    /// Charge the head of one class a failed window; after
    /// `max_window_failures` the item is dropped with its bytes
    /// accounted in `bytes_dropped`.
    fn note_failure(&mut self, queue_is_results: bool) {
        let failures = if queue_is_results {
            &mut self.results_failures
        } else {
            &mut self.images_failures
        };
        *failures += 1;
        if *failures >= self.max_window_failures {
            *failures = 0;
            let item = if queue_is_results {
                self.results.pop_front().unwrap()
            } else {
                self.images.pop_front().unwrap()
            };
            self.stats.items_dropped += 1;
            self.stats.bytes_dropped += item.bytes;
        }
    }
}

impl Default for DownlinkQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::{LinkConfig, LossProfile};

    fn win(aos: f64, los: f64) -> ContactWindow {
        win_at(aos, los, 0)
    }

    fn win_at(aos: f64, los: f64, station_id: usize) -> ContactWindow {
        ContactWindow { aos, los, max_elevation_deg: 45.0, truncated: false, station_id }
    }

    fn link(seed: u64) -> Link {
        Link::new(LinkConfig::downlink(LossProfile::stable()), seed)
    }

    fn item(kind: ItemKind, bytes: u64, ready: f64, tag: u64) -> DownlinkItem {
        DownlinkItem { kind, bytes, ready_at: ready, tag }
    }

    #[test]
    fn results_drain_before_images() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 10_000, 0.0, 1));
        q.push(item(ItemKind::Results, 100, 0.0, 2));
        let got = q.drain_window(&mut link(1), &win(100.0, 200.0));
        assert_eq!(got[0].item.tag, 2, "results first");
        assert_eq!(got[1].item.tag, 1);
    }

    #[test]
    fn item_not_ready_waits_for_next_window() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 100, 500.0, 1));
        let got = q.drain_window(&mut link(2), &win(100.0, 200.0));
        assert!(got.is_empty());
        assert_eq!(q.pending(), 1);
        let got = q.drain_window(&mut link(2), &win(600.0, 700.0));
        assert_eq!(got.len(), 1);
        // latency counted from ready_at, not from push
        assert!(got[0].at >= 600.0);
    }

    #[test]
    fn window_capacity_limits_bytes() {
        let mut q = DownlinkQueue::new();
        // 40 Mbps * 1 s = 5 MB; queue 20 MB of images
        for i in 0..20 {
            q.push(item(ItemKind::Image, 1_000_000, 0.0, i));
        }
        let got = q.drain_window(&mut link(3), &win(0.0, 1.0));
        assert!(got.len() < 20, "only part of the queue fits one window");
        assert!(!got.is_empty());
        assert!(q.pending() > 0);
    }

    #[test]
    fn repeated_failures_drop_item() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 100_000_000, 0.0, 1)); // never fits
        for k in 0..3 {
            q.drain_window(&mut link(4 + k), &win(k as f64 * 100.0, k as f64 * 100.0 + 1.0));
        }
        assert_eq!(q.pending(), 0);
        assert_eq!(q.stats.items_dropped, 1);
        assert_eq!(q.stats.bytes_dropped, 100_000_000, "dropped bytes must be accounted");
        assert_eq!(q.stats.total_bytes(), q.stats.results_bytes + q.stats.image_bytes);
    }

    #[test]
    fn failures_tracked_per_class_head() {
        let mut q = DownlinkQueue::new();
        let big = 10_000_000_000u64; // ~2000 s of airtime: fails any window here
        q.push(item(ItemKind::Image, big, 0.0, 1));
        q.drain_window(&mut link(10), &win(0.0, 1.0));
        q.drain_window(&mut link(11), &win(100.0, 101.0));
        assert_eq!(q.stats.items_dropped, 0);
        // A results item now fails once, in a window too short for even
        // one packet.  Under the old shared counter the image head's two
        // failures would evict it immediately.
        q.push(item(ItemKind::Results, 100, 0.0, 2));
        q.drain_window(&mut link(12), &win(200.0, 200.0001));
        assert_eq!(q.stats.items_dropped, 0, "results head must survive its first failure");
        assert_eq!(q.pending(), 2);
        // A generous window delivers the results, then the image fails
        // its third window and drops — with its bytes accounted.
        let got = q.drain_window(&mut link(13), &win(300.0, 400.0));
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].item.tag, 2);
        assert_eq!(q.stats.items_dropped, 1);
        assert_eq!(q.stats.bytes_dropped, big);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn mid_pass_slices_do_not_count_failures() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 100_000_000, 0.0, 1)); // never fits a 1 s slice
        // five mid-pass slices: the pass isn't over, so no failures accrue
        for k in 0..5 {
            let w = win(k as f64 * 10.0, k as f64 * 10.0 + 1.0);
            q.drain_window_sliced(&mut link(30 + k), &w, false);
        }
        assert_eq!(q.stats.items_dropped, 0, "mid-pass slices must not evict");
        assert_eq!(q.pending(), 1);
        // three pass-closing slices evict, as three failed windows should
        for k in 0..3 {
            let w = win(1000.0 + k as f64 * 100.0, 1000.0 + k as f64 * 100.0 + 1.0);
            q.drain_window_sliced(&mut link(40 + k), &w, true);
        }
        assert_eq!(q.stats.items_dropped, 1);
        assert_eq!(q.stats.bytes_dropped, 100_000_000);
    }

    #[test]
    fn delivery_resets_only_own_class_counter() {
        let mut q = DownlinkQueue::new();
        let big = 10_000_000_000u64;
        q.push(item(ItemKind::Image, big, 0.0, 1));
        q.drain_window(&mut link(20), &win(0.0, 1.0));
        q.drain_window(&mut link(21), &win(100.0, 101.0)); // image failures: 2
        // Delivering results must NOT reset the image head's count: the
        // image drops on its next (third) failed window.
        q.push(item(ItemKind::Results, 100, 0.0, 2));
        let got = q.drain_window(&mut link(22), &win(200.0, 201.0));
        assert_eq!(got.len(), 1, "results delivered, image fails its third window");
        assert_eq!(q.stats.items_dropped, 1);
        assert_eq!(q.stats.bytes_dropped, big);
    }

    #[test]
    fn weights_share_results_priority_and_own_accounting() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Image, 10_000, 0.0, 1));
        q.push(item(ItemKind::Weights, 36, 0.0, 2));
        q.push(item(ItemKind::Results, 100, 0.0, 3));
        let got = q.drain_window(&mut link(7), &win(100.0, 200.0));
        // weights queue with results: both precede imagery, FIFO within
        // the class
        assert_eq!(got[0].item.tag, 2);
        assert_eq!(got[1].item.tag, 3);
        assert_eq!(got[2].item.tag, 1);
        assert_eq!(q.stats.weights_bytes, 36);
        assert_eq!(q.stats.results_bytes, 100);
        assert_eq!(q.stats.image_bytes, 10_000);
        assert_eq!(q.stats.total_bytes(), 36 + 100 + 10_000);
    }

    #[test]
    fn byte_accounting_by_kind() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 160, 0.0, 1));
        q.push(item(ItemKind::Image, 12_288, 0.0, 2));
        q.drain_window(&mut link(5), &win(0.0, 60.0));
        assert_eq!(q.stats.results_bytes, 160);
        assert_eq!(q.stats.image_bytes, 12_288);
        assert_eq!(q.stats.items_delivered, 2);
    }

    #[test]
    fn traced_drain_records_slices_and_drops() {
        use crate::telemetry::trace::TraceSink;
        use std::sync::Arc;
        let sink = Arc::new(TraceSink::new(1, 64));
        let tr = sink.tracer(0, 5);
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 160, 0.0, 1));
        q.drain_window_sliced_traced(&mut link(8), &win(0.0, 60.0), true, Some(&tr));
        // an undeliverable item fails three pass-closing slices and drops
        q.push(item(ItemKind::Image, 100_000_000, 0.0, 2));
        for k in 0..3 {
            let w = win(100.0 + k as f64 * 100.0, 101.0 + k as f64 * 100.0);
            q.drain_window_sliced_traced(&mut link(9 + k), &w, true, Some(&tr));
        }
        let log = sink.merge();
        let slices: Vec<_> =
            log.records().iter().filter(|r| r.kind == SpanKind::DownlinkSlice).collect();
        assert_eq!(slices.len(), 4, "one span per slice");
        assert_eq!(slices[0].payload, TracePayload::StationBytes { station: 0, bytes: 160 });
        assert_eq!(slices[0].t_start, 0.0);
        assert_eq!(slices[0].t_end, 60.0);
        let drops: Vec<_> = log.records().iter().filter(|r| r.kind == SpanKind::Drop).collect();
        assert_eq!(drops.len(), 1);
        assert_eq!(drops[0].payload, TracePayload::Bytes(100_000_000));
        // tracer: None is the plain drain — no records
        let quiet = Arc::new(TraceSink::new(1, 64));
        let mut q2 = DownlinkQueue::new();
        q2.push(item(ItemKind::Results, 160, 0.0, 1));
        q2.drain_window_sliced_traced(&mut link(8), &win(0.0, 60.0), true, None);
        assert!(quiet.merge().is_empty());
        assert_eq!(q2.stats.results_bytes, q.stats.results_bytes);
    }

    #[test]
    fn station_bytes_attribute_deliveries_and_sum_to_total() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 160, 0.0, 1));
        q.push(item(ItemKind::Image, 12_288, 0.0, 2));
        q.push(item(ItemKind::Weights, 36, 0.0, 3));
        // first pass over station 2, second over station 0
        let got = q.drain_window(&mut link(50), &win_at(0.0, 0.05, 2));
        assert!(!got.is_empty(), "short pass still delivers the small results item");
        q.drain_window(&mut link(51), &win_at(100.0, 160.0, 0));
        assert_eq!(q.pending(), 0);
        assert_eq!(q.stats.station_bytes.len(), 3, "grown to cover station 2");
        assert_eq!(q.stats.station_bytes(1), 0, "never transmitted to station 1");
        assert_eq!(q.stats.station_bytes(9), 0, "out-of-range reads are 0, not a panic");
        let sum: u64 = q.stats.station_bytes.iter().sum();
        assert_eq!(sum, q.stats.total_bytes(), "per-station bytes must sum to the total");
        assert!(q.stats.station_bytes(2) >= 36, "weights head went through station 2");
    }

    fn arq() -> ArqPolicy {
        ArqPolicy { max_retries: 4, backoff_initial_s: 0.05, backoff_cap_s: 1.0 }
    }

    #[test]
    fn chaos_drain_without_faults_is_bitwise_nominal() {
        let items = [
            item(ItemKind::Results, 160, 0.0, 1),
            item(ItemKind::Image, 500_000, 0.0, 2),
            item(ItemKind::Weights, 36, 10.0, 3),
            item(ItemKind::Image, 2_000_000, 20.0, 4),
        ];
        let mut nominal = DownlinkQueue::new();
        let mut chaos = DownlinkQueue::new();
        for it in &items {
            nominal.push(it.clone());
            chaos.push(it.clone());
        }
        let mut la = link(60);
        let mut lb = link(60);
        let mut none = || None;
        for (k, closes) in [(0usize, false), (1, true), (2, true)] {
            let w = win(k as f64 * 100.0, k as f64 * 100.0 + 2.0);
            let a = nominal.drain_window_sliced(&mut la, &w, closes);
            let b = chaos.drain_window_sliced_chaos(&mut lb, &w, closes, None, &arq(), &mut none);
            assert_eq!(a.len(), b.len(), "slice {k}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.item.tag, y.item.tag);
                assert_eq!(x.at.to_bits(), y.at.to_bits(), "delivery time must match bitwise");
            }
        }
        assert_eq!(nominal.stats.total_bytes(), chaos.stats.total_bytes());
        assert_eq!(nominal.stats.items_delivered, chaos.stats.items_delivered);
        assert_eq!(la.stats.bytes_delivered, lb.stats.bytes_delivered);
        assert_eq!(la.stats.packets_sent, lb.stats.packets_sent);
        assert_eq!(la.stats.packets_lost, lb.stats.packets_lost);
        assert_eq!(
            la.stats.busy_s.to_bits(),
            lb.stats.busy_s.to_bits(),
            "zero-fault ARQ leaves the link books untouched"
        );
        assert_eq!(lb.stats.retries, 0);
        assert_eq!(lb.stats.bytes_rejected, 0);
    }

    #[test]
    fn chaos_drain_retries_corrupt_frames_and_reconciles_bytes() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 10_000, 0.0, 1));
        let mut l = link(61);
        // first completed attempt arrives corrupt, retry delivers
        let mut verdicts = [Some(FrameFault::Corrupt), None].into_iter();
        let mut inject = move || verdicts.next().flatten();
        let got = q.drain_window_sliced_chaos(&mut l, &win(0.0, 30.0), true, None, &arq(), &mut inject);
        assert_eq!(got.len(), 1);
        assert_eq!(l.stats.retries, 1);
        assert_eq!(l.stats.frames_corrupted, 1);
        assert_eq!(l.stats.bytes_rejected, 10_000, "rejected frame's bytes leave the delivered books");
        assert_eq!(l.stats.bytes_delivered, 10_000, "exactly one accepted copy");
        assert_eq!(q.stats.results_bytes, 10_000, "queue counts the item once");
        assert_eq!(q.stats.items_delivered, 1);
    }

    #[test]
    fn chaos_drain_gives_up_then_replays_without_double_count() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 10_000, 0.0, 7));
        let mut l = link(62);
        // every attempt in the first pass is truncated: ARQ exhausts its
        // retries, the head stays queued with one failed window charged
        let mut always = || Some(FrameFault::Truncate);
        let got = q.drain_window_sliced_chaos(&mut l, &win(0.0, 30.0), true, None, &arq(), &mut always);
        assert!(got.is_empty());
        assert_eq!(l.stats.gave_up, 1);
        assert_eq!(q.pending(), 1, "unacknowledged item stays queued for replay");
        assert_eq!(q.stats.items_delivered, 0);
        // next healthy pass replays it from byte zero, delivered once
        let mut none = || None;
        let got = q.drain_window_sliced_chaos(&mut l, &win(100.0, 130.0), true, None, &arq(), &mut none);
        assert_eq!(got.len(), 1);
        assert_eq!(q.stats.items_delivered, 1, "replay must not double-count");
        assert_eq!(q.stats.results_bytes, 10_000);
        assert_eq!(l.stats.bytes_delivered, 10_000, "only the accepted copy stays in delivered");
        assert_eq!(l.stats.bytes_rejected, 5 * 10_000, "five truncated frames rejected");
    }

    #[test]
    fn chaos_drain_traces_slices_like_nominal() {
        use crate::telemetry::trace::TraceSink;
        use std::sync::Arc;
        let sink = Arc::new(TraceSink::new(1, 64));
        let tr = sink.tracer(0, 3);
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 160, 0.0, 1));
        let mut none = || None;
        q.drain_window_sliced_chaos(&mut link(63), &win(0.0, 60.0), true, Some(&tr), &arq(), &mut none);
        let log = sink.merge();
        let slices: Vec<_> =
            log.records().iter().filter(|r| r.kind == SpanKind::DownlinkSlice).collect();
        assert_eq!(slices.len(), 1);
        assert_eq!(slices[0].payload, TracePayload::StationBytes { station: 0, bytes: 160 });
    }

    #[test]
    fn latency_accumulates() {
        let mut q = DownlinkQueue::new();
        q.push(item(ItemKind::Results, 100, 0.0, 1));
        q.drain_window(&mut link(6), &win(50.0, 60.0));
        assert!(q.stats.mean_latency_s() >= 50.0);
    }
}
