//! Onboard redundancy filter (paper §II: "80%-90% of raw data is invalid
//! due to cloud cover … redundant information such as cloud cover area
//! can be eliminated in advance").
//!
//! Thin wrapper over the CloudScore artifact: batches tiles through the
//! kernel and thresholds the white-fraction statistic.
//!
//! Quantized path (`policy.filter_precision = "i8"`): the keep/drop
//! decision only needs the white-*count* compared against a pre-scaled
//! integer threshold, so the filter can quantize each tile once into a
//! pooled i8 scratch (`q = round(p·127)`, saturating; NaN casts to 0)
//! and integer-accumulate instead of running the f32 kernel.  The i8
//! scale is 127 = `i8::MAX`, the largest scale whose quantized range
//! covers [0, 1] pixels exactly; the integer tile decision
//! `white_count > floor(threshold · n_px)` is *exactly* equivalent to
//! the f32 `white_count / n_px > threshold` (n_px = 4096 = 2¹² makes the
//! f32 division exact and `count` is far below 2²⁴), so the only place
//! the two paths can disagree is per-pixel whiteness within one
//! quantization step (1/127) of `white_thresh` — the documented decision
//! tolerance, equivalence-tested in `tests/datapath_golden.rs`.

use anyhow::Result;

use crate::data::{gather_pixels, Tile};
use crate::runtime::{Model, Runtime};
use crate::util::buffer::QuantPool;

/// Per-tile cloud statistics (mirrors the kernel output row).
#[derive(Clone, Copy, Debug)]
pub struct CloudStats {
    pub mean_lum: f32,
    pub var_lum: f32,
    pub white_frac: f32,
}

/// Numeric path the filter scores tiles with.  `F32` (default) runs the
/// CloudScore artifact and keeps every result bit-identical; `I8`
/// quantizes on the CPU and decides from integer white counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FilterPrecision {
    #[default]
    F32,
    I8,
}

impl FilterPrecision {
    /// Parse the `policy.filter_precision` config value.
    pub fn parse(s: &str) -> Option<FilterPrecision> {
        match s {
            "f32" => Some(FilterPrecision::F32),
            "i8" => Some(FilterPrecision::I8),
            _ => None,
        }
    }
}

/// Fixed-point scale: pixels in [0, 1] map to [0, 127].
pub const QUANT_SCALE: f32 = 127.0;

/// Quantize `pixels` into `out` (`q = round(p·127)`, saturating to the
/// i8 range).  `NaN as i8` is defined to saturate to 0 in Rust, so a NaN
/// channel quantizes to 0 — never white.
pub fn quantize_pixels(pixels: &[f32], out: &mut [i8]) {
    debug_assert_eq!(pixels.len(), out.len());
    for (q, &p) in out.iter_mut().zip(pixels) {
        *q = (p * QUANT_SCALE).round() as i8;
    }
}

/// Integer white threshold: `q > quant_threshold(t)` approximates
/// `p > t` (exact outside the 1/127-wide quantization band around `t`).
pub fn quant_threshold(white_thresh: f32) -> i8 {
    (white_thresh as f64 * QUANT_SCALE as f64).floor().clamp(-128.0, 127.0) as i8
}

/// White pixels in a quantized tile: min channel strictly above `qthr`.
pub fn white_count_quant(quant: &[i8], qthr: i8) -> usize {
    quant
        .chunks_exact(3)
        .filter(|p| p[0].min(p[1]).min(p[2]) > qthr)
        .count()
}

/// CPU f32 reference for the kernel's white fraction: the fraction of
/// pixels whose min channel exceeds `white_thresh`.  Rust's `f32::min`
/// chain skips NaN operands, so an all-NaN pixel compares NaN > t =
/// false — never white (matching the i8 path; a *partially* NaN pixel is
/// where the two definitions may differ, see the module docs).
pub fn white_frac_f32(pixels: &[f32], white_thresh: f32) -> f32 {
    let n = pixels.len() / 3;
    let white = pixels
        .chunks_exact(3)
        .filter(|p| p[0].min(p[1]).min(p[2]) > white_thresh)
        .count();
    white as f32 / n.max(1) as f32
}

/// Pre-scaled integer decision threshold: a tile with `white_count`
/// white pixels out of `n_px` is redundant iff
/// `white_count > scaled_count_threshold(threshold, n_px)`.
pub fn scaled_count_threshold(threshold: f32, n_px: usize) -> i64 {
    (threshold as f64 * n_px as f64).floor() as i64
}

/// The f32 keep/drop rule (strict: exactly-at-threshold keeps).
pub fn is_redundant_f32(white_frac: f32, threshold: f32) -> bool {
    white_frac > threshold
}

/// The integer keep/drop rule — exactly equivalent to
/// [`is_redundant_f32`] for equal white counts (see module docs).
pub fn is_redundant_quant(white_count: usize, n_px: usize, threshold: f32) -> bool {
    white_count as i64 > scaled_count_threshold(threshold, n_px)
}

/// Per-tile stats from the quantized pixels, integer-accumulated:
/// `white_frac` is exact given the quantized whiteness; the luminance
/// moments are fixed-point approximations (the filter decision never
/// reads them — they exist so `score` has the same shape on both paths).
pub fn cloud_stats_quant(quant: &[i8], qthr: i8) -> CloudStats {
    let n = (quant.len() / 3).max(1);
    let mut sum: i64 = 0;
    let mut sumsq: i64 = 0;
    let mut white: usize = 0;
    for p in quant.chunks_exact(3) {
        let l = p[0] as i64 + p[1] as i64 + p[2] as i64; // 3·127·lum
        sum += l;
        sumsq += l * l;
        if p[0].min(p[1]).min(p[2]) > qthr {
            white += 1;
        }
    }
    let scale = 3.0 * QUANT_SCALE as f64; // lum = l / (3·127)
    let mean = sum as f64 / (n as f64 * scale);
    let var = sumsq as f64 / (n as f64 * scale * scale) - mean * mean;
    CloudStats {
        mean_lum: mean as f32,
        var_lum: var.max(0.0) as f32,
        white_frac: white as f32 / n as f32,
    }
}

pub struct CloudFilter<'rt> {
    rt: &'rt Runtime,
    /// white_frac above this ⇒ redundant.
    pub threshold: f32,
    precision: FilterPrecision,
    /// Pooled i8 scratch for the quantized path (shared with the owning
    /// pipeline so steady-state filtering is allocation-free).
    quant: Option<QuantPool>,
}

impl<'rt> CloudFilter<'rt> {
    /// The default f32 filter — bit-identical to every pre-quantization
    /// result.
    pub fn new(rt: &'rt Runtime, threshold: f32) -> CloudFilter<'rt> {
        CloudFilter { rt, threshold, precision: FilterPrecision::F32, quant: None }
    }

    /// Select the scoring path; `quant` backs the i8 scratch (cheap
    /// handle clone — the pool is shared).
    pub fn with_precision(
        rt: &'rt Runtime,
        threshold: f32,
        precision: FilterPrecision,
        quant: QuantPool,
    ) -> CloudFilter<'rt> {
        CloudFilter { rt, threshold, precision, quant: Some(quant) }
    }

    /// Score a batch of tiles (any count; internally padded).  Dispatches
    /// on the configured precision: f32 runs the CloudScore artifact, i8
    /// quantizes into pooled scratch and integer-accumulates on the CPU.
    pub fn score(&self, tiles: &[Tile]) -> Result<Vec<CloudStats>> {
        match self.precision {
            FilterPrecision::F32 => self.score_f32(tiles),
            FilterPrecision::I8 => Ok(self.score_i8(tiles)),
        }
    }

    fn score_f32(&self, tiles: &[Tile]) -> Result<Vec<CloudStats>> {
        let max_b = self.rt.max_batch();
        let mut out = Vec::with_capacity(tiles.len());
        // marshal through the runtime's pooled scratch instead of a
        // fresh concat Vec per chunk
        let mut scratch = self.rt.scratch_buf();
        for chunk in tiles.chunks(max_b) {
            let n_px = gather_pixels(chunk, &mut scratch);
            let rows = self.rt.execute(Model::CloudScore, chunk.len(), &scratch[..n_px])?;
            for r in rows.chunks_exact(3) {
                out.push(CloudStats { mean_lum: r[0], var_lum: r[1], white_frac: r[2] });
            }
        }
        Ok(out)
    }

    /// The quantized scorer: one pooled i8 scratch reused across the
    /// whole batch, no runtime execution at all.
    fn score_i8(&self, tiles: &[Tile]) -> Vec<CloudStats> {
        let qthr = quant_threshold(self.rt.manifest.white_thresh);
        let mut scratch = self.quant_scratch();
        tiles
            .iter()
            .map(|t| {
                let q = &mut scratch[..t.pixels.len()];
                quantize_pixels(&t.pixels, q);
                cloud_stats_quant(q, qthr)
            })
            .collect()
    }

    fn quant_scratch(&self) -> crate::util::buffer::QuantBuf {
        match &self.quant {
            Some(pool) => pool.checkout_dirty(),
            // cold path (a filter built for i8 without a pool is only
            // possible through tests): allocate once for this call
            None => crate::util::buffer::QuantBuf::zeroed(crate::data::TILE_PX),
        }
    }

    /// Partition tiles into (kept, redundant) preserving order.
    pub fn filter(&self, tiles: Vec<Tile>) -> Result<(Vec<Tile>, Vec<Tile>)> {
        let mut kept = Vec::new();
        let mut redundant = Vec::new();
        match self.precision {
            FilterPrecision::F32 => {
                let stats = self.score_f32(&tiles)?;
                for (tile, s) in tiles.into_iter().zip(stats) {
                    if is_redundant_f32(s.white_frac, self.threshold) {
                        redundant.push(tile);
                    } else {
                        kept.push(tile);
                    }
                }
            }
            FilterPrecision::I8 => {
                // integer fast path: quantize once per tile, count white
                // pixels, compare against the pre-scaled threshold —
                // never materializing a float statistic
                let qthr = quant_threshold(self.rt.manifest.white_thresh);
                let mut scratch = self.quant_scratch();
                for tile in tiles {
                    let q = &mut scratch[..tile.pixels.len()];
                    quantize_pixels(&tile.pixels, q);
                    let white = white_count_quant(q, qthr);
                    if is_redundant_quant(white, tile.pixels.len() / 3, self.threshold) {
                        redundant.push(tile);
                    } else {
                        kept.push(tile);
                    }
                }
            }
        }
        Ok((kept, redundant))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{split_scene, SceneGen, Version, TILE_PX};

    fn rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    #[test]
    fn v1_filters_most_tiles() {
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, rt.manifest.redundant_white_frac);
        let scene = SceneGen::new(42, Version::V1.spec(), 8, 8).capture();
        let tiles = split_scene(&scene, 64);
        let n = tiles.len();
        let (kept, redundant) = f.filter(tiles).unwrap();
        assert_eq!(kept.len() + redundant.len(), n);
        let rate = redundant.len() as f64 / n as f64;
        assert!(rate > 0.7, "v1 filter rate {rate}");
    }

    #[test]
    fn v2_filters_less() {
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, rt.manifest.redundant_white_frac);
        let scene = SceneGen::new(43, Version::V2.spec(), 8, 8).capture();
        let n = 64;
        let (_, redundant) = f.filter(split_scene(&scene, 64)).unwrap();
        let rate = redundant.len() as f64 / n as f64;
        assert!((0.1..0.75).contains(&rate), "v2 filter rate {rate}");
    }

    #[test]
    fn scores_match_cpu_recompute() {
        // kernel white_frac == straightforward rust recompute
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, 0.5);
        let scene = SceneGen::new(44, Version::V2.spec(), 2, 2).capture();
        let tiles = split_scene(&scene, 64);
        let stats = f.score(&tiles).unwrap();
        for (tile, s) in tiles.iter().zip(&stats) {
            let white = white_frac_f32(&tile.pixels, rt.manifest.white_thresh);
            assert!((white - s.white_frac).abs() < 1e-4, "{white} vs {}", s.white_frac);
        }
    }

    #[test]
    fn i8_filter_partitions_like_f32_on_real_scenes() {
        let Some(rt) = rt() else { return };
        let quant = QuantPool::new(TILE_PX);
        let scene = SceneGen::new(45, Version::V1.spec(), 4, 4).capture();
        let f32_filter = CloudFilter::new(&rt, rt.manifest.redundant_white_frac);
        let i8_filter = CloudFilter::with_precision(
            &rt,
            rt.manifest.redundant_white_frac,
            FilterPrecision::I8,
            quant,
        );
        let (k32, r32) = f32_filter.filter(split_scene(&scene, 64)).unwrap();
        let (k8, r8) = i8_filter.filter(split_scene(&scene, 64)).unwrap();
        // synthetic scenes put pixels well away from the white threshold,
        // so the quantization band is empty and the partitions agree
        assert_eq!(k32.len(), k8.len(), "i8 kept set diverged");
        assert_eq!(r32.len(), r8.len());
        for (a, b) in k32.iter().zip(&k8) {
            assert_eq!((a.x0, a.y0), (b.x0, b.y0));
        }
    }

    // ---- artifact-free: the quantization/decision primitives ----

    /// The kernel's white threshold (python/compile/kernels/cloudscore.py);
    /// tests pin against the constant so they run artifact-free.
    const WHITE: f32 = 0.72;

    fn tile_pixels(white_px: usize, n_px: usize) -> Vec<f32> {
        // `white_px` pixels of pure white, the rest dark grey
        let mut v = vec![0.1f32; n_px * 3];
        for p in v[..white_px * 3].iter_mut() {
            *p = 1.0;
        }
        v
    }

    fn decisions(pixels: &[f32], threshold: f32) -> (bool, bool) {
        let f = is_redundant_f32(white_frac_f32(pixels, WHITE), threshold);
        let mut q = vec![0i8; pixels.len()];
        quantize_pixels(pixels, &mut q);
        let white = white_count_quant(&q, quant_threshold(WHITE));
        let i = is_redundant_quant(white, pixels.len() / 3, threshold);
        (f, i)
    }

    #[test]
    fn exactly_at_threshold_keeps_on_both_paths() {
        // white_frac == threshold exactly: the strict `>` keeps the tile
        // on the f32 path, and `count > floor(t·n)` keeps it on the i8
        // path — count == floor(t·n) when t·n is integral.
        let n = 4096;
        let thr = 0.5f32; // 2048 / 4096, exactly representable
        let px = tile_pixels(2048, n);
        let (f, i) = decisions(&px, thr);
        assert!(!f, "f32: exactly-at-threshold must be kept (strict >)");
        assert!(!i, "i8: exactly-at-threshold must be kept");
        // one more white pixel tips both over
        let px = tile_pixels(2049, n);
        let (f, i) = decisions(&px, thr);
        assert!(f && i, "one pixel past the threshold must drop on both paths");
    }

    #[test]
    fn all_white_and_all_black_agree() {
        let n = 4096;
        let white = vec![1.0f32; n * 3];
        let black = vec![0.0f32; n * 3];
        let (f, i) = decisions(&white, 0.5);
        assert!(f && i, "all-white must drop on both paths");
        let (f, i) = decisions(&black, 0.5);
        assert!(!f && !i, "all-black must keep on both paths");
        // threshold 1.0 is unreachable: even all-white keeps (frac == 1.0
        // is not > 1.0, and count 4096 is not > floor(1.0·4096))
        let (f, i) = decisions(&white, 1.0);
        assert!(!f && !i);
    }

    #[test]
    fn nan_pixels_are_never_white_on_either_path() {
        let n = 64;
        let mut px = vec![1.0f32; n * 3]; // fully white baseline
        // all-NaN pixel: f32 min chain yields NaN (NaN > t is false),
        // i8 quantizes NaN to 0 — non-white on both paths
        px[0] = f32::NAN;
        px[1] = f32::NAN;
        px[2] = f32::NAN;
        let wf = white_frac_f32(&px, WHITE);
        assert!((wf - (n as f32 - 1.0) / n as f32).abs() < 1e-6, "NaN pixel counted white: {wf}");
        let mut q = vec![0i8; px.len()];
        quantize_pixels(&px, &mut q);
        assert_eq!(q[0], 0, "NaN must quantize to 0");
        assert_eq!(white_count_quant(&q, quant_threshold(WHITE)), n - 1);
        // decision identical wherever both are defined
        let (f, i) = decisions(&px, (n as f32 - 1.5) / n as f32);
        assert!(f && i);
    }

    #[test]
    fn quantization_is_saturating_and_monotone() {
        let mut q = [0i8; 6];
        quantize_pixels(&[-5.0, 0.0, 0.5, 1.0, 5.0, f32::INFINITY], &mut q);
        assert_eq!(q, [-128, 0, 64, 127, 127, 127]);
        // the integer threshold brackets the float one
        let qt = quant_threshold(WHITE);
        assert!(qt as f32 / QUANT_SCALE <= WHITE);
        assert!((qt + 1) as f32 / QUANT_SCALE > WHITE);
    }

    #[test]
    fn scaled_threshold_matches_f32_division_for_every_count() {
        // `count/4096 > t` (exact f32 division) ⟺ `count > floor(t·4096)`
        // for every possible count — the exact-equivalence claim
        for thr in [0.0f32, 0.3, 0.5, 0.6, 0.72, 0.9999, 1.0] {
            let scaled = scaled_count_threshold(thr, 4096);
            for count in 0..=4096usize {
                let f = is_redundant_f32(count as f32 / 4096.0, thr);
                let i = (count as i64) > scaled;
                assert_eq!(f, i, "thr {thr} count {count}");
            }
        }
    }

    #[test]
    fn quant_stats_track_f32_moments() {
        let mut rng = crate::util::rng::Rng::new(9);
        let px: Vec<f32> = (0..TILE_PX).map(|_| rng.f32()).collect();
        let mut q = vec![0i8; TILE_PX];
        quantize_pixels(&px, &mut q);
        let s = cloud_stats_quant(&q, quant_threshold(WHITE));
        // f64 reference moments
        let n = (TILE_PX / 3) as f64;
        let lums: Vec<f64> =
            px.chunks_exact(3).map(|p| (p[0] + p[1] + p[2]) as f64 / 3.0).collect();
        let mean = lums.iter().sum::<f64>() / n;
        let var = lums.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / n;
        assert!((s.mean_lum as f64 - mean).abs() < 0.01, "{} vs {mean}", s.mean_lum);
        assert!((s.var_lum as f64 - var).abs() < 0.01, "{} vs {var}", s.var_lum);
        assert!((s.white_frac - white_frac_f32(&px, WHITE)).abs() < 0.05);
    }
}
