//! Onboard redundancy filter (paper §II: "80%-90% of raw data is invalid
//! due to cloud cover … redundant information such as cloud cover area
//! can be eliminated in advance").
//!
//! Thin wrapper over the CloudScore artifact: batches tiles through the
//! kernel and thresholds the white-fraction statistic.

use anyhow::Result;

use crate::data::{gather_pixels, Tile};
use crate::runtime::{Model, Runtime};

/// Per-tile cloud statistics (mirrors the kernel output row).
#[derive(Clone, Copy, Debug)]
pub struct CloudStats {
    pub mean_lum: f32,
    pub var_lum: f32,
    pub white_frac: f32,
}

pub struct CloudFilter<'rt> {
    rt: &'rt Runtime,
    /// white_frac above this ⇒ redundant.
    pub threshold: f32,
}

impl<'rt> CloudFilter<'rt> {
    pub fn new(rt: &'rt Runtime, threshold: f32) -> CloudFilter<'rt> {
        CloudFilter { rt, threshold }
    }

    /// Score a batch of tiles (any count; internally padded).
    pub fn score(&self, tiles: &[Tile]) -> Result<Vec<CloudStats>> {
        let max_b = self.rt.max_batch();
        let mut out = Vec::with_capacity(tiles.len());
        // marshal through the runtime's pooled scratch instead of a
        // fresh concat Vec per chunk
        let mut scratch = self.rt.scratch_buf();
        for chunk in tiles.chunks(max_b) {
            let n_px = gather_pixels(chunk, &mut scratch);
            let rows = self.rt.execute(Model::CloudScore, chunk.len(), &scratch[..n_px])?;
            for r in rows.chunks_exact(3) {
                out.push(CloudStats { mean_lum: r[0], var_lum: r[1], white_frac: r[2] });
            }
        }
        Ok(out)
    }

    /// Partition tiles into (kept, redundant) preserving order.
    pub fn filter(&self, tiles: Vec<Tile>) -> Result<(Vec<Tile>, Vec<Tile>)> {
        let stats = self.score(&tiles)?;
        let mut kept = Vec::new();
        let mut redundant = Vec::new();
        for (tile, s) in tiles.into_iter().zip(stats) {
            if s.white_frac > self.threshold {
                redundant.push(tile);
            } else {
                kept.push(tile);
            }
        }
        Ok((kept, redundant))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{split_scene, SceneGen, Version};

    fn rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    #[test]
    fn v1_filters_most_tiles() {
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, rt.manifest.redundant_white_frac);
        let scene = SceneGen::new(42, Version::V1.spec(), 8, 8).capture();
        let tiles = split_scene(&scene, 64);
        let n = tiles.len();
        let (kept, redundant) = f.filter(tiles).unwrap();
        assert_eq!(kept.len() + redundant.len(), n);
        let rate = redundant.len() as f64 / n as f64;
        assert!(rate > 0.7, "v1 filter rate {rate}");
    }

    #[test]
    fn v2_filters_less() {
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, rt.manifest.redundant_white_frac);
        let scene = SceneGen::new(43, Version::V2.spec(), 8, 8).capture();
        let n = 64;
        let (_, redundant) = f.filter(split_scene(&scene, 64)).unwrap();
        let rate = redundant.len() as f64 / n as f64;
        assert!((0.1..0.75).contains(&rate), "v2 filter rate {rate}");
    }

    #[test]
    fn scores_match_cpu_recompute() {
        // kernel white_frac == straightforward rust recompute
        let Some(rt) = rt() else { return };
        let f = CloudFilter::new(&rt, 0.5);
        let scene = SceneGen::new(44, Version::V2.spec(), 2, 2).capture();
        let tiles = split_scene(&scene, 64);
        let stats = f.score(&tiles).unwrap();
        for (tile, s) in tiles.iter().zip(&stats) {
            let white = tile
                .pixels
                .chunks_exact(3)
                .filter(|p| p[0].min(p[1]).min(p[2]) > rt.manifest.white_thresh)
                .count() as f32
                / (64.0 * 64.0);
            assert!((white - s.white_frac).abs() < 1e-4, "{white} vs {}", s.white_frac);
        }
    }
}
