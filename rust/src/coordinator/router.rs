//! Confidence-threshold router (Fig 5 decision point).
//!
//! "If confidence threshold in the results is high, the processed results
//! are sent back to the ground directly. However, if confidence threshold
//! is low, the satellite transmits the images to the ground, where the
//! high-precision detection model is used for exact detection."
//!
//! Decision statistic: the maximum detection score on the tile.  Empty
//! tiles (no detections at all) are treated as *confidently empty* when
//! the best objectness anywhere is very low — otherwise offloaded, since
//! a weak model failing to see anything is exactly the uncertain case.

use crate::detect::Detection;

use super::TileFate;

#[derive(Clone, Copy, Debug)]
pub struct RouterPolicy {
    /// Max-score at or above this ⇒ results are final onboard.
    pub confidence_threshold: f32,
    /// Best raw objectness below this on an empty tile ⇒ confidently
    /// empty (no offload, nothing to send).
    pub empty_objectness: f32,
}

impl Default for RouterPolicy {
    fn default() -> RouterPolicy {
        RouterPolicy { confidence_threshold: 0.90, empty_objectness: 0.25 }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct RouterStats {
    pub onboard_final: u64,
    pub offloaded: u64,
    pub confidently_empty: u64,
}

impl RouterStats {
    pub fn total(&self) -> u64 {
        self.onboard_final + self.offloaded
    }

    pub fn offload_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.offloaded as f64 / t as f64
        }
    }

    /// Fold per-scene stats into a scenario total.  Counts are sums, so
    /// merging is exact regardless of the order stage workers finish in.
    pub fn merge(&mut self, other: &RouterStats) {
        self.onboard_final += other.onboard_final;
        self.offloaded += other.offloaded;
        self.confidently_empty += other.confidently_empty;
    }
}

/// Route one tile given its NMS'd onboard detections and the best raw
/// objectness over all grid cells.
pub fn route(
    policy: &RouterPolicy,
    dets: &[Detection],
    best_objectness: f32,
    stats: &mut RouterStats,
) -> TileFate {
    let max_score = dets.iter().map(|d| d.score).fold(f32::MIN, f32::max);
    if dets.is_empty() {
        if best_objectness < policy.empty_objectness {
            stats.onboard_final += 1;
            stats.confidently_empty += 1;
            TileFate::OnboardFinal
        } else {
            stats.offloaded += 1;
            TileFate::Offloaded
        }
    } else if max_score >= policy.confidence_threshold {
        stats.onboard_final += 1;
        TileFate::OnboardFinal
    } else {
        stats.offloaded += 1;
        TileFate::Offloaded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(score: f32) -> Detection {
        Detection { cx: 10.0, cy: 10.0, w: 8.0, h: 8.0, score, class: 0 }
    }

    fn policy() -> RouterPolicy {
        RouterPolicy { confidence_threshold: 0.45, empty_objectness: 0.25 }
    }

    #[test]
    fn confident_detection_stays_onboard() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.9)], 0.9, &mut s), TileFate::OnboardFinal);
        assert_eq!(s.onboard_final, 1);
    }

    #[test]
    fn weak_detection_offloads() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.3)], 0.3, &mut s), TileFate::Offloaded);
        assert_eq!(s.offloaded, 1);
    }

    #[test]
    fn confidently_empty_stays_onboard() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[], 0.05, &mut s), TileFate::OnboardFinal);
        assert_eq!(s.confidently_empty, 1);
    }

    #[test]
    fn uncertain_empty_offloads() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[], 0.4, &mut s), TileFate::Offloaded);
    }

    #[test]
    fn threshold_is_inclusive() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.45)], 0.45, &mut s), TileFate::OnboardFinal);
    }

    #[test]
    fn stats_conserve_tiles() {
        let mut s = RouterStats::default();
        for score in [0.1, 0.5, 0.9, 0.2] {
            route(&policy(), &[det(score)], score, &mut s);
        }
        route(&policy(), &[], 0.01, &mut s);
        assert_eq!(s.total(), 5);
        assert_eq!(s.onboard_final + s.offloaded, 5);
    }

    #[test]
    fn merge_sums_counts() {
        let mut a = RouterStats { onboard_final: 2, offloaded: 1, confidently_empty: 1 };
        let b = RouterStats { onboard_final: 3, offloaded: 4, confidently_empty: 0 };
        a.merge(&b);
        assert_eq!(a.onboard_final, 5);
        assert_eq!(a.offloaded, 5);
        assert_eq!(a.confidently_empty, 1);
        assert_eq!(a.total(), 10);
    }

    #[test]
    fn max_score_drives_decision() {
        let mut s = RouterStats::default();
        // one weak + one strong detection: the strong one wins
        assert_eq!(
            route(&policy(), &[det(0.2), det(0.8)], 0.8, &mut s),
            TileFate::OnboardFinal
        );
    }
}
