//! Confidence-threshold router (Fig 5 decision point).
//!
//! "If confidence threshold in the results is high, the processed results
//! are sent back to the ground directly. However, if confidence threshold
//! is low, the satellite transmits the images to the ground, where the
//! high-precision detection model is used for exact detection."
//!
//! Decision statistic: the maximum detection score on the tile.  Empty
//! tiles (no detections at all) are treated as *confidently empty* when
//! the best objectness anywhere is very low — otherwise offloaded, since
//! a weak model failing to see anything is exactly the uncertain case.
//!
//! Adaptive mode (off by default): the policy consults a [`LinkSnapshot`]
//! — downlink backlog + recent loss rate, both functions of virtual
//! mission time, so decisions stay deterministic — and tightens the
//! offload threshold when the link is stressed (a raw tile queued behind
//! a MakerSat-grade link is a tile that will never arrive) or relaxes it
//! when the link is idle (collaborative accuracy is cheap to harvest).

use crate::detect::Detection;

use super::TileFate;

/// What the router is allowed to observe about the downlink, sampled at
/// the scene's virtual capture time.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkSnapshot {
    /// Bytes queued for downlink (results + images).
    pub backlog_bytes: u64,
    /// Loss rate over recent traffic — the caller samples it over the
    /// packets sent since the previous decision and decays it while the
    /// link is silent, so one bad early pass doesn't latch the tightened
    /// state for the whole mission.
    pub loss_rate: f64,
}

/// Knobs for link-aware threshold adaptation.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveRouting {
    /// Backlog above this ⇒ tighten (offload less).
    pub backlog_high_bytes: u64,
    /// Loss rate above this ⇒ tighten.
    pub loss_high: f64,
    /// Subtracted from the confidence threshold when stressed.
    pub tighten_step: f32,
    /// Added when the link is clearly idle (backlog under a quarter of
    /// the high watermark and loss under half the limit).
    pub relax_step: f32,
}

impl Default for AdaptiveRouting {
    fn default() -> AdaptiveRouting {
        AdaptiveRouting {
            backlog_high_bytes: 5_000_000,
            loss_high: 0.2,
            tighten_step: 0.2,
            relax_step: 0.05,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct RouterPolicy {
    /// Max-score at or above this ⇒ results are final onboard.
    pub confidence_threshold: f32,
    /// Best raw objectness below this on an empty tile ⇒ confidently
    /// empty (no offload, nothing to send).
    pub empty_objectness: f32,
    /// Link-aware threshold adaptation; `None` is the paper's static
    /// policy.
    pub adaptive: Option<AdaptiveRouting>,
}

impl Default for RouterPolicy {
    fn default() -> RouterPolicy {
        RouterPolicy { confidence_threshold: 0.90, empty_objectness: 0.25, adaptive: None }
    }
}

impl RouterPolicy {
    /// Floor for any composed confidence threshold.  Dropping it below
    /// `empty_objectness` would rank a tile with a barely-scored
    /// detection as more trustworthy than a confidently-empty one —
    /// stacked tightening (adaptive stress + governor defer) used to
    /// drive the threshold there silently, inverting the empty-tile
    /// branch of [`route`].  The floor never exceeds the threshold this
    /// policy already carries: composition must not *raise* a threshold
    /// the operator statically configured below the empty bar.
    fn threshold_floor(&self) -> f32 {
        self.empty_objectness.min(self.confidence_threshold).clamp(0.05, 0.999)
    }

    /// The policy actually applied under `snapshot`: identical to `self`
    /// in static mode; with adaptation on, the confidence threshold
    /// tightens under backlog/loss stress and relaxes on an idle link.
    pub fn effective(&self, snapshot: &LinkSnapshot) -> RouterPolicy {
        let Some(ad) = self.adaptive else { return *self };
        let mut threshold = self.confidence_threshold;
        if snapshot.backlog_bytes > ad.backlog_high_bytes || snapshot.loss_rate > ad.loss_high {
            threshold -= ad.tighten_step;
        } else if snapshot.backlog_bytes < ad.backlog_high_bytes / 4
            && snapshot.loss_rate < ad.loss_high / 2.0
        {
            threshold += ad.relax_step;
        }
        RouterPolicy {
            confidence_threshold: threshold.clamp(self.threshold_floor(), 0.999),
            ..*self
        }
    }

    /// The policy at a governed decision point: [`Self::effective`]
    /// under the adaptive snapshot when one was sampled, then
    /// [`Self::tightened`] by the deferring governor's step when one is
    /// in force.  Shared by the thread driver and the fleet machine so
    /// the decision stack cannot drift between them.
    pub fn governed(&self, snapshot: Option<&LinkSnapshot>, tighten: Option<f32>) -> RouterPolicy {
        let mut eff = match snapshot {
            Some(s) => self.effective(s),
            None => *self,
        };
        if let Some(step) = tighten {
            eff = eff.tightened(step);
        }
        eff
    }

    /// This policy with the confidence threshold dropped by `step`
    /// (offload less).  The power governor composes it on top of
    /// [`Self::effective`] while deferring downlink drains: raw tiles
    /// queued behind a transmitter that is off are pure backlog.  The
    /// composition clamps at `empty_objectness` like the adaptive path.
    pub fn tightened(&self, step: f32) -> RouterPolicy {
        RouterPolicy {
            confidence_threshold: (self.confidence_threshold - step)
                .clamp(self.threshold_floor(), 0.999),
            ..*self
        }
    }
}

/// Recent-loss estimator feeding the adaptive router's snapshots: loss
/// rate over the packets sent since the previous decision, not the
/// link's whole lifetime, decayed while the link is silent so one bad
/// early pass doesn't latch the tightened state through a multi-hour
/// contact gap.  Both constellation drivers keep one per satellite.
#[derive(Clone, Copy, Debug, Default)]
pub struct LossTracker {
    prev_sent: u64,
    prev_lost: u64,
    recent_loss: f64,
}

impl LossTracker {
    /// Fold the link's cumulative packet counters at a decision point
    /// and return the loss rate over the window since the last call.
    pub fn update(&mut self, packets_sent: u64, packets_lost: u64) -> f64 {
        let d_sent = packets_sent - self.prev_sent;
        if d_sent > 0 {
            self.recent_loss = (packets_lost - self.prev_lost) as f64 / d_sent as f64;
        } else {
            // no traffic since the last decision: the old estimate goes
            // stale, so decay it instead of latching it
            self.recent_loss *= 0.5;
        }
        self.prev_sent = packets_sent;
        self.prev_lost = packets_lost;
        self.recent_loss
    }
}

/// Re-route a scene's processed tiles under `policy`, replacing the
/// scene's router stats wholesale — the governed re-route both drivers
/// apply at a scene's virtual capture time.
pub fn reroute(
    policy: &RouterPolicy,
    processed: &mut [super::pipeline::ProcessedTile],
) -> RouterStats {
    let mut stats = RouterStats::default();
    for p in processed.iter_mut() {
        p.fate = route(policy, &p.onboard_dets, p.best_objectness, &mut stats);
    }
    stats
}

#[derive(Clone, Copy, Debug, Default)]
pub struct RouterStats {
    pub onboard_final: u64,
    pub offloaded: u64,
    pub confidently_empty: u64,
}

impl RouterStats {
    pub fn total(&self) -> u64 {
        self.onboard_final + self.offloaded
    }

    pub fn offload_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.offloaded as f64 / t as f64
        }
    }

    /// Fold per-scene stats into a scenario total.  Counts are sums, so
    /// merging is exact regardless of the order stage workers finish in.
    pub fn merge(&mut self, other: &RouterStats) {
        self.onboard_final += other.onboard_final;
        self.offloaded += other.offloaded;
        self.confidently_empty += other.confidently_empty;
    }
}

/// Route one tile given its NMS'd onboard detections and the best raw
/// objectness over all grid cells.
pub fn route(
    policy: &RouterPolicy,
    dets: &[Detection],
    best_objectness: f32,
    stats: &mut RouterStats,
) -> TileFate {
    let max_score = dets.iter().map(|d| d.score).fold(f32::MIN, f32::max);
    if dets.is_empty() {
        if best_objectness < policy.empty_objectness {
            stats.onboard_final += 1;
            stats.confidently_empty += 1;
            TileFate::OnboardFinal
        } else {
            stats.offloaded += 1;
            TileFate::Offloaded
        }
    } else if max_score >= policy.confidence_threshold {
        stats.onboard_final += 1;
        TileFate::OnboardFinal
    } else {
        stats.offloaded += 1;
        TileFate::Offloaded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(score: f32) -> Detection {
        Detection { cx: 10.0, cy: 10.0, w: 8.0, h: 8.0, score, class: 0 }
    }

    fn policy() -> RouterPolicy {
        RouterPolicy { confidence_threshold: 0.45, empty_objectness: 0.25, adaptive: None }
    }

    #[test]
    fn confident_detection_stays_onboard() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.9)], 0.9, &mut s), TileFate::OnboardFinal);
        assert_eq!(s.onboard_final, 1);
    }

    #[test]
    fn weak_detection_offloads() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.3)], 0.3, &mut s), TileFate::Offloaded);
        assert_eq!(s.offloaded, 1);
    }

    #[test]
    fn confidently_empty_stays_onboard() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[], 0.05, &mut s), TileFate::OnboardFinal);
        assert_eq!(s.confidently_empty, 1);
    }

    #[test]
    fn uncertain_empty_offloads() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[], 0.4, &mut s), TileFate::Offloaded);
    }

    #[test]
    fn threshold_is_inclusive() {
        let mut s = RouterStats::default();
        assert_eq!(route(&policy(), &[det(0.45)], 0.45, &mut s), TileFate::OnboardFinal);
    }

    #[test]
    fn stats_conserve_tiles() {
        let mut s = RouterStats::default();
        for score in [0.1, 0.5, 0.9, 0.2] {
            route(&policy(), &[det(score)], score, &mut s);
        }
        route(&policy(), &[], 0.01, &mut s);
        assert_eq!(s.total(), 5);
        assert_eq!(s.onboard_final + s.offloaded, 5);
    }

    #[test]
    fn merge_sums_counts() {
        let mut a = RouterStats { onboard_final: 2, offloaded: 1, confidently_empty: 1 };
        let b = RouterStats { onboard_final: 3, offloaded: 4, confidently_empty: 0 };
        a.merge(&b);
        assert_eq!(a.onboard_final, 5);
        assert_eq!(a.offloaded, 5);
        assert_eq!(a.confidently_empty, 1);
        assert_eq!(a.total(), 10);
    }

    #[test]
    fn max_score_drives_decision() {
        let mut s = RouterStats::default();
        // one weak + one strong detection: the strong one wins
        assert_eq!(
            route(&policy(), &[det(0.2), det(0.8)], 0.8, &mut s),
            TileFate::OnboardFinal
        );
    }

    fn adaptive_policy() -> RouterPolicy {
        RouterPolicy {
            confidence_threshold: 0.45,
            empty_objectness: 0.25,
            adaptive: Some(AdaptiveRouting::default()),
        }
    }

    #[test]
    fn static_policy_ignores_snapshot() {
        let p = policy();
        let stressed = LinkSnapshot { backlog_bytes: u64::MAX, loss_rate: 1.0 };
        assert_eq!(p.effective(&stressed).confidence_threshold, p.confidence_threshold);
    }

    #[test]
    fn backlog_tightens_threshold() {
        let p = adaptive_policy();
        let snap = LinkSnapshot { backlog_bytes: 6_000_000, loss_rate: 0.0 };
        let eff = p.effective(&snap);
        assert!((eff.confidence_threshold - 0.25).abs() < 1e-6, "{}", eff.confidence_threshold);
        // a tile the static policy would offload now stays onboard
        let mut s = RouterStats::default();
        assert_eq!(route(&eff, &[det(0.3)], 0.3, &mut s), TileFate::OnboardFinal);
    }

    #[test]
    fn loss_tightens_threshold() {
        let p = adaptive_policy();
        let snap = LinkSnapshot { backlog_bytes: 0, loss_rate: 0.5 };
        assert!((p.effective(&snap).confidence_threshold - 0.25).abs() < 1e-6);
    }

    #[test]
    fn idle_link_relaxes_threshold() {
        let p = adaptive_policy();
        let snap = LinkSnapshot { backlog_bytes: 0, loss_rate: 0.0 };
        assert!((p.effective(&snap).confidence_threshold - 0.5).abs() < 1e-6);
    }

    #[test]
    fn mid_band_leaves_threshold_alone() {
        let p = adaptive_policy();
        // backlog between the relax and tighten watermarks
        let snap = LinkSnapshot { backlog_bytes: 2_000_000, loss_rate: 0.05 };
        assert_eq!(p.effective(&snap).confidence_threshold, 0.45);
    }

    #[test]
    fn tightened_composes_with_effective() {
        // the governor tightens whatever the adaptive path produced
        let p = adaptive_policy();
        let idle = LinkSnapshot { backlog_bytes: 0, loss_rate: 0.0 };
        let eff = p.effective(&idle); // relaxed to 0.5
        let gov = eff.tightened(0.2);
        assert!((gov.confidence_threshold - 0.3).abs() < 1e-6, "{}", gov.confidence_threshold);
        // and clamps like the adaptive path does — at the empty bar
        assert_eq!(policy().tightened(5.0).confidence_threshold, 0.25);
    }

    #[test]
    fn effective_threshold_clamped_at_empty_objectness() {
        // a base threshold configured *below* the empty bar is the
        // operator's static choice: tightening clamps at that base (it
        // can go no lower), never rises to the bar
        let mut p = adaptive_policy();
        p.confidence_threshold = 0.1;
        let stressed = LinkSnapshot { backlog_bytes: u64::MAX, loss_rate: 1.0 };
        assert!((p.effective(&stressed).confidence_threshold - 0.1).abs() < 1e-6);
        // and an idle link still relaxes it untouched by the bar
        let idle = LinkSnapshot { backlog_bytes: 0, loss_rate: 0.0 };
        assert!((p.effective(&idle).confidence_threshold - 0.15).abs() < 1e-6);
        // a policy with no empty bar keeps the absolute 0.05 floor
        p.empty_objectness = 0.0;
        assert!((p.effective(&stressed).confidence_threshold - 0.05).abs() < 1e-6);
    }

    #[test]
    fn loss_tracker_windows_and_decays() {
        let mut lt = LossTracker::default();
        assert_eq!(lt.update(100, 10), 0.1);
        // next window: 100 more packets, none lost — the rate is the
        // window's, not the lifetime's
        assert_eq!(lt.update(200, 10), 0.0);
        lt.update(300, 60); // 50 lost of 100 sent
        assert_eq!(lt.update(300, 60), 0.25, "silent link decays the estimate");
        assert_eq!(lt.update(300, 60), 0.125);
    }

    #[test]
    fn governed_composes_snapshot_then_step() {
        let p = adaptive_policy();
        let idle = LinkSnapshot { backlog_bytes: 0, loss_rate: 0.0 };
        // snapshot relaxes 0.45 → 0.5, governor tightens to 0.3
        let g = p.governed(Some(&idle), Some(0.2));
        assert!((g.confidence_threshold - 0.3).abs() < 1e-6, "{}", g.confidence_threshold);
        // no snapshot: static base, tightened only
        let g = policy().governed(None, Some(0.1));
        assert!((g.confidence_threshold - 0.35).abs() < 1e-6);
        // neither adaptation nor governor: identity
        assert_eq!(p.governed(None, None).confidence_threshold, p.confidence_threshold);
    }

    #[test]
    fn governor_on_stressed_adaptive_cannot_invert_empty_branch() {
        // regression: governor defer (tightened) stacked on an adaptive
        // policy already tightened by a stressed link used to push the
        // threshold to 0.05 < empty_objectness, inverting the
        // confidently-empty ordering
        let p = adaptive_policy();
        let stressed = LinkSnapshot { backlog_bytes: u64::MAX, loss_rate: 1.0 };
        let eff = p.effective(&stressed); // 0.45 - 0.2 = 0.25
        let gov = eff.tightened(0.2); // would be 0.05 unclamped
        assert!(
            gov.confidence_threshold >= gov.empty_objectness,
            "threshold {} below empty bar {}",
            gov.confidence_threshold,
            gov.empty_objectness
        );
        assert!((gov.confidence_threshold - 0.25).abs() < 1e-6);
        // the empty-tile ordering survives the whole stack: an empty
        // tile below the bar stays onboard, and no detection weaker than
        // the bar can count as confident
        let mut s = RouterStats::default();
        assert_eq!(route(&gov, &[], 0.2, &mut s), TileFate::OnboardFinal);
        assert_eq!(s.confidently_empty, 1);
        assert_eq!(route(&gov, &[det(0.2)], 0.2, &mut s), TileFate::Offloaded);
    }
}
