//! Event-driven mega-constellation engine: the scalable sibling of
//! [`super::constellation::run_constellation`].
//!
//! The thread-per-satellite runner spawns a capture thread plus onboard
//! stage workers for every satellite, so its fleet size is bounded by
//! thread count.  Here each satellite is a [`FleetSat`] — a virtual-time
//! [`SatMachine`](crate::sim::SatMachine) owning the satellite's entire
//! world (scene RNG stream, [`Timeline`] cursor, [`DownlinkQueue`],
//! link, [`PowerState`], [`FedScheduler`], fold accumulator) — and the
//! whole fleet is stepped by [`crate::sim::run_sharded`]: `fleet.shards`
//! worker threads, each draining a binary heap of `(virtual_time,
//! sat_id, event_kind)` keys.  Thread count equals shard count, never
//! satellite count, and `fleet.max_events_in_flight` bounds how many
//! satellites a shard materializes at once.
//!
//! # Parity with the thread driver
//!
//! [`run_fleet`] reproduces `run_constellation`'s report for the same
//! config (`tests/fleet_parity.rs`): each event handler is the
//! corresponding slice of the thread driver's loop, executed at the
//! same virtual time with the same per-satellite state.  Two deliberate
//! mechanical differences, neither observable in the report:
//!
//! * **Synchronous ground segment.**  The driver dispatches delivered
//!   imagery to a ground thread and folds replies when they land; here
//!   the machine calls the shared ground [`Pipeline`] inline, one
//!   `infer` per drain slice with tiles in delivered order — the same
//!   batch composition, so ground detections are bit-identical.  Calls
//!   from different shards serialize on the runtime's per-model
//!   execution lock (exactly one ground GPU), and each call is a pure
//!   function of its batch, so cross-shard interleaving is
//!   unobservable.  Everything order-sensitive — report ordering,
//!   fleet FedAvg, fleet gauges — happens after the shards join.
//! * **Shed captures skip onboard inference.**  The thread driver's
//!   stage workers run ahead of the governor, so a shed scene has
//!   already paid its (discarded) onboard inference in wallclock.  The
//!   fleet machine knows the verdict before the stage runs and skips
//!   it; the capture RNG still advances (stream parity) and a shed
//!   scene folds nothing, so only wallclock and stage telemetry differ.
//!
//! Federated aggregation stays a round-barrier operation: satellites
//! record per-round participation during their missions, and FedAvg
//! replays the recorded sets once after the join, in `sat_id` order —
//! shard count cannot reorder it.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::registry::Registry as NodeRegistry;
use crate::cluster::{NodeId, NodeRole};
use crate::config::Config;
use crate::data::{SceneGen, Tile, Version};
use crate::detect::Detection;
use crate::link::{Link, LinkConfig};
use crate::orbit::StationNetwork;
use crate::power::{PowerState, PowerVerdict};
use crate::runtime::{Model, Runtime};
use crate::sedna::federated::{self, FedScheduler};
use crate::sedna::{GlobalManager, LocalController, TaskKind, TaskPhase, TaskSpec};
use crate::sim::{
    apply_seu, run_sharded, scene_timing, ChaosStats, ContactSlice, DutyCycles, EventKind,
    FaultPlan, MachineStep, SatMachine, Timeline, ADMISSION_WAIT_BUCKETS,
    ADMISSION_WAIT_FIRST_BOUND_S,
};
use crate::telemetry::trace::{SatTracer, SpanKind, TracePayload, TraceSink};
use crate::telemetry::{per_node_gauges_enabled, Counter, Gauge, Histogram, Registry};

use super::constellation::{
    apply_fed_rounds, chaos_gated_drain, fleet_fed_report, fold_ready, poll_fed_gated,
    set_fleet_power_gauges, ConstellationReport, PendingScene, SatelliteReport, TAG_STRIDE,
};
use super::downlink::{Delivered, DownlinkItem, DownlinkQueue, ItemKind};
use super::engine::{trace_onboard, OnboardStage, SceneJob, Stage};
use super::layout::{mission_timeline, plane_satellite, station_network};
use super::pipeline::{Pipeline, ScenarioAccumulator, RESULT_HEADER_BYTES};
use super::router::{reroute, LinkSnapshot, LossTracker};
use super::TileFate;

/// Everything the fleet's machines share: the ground segment, control
/// plane, telemetry, and the immutable run parameters.  All fields are
/// `Sync`; per-satellite mutable state lives in the machines.
struct FleetShared<'a, 'rt> {
    rt: &'rt Runtime,
    cfg: &'a Config,
    version: Version,
    scenes: usize,
    horizon: f64,
    net: StationNetwork,
    /// Shared ground HeavyDet segment — one pipeline, called inline
    /// from shard workers, serialized by the runtime's per-model lock.
    ground_pipe: Pipeline<'rt>,
    registry: Mutex<NodeRegistry>,
    gm: Mutex<GlobalManager>,
    task: &'a str,
    metrics: &'a Registry,
    /// Flight recorder — `None` when `trace.enabled` is off, which is
    /// the one branch every instrumentation site pays.
    trace: Option<Arc<TraceSink>>,
    /// Exact per-satellite `.<node>` gauges at or below
    /// `telemetry.per_node_limit`; past the cutoff the per-sat handles
    /// are detached sinks and only fixed-size digests are recorded.
    per_node: bool,
    fed_train_s: f64,
    produced: Arc<Counter>,
    delivered_items: Arc<Counter>,
    served: Arc<Counter>,
    ground_svc: Arc<Histogram>,
    onboard_items: Arc<Counter>,
    onboard_svc: Arc<Histogram>,
}

/// Mission-tail bookkeeping, created when the last scene has been
/// driven: the unconsumed contact slices plus the power-integration
/// cursor the thread driver keeps in its tail loop.
struct TailState {
    start: f64,
    comm_before: f64,
    power_cursor: f64,
    power_step: f64,
    slices: VecDeque<ContactSlice>,
}

/// One satellite as a virtual-time state machine.  Field-for-field this
/// is the local state of `run_satellite`; the event handlers are that
/// function's loop bodies, re-cut along event boundaries.
struct FleetSat<'a, 'rt> {
    sh: &'a FleetShared<'a, 'rt>,
    index: usize,
    node: NodeId,
    lc: LocalController,
    timeline: Timeline,
    pipeline: Pipeline<'rt>,
    gen: SceneGen,
    acc: ScenarioAccumulator,
    queue: DownlinkQueue,
    link: Link,
    power: Option<PowerState>,
    power_metrics: Option<(Arc<Gauge>, Arc<Counter>, Arc<Counter>)>,
    fed: Option<FedScheduler>,
    fed_metrics: Option<(Arc<Counter>, Arc<Counter>)>,
    /// Seeded fault plan (`None` when `chaos.enabled` is off) plus the
    /// per-satellite fault ledger it fills.  The plan is a pure
    /// function of (chaos.seed, sat index, horizon, scenes), so it is
    /// identical to the thread driver's whatever the shard count.
    chaos_plan: Option<FaultPlan>,
    chaos_stats: ChaosStats,
    pending: BTreeMap<usize, PendingScene>,
    shed_idx: BTreeSet<usize>,
    next_fold: usize,
    next_drive: usize,
    loss: LossTracker,
    /// Per-satellite flight-recorder handle (rings live in the shared
    /// sink, one per shard); `None` when tracing is off.
    tracer: Option<SatTracer>,
    frag: usize,
    tail: Option<TailState>,
    first: (f64, EventKind),
}

impl<'a, 'rt> FleetSat<'a, 'rt> {
    fn new(sh: &'a FleetShared<'a, 'rt>, index: usize, node: NodeId) -> Result<FleetSat<'a, 'rt>> {
        let cfg = sh.cfg;
        let mut lc = LocalController::new(node.clone());
        lc.start(sh.task);
        sh.gm.lock().unwrap().report(sh.task, &node, TaskPhase::Running)?;

        // one orbital plane per satellite, phased around the
        // constellation — the same `coordinator::layout` helpers as the
        // thread driver, so the engines cannot drift apart
        let sat = plane_satellite(cfg, index, &node.to_string());
        let timeline = mission_timeline(cfg, &sat, &sh.net);

        let mut sat_cfg = cfg.clone();
        sat_cfg.seed = cfg.seed.wrapping_add(1 + index as u64 * 101);
        let pipeline = Pipeline::new(sh.rt, sat_cfg);
        let gen = pipeline.scene_gen(sh.version);
        let acc = ScenarioAccumulator::new(&pipeline.cfg, sh.rt.manifest.classes);
        let link = Link::new(LinkConfig::downlink(pipeline.cfg.loss()), pipeline.cfg.seed);
        let power = cfg.power.enabled.then(|| PowerState::new(&cfg.power, &cfg.energy));
        // past the per-node cutoff the suffixed handles become detached
        // sinks (unregistered, dropped with the machine): call sites
        // stay branch-free and gauge cardinality stays fixed — fleet
        // aggregates come from the barrier digests instead
        let power_metrics = power.as_ref().map(|_| {
            (
                if sh.per_node {
                    sh.metrics.gauge(&format!("power.soc_pct.{node}"))
                } else {
                    Arc::new(Gauge::default())
                },
                sh.metrics.counter("power.scenes_deferred"),
                sh.metrics.counter("power.scenes_shed"),
            )
        });
        let fed = cfg.federated.enabled.then(|| FedScheduler::new(&cfg.federated, sh.horizon));
        let fed_metrics = fed.as_ref().map(|_| {
            if sh.per_node {
                (
                    sh.metrics.counter(&format!("federated.rounds.{node}")),
                    sh.metrics.counter(&format!("federated.skipped_power.{node}")),
                )
            } else {
                (Arc::new(Counter::default()), Arc::new(Counter::default()))
            }
        });
        let chaos_plan =
            cfg.chaos.enabled.then(|| FaultPlan::compile(&cfg.chaos, index, sh.horizon, sh.scenes));
        // ring index: `tracer` reduces it modulo the sink's shard count,
        // which run_fleet sized to the scheduler's effective shard
        // count, so each satellite records into the ring owned by the
        // shard that steps it (`sat_id % shards`) — single-writer rings.
        let tracer = sh.trace.as_ref().map(|t| t.tracer(index, index));
        let frag = pipeline.cfg.fragment_px;
        let mut m = FleetSat {
            sh,
            index,
            node,
            lc,
            timeline,
            pipeline,
            gen,
            acc,
            queue: DownlinkQueue::new(),
            link,
            power,
            power_metrics,
            fed,
            fed_metrics,
            chaos_plan,
            chaos_stats: ChaosStats::default(),
            pending: BTreeMap::new(),
            shed_idx: BTreeSet::new(),
            next_fold: 0,
            next_drive: 0,
            loss: LossTracker::default(),
            tracer,
            frag,
            tail: None,
            first: (0.0, EventKind::Capture),
        };
        m.first = if sh.scenes > 0 {
            (m.timeline.now_s(), EventKind::Capture)
        } else {
            m.enter_tail();
            m.next_tail_key()
        };
        Ok(m)
    }

    /// One synchronous ground round-trip for a drain's delivered items —
    /// the machine-world `dispatch_ground` + `apply_ground_reply`.  One
    /// `infer` per drain slice, tiles in delivered order: the same batch
    /// composition as the async dispatch, so ground detections are
    /// bit-identical to the thread driver's.  `t` is the drain slice's
    /// virtual end time, where the ground-inference trace event lands.
    fn ground_round_trip(&mut self, delivered: Vec<Delivered>, t: f64) -> Result<()> {
        self.sh.delivered_items.add(delivered.len() as u64);
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        let mut tiles: Vec<Tile> = Vec::new();
        for d in &delivered {
            if d.item.kind != ItemKind::Image {
                continue;
            }
            let sidx = (d.item.tag / TAG_STRIDE) as usize;
            let tidx = (d.item.tag % TAG_STRIDE) as usize;
            let scene = self
                .pending
                .get(&sidx)
                .ok_or_else(|| anyhow!("delivered tile for unknown scene {sidx}"))?;
            tiles.push(scene.processed[tidx].tile.clone());
            pairs.push((sidx, tidx));
        }
        if tiles.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let (dets, _, wall) = self.sh.ground_pipe.infer(Model::Heavy, &tiles)?;
        self.sh.ground_svc.observe_secs(t0.elapsed().as_secs_f64());
        self.sh.served.add(tiles.len() as u64);
        if let Some(tr) = &self.tracer {
            tr.event(SpanKind::GroundInfer, t, TracePayload::Batch(tiles.len()));
        }
        let wall_each = wall / pairs.len().max(1) as f64;
        for (&(sidx, tidx), d) in pairs.iter().zip(dets) {
            let scene = self.pending.get_mut(&sidx).expect("scene vanished mid-delivery");
            scene.processed[tidx].ground_dets = Some(d);
            scene.outstanding -= 1;
            scene.wall += wall_each;
        }
        Ok(())
    }

    /// Poll the federated scheduler at virtual time `t` and apply the
    /// decisions — the `fed.poll` + `apply_fed_rounds` pair the thread
    /// driver inlines at every decision point.
    fn fed_poll(&mut self, t: f64) {
        if let Some(f) = self.fed.as_mut() {
            let decisions = poll_fed_gated(
                f,
                self.chaos_plan.as_ref(),
                t,
                self.power.as_ref().map(|p| p.soc_frac()),
            );
            let wire = f.wire_bytes();
            apply_fed_rounds(
                decisions,
                wire,
                self.sh.fed_train_s,
                &mut self.queue,
                &mut self.power,
                &mut self.acc,
                &self.fed_metrics,
                self.tracer.as_ref(),
            );
        }
    }

    /// Scene-capture event: capture + onboard + one iteration of the
    /// thread driver's scene loop (shed path or normal path), then
    /// either the next capture or the mission tail.
    fn on_capture(&mut self) -> Result<MachineStep> {
        let idx = self.next_drive;
        let mut scene = self.gen.capture();
        // chaos: SEU strikes hit the freshly captured buffer,
        // pre-filter — the same plan slots the thread driver's capture
        // thread applies, so the pixels are bit-identical
        if let Some(c) = self.chaos_plan.as_ref() {
            if let Some(seed) = c.seu_for_scene(idx) {
                apply_seu(&mut scene.pixels, seed, c.seu_flips());
            }
        }
        self.sh.produced.inc();
        // chaos: dark at this capture instant — the scene is lost
        // outright, checked before the power verdict (a dead bus
        // outranks a low battery).  Like the shed path, the capture RNG
        // advanced (stream parity) and the onboard stage is skipped.
        if self
            .chaos_plan
            .as_ref()
            .map(|c| c.crashed_at(self.timeline.now_s()))
            .unwrap_or(false)
        {
            let t_crash = self.timeline.now_s();
            if let Some(tr) = &self.tracer {
                tr.event(SpanKind::FaultCrash, t_crash, TracePayload::None);
            }
            self.chaos_stats.lost_to_crash += 1;
            drop(scene);
            let (_, period) = scene_timing(self.timeline.timing(), 0);
            let t = self.timeline.advance(period);
            let blacked = self.timeline.due_contacts(t).len() as u64;
            self.chaos_stats.slices_blacked_out += blacked;
            self.chaos_stats.heartbeats_suppressed += blacked;
            let duties = DutyCycles::default();
            self.acc.extend_mission(period, duties);
            if let Some(p) = self.power.as_mut() {
                p.advance_period(period, duties, self.timeline.sunlit_s(t_crash, t));
                if let Some((soc, _, _)) = &self.power_metrics {
                    soc.set(p.soc_pct());
                }
            }
            self.fed_poll(t);
            self.shed_idx.insert(idx);
            self.next_drive += 1;
            fold_ready(&mut self.pending, &mut self.shed_idx, &mut self.next_fold, &mut self.acc, false);
            return self.after_scene();
        }
        let verdict = self.power.as_ref().map(|p| p.verdict()).unwrap_or(PowerVerdict::Nominal);
        // governed verdicts are flight-recorder events, stamped with the
        // SoC the governor read at this capture's virtual time
        if let (Some(tr), Some(kind)) = (&self.tracer, verdict.trace_kind()) {
            let soc = self.power.as_ref().expect("governed verdict implies power state").soc_pct();
            tr.event(kind, self.timeline.now_s(), TracePayload::Soc(soc));
        }
        if verdict == PowerVerdict::Shed {
            // capture RNG advanced (stream parity with the thread
            // driver), but the shed scene's onboard inference is
            // skipped: the driver had already paid it on its
            // run-ahead stage workers, here the verdict precedes the
            // stage.  A shed scene folds nothing, so only wallclock
            // and stage telemetry differ.
            drop(scene);
            let (_, period) = scene_timing(self.timeline.timing(), 0);
            let t_start = self.timeline.now_s();
            let t = self.timeline.advance(period);
            let _ = self.timeline.due_contacts(t);
            let duties = DutyCycles::default();
            self.acc.extend_mission(period, duties);
            let p = self.power.as_mut().expect("shed verdict implies power state");
            p.advance_period(period, duties, self.timeline.sunlit_s(t_start, t));
            p.stats.scenes_shed += 1;
            if let Some((soc, _, shed)) = &self.power_metrics {
                shed.inc();
                soc.set(p.soc_pct());
            }
            self.fed_poll(t);
            self.shed_idx.insert(idx);
            self.next_drive += 1;
            fold_ready(&mut self.pending, &mut self.shed_idx, &mut self.next_fold, &mut self.acc, false);
            return self.after_scene();
        }
        let deferring = verdict == PowerVerdict::Defer;

        let t0 = Instant::now();
        let mut stage = OnboardStage { p: &self.pipeline, frag: self.frag };
        let mut d = stage.process(SceneJob { idx, scene })?;
        self.sh.onboard_svc.observe_secs(t0.elapsed().as_secs_f64());
        self.sh.onboard_items.inc();

        // link-aware adaptive routing at this scene's virtual capture
        // time — the governed re-route shared with the thread driver
        if self.pipeline.policy.adaptive.is_some() || deferring {
            let snap = self.pipeline.policy.adaptive.is_some().then(|| LinkSnapshot {
                backlog_bytes: self.queue.pending_bytes(),
                loss_rate: self
                    .loss
                    .update(self.link.stats.packets_sent, self.link.stats.packets_lost),
            });
            let step = deferring.then(|| {
                self.power
                    .as_ref()
                    .expect("defer verdict implies power state")
                    .governor()
                    .defer_tighten
            });
            let eff = self.pipeline.policy.governed(snap.as_ref(), step);
            d.router = reroute(&eff, &mut d.processed);
        }

        let (busy, period) = scene_timing(self.timeline.timing(), d.processed.len());
        let t_capture = self.timeline.now_s();
        // chaos: record the SEU that struck this scene's buffer — the
        // same (stat, trace) pair the thread driver emits here
        if let Some(c) = self.chaos_plan.as_ref() {
            if c.seu_for_scene(idx).is_some() {
                self.chaos_stats.seu_scenes += 1;
                if let Some(tr) = &self.tracer {
                    tr.event(
                        SpanKind::FaultSeu,
                        t_capture,
                        TracePayload::Batch(c.seu_flips() as usize),
                    );
                }
            }
        }
        if let Some(tr) = &self.tracer {
            trace_onboard(tr, &d, t_capture, self.timeline.timing().capture_overhead_s, busy);
        }
        let ready = t_capture + busy;
        let mut outstanding = 0usize;
        for (tidx, p) in d.processed.iter().enumerate() {
            let tag = idx as u64 * TAG_STRIDE + tidx as u64;
            match p.fate {
                TileFate::OnboardFinal => self.queue.push(DownlinkItem {
                    kind: ItemKind::Results,
                    bytes: RESULT_HEADER_BYTES
                        + Detection::WIRE_BYTES * p.onboard_dets.len() as u64,
                    ready_at: ready,
                    tag,
                }),
                TileFate::Offloaded => {
                    outstanding += 1;
                    self.queue.push(DownlinkItem {
                        kind: ItemKind::Image,
                        bytes: p.tile.raw_bytes(),
                        ready_at: ready,
                        tag,
                    });
                }
                TileFate::Filtered => unreachable!("filtered tiles are not processed"),
            }
        }
        self.pending.insert(
            idx,
            PendingScene {
                bentpipe_bytes: d.bentpipe_bytes,
                n_scene_tiles: d.n_scene_tiles,
                processed: d.processed,
                n_filtered: d.n_filtered,
                wall: d.wall,
                router: d.router,
                duties: DutyCycles::default(),
                outstanding,
            },
        );

        // advance one scene period, then spend the elapsed contact time;
        // a deferring governor keeps the transmitter off
        let comm_before = self.link.stats.busy_s;
        let t = self.timeline.advance(period);
        if deferring {
            let _ = self.timeline.due_contacts(t);
        } else {
            for slice in self.timeline.due_contacts(t) {
                let at_ms = (slice.window.aos * 1000.0) as u64;
                let got = chaos_gated_drain(
                    &mut self.chaos_plan,
                    &mut self.chaos_stats,
                    &mut self.queue,
                    &mut self.link,
                    &slice.window,
                    slice.closes_pass,
                    self.tracer.as_ref(),
                    || {
                        self.sh.registry.lock().unwrap().heartbeat(&self.node, at_ms);
                    },
                );
                let Some(got) = got else { continue }; // blacked out
                self.ground_round_trip(got, slice.window.los)?;
            }
        }
        let comm_busy = self.link.stats.busy_s - comm_before;
        let duties = self.timeline.observed_duties(
            busy,
            period,
            comm_busy,
            self.timeline.timing().capture_overhead_s,
        );
        self.pending.get_mut(&idx).expect("scene just inserted").duties = duties;
        if let Some(p) = self.power.as_mut() {
            p.advance_period(period, duties, self.timeline.sunlit_s(t_capture, t));
            if deferring {
                p.stats.scenes_deferred += 1;
            }
            if let Some((soc, deferred, _)) = &self.power_metrics {
                if deferring {
                    deferred.inc();
                }
                soc.set(p.soc_pct());
            }
        }
        self.fed_poll(t);
        self.next_drive += 1;
        fold_ready(&mut self.pending, &mut self.shed_idx, &mut self.next_fold, &mut self.acc, false);
        self.after_scene()
    }

    fn after_scene(&mut self) -> Result<MachineStep> {
        if self.next_drive < self.sh.scenes {
            Ok(MachineStep::Yield(self.timeline.now_s(), EventKind::Capture))
        } else {
            self.enter_tail();
            let (t, kind) = self.next_tail_key();
            Ok(MachineStep::Yield(t, kind))
        }
    }

    /// Materialize the mission tail: every still-unconsumed contact
    /// slice (the thread driver's `remaining_contacts()` loop), plus the
    /// power cursor that integrates the idle time between them.
    fn enter_tail(&mut self) {
        let start = self.timeline.now_s();
        let slices: VecDeque<ContactSlice> = self.timeline.remaining_contacts().into();
        self.tail = Some(TailState {
            start,
            comm_before: self.link.stats.busy_s,
            power_cursor: start,
            power_step: self.timeline.timing().scene_period_floor_s.max(1.0),
            slices,
        });
    }

    /// Next tail event: the next contact slice at its AOS, then any
    /// post-pass federated round at its due time, then mission end at
    /// the horizon.
    fn next_tail_key(&self) -> (f64, EventKind) {
        let tail = self.tail.as_ref().expect("tail state");
        if let Some(s) = tail.slices.front() {
            (s.window.aos, EventKind::ContactSlice)
        } else if let Some(due) = self.fed.as_ref().and_then(|f| f.due_next()) {
            (due, EventKind::RoundBoundary)
        } else {
            (self.sh.horizon, EventKind::MissionEnd)
        }
    }

    /// One tail contact slice — the body of the thread driver's
    /// `remaining_contacts()` loop for a single slice.
    fn on_contact_slice(&mut self) -> Result<MachineStep> {
        let mut tail = self.tail.take().expect("tail state");
        let slice = tail.slices.pop_front().expect("slice event without a slice");
        // federated rounds due by the end of this pass fire first so
        // their weights can ride it; power integrates idle time to each
        // round boundary, clamped at AOS
        if let Some(f) = self.fed.as_mut() {
            while let Some(due) = f.due_next().filter(|d| *d <= slice.window.los) {
                if let Some(p) = self.power.as_mut() {
                    let target = due.min(slice.window.aos);
                    p.advance_chunked(
                        &self.timeline,
                        tail.power_cursor,
                        target,
                        DutyCycles::default(),
                        tail.power_step,
                    );
                    tail.power_cursor = tail.power_cursor.max(target);
                }
                let decisions = poll_fed_gated(
                    f,
                    self.chaos_plan.as_ref(),
                    due,
                    self.power.as_ref().map(|p| p.soc_frac()),
                );
                let wire = f.wire_bytes();
                apply_fed_rounds(
                    decisions,
                    wire,
                    self.sh.fed_train_s,
                    &mut self.queue,
                    &mut self.power,
                    &mut self.acc,
                    &self.fed_metrics,
                    self.tracer.as_ref(),
                );
            }
        }
        if let Some(p) = self.power.as_mut() {
            // idle mission time up to this pass, so the verdict
            // reflects SoC at AOS
            let aos = slice.window.aos;
            p.advance_chunked(
                &self.timeline,
                tail.power_cursor,
                aos,
                DutyCycles::default(),
                tail.power_step,
            );
            tail.power_cursor = aos;
            if p.verdict() == PowerVerdict::Shed {
                // transmitter stays off through this pass; the AOS→LOS
                // stretch is integrated by the next event's idle
                // advance from `power_cursor`, exactly like the thread
                // driver's `continue`
                if let Some(tr) = &self.tracer {
                    tr.event(SpanKind::Shed, aos, TracePayload::Soc(p.soc_pct()));
                }
                self.tail = Some(tail);
                let (t, kind) = self.next_tail_key();
                return Ok(MachineStep::Yield(t, kind));
            }
        }
        let at_ms = (slice.window.aos * 1000.0) as u64;
        let busy_before = self.link.stats.busy_s;
        let got = chaos_gated_drain(
            &mut self.chaos_plan,
            &mut self.chaos_stats,
            &mut self.queue,
            &mut self.link,
            &slice.window,
            slice.closes_pass,
            self.tracer.as_ref(),
            || {
                self.sh.registry.lock().unwrap().heartbeat(&self.node, at_ms);
            },
        );
        let Some(got) = got else {
            // blacked out: the pass never happens; AOS→LOS integrates
            // as idle from `power_cursor`, exactly like the thread
            // driver's `continue` past a blacked-out tail slice
            self.tail = Some(tail);
            let (t, kind) = self.next_tail_key();
            return Ok(MachineStep::Yield(t, kind));
        };
        self.tail = Some(tail);
        self.ground_round_trip(got, slice.window.los)?;
        let mut tail = self.tail.take().expect("tail state");
        if let Some(p) = self.power.as_mut() {
            let comm = self.link.stats.busy_s - busy_before;
            let duties = self.timeline.observed_duties(0.0, slice.window.duration_s(), comm, 0.0);
            let (aos, los) = (slice.window.aos, slice.window.los);
            p.advance_chunked(&self.timeline, aos, los, duties, tail.power_step);
            tail.power_cursor = los;
        }
        self.tail = Some(tail);
        let (t, kind) = self.next_tail_key();
        Ok(MachineStep::Yield(t, kind))
    }

    /// One federated round due after the last pass — the thread
    /// driver's post-pass `while let Some(due) = f.due_next()` loop,
    /// one iteration per event.
    fn on_round_boundary(&mut self) -> Result<MachineStep> {
        let tail = self.tail.as_mut().expect("tail state");
        let f = self.fed.as_mut().expect("round event without a scheduler");
        let due = f.due_next().expect("round event without a due round");
        if let Some(p) = self.power.as_mut() {
            p.advance_chunked(
                &self.timeline,
                tail.power_cursor,
                due,
                DutyCycles::default(),
                tail.power_step,
            );
            tail.power_cursor = tail.power_cursor.max(due);
        }
        let decisions = poll_fed_gated(
            f,
            self.chaos_plan.as_ref(),
            due,
            self.power.as_ref().map(|p| p.soc_frac()),
        );
        let wire = f.wire_bytes();
        apply_fed_rounds(
            decisions,
            wire,
            self.sh.fed_train_s,
            &mut self.queue,
            &mut self.power,
            &mut self.acc,
            &self.fed_metrics,
            self.tracer.as_ref(),
        );
        let (t, kind) = self.next_tail_key();
        Ok(MachineStep::Yield(t, kind))
    }

    /// Mission horizon: force-fold the remaining scenes (undelivered
    /// offloads are evaluated with their onboard detections), account
    /// the tail's energy, and integrate power to the horizon.
    fn on_mission_end(&mut self) -> Result<MachineStep> {
        // ground replies already folded in at their drain points (the
        // synchronous segment has no in-flight completions to await)
        fold_ready(&mut self.pending, &mut self.shed_idx, &mut self.next_fold, &mut self.acc, true);
        let tail = self.tail.as_ref().expect("mission end before tail");
        let tail_dt = self.sh.horizon - tail.start;
        if tail_dt > 0.0 {
            let tail_comm = self.link.stats.busy_s - tail.comm_before;
            self.acc
                .extend_mission(tail_dt, self.timeline.observed_duties(0.0, tail_dt, tail_comm, 0.0));
        }
        if let Some(p) = self.power.as_mut() {
            p.advance_chunked(
                &self.timeline,
                tail.power_cursor,
                self.sh.horizon,
                DutyCycles::default(),
                tail.power_step,
            );
            if let Some((soc, _, _)) = &self.power_metrics {
                soc.set(p.soc_pct());
            }
        }
        Ok(MachineStep::Done)
    }

    /// Consume the machine into its report — the thread driver's
    /// post-scope accounting, verbatim.
    fn into_report(mut self) -> Result<SatelliteReport> {
        let scenes = self.sh.scenes;
        // plan-level totals land once the mission is over, same as the
        // thread driver's post-scope accounting
        if let Some(c) = &self.chaos_plan {
            self.chaos_stats.crashes = c.crash_windows().len() as u64;
            self.chaos_stats.dropouts = c.dropout_windows().len() as u64;
        }
        let shed = self.power.as_ref().map(|p| p.stats.scenes_shed as usize).unwrap_or(0);
        let lost = self.chaos_stats.lost_to_crash as usize;
        anyhow::ensure!(
            self.acc.scenes() + shed + lost == scenes,
            "satellite {} lost scenes: folded {} + shed {shed} + crashed {lost} of {scenes}",
            self.index,
            self.acc.scenes()
        );
        if let Some(f) = &self.fed {
            anyhow::ensure!(
                f.stats.rounds_completed
                    + f.stats.rounds_skipped_power
                    + f.stats.rounds_skipped_crash
                    == f.stats.rounds_scheduled,
                "satellite {} lost federated rounds: {} + {} + {} of {}",
                self.index,
                f.stats.rounds_completed,
                f.stats.rounds_skipped_power,
                f.stats.rounds_skipped_crash,
                f.stats.rounds_scheduled
            );
        }
        let ps = self.pipeline.tile_pool_stats();
        let hit_pct = (ps.hit_rate() * 100.0).round() as i64;
        if self.sh.per_node {
            let node = &self.node;
            self.sh
                .metrics
                .gauge(&format!("constellation.pool.tile_allocs.{node}"))
                .set(ps.allocs as i64);
            self.sh.metrics.gauge(&format!("constellation.pool.tile_hit_pct.{node}")).set(hit_pct);
            self.sh
                .metrics
                .gauge(&format!("constellation.pool.tile_evictions.{node}"))
                .set(ps.evictions as i64);
        }
        // fixed-size fleet aggregates, observed from shard workers as
        // machines finish — every digest update commutes, so the render
        // is identical whatever order the shards retire satellites in
        self.sh.metrics.digest("constellation.pool.tile_allocs").observe(ps.allocs as i64);
        self.sh.metrics.digest("constellation.pool.tile_hit_pct").observe(hit_pct);
        self.sh.metrics.digest("constellation.pool.tile_evictions").observe(ps.evictions as i64);
        self.lc.finish(self.sh.task, true);
        self.sh.gm.lock().unwrap().report(self.sh.task, &self.node, TaskPhase::Completed)?;
        let power_stats = self.power.map(|p| p.stats);
        let fed_stats = self.fed.map(|f| f.stats);
        let mut result = self.acc.finish(self.sh.version, self.sh.cfg.fragment_px);
        result.power = power_stats;
        result.federated = fed_stats.clone();
        Ok(SatelliteReport {
            index: self.index,
            name: self.node.to_string(),
            result,
            downlink: self.queue.stats.clone(),
            link: self.link.stats,
            windows: self.timeline.n_contacts(),
            contact_s: self.timeline.contact_total_s(),
            sunlit_s: self.timeline.sunlit_s(0.0, self.sh.horizon),
            power: power_stats,
            federated: fed_stats,
            chaos: self.chaos_plan.is_some().then_some(self.chaos_stats),
        })
    }
}

impl SatMachine for FleetSat<'_, '_> {
    type Report = SatelliteReport;

    fn start(&mut self) -> (f64, EventKind) {
        self.first
    }

    fn on_event(&mut self, _time_s: f64, kind: EventKind) -> Result<MachineStep> {
        match kind {
            EventKind::Capture => self.on_capture(),
            EventKind::ContactSlice => self.on_contact_slice(),
            EventKind::RoundBoundary => self.on_round_boundary(),
            EventKind::MissionEnd => self.on_mission_end(),
        }
    }

    fn finish(self) -> Result<SatelliteReport> {
        self.into_report()
    }
}

/// Run the constellation as an event-driven fleet: `fleet.shards`
/// worker threads step every satellite's state machine in virtual time.
/// Produces the same [`ConstellationReport`] as
/// [`super::constellation::run_constellation`] for any config (bit-wise
/// for its deterministic fields), but scales to fleets five orders of
/// magnitude past the thread-per-satellite design — see
/// `benches/perf_fleet.rs` for the 10k/100k regime.
pub fn run_fleet(rt: &Runtime, cfg: &Config, version: Version) -> Result<ConstellationReport> {
    cfg.energy.validate()?;
    cfg.power.validate()?;
    cfg.federated.validate()?;
    cfg.fleet.validate()?;
    cfg.chaos.validate()?;
    cfg.validate_cross()?;
    anyhow::ensure!(!cfg.stations.is_empty(), "stations must list at least one ground station");
    let n_sats = cfg.constellation.satellites.max(1);
    let scenes = cfg.constellation.scenes_per_satellite;
    let metrics = Registry::new();

    // control plane: node registry + Sedna JointInference task,
    // identical to the thread driver's
    let ground_node = NodeId::new("ground-1");
    let sat_nodes: Vec<NodeId> = (0..n_sats).map(|i| NodeId::new(format!("sat-{i}"))).collect();
    let registry = Mutex::new(NodeRegistry::new(60_000, 600_000));
    {
        let mut reg = registry.lock().unwrap();
        reg.register(ground_node.clone(), NodeRole::Cloud, 64_000, 262_144, 0);
        for id in &sat_nodes {
            reg.register(id.clone(), NodeRole::Edge, 4_000, 8_192, 0);
        }
    }
    let gm = Mutex::new(GlobalManager::new());
    let task = "joint-inference";
    {
        let mut workers = sat_nodes.clone();
        workers.push(ground_node.clone());
        gm.lock().unwrap().create(TaskSpec {
            name: task.into(),
            kind: TaskKind::JointInference,
            workers,
            params: BTreeMap::new(),
        })?;
    }

    // flight recorder: one single-writer ring per scheduler shard,
    // merged into a deterministic stream after the join barrier
    let shards_effective = cfg.fleet.shards.max(1).min(n_sats);
    let trace_sink =
        cfg.trace.enabled.then(|| Arc::new(TraceSink::new(shards_effective, cfg.trace.ring_cap)));

    let t0 = Instant::now();
    let shared = FleetShared {
        rt,
        cfg,
        version,
        scenes,
        horizon: cfg.constellation.horizon_s,
        net: station_network(cfg),
        ground_pipe: Pipeline::new(rt, cfg.clone()),
        registry,
        gm,
        task,
        metrics: &metrics,
        trace: trace_sink.clone(),
        per_node: per_node_gauges_enabled(n_sats, cfg.telemetry.per_node_limit),
        fed_train_s: federated::train_seconds(cfg.federated.epochs, cfg.federated.samples_per_node),
        produced: metrics.counter("constellation.capture.items"),
        delivered_items: metrics.counter("constellation.downlink.items_delivered"),
        served: metrics.counter("constellation.ground.tiles"),
        ground_svc: metrics.histogram("constellation.ground.service_s"),
        onboard_items: metrics.counter("constellation.onboard.items"),
        onboard_svc: metrics.histogram("constellation.onboard.service_s"),
    };

    let (reports, fstats) = run_sharded(
        n_sats,
        cfg.fleet.shards,
        cfg.fleet.max_events_in_flight,
        |i| FleetSat::new(&shared, i, sat_nodes[i].clone()),
    )?;

    metrics.gauge("fleet.events_processed").set(fstats.events as i64);
    metrics.gauge("fleet.peak_live_machines").set(fstats.peak_live as i64);
    // scheduler self-observability: per-shard load balance, checkpoint
    // heap depths, and the virtual-time admission-wait distribution
    metrics.gauge("fleet.max_heap_depth").set(fstats.max_heap_depth as i64);
    for (shard, events) in fstats.events_per_shard.iter().enumerate() {
        metrics.gauge(&format!("fleet.shard_events.{shard}")).set(*events as i64);
    }
    metrics
        .histogram_with_range(
            "fleet.admission_wait_s",
            ADMISSION_WAIT_FIRST_BOUND_S,
            ADMISSION_WAIT_BUCKETS,
        )
        .merge(&fstats.admission_wait_hist);
    metrics
        .gauge("constellation.runtime.scratch_allocs")
        .set(rt.scratch_stats().allocs as i64);

    shared.gm.lock().unwrap().report(task, &ground_node, TaskPhase::Completed)?;
    let task_completed =
        shared.gm.lock().unwrap().get(task).map(|(_, st)| st.phase) == Some(TaskPhase::Completed);
    let tiles_total = reports.iter().map(|r| r.result.tiles_total).sum();
    set_fleet_power_gauges(&metrics, &reports);
    let fed_report = fleet_fed_report(cfg, &reports, &metrics);

    Ok(ConstellationReport {
        satellites: reports,
        tiles_total,
        wall_s: t0.elapsed().as_secs_f64(),
        task_completed,
        federated: fed_report,
        telemetry: metrics.render(),
        trace: trace_sink.map(|s| s.merge()),
    })
}
