//! The paper's system contribution: satellite-ground collaborative
//! inference (Fig 5 workflow).
//!
//! Stages, each its own module:
//!
//! 1. capture      — `data::SceneGen` (the camera)
//! 2. split        — `data::split_scene` (onboard image splitting, Fig 6)
//! 3. [`cloudfilter`] — redundancy filter over the CloudScore artifact
//! 4. [`batcher`]  — dynamic batching up to the exported batch size
//! 5. onboard inference — TinyDet via [`crate::runtime`]
//! 6. [`router`]   — confidence-threshold routing: results go straight
//!                   down; low-confidence tiles are queued for image
//!                   downlink and ground re-inference (HeavyDet)
//! 7. [`downlink`] — contact-window-gated transfer over the lossy link
//! 8. evaluation   — mAP of in-orbit vs collaborative + byte accounting
//!
//! Execution paths over those stages:
//!
//! * [`pipeline`] — per-scene stage bodies + the sequential facade
//!   (`run_scenario`) and the shared result fold; unit-testable without
//!   artifacts above the runtime.
//! * [`engine`] — the staged concurrent executor: bounded typed channels
//!   between stage workers so onboard and ground inference overlap
//!   (bit-identical results to the facade).
//! * [`constellation`] — N satellites in parallel sharing one ground
//!   segment, each running the engine's capture/onboard stages
//!   concurrently over its own [`crate::sim::Timeline`] (contact
//!   windows, eclipse phases, derived energy duties), with ground
//!   round-trips as asynchronous completions, cluster/sedna bookkeeping,
//!   and per-stage telemetry.
//! * [`fleet`] — the same constellation as sharded virtual-time state
//!   machines ([`crate::sim::run_sharded`]): thread count = shard
//!   count, not satellite count, so missions scale to 10k–100k
//!   satellites while reproducing the thread driver's report.
//!
//! Shared mission geometry: [`layout`] holds the config-driven
//! constellation seeding + ground-segment construction both execution
//! paths use, and [`scheduler`] arbitrates multi-station contact
//! overlap into the disjoint merged track a timeline consumes.

pub mod batcher;
pub mod cloudfilter;
pub mod constellation;
pub mod downlink;
pub mod engine;
pub mod fleet;
pub mod layout;
pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use constellation::{run_constellation, ConstellationReport, SatelliteReport};
pub use engine::StagedEngine;
pub use fleet::run_fleet;
pub use layout::{mission_timeline, plane_satellite, station_network, CONTACT_SCAN_STEP_S};
pub use pipeline::{Pipeline, ScenarioAccumulator, ScenarioResult};
pub use scheduler::{ContactScheduler, ContactStrategy, GreedyMaxElevation, SchedulerStats};

/// Where a tile ended up — the router's conservation invariant is that
/// every split tile is assigned exactly one of these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileFate {
    /// Dropped by the redundancy filter (cloud-covered).
    Filtered,
    /// Onboard detections were confident; only results downlinked.
    OnboardFinal,
    /// Low confidence; raw tile downlinked for ground inference.
    Offloaded,
}
