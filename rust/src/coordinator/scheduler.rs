//! Contact scheduling: which station does a satellite spend battery and
//! backlog on when several can see it at once?
//!
//! Per-station visibility tracks (from
//! [`crate::orbit::StationNetwork::contact_tracks`]) may overlap in
//! time, but the spacecraft has one transmitter.  The
//! [`ContactScheduler`] arbitrates the overlaps *at plan time* — before
//! the mission runs — producing one sorted, pairwise-disjoint sequence
//! of station-tagged windows that [`crate::sim::Timeline`] consumes as
//! its merged view.  Disjointness by construction is what makes the
//! system-wide invariant "one satellite never transmits to two stations
//! simultaneously" structural rather than policed.
//!
//! The decision rule is pluggable ([`ContactStrategy`]); the default
//! [`GreedyMaxElevation`] picks, at each pass AOS, the candidate pass
//! with the highest peak elevation (higher culmination ⇒ shorter slant
//! range ⇒ better link budget), breaking ties toward the lower station
//! index for determinism.
//!
//! For a single-station network the plan is the identity function on
//! the track — bit-for-bit, flags included — which is how the default
//! Beijing-only configuration keeps every pre-refactor report and
//! golden test unchanged.

use crate::orbit::ContactWindow;

/// A pluggable pass-selection rule.  `choose` receives the non-empty
/// set of candidate windows open at the decision instant and returns
/// the index of the one to commit the transmitter to.
pub trait ContactStrategy {
    fn choose(&self, candidates: &[&ContactWindow]) -> usize;

    /// Strategy name for reports and bench labels.
    fn name(&self) -> &'static str;
}

/// Default strategy: highest peak elevation wins; ties break toward the
/// lower `station_id` so plans are deterministic.
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyMaxElevation;

impl ContactStrategy for GreedyMaxElevation {
    fn choose(&self, candidates: &[&ContactWindow]) -> usize {
        let mut best = 0;
        for (i, w) in candidates.iter().enumerate().skip(1) {
            let b = candidates[best];
            if w.max_elevation_deg > b.max_elevation_deg
                || (w.max_elevation_deg == b.max_elevation_deg && w.station_id < b.station_id)
            {
                best = i;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "greedy-max-elevation"
    }
}

/// Plan accounting, per satellite (sum across a fleet with [`absorb`]).
///
/// [`absorb`]: SchedulerStats::absorb
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SchedulerStats {
    /// Strategy invocations (one per committed plan segment).
    pub decisions: u64,
    /// Committed segments whose start was clipped because another
    /// station's pass held the transmitter at their AOS.
    pub clipped: u64,
    /// Windows never used at all: fully covered by segments awarded to
    /// other stations.
    pub shadowed: u64,
    /// Committed segments per station (index = `station_id`).
    pub per_station_passes: Vec<u64>,
    /// Committed seconds per station (index = `station_id`).
    pub per_station_seconds: Vec<f64>,
}

impl SchedulerStats {
    fn sized(n_stations: usize) -> SchedulerStats {
        SchedulerStats {
            per_station_passes: vec![0; n_stations],
            per_station_seconds: vec![0.0; n_stations],
            ..SchedulerStats::default()
        }
    }

    /// Fold another satellite's plan accounting into this one.
    pub fn absorb(&mut self, other: &SchedulerStats) {
        self.decisions += other.decisions;
        self.clipped += other.clipped;
        self.shadowed += other.shadowed;
        if self.per_station_passes.len() < other.per_station_passes.len() {
            self.per_station_passes.resize(other.per_station_passes.len(), 0);
            self.per_station_seconds.resize(other.per_station_seconds.len(), 0.0);
        }
        for (i, p) in other.per_station_passes.iter().enumerate() {
            self.per_station_passes[i] += p;
        }
        for (i, s) in other.per_station_seconds.iter().enumerate() {
            self.per_station_seconds[i] += s;
        }
    }
}

/// Plans the merged contact sequence for one satellite from its
/// per-station visibility tracks.
#[derive(Clone, Debug, Default)]
pub struct ContactScheduler<S: ContactStrategy = GreedyMaxElevation> {
    strategy: S,
}

impl ContactScheduler {
    /// The default scheduler: [`GreedyMaxElevation`].  (A named
    /// constructor because `Self::default()` cannot infer the strategy
    /// parameter in expression position.)
    pub fn greedy() -> ContactScheduler {
        ContactScheduler { strategy: GreedyMaxElevation }
    }
}

impl<S: ContactStrategy> ContactScheduler<S> {
    pub fn with_strategy(strategy: S) -> ContactScheduler<S> {
        ContactScheduler { strategy }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Arbitrate per-station tracks into one sorted, pairwise-disjoint,
    /// station-tagged window sequence.
    ///
    /// Greedy sweep: maintain a cursor at the end of the last committed
    /// segment.  At each decision instant (the earliest moment any
    /// unconsumed window is live past the cursor), hand the strategy
    /// every window open at that instant; commit the winner from
    /// `max(aos, cursor)` to its LOS; windows the commitment fully
    /// covers are shadowed, partially-covered ones compete again for
    /// their remainder.  A scheduler-clipped start sets `truncated`
    /// (the clamp-not-a-crossing semantics `ContactWindow` already
    /// defines).  Every committed segment is strictly positive — the
    /// zero-length-slice regression the tests pin.
    pub fn plan(&self, tracks: &[Vec<ContactWindow>]) -> (Vec<ContactWindow>, SchedulerStats) {
        let mut stats = SchedulerStats::sized(tracks.len());
        let mut pool: Vec<&ContactWindow> = tracks.iter().flatten().collect();
        pool.sort_by(|a, b| a.aos.total_cmp(&b.aos).then(a.station_id.cmp(&b.station_id)));
        let mut used = vec![false; pool.len()];
        let mut merged: Vec<ContactWindow> = Vec::new();
        let mut cursor = f64::NEG_INFINITY;
        let mut i = 0;
        loop {
            // skip consumed windows and ones fully shadowed by the plan
            while i < pool.len() && (used[i] || pool[i].los <= cursor) {
                if !used[i] {
                    stats.shadowed += 1;
                }
                i += 1;
            }
            if i >= pool.len() {
                break;
            }
            // decision instant: earliest moment a remaining window is live
            let t = pool[i].aos.max(cursor);
            // every unconsumed window open at t competes (pool is sorted
            // by AOS, so the scan stops at the first later opener)
            let mut cand_idx = Vec::new();
            for (j, w) in pool.iter().enumerate().skip(i) {
                if w.aos > t {
                    break;
                }
                if !used[j] && w.los > t {
                    cand_idx.push(j);
                }
            }
            let cands: Vec<&ContactWindow> = cand_idx.iter().map(|&j| pool[j]).collect();
            stats.decisions += 1;
            let choice = self.strategy.choose(&cands);
            debug_assert!(choice < cands.len(), "strategy returned an out-of-range index");
            let pick_j = cand_idx[choice];
            used[pick_j] = true;
            let pick = pool[pick_j];
            let start = pick.aos.max(cursor);
            let clipped = start > pick.aos;
            if clipped {
                stats.clipped += 1;
            }
            stats.per_station_passes[pick.station_id] += 1;
            stats.per_station_seconds[pick.station_id] += pick.los - start;
            merged.push(ContactWindow {
                aos: start,
                los: pick.los,
                max_elevation_deg: pick.max_elevation_deg,
                truncated: pick.truncated || clipped,
                station_id: pick.station_id,
            });
            cursor = pick.los;
        }
        (merged, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(aos: f64, los: f64, el: f64, id: usize) -> ContactWindow {
        ContactWindow { aos, los, max_elevation_deg: el, truncated: false, station_id: id }
    }

    fn assert_disjoint_sorted_positive(plan: &[ContactWindow]) {
        for pair in plan.windows(2) {
            assert!(pair[1].aos >= pair[0].los, "overlap/backtrack: {pair:?}");
        }
        for seg in plan {
            assert!(seg.duration_s() > 0.0, "zero-length segment {seg:?}");
        }
    }

    #[test]
    fn single_station_plan_is_the_identity() {
        // The bit-parity cornerstone: one station in, the exact same
        // windows out — boundaries, elevations, and flags untouched.
        let track = vec![w(100.0, 200.0, 23.0, 0), w(5800.0, 6200.0, 67.5, 0)];
        let (plan, stats) = ContactScheduler::greedy().plan(&[track.clone()]);
        assert_eq!(plan, track);
        assert_eq!(stats.decisions, 2);
        assert_eq!(stats.clipped, 0);
        assert_eq!(stats.shadowed, 0);
        assert_eq!(stats.per_station_passes, vec![2]);
        assert!((stats.per_station_seconds[0] - 500.0).abs() < 1e-12);
    }

    #[test]
    fn higher_elevation_station_wins_overlap() {
        // Station 1 culminates higher during the overlap; it gets the
        // middle, station 0 keeps its flanks.
        let tracks = vec![
            vec![w(100.0, 300.0, 30.0, 0)],
            vec![w(150.0, 250.0, 80.0, 1)],
        ];
        let (plan, stats) = ContactScheduler::greedy().plan(&tracks);
        assert_disjoint_sorted_positive(&plan);
        // at t=100 only station 0 is live → commit [100, 300)?  No:
        // the greedy sweep commits whole passes; station 0 wins its AOS
        // and holds to LOS.  Station 1's pass is fully shadowed.
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].station_id, 0);
        assert_eq!(stats.shadowed, 1);

        // if station 1 is already up at station 0's AOS, elevation wins
        let tracks = vec![
            vec![w(100.0, 300.0, 30.0, 0)],
            vec![w(100.0, 250.0, 80.0, 1)],
        ];
        let (plan, stats) = ContactScheduler::greedy().plan(&tracks);
        assert_disjoint_sorted_positive(&plan);
        assert_eq!(plan.len(), 2, "{plan:?}");
        assert_eq!(plan[0].station_id, 1);
        assert_eq!((plan[0].aos, plan[0].los), (100.0, 250.0));
        assert_eq!(plan[1].station_id, 0);
        assert_eq!((plan[1].aos, plan[1].los), (250.0, 300.0));
        assert!(plan[1].truncated, "clipped start is a clamp, flagged");
        assert_eq!(stats.clipped, 1);
        assert_eq!(stats.per_station_passes, vec![1, 1]);
    }

    #[test]
    fn ties_break_toward_lower_station_id() {
        let tracks = vec![
            vec![w(100.0, 200.0, 45.0, 0)],
            vec![w(100.0, 200.0, 45.0, 1)],
        ];
        let (plan, stats) = ContactScheduler::greedy().plan(&tracks);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].station_id, 0);
        assert_eq!(stats.shadowed, 1);
    }

    #[test]
    fn identical_overlaps_never_produce_zero_length_segments() {
        // Regression: two stations seeing near-identical passes (e.g. a
        // co-located wide-mask pair) must not emit zero-length slivers
        // at the shared boundaries.
        let tracks = vec![
            vec![w(100.0, 200.0, 50.0, 0), w(6000.0, 6400.0, 20.0, 0)],
            vec![w(100.0, 200.0, 60.0, 1), w(6000.0, 6500.0, 25.0, 1)],
        ];
        let (plan, _) = ContactScheduler::greedy().plan(&tracks);
        assert_disjoint_sorted_positive(&plan);
        // first pass: station 1 wins outright, station 0 shadowed (los
        // equal → remainder empty).  second pass: station 1 again (25 >
        // 20), station 0's window fully covered.
        assert_eq!(plan.len(), 2, "{plan:?}");
        assert!(plan.iter().all(|s| s.station_id == 1));
    }

    #[test]
    fn chained_overlaps_hand_off_in_sequence() {
        // Three stations with staggered passes: each hand-off happens at
        // the previous LOS, remainders stay positive, nothing is lost.
        let tracks = vec![
            vec![w(0.0, 100.0, 40.0, 0)],
            vec![w(50.0, 150.0, 30.0, 1)],
            vec![w(120.0, 260.0, 20.0, 2)],
        ];
        let (plan, stats) = ContactScheduler::greedy().plan(&tracks);
        assert_disjoint_sorted_positive(&plan);
        assert_eq!(plan.len(), 3);
        assert_eq!(
            plan.iter().map(|s| (s.station_id, s.aos, s.los)).collect::<Vec<_>>(),
            vec![(0, 0.0, 100.0), (1, 100.0, 150.0), (2, 150.0, 260.0)]
        );
        assert_eq!(stats.clipped, 2, "both hand-offs clip a start");
        let planned: f64 = plan.iter().map(|s| s.duration_s()).sum();
        assert!((planned - 260.0).abs() < 1e-12, "full union covered: {planned}");
    }

    #[test]
    fn stats_absorb_accumulates_across_satellites() {
        let tracks_a = vec![vec![w(0.0, 100.0, 40.0, 0)], vec![w(50.0, 150.0, 30.0, 1)]];
        let tracks_b = vec![vec![w(10.0, 90.0, 10.0, 0)], vec![]];
        let sched = ContactScheduler::greedy();
        let (_, sa) = sched.plan(&tracks_a);
        let (_, sb) = sched.plan(&tracks_b);
        let mut total = SchedulerStats::default();
        total.absorb(&sa);
        total.absorb(&sb);
        assert_eq!(total.decisions, sa.decisions + sb.decisions);
        assert_eq!(total.per_station_passes.len(), 2);
        assert_eq!(total.per_station_passes[0], 2);
        assert!(
            (total.per_station_seconds[0]
                - (sa.per_station_seconds[0] + sb.per_station_seconds[0]))
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn empty_and_contactless_tracks_plan_to_nothing() {
        let (plan, stats) = ContactScheduler::greedy().plan(&[]);
        assert!(plan.is_empty());
        assert_eq!(stats.decisions, 0);
        let (plan, stats) = ContactScheduler::greedy().plan(&[vec![], vec![]]);
        assert!(plan.is_empty());
        assert_eq!(stats.decisions, 0);
        assert_eq!(stats.per_station_passes, vec![0, 0]);
    }
}
