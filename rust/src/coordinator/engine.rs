//! Staged concurrent scenario engine — the stage-graph refactor.
//!
//! The sequential `Pipeline::run_scenario` is a monolith: ground
//! re-inference blocks the next capture.  This module decomposes the
//! scenario into explicit stages connected by bounded typed channels and
//! runs them on scoped worker threads, so scene k's ground (HeavyDet)
//! inference overlaps scene k+1's capture and onboard (CloudScore +
//! TinyDet) inference — the per-model execution locks in
//! [`crate::runtime`] make that overlap safe and real.
//!
//! Stage graph / channel topology (all channels `sync_channel(depth)`):
//!
//! ```text
//! capture ──▶ [onboard × W₁] ──▶ [ground × W₂] ──▶ collector
//!   (source)   split·filter·       HeavyDet on       re-sequence by
//!              batch·TinyDet·      offloaded tiles    capture index,
//!              route                                  fold via
//!                                                     ScenarioAccumulator
//! ```
//!
//! Parity: every stage body is the exact function the sequential facade
//! calls (`onboard_scene`, `ground_scene`) and the collector re-sequences
//! scenes into capture order before folding through the shared
//! [`ScenarioAccumulator`], so for the same config + seed the staged
//! result is bit-identical to the sequential one (asserted by
//! `rust/tests/engine_parity.rs`).
//!
//! Per-stage telemetry: `engine.<stage>.items` counters plus
//! `engine.<stage>.service_s` / `engine.<stage>.queue_wait_s` histograms
//! in [`StagedEngine::metrics`].

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::data::{Scene, Version};
use crate::telemetry::Registry;
use crate::util::pool;

use super::pipeline::{Pipeline, ProcessedTile, ScenarioAccumulator, ScenarioResult};
use super::router::RouterStats;

/// One stage of the graph: a typed item transformer.  Stages are driven
/// by [`worker_loop`], which owns the channel plumbing and telemetry so
/// implementations stay pure.
pub trait Stage {
    type In: Send;
    type Out: Send;
    /// Metric name segment (`engine.<name>.*`).
    fn name(&self) -> &'static str;
    fn process(&mut self, item: Self::In) -> Result<Self::Out>;
}

/// Channel message wrapper stamping enqueue time, so queue waits are
/// observable per stage.
pub(crate) struct Envelope<T> {
    pub(crate) at: Instant,
    pub(crate) inner: T,
}

impl<T> Envelope<T> {
    pub(crate) fn new(inner: T) -> Envelope<T> {
        Envelope { at: Instant::now(), inner }
    }
}

/// A captured scene entering the graph.
pub(crate) struct SceneJob {
    pub(crate) idx: usize,
    pub(crate) scene: Scene,
}

/// Per-scene output of the onboard stage; the ground stage completes the
/// offloaded tiles in place.  Shared with the constellation runner,
/// whose per-satellite driver stands in for the ground stage + collector.
pub(crate) struct OnboardDone {
    pub(crate) idx: usize,
    pub(crate) bentpipe_bytes: u64,
    pub(crate) n_scene_tiles: usize,
    pub(crate) processed: Vec<ProcessedTile>,
    pub(crate) n_filtered: usize,
    pub(crate) wall: f64,
    pub(crate) router: RouterStats,
}

/// Flight-recorder triplet for one scene's onboard work, shared by the
/// constellation thread driver and the fleet machine so both emit the
/// identical record shapes: a `Capture` span over the capture overhead
/// (batch = scene tiles), a `Filter` event for the cloud-filter outcome
/// (batch = tiles filtered out), and an `OnboardInfer` span over the
/// scene's busy seconds (batch = tiles inferred onboard).
pub(crate) fn trace_onboard(
    tracer: &crate::telemetry::trace::SatTracer,
    done: &OnboardDone,
    t_capture: f64,
    capture_overhead_s: f64,
    busy_s: f64,
) {
    use crate::telemetry::trace::{SpanKind, TracePayload};
    tracer.span(
        SpanKind::Capture,
        t_capture,
        t_capture + capture_overhead_s,
        TracePayload::Batch(done.n_scene_tiles),
    );
    tracer.event(SpanKind::Filter, t_capture, TracePayload::Batch(done.n_filtered));
    tracer.span(
        SpanKind::OnboardInfer,
        t_capture,
        t_capture + busy_s,
        TracePayload::Batch(done.processed.len()),
    );
}

pub(crate) struct OnboardStage<'p, 'rt> {
    pub(crate) p: &'p Pipeline<'rt>,
    pub(crate) frag: usize,
}

impl Stage for OnboardStage<'_, '_> {
    type In = SceneJob;
    type Out = OnboardDone;

    fn name(&self) -> &'static str {
        "onboard"
    }

    fn process(&mut self, job: SceneJob) -> Result<OnboardDone> {
        let mut router = RouterStats::default();
        let bentpipe_bytes = job.scene.size_bytes();
        let n_scene_tiles = (job.scene.width / self.frag) * (job.scene.height / self.frag);
        let (processed, n_filtered, wall) = self.p.onboard_scene(&job.scene, &mut router)?;
        Ok(OnboardDone {
            idx: job.idx,
            bentpipe_bytes,
            n_scene_tiles,
            processed,
            n_filtered,
            wall,
            router,
        })
    }
}

struct GroundStage<'p, 'rt> {
    p: &'p Pipeline<'rt>,
}

impl Stage for GroundStage<'_, '_> {
    type In = OnboardDone;
    type Out = OnboardDone;

    fn name(&self) -> &'static str {
        "ground"
    }

    fn process(&mut self, mut done: OnboardDone) -> Result<OnboardDone> {
        done.wall += self.p.ground_scene(&mut done.processed)?;
        Ok(done)
    }
}

/// Drive one stage worker: recv → process → send, recording service time,
/// queue wait, and item count under `<prefix>.<stage>.*`.  On a stage
/// error the worker parks the error and exits; dropping its sender lets
/// the rest of the graph drain and shut down instead of deadlocking.
pub(crate) fn worker_loop<S: Stage>(
    prefix: &str,
    mut stage: S,
    rx: &Mutex<Receiver<Envelope<S::In>>>,
    tx: &SyncSender<Envelope<S::Out>>,
    metrics: &Registry,
    errs: &Mutex<Vec<anyhow::Error>>,
) {
    let items = metrics.counter(&format!("{prefix}.{}.items", stage.name()));
    let svc = metrics.histogram(&format!("{prefix}.{}.service_s", stage.name()));
    let wait = metrics.histogram(&format!("{prefix}.{}.queue_wait_s", stage.name()));
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(env) = msg else { break };
        wait.observe_secs(env.at.elapsed().as_secs_f64());
        let t0 = Instant::now();
        match stage.process(env.inner) {
            Ok(out) => {
                svc.observe_secs(t0.elapsed().as_secs_f64());
                items.inc();
                if tx.send(Envelope::new(out)).is_err() {
                    break; // downstream shut down
                }
            }
            Err(e) => {
                errs.lock().unwrap().push(e);
                break;
            }
        }
    }
}

/// Concurrent scenario executor over a borrowed [`Pipeline`].
pub struct StagedEngine<'p, 'rt> {
    pipeline: &'p Pipeline<'rt>,
    pub cfg: EngineConfig,
    /// Per-stage counters and latency histograms, accumulated across
    /// every `run_scenario` call on this engine (the registry is never
    /// reset — use a fresh engine for per-run numbers).
    pub metrics: Registry,
}

impl<'p, 'rt> StagedEngine<'p, 'rt> {
    pub fn new(pipeline: &'p Pipeline<'rt>) -> StagedEngine<'p, 'rt> {
        StagedEngine {
            pipeline,
            cfg: pipeline.cfg.engine.clone(),
            metrics: Registry::new(),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> StagedEngine<'p, 'rt> {
        self.cfg.workers = workers;
        self
    }

    /// Run the scenario concurrently.  With `workers <= 1` there is
    /// nothing to overlap, so this is exactly the sequential facade.
    pub fn run_scenario(&self, version: Version, n_scenes: usize) -> Result<ScenarioResult> {
        if self.cfg.workers <= 1 {
            return self.pipeline.run_scenario(version, n_scenes);
        }
        let p = self.pipeline;
        let depth = self.cfg.channel_depth.max(1);
        // Split inference workers across the two heavy stages; onboard
        // gets the odd one out (it also runs the CloudScore filter).
        let onboard_workers = self.cfg.workers.div_ceil(2);
        let ground_workers = (self.cfg.workers / 2).max(1);

        let (tx_scene, rx_scene) = sync_channel::<Envelope<SceneJob>>(depth);
        let (tx_onboard, rx_onboard) = sync_channel::<Envelope<OnboardDone>>(depth);
        let (tx_done, rx_done) = sync_channel::<Envelope<OnboardDone>>(depth);
        let rx_scene = Arc::new(Mutex::new(rx_scene));
        let rx_onboard = Arc::new(Mutex::new(rx_onboard));

        let mut gen = p.scene_gen(version);
        let mut acc = ScenarioAccumulator::new(&p.cfg, p.rt.manifest.classes);
        let errs: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
        let metrics = &self.metrics;
        let frag = p.cfg.fragment_px;

        {
            let errs = &errs;
            let acc_ref = &mut acc;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();

            // capture source (SceneGen is inherently sequential: one RNG
            // stream).  Send failure means downstream stopped on error.
            jobs.push(Box::new(move || {
                let produced = metrics.counter("engine.capture.items");
                for idx in 0..n_scenes {
                    let scene = gen.capture();
                    produced.inc();
                    if tx_scene.send(Envelope::new(SceneJob { idx, scene })).is_err() {
                        break;
                    }
                }
            }));

            for _ in 0..onboard_workers {
                let rx = Arc::clone(&rx_scene);
                let tx = tx_onboard.clone();
                jobs.push(Box::new(move || {
                    worker_loop("engine", OnboardStage { p, frag }, &rx, &tx, metrics, errs);
                }));
            }
            // Drop the spawner's channel handles: termination propagates
            // through sender/receiver drops, so no handle may outlive the
            // workers or the graph never observes shutdown.
            drop(rx_scene);
            drop(tx_onboard);

            for _ in 0..ground_workers {
                let rx = Arc::clone(&rx_onboard);
                let tx = tx_done.clone();
                jobs.push(Box::new(move || {
                    worker_loop("engine", GroundStage { p }, &rx, &tx, metrics, errs);
                }));
            }
            drop(rx_onboard);
            drop(tx_done);

            // collector: re-sequence by capture index, fold in order —
            // this is what keeps the result bit-identical to sequential.
            jobs.push(Box::new(move || {
                let wait = metrics.histogram("engine.evaluate.queue_wait_s");
                let mut held: BTreeMap<usize, OnboardDone> = BTreeMap::new();
                let mut next = 0usize;
                for env in rx_done.iter() {
                    wait.observe_secs(env.at.elapsed().as_secs_f64());
                    held.insert(env.inner.idx, env.inner);
                    while let Some(d) = held.remove(&next) {
                        acc_ref.add_scene(
                            &d.router,
                            d.bentpipe_bytes,
                            d.n_scene_tiles,
                            &d.processed,
                            d.n_filtered,
                            d.wall,
                        );
                        next += 1;
                    }
                }
            }));

            pool::scope_jobs(jobs);
        }

        if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
            return Err(e);
        }
        anyhow::ensure!(
            acc.scenes() == n_scenes,
            "staged engine lost scenes: folded {} of {n_scenes}",
            acc.scenes()
        );
        // zero-copy path health: a warmed tile pool allocates only its
        // steady-state population, so the gauges expose per-tile
        // allocation behaviour without a profiler
        let ps = p.tile_pool_stats();
        self.metrics.gauge("engine.pool.tile_allocs").set(ps.allocs as i64);
        self.metrics
            .gauge("engine.pool.tile_hit_pct")
            .set((ps.hit_rate() * 100.0).round() as i64);
        // nonzero only with a capped pool (engine.tile_pool_cap): returns
        // whose storage was freed instead of parked
        self.metrics.gauge("engine.pool.tile_evictions").set(ps.evictions as i64);
        Ok(acc.finish(version, frag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::runtime::Runtime;

    fn rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.scene_cells = 4;
        cfg
    }

    #[test]
    fn staged_conserves_tiles() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = StagedEngine::new(&p).with_workers(2).run_scenario(Version::V2, 3).unwrap();
        assert_eq!(
            r.tiles_total,
            r.tiles_filtered + r.router.onboard_final as usize + r.router.offloaded as usize
        );
        assert_eq!(r.scenes, 3);
    }

    #[test]
    fn single_worker_is_the_sequential_facade() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let staged = StagedEngine::new(&p).with_workers(1).run_scenario(Version::V2, 2).unwrap();
        let seq = p.run_scenario(Version::V2, 2).unwrap();
        assert_eq!(staged.tiles_total, seq.tiles_total);
        assert_eq!(staged.map_collab, seq.map_collab);
    }

    #[test]
    fn stage_telemetry_recorded() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let engine = StagedEngine::new(&p).with_workers(2);
        engine.run_scenario(Version::V2, 2).unwrap();
        let text = engine.metrics.render();
        assert!(text.contains("counter engine.capture.items 2"), "{text}");
        assert!(text.contains("counter engine.onboard.items 2"), "{text}");
        assert!(text.contains("counter engine.ground.items 2"), "{text}");
        assert!(text.contains("histogram engine.onboard.service_s"), "{text}");
    }
}
