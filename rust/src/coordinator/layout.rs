//! Config-driven constellation layout, shared by both engines.
//!
//! `run_constellation` (thread driver) and `run_fleet` (event machine)
//! must fly *the same* mission for the same config — the `fleet_parity`
//! tests pin their reports bit-for-bit.  Before this module each engine
//! hardcoded its own copy of the satellite seeding (`baoyun()` plus
//! per-index RAAN/phase spread) and the ground segment
//! (`beijing_station()`); any drift between the copies would silently
//! break parity.  Now both call one helper set:
//!
//! * [`plane_satellite`] — the per-index orbital-plane seeding;
//! * [`station_network`] — the ground segment from `cfg.stations`
//!   (defaults to the single Beijing station, preserving every pre-
//!   multi-station result);
//! * [`mission_timeline`] — timeline construction: degenerate for
//!   `ideal_contact`, the legacy single-station orbital scan for one
//!   station (bit-identical path), or scheduler-arbitrated per-station
//!   tracks for a real network.

use crate::config::Config;
use crate::orbit::{baoyun, GroundStation, Propagator, Satellite, StationNetwork};
use crate::sim::{scan_spans, Timeline};

use super::scheduler::ContactScheduler;

/// Coarse contact/eclipse scan step both engines have always used.
pub const CONTACT_SCAN_STEP_S: f64 = 10.0;

/// Satellite `index` of the constellation: the Baoyun platform spread
/// across orbital planes by `raan_step_rad` and phased evenly around
/// the orbit.  Exactly the seeding both engines previously inlined.
pub fn plane_satellite(cfg: &Config, index: usize, name: &str) -> Satellite {
    let mut sat = baoyun();
    sat.name = name.to_string();
    sat.raan_rad = index as f64 * cfg.constellation.raan_step_rad;
    sat.phase_rad =
        index as f64 * std::f64::consts::TAU / cfg.constellation.satellites.max(1) as f64;
    sat
}

/// The ground segment described by `cfg.stations` (validated non-empty;
/// the default is the single Beijing station).
pub fn station_network(cfg: &Config) -> StationNetwork {
    StationNetwork::new(
        cfg.stations
            .iter()
            .map(|s| GroundStation {
                name: s.name.clone(),
                lat_deg: s.lat_deg,
                lon_deg: s.lon_deg,
                min_elevation_deg: s.min_elevation_deg,
            })
            .collect(),
    )
}

/// One satellite's mission timeline over the ground segment.
///
/// * `ideal_contact` → the degenerate always-in-contact timeline
///   (single-satellite scenario parity path).
/// * one station → the legacy single-station orbital construction,
///   bit-for-bit identical to the pre-multi-station code.
/// * N stations → per-station visibility tracks arbitrated by the
///   greedy [`ContactScheduler`] into a disjoint merged view.
pub fn mission_timeline<P: Propagator + ?Sized>(
    cfg: &Config,
    sat: &P,
    net: &StationNetwork,
) -> Timeline {
    let horizon = cfg.constellation.horizon_s;
    if cfg.constellation.ideal_contact {
        return Timeline::degenerate(&cfg.timing, horizon);
    }
    if net.len() == 1 {
        return Timeline::orbital(&cfg.timing, sat, net.station(0), horizon, CONTACT_SCAN_STEP_S);
    }
    let tracks = net.contact_tracks(sat, 0.0, horizon, CONTACT_SCAN_STEP_S);
    let (merged, _stats) = ContactScheduler::greedy().plan(&tracks);
    let sunlit = scan_spans(|t| !sat.in_eclipse(t), 0.0, horizon, CONTACT_SCAN_STEP_S);
    Timeline::from_tracks(&cfg.timing, tracks, merged, Some(sunlit), horizon)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StationConfig;
    use crate::orbit::beijing_station;

    #[test]
    fn default_network_is_exactly_the_beijing_station() {
        let cfg = Config::default();
        let net = station_network(&cfg);
        assert_eq!(net.len(), 1);
        let gs = net.station(0);
        let legacy = beijing_station();
        assert_eq!(gs.name, legacy.name);
        assert_eq!(gs.lat_deg.to_bits(), legacy.lat_deg.to_bits());
        assert_eq!(gs.lon_deg.to_bits(), legacy.lon_deg.to_bits());
        assert_eq!(gs.min_elevation_deg.to_bits(), legacy.min_elevation_deg.to_bits());
    }

    #[test]
    fn plane_satellite_matches_legacy_inline_seeding() {
        let mut cfg = Config::default();
        cfg.constellation.satellites = 4;
        for index in 0..4 {
            let sat = plane_satellite(&cfg, index, "sat-x");
            let mut legacy = baoyun();
            legacy.name = "sat-x".to_string();
            legacy.raan_rad = index as f64 * cfg.constellation.raan_step_rad;
            legacy.phase_rad = index as f64 * std::f64::consts::TAU / 4.0;
            assert_eq!(sat.name, legacy.name);
            assert_eq!(sat.altitude_km.to_bits(), legacy.altitude_km.to_bits());
            assert_eq!(sat.inclination_rad.to_bits(), legacy.inclination_rad.to_bits());
            assert_eq!(sat.raan_rad.to_bits(), legacy.raan_rad.to_bits());
            assert_eq!(sat.phase_rad.to_bits(), legacy.phase_rad.to_bits());
        }
    }

    #[test]
    fn single_station_timeline_matches_legacy_orbital_construction() {
        let mut cfg = Config::default();
        cfg.constellation.horizon_s = 21_600.0;
        let sat = plane_satellite(&cfg, 1, "parity");
        let net = station_network(&cfg);
        let tl = mission_timeline(&cfg, &sat, &net);
        let legacy =
            Timeline::orbital(&cfg.timing, &sat, &beijing_station(), 21_600.0, 10.0);
        assert_eq!(tl.n_contacts(), legacy.n_contacts());
        assert_eq!(tl.contact_total_s().to_bits(), legacy.contact_total_s().to_bits());
        assert_eq!(tl.n_stations(), 1);
    }

    #[test]
    fn multi_station_timeline_schedules_disjoint_tagged_windows() {
        let mut cfg = Config::default();
        cfg.constellation.horizon_s = 86_400.0;
        cfg.stations = vec![
            StationConfig::default(),
            StationConfig {
                name: "Kashi".into(),
                lat_deg: 39.47,
                lon_deg: 75.98,
                min_elevation_deg: 10.0,
            },
            StationConfig {
                name: "Sanya".into(),
                lat_deg: 18.23,
                lon_deg: 109.5,
                min_elevation_deg: 10.0,
            },
        ];
        let sat = plane_satellite(&cfg, 0, "multi");
        let net = station_network(&cfg);
        let mut tl = mission_timeline(&cfg, &sat, &net);
        assert_eq!(tl.n_stations(), 3);
        // the scheduled view sees at least as much contact as any single
        // station's raw track, and never more than their sum
        let best: f64 = (0..3)
            .map(|i| tl.station_contact_total_s(i))
            .fold(0.0, f64::max);
        let sum: f64 = (0..3).map(|i| tl.station_contact_total_s(i)).sum();
        let merged = tl.contact_total_s();
        assert!(merged >= best - 1e-9, "merged {merged} < best single {best}");
        assert!(merged <= sum + 1e-9, "merged {merged} exceeds union bound {sum}");
        // every consumed slice is tagged with a real station and slices
        // never overlap
        let slices = tl.remaining_contacts();
        assert!(!slices.is_empty());
        for s in &slices {
            assert!(s.window.station_id < 3);
            assert!(s.window.duration_s() > 0.0);
        }
        for pair in slices.windows(2) {
            assert!(pair[0].window.los <= pair[1].window.aos);
        }
    }
}
