//! End-to-end collaborative-inference pipeline (Fig 5) + evaluation.
//!
//! `run_scenario` reproduces the paper's case-study measurements for one
//! dataset version: filter rate (Fig 6), in-orbit vs collaborative mAP
//! (Fig 7), downlinked-byte accounting (the 90% headline), router stats,
//! and duty-cycled energy (Tables 2–3 + the 17% headline).
//!
//! Since the stage-graph refactor this module holds the per-scene stage
//! bodies ([`Pipeline::onboard_scene`], [`Pipeline::ground_scene`]) and
//! the order-dependent result fold ([`ScenarioAccumulator`]); both the
//! sequential facade here and the concurrent [`super::engine`] execute
//! exactly these functions, which is what makes the staged engine's
//! `ScenarioResult` bit-identical to the sequential one.

use anyhow::Result;

use crate::config::Config;
use crate::data::{gather_pixels, split_scene_pooled, SceneGen, Tile, Version, TILE_PX};
use crate::detect::{decode_rows, nms, Detection, Evaluator, MapReport};
use crate::energy::EnergyMeter;
use crate::runtime::{Model, Runtime};
use crate::sim::{DutyCycles, Timeline};
use crate::util::buffer::{PixelPool, PoolStats, QuantPool};

use super::batcher::Batcher;
use super::cloudfilter::{CloudFilter, FilterPrecision};
use super::router::{route, AdaptiveRouting, RouterPolicy, RouterStats};
use super::TileFate;

// Mission-time constants and the shared scene-timing definition now live
// in the unified simulation core; re-exported here for the established
// import paths (benches, examples, constellation).
pub use crate::sim::{scene_timing, GROUND_S_PER_TILE, ONBOARD_S_PER_TILE};

/// Per-tile header bytes accompanying compact results.
pub const RESULT_HEADER_BYTES: u64 = 8;

/// One processed tile with everything the ground segment ends up knowing.
pub struct ProcessedTile {
    pub tile: Tile,
    pub fate: TileFate,
    pub onboard_dets: Vec<Detection>,
    /// Present for offloaded tiles once ground inference ran.
    pub ground_dets: Option<Vec<Detection>>,
    pub best_objectness: f32,
}

#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub version: &'static str,
    pub fragment_px: usize,
    pub scenes: usize,
    pub tiles_total: usize,
    pub tiles_filtered: usize,
    pub router: RouterStats,
    /// mAP if the satellite's own results were final everywhere.
    pub map_inorbit: f64,
    /// mAP of the collaborative system (Fig 7's right bars).
    pub map_collab: f64,
    pub report_inorbit: MapReport,
    pub report_collab: MapReport,
    /// Bytes a bent-pipe would downlink (all raw scenes).
    pub bentpipe_bytes: u64,
    /// Bytes the collaborative system downlinks (results + offload images).
    pub collab_bytes: u64,
    pub mean_confidence: f64,
    /// Onboard compute duty cycle over the scenario's virtual time.
    pub compute_duty: f64,
    /// Energy: compute share of total (17% headline).
    pub energy_compute_share: f64,
    /// Wallclock spent in PJRT execution (perf metric).
    pub wall_infer_s: f64,
    /// SoC trajectory + governor stats when the power subsystem is
    /// enabled (`power.enabled`); `None` on the single-satellite paths
    /// and whenever power is off — the constellation driver fills it in
    /// after the fold, so the accumulator stays power-agnostic.
    pub power: Option<crate::power::PowerStats>,
    /// Federated round accounting when scheduling is enabled
    /// (`federated.enabled`); `None` otherwise — filled in by the
    /// constellation driver after the fold, like `power`.
    pub federated: Option<crate::sedna::federated::FederatedStats>,
}

impl ScenarioResult {
    pub fn filter_rate(&self) -> f64 {
        self.tiles_filtered as f64 / self.tiles_total.max(1) as f64
    }

    pub fn data_reduction(&self) -> f64 {
        1.0 - self.collab_bytes as f64 / self.bentpipe_bytes.max(1) as f64
    }

    pub fn accuracy_improvement(&self) -> f64 {
        if self.map_inorbit <= 0.0 {
            0.0
        } else {
            (self.map_collab - self.map_inorbit) / self.map_inorbit
        }
    }
}

/// Order-dependent fold of per-scene outputs into a [`ScenarioResult`].
///
/// Floating-point accumulation (confidence sums, energy integration) and
/// evaluator record order depend on scene order, so the staged engine's
/// collector re-sequences scenes by capture index before feeding this —
/// identical per-scene inputs then produce a bit-identical result on both
/// paths.
///
/// Virtual time lives on an internal degenerate [`Timeline`] whose clock
/// advances one scene period per fold.  Duty cycles handed to the
/// [`EnergyMeter`] are no longer hardcoded here: [`Self::add_scene`]
/// derives the always-in-contact nominal duties from the timeline, and
/// the constellation path passes real observed duties (link airtime,
/// capture events) through [`Self::add_scene_observed`].
pub struct ScenarioAccumulator {
    router: RouterStats,
    ev_inorbit: Evaluator,
    ev_collab: Evaluator,
    tiles_total: usize,
    tiles_filtered: usize,
    bentpipe_bytes: u64,
    collab_bytes: u64,
    conf_sum: f64,
    conf_n: u64,
    wall_infer: f64,
    onboard_busy_s: f64,
    energy: EnergyMeter,
    scenes: usize,
    timeline: Timeline,
}

impl ScenarioAccumulator {
    pub fn new(cfg: &Config, classes: usize) -> ScenarioAccumulator {
        ScenarioAccumulator {
            router: RouterStats::default(),
            ev_inorbit: Evaluator::new(classes, 0.5),
            ev_collab: Evaluator::new(classes, 0.5),
            tiles_total: 0,
            tiles_filtered: 0,
            bentpipe_bytes: 0,
            collab_bytes: 0,
            conf_sum: 0.0,
            conf_n: 0,
            wall_infer: 0.0,
            onboard_busy_s: 0.0,
            energy: EnergyMeter::with_floors(cfg.energy.pi_idle_floor, cfg.energy.comm_idle_floor),
            scenes: 0,
            timeline: Timeline::degenerate(&cfg.timing, f64::INFINITY),
        }
    }

    /// Fold one scene, in capture order, with the degenerate timeline's
    /// nominal duty cycles (the single-satellite scenario abstraction).
    pub fn add_scene(
        &mut self,
        router: &RouterStats,
        bentpipe_bytes: u64,
        n_scene_tiles: usize,
        processed: &[ProcessedTile],
        n_filtered: usize,
        wall: f64,
    ) {
        let (busy, period) = scene_timing(self.timeline.timing(), processed.len());
        let duties = self.timeline.nominal_duties(busy, period);
        self.add_scene_observed(router, bentpipe_bytes, n_scene_tiles, processed, n_filtered, wall, duties);
    }

    /// Fold one scene with externally observed duty cycles (the
    /// constellation path: comm from link airtime inside contact
    /// windows, camera from capture events).
    #[allow(clippy::too_many_arguments)] // the scene fold, not public API surface
    pub fn add_scene_observed(
        &mut self,
        router: &RouterStats,
        bentpipe_bytes: u64,
        n_scene_tiles: usize,
        processed: &[ProcessedTile],
        n_filtered: usize,
        wall: f64,
        duties: DutyCycles,
    ) {
        self.scenes += 1;
        self.router.merge(router);
        self.bentpipe_bytes += bentpipe_bytes;
        self.tiles_total += n_scene_tiles;
        self.tiles_filtered += n_filtered;
        self.wall_infer += wall;

        for p in processed {
            // evaluation — in-orbit: onboard detections everywhere
            self.ev_inorbit.add_image(&p.onboard_dets, &p.tile.gt);
            // collaborative: ground detections replace offloaded tiles
            match (&p.fate, &p.ground_dets) {
                (TileFate::Offloaded, Some(g)) => self.ev_collab.add_image(g, &p.tile.gt),
                _ => self.ev_collab.add_image(&p.onboard_dets, &p.tile.gt),
            }
            // byte accounting
            match p.fate {
                TileFate::OnboardFinal => {
                    self.collab_bytes += RESULT_HEADER_BYTES
                        + Detection::WIRE_BYTES * p.onboard_dets.len() as u64;
                }
                TileFate::Offloaded => {
                    self.collab_bytes += p.tile.raw_bytes();
                }
                TileFate::Filtered => unreachable!("filtered tiles are not processed"),
            }
            if let Some(best) = p.onboard_dets.first() {
                self.conf_sum += best.score as f64;
                self.conf_n += 1;
            }
        }

        // virtual-time + energy accounting for this scene: the satellite is
        // busy ONBOARD_S_PER_TILE per kept tile; capture and filtering are
        // folded into a per-scene constant.  The mission clock advances one
        // scene period and the energy meter integrates the duty cycles the
        // timeline (or the constellation's observation) derived.
        let (busy, scene_period) = scene_timing(self.timeline.timing(), processed.len());
        self.onboard_busy_s += busy;
        self.timeline.advance(scene_period);
        self.energy.advance(scene_period, duties.compute, duties.comm, duties.camera);
    }

    /// Advance mission time past the last capture without folding a
    /// scene — the constellation's downlink tail, where queued items get
    /// their remaining contact windows.  Integrates energy at the given
    /// duties (compute 0 ⇒ the meter's idle floor; comm reflects the
    /// tail drains' observed link airtime).  Single-satellite paths
    /// never call this, so their results are untouched.
    pub fn extend_mission(&mut self, dt_s: f64, duties: DutyCycles) {
        if dt_s <= 0.0 {
            return;
        }
        self.timeline.advance(dt_s);
        self.energy.advance(dt_s, duties.compute, duties.comm, duties.camera);
    }

    /// Charge one federated local-training burst to the H2 energy
    /// ledger (the constellation driver calls this at each participating
    /// round; single-satellite paths never do, so their
    /// `energy_compute_share` is untouched).
    pub fn add_training(&mut self, train_s: f64) {
        self.energy.add_training(train_s);
    }

    /// Scenes folded so far (the engine's collector uses this to detect
    /// lost work).
    pub fn scenes(&self) -> usize {
        self.scenes
    }

    pub fn finish(self, version: Version, fragment_px: usize) -> ScenarioResult {
        // Each report is computed once and the headline maps are derived
        // from the cached values (the pre-refactor code evaluated every
        // report twice).
        let report_inorbit = self.ev_inorbit.report();
        let report_collab = self.ev_collab.report();
        ScenarioResult {
            version: version.name(),
            fragment_px,
            scenes: self.scenes,
            tiles_total: self.tiles_total,
            tiles_filtered: self.tiles_filtered,
            router: self.router,
            map_inorbit: report_inorbit.map,
            map_collab: report_collab.map,
            report_inorbit,
            report_collab,
            bentpipe_bytes: self.bentpipe_bytes,
            collab_bytes: self.collab_bytes,
            mean_confidence: if self.conf_n == 0 {
                0.0
            } else {
                self.conf_sum / self.conf_n as f64
            },
            compute_duty: self.onboard_busy_s / self.timeline.now_s().max(1e-9),
            energy_compute_share: self.energy.compute_share(),
            wall_infer_s: self.wall_infer,
            power: None,
            federated: None,
        }
    }
}

pub struct Pipeline<'rt> {
    pub(crate) rt: &'rt Runtime,
    pub cfg: Config,
    pub policy: RouterPolicy,
    pub onboard_model: Model,
    /// Tile-buffer pool for the split→batch→infer hot path: `cut` checks
    /// buffers out here and every downstream clone (ground offload,
    /// constellation dispatch) draws from the same pool, so steady-state
    /// scene processing performs zero per-tile pixel allocations.  Capped
    /// at `engine.tile_pool_cap` parked buffers (0 = unbounded).
    tile_pool: PixelPool,
    /// Scoring path for the redundancy filter, parsed from the validated
    /// `policy.filter_precision` knob ("f32" keeps every result
    /// bit-identical; "i8" decides from integer white counts).
    filter_precision: FilterPrecision,
    /// Pooled i8 scratch backing the quantized filter path.
    quant_pool: QuantPool,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: Config) -> Pipeline<'rt> {
        let policy = RouterPolicy {
            confidence_threshold: cfg.policy.confidence_threshold,
            empty_objectness: cfg.policy.empty_objectness,
            // Adaptation only bites where a LinkSnapshot exists (the
            // constellation driver re-routes with `policy.effective`);
            // link-blind paths always apply the base threshold.
            adaptive: if cfg.policy.adaptive {
                Some(AdaptiveRouting {
                    backlog_high_bytes: cfg.policy.adaptive_backlog_bytes,
                    loss_high: cfg.policy.adaptive_loss_rate,
                    tighten_step: cfg.policy.adaptive_tighten,
                    relax_step: cfg.policy.adaptive_relax,
                })
            } else {
                None
            },
        };
        // config::parse already validated the knob; unreachable fallback
        // keeps a hand-built Config with a bad string on the default path
        let filter_precision =
            FilterPrecision::parse(&cfg.policy.filter_precision).unwrap_or_default();
        let tile_pool = PixelPool::with_cap(TILE_PX, cfg.engine.tile_pool_cap);
        Pipeline {
            rt,
            cfg,
            policy,
            onboard_model: Model::Tiny,
            tile_pool,
            filter_precision,
            quant_pool: QuantPool::new(TILE_PX),
        }
    }

    /// Tile-pool accounting: `allocs` stops growing once the pool has
    /// warmed to the maximum number of tiles in flight (asserted by the
    /// zero-copy path tests; exported as engine/constellation gauges).
    pub fn tile_pool_stats(&self) -> PoolStats {
        self.tile_pool.stats()
    }

    /// Deterministic scene source for one scenario run — shared by the
    /// sequential facade and the engine's capture stage so both observe
    /// the identical capture stream.
    pub fn scene_gen(&self, version: Version) -> SceneGen {
        SceneGen::new(
            self.cfg.seed ^ version.name().len() as u64,
            version.spec(),
            self.cfg.scene_cells,
            self.cfg.scene_cells,
        )
    }

    /// Run one detector over tiles; returns (per-tile NMS'd detections,
    /// per-tile best objectness, wallclock seconds).
    pub fn infer(&self, model: Model, tiles: &[Tile]) -> Result<(Vec<Vec<Detection>>, Vec<f32>, f64)> {
        let m = &self.rt.manifest;
        let cols = m.grid * m.grid * m.head_d;
        let max_b = self.rt.max_batch();
        let mut dets = Vec::with_capacity(tiles.len());
        let mut best_obj = Vec::with_capacity(tiles.len());
        let mut wall = 0.0;
        // one pooled scratch for every chunk of this call — the PJRT
        // marshal is a slice copy into reused storage, not a fresh Vec
        let mut scratch = self.rt.scratch_buf();
        for chunk in tiles.chunks(max_b) {
            let n_px = gather_pixels(chunk, &mut scratch);
            let t0 = std::time::Instant::now();
            let rows = self.rt.execute(model, chunk.len(), &scratch[..n_px])?;
            wall += t0.elapsed().as_secs_f64();
            for i in 0..chunk.len() {
                let r = &rows[i * cols..(i + 1) * cols];
                let obj = r
                    .chunks_exact(m.head_d)
                    .map(|c| c[4])
                    .fold(f32::MIN, f32::max);
                best_obj.push(obj);
                let raw = decode_rows(r, m.head_d, self.cfg.policy.score_threshold);
                dets.push(nms(raw, self.cfg.policy.nms_iou));
            }
        }
        Ok((dets, best_obj, wall))
    }

    /// Onboard half of one scene: split → cloud-filter → dynamic-batch →
    /// onboard infer → route.  Batches form through the [`Batcher`] (the
    /// hot path since the staged-engine refactor); enqueueing a whole
    /// scene and draining with flush reproduces `chunks(max_batch)`
    /// exactly, so detections are unchanged from the pre-batcher pipeline.
    pub fn onboard_scene(
        &self,
        scene: &crate::data::Scene,
        router_stats: &mut RouterStats,
    ) -> Result<(Vec<ProcessedTile>, usize, f64)> {
        let tiles = split_scene_pooled(scene, self.cfg.fragment_px, &self.tile_pool);
        // default (f32) takes the exact pre-quantization code path, so
        // default-config results stay bit-identical; i8 shares the
        // pipeline's pooled quantization scratch
        let filter = match self.filter_precision {
            FilterPrecision::F32 => {
                CloudFilter::new(self.rt, self.cfg.policy.redundancy_threshold)
            }
            FilterPrecision::I8 => CloudFilter::with_precision(
                self.rt,
                self.cfg.policy.redundancy_threshold,
                FilterPrecision::I8,
                self.quant_pool.clone(),
            ),
        };
        let (kept, redundant) = filter.filter(tiles)?;
        let n_filtered = redundant.len();
        // redundant tiles are simply dropped (their GT is lost — the
        // communication/accuracy trade the paper accepts); their buffers
        // go straight back to the tile pool
        drop(redundant);

        let mut batcher = Batcher::new(self.rt.max_batch(), self.cfg.engine.batch_max_wait_s);
        for t in kept {
            batcher.push(t, 0.0);
        }
        let mut processed: Vec<ProcessedTile> = Vec::new();
        let mut wall = 0.0;
        // queue delays land in one reused vec (this facade discards them;
        // latency-aware callers read them between pops)
        let mut delays = Vec::with_capacity(self.rt.max_batch());
        while let Some(batch) = batcher.pop(0.0, true, &mut delays) {
            let (dets, best_obj, w) = self.infer(self.onboard_model, &batch)?;
            wall += w;
            for ((tile, onboard_dets), best) in batch.into_iter().zip(dets).zip(best_obj) {
                let fate = route(&self.policy, &onboard_dets, best, router_stats);
                processed.push(ProcessedTile {
                    tile,
                    fate,
                    onboard_dets,
                    ground_dets: None,
                    best_objectness: best,
                });
            }
        }
        Ok((processed, n_filtered, wall))
    }

    /// Ground half: re-inference (HeavyDet) for offloaded tiles.  Returns
    /// the PJRT wallclock spent.
    pub fn ground_scene(&self, processed: &mut [ProcessedTile]) -> Result<f64> {
        let offload_idx: Vec<usize> = processed
            .iter()
            .enumerate()
            .filter(|(_, p)| p.fate == TileFate::Offloaded)
            .map(|(i, _)| i)
            .collect();
        if offload_idx.is_empty() {
            return Ok(0.0);
        }
        let off_tiles: Vec<Tile> =
            offload_idx.iter().map(|&i| processed[i].tile.clone()).collect();
        let (gdets, _, wall) = self.infer(Model::Heavy, &off_tiles)?;
        for (&i, d) in offload_idx.iter().zip(gdets) {
            processed[i].ground_dets = Some(d);
        }
        Ok(wall)
    }

    /// Process one scene through split → filter → batch → onboard → route
    /// → ground.  Ground inference runs immediately (the contact-window
    /// dynamics are layered on by [`super::constellation`] and the orbital
    /// examples via [`super::downlink`]).
    pub fn process_scene(
        &self,
        scene: &crate::data::Scene,
        router_stats: &mut RouterStats,
    ) -> Result<(Vec<ProcessedTile>, usize, f64)> {
        let (mut processed, n_filtered, mut wall) = self.onboard_scene(scene, router_stats)?;
        wall += self.ground_scene(&mut processed)?;
        Ok((processed, n_filtered, wall))
    }

    /// Full scenario: `n_scenes` captures of a dataset `version`,
    /// processed sequentially.  This is the reference facade the staged
    /// engine ([`super::engine::StagedEngine`]) must match bit-for-bit.
    pub fn run_scenario(&self, version: Version, n_scenes: usize) -> Result<ScenarioResult> {
        let mut gen = self.scene_gen(version);
        let mut acc = ScenarioAccumulator::new(&self.cfg, self.rt.manifest.classes);
        for _ in 0..n_scenes {
            let scene = gen.capture();
            let mut router = RouterStats::default();
            let (processed, n_filtered, wall) = self.process_scene(&scene, &mut router)?;
            let n_scene_tiles = (scene.width / self.cfg.fragment_px)
                * (scene.height / self.cfg.fragment_px);
            acc.add_scene(&router, scene.size_bytes(), n_scene_tiles, &processed, n_filtered, wall);
        }
        Ok(acc.finish(version, self.cfg.fragment_px))
    }

    /// Convenience: run the scenario on the staged concurrent engine with
    /// the config's engine section.
    pub fn run_scenario_staged(&self, version: Version, n_scenes: usize) -> Result<ScenarioResult> {
        super::engine::StagedEngine::new(self).run_scenario(version, n_scenes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.scene_cells = 4; // 256x256 scenes: fast tests
        cfg
    }

    #[test]
    fn scenario_conserves_tiles() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 2).unwrap();
        assert_eq!(
            r.tiles_total,
            r.tiles_filtered + r.router.onboard_final as usize + r.router.offloaded as usize
        );
    }

    #[test]
    fn v1_filter_rate_near_90pct() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V1, 4).unwrap();
        assert!((0.75..1.0).contains(&r.filter_rate()), "rate {}", r.filter_rate());
    }

    #[test]
    fn collaborative_beats_inorbit() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 6).unwrap();
        assert!(
            r.map_collab > r.map_inorbit,
            "collab {} <= inorbit {}",
            r.map_collab,
            r.map_inorbit
        );
    }

    #[test]
    fn data_reduction_substantial() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V1, 4).unwrap();
        assert!(r.data_reduction() > 0.6, "reduction {}", r.data_reduction());
        assert!(r.collab_bytes < r.bentpipe_bytes);
    }

    #[test]
    fn energy_share_in_plausible_band() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 3).unwrap();
        assert!((0.05..0.25).contains(&r.energy_compute_share), "{}", r.energy_compute_share);
    }

    #[test]
    fn reports_match_headline_maps() {
        // satellite fix: each evaluator report is computed once; the
        // headline maps must be the cached reports' maps.
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 2).unwrap();
        assert_eq!(r.map_inorbit, r.report_inorbit.map);
        assert_eq!(r.map_collab, r.report_collab.map);
    }

    #[test]
    fn timing_config_drives_duty_cycle() {
        let Some(rt) = rt() else { return };
        let mut cfg = small_cfg();
        cfg.timing.scene_period_floor_s = 300.0; // much idler satellite
        let idle = Pipeline::new(&rt, cfg).run_scenario(Version::V2, 2).unwrap();
        let busy = Pipeline::new(&rt, small_cfg()).run_scenario(Version::V2, 2).unwrap();
        assert!(idle.compute_duty < busy.compute_duty, "{} vs {}", idle.compute_duty, busy.compute_duty);
    }
}
