//! End-to-end collaborative-inference pipeline (Fig 5) + evaluation.
//!
//! `run_scenario` reproduces the paper's case-study measurements for one
//! dataset version: filter rate (Fig 6), in-orbit vs collaborative mAP
//! (Fig 7), downlinked-byte accounting (the 90% headline), router stats,
//! and duty-cycled energy (Tables 2–3 + the 17% headline).

use anyhow::Result;

use crate::config::Config;
use crate::data::{split_scene, SceneGen, Tile, Version};
use crate::detect::{decode_rows, nms, Detection, Evaluator, MapReport};
use crate::energy::EnergyMeter;
use crate::runtime::{Model, Runtime};

use super::cloudfilter::CloudFilter;
use super::router::{route, RouterPolicy, RouterStats};
use super::TileFate;

/// Modeled onboard service time per tile (Raspberry-Pi-class YOLO-tiny;
/// drives energy duty cycles and orbital-time latency, not wallclock).
pub const ONBOARD_S_PER_TILE: f64 = 0.65;
/// Ground GPU-class service time per tile.
pub const GROUND_S_PER_TILE: f64 = 0.05;
/// Per-tile header bytes accompanying compact results.
pub const RESULT_HEADER_BYTES: u64 = 8;

/// One processed tile with everything the ground segment ends up knowing.
pub struct ProcessedTile {
    pub tile: Tile,
    pub fate: TileFate,
    pub onboard_dets: Vec<Detection>,
    /// Present for offloaded tiles once ground inference ran.
    pub ground_dets: Option<Vec<Detection>>,
    pub best_objectness: f32,
}

#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub version: &'static str,
    pub fragment_px: usize,
    pub scenes: usize,
    pub tiles_total: usize,
    pub tiles_filtered: usize,
    pub router: RouterStats,
    /// mAP if the satellite's own results were final everywhere.
    pub map_inorbit: f64,
    /// mAP of the collaborative system (Fig 7's right bars).
    pub map_collab: f64,
    pub report_inorbit: MapReport,
    pub report_collab: MapReport,
    /// Bytes a bent-pipe would downlink (all raw scenes).
    pub bentpipe_bytes: u64,
    /// Bytes the collaborative system downlinks (results + offload images).
    pub collab_bytes: u64,
    pub mean_confidence: f64,
    /// Onboard compute duty cycle over the scenario's virtual time.
    pub compute_duty: f64,
    /// Energy: compute share of total (17% headline).
    pub energy_compute_share: f64,
    /// Wallclock spent in PJRT execution (perf metric).
    pub wall_infer_s: f64,
}

impl ScenarioResult {
    pub fn filter_rate(&self) -> f64 {
        self.tiles_filtered as f64 / self.tiles_total.max(1) as f64
    }

    pub fn data_reduction(&self) -> f64 {
        1.0 - self.collab_bytes as f64 / self.bentpipe_bytes.max(1) as f64
    }

    pub fn accuracy_improvement(&self) -> f64 {
        if self.map_inorbit <= 0.0 {
            0.0
        } else {
            (self.map_collab - self.map_inorbit) / self.map_inorbit
        }
    }
}

pub struct Pipeline<'rt> {
    rt: &'rt Runtime,
    pub cfg: Config,
    pub policy: RouterPolicy,
    pub onboard_model: Model,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: Config) -> Pipeline<'rt> {
        let policy = RouterPolicy {
            confidence_threshold: cfg.policy.confidence_threshold,
            empty_objectness: 0.25,
        };
        Pipeline { rt, cfg, policy, onboard_model: Model::Tiny }
    }

    /// Run one detector over tiles; returns (per-tile NMS'd detections,
    /// per-tile best objectness, wallclock seconds).
    pub fn infer(&self, model: Model, tiles: &[Tile]) -> Result<(Vec<Vec<Detection>>, Vec<f32>, f64)> {
        let m = &self.rt.manifest;
        let cols = m.grid * m.grid * m.head_d;
        let max_b = self.rt.max_batch();
        let mut dets = Vec::with_capacity(tiles.len());
        let mut best_obj = Vec::with_capacity(tiles.len());
        let mut wall = 0.0;
        for chunk in tiles.chunks(max_b) {
            let mut input = Vec::with_capacity(chunk.len() * m.tile * m.tile * 3);
            for t in chunk {
                input.extend_from_slice(&t.pixels);
            }
            let t0 = std::time::Instant::now();
            let rows = self.rt.execute(model, chunk.len(), &input)?;
            wall += t0.elapsed().as_secs_f64();
            for i in 0..chunk.len() {
                let r = &rows[i * cols..(i + 1) * cols];
                let obj = r
                    .chunks_exact(m.head_d)
                    .map(|c| c[4])
                    .fold(f32::MIN, f32::max);
                best_obj.push(obj);
                let raw = decode_rows(r, m.head_d, self.cfg.policy.score_threshold);
                dets.push(nms(raw, self.cfg.policy.nms_iou));
            }
        }
        Ok((dets, best_obj, wall))
    }

    /// Process one scene through split → filter → onboard → route →
    /// ground.  Ground inference runs immediately (the contact-window
    /// dynamics are layered on by the orbital examples via
    /// [`super::downlink`]).
    pub fn process_scene(
        &self,
        scene: &crate::data::Scene,
        router_stats: &mut RouterStats,
    ) -> Result<(Vec<ProcessedTile>, usize, f64)> {
        let tiles = split_scene(scene, self.cfg.fragment_px);
        let filter = CloudFilter::new(self.rt, self.cfg.policy.redundancy_threshold);
        let (kept, redundant) = filter.filter(tiles)?;
        let n_filtered = redundant.len();

        let (dets, best_obj, mut wall) = self.infer(self.onboard_model, &kept)?;
        let mut processed: Vec<ProcessedTile> = kept
            .into_iter()
            .zip(dets)
            .zip(best_obj)
            .map(|((tile, onboard_dets), best)| {
                let fate = route(&self.policy, &onboard_dets, best, router_stats);
                ProcessedTile { tile, fate, onboard_dets, ground_dets: None, best_objectness: best }
            })
            .collect();

        // ground re-inference for offloaded tiles
        let offload_idx: Vec<usize> = processed
            .iter()
            .enumerate()
            .filter(|(_, p)| p.fate == TileFate::Offloaded)
            .map(|(i, _)| i)
            .collect();
        if !offload_idx.is_empty() {
            let off_tiles: Vec<Tile> =
                offload_idx.iter().map(|&i| processed[i].tile.clone()).collect();
            let (gdets, _, w) = self.infer(Model::Heavy, &off_tiles)?;
            wall += w;
            for (&i, d) in offload_idx.iter().zip(gdets) {
                processed[i].ground_dets = Some(d);
            }
        }
        // redundant tiles are simply dropped (their GT is lost — the
        // communication/accuracy trade the paper accepts)
        drop(redundant);
        Ok((processed, n_filtered, wall))
    }

    /// Full scenario: `n_scenes` captures of a dataset `version`.
    pub fn run_scenario(&self, version: Version, n_scenes: usize) -> Result<ScenarioResult> {
        let mut gen = SceneGen::new(
            self.cfg.seed ^ version.name().len() as u64,
            version.spec(),
            self.cfg.scene_cells,
            self.cfg.scene_cells,
        );
        let mut router_stats = RouterStats::default();
        let mut ev_inorbit = Evaluator::new(self.rt.manifest.classes, 0.5);
        let mut ev_collab = Evaluator::new(self.rt.manifest.classes, 0.5);
        let mut tiles_total = 0;
        let mut tiles_filtered = 0;
        let mut bentpipe_bytes = 0u64;
        let mut collab_bytes = 0u64;
        let mut conf_sum = 0.0;
        let mut conf_n = 0u64;
        let mut wall_infer = 0.0;
        let mut onboard_busy_s = 0.0;
        let mut virtual_s = 0.0;
        let mut energy = EnergyMeter::new();

        for _ in 0..n_scenes {
            let scene = gen.capture();
            bentpipe_bytes += scene.size_bytes();
            let n_scene_tiles = (scene.width / self.cfg.fragment_px)
                * (scene.height / self.cfg.fragment_px);
            tiles_total += n_scene_tiles;
            let (processed, n_filtered, wall) = self.process_scene(&scene, &mut router_stats)?;
            wall_infer += wall;
            tiles_filtered += n_filtered;

            for p in &processed {
                // evaluation — in-orbit: onboard detections everywhere
                ev_inorbit.add_image(&p.onboard_dets, &p.tile.gt);
                // collaborative: ground detections replace offloaded tiles
                match (&p.fate, &p.ground_dets) {
                    (TileFate::Offloaded, Some(g)) => ev_collab.add_image(g, &p.tile.gt),
                    _ => ev_collab.add_image(&p.onboard_dets, &p.tile.gt),
                }
                // byte accounting
                match p.fate {
                    TileFate::OnboardFinal => {
                        collab_bytes += RESULT_HEADER_BYTES
                            + Detection::WIRE_BYTES * p.onboard_dets.len() as u64;
                    }
                    TileFate::Offloaded => {
                        collab_bytes += p.tile.raw_bytes();
                    }
                    TileFate::Filtered => unreachable!("filtered tiles are not processed"),
                }
                if let Some(best) = p.onboard_dets.first() {
                    conf_sum += best.score as f64;
                    conf_n += 1;
                }
            }

            // virtual-time + energy accounting for this scene: the
            // satellite is busy ONBOARD_S_PER_TILE per kept tile; capture
            // and filtering are folded into a per-scene constant.
            let busy = processed.len() as f64 * ONBOARD_S_PER_TILE + 2.0;
            let scene_period = busy.max(30.0); // at most one scene per 30 s
            onboard_busy_s += busy;
            virtual_s += scene_period;
            energy.advance(scene_period, busy / scene_period, 0.05, 0.1);
        }

        Ok(ScenarioResult {
            version: version.name(),
            fragment_px: self.cfg.fragment_px,
            scenes: n_scenes,
            tiles_total,
            tiles_filtered,
            router: router_stats,
            map_inorbit: ev_inorbit.report().map,
            map_collab: ev_collab.report().map,
            report_inorbit: ev_inorbit.report(),
            report_collab: ev_collab.report(),
            bentpipe_bytes,
            collab_bytes,
            mean_confidence: if conf_n == 0 { 0.0 } else { conf_sum / conf_n as f64 },
            compute_duty: onboard_busy_s / virtual_s.max(1e-9),
            energy_compute_share: energy.compute_share(),
            wall_infer_s: wall_infer,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.scene_cells = 4; // 256x256 scenes: fast tests
        cfg
    }

    #[test]
    fn scenario_conserves_tiles() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 2).unwrap();
        assert_eq!(
            r.tiles_total,
            r.tiles_filtered + r.router.onboard_final as usize + r.router.offloaded as usize
        );
    }

    #[test]
    fn v1_filter_rate_near_90pct() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V1, 4).unwrap();
        assert!((0.75..1.0).contains(&r.filter_rate()), "rate {}", r.filter_rate());
    }

    #[test]
    fn collaborative_beats_inorbit() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 6).unwrap();
        assert!(
            r.map_collab > r.map_inorbit,
            "collab {} <= inorbit {}",
            r.map_collab,
            r.map_inorbit
        );
    }

    #[test]
    fn data_reduction_substantial() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V1, 4).unwrap();
        assert!(r.data_reduction() > 0.6, "reduction {}", r.data_reduction());
        assert!(r.collab_bytes < r.bentpipe_bytes);
    }

    #[test]
    fn energy_share_in_plausible_band() {
        let Some(rt) = rt() else { return };
        let p = Pipeline::new(&rt, small_cfg());
        let r = p.run_scenario(Version::V2, 3).unwrap();
        assert!((0.05..0.25).contains(&r.energy_compute_share), "{}", r.energy_compute_share);
    }
}
