//! Constellation-scale scenario runner: N satellites, one ground segment.
//!
//! Each satellite runs a staged pipeline on its own mission
//! [`crate::sim::Timeline`]:
//! a capture source thread feeds onboard stage workers (split · filter ·
//! batch · TinyDet · route — the same [`super::engine`] stage bodies the
//! single-satellite engine runs), so capture, filtering, and onboard
//! inference overlap *within* each satellite, while a driver loop
//! re-sequences scenes into capture order and advances the virtual
//! mission clock one scene period at a time.  Ground round-trips are
//! asynchronous completions on that timeline: delivered imagery is
//! dispatched to the shared ground segment and the driver keeps
//! capturing; replies fold in whenever they land.
//!
//! With `power.enabled`, the driver owns a per-satellite
//! [`PowerState`] (solar array + battery + governor from
//! [`crate::power`]) and consults its verdict at each scene's virtual
//! capture time: below `soc_defer` downlink drains are deferred to the
//! next window (transmitter off, elapsed window time passes unused)
//! and the router threshold tightens on top of the adaptive path's
//! `effective()`; below `soc_critical` the capture is shed outright —
//! camera and compute idle for that period, nothing queued or folded.
//! SoC is integrated per scene period from the timeline's sunlit
//! seconds minus the same duty-cycled load the energy meter charges,
//! so verdicts are deterministic functions of mission time.
//!
//! Every satellite queues results and offloaded imagery in a
//! [`DownlinkQueue`] whose drains are gated by its *own* contact windows
//! — handed out incrementally by the timeline so no window airtime is
//! ever double-spent — and shares a single ground-segment worker that
//! serves HeavyDet re-inference for every satellite (serialized by the
//! runtime's per-model execution lock — exactly one ground GPU).  Energy
//! duty cycles are *derived*, not assumed: comm duty from actual
//! [`Link`] airtime inside contact windows, camera duty from capture
//! events, compute duty from onboard busy time.  With
//! `policy.adaptive`, the router consults downlink backlog and recent
//! loss rate at each scene's virtual capture time and tightens/relaxes
//! the offload threshold (the weak-network and MakerSat-incident
//! regimes from [`crate::link::LossProfile`]).
//!
//! Scenes fold through the same [`ScenarioAccumulator`] as the
//! single-satellite paths, in capture order, with one honest
//! difference: an offloaded tile whose imagery never survives a contact
//! window is evaluated with its onboard detections (the collaborative
//! gain only materializes for delivered tiles).  Byte accounting keeps
//! both views: the scenario fold's `collab_bytes` stays nominal (bytes
//! *queued* for downlink, same as single-satellite runs) while
//! [`SatelliteReport::downlink`] records what the lossy windowed link
//! actually delivered — and, since the per-head failure accounting,
//! what it dropped (`bytes_dropped`).  With `constellation.ideal_contact`
//! and a lossless link, a 1-satellite run reproduces `run_scenario`
//! exactly (`tests/constellation_parity.rs`).
//!
//! With `federated.enabled`, §3.4's FederatedLearning runs as a
//! first-class mission workload on the same timelines: each satellite
//! owns a non-IID shard (seeded per plane) and a
//! [`FedScheduler`] firing local-training rounds every
//! `round_interval_s` of virtual time.  A round consults the power
//! subsystem first — below `federated.min_soc` it is skipped and
//! reported (`rounds_skipped_power`), at or above it the satellite
//! charges the training burst against its battery and queues the round's
//! weights (`ItemKind::Weights`) on its own [`DownlinkQueue`], where
//! they contend with imagery for pass airtime.  After the mission the
//! fleet aggregation replays the recorded participant sets with
//! partial-participation FedAvg (`sedna::federated::train_schedule`):
//! each round averages whichever subset trained, and an empty round
//! keeps the previous global.
//!
//! Cluster/sedna bookkeeping mirrors the paper's control plane: every
//! satellite registers as an Edge node and heartbeats during contact
//! windows, and the whole run is scheduled as a Sedna `JointInference`
//! task whose per-worker phases aggregate into the report.
//!
//! This runner is the *small-N facade*: it spawns a capture thread plus
//! onboard workers per satellite, which tops out at tens of sats.  The
//! event-driven fleet engine ([`super::fleet::run_fleet`]) produces the
//! same [`ConstellationReport`] from sharded virtual-time state
//! machines and is the path that scales to 10k–100k satellites
//! (`tests/fleet_parity.rs` pins the two together).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::registry::Registry as NodeRegistry;
use crate::cluster::{NodeId, NodeRole};
use crate::config::Config;
use crate::data::{Tile, Version};
use crate::detect::Detection;
use crate::link::{Link, LinkConfig, LinkStats};
use crate::orbit::{ContactWindow, StationNetwork};
use crate::power::{PowerState, PowerVerdict};
use crate::runtime::{Model, Runtime};
use crate::sedna::federated::{self, FedScheduler, RoundDecision};
use crate::sedna::{GlobalManager, LocalController, TaskKind, TaskPhase, TaskSpec};
use crate::sim::{apply_seu, scene_timing, ChaosStats, DutyCycles, FaultPlan};
use crate::telemetry::trace::{SatTracer, SpanKind, TraceLog, TracePayload, TraceSink};
use crate::telemetry::{per_node_gauges_enabled, Counter, Gauge, Registry};

use super::downlink::{Delivered, DownlinkItem, DownlinkQueue, DownlinkStats, ItemKind};
use super::engine::{trace_onboard, worker_loop, Envelope, OnboardDone, OnboardStage, SceneJob};
use super::layout::{mission_timeline, plane_satellite, station_network};
use super::pipeline::{
    Pipeline, ProcessedTile, ScenarioAccumulator, ScenarioResult, RESULT_HEADER_BYTES,
};
use super::router::{reroute, LinkSnapshot, LossTracker, RouterStats};
use super::TileFate;

/// Downlink tag encoding: scene index * stride + tile index.
pub(super) const TAG_STRIDE: u64 = 1_000_000;
/// Tag base for federated weight items (tag = base + round index),
/// disjoint from the scene/tile tag space.
pub(super) const FED_TAG_BASE: u64 = u64::MAX - TAG_STRIDE;

/// One satellite's share of the constellation run.
pub struct SatelliteReport {
    /// Constellation plane index (reports are ordered by this).
    pub index: usize,
    pub name: String,
    /// Full scenario metrics (same fold as single-satellite runs).
    /// `result.collab_bytes` is the *nominal* accounting — what the
    /// system queued for downlink; `downlink` below holds what actually
    /// crossed the lossy windowed link, so under heavy loss
    /// `result.collab_bytes > downlink.total_bytes()`.
    pub result: ScenarioResult,
    pub downlink: DownlinkStats,
    pub link: LinkStats,
    pub windows: usize,
    pub contact_s: f64,
    /// Sunlit seconds over the mission horizon (the timeline's
    /// illumination event source; horizon minus this is eclipse time).
    pub sunlit_s: f64,
    /// SoC trajectory + governor stats (`result.power` carries the same
    /// data; duplicated here so fleet tooling can read power health
    /// without unpacking the scenario fold).  `None` when `power.enabled`
    /// is off.
    pub power: Option<crate::power::PowerStats>,
    /// Federated round accounting — per-round participation plus the
    /// counters that must reconcile (`rounds_completed +
    /// rounds_skipped_power + rounds_skipped_crash ==
    /// rounds_scheduled`).  `None` when `federated.enabled` is off.
    pub federated: Option<federated::FederatedStats>,
    /// Injected-fault ledger for this satellite's seeded fault plan:
    /// scenes lost to crashes, blacked-out drain slices, SEU strikes,
    /// suppressed heartbeats.  Reconciles with the scene fold
    /// (`result` scenes + shed + `lost_to_crash` == scenes) and with
    /// the ARQ counters in `link`.  `None` when `chaos.enabled` is off.
    pub chaos: Option<ChaosStats>,
}

pub struct ConstellationReport {
    pub satellites: Vec<SatelliteReport>,
    pub tiles_total: usize,
    /// Wallclock for the whole constellation run.
    pub wall_s: f64,
    /// Sedna JointInference task reached Completed.
    pub task_completed: bool,
    /// Fleet FedAvg outcome over the satellites' recorded participant
    /// sets; `None` when `federated.enabled` is off.
    pub federated: Option<federated::FleetTrainingReport>,
    /// Rendered per-stage telemetry (queue waits, service times, depths).
    pub telemetry: String,
    /// Mission flight-recorder log, merged deterministically at the join
    /// barrier from the per-shard rings; `None` when `trace.enabled` is
    /// off.
    pub trace: Option<TraceLog>,
}

impl ConstellationReport {
    /// Aggregate throughput across all satellites.
    pub fn aggregate_tiles_per_s(&self) -> f64 {
        self.tiles_total as f64 / self.wall_s.max(1e-9)
    }
}

/// HeavyDet work order for the shared ground segment.
struct GroundRequest {
    tiles: Vec<Tile>,
    reply: Sender<Result<(Vec<Vec<Detection>>, f64)>>,
    at: Instant,
}

/// A ground round-trip in flight: which (scene, tile) slots the reply
/// will fill, and the channel it arrives on.  The driver polls these
/// between scenes instead of blocking on each send.
struct GroundInflight {
    pairs: Vec<(usize, usize)>,
    rx: Receiver<Result<(Vec<Vec<Detection>>, f64)>>,
}

/// A scene waiting for its offloaded tiles to clear the downlink.
/// Shared with the event-driven fleet engine (`super::fleet`), whose
/// machines keep the same per-scene ledger.
pub(super) struct PendingScene {
    pub(super) bentpipe_bytes: u64,
    pub(super) n_scene_tiles: usize,
    pub(super) processed: Vec<ProcessedTile>,
    pub(super) n_filtered: usize,
    pub(super) wall: f64,
    pub(super) router: RouterStats,
    /// Duty cycles observed over this scene's period on the mission
    /// timeline (comm from link airtime, camera from the capture event).
    pub(super) duties: DutyCycles,
    /// Offloaded tiles not yet ground-inferred (delivery pending).
    pub(super) outstanding: usize,
}

/// Run `cfg.constellation.satellites` satellites against one ground
/// segment.  Per-satellite seeds, orbital planes, and contact windows
/// differ; the scene workload per satellite is
/// `cfg.constellation.scenes_per_satellite`.
pub fn run_constellation(rt: &Runtime, cfg: &Config, version: Version) -> Result<ConstellationReport> {
    cfg.energy.validate()?;
    cfg.power.validate()?;
    cfg.federated.validate()?;
    cfg.chaos.validate()?;
    cfg.validate_cross()?;
    anyhow::ensure!(!cfg.stations.is_empty(), "stations must list at least one ground station");
    let n_sats = cfg.constellation.satellites.max(1);
    let scenes = cfg.constellation.scenes_per_satellite;
    let metrics = Registry::new();
    let net = station_network(cfg);

    // control plane: node registry + Sedna JointInference task
    let ground_node = NodeId::new("ground-1");
    let sat_nodes: Vec<NodeId> = (0..n_sats).map(|i| NodeId::new(format!("sat-{i}"))).collect();
    let registry = Mutex::new(NodeRegistry::new(60_000, 600_000));
    {
        let mut reg = registry.lock().unwrap();
        reg.register(ground_node.clone(), NodeRole::Cloud, 64_000, 262_144, 0);
        for id in &sat_nodes {
            reg.register(id.clone(), NodeRole::Edge, 4_000, 8_192, 0);
        }
    }
    let gm = Mutex::new(GlobalManager::new());
    let task = "joint-inference";
    {
        let mut workers = sat_nodes.clone();
        workers.push(ground_node.clone());
        gm.lock().unwrap().create(TaskSpec {
            name: task.into(),
            kind: TaskKind::JointInference,
            workers,
            params: BTreeMap::new(),
        })?;
    }

    // flight recorder: one single-writer ring per satellite thread here
    // (the fleet engine uses one per scheduler shard); merge() after the
    // join produces the same (time, sat, kind)-sorted stream either way
    let trace_sink =
        cfg.trace.enabled.then(|| Arc::new(TraceSink::new(n_sats, cfg.trace.ring_cap)));
    let per_node = per_node_gauges_enabled(n_sats, cfg.telemetry.per_node_limit);

    let (ground_tx, ground_rx) = channel::<GroundRequest>();
    let t0 = Instant::now();
    let mut reports: Vec<SatelliteReport> = Vec::with_capacity(n_sats);

    std::thread::scope(|s| -> Result<()> {
        // shared ground segment: one HeavyDet server for all satellites
        let ground_pipe = Pipeline::new(rt, cfg.clone());
        let metrics_ref = &metrics;
        let ground = s.spawn(move || {
            let wait = metrics_ref.histogram("constellation.ground.queue_wait_s");
            let svc = metrics_ref.histogram("constellation.ground.service_s");
            let served = metrics_ref.counter("constellation.ground.tiles");
            let depth = metrics_ref.gauge("constellation.ground.queue_depth");
            while let Ok(req) = ground_rx.recv() {
                depth.dec();
                wait.observe_secs(req.at.elapsed().as_secs_f64());
                let t = Instant::now();
                let out = ground_pipe
                    .infer(Model::Heavy, &req.tiles)
                    .map(|(dets, _, wall)| (dets, wall));
                svc.observe_secs(t.elapsed().as_secs_f64());
                served.add(req.tiles.len() as u64);
                let _ = req.reply.send(out);
            }
        });

        let mut handles = Vec::with_capacity(n_sats);
        for i in 0..n_sats {
            let node = sat_nodes[i].clone();
            let tx = ground_tx.clone();
            let registry = &registry;
            let gm = &gm;
            let net = &net;
            let tracer = trace_sink.as_ref().map(|t| t.tracer(i, i));
            handles.push(s.spawn(move || -> Result<SatelliteReport> {
                run_satellite(
                    rt, cfg, version, i, node, tx, registry, gm, task, net, metrics_ref, scenes,
                    tracer, per_node,
                )
            }));
        }
        drop(ground_tx); // ground loop ends when the last satellite hangs up

        let mut first_err = None;
        for h in handles {
            match h.join().map_err(|_| anyhow!("satellite thread panicked"))? {
                Ok(r) => reports.push(r),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        ground.join().map_err(|_| anyhow!("ground thread panicked"))?;
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    // zero-copy path health: marshalling scratch is pooled on the shared
    // runtime, so its alloc count is the fleet's peak marshal concurrency
    metrics
        .gauge("constellation.runtime.scratch_allocs")
        .set(rt.scratch_stats().allocs as i64);

    gm.lock().unwrap().report(task, &ground_node, TaskPhase::Completed)?;
    let task_completed =
        gm.lock().unwrap().get(task).map(|(_, st)| st.phase) == Some(TaskPhase::Completed);
    reports.sort_by_key(|r| r.index);
    let tiles_total = reports.iter().map(|r| r.result.tiles_total).sum();

    set_fleet_power_gauges(&metrics, &reports);
    let fed_report = fleet_fed_report(cfg, &reports, &metrics);

    Ok(ConstellationReport {
        satellites: reports,
        tiles_total,
        wall_s: t0.elapsed().as_secs_f64(),
        task_completed,
        federated: fed_report,
        telemetry: metrics.render(),
        trace: trace_sink.map(|s| s.merge()),
    })
}

/// Fleet-level power gauges, aggregated deterministically at the join
/// barrier from the index-sorted reports.  Per-satellite SoC stays on
/// its suffixed `power.soc_pct.<node>` gauge; these two summarize the
/// fleet without any thread racing to write last (the
/// last-write-wins hazard a single shared gauge would have).
pub(super) fn set_fleet_power_gauges(metrics: &Registry, reports: &[SatelliteReport]) {
    let socs: Vec<i64> = reports
        .iter()
        .filter_map(|r| r.power.as_ref().map(|p| (p.final_soc_frac * 100.0).round() as i64))
        .collect();
    if socs.is_empty() {
        return;
    }
    metrics.gauge("power.soc_pct.fleet_min").set(socs.iter().copied().min().unwrap_or(0));
    metrics
        .gauge("power.soc_pct.fleet_mean")
        .set(socs.iter().sum::<i64>() / socs.len() as i64);
    // full fleet distribution in fixed space — the view that survives
    // past the per-node gauge cutoff
    let dig = metrics.digest("power.soc_pct");
    for s in &socs {
        dig.observe(*s);
    }
}

/// Fleet aggregation: replay the recorded per-round participant sets
/// with partial-participation FedAvg.  The satellites already paid the
/// schedule's costs in mission time (training energy, weight airtime);
/// the weight arithmetic itself has no feedback into mission dynamics,
/// so running it once after the satellites join keeps the round
/// sequence strictly ordered without cross-satellite blocking — this is
/// the round-barrier aggregation both the thread driver and the fleet
/// engine share.  `None` when `federated.enabled` is off.
pub(super) fn fleet_fed_report(
    cfg: &Config,
    reports: &[SatelliteReport],
    metrics: &Registry,
) -> Option<federated::FleetTrainingReport> {
    cfg.federated.enabled.then(|| {
        let n_sats = cfg.constellation.satellites.max(1);
        let fed = &cfg.federated;
        let shards = federated::fleet_shards(n_sats, fed.samples_per_node, fed.dim, cfg.seed);
        let test = federated::make_shard(cfg.seed + 10_000, 2000, fed.dim, 0.0);
        let rounds = FedScheduler::rounds_in(cfg.constellation.horizon_s, fed.round_interval_s);
        let participation: Vec<&[bool]> = reports
            .iter()
            .map(|r| {
                r.federated.as_ref().map(|f| f.participated.as_slice()).unwrap_or(&[])
            })
            .collect();
        let rep = federated::train_schedule(
            &shards,
            &test,
            rounds,
            |r, w| participation[w].get(r).copied().unwrap_or(false),
            fed.epochs,
            fed.lr,
            fed.dim,
            cfg.seed,
        );
        metrics
            .gauge("federated.accuracy_pct")
            .set((rep.final_accuracy() * 100.0).round() as i64);
        // per-satellite round participation as fixed-size digests — the
        // fleet view once `.<node>` counters pass the cardinality cutoff
        let rounds_dig = metrics.digest("federated.rounds");
        let skipped_dig = metrics.digest("federated.skipped_power");
        for r in reports {
            if let Some(f) = &r.federated {
                rounds_dig.observe(f.rounds_completed as i64);
                skipped_dig.observe(f.rounds_skipped_power as i64);
            }
        }
        rep
    })
}

/// Apply federated round decisions: a participating round queues its
/// weights for uplink (contending with imagery for window airtime) and
/// charges the training burst to the battery and the H2 energy ledger;
/// a skipped round only counts.  Shared by the scene loop, the mission
/// tail, and the fleet engine's event handlers.
pub(super) fn apply_fed_rounds(
    decisions: Vec<RoundDecision>,
    wire_bytes: u64,
    train_s: f64,
    queue: &mut DownlinkQueue,
    power: &mut Option<PowerState>,
    acc: &mut ScenarioAccumulator,
    counters: &Option<(std::sync::Arc<Counter>, std::sync::Arc<Counter>)>,
    tracer: Option<&SatTracer>,
) {
    for d in decisions {
        if let Some(tr) = tracer {
            // a participating round spans its training burst; a skipped
            // round is an instant with the verdict in the payload
            let t_end = if d.participated { d.due_s + train_s } else { d.due_s };
            tr.span(
                SpanKind::TrainingRound,
                d.due_s,
                t_end,
                TracePayload::Verdict(d.trace_verdict()),
            );
        }
        if d.participated {
            queue.push(DownlinkItem {
                kind: ItemKind::Weights,
                bytes: wire_bytes,
                ready_at: d.due_s + train_s,
                tag: FED_TAG_BASE + d.round as u64,
            });
            if let Some(p) = power.as_mut() {
                p.charge_training(train_s);
            }
            acc.add_training(train_s);
        }
        if let Some((completed, skipped)) = counters {
            if d.participated {
                completed.inc();
            } else {
                skipped.inc();
            }
        }
    }
}

/// Poll the federated scheduler with the chaos crash gate when a fault
/// plan is live — rounds due while the satellite is dark are skipped as
/// their own class (`rounds_skipped_crash`).  With no plan this is
/// exactly [`FedScheduler::poll`].  Shared by both engines so the gate
/// cannot drift between them.
pub(super) fn poll_fed_gated(
    f: &mut FedScheduler,
    chaos: Option<&FaultPlan>,
    t: f64,
    soc: Option<f64>,
) -> Vec<RoundDecision> {
    match chaos {
        Some(c) => f.poll_gated(t, soc, |due| c.crashed_at(due)),
        None => f.poll(t, soc),
    }
}

/// The chaos gate for one drain slice, shared verbatim by both engines:
///
/// * satellite dark at AOS → the slice is blacked out (`None`): no
///   heartbeat, no drain, no per-head failure charge — from the
///   ground's point of view the pass never happens;
/// * registry dropout at AOS → the heartbeat is suppressed (only the
///   cloud-side belief degrades) but the drain proceeds;
/// * otherwise the heartbeat fires and, with a plan live, the drain
///   runs under the ARQ retry loop fed by the plan's frame-fault
///   stream, with rejected bytes recorded as a `FaultFrame` event.
///
/// With no plan this is exactly heartbeat + the traced drain — the
/// default-off bit-identity hinges on that branch staying bare.
#[allow(clippy::too_many_arguments)] // internal plumbing shared by both engines
pub(super) fn chaos_gated_drain(
    chaos: &mut Option<FaultPlan>,
    stats: &mut ChaosStats,
    queue: &mut DownlinkQueue,
    link: &mut Link,
    window: &ContactWindow,
    closes_pass: bool,
    tracer: Option<&SatTracer>,
    heartbeat: impl FnOnce(),
) -> Option<Vec<Delivered>> {
    let Some(c) = chaos.as_mut() else {
        heartbeat();
        return Some(queue.drain_window_sliced_traced(link, window, closes_pass, tracer));
    };
    if c.crashed_at(window.aos) {
        stats.slices_blacked_out += 1;
        stats.heartbeats_suppressed += 1;
        return None;
    }
    if c.dropout_at(window.aos) {
        stats.heartbeats_suppressed += 1;
        if let Some(tr) = tracer {
            tr.event(SpanKind::FaultDropout, window.aos, TracePayload::None);
        }
    } else {
        heartbeat();
    }
    let rejected_before = link.stats.bytes_rejected;
    let arq = c.arq;
    let got = queue.drain_window_sliced_chaos(link, window, closes_pass, tracer, &arq, &mut || {
        c.next_frame_fault()
    });
    let rejected = link.stats.bytes_rejected - rejected_before;
    if rejected > 0 {
        if let Some(tr) = tracer {
            tr.event(SpanKind::FaultFrame, window.los, TracePayload::Bytes(rejected));
        }
    }
    Some(got)
}

/// Apply one ground reply: fill the (scene, tile) slots it answers and
/// release those tiles' outstanding counts.
fn apply_ground_reply(
    pending: &mut BTreeMap<usize, PendingScene>,
    pairs: &[(usize, usize)],
    dets: Vec<Vec<Detection>>,
    wall: f64,
) {
    let wall_each = wall / pairs.len().max(1) as f64;
    for (&(sidx, tidx), d) in pairs.iter().zip(dets) {
        let scene = pending.get_mut(&sidx).expect("scene vanished mid-delivery");
        scene.processed[tidx].ground_dets = Some(d);
        scene.outstanding -= 1;
        scene.wall += wall_each;
    }
}

/// Collect completed ground round-trips.  Non-blocking between scenes
/// (the timeline keeps moving); blocking at end of mission, when nothing
/// is left to overlap with.
fn poll_ground(
    inflight: &mut Vec<GroundInflight>,
    pending: &mut BTreeMap<usize, PendingScene>,
    block: bool,
) -> Result<()> {
    let mut i = 0;
    while i < inflight.len() {
        let outcome = if block {
            Some(inflight[i].rx.recv().map_err(|_| anyhow!("ground segment hung up"))??)
        } else {
            match inflight[i].rx.try_recv() {
                Ok(r) => Some(r?),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => {
                    return Err(anyhow!("ground segment hung up"))
                }
            }
        };
        match outcome {
            Some((dets, wall)) => {
                let f = inflight.swap_remove(i);
                apply_ground_reply(pending, &f.pairs, dets, wall);
            }
            None => i += 1,
        }
    }
    Ok(())
}

/// Fold every leading scene whose offloads have all resolved, skipping
/// capture indices the governor shed (no scene exists there — the
/// camera never fired).  With `force`, outstanding offloads no longer
/// gate the fold — the end-of-mission path, where undelivered offloads
/// are evaluated with their onboard detections.
pub(super) fn fold_ready(
    pending: &mut BTreeMap<usize, PendingScene>,
    shed_idx: &mut BTreeSet<usize>,
    next_fold: &mut usize,
    acc: &mut ScenarioAccumulator,
    force: bool,
) {
    loop {
        if shed_idx.remove(next_fold) {
            *next_fold += 1;
        } else if pending.get(next_fold).map(|p| force || p.outstanding == 0).unwrap_or(false) {
            let p = pending.remove(next_fold).unwrap();
            acc.add_scene_observed(
                &p.router,
                p.bentpipe_bytes,
                p.n_scene_tiles,
                &p.processed,
                p.n_filtered,
                p.wall,
                p.duties,
            );
            *next_fold += 1;
        } else {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)] // internal plumbing fn, not API
fn run_satellite(
    rt: &Runtime,
    cfg: &Config,
    version: Version,
    index: usize,
    node: NodeId,
    ground_tx: Sender<GroundRequest>,
    registry: &Mutex<NodeRegistry>,
    gm: &Mutex<GlobalManager>,
    task: &str,
    net: &StationNetwork,
    metrics: &Registry,
    scenes: usize,
    tracer: Option<SatTracer>,
    per_node: bool,
) -> Result<SatelliteReport> {
    let mut lc = LocalController::new(node.clone());
    lc.start(task);
    gm.lock().unwrap().report(task, &node, TaskPhase::Running)?;

    // one orbital plane per satellite, phased around the constellation;
    // the timeline owns this satellite's contact windows + eclipse phases
    // (seeding + timeline construction shared with the fleet engine via
    // `coordinator::layout`)
    let sat = plane_satellite(cfg, index, &node.to_string());
    let horizon = cfg.constellation.horizon_s;
    let mut timeline = mission_timeline(cfg, &sat, net);

    let mut sat_cfg = cfg.clone();
    sat_cfg.seed = cfg.seed.wrapping_add(1 + index as u64 * 101);
    let pipeline = Pipeline::new(rt, sat_cfg);
    let gen = pipeline.scene_gen(version);
    let mut acc = ScenarioAccumulator::new(&pipeline.cfg, rt.manifest.classes);
    let mut queue = DownlinkQueue::new();
    let mut link = Link::new(LinkConfig::downlink(pipeline.cfg.loss()), pipeline.cfg.seed);
    let delivered_items = metrics.counter("constellation.downlink.items_delivered");
    let queue_depth = metrics.gauge("constellation.ground.queue_depth");

    // energy-aware power subsystem; `None` (the default) leaves every
    // driver decision exactly as the power-blind code path made it
    let mut power = cfg.power.enabled.then(|| PowerState::new(&cfg.power, &cfg.energy));
    // the SoC gauge is per-satellite (a fleet-shared gauge would be
    // last-write-wins across threads); fleet-level SoC is aggregated
    // deterministically at the join barrier instead
    // (`set_fleet_power_gauges` → power.soc_pct.fleet_min/fleet_mean).
    // The defer/shed counters sum correctly across the fleet and stay
    // shared.  Past the `telemetry.per_node_limit` cutoff the suffixed
    // gauge becomes a detached sink: call sites stay branch-free and
    // cardinality stays fixed (the barrier digest carries the fleet
    // distribution instead).
    let power_metrics = power.as_ref().map(|_| {
        (
            if per_node {
                metrics.gauge(&format!("power.soc_pct.{node}"))
            } else {
                Arc::new(Gauge::default())
            },
            metrics.counter("power.scenes_deferred"),
            metrics.counter("power.scenes_shed"),
        )
    });

    // federated round clock; rounds fire in virtual time, gated on SoC
    // when the power subsystem is on, and their weights contend with
    // imagery for pass airtime through the same downlink queue
    let mut fed = cfg.federated.enabled.then(|| FedScheduler::new(&cfg.federated, horizon));
    let fed_train_s =
        federated::train_seconds(cfg.federated.epochs, cfg.federated.samples_per_node);
    // per-sat counters (a fleet-summed pair would hide which satellite
    // the eclipse starved); past the cutoff they detach and the
    // `federated.rounds`/`federated.skipped_power` digests take over
    let fed_metrics = fed.as_ref().map(|_| {
        if per_node {
            (
                metrics.counter(&format!("federated.rounds.{node}")),
                metrics.counter(&format!("federated.skipped_power.{node}")),
            )
        } else {
            (Arc::new(Counter::default()), Arc::new(Counter::default()))
        }
    });

    // seeded chaos: the fault plan is a pure function of (chaos.seed,
    // sat index, horizon, scene count) — identical across engines and
    // shard counts — and `None` when disabled, so the nominal path
    // never consults it (default-off stays bit-identical)
    let mut chaos =
        cfg.chaos.enabled.then(|| FaultPlan::compile(&cfg.chaos, index, horizon, scenes));
    let mut chaos_stats = ChaosStats::default();

    let mut pending: BTreeMap<usize, PendingScene> = BTreeMap::new();
    let mut inflight: Vec<GroundInflight> = Vec::new();
    // capture indices the governor shed: no scene exists to fold there
    let mut shed_idx: BTreeSet<usize> = BTreeSet::new();
    let mut next_fold = 0usize;
    let frag = pipeline.cfg.fragment_px;
    let depth = pipeline.cfg.engine.channel_depth.max(1);
    // all engine workers go to the onboard stage here — the ground stage
    // is the shared segment, reached through async completions
    let onboard_workers = pipeline.cfg.engine.workers.max(1);
    let errs: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());

    let (tx_scene, rx_scene) = sync_channel::<Envelope<SceneJob>>(depth);
    let (tx_onboard, rx_onboard) = sync_channel::<Envelope<OnboardDone>>(depth);
    let rx_scene = Arc::new(Mutex::new(rx_scene));
    let pipeline_ref = &pipeline;
    let errs_ref = &errs;

    // dispatch one drain's worth of delivered imagery to the ground
    // segment; the reply is an asynchronous completion on the timeline.
    // `t` is the drain slice's virtual end time — where ground
    // re-inference lands in the flight recorder.
    let dispatch_ground = |delivered: Vec<Delivered>,
                          pending: &BTreeMap<usize, PendingScene>,
                          inflight: &mut Vec<GroundInflight>,
                          t: f64|
     -> Result<()> {
        delivered_items.add(delivered.len() as u64);
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        let mut tiles: Vec<Tile> = Vec::new();
        for d in &delivered {
            if d.item.kind != ItemKind::Image {
                continue;
            }
            let sidx = (d.item.tag / TAG_STRIDE) as usize;
            let tidx = (d.item.tag % TAG_STRIDE) as usize;
            let scene = pending
                .get(&sidx)
                .ok_or_else(|| anyhow!("delivered tile for unknown scene {sidx}"))?;
            tiles.push(scene.processed[tidx].tile.clone());
            pairs.push((sidx, tidx));
        }
        if tiles.is_empty() {
            return Ok(());
        }
        if let Some(tr) = &tracer {
            tr.event(SpanKind::GroundInfer, t, TracePayload::Batch(tiles.len()));
        }
        let (reply_tx, reply_rx) = channel();
        queue_depth.inc();
        ground_tx
            .send(GroundRequest { tiles, reply: reply_tx, at: Instant::now() })
            .map_err(|_| anyhow!("ground segment gone"))?;
        inflight.push(GroundInflight { pairs, rx: reply_rx });
        Ok(())
    };

    std::thread::scope(|s| -> Result<()> {
        // capture source: one deterministic RNG stream, its own thread,
        // so scene k+1's capture overlaps scene k's onboard inference
        let produced = metrics.counter("constellation.capture.items");
        // chaos: per-scene SEU strikes were decided at plan compile
        // (pure in seed + sat index), so the capture thread applies
        // them from its own copy without sharing the plan the driver
        // mutates — the fleet machine applies the same slots inline
        let seu_strikes: Option<(Vec<Option<u64>>, u32)> = chaos
            .as_ref()
            .map(|c| ((0..scenes).map(|i| c.seu_for_scene(i)).collect(), c.seu_flips()));
        s.spawn(move || {
            let mut gen = gen;
            for idx in 0..scenes {
                let mut scene = gen.capture();
                if let Some((seeds, flips)) = &seu_strikes {
                    if let Some(seed) = seeds[idx] {
                        apply_seu(&mut scene.pixels, *seed, *flips);
                    }
                }
                produced.inc();
                if tx_scene.send(Envelope::new(SceneJob { idx, scene })).is_err() {
                    break;
                }
            }
        });
        for _ in 0..onboard_workers {
            let rx = Arc::clone(&rx_scene);
            let tx = tx_onboard.clone();
            s.spawn(move || {
                worker_loop(
                    "constellation",
                    OnboardStage { p: pipeline_ref, frag },
                    &rx,
                    &tx,
                    metrics,
                    errs_ref,
                );
            });
        }
        drop(rx_scene);
        drop(tx_onboard);

        // driver: re-sequence scenes into capture order and advance the
        // mission timeline; nothing below blocks on the ground segment.
        // The receiver is owned here so an early error return drops it,
        // failing the workers' sends instead of deadlocking the scope.
        let rx_onboard = rx_onboard;
        let mut held: BTreeMap<usize, OnboardDone> = BTreeMap::new();
        let mut next_drive = 0usize;
        // recent loss rate for the adaptive router: rate over the packets
        // sent since the previous scene, not the link's whole lifetime
        // (a bad early pass must not latch the tightened state forever)
        let mut loss = LossTracker::default();
        for env in rx_onboard.iter() {
            held.insert(env.inner.idx, env.inner);
            while let Some(mut d) = held.remove(&next_drive) {
                // chaos: a satellite dark at this capture instant loses
                // the scene outright — the camera never fires, nothing
                // is queued or folded, and the period's contact time
                // passes unused (a recovering node has nothing to
                // send).  Checked before the power verdict: a dead bus
                // outranks a low battery.  Like the shed path, the
                // onboard stage already paid the discarded inference in
                // simulator wallclock, not mission energy.
                if chaos.as_ref().map(|c| c.crashed_at(timeline.now_s())).unwrap_or(false) {
                    let t_crash = timeline.now_s();
                    if let Some(tr) = &tracer {
                        tr.event(SpanKind::FaultCrash, t_crash, TracePayload::None);
                    }
                    chaos_stats.lost_to_crash += 1;
                    drop(d);
                    let (_, period) = scene_timing(timeline.timing(), 0);
                    let t = timeline.advance(period);
                    let blacked = timeline.due_contacts(t).len() as u64;
                    chaos_stats.slices_blacked_out += blacked;
                    chaos_stats.heartbeats_suppressed += blacked;
                    let duties = DutyCycles::default();
                    acc.extend_mission(period, duties);
                    if let Some(p) = power.as_mut() {
                        p.advance_period(period, duties, timeline.sunlit_s(t_crash, t));
                        if let Some((soc, _, _)) = &power_metrics {
                            soc.set(p.soc_pct());
                        }
                    }
                    if let Some(f) = fed.as_mut() {
                        let decisions =
                            poll_fed_gated(f, chaos.as_ref(), t, power.as_ref().map(|p| p.soc_frac()));
                        let wire = f.wire_bytes();
                        apply_fed_rounds(
                            decisions, wire, fed_train_s, &mut queue, &mut power, &mut acc,
                            &fed_metrics, tracer.as_ref(),
                        );
                    }
                    shed_idx.insert(next_drive);
                    next_drive += 1;
                    poll_ground(&mut inflight, &mut pending, false)?;
                    fold_ready(&mut pending, &mut shed_idx, &mut next_fold, &mut acc, false);
                    continue;
                }
                // the power governor speaks at this scene's virtual
                // capture time; SoC is pure mission-time history, so
                // governed runs stay deterministic
                let verdict =
                    power.as_ref().map(|p| p.verdict()).unwrap_or(PowerVerdict::Nominal);
                // governed verdicts are flight-recorder events, stamped
                // with the SoC the governor read at this capture time
                if let (Some(tr), Some(kind)) = (&tracer, verdict.trace_kind()) {
                    let soc =
                        power.as_ref().expect("governed verdict implies power state").soc_pct();
                    tr.event(kind, timeline.now_s(), TracePayload::Soc(soc));
                }
                if verdict == PowerVerdict::Shed {
                    // below soc_critical the capture is shed: camera and
                    // compute idle this period, transmitter off, and the
                    // contact time that elapses passes unused (airtime
                    // cannot be banked); the scene never happened in
                    // mission time, so nothing is queued or folded.
                    // Wallclock trade: the onboard stage ran ahead of
                    // this verdict (the stage overlap PR 2 built), so
                    // the discarded inference cost simulator wallclock —
                    // but no mission-time energy.
                    drop(d);
                    let (_, period) = scene_timing(timeline.timing(), 0);
                    let t_start = timeline.now_s();
                    let t = timeline.advance(period);
                    let _ = timeline.due_contacts(t);
                    let duties = DutyCycles::default();
                    acc.extend_mission(period, duties);
                    let p = power.as_mut().expect("shed verdict implies power state");
                    p.advance_period(period, duties, timeline.sunlit_s(t_start, t));
                    p.stats.scenes_shed += 1;
                    if let Some((soc, _, shed)) = &power_metrics {
                        shed.inc();
                        soc.set(p.soc_pct());
                    }
                    // rounds due this period are decided at its end;
                    // below soc_critical they land under min_soc (the
                    // validate_cross invariant) and skip
                    if let Some(f) = fed.as_mut() {
                        let decisions =
                            poll_fed_gated(f, chaos.as_ref(), t, power.as_ref().map(|p| p.soc_frac()));
                        let wire = f.wire_bytes();
                        apply_fed_rounds(
                            decisions, wire, fed_train_s, &mut queue, &mut power, &mut acc,
                            &fed_metrics, tracer.as_ref(),
                        );
                    }
                    shed_idx.insert(next_drive);
                    next_drive += 1;
                    poll_ground(&mut inflight, &mut pending, false)?;
                    fold_ready(&mut pending, &mut shed_idx, &mut next_fold, &mut acc, false);
                    continue;
                }
                let deferring = verdict == PowerVerdict::Defer;

                // link-aware adaptive routing: re-route with the policy
                // effective under the downlink state at this virtual
                // capture time (deterministic — no wallclock involved);
                // a deferring governor tightens on top of whatever the
                // adaptive path produced — the governed re-route shared
                // with the fleet machine
                if pipeline.policy.adaptive.is_some() || deferring {
                    let snap = pipeline.policy.adaptive.is_some().then(|| LinkSnapshot {
                        backlog_bytes: queue.pending_bytes(),
                        loss_rate: loss.update(link.stats.packets_sent, link.stats.packets_lost),
                    });
                    let step = deferring.then(|| {
                        power
                            .as_ref()
                            .expect("defer verdict implies power state")
                            .governor()
                            .defer_tighten
                    });
                    let eff = pipeline.policy.governed(snap.as_ref(), step);
                    d.router = reroute(&eff, &mut d.processed);
                }

                let (busy, period) = scene_timing(timeline.timing(), d.processed.len());
                let t_capture = timeline.now_s();
                // chaos: record the SEU that struck this scene's buffer
                // (the flips were applied on the capture thread,
                // pre-filter; the NaN-guarded fold degrades gracefully)
                if let Some(c) = chaos.as_ref() {
                    if c.seu_for_scene(next_drive).is_some() {
                        chaos_stats.seu_scenes += 1;
                        if let Some(tr) = &tracer {
                            tr.event(
                                SpanKind::FaultSeu,
                                t_capture,
                                TracePayload::Batch(c.seu_flips() as usize),
                            );
                        }
                    }
                }
                if let Some(tr) = &tracer {
                    trace_onboard(tr, &d, t_capture, timeline.timing().capture_overhead_s, busy);
                }
                let ready = t_capture + busy;
                let mut outstanding = 0usize;
                for (tidx, p) in d.processed.iter().enumerate() {
                    let tag = next_drive as u64 * TAG_STRIDE + tidx as u64;
                    match p.fate {
                        TileFate::OnboardFinal => queue.push(DownlinkItem {
                            kind: ItemKind::Results,
                            bytes: RESULT_HEADER_BYTES
                                + Detection::WIRE_BYTES * p.onboard_dets.len() as u64,
                            ready_at: ready,
                            tag,
                        }),
                        TileFate::Offloaded => {
                            outstanding += 1;
                            queue.push(DownlinkItem {
                                kind: ItemKind::Image,
                                bytes: p.tile.raw_bytes(),
                                ready_at: ready,
                                tag,
                            });
                        }
                        TileFate::Filtered => unreachable!("filtered tiles are not processed"),
                    }
                }

                // register the scene before any drain can deliver its
                // imagery; duties are patched in once the drains for
                // this period have been observed
                pending.insert(
                    next_drive,
                    PendingScene {
                        bentpipe_bytes: d.bentpipe_bytes,
                        n_scene_tiles: d.n_scene_tiles,
                        processed: d.processed,
                        n_filtered: d.n_filtered,
                        wall: d.wall,
                        router: d.router,
                        duties: DutyCycles::default(),
                        outstanding,
                    },
                );

                // advance the mission clock one scene period, then spend
                // the contact time that has elapsed; comm duty is the
                // link airtime those drains actually consumed.  While
                // deferring, the transmitter is off: elapsed window time
                // passes unused and queued items wait for the next window.
                let comm_before = link.stats.busy_s;
                let t = timeline.advance(period);
                if deferring {
                    let _ = timeline.due_contacts(t);
                } else {
                    for slice in timeline.due_contacts(t) {
                        let at_ms = (slice.window.aos * 1000.0) as u64;
                        let got = chaos_gated_drain(
                            &mut chaos,
                            &mut chaos_stats,
                            &mut queue,
                            &mut link,
                            &slice.window,
                            slice.closes_pass,
                            tracer.as_ref(),
                            || {
                                registry.lock().unwrap().heartbeat(&node, at_ms);
                            },
                        );
                        let Some(got) = got else { continue }; // blacked out
                        dispatch_ground(got, &pending, &mut inflight, slice.window.los)?;
                    }
                }
                let comm_busy = link.stats.busy_s - comm_before;
                let duties = timeline
                    .observed_duties(busy, period, comm_busy, timeline.timing().capture_overhead_s);
                pending.get_mut(&next_drive).expect("scene just inserted").duties = duties;
                if let Some(p) = power.as_mut() {
                    p.advance_period(period, duties, timeline.sunlit_s(t_capture, t));
                    if deferring {
                        p.stats.scenes_deferred += 1;
                    }
                    if let Some((soc, deferred, _)) = &power_metrics {
                        if deferring {
                            deferred.inc();
                        }
                        soc.set(p.soc_pct());
                    }
                }
                // federated rounds due this scene period, decided with
                // the SoC the period's flows left behind; their weights
                // queue for the next drain (possibly this period's tail)
                if let Some(f) = fed.as_mut() {
                    let decisions =
                        poll_fed_gated(f, chaos.as_ref(), t, power.as_ref().map(|p| p.soc_frac()));
                    let wire = f.wire_bytes();
                    apply_fed_rounds(
                        decisions, wire, fed_train_s, &mut queue, &mut power, &mut acc,
                        &fed_metrics, tracer.as_ref(),
                    );
                }
                next_drive += 1;

                // harvest any completed ground round-trips, then fold
                // every leading scene whose offloads have all resolved
                poll_ground(&mut inflight, &mut pending, false)?;
                fold_ready(&mut pending, &mut shed_idx, &mut next_fold, &mut acc, false);
            }
        }

        // mission tail: remaining windows give queued items their chance.
        // A governed satellite keeps integrating power through the tail
        // and skips any pass that opens below soc_critical — with no
        // captures left to protect, the defer band transmits (downlink
        // is the remaining mission value), but a critical battery still
        // keeps its transmitter off.
        let tail_start = timeline.now_s();
        let tail_comm_before = link.stats.busy_s;
        let power_step = timeline.timing().scene_period_floor_s.max(1.0);
        let mut power_cursor = tail_start;
        for slice in timeline.remaining_contacts() {
            // federated rounds due by the end of this pass fire first so
            // their weights can ride it.  Power integrates idle time to
            // each round boundary, clamped at AOS — pass time itself is
            // integrated with observed duties after the drain, so a
            // round due mid-pass is gated on the SoC at AOS.
            if let Some(f) = fed.as_mut() {
                while let Some(due) = f.due_next().filter(|d| *d <= slice.window.los) {
                    if let Some(p) = power.as_mut() {
                        let target = due.min(slice.window.aos);
                        p.advance_chunked(
                            &timeline,
                            power_cursor,
                            target,
                            DutyCycles::default(),
                            power_step,
                        );
                        power_cursor = power_cursor.max(target);
                    }
                    let decisions =
                        poll_fed_gated(f, chaos.as_ref(), due, power.as_ref().map(|p| p.soc_frac()));
                    let wire = f.wire_bytes();
                    apply_fed_rounds(
                        decisions, wire, fed_train_s, &mut queue, &mut power, &mut acc,
                        &fed_metrics, tracer.as_ref(),
                    );
                }
            }
            if let Some(p) = power.as_mut() {
                // idle mission time up to this pass, so the verdict
                // reflects SoC at AOS
                let aos = slice.window.aos;
                p.advance_chunked(&timeline, power_cursor, aos, DutyCycles::default(), power_step);
                power_cursor = aos;
                if p.verdict() == PowerVerdict::Shed {
                    if let Some(tr) = &tracer {
                        tr.event(SpanKind::Shed, aos, TracePayload::Soc(p.soc_pct()));
                    }
                    continue;
                }
            }
            let at_ms = (slice.window.aos * 1000.0) as u64;
            let busy_before = link.stats.busy_s;
            let got = chaos_gated_drain(
                &mut chaos,
                &mut chaos_stats,
                &mut queue,
                &mut link,
                &slice.window,
                slice.closes_pass,
                tracer.as_ref(),
                || {
                    registry.lock().unwrap().heartbeat(&node, at_ms);
                },
            );
            // blacked out: the pass never happens; AOS→LOS integrates
            // as idle from `power_cursor`, exactly like the shed branch
            let Some(got) = got else { continue };
            dispatch_ground(got, &pending, &mut inflight, slice.window.los)?;
            if let Some(p) = power.as_mut() {
                let comm = link.stats.busy_s - busy_before;
                let duties =
                    timeline.observed_duties(0.0, slice.window.duration_s(), comm, 0.0);
                let (aos, los) = (slice.window.aos, slice.window.los);
                p.advance_chunked(&timeline, aos, los, duties, power_step);
                power_cursor = los;
            }
        }
        // rounds due after the last pass still fire (battery permitting)
        // — their weights are queued and counted, but with no window
        // left they wait for a mission extension, which is honest
        if let Some(f) = fed.as_mut() {
            while let Some(due) = f.due_next() {
                if let Some(p) = power.as_mut() {
                    p.advance_chunked(
                        &timeline,
                        power_cursor,
                        due,
                        DutyCycles::default(),
                        power_step,
                    );
                    power_cursor = power_cursor.max(due);
                }
                let decisions =
                    poll_fed_gated(f, chaos.as_ref(), due, power.as_ref().map(|p| p.soc_frac()));
                let wire = f.wire_bytes();
                apply_fed_rounds(
                    decisions, wire, fed_train_s, &mut queue, &mut power, &mut acc,
                    &fed_metrics, tracer.as_ref(),
                );
            }
        }
        // everything dispatched; now completions are all that's left
        poll_ground(&mut inflight, &mut pending, true)?;
        // fold the resolved scenes; force-fold the rest — undelivered
        // offloads are evaluated with their onboard detections
        fold_ready(&mut pending, &mut shed_idx, &mut next_fold, &mut acc, true);
        // the tail is mission time too: integrate its energy with the
        // comm airtime the tail drains actually consumed (compute idle,
        // camera off) — with default configs most contact happens here
        let tail_dt = horizon - tail_start;
        if tail_dt > 0.0 {
            let tail_comm = link.stats.busy_s - tail_comm_before;
            acc.extend_mission(tail_dt, timeline.observed_duties(0.0, tail_dt, tail_comm, 0.0));
        }
        if let Some(p) = power.as_mut() {
            // the stretch after the last pass is idle mission time too
            p.advance_chunked(&timeline, power_cursor, horizon, DutyCycles::default(), power_step);
            if let Some((soc, _, _)) = &power_metrics {
                soc.set(p.soc_pct());
            }
        }
        Ok(())
    })?;

    if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
        return Err(e);
    }
    // plan-level totals land once the mission is over; the per-event
    // counters above accumulated as faults fired
    if let Some(c) = &chaos {
        chaos_stats.crashes = c.crash_windows().len() as u64;
        chaos_stats.dropouts = c.dropout_windows().len() as u64;
    }
    let shed = power.as_ref().map(|p| p.stats.scenes_shed as usize).unwrap_or(0);
    let lost = chaos_stats.lost_to_crash as usize;
    anyhow::ensure!(
        acc.scenes() + shed + lost == scenes,
        "satellite {index} lost scenes: folded {} + shed {shed} + crashed {lost} of {scenes}",
        acc.scenes()
    );

    if let Some(f) = &fed {
        anyhow::ensure!(
            f.stats.rounds_completed + f.stats.rounds_skipped_power + f.stats.rounds_skipped_crash
                == f.stats.rounds_scheduled,
            "satellite {index} lost federated rounds: {} + {} + {} of {}",
            f.stats.rounds_completed,
            f.stats.rounds_skipped_power,
            f.stats.rounds_skipped_crash,
            f.stats.rounds_scheduled
        );
    }

    // per-satellite tile-pool health: allocs plateau at the satellite's
    // max tiles in flight (split + pending offload clones), then every
    // further scene is allocation-free
    let ps = pipeline.tile_pool_stats();
    let hit_pct = (ps.hit_rate() * 100.0).round() as i64;
    if per_node {
        metrics.gauge(&format!("constellation.pool.tile_allocs.{node}")).set(ps.allocs as i64);
        metrics.gauge(&format!("constellation.pool.tile_hit_pct.{node}")).set(hit_pct);
        metrics
            .gauge(&format!("constellation.pool.tile_evictions.{node}"))
            .set(ps.evictions as i64);
    }
    // fixed-size fleet aggregates — digest updates commute, so satellite
    // threads finishing in any order render identically
    metrics.digest("constellation.pool.tile_allocs").observe(ps.allocs as i64);
    metrics.digest("constellation.pool.tile_hit_pct").observe(hit_pct);
    metrics.digest("constellation.pool.tile_evictions").observe(ps.evictions as i64);

    lc.finish(task, true);
    gm.lock().unwrap().report(task, &node, TaskPhase::Completed)?;
    let power_stats = power.map(|p| p.stats);
    let fed_stats = fed.map(|f| f.stats);
    let mut result = acc.finish(version, cfg.fragment_px);
    result.power = power_stats;
    result.federated = fed_stats.clone();
    Ok(SatelliteReport {
        index,
        name: node.to_string(),
        result,
        downlink: queue.stats.clone(),
        link: link.stats,
        windows: timeline.n_contacts(),
        contact_s: timeline.contact_total_s(),
        sunlit_s: timeline.sunlit_s(0.0, horizon),
        power: power_stats,
        federated: fed_stats,
        chaos: chaos.is_some().then_some(chaos_stats),
    })
}
