//! Constellation-scale scenario runner: N satellites, one ground segment.
//!
//! Each satellite runs its scenario (capture → filter → batch → onboard
//! infer → route) sequentially on its own thread — the concurrency here
//! is *across* satellites, plus the asynchronous shared ground segment;
//! within one satellite, [`super::engine::StagedEngine`]-style stage
//! overlap is future work.  Every satellite queues results and
//! offloaded imagery in a [`DownlinkQueue`] whose drains are gated by its
//! *own* contact windows from [`crate::orbit`], and shares a single
//! ground-segment worker that serves HeavyDet re-inference for every
//! satellite (serialized by the runtime's per-model execution lock —
//! exactly one ground GPU).  Scenes fold through the same
//! [`ScenarioAccumulator`] as the single-satellite paths, in capture
//! order, with one honest difference: an offloaded tile whose imagery
//! never survives a contact window is evaluated with its onboard
//! detections (the collaborative gain only materializes for delivered
//! tiles).  Byte accounting keeps both views: the scenario fold's
//! `collab_bytes` stays nominal (bytes *queued* for downlink, same as
//! single-satellite runs) while [`SatelliteReport::downlink`] records
//! what the lossy windowed link actually delivered.
//!
//! Cluster/sedna bookkeeping mirrors the paper's control plane: every
//! satellite registers as an Edge node and heartbeats during contact
//! windows, and the whole run is scheduled as a Sedna `JointInference`
//! task whose per-worker phases aggregate into the report.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::cluster::registry::Registry as NodeRegistry;
use crate::cluster::{NodeId, NodeRole};
use crate::config::Config;
use crate::data::{Tile, Version};
use crate::detect::Detection;
use crate::link::{Link, LinkConfig, LinkStats};
use crate::orbit::{baoyun, beijing_station, contact_windows};
use crate::runtime::{Model, Runtime};
use crate::sedna::{GlobalManager, LocalController, TaskKind, TaskPhase, TaskSpec};
use crate::telemetry::Registry;

use super::downlink::{DownlinkItem, DownlinkQueue, DownlinkStats, ItemKind};
use super::pipeline::{
    scene_timing, Pipeline, ProcessedTile, ScenarioAccumulator, ScenarioResult,
    RESULT_HEADER_BYTES,
};
use super::router::RouterStats;
use super::TileFate;

/// Downlink tag encoding: scene index * stride + tile index.
const TAG_STRIDE: u64 = 1_000_000;

/// One satellite's share of the constellation run.
pub struct SatelliteReport {
    /// Constellation plane index (reports are ordered by this).
    pub index: usize,
    pub name: String,
    /// Full scenario metrics (same fold as single-satellite runs).
    /// `result.collab_bytes` is the *nominal* accounting — what the
    /// system queued for downlink; `downlink` below holds what actually
    /// crossed the lossy windowed link, so under heavy loss
    /// `result.collab_bytes > downlink.total_bytes()`.
    pub result: ScenarioResult,
    pub downlink: DownlinkStats,
    pub link: LinkStats,
    pub windows: usize,
    pub contact_s: f64,
}

pub struct ConstellationReport {
    pub satellites: Vec<SatelliteReport>,
    pub tiles_total: usize,
    /// Wallclock for the whole constellation run.
    pub wall_s: f64,
    /// Sedna JointInference task reached Completed.
    pub task_completed: bool,
    /// Rendered per-stage telemetry (queue waits, service times, depths).
    pub telemetry: String,
}

impl ConstellationReport {
    /// Aggregate throughput across all satellites.
    pub fn aggregate_tiles_per_s(&self) -> f64 {
        self.tiles_total as f64 / self.wall_s.max(1e-9)
    }
}

/// HeavyDet work order for the shared ground segment.
struct GroundRequest {
    tiles: Vec<Tile>,
    reply: Sender<Result<(Vec<Vec<Detection>>, f64)>>,
    at: Instant,
}

/// A scene waiting for its offloaded tiles to clear the downlink.
struct PendingScene {
    bentpipe_bytes: u64,
    n_scene_tiles: usize,
    processed: Vec<ProcessedTile>,
    n_filtered: usize,
    wall: f64,
    router: RouterStats,
    /// Offloaded tiles not yet ground-inferred (delivery pending).
    outstanding: usize,
}

/// Run `cfg.constellation.satellites` satellites against one ground
/// segment.  Per-satellite seeds, orbital planes, and contact windows
/// differ; the scene workload per satellite is
/// `cfg.constellation.scenes_per_satellite`.
pub fn run_constellation(rt: &Runtime, cfg: &Config, version: Version) -> Result<ConstellationReport> {
    let n_sats = cfg.constellation.satellites.max(1);
    let scenes = cfg.constellation.scenes_per_satellite;
    let metrics = Registry::new();
    let gs = beijing_station();

    // control plane: node registry + Sedna JointInference task
    let ground_node = NodeId::new("ground-1");
    let sat_nodes: Vec<NodeId> = (0..n_sats).map(|i| NodeId::new(format!("sat-{i}"))).collect();
    let registry = Mutex::new(NodeRegistry::new(60_000, 600_000));
    {
        let mut reg = registry.lock().unwrap();
        reg.register(ground_node.clone(), NodeRole::Cloud, 64_000, 262_144, 0);
        for id in &sat_nodes {
            reg.register(id.clone(), NodeRole::Edge, 4_000, 8_192, 0);
        }
    }
    let gm = Mutex::new(GlobalManager::new());
    let task = "joint-inference";
    {
        let mut workers = sat_nodes.clone();
        workers.push(ground_node.clone());
        gm.lock().unwrap().create(TaskSpec {
            name: task.into(),
            kind: TaskKind::JointInference,
            workers,
            params: BTreeMap::new(),
        })?;
    }

    let (ground_tx, ground_rx) = channel::<GroundRequest>();
    let t0 = Instant::now();
    let mut reports: Vec<SatelliteReport> = Vec::with_capacity(n_sats);

    std::thread::scope(|s| -> Result<()> {
        // shared ground segment: one HeavyDet server for all satellites
        let ground_pipe = Pipeline::new(rt, cfg.clone());
        let metrics_ref = &metrics;
        let ground = s.spawn(move || {
            let wait = metrics_ref.histogram("constellation.ground.queue_wait_s");
            let svc = metrics_ref.histogram("constellation.ground.service_s");
            let served = metrics_ref.counter("constellation.ground.tiles");
            let depth = metrics_ref.gauge("constellation.ground.queue_depth");
            while let Ok(req) = ground_rx.recv() {
                depth.dec();
                wait.observe_secs(req.at.elapsed().as_secs_f64());
                let t = Instant::now();
                let out = ground_pipe
                    .infer(Model::Heavy, &req.tiles)
                    .map(|(dets, _, wall)| (dets, wall));
                svc.observe_secs(t.elapsed().as_secs_f64());
                served.add(req.tiles.len() as u64);
                let _ = req.reply.send(out);
            }
        });

        let mut handles = Vec::with_capacity(n_sats);
        for i in 0..n_sats {
            let node = sat_nodes[i].clone();
            let tx = ground_tx.clone();
            let registry = &registry;
            let gm = &gm;
            let gs = &gs;
            handles.push(s.spawn(move || -> Result<SatelliteReport> {
                run_satellite(rt, cfg, version, i, node, tx, registry, gm, task, gs, metrics_ref, scenes)
            }));
        }
        drop(ground_tx); // ground loop ends when the last satellite hangs up

        let mut first_err = None;
        for h in handles {
            match h.join().map_err(|_| anyhow!("satellite thread panicked"))? {
                Ok(r) => reports.push(r),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        ground.join().map_err(|_| anyhow!("ground thread panicked"))?;
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    gm.lock().unwrap().report(task, &ground_node, TaskPhase::Completed)?;
    let task_completed =
        gm.lock().unwrap().get(task).map(|(_, st)| st.phase) == Some(TaskPhase::Completed);
    reports.sort_by_key(|r| r.index);
    let tiles_total = reports.iter().map(|r| r.result.tiles_total).sum();
    Ok(ConstellationReport {
        satellites: reports,
        tiles_total,
        wall_s: t0.elapsed().as_secs_f64(),
        task_completed,
        telemetry: metrics.render(),
    })
}

#[allow(clippy::too_many_arguments)] // internal plumbing fn, not API
fn run_satellite(
    rt: &Runtime,
    cfg: &Config,
    version: Version,
    index: usize,
    node: NodeId,
    ground_tx: Sender<GroundRequest>,
    registry: &Mutex<NodeRegistry>,
    gm: &Mutex<GlobalManager>,
    task: &str,
    gs: &crate::orbit::GroundStation,
    metrics: &Registry,
    scenes: usize,
) -> Result<SatelliteReport> {
    let mut lc = LocalController::new(node.clone());
    lc.start(task);
    gm.lock().unwrap().report(task, &node, TaskPhase::Running)?;

    // one orbital plane per satellite, phased around the constellation
    let mut sat = baoyun();
    sat.name = node.to_string();
    sat.raan_rad = index as f64 * cfg.constellation.raan_step_rad;
    sat.phase_rad = index as f64 * std::f64::consts::TAU / cfg.constellation.satellites.max(1) as f64;
    let windows = contact_windows(&sat, gs, 0.0, cfg.constellation.horizon_s, 10.0);
    let contact_s: f64 = windows.iter().map(|w| w.duration_s()).sum();

    let mut sat_cfg = cfg.clone();
    sat_cfg.seed = cfg.seed.wrapping_add(1 + index as u64 * 101);
    let pipeline = Pipeline::new(rt, sat_cfg);
    let mut gen = pipeline.scene_gen(version);
    let mut acc = ScenarioAccumulator::new(&pipeline.cfg, rt.manifest.classes);
    let mut queue = DownlinkQueue::new();
    let mut link = Link::new(LinkConfig::downlink(pipeline.cfg.loss()), pipeline.cfg.seed);
    let onboard_svc = metrics.histogram("constellation.onboard.service_s");
    let delivered_items = metrics.counter("constellation.downlink.items_delivered");
    let queue_depth = metrics.gauge("constellation.ground.queue_depth");

    let mut pending: BTreeMap<usize, PendingScene> = BTreeMap::new();
    let mut next_fold = 0usize;
    let mut t = 0.0f64; // virtual mission time
    let mut next_w = 0usize;

    // ground round-trip for every Image item delivered in one drain
    let mut serve_delivered = |delivered: Vec<super::downlink::Delivered>,
                               pending: &mut BTreeMap<usize, PendingScene>|
     -> Result<()> {
        let mut tags: Vec<(usize, usize)> = Vec::new();
        let mut tiles: Vec<Tile> = Vec::new();
        for d in &delivered {
            if d.item.kind != ItemKind::Image {
                continue;
            }
            let sidx = (d.item.tag / TAG_STRIDE) as usize;
            let tidx = (d.item.tag % TAG_STRIDE) as usize;
            let scene = pending
                .get(&sidx)
                .ok_or_else(|| anyhow!("delivered tile for unknown scene {sidx}"))?;
            tiles.push(scene.processed[tidx].tile.clone());
            tags.push((sidx, tidx));
        }
        delivered_items.add(delivered.len() as u64);
        if tiles.is_empty() {
            return Ok(());
        }
        let n = tiles.len();
        let (reply_tx, reply_rx) = channel();
        queue_depth.inc();
        ground_tx
            .send(GroundRequest { tiles, reply: reply_tx, at: Instant::now() })
            .map_err(|_| anyhow!("ground segment gone"))?;
        let (dets, wall) = reply_rx.recv().context("ground segment hung up")??;
        let wall_each = wall / n as f64;
        for ((sidx, tidx), d) in tags.into_iter().zip(dets) {
            let scene = pending.get_mut(&sidx).expect("scene vanished mid-delivery");
            scene.processed[tidx].ground_dets = Some(d);
            scene.outstanding -= 1;
            scene.wall += wall_each;
        }
        Ok(())
    };

    for idx in 0..scenes {
        let scene = gen.capture();
        let mut router = RouterStats::default();
        let svc0 = Instant::now();
        let (processed, n_filtered, wall) = pipeline.onboard_scene(&scene, &mut router)?;
        onboard_svc.observe_secs(svc0.elapsed().as_secs_f64());

        let (busy, period) = scene_timing(&pipeline.cfg.timing, processed.len());
        let ready = t + busy;
        let mut outstanding = 0usize;
        for (tidx, p) in processed.iter().enumerate() {
            let tag = idx as u64 * TAG_STRIDE + tidx as u64;
            match p.fate {
                TileFate::OnboardFinal => queue.push(DownlinkItem {
                    kind: ItemKind::Results,
                    bytes: RESULT_HEADER_BYTES
                        + Detection::WIRE_BYTES * p.onboard_dets.len() as u64,
                    ready_at: ready,
                    tag,
                }),
                TileFate::Offloaded => {
                    outstanding += 1;
                    queue.push(DownlinkItem {
                        kind: ItemKind::Image,
                        bytes: p.tile.raw_bytes(),
                        ready_at: ready,
                        tag,
                    });
                }
                TileFate::Filtered => unreachable!("filtered tiles are not processed"),
            }
        }
        let n_scene_tiles = (scene.width / pipeline.cfg.fragment_px)
            * (scene.height / pipeline.cfg.fragment_px);
        pending.insert(
            idx,
            PendingScene {
                bentpipe_bytes: scene.size_bytes(),
                n_scene_tiles,
                processed,
                n_filtered,
                wall,
                router,
                outstanding,
            },
        );
        t += period;

        // contact windows that have opened by now: heartbeat + drain
        while next_w < windows.len() && windows[next_w].aos < t {
            let w = &windows[next_w];
            registry.lock().unwrap().heartbeat(&node, (w.aos * 1000.0) as u64);
            let got = queue.drain_window(&mut link, w);
            serve_delivered(got, &mut pending)?;
            next_w += 1;
        }
        // fold every leading scene whose offloads have all resolved
        while pending.get(&next_fold).map(|p| p.outstanding == 0).unwrap_or(false) {
            let p = pending.remove(&next_fold).unwrap();
            acc.add_scene(&p.router, p.bentpipe_bytes, p.n_scene_tiles, &p.processed, p.n_filtered, p.wall);
            next_fold += 1;
        }
    }

    // mission tail: remaining windows give queued items their chance
    while next_w < windows.len() {
        let w = &windows[next_w];
        registry.lock().unwrap().heartbeat(&node, (w.aos * 1000.0) as u64);
        let got = queue.drain_window(&mut link, w);
        serve_delivered(got, &mut pending)?;
        next_w += 1;
    }
    // force-fold: undelivered offloads are evaluated with onboard results
    while let Some(p) = pending.remove(&next_fold) {
        acc.add_scene(&p.router, p.bentpipe_bytes, p.n_scene_tiles, &p.processed, p.n_filtered, p.wall);
        next_fold += 1;
    }

    lc.finish(task, true);
    gm.lock().unwrap().report(task, &node, TaskPhase::Completed)?;
    Ok(SatelliteReport {
        index,
        name: node.to_string(),
        result: acc.finish(version, cfg.fragment_px),
        downlink: queue.stats,
        link: link.stats,
        windows: windows.len(),
        contact_s,
    })
}
