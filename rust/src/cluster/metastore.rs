//! MetaManager — versioned metadata store with offline autonomy.
//!
//! Paper §3.2: "A lightweight management component named MetaManager
//! stores metadata. When edge nodes go offline, applications are managed
//! and restored based on storage metadata."
//!
//! Model: the cloud store is the source of truth; each edge node holds a
//! snapshot replica.  While connected, edge pulls deltas by version;
//! while disconnected, edge reads (and locally stages writes) against its
//! snapshot; on reconnect, staged writes are pushed and deltas pulled.

use std::collections::BTreeMap;

/// Monotone version counter per store.
pub type Version = u64;

#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub value: String,
    pub version: Version,
}

#[derive(Default, Clone)]
pub struct MetaStore {
    data: BTreeMap<String, Entry>,
    version: Version,
}

impl MetaStore {
    pub fn new() -> MetaStore {
        MetaStore::default()
    }

    pub fn put(&mut self, key: impl Into<String>, value: impl Into<String>) -> Version {
        self.version += 1;
        self.data.insert(key.into(), Entry { value: value.into(), version: self.version });
        self.version
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.data.get(key).map(|e| e.value.as_str())
    }

    pub fn version(&self) -> Version {
        self.version
    }

    /// All entries newer than `since` (the sync delta).
    pub fn delta_since(&self, since: Version) -> Vec<(String, Entry)> {
        self.data
            .iter()
            .filter(|(_, e)| e.version > since)
            .map(|(k, e)| (k.clone(), e.clone()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Edge-side replica with staged offline writes.
pub struct EdgeReplica {
    snapshot: MetaStore,
    /// Last cloud version incorporated.
    synced_version: Version,
    /// Writes made while offline, applied to the cloud on reconnect.
    staged: Vec<(String, String)>,
    pub connected: bool,
}

impl EdgeReplica {
    pub fn new() -> EdgeReplica {
        EdgeReplica { snapshot: MetaStore::new(), synced_version: 0, staged: Vec::new(), connected: false }
    }

    /// Offline-autonomous read: always served locally.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.snapshot.get(key)
    }

    /// Write: applied locally immediately; staged for the cloud if
    /// disconnected.
    pub fn put(&mut self, cloud: Option<&mut MetaStore>, key: &str, value: &str) {
        self.snapshot.put(key, value);
        match (self.connected, cloud) {
            (true, Some(c)) => {
                c.put(key, value);
                self.synced_version = c.version();
            }
            _ => self.staged.push((key.to_string(), value.to_string())),
        }
    }

    pub fn staged_count(&self) -> usize {
        self.staged.len()
    }

    /// Reconnect: push staged writes, pull the delta.
    pub fn sync(&mut self, cloud: &mut MetaStore) {
        self.connected = true;
        for (k, v) in self.staged.drain(..) {
            cloud.put(k, v);
        }
        for (k, e) in cloud.delta_since(self.synced_version) {
            self.snapshot.put(k, e.value);
        }
        self.synced_version = cloud.version();
    }

    pub fn disconnect(&mut self) {
        self.connected = false;
    }
}

impl Default for EdgeReplica {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_bumps_version() {
        let mut s = MetaStore::new();
        let v1 = s.put("a", "1");
        let v2 = s.put("b", "2");
        assert!(v2 > v1);
        assert_eq!(s.get("a"), Some("1"));
    }

    #[test]
    fn delta_only_newer() {
        let mut s = MetaStore::new();
        s.put("a", "1");
        let v = s.version();
        s.put("b", "2");
        let d = s.delta_since(v);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, "b");
    }

    #[test]
    fn offline_reads_served_from_snapshot() {
        let mut cloud = MetaStore::new();
        cloud.put("app/detector", "v1");
        let mut edge = EdgeReplica::new();
        edge.sync(&mut cloud);
        edge.disconnect();
        // cloud moves on; edge still answers from its snapshot
        cloud.put("app/detector", "v2");
        assert_eq!(edge.get("app/detector"), Some("v1"));
    }

    #[test]
    fn offline_writes_staged_and_pushed_on_reconnect() {
        let mut cloud = MetaStore::new();
        let mut edge = EdgeReplica::new();
        edge.sync(&mut cloud);
        edge.disconnect();
        edge.put(None, "telemetry/last_map", "0.41");
        assert_eq!(edge.staged_count(), 1);
        assert_eq!(edge.get("telemetry/last_map"), Some("0.41")); // local apply
        edge.sync(&mut cloud);
        assert_eq!(cloud.get("telemetry/last_map"), Some("0.41"));
        assert_eq!(edge.staged_count(), 0);
    }

    #[test]
    fn reconnect_pulls_cloud_changes() {
        let mut cloud = MetaStore::new();
        let mut edge = EdgeReplica::new();
        edge.sync(&mut cloud);
        edge.disconnect();
        cloud.put("app/detector", "v2");
        edge.sync(&mut cloud);
        assert_eq!(edge.get("app/detector"), Some("v2"));
    }

    #[test]
    fn connected_writes_go_straight_through() {
        let mut cloud = MetaStore::new();
        let mut edge = EdgeReplica::new();
        edge.sync(&mut cloud);
        edge.put(Some(&mut cloud), "k", "v");
        assert_eq!(cloud.get("k"), Some("v"));
        assert_eq!(edge.staged_count(), 0);
    }
}
