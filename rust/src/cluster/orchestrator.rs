//! Containerized-app orchestration with desired-state reconciliation.
//!
//! Paper §3.1 "Safe and reliable": "With container orchestration for
//! microservices, onboard applications can be automatically scaled,
//! fault-tolerant which copes with the complex environment of space and
//! keeps onboard applications available at all times."
//!
//! A deliberately small Kubernetes: AppSpec (desired replicas + placement
//! + image), PodInstance (actual), and a reconcile step that starts
//! missing pods, restarts failed ones, and performs rolling image
//! updates.  Placement respects node readiness *as known locally* — the
//! edge keeps reconciling its own pods while offline (offline autonomy).

use std::collections::BTreeMap;

use super::registry::{NodeStatus, Registry};
use super::{Millis, NodeId, NodeRole};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Placement {
    Edge,
    Cloud,
}

#[derive(Clone, Debug, PartialEq)]
pub struct AppSpec {
    pub name: String,
    pub image: String,
    pub replicas: usize,
    pub placement: Placement,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PodPhase {
    Running,
    Failed,
}

#[derive(Clone, Debug)]
pub struct PodInstance {
    pub app: String,
    pub image: String,
    pub node: NodeId,
    pub phase: PodPhase,
    pub started_at: Millis,
    pub restarts: u32,
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ReconcileActions {
    pub started: usize,
    pub restarted: usize,
    pub updated: usize,
    pub removed: usize,
    /// Pods evicted from Offline (or unregistered) nodes this pass; the
    /// same pass's scale-up replaces them wherever a Ready node of the
    /// right role exists.
    pub failed_over: usize,
}

pub struct Orchestrator {
    specs: BTreeMap<String, AppSpec>,
    pods: Vec<PodInstance>,
}

impl Default for Orchestrator {
    fn default() -> Self {
        Self::new()
    }
}

impl Orchestrator {
    pub fn new() -> Orchestrator {
        Orchestrator { specs: BTreeMap::new(), pods: Vec::new() }
    }

    pub fn apply(&mut self, spec: AppSpec) {
        self.specs.insert(spec.name.clone(), spec);
    }

    pub fn delete(&mut self, app: &str) {
        self.specs.remove(app);
    }

    pub fn pods(&self, app: &str) -> Vec<&PodInstance> {
        self.pods.iter().filter(|p| p.app == app).collect()
    }

    pub fn running(&self, app: &str) -> usize {
        self.pods.iter().filter(|p| p.app == app && p.phase == PodPhase::Running).count()
    }

    /// Inject a pod failure (radiation upset, OOM, …) — test hook and
    /// simulation event.
    pub fn fail_pod(&mut self, app: &str, idx: usize) -> bool {
        let mut i = 0;
        for p in self.pods.iter_mut() {
            if p.app == app {
                if i == idx {
                    p.phase = PodPhase::Failed;
                    return true;
                }
                i += 1;
            }
        }
        false
    }

    /// One reconcile pass: drive actual state toward every spec.
    pub fn reconcile(&mut self, registry: &Registry, now: Millis) -> ReconcileActions {
        let mut acts = ReconcileActions::default();

        // remove pods whose app was deleted
        let before = self.pods.len();
        let specs = &self.specs;
        self.pods.retain(|p| specs.contains_key(&p.app));
        acts.removed += before - self.pods.len();

        // fail over pods stranded on dead nodes: the registry's belief
        // says the node is gone (Offline, or never registered), so its
        // pods cannot be serving.  Evicting them *before* the per-spec
        // loop lets the same pass's scale-up replace each one on a Ready
        // node — rescheduled exactly once, and a second reconcile at the
        // same `now` finds nothing left to evict (idempotent).  NotReady
        // nodes keep their pods: transient heartbeat silence (a contact
        // gap) must not thrash placements.
        let before = self.pods.len();
        self.pods.retain(|p| {
            matches!(
                registry.status(&p.node, now),
                Some(NodeStatus::Ready) | Some(NodeStatus::NotReady)
            )
        });
        acts.failed_over += before - self.pods.len();

        let candidates: Vec<(NodeId, NodeRole)> = registry
            .nodes()
            .filter(|n| registry.status(&n.id, now) == Some(NodeStatus::Ready))
            .map(|n| (n.id.clone(), n.role))
            .collect();

        for spec in self.specs.values() {
            let want_role = match spec.placement {
                Placement::Edge => NodeRole::Edge,
                Placement::Cloud => NodeRole::Cloud,
            };
            // restart failed pods in place
            for p in self.pods.iter_mut().filter(|p| p.app == spec.name) {
                if p.phase == PodPhase::Failed {
                    p.phase = PodPhase::Running;
                    p.restarts += 1;
                    p.started_at = now;
                    acts.restarted += 1;
                }
                // rolling update: replace image on mismatch
                if p.image != spec.image {
                    p.image = spec.image.clone();
                    p.started_at = now;
                    acts.updated += 1;
                }
            }
            // scale up onto ready nodes of the right role (round-robin)
            let mut nodes: Vec<&NodeId> =
                candidates.iter().filter(|(_, r)| *r == want_role).map(|(id, _)| id).collect();
            nodes.sort();
            if nodes.is_empty() {
                continue; // no placement target: stay pending
            }
            let mut have = self.pods.iter().filter(|p| p.app == spec.name).count();
            let mut rr = have;
            while have < spec.replicas {
                let node = nodes[rr % nodes.len()].clone();
                self.pods.push(PodInstance {
                    app: spec.name.clone(),
                    image: spec.image.clone(),
                    node,
                    phase: PodPhase::Running,
                    started_at: now,
                    restarts: 0,
                });
                acts.started += 1;
                have += 1;
                rr += 1;
            }
            // scale down
            while have > spec.replicas {
                if let Some(pos) = self.pods.iter().rposition(|p| p.app == spec.name) {
                    self.pods.remove(pos);
                    acts.removed += 1;
                }
                have -= 1;
            }
        }
        acts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Orchestrator, Registry) {
        let mut reg = Registry::new(10_000, 60_000);
        reg.register(NodeId::new("baoyun"), NodeRole::Edge, 4000, 8192, 0);
        reg.register(NodeId::new("ground-1"), NodeRole::Cloud, 64_000, 262_144, 0);
        (Orchestrator::new(), reg)
    }

    fn detector_spec(image: &str, replicas: usize) -> AppSpec {
        AppSpec {
            name: "detector".into(),
            image: image.into(),
            replicas,
            placement: Placement::Edge,
        }
    }

    #[test]
    fn starts_missing_pods() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 2));
        let acts = o.reconcile(&reg, 0);
        assert_eq!(acts.started, 2);
        assert_eq!(o.running("detector"), 2);
        assert!(o.pods("detector").iter().all(|p| p.node == NodeId::new("baoyun")));
    }

    #[test]
    fn restarts_failed_pods() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        o.reconcile(&reg, 0);
        assert!(o.fail_pod("detector", 0));
        assert_eq!(o.running("detector"), 0);
        let acts = o.reconcile(&reg, 1000);
        assert_eq!(acts.restarted, 1);
        assert_eq!(o.running("detector"), 1);
        assert_eq!(o.pods("detector")[0].restarts, 1);
    }

    #[test]
    fn rolling_update_swaps_image() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        o.reconcile(&reg, 0);
        o.apply(detector_spec("tinydet:v2", 1));
        let acts = o.reconcile(&reg, 5000);
        assert_eq!(acts.updated, 1);
        assert_eq!(o.pods("detector")[0].image, "tinydet:v2");
    }

    #[test]
    fn no_ready_node_keeps_pending() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        // edge node silent long enough to be Offline
        let acts = o.reconcile(&reg, 10_000_000);
        assert_eq!(acts.started, 0);
        assert_eq!(o.running("detector"), 0);
    }

    #[test]
    fn edge_keeps_reconciling_while_cloud_view_offline() {
        // Offline autonomy: the *edge's own* registry still sees itself.
        let (mut o, mut edge_reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        edge_reg.heartbeat(&NodeId::new("baoyun"), 10_000_000);
        let acts = o.reconcile(&edge_reg, 10_000_001);
        assert_eq!(acts.started, 1);
    }

    #[test]
    fn scale_down_removes_pods() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 3));
        o.reconcile(&reg, 0);
        o.apply(detector_spec("tinydet:v1", 1));
        let acts = o.reconcile(&reg, 100);
        assert_eq!(acts.removed, 2);
        assert_eq!(o.running("detector"), 1);
    }

    #[test]
    fn deleted_app_pods_removed() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 2));
        o.reconcile(&reg, 0);
        o.delete("detector");
        let acts = o.reconcile(&reg, 100);
        assert_eq!(acts.removed, 2);
        assert!(o.pods("detector").is_empty());
    }

    #[test]
    fn crashed_node_pods_fail_over_exactly_once() {
        let (mut o, mut reg) = setup();
        reg.register(NodeId::new("baoxing"), NodeRole::Edge, 4000, 8192, 0);
        o.apply(detector_spec("tinydet:v1", 1));
        o.reconcile(&reg, 0);
        let first_node = o.pods("detector")[0].node.clone();
        // the hosting node crashes (silent past eviction); the spare
        // edge node keeps heartbeating
        let now = 100_000;
        let spare = if first_node == NodeId::new("baoyun") { "baoxing" } else { "baoyun" };
        reg.heartbeat(&NodeId::new(spare), now);
        let acts = o.reconcile(&reg, now);
        assert_eq!(acts.failed_over, 1, "stranded pod evicted");
        assert_eq!(acts.started, 1, "and replaced in the same pass");
        assert_eq!(o.running("detector"), 1);
        assert_eq!(o.pods("detector")[0].node, NodeId::new(spare));
        // idempotent: a second reconcile at the same `now` does nothing
        let again = o.reconcile(&reg, now);
        assert_eq!(again, ReconcileActions::default(), "no duplicate reschedule");
        assert_eq!(o.running("detector"), 1);
    }

    #[test]
    fn failover_without_target_leaves_pod_pending() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        o.reconcile(&reg, 0);
        assert_eq!(o.running("detector"), 1);
        // every node dark: the pod is evicted once, nothing replaces it
        let acts = o.reconcile(&reg, 10_000_000);
        assert_eq!(acts.failed_over, 1);
        assert_eq!(acts.started, 0);
        assert_eq!(o.running("detector"), 0);
        let again = o.reconcile(&reg, 10_000_000);
        assert_eq!(again, ReconcileActions::default(), "eviction happens exactly once");
        // the node comes back: the pending pod is finally placed
        let mut reg = reg;
        reg.heartbeat(&NodeId::new("baoyun"), 10_000_000);
        let back = o.reconcile(&reg, 10_000_001);
        assert_eq!(back.started, 1);
        assert_eq!(o.running("detector"), 1);
    }

    #[test]
    fn notready_node_keeps_its_pods() {
        let (mut o, reg) = setup();
        o.apply(detector_spec("tinydet:v1", 1));
        o.reconcile(&reg, 0);
        // silence past grace but short of eviction: NotReady, pods stay
        let acts = o.reconcile(&reg, 30_000);
        assert_eq!(acts.failed_over, 0, "transient silence must not thrash placement");
        assert_eq!(o.running("detector"), 1);
        assert_eq!(o.pods("detector")[0].node, NodeId::new("baoyun"));
    }

    #[test]
    fn cloud_placement_targets_cloud_nodes() {
        let (mut o, reg) = setup();
        o.apply(AppSpec {
            name: "heavydet".into(),
            image: "heavydet:v1".into(),
            replicas: 1,
            placement: Placement::Cloud,
        });
        o.reconcile(&reg, 0);
        assert_eq!(o.pods("heavydet")[0].node, NodeId::new("ground-1"));
    }
}
