//! EdgeMesh — service discovery + traffic proxy/relay selection.
//!
//! Paper §3.1/§3.2: EdgeMesh "provides simple service discovery and
//! traffic proxy functions for satellite service, thereby shielding the
//! complex network structure", and "EdgeMesh-Agent with relay capability
//! can automatically become a relay server, providing other nodes with
//! the functions of assisting hole punching and relaying".
//!
//! Model: services register endpoints on nodes; resolution prefers local
//! endpoints, then direct remote, then a relay-capable agent.

use std::collections::BTreeMap;

use super::NodeId;

#[derive(Clone, Debug, PartialEq)]
pub struct Endpoint {
    pub node: NodeId,
    pub service: String,
    pub port: u16,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Route {
    /// Endpoint on the caller's own node.
    Local(Endpoint),
    /// Direct connection to the endpoint's node.
    Direct(Endpoint),
    /// Via a relay agent (hole-punching assisted).
    Relayed { via: NodeId, endpoint: Endpoint },
}

#[derive(Default)]
pub struct EdgeMesh {
    endpoints: BTreeMap<String, Vec<Endpoint>>,
    /// node -> node reachability (true = direct connection possible)
    reachable: BTreeMap<(NodeId, NodeId), bool>,
    relays: Vec<NodeId>,
}

impl EdgeMesh {
    pub fn new() -> EdgeMesh {
        EdgeMesh::default()
    }

    pub fn register(&mut self, service: &str, node: NodeId, port: u16) {
        self.endpoints.entry(service.to_string()).or_default().push(Endpoint {
            node,
            service: service.to_string(),
            port,
        });
    }

    pub fn deregister_node(&mut self, node: &NodeId) {
        for eps in self.endpoints.values_mut() {
            eps.retain(|e| &e.node != node);
        }
        self.relays.retain(|r| r != node);
    }

    pub fn set_reachable(&mut self, a: NodeId, b: NodeId, ok: bool) {
        self.reachable.insert((a.clone(), b.clone()), ok);
        self.reachable.insert((b, a), ok);
    }

    fn is_reachable(&self, a: &NodeId, b: &NodeId) -> bool {
        *self.reachable.get(&(a.clone(), b.clone())).unwrap_or(&false)
    }

    /// Promote a node to relay (the merged EdgeMesh-Server capability).
    pub fn promote_relay(&mut self, node: NodeId) {
        if !self.relays.contains(&node) {
            self.relays.push(node);
        }
    }

    /// Resolve `service` from `caller`: local > direct > relayed.
    pub fn resolve(&self, caller: &NodeId, service: &str) -> Option<Route> {
        let eps = self.endpoints.get(service)?;
        if let Some(e) = eps.iter().find(|e| &e.node == caller) {
            return Some(Route::Local(e.clone()));
        }
        if let Some(e) = eps.iter().find(|e| self.is_reachable(caller, &e.node)) {
            return Some(Route::Direct(e.clone()));
        }
        for relay in &self.relays {
            if !self.is_reachable(caller, relay) {
                continue;
            }
            if let Some(e) = eps.iter().find(|e| self.is_reachable(relay, &e.node)) {
                return Some(Route::Relayed { via: relay.clone(), endpoint: e.clone() });
            }
        }
        None
    }

    pub fn endpoints(&self, service: &str) -> &[Endpoint] {
        self.endpoints.get(service).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(s: &str) -> NodeId {
        NodeId::new(s)
    }

    fn mesh() -> EdgeMesh {
        let mut m = EdgeMesh::new();
        m.register("inference", n("baoyun"), 8080);
        m.register("inference", n("ground"), 8080);
        m.register("aggregator", n("ground"), 9090);
        m
    }

    #[test]
    fn prefers_local_endpoint() {
        let m = mesh();
        match m.resolve(&n("baoyun"), "inference") {
            Some(Route::Local(e)) => assert_eq!(e.node, n("baoyun")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn direct_when_reachable() {
        let mut m = mesh();
        m.set_reachable(n("baoyun"), n("ground"), true);
        match m.resolve(&n("baoyun"), "aggregator") {
            Some(Route::Direct(e)) => assert_eq!(e.node, n("ground")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn relayed_when_no_direct_path() {
        let mut m = mesh();
        // baoyun <-> cxls <-> ground, no direct baoyun<->ground
        m.promote_relay(n("cxls"));
        m.set_reachable(n("baoyun"), n("cxls"), true);
        m.set_reachable(n("cxls"), n("ground"), true);
        match m.resolve(&n("baoyun"), "aggregator") {
            Some(Route::Relayed { via, endpoint }) => {
                assert_eq!(via, n("cxls"));
                assert_eq!(endpoint.node, n("ground"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unresolvable_when_partitioned() {
        let m = mesh();
        assert_eq!(m.resolve(&n("baoyun"), "aggregator"), None);
    }

    #[test]
    fn deregister_removes_endpoints() {
        let mut m = mesh();
        m.deregister_node(&n("ground"));
        assert!(m.endpoints("aggregator").is_empty());
        assert_eq!(m.endpoints("inference").len(), 1);
    }

    #[test]
    fn unknown_service_none() {
        let m = mesh();
        assert_eq!(m.resolve(&n("baoyun"), "nope"), None);
    }
}
