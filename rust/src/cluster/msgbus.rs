//! Reliable cloud↔edge messaging over the lossy space link.
//!
//! Paper §3.2 "Reliable connection": "The network between satellites and
//! ground station often suffers from low bandwidth and serious packet
//! loss. The platform manages edge-cloud messages in the same way, and
//! the data is still reliably transmitted in weak network scenarios."
//!
//! Semantics: at-least-once transport + receiver-side dedup by message id
//! = exactly-once delivery to the application, in send order per
//! direction.  Messages queue while no contact window is open.

use std::collections::{BTreeMap, VecDeque};

use crate::link::Link;

use super::Millis;

#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub id: u64,
    pub topic: String,
    pub payload: Vec<u8>,
    pub enqueued_at: Millis,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct BusStats {
    pub enqueued: u64,
    pub delivered: u64,
    pub duplicates_dropped: u64,
    pub send_attempts: u64,
}

/// One direction of the bus (cloud→edge or edge→cloud).
pub struct Channel {
    queue: VecDeque<Message>,
    next_id: u64,
    /// receiver-side dedup window
    seen: BTreeMap<u64, ()>,
    delivered: Vec<Message>,
    pub stats: BusStats,
}

impl Default for Channel {
    fn default() -> Self {
        Self::new()
    }
}

impl Channel {
    pub fn new() -> Channel {
        Channel {
            queue: VecDeque::new(),
            next_id: 1,
            seen: BTreeMap::new(),
            delivered: Vec::new(),
            stats: BusStats::default(),
        }
    }

    pub fn send(&mut self, topic: &str, payload: Vec<u8>, now: Millis) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Message { id, topic: topic.to_string(), payload, enqueued_at: now });
        self.stats.enqueued += 1;
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pump the queue through `link` within `budget_s` of window time.
    /// Undelivered messages stay queued (head-of-line, preserving order).
    /// Returns the number of messages delivered this pump.
    pub fn pump(&mut self, link: &mut Link, budget_s: f64) -> usize {
        let mut remaining = budget_s;
        let mut n = 0;
        while let Some(front) = self.queue.front() {
            let bytes = (front.payload.len() + front.topic.len() + 16) as u64;
            self.stats.send_attempts += 1;
            let t = link.transmit(bytes, remaining);
            remaining -= t.elapsed_s;
            if !t.completed {
                break; // window exhausted or link dead: keep queued
            }
            let msg = self.queue.pop_front().unwrap();
            if self.seen.insert(msg.id, ()).is_none() {
                self.delivered.push(msg);
                self.stats.delivered += 1;
                n += 1;
            } else {
                self.stats.duplicates_dropped += 1;
            }
            if remaining <= 0.0 {
                break;
            }
        }
        n
    }

    /// Drain messages delivered to the application.
    pub fn take_delivered(&mut self) -> Vec<Message> {
        std::mem::take(&mut self.delivered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::{LinkConfig, LossProfile};

    fn stable_link(seed: u64) -> Link {
        Link::new(LinkConfig::downlink(LossProfile::stable()), seed)
    }

    #[test]
    fn messages_flow_in_order() {
        let mut ch = Channel::new();
        let mut link = stable_link(1);
        ch.send("a", vec![0; 100], 0);
        ch.send("b", vec![0; 100], 0);
        let n = ch.pump(&mut link, 10.0);
        assert_eq!(n, 2);
        let got = ch.take_delivered();
        assert_eq!(got[0].topic, "a");
        assert_eq!(got[1].topic, "b");
    }

    #[test]
    fn no_window_no_delivery() {
        let mut ch = Channel::new();
        let mut link = stable_link(2);
        ch.send("x", vec![0; 1_000_000], 0);
        let n = ch.pump(&mut link, 0.0001); // effectively closed window
        assert_eq!(n, 0);
        assert_eq!(ch.pending(), 1, "message must remain queued");
    }

    #[test]
    fn weak_link_still_delivers_eventually() {
        // §3.2's claim: reliable delivery over weak networks.
        let mut ch = Channel::new();
        let mut link = Link::new(LinkConfig::downlink(LossProfile::weak()), 3);
        for i in 0..20 {
            ch.send("t", vec![0; 5_000], i);
        }
        let mut pumps = 0;
        while ch.pending() > 0 && pumps < 100 {
            ch.pump(&mut link, 1.0);
            pumps += 1;
        }
        assert_eq!(ch.pending(), 0, "after {pumps} pumps");
        assert_eq!(ch.stats.delivered, 20);
    }

    #[test]
    fn dedup_drops_duplicate_ids() {
        let mut ch = Channel::new();
        let mut link = stable_link(4);
        ch.send("a", vec![1], 0);
        ch.pump(&mut link, 10.0);
        // simulate a retransmitted duplicate arriving
        ch.queue.push_back(Message { id: 1, topic: "a".into(), payload: vec![1], enqueued_at: 0 });
        ch.pump(&mut link, 10.0);
        assert_eq!(ch.stats.duplicates_dropped, 1);
        assert_eq!(ch.stats.delivered, 1);
    }

    #[test]
    fn stats_consistent() {
        let mut ch = Channel::new();
        let mut link = stable_link(5);
        for _ in 0..10 {
            ch.send("t", vec![0; 100], 0);
        }
        ch.pump(&mut link, 10.0);
        assert_eq!(ch.stats.enqueued, 10);
        assert_eq!(ch.stats.delivered + ch.pending() as u64, 10);
    }
}
