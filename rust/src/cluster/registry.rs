//! Node registry + heartbeat health tracking.
//!
//! CloudCore's view of the cluster: edge nodes (satellites) miss
//! heartbeats whenever the link is down, transitioning Ready → NotReady →
//! Offline.  The paper's EdgeCore keeps the node itself running; the
//! registry is only the *cloud-side* belief, which is exactly what makes
//! offline autonomy necessary.

use std::collections::BTreeMap;

use super::{Millis, NodeId, NodeRole};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Ready,
    /// Heartbeats missed beyond the grace period.
    NotReady,
    /// Declared gone after the eviction period.
    Offline,
}

#[derive(Clone, Debug)]
pub struct NodeRecord {
    pub id: NodeId,
    pub role: NodeRole,
    pub cpu_millicores: u32,
    pub memory_mb: u32,
    pub last_heartbeat: Millis,
    pub registered_at: Millis,
}

pub struct Registry {
    nodes: BTreeMap<NodeId, NodeRecord>,
    /// Ready → NotReady after this silence.
    pub grace_ms: Millis,
    /// NotReady → Offline after this silence.
    pub eviction_ms: Millis,
}

impl Registry {
    pub fn new(grace_ms: Millis, eviction_ms: Millis) -> Registry {
        assert!(eviction_ms >= grace_ms);
        Registry { nodes: BTreeMap::new(), grace_ms, eviction_ms }
    }

    pub fn register(&mut self, id: NodeId, role: NodeRole, cpu_millicores: u32, memory_mb: u32, now: Millis) {
        self.nodes.insert(
            id.clone(),
            NodeRecord { id, role, cpu_millicores, memory_mb, last_heartbeat: now, registered_at: now },
        );
    }

    pub fn heartbeat(&mut self, id: &NodeId, now: Millis) -> bool {
        match self.nodes.get_mut(id) {
            Some(n) => {
                n.last_heartbeat = now;
                true
            }
            None => false,
        }
    }

    /// Cloud-side belief about a node's health at `now`.
    ///
    /// Boundary semantics are **inclusive** on both thresholds, so every
    /// silence maps to exactly one status with no dead millisecond:
    /// `silence <= grace_ms` is `Ready`, `grace_ms < silence <=
    /// eviction_ms` is `NotReady`, and `silence > eviction_ms` is
    /// `Offline`.  A node heard from exactly `grace_ms` ago is still
    /// Ready; exactly `eviction_ms` ago is still NotReady — degradation
    /// happens strictly *after* each threshold.  A heartbeat in the
    /// future of `now` saturates to zero silence (Ready), never panics.
    pub fn status(&self, id: &NodeId, now: Millis) -> Option<NodeStatus> {
        self.nodes.get(id).map(|n| {
            let silence = now.saturating_sub(n.last_heartbeat);
            if silence <= self.grace_ms {
                NodeStatus::Ready
            } else if silence <= self.eviction_ms {
                NodeStatus::NotReady
            } else {
                NodeStatus::Offline
            }
        })
    }

    pub fn nodes(&self) -> impl Iterator<Item = &NodeRecord> {
        self.nodes.values()
    }

    pub fn ready_nodes(&self, now: Millis) -> Vec<NodeId> {
        self.nodes
            .keys()
            .filter(|id| self.status(id, now) == Some(NodeStatus::Ready))
            .cloned()
            .collect()
    }

    pub fn get(&self, id: &NodeId) -> Option<&NodeRecord> {
        self.nodes.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(name: &str) -> NodeId {
        NodeId::new(name)
    }

    fn reg() -> Registry {
        let mut r = Registry::new(10_000, 60_000);
        r.register(edge("baoyun"), NodeRole::Edge, 4000, 8192, 0);
        r.register(edge("ground"), NodeRole::Cloud, 64_000, 262_144, 0);
        r
    }

    #[test]
    fn fresh_node_is_ready() {
        let r = reg();
        assert_eq!(r.status(&edge("baoyun"), 5_000), Some(NodeStatus::Ready));
    }

    #[test]
    fn silence_degrades_to_notready_then_offline() {
        let r = reg();
        assert_eq!(r.status(&edge("baoyun"), 30_000), Some(NodeStatus::NotReady));
        assert_eq!(r.status(&edge("baoyun"), 100_000), Some(NodeStatus::Offline));
    }

    #[test]
    fn heartbeat_restores_ready() {
        let mut r = reg();
        assert_eq!(r.status(&edge("baoyun"), 100_000), Some(NodeStatus::Offline));
        assert!(r.heartbeat(&edge("baoyun"), 100_000));
        assert_eq!(r.status(&edge("baoyun"), 100_001), Some(NodeStatus::Ready));
    }

    #[test]
    fn status_boundaries_are_inclusive() {
        // grace 10_000, eviction 60_000, last heartbeat at 0: both
        // thresholds keep the milder status at exact equality and
        // degrade strictly after it
        let r = reg();
        let sat = edge("baoyun");
        assert_eq!(r.status(&sat, 10_000), Some(NodeStatus::Ready), "silence == grace_ms");
        assert_eq!(r.status(&sat, 10_001), Some(NodeStatus::NotReady), "grace_ms + 1");
        assert_eq!(r.status(&sat, 60_000), Some(NodeStatus::NotReady), "silence == eviction_ms");
        assert_eq!(r.status(&sat, 60_001), Some(NodeStatus::Offline), "eviction_ms + 1");
        // a future-dated heartbeat saturates: silence 0, still Ready
        let mut r = reg();
        r.heartbeat(&sat, 50_000);
        assert_eq!(r.status(&sat, 40_000), Some(NodeStatus::Ready));
    }

    #[test]
    fn notready_node_recovers_to_ready_on_heartbeat() {
        let mut r = reg();
        let sat = edge("baoyun");
        // silent past grace but short of eviction: NotReady, not gone
        assert_eq!(r.status(&sat, 30_000), Some(NodeStatus::NotReady));
        assert!(r.heartbeat(&sat, 30_000));
        assert_eq!(r.status(&sat, 30_000), Some(NodeStatus::Ready), "recovery is immediate");
        assert_eq!(r.status(&sat, 40_000), Some(NodeStatus::Ready));
        assert!(r.ready_nodes(30_000).contains(&sat));
    }

    #[test]
    fn unknown_node_heartbeat_rejected() {
        let mut r = reg();
        assert!(!r.heartbeat(&edge("ghost"), 0));
        assert_eq!(r.status(&edge("ghost"), 0), None);
    }

    #[test]
    fn ready_nodes_filters() {
        let mut r = reg();
        r.heartbeat(&edge("ground"), 50_000);
        let ready = r.ready_nodes(55_000);
        assert_eq!(ready, vec![edge("ground")]);
    }
}
