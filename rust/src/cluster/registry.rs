//! Node registry + heartbeat health tracking.
//!
//! CloudCore's view of the cluster: edge nodes (satellites) miss
//! heartbeats whenever the link is down, transitioning Ready → NotReady →
//! Offline.  The paper's EdgeCore keeps the node itself running; the
//! registry is only the *cloud-side* belief, which is exactly what makes
//! offline autonomy necessary.

use std::collections::BTreeMap;

use super::{Millis, NodeId, NodeRole};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Ready,
    /// Heartbeats missed beyond the grace period.
    NotReady,
    /// Declared gone after the eviction period.
    Offline,
}

#[derive(Clone, Debug)]
pub struct NodeRecord {
    pub id: NodeId,
    pub role: NodeRole,
    pub cpu_millicores: u32,
    pub memory_mb: u32,
    pub last_heartbeat: Millis,
    pub registered_at: Millis,
}

pub struct Registry {
    nodes: BTreeMap<NodeId, NodeRecord>,
    /// Ready → NotReady after this silence.
    pub grace_ms: Millis,
    /// NotReady → Offline after this silence.
    pub eviction_ms: Millis,
}

impl Registry {
    pub fn new(grace_ms: Millis, eviction_ms: Millis) -> Registry {
        assert!(eviction_ms >= grace_ms);
        Registry { nodes: BTreeMap::new(), grace_ms, eviction_ms }
    }

    pub fn register(&mut self, id: NodeId, role: NodeRole, cpu_millicores: u32, memory_mb: u32, now: Millis) {
        self.nodes.insert(
            id.clone(),
            NodeRecord { id, role, cpu_millicores, memory_mb, last_heartbeat: now, registered_at: now },
        );
    }

    pub fn heartbeat(&mut self, id: &NodeId, now: Millis) -> bool {
        match self.nodes.get_mut(id) {
            Some(n) => {
                n.last_heartbeat = now;
                true
            }
            None => false,
        }
    }

    pub fn status(&self, id: &NodeId, now: Millis) -> Option<NodeStatus> {
        self.nodes.get(id).map(|n| {
            let silence = now.saturating_sub(n.last_heartbeat);
            if silence <= self.grace_ms {
                NodeStatus::Ready
            } else if silence <= self.eviction_ms {
                NodeStatus::NotReady
            } else {
                NodeStatus::Offline
            }
        })
    }

    pub fn nodes(&self) -> impl Iterator<Item = &NodeRecord> {
        self.nodes.values()
    }

    pub fn ready_nodes(&self, now: Millis) -> Vec<NodeId> {
        self.nodes
            .keys()
            .filter(|id| self.status(id, now) == Some(NodeStatus::Ready))
            .cloned()
            .collect()
    }

    pub fn get(&self, id: &NodeId) -> Option<&NodeRecord> {
        self.nodes.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(name: &str) -> NodeId {
        NodeId::new(name)
    }

    fn reg() -> Registry {
        let mut r = Registry::new(10_000, 60_000);
        r.register(edge("baoyun"), NodeRole::Edge, 4000, 8192, 0);
        r.register(edge("ground"), NodeRole::Cloud, 64_000, 262_144, 0);
        r
    }

    #[test]
    fn fresh_node_is_ready() {
        let r = reg();
        assert_eq!(r.status(&edge("baoyun"), 5_000), Some(NodeStatus::Ready));
    }

    #[test]
    fn silence_degrades_to_notready_then_offline() {
        let r = reg();
        assert_eq!(r.status(&edge("baoyun"), 30_000), Some(NodeStatus::NotReady));
        assert_eq!(r.status(&edge("baoyun"), 100_000), Some(NodeStatus::Offline));
    }

    #[test]
    fn heartbeat_restores_ready() {
        let mut r = reg();
        assert_eq!(r.status(&edge("baoyun"), 100_000), Some(NodeStatus::Offline));
        assert!(r.heartbeat(&edge("baoyun"), 100_000));
        assert_eq!(r.status(&edge("baoyun"), 100_001), Some(NodeStatus::Ready));
    }

    #[test]
    fn unknown_node_heartbeat_rejected() {
        let mut r = reg();
        assert!(!r.heartbeat(&edge("ghost"), 0));
        assert_eq!(r.status(&edge("ghost"), 0), None);
    }

    #[test]
    fn ready_nodes_filters() {
        let mut r = reg();
        r.heartbeat(&edge("ground"), 50_000);
        let ready = r.ready_nodes(55_000);
        assert_eq!(ready, vec![edge("ground")]);
    }
}
