//! KubeEdge-like cluster substrate (paper §3.2).
//!
//! The paper manages the satellite with KubeEdge: a CloudCore in the
//! ground cloud and a lightweight EdgeCore on the satellite, connected by
//! an unreliable space link.  We reproduce the behaviours the paper
//! claims, each in its own module:
//!
//! * [`registry`]     — node registration + heartbeat health (Ready /
//!                      NotReady / Offline).
//! * [`metastore`]    — MetaManager: versioned metadata KV with local
//!                      snapshots ("offline autonomous": apps are managed
//!                      and restored from storage metadata while offline).
//! * [`msgbus`]       — reliable cloud↔edge delivery over the lossy link
//!                      ("the data is still reliably transmitted in weak
//!                      network scenarios").
//! * [`orchestrator`] — containerized app orchestration: desired-state
//!                      reconcile, restart policy, rolling update
//!                      ("automatically scaled, fault-tolerant").
//! * [`edgemesh`]     — EdgeMesh service discovery + relay selection.
//!
//! Time is virtual everywhere (`Millis`), so failure-injection tests are
//! deterministic and instant.

pub mod edgemesh;
pub mod metastore;
pub mod msgbus;
pub mod orchestrator;
pub mod registry;

/// Virtual time in milliseconds since sim epoch.
pub type Millis = u64;

/// Node identity. Cloud nodes live in the ground segment, edge nodes on
/// satellites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeRole {
    Cloud,
    Edge,
}

#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub String);

impl NodeId {
    pub fn new(s: impl Into<String>) -> NodeId {
        NodeId(s.into())
    }
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
