//! Onboard energy model (paper Tables 2–3 and the 17% headline).
//!
//! The paper telemeters per-subsystem voltage/current on Baoyun and
//! reports: Table 2 — platform power distribution summing to 51.07 W with
//! payloads at 26.93 W; Table 3 — payload breakdown where the Raspberry
//! Pi compute module draws 8.78 W (33% of payloads, ≈17% of the total).
//!
//! We seed the model with the same nameplate wattages and *re-derive* the
//! shares by integrating duty-cycled power over a simulated mission
//! timeline: compute draws full power only while inference batches run,
//! comm only during contact windows, camera only during captures.  The
//! 17% figure is an output of the simulation, not a constant.
//!
//! The duty cycles handed to [`EnergyMeter::advance`] come from the
//! mission-time core ([`crate::sim::Timeline`]): single-satellite runs
//! integrate the configured nominal duties of the degenerate
//! always-in-contact timeline, while the constellation derives comm duty
//! from actual link airtime inside contact windows and camera duty from
//! capture events.

use std::collections::BTreeMap;

/// Platform subsystems (Table 2 rows).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Subsystem {
    Electrical,
    Propulsion,
    Guidance,
    Avionics,
    Comm,
    Payloads,
}

impl Subsystem {
    pub fn all() -> [Subsystem; 6] {
        [
            Subsystem::Electrical,
            Subsystem::Propulsion,
            Subsystem::Guidance,
            Subsystem::Avionics,
            Subsystem::Comm,
            Subsystem::Payloads,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Subsystem::Electrical => "Electrical",
            Subsystem::Propulsion => "Propulsion",
            Subsystem::Guidance => "Guidance",
            Subsystem::Avionics => "Avionics",
            Subsystem::Comm => "Comm.",
            Subsystem::Payloads => "Payloads",
        }
    }

    /// Nameplate active power, W (Table 2).
    pub fn nameplate_w(self) -> f64 {
        match self {
            Subsystem::Electrical => 1.47,
            Subsystem::Propulsion => 7.00,
            Subsystem::Guidance => 5.43,
            Subsystem::Avionics => 4.81,
            Subsystem::Comm => 5.43,
            Subsystem::Payloads => 26.93,
        }
    }
}

/// Payload subsystems (Table 3 rows).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Payload {
    Camera,
    Occultation,
    Tribology,
    Mems,
    Adsbs,
    RaspberryPi,
}

impl Payload {
    pub fn all() -> [Payload; 6] {
        [
            Payload::Camera,
            Payload::Occultation,
            Payload::Tribology,
            Payload::Mems,
            Payload::Adsbs,
            Payload::RaspberryPi,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Payload::Camera => "Camera",
            Payload::Occultation => "Occultation",
            Payload::Tribology => "Tribology",
            Payload::Mems => "Mems",
            Payload::Adsbs => "Adsbs",
            Payload::RaspberryPi => "Raspberry Pi",
        }
    }

    /// Nameplate active power, W (Table 3).
    pub fn nameplate_w(self) -> f64 {
        match self {
            Payload::Camera => 0.09,
            Payload::Occultation => 6.26,
            Payload::Tribology => 5.68,
            Payload::Mems => 0.95,
            Payload::Adsbs => 6.12,
            Payload::RaspberryPi => 8.78,
        }
    }
}

/// Total platform power when everything is active (Table 2 "Sum").
pub fn table2_sum_w() -> f64 {
    Subsystem::all().iter().map(|s| s.nameplate_w()).sum()
}

/// Energy accumulator: integrates P·dt per subsystem/payload.
///
/// Idle duty floors (Pi and Comm draw a floor fraction of nameplate even
/// when idle) come from the `energy` config section; the defaults are
/// the values previously hardcoded here, so results are unchanged until
/// a scenario models low-idle hardware.
#[derive(Clone, Debug)]
pub struct EnergyMeter {
    /// Joules per platform subsystem.
    platform_j: BTreeMap<&'static str, f64>,
    /// Joules per payload.
    payload_j: BTreeMap<&'static str, f64>,
    pub elapsed_s: f64,
    /// Raspberry Pi idle draw as a fraction of active draw.
    pi_idle_floor: f64,
    /// Comm subsystem idle draw as a fraction of nameplate.
    comm_idle_floor: f64,
    /// Federated local-training energy — its own ledger line so the H2
    /// accounting keeps inference and training distinguishable.
    training_j: f64,
}

impl Default for EnergyMeter {
    fn default() -> EnergyMeter {
        let d = crate::config::EnergyConfig::default();
        EnergyMeter::with_floors(d.pi_idle_floor, d.comm_idle_floor)
    }
}

impl EnergyMeter {
    pub fn new() -> EnergyMeter {
        EnergyMeter::default()
    }

    /// Meter with explicit idle floors (the `energy` config section).
    pub fn with_floors(pi_idle_floor: f64, comm_idle_floor: f64) -> EnergyMeter {
        EnergyMeter {
            platform_j: BTreeMap::new(),
            payload_j: BTreeMap::new(),
            elapsed_s: 0.0,
            pi_idle_floor: pi_idle_floor.clamp(0.0, 1.0),
            comm_idle_floor: comm_idle_floor.clamp(0.0, 1.0),
            training_j: 0.0,
        }
    }

    /// Charge one federated local-training burst: `dt_s` seconds of the
    /// Pi at full active draw, on top of whatever duty the enclosing
    /// period integrates (training overlays the period, it does not add
    /// mission time).  Returns the joules charged.
    pub fn add_training(&mut self, dt_s: f64) -> f64 {
        assert!(dt_s >= 0.0);
        let j = Payload::RaspberryPi.nameplate_w() * dt_s;
        self.training_j += j;
        j
    }

    pub fn training_j(&self) -> f64 {
        self.training_j
    }

    /// Advance time by dt with the given duty cycles (0..1) per subsystem.
    ///
    /// `compute_duty` scales the Raspberry Pi (inference running),
    /// `comm_duty` the Comm subsystem (contact window + transmitting),
    /// `camera_duty` the camera (capturing).  Always-on subsystems
    /// integrate at nameplate; idle compute draws a floor fraction.
    pub fn advance(&mut self, dt_s: f64, compute_duty: f64, comm_duty: f64, camera_duty: f64) {
        assert!(dt_s >= 0.0);
        self.elapsed_s += dt_s;
        for s in Subsystem::all() {
            let duty = match s {
                Subsystem::Comm => {
                    self.comm_idle_floor
                        + (1.0 - self.comm_idle_floor) * comm_duty.clamp(0.0, 1.0)
                }
                Subsystem::Payloads => continue, // integrated per-payload below
                _ => 1.0,
            };
            *self.platform_j.entry(s.name()).or_insert(0.0) += s.nameplate_w() * duty * dt_s;
        }
        for p in Payload::all() {
            let duty = match p {
                Payload::RaspberryPi => {
                    self.pi_idle_floor
                        + (1.0 - self.pi_idle_floor) * compute_duty.clamp(0.0, 1.0)
                }
                Payload::Camera => camera_duty.clamp(0.0, 1.0),
                _ => 1.0, // science payloads run continuously
            };
            *self.payload_j.entry(p.name()).or_insert(0.0) += p.nameplate_w() * duty * dt_s;
        }
    }

    pub fn payload_total_j(&self) -> f64 {
        self.payload_j.values().sum::<f64>() + self.training_j
    }

    pub fn platform_total_j(&self) -> f64 {
        self.platform_j.values().sum::<f64>() + self.payload_total_j()
    }

    pub fn payload_j(&self, p: Payload) -> f64 {
        *self.payload_j.get(p.name()).unwrap_or(&0.0)
    }

    pub fn platform_j(&self, s: Subsystem) -> f64 {
        if s == Subsystem::Payloads {
            self.payload_total_j()
        } else {
            *self.platform_j.get(s.name()).unwrap_or(&0.0)
        }
    }

    /// Mean power per platform subsystem, W — the regenerated Table 2.
    pub fn table2_rows(&self) -> Vec<(&'static str, f64)> {
        let t = self.elapsed_s.max(1e-9);
        let mut rows: Vec<(&'static str, f64)> = Subsystem::all()
            .iter()
            .map(|&s| (s.name(), self.platform_j(s) / t))
            .collect();
        rows.push(("Sum", self.platform_total_j() / t));
        rows
    }

    /// Mean power per payload, W — the regenerated Table 3.
    pub fn table3_rows(&self) -> Vec<(&'static str, f64)> {
        let t = self.elapsed_s.max(1e-9);
        Payload::all().iter().map(|&p| (p.name(), self.payload_j(p) / t)).collect()
    }

    /// Fraction of total onboard energy consumed by computing (the
    /// paper's ≈17% headline, H2).  Training runs on the Pi, so its
    /// ledger line counts as computing; without federated rounds it is
    /// zero and the share is unchanged.
    pub fn compute_share(&self) -> f64 {
        (self.payload_j(Payload::RaspberryPi) + self.training_j)
            / self.platform_total_j().max(1e-9)
    }

    /// Fraction of payload energy consumed by computing (paper: 33%).
    /// Training counts as computing here too, consistent with
    /// [`Self::compute_share`].
    pub fn compute_share_of_payloads(&self) -> f64 {
        (self.payload_j(Payload::RaspberryPi) + self.training_j)
            / self.payload_total_j().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_sum_matches_paper() {
        assert!((table2_sum_w() - 51.07).abs() < 1e-9, "{}", table2_sum_w());
    }

    #[test]
    fn nameplate_compute_share_is_17pct() {
        // The paper's arithmetic: 8.78 / 51.07 ≈ 17.2%.
        let share = Payload::RaspberryPi.nameplate_w() / table2_sum_w();
        assert!((share - 0.17).abs() < 0.005, "{share}");
    }

    #[test]
    fn full_duty_reproduces_nameplate_rows() {
        let mut m = EnergyMeter::new();
        m.advance(3600.0, 1.0, 1.0, 1.0);
        for (name, w) in m.table3_rows() {
            let want = Payload::all().iter().find(|p| p.name() == name).unwrap().nameplate_w();
            assert!((w - want).abs() < 1e-9, "{name}: {w} vs {want}");
        }
        // NOTE: the paper's tables are internally inconsistent — Table 3's
        // payload rows sum to 27.88 W while Table 2 reports payloads at
        // 26.93 W (telemetry averaged over different duty cycles).  At
        // full duty our platform total is 24.14 + 27.88 = 52.02 W; the
        // published 51.07 W emerges under realistic duty cycling (see
        // compute_share_close_to_17pct_at_realistic_duty).
        let sum = m.platform_total_j() / 3600.0;
        assert!((sum - 52.02).abs() < 1e-6, "sum {sum}");
    }

    #[test]
    fn compute_share_close_to_17pct_at_realistic_duty() {
        // Over an orbit: inference runs most of the sunlit side, comm only
        // in windows.  With high compute duty the share approaches 17%.
        let mut m = EnergyMeter::new();
        m.advance(5677.0, 0.9, 0.08, 0.3);
        let share = m.compute_share();
        assert!((0.12..0.20).contains(&share), "share {share}");
    }

    #[test]
    fn idle_compute_draws_floor() {
        let mut m = EnergyMeter::new();
        m.advance(100.0, 0.0, 0.0, 0.0);
        let pi = m.payload_j(Payload::RaspberryPi);
        assert!((pi - 8.78 * 0.25 * 100.0).abs() < 1e-9);
    }

    #[test]
    fn configured_floors_drive_idle_draw() {
        // Low-idle hardware: an idle Pi and Comm draw far less, and full
        // duty still reaches nameplate.
        let mut low = EnergyMeter::with_floors(0.05, 0.02);
        low.advance(100.0, 0.0, 0.0, 0.0);
        assert!((low.payload_j(Payload::RaspberryPi) - 8.78 * 0.05 * 100.0).abs() < 1e-9);
        assert!((low.platform_j(Subsystem::Comm) - 5.43 * 0.02 * 100.0).abs() < 1e-9);
        let mut full = EnergyMeter::with_floors(0.05, 0.02);
        full.advance(100.0, 1.0, 1.0, 1.0);
        assert!((full.payload_j(Payload::RaspberryPi) - 8.78 * 100.0).abs() < 1e-6);
        assert!((full.platform_j(Subsystem::Comm) - 5.43 * 100.0).abs() < 1e-6);
    }

    #[test]
    fn default_floors_match_legacy_constants() {
        // `EnergyMeter::new()` must integrate exactly as the pre-config
        // hardcoded floors (0.25 Pi, 0.15 Comm) did.
        let mut m = EnergyMeter::new();
        m.advance(100.0, 0.0, 0.0, 0.0);
        assert!((m.payload_j(Payload::RaspberryPi) - 8.78 * 0.25 * 100.0).abs() < 1e-9);
        assert!((m.platform_j(Subsystem::Comm) - 5.43 * 0.15 * 100.0).abs() < 1e-9);
    }

    #[test]
    fn training_line_adds_to_totals_and_compute_share() {
        let mut m = EnergyMeter::new();
        m.advance(100.0, 0.0, 0.0, 0.0);
        let before = m.platform_total_j();
        let share_before = m.compute_share();
        let j = m.add_training(10.0);
        assert!((j - 8.78 * 10.0).abs() < 1e-9, "training runs at Pi nameplate");
        assert!((m.training_j() - j).abs() < 1e-12);
        assert!((m.platform_total_j() - before - j).abs() < 1e-9);
        assert!(m.compute_share() > share_before, "training counts as computing");
        // the Table-3 rows themselves are untouched — training is its
        // own ledger line, not a duty on the inference row
        assert!((m.payload_j(Payload::RaspberryPi) - 8.78 * 0.25 * 100.0).abs() < 1e-9);
    }

    #[test]
    fn energy_monotone_in_time() {
        let mut m = EnergyMeter::new();
        m.advance(10.0, 0.5, 0.5, 0.5);
        let e1 = m.platform_total_j();
        m.advance(10.0, 0.5, 0.5, 0.5);
        assert!(m.platform_total_j() > e1);
    }

    #[test]
    fn compute_share_of_payloads_near_third_at_full_duty() {
        let mut m = EnergyMeter::new();
        m.advance(1000.0, 1.0, 1.0, 1.0);
        let share = m.compute_share_of_payloads();
        // paper says "33% of the total energy consumed by the payloads";
        // against Table 3's own row sum it is 8.78 / 27.88 ≈ 31.5%.
        assert!((share - 8.78 / 27.88).abs() < 0.01, "{share}");
    }
}
