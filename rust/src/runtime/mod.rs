//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! rust request path.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module is
//! everything the serving binary needs: a CPU PJRT client, one compiled
//! executable per (model, batch-size) variant, literal marshalling, and
//! batch padding so callers can submit ragged batches.
//!
//! Interchange is HLO **text** (see python/compile/aot.py): jax ≥ 0.5
//! emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.

mod manifest;

pub use manifest::{Manifest, ModelInfo};

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::util::buffer::{PixelBuf, PixelPool, PoolStats};

/// Names of the detector artifacts (file stem prefix in artifacts/).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Model {
    /// Onboard lightweight detector (YOLOv3-tiny stand-in).
    Tiny,
    /// Incrementally-retrained onboard detector (Sedna hot-swap target).
    TinyV2,
    /// Ground high-precision detector (YOLOv3 stand-in).
    Heavy,
    /// Redundancy (cloud-cover) filter.
    CloudScore,
}

impl Model {
    pub fn stem(self) -> &'static str {
        match self {
            Model::Tiny => "tinydet",
            Model::TinyV2 => "tinydet_v2",
            Model::Heavy => "heavydet",
            Model::CloudScore => "cloudscore",
        }
    }

    pub fn all() -> [Model; 4] {
        [Model::Tiny, Model::TinyV2, Model::Heavy, Model::CloudScore]
    }
}

struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
    out_cols: usize, // per-image f32s in the output
}

/// A PJRT CPU client plus lazily-compiled executables per (model, batch).
///
/// Concurrency audit (staged-engine refactor): every piece of mutable
/// state is behind a `Mutex` — `exes` (compile-once cache, held only for
/// lookup/compile, never across `execute`), `costs` (calibration table,
/// held only for lookup/insert inside `plan`/`calibrate`), and
/// `exec_locks` below.  Cached executables are leaked to `&'static`, so
/// worker threads execute without touching the cache lock.  Stage workers
/// may therefore share `&Runtime` freely; the only serialization point is
/// the per-model execution lock.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: Mutex<HashMap<(Model, usize), &'static LoadedExe>>,
    /// Measured per-call seconds per (model, batch), filled by
    /// [`Runtime::calibrate`].  Perf finding (EXPERIMENTS.md §Perf): the
    /// interpret-lowered b8 artifacts run *slower per tile* than b1 on
    /// CPU-PJRT, so `execute` picks the cheapest plan instead of blindly
    /// padding to the largest exported batch.
    costs: Mutex<HashMap<(Model, usize), f64>>,
    /// Per-model execution locks: concurrent `execute` calls on *different*
    /// models (onboard Tiny vs ground Heavy) overlap, while calls on the
    /// same model serialize — CPU-PJRT gains nothing from oversubscribing
    /// one executable and the lock keeps its arena usage bounded.
    exec_locks: Mutex<HashMap<Model, Arc<Mutex<()>>>>,
    /// Marshalling scratch pool (`max_batch * tile_px` f32 per buffer):
    /// callers gather ragged batches into a checkout instead of building
    /// per-chunk `Vec`s, and `execute` pads tail calls in place here.
    scratch: PixelPool,
    /// Output-row pool (`max_batch * widest out_cols` f32 per buffer):
    /// [`Runtime::execute`] assembles its result directly into a pooled
    /// buffer instead of growing a fresh `Vec` per call, so the steady
    /// state batch→rows hop is allocation-free too.  Requests wider than
    /// one buffer (n beyond `max_batch`) fall back to a one-off `Vec`.
    rows: PixelPool,
}

/// Inference output rows (`n * out_cols` f32s) from [`Runtime::execute`],
/// backed by the runtime's row pool when the request fits one pooled
/// buffer.  Derefs to the filled `[f32]` prefix; dropping it returns the
/// storage, so a steady-state infer loop recycles its output rows the
/// same way it recycles its marshalling scratch.
pub struct OutputRows {
    buf: PixelBuf,
    len: usize,
}

impl OutputRows {
    /// The filled rows (`n * out_cols` f32s).
    pub fn as_slice(&self) -> &[f32] {
        &self.buf[..self.len]
    }
}

impl std::ops::Deref for OutputRows {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl<'a> IntoIterator for &'a OutputRows {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl fmt::Debug for OutputRows {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OutputRows")
            .field("len", &self.len)
            .field("pooled", &self.buf.is_pooled())
            .finish()
    }
}

impl Runtime {
    /// Open `artifacts/` (built by `make artifacts`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        let max_batch = manifest.batch_sizes.iter().copied().max().unwrap_or(1);
        let scratch = PixelPool::new(max_batch * manifest.tile * manifest.tile * 3);
        // widest per-image output across models: detector head rows
        // (grid² · head_d) dwarf cloudscore's 3, so one pool serves both
        let max_cols = (manifest.grid * manifest.grid * manifest.head_d).max(3);
        let rows = PixelPool::new(max_batch * max_cols);
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: Mutex::new(HashMap::new()),
            costs: Mutex::new(HashMap::new()),
            exec_locks: Mutex::new(HashMap::new()),
            scratch,
            rows,
        })
    }

    /// Check out a marshalling scratch buffer (`max_batch * tile_px`
    /// f32, contents unspecified).  Callers gather tile batches into it
    /// and pass only the filled prefix to [`Runtime::execute`]; dropping
    /// it returns the storage, so steady-state marshalling is
    /// allocation-free and pays no per-checkout clear.
    pub fn scratch_buf(&self) -> PixelBuf {
        self.scratch.checkout_dirty()
    }

    /// Scratch-pool accounting (asserted by the zero-copy path tests).
    pub fn scratch_stats(&self) -> PoolStats {
        self.scratch.stats()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Per-image output width: G*G*HEAD_D for detectors, 3 for cloudscore.
    fn out_cols(&self, model: Model) -> usize {
        match model {
            Model::CloudScore => 3,
            _ => self.manifest.grid * self.manifest.grid * self.manifest.head_d,
        }
    }

    fn artifact_path(&self, model: Model, batch: usize) -> PathBuf {
        self.dir.join(format!("{}_b{}.hlo.txt", model.stem(), batch))
    }

    /// Compile (once) and cache the executable for (model, batch).
    fn exe(&self, model: Model, batch: usize) -> Result<&'static LoadedExe> {
        if !self.manifest.batch_sizes.contains(&batch) {
            return Err(anyhow!(
                "batch {batch} not exported; available: {:?}",
                self.manifest.batch_sizes
            ));
        }
        let mut guard = self.exes.lock().unwrap();
        if let Some(e) = guard.get(&(model, batch)) {
            return Ok(e);
        }
        let path = self.artifact_path(model, batch);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(wrap_xla)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap_xla)?;
        let loaded = Box::leak(Box::new(LoadedExe { exe, out_cols: self.out_cols(model) }));
        guard.insert((model, batch), loaded);
        Ok(loaded)
    }

    /// Eagerly compile every (model, batch) pair — serving startup path.
    pub fn warmup(&self) -> Result<()> {
        for model in Model::all() {
            for &b in &self.manifest.batch_sizes.clone() {
                self.exe(model, b)?;
            }
        }
        Ok(())
    }

    /// Measure per-call cost of every (model, batch) variant so `execute`
    /// can choose the cheapest batching plan.  Cheap (a few dummy calls);
    /// run once at startup after [`Runtime::warmup`].
    pub fn calibrate(&self) -> Result<()> {
        let t = self.manifest.tile;
        for model in Model::all() {
            for &b in &self.manifest.batch_sizes.clone() {
                let input = vec![0.5f32; b * t * t * 3];
                self.execute_exact(model, b, &input)?; // warm
                let reps = 3;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    self.execute_exact(model, b, &input)?;
                }
                let per_call = t0.elapsed().as_secs_f64() / reps as f64;
                self.costs.lock().unwrap().insert((model, b), per_call);
            }
        }
        Ok(())
    }

    /// Cheapest sequence of exported batch sizes covering `n` tiles.
    /// Uncalibrated fallback: one padded call at the smallest fitting (or
    /// largest) batch — the pre-perf-pass behaviour.
    fn plan(&self, model: Model, n: usize) -> Vec<usize> {
        let sizes = &self.manifest.batch_sizes;
        let costs = self.costs.lock().unwrap();
        if !sizes.iter().all(|b| costs.contains_key(&(model, *b))) {
            let b = sizes.iter().copied().filter(|&b| b >= n).min()
                .unwrap_or_else(|| sizes.iter().copied().max().unwrap_or(1));
            let mut plan = Vec::new();
            let mut left = n;
            loop {
                plan.push(b);
                if left <= b {
                    return plan;
                }
                left -= b;
            }
        }
        // DP over remaining tiles (n is small: <= a few hundred)
        let mut best: Vec<(f64, Option<usize>)> = vec![(0.0, None); n + 1];
        for left in 1..=n {
            let mut b_cost = f64::INFINITY;
            let mut b_choice = None;
            for &b in sizes {
                let c = costs[&(model, b)] + best[left.saturating_sub(b)].0;
                if c < b_cost {
                    b_cost = c;
                    b_choice = Some(b);
                }
            }
            best[left] = (b_cost, b_choice);
        }
        let mut plan = Vec::new();
        let mut left = n;
        while left > 0 {
            let b = best[left].1.expect("plan");
            plan.push(b);
            left = left.saturating_sub(b);
        }
        plan
    }

    /// Execute `model` on exactly `batch` images (`batch * tile * tile * 3`
    /// f32s, NHWC) and return the raw output rows
    /// (`batch * out_cols` f32s).
    pub fn execute_exact(&self, model: Model, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        let t = self.manifest.tile;
        let want = batch * t * t * 3;
        if input.len() != want {
            return Err(anyhow!("input len {} != {want}", input.len()));
        }
        let loaded: &LoadedExe = self.exe(model, batch)?;
        let model_lock = {
            let mut guard = self.exec_locks.lock().unwrap();
            Arc::clone(guard.entry(model).or_default())
        };
        let _exec_guard = model_lock.lock().unwrap();
        let lit = xla::Literal::vec1(input)
            .reshape(&[batch as i64, t as i64, t as i64, 3])
            .map_err(wrap_xla)?;
        let result = loaded.exe.execute::<xla::Literal>(&[lit]).map_err(wrap_xla)?;
        let out = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = out.to_tuple1().map_err(wrap_xla)?;
        let v = out.to_vec::<f32>().map_err(wrap_xla)?;
        debug_assert_eq!(v.len(), batch * loaded.out_cols);
        Ok(v)
    }

    /// Execute `model` on `n` images (any count), splitting/padding across
    /// the exported batch variants along the cheapest calibrated plan.
    /// The result rows come from the pooled output buffers when `n * cols`
    /// fits one buffer (every coordinator chunk does); dropping the
    /// [`OutputRows`] recycles the storage.
    pub fn execute(&self, model: Model, n: usize, input: &[f32]) -> Result<OutputRows> {
        let t = self.manifest.tile;
        let px = t * t * 3;
        assert_eq!(input.len(), n * px, "input length mismatch");
        let cols = self.out_cols(model);
        let total = n * cols;
        let mut out = if total <= self.rows.buf_len() {
            self.rows.checkout_dirty()
        } else {
            // oversize request (n beyond max_batch): one-off allocation,
            // never parked in the pool (pooled buffers are fixed-length)
            PixelBuf::from(vec![0.0f32; total])
        };
        let mut done = 0usize;
        for b in self.plan(model, n) {
            let take = b.min(n - done);
            let dst = &mut out[done * cols..(done + take) * cols];
            if take == b {
                let full =
                    self.execute_exact(model, b, &input[done * px..(done + b) * px])?;
                dst.copy_from_slice(&full);
            } else {
                // pad the tail call in place in pooled scratch, zeroing
                // only the pad rows the executable will actually read
                let mut padded = self.scratch.checkout_dirty();
                padded[..take * px].copy_from_slice(&input[done * px..]);
                padded[take * px..b * px].fill(0.0);
                let full = self.execute_exact(model, b, &padded[..b * px])?;
                dst.copy_from_slice(&full[..take * cols]);
            }
            done += take;
            if done >= n {
                break;
            }
        }
        debug_assert_eq!(done, n);
        Ok(OutputRows { buf: out, len: total })
    }

    /// Output-row pool accounting (asserted by the zero-copy path tests).
    pub fn rows_stats(&self) -> PoolStats {
        self.rows.stats()
    }

    /// Largest exported batch — the coordinator's batcher targets this.
    pub fn max_batch(&self) -> usize {
        self.manifest.batch_sizes.iter().copied().max().unwrap_or(1)
    }
}

/// The xla crate's error type doesn't implement std::error::Error for all
/// variants ergonomically; normalize through strings once, here.
fn wrap_xla<E: std::fmt::Debug>(e: E) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests need `make artifacts` to have run; they are the rust half
    // of the kernel-parity story (see also rust/tests/runtime_parity.rs).
    fn artifacts() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).expect("open artifacts"))
    }

    #[test]
    fn manifest_loaded() {
        let Some(rt) = artifacts() else { return };
        assert_eq!(rt.manifest.tile, 64);
        assert_eq!(rt.manifest.grid, 8);
        assert!(rt.manifest.batch_sizes.contains(&1));
    }

    #[test]
    fn cloudscore_white_image() {
        let Some(rt) = artifacts() else { return };
        let t = rt.manifest.tile;
        let input = vec![1.0f32; t * t * 3];
        let out = rt.execute(Model::CloudScore, 1, &input).expect("exec");
        assert_eq!(out.len(), 3);
        assert!((out[0] - 1.0).abs() < 1e-5, "mean lum {}", out[0]);
        assert!(out[1].abs() < 1e-5, "variance {}", out[1]);
        assert!((out[2] - 1.0).abs() < 1e-5, "white frac {}", out[2]);
    }

    #[test]
    fn execute_rejects_unknown_batch() {
        let Some(rt) = artifacts() else { return };
        let t = rt.manifest.tile;
        let err = rt.execute_exact(Model::Tiny, 3, &vec![0.0; 3 * t * t * 3]);
        assert!(err.is_err());
    }

    #[test]
    fn plan_covers_n_and_prefers_cheap_variant() {
        let Some(rt) = artifacts() else { return };
        // uncalibrated: single padded call
        assert_eq!(rt.plan(Model::Tiny, 3).iter().sum::<usize>() >= 3, true);
        rt.calibrate().unwrap();
        for n in [1usize, 3, 8, 11, 40] {
            let plan = rt.plan(Model::Tiny, n);
            assert!(plan.iter().sum::<usize>() >= n, "plan {plan:?} for n={n}");
            assert!(plan.iter().all(|b| rt.manifest.batch_sizes.contains(b)));
        }
        // execute still correct after calibration for an awkward n
        let t = rt.manifest.tile;
        let mut rng = crate::util::rng::Rng::new(2);
        let input: Vec<f32> = (0..5 * t * t * 3).map(|_| rng.f32()).collect();
        let cols = rt.manifest.grid * rt.manifest.grid * rt.manifest.head_d;
        let batched = rt.execute(Model::Tiny, 5, &input).unwrap();
        for i in 0..5 {
            let one = rt
                .execute_exact(Model::Tiny, 1, &input[i * t * t * 3..(i + 1) * t * t * 3])
                .unwrap();
            for (a, b) in batched[i * cols..(i + 1) * cols].iter().zip(&one) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn execute_handles_n_beyond_max_batch() {
        let Some(rt) = artifacts() else { return };
        let t = rt.manifest.tile;
        let n = rt.max_batch() + 3;
        let input = vec![0.25f32; n * t * t * 3];
        let out = rt.execute(Model::CloudScore, n, &input).unwrap();
        assert_eq!(out.len(), n * 3);
    }

    #[test]
    fn padding_matches_exact() {
        let Some(rt) = artifacts() else { return };
        let t = rt.manifest.tile;
        let n = 3; // pads to 8
        let mut input = Vec::with_capacity(n * t * t * 3);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..input.capacity() {
            input.push(rng.f32());
        }
        let padded = rt.execute(Model::Tiny, n, &input).expect("padded");
        // same tiles run through b1 one at a time
        let cols = rt.manifest.grid * rt.manifest.grid * rt.manifest.head_d;
        for i in 0..n {
            let one = rt
                .execute_exact(Model::Tiny, 1, &input[i * t * t * 3..(i + 1) * t * t * 3])
                .expect("b1");
            for (a, b) in padded[i * cols..(i + 1) * cols].iter().zip(&one) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }
}
