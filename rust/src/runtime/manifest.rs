//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub arch: String,
    pub steps: usize,
    pub final_loss_ema: f64,
    pub param_count: usize,
    /// batch size -> artifact file name
    pub files: BTreeMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub tile: usize,
    pub grid: usize,
    pub stride: f32,
    pub anchor: (f32, f32),
    pub classes: usize,
    pub class_names: Vec<String>,
    pub head_d: usize,
    pub batch_sizes: Vec<usize>,
    pub white_thresh: f32,
    pub redundant_white_frac: f32,
    pub fast: bool,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let num = |k: &str| -> Result<f64> {
            Ok(j.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("{k} not a number"))?)
        };
        let anchor = j.req("anchor")?.as_arr().context("anchor")?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models")? {
            let mut files = BTreeMap::new();
            for (b, f) in m.req("files")?.as_obj().context("files")? {
                files.insert(b.parse::<usize>()?, f.as_str().context("file")?.to_string());
            }
            models.insert(
                name.clone(),
                ModelInfo {
                    arch: m.req("arch")?.as_str().context("arch")?.to_string(),
                    steps: m.req("steps")?.as_usize().context("steps")?,
                    final_loss_ema: m.req("final_loss_ema")?.as_f64().context("loss")?,
                    param_count: m.req("param_count")?.as_usize().context("params")?,
                    files,
                },
            );
        }
        Ok(Manifest {
            tile: num("tile")? as usize,
            grid: num("grid")? as usize,
            stride: num("stride")? as f32,
            anchor: (
                anchor[0].as_f64().context("anchor[0]")? as f32,
                anchor[1].as_f64().context("anchor[1]")? as f32,
            ),
            classes: num("classes")? as usize,
            class_names: j
                .req("class_names")?
                .as_arr()
                .context("class_names")?
                .iter()
                .map(|s| s.as_str().unwrap_or("?").to_string())
                .collect(),
            head_d: num("head_d")? as usize,
            batch_sizes: j
                .req("batch_sizes")?
                .as_arr()
                .context("batch_sizes")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            white_thresh: num("white_thresh")? as f32,
            redundant_white_frac: num("redundant_white_frac")? as f32,
            fast: j.get("fast").and_then(|v| v.as_bool()).unwrap_or(false),
            models,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "tile": 64, "grid": 8, "stride": 8.0, "anchor": [16.0, 16.0],
        "classes": 8, "class_names": ["a","b","c","d","e","f","g","h"],
        "head_d": 13, "batch_sizes": [1, 8],
        "white_thresh": 0.72, "redundant_white_frac": 0.5, "fast": false,
        "models": {
            "tiny": {"arch": "tiny", "steps": 260, "final_loss_ema": 1.5,
                      "param_count": 14005,
                      "files": {"1": "tinydet_b1.hlo.txt", "8": "tinydet_b8.hlo.txt"}}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tile, 64);
        assert_eq!(m.grid, 8);
        assert_eq!(m.batch_sizes, vec![1, 8]);
        assert_eq!(m.models["tiny"].param_count, 14005);
        assert_eq!(m.models["tiny"].files[&8], "tinydet_b8.hlo.txt");
        assert_eq!(m.class_names.len(), 8);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Manifest::parse("{}").is_err());
    }
}
