//! Fixed-size worker thread pool + simple event loop primitives.
//!
//! Offline substitute for tokio: the coordinator's concurrency needs are a
//! leader event loop plus a small number of worker threads (onboard
//! inference, ground inference, link pumps), which map cleanly onto
//! std::thread + mpsc.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool executing boxed jobs; `join` drains and stops.
pub struct Pool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    pub fn new(n: usize) -> Pool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool joined")
            .send(Box::new(f))
            .expect("pool worker died");
    }

    /// Run `f` over all items in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.spawn(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx.iter() {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker panicked")).collect()
    }

    pub fn join(mut self) {
        self.shutdown();
    }

    /// Drain queued jobs and stop all workers.  Idempotent: safe to call
    /// more than once (and again from `Drop`); after shutdown, `spawn`
    /// panics — the pool is done.
    pub fn shutdown(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Ordered parallel map over items that may borrow non-'static data, run
/// on `n` scoped threads — the borrowed-data counterpart of
/// [`Pool::map`] (whose jobs must be 'static), for callers that fan a
/// batch out against the `Runtime` without an explicit stage graph.
pub fn scoped_map<T, R, F>(n: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = n.max(1);
    let total = items.len();
    let mut shards: Vec<Vec<(usize, T)>> = (0..n).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        shards[i % n].push((i, item));
    }
    let mut out: Vec<Option<R>> = (0..total).map(|_| None).collect();
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                s.spawn(move || shard.into_iter().map(|(i, t)| (i, f(t))).collect::<Vec<_>>())
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("scoped_map worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|o| o.expect("scoped_map lost an item")).collect()
}

/// Run a set of heterogeneous borrowed jobs to completion on scoped
/// threads — one thread per job.  The staged engine's stage workers run
/// through this (they borrow the `Runtime` and each other's channels, so
/// they cannot be `Pool` jobs).
pub fn scope_jobs<'env>(jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs.into_iter().map(|j| s.spawn(j)).collect();
        for h in handles {
            h.join().expect("stage worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = Pool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn shutdown_is_idempotent() {
        let mut pool = Pool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
        pool.shutdown(); // second call is a no-op (and Drop will be a third)
    }

    #[test]
    fn scoped_map_borrows_and_preserves_order() {
        let base = vec![10u64, 20, 30]; // borrowed, not 'static-moved
        let out = scoped_map(2, vec![0usize, 1, 2], |i| base[i] + i as u64);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn scope_jobs_runs_all_to_completion() {
        let counter = AtomicUsize::new(0);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for _ in 0..5 {
            jobs.push(Box::new(|| {
                counter.fetch_add(1, Ordering::SeqCst);
            }));
        }
        scope_jobs(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 5);
    }
}
