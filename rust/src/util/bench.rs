//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Used by the `harness = false` targets under `rust/benches/`.  Gives
//! warmup, timed iterations, and robust summary stats (median + p10/p90),
//! printed in a fixed format that EXPERIMENTS.md records.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

/// Time `f` for at least `min_iters` iterations and ~`budget` wall time.
pub fn run<F: FnMut()>(name: &str, min_iters: usize, budget: Duration, mut f: F) -> Stats {
    // Warmup: a few runs so lazily-initialized state (PJRT executables,
    // caches) doesn't pollute the first sample.
    let warmups = 2.min(min_iters);
    for _ in 0..warmups {
        f();
    }
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < min_iters || (t0.elapsed() < budget && samples.len() < 10_000) {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
    }
    samples.sort();
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let stats = Stats {
        iters: samples.len(),
        median: pick(0.5),
        p10: pick(0.1),
        p90: pick(0.9),
        mean,
    };
    println!(
        "bench {name:<42} iters={:<5} median={:>12?} p10={:>12?} p90={:>12?} ({:.1}/s)",
        stats.iters, stats.median, stats.p10, stats.p90, stats.per_sec()
    );
    stats
}

/// One-shot measurement for expensive end-to-end runs.
pub fn once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed();
    println!("bench {name:<42} once            elapsed={dt:>12?}");
    (out, dt)
}

/// Emit one machine-readable bench record: a single JSON object per line
/// (`{"bench": <name>, <field>: <value>, ...}`), the format EXPERIMENTS
/// tooling greps out of bench logs.  Non-finite values are emitted as
/// null so the line stays valid JSON.
pub fn json_line(name: &str, fields: &[(&str, f64)]) {
    let mut s = format!("{{\"bench\":\"{name}\"");
    for (k, v) in fields {
        if v.is_finite() {
            s.push_str(&format!(",\"{k}\":{v}"));
        } else {
            s.push_str(&format!(",\"{k}\":null"));
        }
    }
    s.push('}');
    println!("{s}");
}

/// Render a paper-style table row: fixed-width columns.
pub fn table_row(cols: &[&str], widths: &[usize]) -> String {
    let mut s = String::new();
    for (c, w) in cols.iter().zip(widths) {
        s.push_str(&format!("{:<width$}", c, width = w));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_at_least_min_iters() {
        let mut n = 0;
        let stats = run("noop", 5, Duration::from_millis(1), || n += 1);
        assert!(stats.iters >= 5);
        assert!(n >= stats.iters); // warmup runs extra
    }

    #[test]
    fn percentiles_ordered() {
        let stats = run("sleepless", 10, Duration::from_millis(5), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(stats.p10 <= stats.median && stats.median <= stats.p90);
    }

    #[test]
    fn table_row_pads() {
        let row = table_row(&["a", "bb"], &[4, 4]);
        assert_eq!(row, "a   bb  ");
    }

    #[test]
    fn json_line_smoke() {
        // json_line prints; just exercise the formatting paths (finite +
        // non-finite) for panics.
        json_line("t", &[("a", 1.5), ("b", f64::NAN)]);
    }
}
