//! Pooled fixed-size f32 pixel buffers — the zero-copy hot data path.
//!
//! The capture→tile→infer path used to allocate (and zero) a fresh
//! `Vec<f32>` for every tile and every scene; at steady state those
//! buffers have a bounded population (channel depths × batch sizes), so
//! a checkout/return pool removes every per-item allocation after
//! warmup.  [`PixelPool`] hands out [`PixelBuf`]s that return themselves
//! to the pool on drop; a buffer cloned from a pooled buffer is drawn
//! from the same pool (the ground-offload copy path), and stats expose
//! the checkout/return/alloc balance the invariant tests and the
//! `perf_datapath` bench assert on.
//!
//! Ownership rules (see DESIGN.md "Hot data path"):
//! * the pool owner (SceneGen, Pipeline, Runtime) decides the buffer
//!   length at construction; every checkout is that exact length;
//! * `checkout()` returns a **zeroed** buffer — semantically identical
//!   to `vec![0.0; len]`, which is what the pre-pool code allocated —
//!   while `checkout_dirty()` skips the clear for callers that
//!   overwrite every element they later read;
//! * dropping a pooled `PixelBuf` returns the storage; dropping the
//!   pool itself only drops the free list — outstanding buffers keep
//!   the shared inner state alive and still return storage harmlessly.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Checkout/return pool of fixed-length `f32` buffers.
///
/// Cloning the pool handle is cheap (shared `Arc`); all clones draw
/// from the same free list, so a pool may be shared across worker
/// threads (checkout/return is one short mutex hold around a `Vec`
/// push/pop).
#[derive(Clone)]
pub struct PixelPool {
    inner: Arc<PoolInner>,
}

struct PoolInner {
    buf_len: usize,
    free: Mutex<Vec<Vec<f32>>>,
    checkouts: AtomicU64,
    returns: AtomicU64,
    allocs: AtomicU64,
}

/// Point-in-time pool accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out over the pool's lifetime.
    pub checkouts: u64,
    /// Buffers returned (dropped while pooled).
    pub returns: u64,
    /// Checkouts that had to allocate (free list empty).
    pub allocs: u64,
    /// Buffers currently sitting on the free list.
    pub free: usize,
}

impl PoolStats {
    /// Checkouts served from the free list without allocating.
    /// Saturating: the counters are independent relaxed reads, so a
    /// snapshot taken while another thread is mid-checkout may observe
    /// `allocs` ahead of `checkouts` by one.
    pub fn hits(&self) -> u64 {
        self.checkouts.saturating_sub(self.allocs)
    }

    /// Fraction of checkouts served without allocating (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            0.0
        } else {
            self.hits() as f64 / self.checkouts as f64
        }
    }

    /// Buffers currently checked out (the pool's live population).
    /// Saturating, like [`Self::hits`].
    pub fn live(&self) -> u64 {
        self.checkouts.saturating_sub(self.returns)
    }
}

impl PixelPool {
    /// A pool of `buf_len`-element buffers (e.g. one tile or one scene).
    pub fn new(buf_len: usize) -> PixelPool {
        PixelPool {
            inner: Arc::new(PoolInner {
                buf_len,
                free: Mutex::new(Vec::new()),
                checkouts: AtomicU64::new(0),
                returns: AtomicU64::new(0),
                allocs: AtomicU64::new(0),
            }),
        }
    }

    /// Buffer length every checkout of this pool has.
    pub fn buf_len(&self) -> usize {
        self.inner.buf_len
    }

    /// Check out a zeroed buffer (reused storage is cleared, fresh
    /// storage is born zeroed, so this is exactly `vec![0.0; buf_len]`
    /// without the steady-state allocation).
    pub fn checkout(&self) -> PixelBuf {
        let (mut data, reused) = self.inner.take();
        if reused {
            data.fill(0.0);
        }
        PixelBuf { data, pool: Some(Arc::clone(&self.inner)) }
    }

    /// Check out a buffer with **unspecified contents** — for hot-path
    /// callers that overwrite every element they read back (the tiler
    /// writes every output f32; batch gathers read only the prefix they
    /// just wrote).  Skips the per-checkout memset that would otherwise
    /// re-pay, per item, the cost the pool exists to remove.  Use
    /// [`Self::checkout`] wherever zeroed semantics matter.
    pub fn checkout_dirty(&self) -> PixelBuf {
        let (data, _reused) = self.inner.take();
        PixelBuf { data, pool: Some(Arc::clone(&self.inner)) }
    }

    pub fn stats(&self) -> PoolStats {
        self.inner.stats()
    }
}

impl PoolInner {
    /// Pop a free buffer (`true`: contents are stale) or allocate one
    /// (`false`: born zeroed) — so `checkout` clears only reused storage.
    fn take(&self) -> (Vec<f32>, bool) {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let reused = self.free.lock().unwrap().pop();
        match reused {
            Some(v) => (v, true),
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                (vec![0.0; self.buf_len], false)
            }
        }
    }

    fn stats(&self) -> PoolStats {
        PoolStats {
            checkouts: self.checkouts.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            free: self.free.lock().unwrap().len(),
        }
    }
}

/// An owned f32 buffer, optionally backed by a [`PixelPool`].
///
/// Derefs to `[f32]`; drops return pooled storage to the pool.  A plain
/// (unpooled) buffer behaves exactly like the `Vec<f32>` it wraps, so
/// tests and cold paths can keep constructing pixel data directly.
pub struct PixelBuf {
    data: Vec<f32>,
    pool: Option<Arc<PoolInner>>,
}

impl PixelBuf {
    /// Unpooled zeroed buffer — the cold-path equivalent of `checkout`.
    pub fn zeroed(len: usize) -> PixelBuf {
        PixelBuf { data: vec![0.0; len], pool: None }
    }

    /// Whether dropping this buffer returns storage to a pool.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }
}

impl From<Vec<f32>> for PixelBuf {
    fn from(data: Vec<f32>) -> PixelBuf {
        PixelBuf { data, pool: None }
    }
}

impl Deref for PixelBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl DerefMut for PixelBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl Clone for PixelBuf {
    /// A clone of a pooled buffer is drawn from the same pool (no fresh
    /// allocation at steady state) and carries a bit-identical copy of
    /// the contents; unpooled buffers clone like a `Vec`.
    fn clone(&self) -> PixelBuf {
        match &self.pool {
            Some(pool) if self.data.len() == pool.buf_len => {
                let (mut data, _reused) = pool.take();
                data.copy_from_slice(&self.data);
                PixelBuf { data, pool: Some(Arc::clone(pool)) }
            }
            _ => PixelBuf { data: self.data.clone(), pool: None },
        }
    }
}

impl Drop for PixelBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.returns.fetch_add(1, Ordering::Relaxed);
            pool.free.lock().unwrap().push(std::mem::take(&mut self.data));
        }
    }
}

impl PartialEq for PixelBuf {
    fn eq(&self, other: &PixelBuf) -> bool {
        self.data == other.data
    }
}

impl fmt::Debug for PixelBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PixelBuf")
            .field("len", &self.data.len())
            .field("pooled", &self.pool.is_some())
            .field("head", &&self.data[..self.data.len().min(4)])
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_allocates_then_reuses() {
        let pool = PixelPool::new(8);
        let a = pool.checkout();
        drop(a);
        let b = pool.checkout();
        drop(b);
        let s = pool.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.returns, 2);
        assert_eq!(s.allocs, 1, "second checkout must reuse the first buffer");
        assert_eq!(s.hits(), 1);
        assert_eq!(s.free, 1);
    }

    #[test]
    fn checkout_is_zeroed_after_dirty_return() {
        let pool = PixelPool::new(4);
        let mut a = pool.checkout();
        a.fill(7.5);
        drop(a);
        let b = pool.checkout();
        assert!(b.iter().all(|&v| v == 0.0), "reused buffer not cleared: {b:?}");
    }

    #[test]
    fn concurrent_checkouts_grow_capacity_once() {
        let pool = PixelPool::new(4);
        let bufs: Vec<PixelBuf> = (0..3).map(|_| pool.checkout()).collect();
        assert_eq!(pool.stats().allocs, 3);
        assert_eq!(pool.stats().live(), 3);
        drop(bufs);
        let again: Vec<PixelBuf> = (0..3).map(|_| pool.checkout()).collect();
        assert_eq!(pool.stats().allocs, 3, "warm pool must not allocate");
        drop(again);
        let s = pool.stats();
        assert_eq!(s.checkouts, s.returns);
        assert_eq!(s.free, 3);
    }

    #[test]
    fn checkout_dirty_reuses_without_affecting_balance() {
        let pool = PixelPool::new(4);
        drop(pool.checkout());
        let d = pool.checkout_dirty();
        assert!(d.is_pooled());
        assert_eq!(d.len(), 4);
        drop(d);
        let s = pool.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.returns, 2);
        assert_eq!(s.allocs, 1, "dirty checkout must reuse the freed buffer");
    }

    #[test]
    fn clone_draws_from_the_same_pool() {
        let pool = PixelPool::new(4);
        let mut a = pool.checkout();
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        drop(pool.checkout()); // park one free buffer for the clone
        let b = a.clone();
        assert!(b.is_pooled());
        assert_eq!(&a[..], &b[..]);
        let a_stats = PixelPool { inner: Arc::clone(a.pool.as_ref().unwrap()) }.stats();
        assert_eq!(a_stats.allocs, 2, "clone must reuse the parked buffer");
    }

    #[test]
    fn unpooled_buf_behaves_like_vec() {
        let v: PixelBuf = vec![1.0f32, 2.0].into();
        assert!(!v.is_pooled());
        let w = v.clone();
        assert_eq!(v, w);
        assert_eq!(v.len(), 2);
        assert_eq!(PixelBuf::zeroed(3)[..], [0.0, 0.0, 0.0]);
    }
}
