//! Pooled fixed-size buffers — the zero-copy hot data path.
//!
//! The capture→tile→infer path used to allocate (and zero) a fresh
//! `Vec<f32>` for every tile and every scene; at steady state those
//! buffers have a bounded population (channel depths × batch sizes), so
//! a checkout/return pool removes every per-item allocation after
//! warmup.  [`PixelPool`] hands out [`PixelBuf`]s that return themselves
//! to the pool on drop; a buffer cloned from a pooled buffer is drawn
//! from the same pool (the ground-offload copy path), and stats expose
//! the checkout/return/alloc balance the invariant tests and the
//! `perf_datapath` bench assert on.
//!
//! The pool is generic over the element ([`Pool<T>`]): the f32 pixel
//! pools and the quantized cloud filter's i8 scratch ([`QuantPool`])
//! share one implementation, so the accounting and eviction semantics
//! can never diverge between precisions.
//!
//! Ownership rules (see DESIGN.md "Hot data path"):
//! * the pool owner (SceneGen, Pipeline, Runtime) decides the buffer
//!   length at construction; every checkout is that exact length;
//! * `checkout()` returns a **zeroed** buffer — semantically identical
//!   to `vec![0.0; len]`, which is what the pre-pool code allocated —
//!   while `checkout_dirty()` skips the clear for callers that
//!   overwrite every element they later read;
//! * dropping a pooled buffer returns the storage; dropping the
//!   pool itself only drops the free list — outstanding buffers keep
//!   the shared inner state alive and still return storage harmlessly;
//! * a pool built with [`Pool::with_cap`] bounds its free list: returns
//!   beyond the cap *evict* (free) the storage instead of parking it,
//!   so large fleets bound their idle-buffer footprint.  The default
//!   (`cap = 0`) is unbounded — the pre-cap behaviour, bit-for-bit.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Checkout/return pool of fixed-length buffers over element `T`.
///
/// Cloning the pool handle is cheap (shared `Arc`); all clones draw
/// from the same free list, so a pool may be shared across worker
/// threads (checkout/return is one short mutex hold around a `Vec`
/// push/pop).
pub struct Pool<T> {
    inner: Arc<PoolInner<T>>,
}

/// The hot-path f32 pixel pool (tiles, scenes, marshalling scratch).
pub type PixelPool = Pool<f32>;
/// Pooled i8 scratch for the quantized cloud filter.
pub type QuantPool = Pool<i8>;

// Derived Clone would bound T: Clone; the handle only clones the Arc.
impl<T> Clone for Pool<T> {
    fn clone(&self) -> Pool<T> {
        Pool { inner: Arc::clone(&self.inner) }
    }
}

struct PoolInner<T> {
    buf_len: usize,
    /// Free-list cap: returns beyond this evict instead of parking.
    /// 0 = unbounded.
    cap: usize,
    free: Mutex<Vec<Vec<T>>>,
    checkouts: AtomicU64,
    returns: AtomicU64,
    allocs: AtomicU64,
    evictions: AtomicU64,
}

/// Point-in-time pool accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out over the pool's lifetime.
    pub checkouts: u64,
    /// Buffers returned (dropped while pooled) — includes evictions.
    pub returns: u64,
    /// Checkouts that had to allocate (free list empty).
    pub allocs: u64,
    /// Returns whose storage was freed instead of parked (free list at
    /// its cap), plus buffers freed by [`Pool::shrink_to`].
    pub evictions: u64,
    /// Buffers currently sitting on the free list.
    pub free: usize,
}

impl PoolStats {
    /// Checkouts served from the free list without allocating.
    /// Saturating: the counters are independent relaxed reads, so a
    /// snapshot taken while another thread is mid-checkout may observe
    /// `allocs` ahead of `checkouts` by one.
    pub fn hits(&self) -> u64 {
        self.checkouts.saturating_sub(self.allocs)
    }

    /// Fraction of checkouts served without allocating (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            0.0
        } else {
            self.hits() as f64 / self.checkouts as f64
        }
    }

    /// Buffers currently checked out (the pool's live population).
    /// Saturating, like [`Self::hits`].
    pub fn live(&self) -> u64 {
        self.checkouts.saturating_sub(self.returns)
    }
}

impl<T: Copy + Default> Pool<T> {
    /// A pool of `buf_len`-element buffers (e.g. one tile or one scene)
    /// with an unbounded free list.
    pub fn new(buf_len: usize) -> Pool<T> {
        Pool::with_cap(buf_len, 0)
    }

    /// A pool whose free list is capped at `cap` parked buffers:
    /// returns beyond the cap free their storage (counted as
    /// `evictions`) instead of parking it.  `cap = 0` means unbounded —
    /// identical to [`Pool::new`].
    pub fn with_cap(buf_len: usize, cap: usize) -> Pool<T> {
        Pool {
            inner: Arc::new(PoolInner {
                buf_len,
                cap,
                free: Mutex::new(Vec::new()),
                checkouts: AtomicU64::new(0),
                returns: AtomicU64::new(0),
                allocs: AtomicU64::new(0),
                evictions: AtomicU64::new(0),
            }),
        }
    }

    /// Buffer length every checkout of this pool has.
    pub fn buf_len(&self) -> usize {
        self.inner.buf_len
    }

    /// Check out a zeroed buffer (reused storage is cleared, fresh
    /// storage is born zeroed, so this is exactly `vec![T::default(); buf_len]`
    /// without the steady-state allocation).
    pub fn checkout(&self) -> PoolBuf<T> {
        let (mut data, reused) = self.inner.take();
        if reused {
            data.fill(T::default());
        }
        PoolBuf { data, pool: Some(Arc::clone(&self.inner)) }
    }

    /// Check out a buffer with **unspecified contents** — for hot-path
    /// callers that overwrite every element they read back (the tiler
    /// writes every output f32; batch gathers read only the prefix they
    /// just wrote).  Skips the per-checkout memset that would otherwise
    /// re-pay, per item, the cost the pool exists to remove.  Use
    /// [`Self::checkout`] wherever zeroed semantics matter.
    pub fn checkout_dirty(&self) -> PoolBuf<T> {
        let (data, _reused) = self.inner.take();
        PoolBuf { data, pool: Some(Arc::clone(&self.inner)) }
    }

    /// Free parked buffers beyond `keep`, counting them as evictions —
    /// an explicit trim for fleet-scale callers that want to release
    /// warmup overshoot without waiting for capped returns.
    pub fn shrink_to(&self, keep: usize) {
        let mut freed = 0u64;
        {
            let mut free = self.inner.free.lock().unwrap();
            while free.len() > keep {
                free.pop();
                freed += 1;
            }
        }
        if freed > 0 {
            self.inner.evictions.fetch_add(freed, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> PoolStats {
        self.inner.stats()
    }
}

impl<T: Copy + Default> PoolInner<T> {
    /// Pop a free buffer (`true`: contents are stale) or allocate one
    /// (`false`: born zeroed) — so `checkout` clears only reused storage.
    fn take(&self) -> (Vec<T>, bool) {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let reused = self.free.lock().unwrap().pop();
        match reused {
            Some(v) => (v, true),
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                (vec![T::default(); self.buf_len], false)
            }
        }
    }
}

impl<T> PoolInner<T> {
    fn stats(&self) -> PoolStats {
        PoolStats {
            checkouts: self.checkouts.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            free: self.free.lock().unwrap().len(),
        }
    }
}

/// An owned buffer, optionally backed by a [`Pool`].
///
/// Derefs to `[T]`; drops return pooled storage to the pool.  A plain
/// (unpooled) buffer behaves exactly like the `Vec<T>` it wraps, so
/// tests and cold paths can keep constructing pixel data directly.
pub struct PoolBuf<T> {
    data: Vec<T>,
    pool: Option<Arc<PoolInner<T>>>,
}

/// The hot-path f32 buffer handed out by a [`PixelPool`].
pub type PixelBuf = PoolBuf<f32>;
/// i8 quantization scratch handed out by a [`QuantPool`].
pub type QuantBuf = PoolBuf<i8>;

impl<T: Copy + Default> PoolBuf<T> {
    /// Unpooled zeroed buffer — the cold-path equivalent of `checkout`.
    pub fn zeroed(len: usize) -> PoolBuf<T> {
        PoolBuf { data: vec![T::default(); len], pool: None }
    }
}

impl<T> PoolBuf<T> {
    /// Whether dropping this buffer returns storage to a pool.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }
}

impl<T> From<Vec<T>> for PoolBuf<T> {
    fn from(data: Vec<T>) -> PoolBuf<T> {
        PoolBuf { data, pool: None }
    }
}

impl<T> Deref for PoolBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.data
    }
}

impl<T> DerefMut for PoolBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.data
    }
}

impl<T: Copy + Default> Clone for PoolBuf<T> {
    /// A clone of a pooled buffer is drawn from the same pool (no fresh
    /// allocation at steady state) and carries a bit-identical copy of
    /// the contents; unpooled buffers clone like a `Vec`.
    fn clone(&self) -> PoolBuf<T> {
        match &self.pool {
            Some(pool) if self.data.len() == pool.buf_len => {
                let (mut data, _reused) = pool.take();
                data.copy_from_slice(&self.data);
                PoolBuf { data, pool: Some(Arc::clone(pool)) }
            }
            _ => PoolBuf { data: self.data.clone(), pool: None },
        }
    }
}

impl<T> Drop for PoolBuf<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.returns.fetch_add(1, Ordering::Relaxed);
            let data = std::mem::take(&mut self.data);
            let mut free = pool.free.lock().unwrap();
            if pool.cap > 0 && free.len() >= pool.cap {
                drop(free); // release the lock before freeing the Vec
                pool.evictions.fetch_add(1, Ordering::Relaxed);
                // data drops here: evicted, not parked
            } else {
                free.push(data);
            }
        }
    }
}

impl<T: PartialEq> PartialEq for PoolBuf<T> {
    fn eq(&self, other: &PoolBuf<T>) -> bool {
        self.data == other.data
    }
}

impl<T: fmt::Debug> fmt::Debug for PoolBuf<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PoolBuf")
            .field("len", &self.data.len())
            .field("pooled", &self.pool.is_some())
            .field("head", &&self.data[..self.data.len().min(4)])
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_allocates_then_reuses() {
        let pool = PixelPool::new(8);
        let a = pool.checkout();
        drop(a);
        let b = pool.checkout();
        drop(b);
        let s = pool.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.returns, 2);
        assert_eq!(s.allocs, 1, "second checkout must reuse the first buffer");
        assert_eq!(s.hits(), 1);
        assert_eq!(s.free, 1);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn checkout_is_zeroed_after_dirty_return() {
        let pool = PixelPool::new(4);
        let mut a = pool.checkout();
        a.fill(7.5);
        drop(a);
        let b = pool.checkout();
        assert!(b.iter().all(|&v| v == 0.0), "reused buffer not cleared: {b:?}");
    }

    #[test]
    fn concurrent_checkouts_grow_capacity_once() {
        let pool = PixelPool::new(4);
        let bufs: Vec<PixelBuf> = (0..3).map(|_| pool.checkout()).collect();
        assert_eq!(pool.stats().allocs, 3);
        assert_eq!(pool.stats().live(), 3);
        drop(bufs);
        let again: Vec<PixelBuf> = (0..3).map(|_| pool.checkout()).collect();
        assert_eq!(pool.stats().allocs, 3, "warm pool must not allocate");
        drop(again);
        let s = pool.stats();
        assert_eq!(s.checkouts, s.returns);
        assert_eq!(s.free, 3);
    }

    #[test]
    fn checkout_dirty_reuses_without_affecting_balance() {
        let pool = PixelPool::new(4);
        drop(pool.checkout());
        let d = pool.checkout_dirty();
        assert!(d.is_pooled());
        assert_eq!(d.len(), 4);
        drop(d);
        let s = pool.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.returns, 2);
        assert_eq!(s.allocs, 1, "dirty checkout must reuse the freed buffer");
    }

    #[test]
    fn clone_draws_from_the_same_pool() {
        let pool = PixelPool::new(4);
        let mut a = pool.checkout();
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        drop(pool.checkout()); // park one free buffer for the clone
        let b = a.clone();
        assert!(b.is_pooled());
        assert_eq!(&a[..], &b[..]);
        let a_stats = PixelPool { inner: Arc::clone(a.pool.as_ref().unwrap()) }.stats();
        assert_eq!(a_stats.allocs, 2, "clone must reuse the parked buffer");
    }

    #[test]
    fn unpooled_buf_behaves_like_vec() {
        let v: PixelBuf = vec![1.0f32, 2.0].into();
        assert!(!v.is_pooled());
        let w = v.clone();
        assert_eq!(v, w);
        assert_eq!(v.len(), 2);
        assert_eq!(PixelBuf::zeroed(3)[..], [0.0, 0.0, 0.0]);
    }

    #[test]
    fn capped_pool_evicts_beyond_cap() {
        let pool = PixelPool::with_cap(4, 2);
        let bufs: Vec<PixelBuf> = (0..4).map(|_| pool.checkout()).collect();
        drop(bufs); // 4 returns against a cap of 2: last 2 evict
        let s = pool.stats();
        assert_eq!(s.returns, 4, "evicted buffers still count as returned");
        assert_eq!(s.free, 2, "free list must stay at its cap");
        assert_eq!(s.evictions, 2);
        assert_eq!(s.live(), 0, "live accounting unaffected by eviction");
        // the parked two still serve checkouts without allocating
        let a = pool.checkout();
        let b = pool.checkout();
        assert_eq!(pool.stats().allocs, 4, "capped pool must reuse parked buffers");
        drop((a, b));
    }

    #[test]
    fn uncapped_pool_never_evicts() {
        let pool = PixelPool::new(4); // cap 0 = unbounded
        let bufs: Vec<PixelBuf> = (0..8).map(|_| pool.checkout()).collect();
        drop(bufs);
        let s = pool.stats();
        assert_eq!(s.free, 8);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn shrink_to_frees_parked_buffers() {
        let pool = PixelPool::new(4);
        let bufs: Vec<PixelBuf> = (0..5).map(|_| pool.checkout()).collect();
        drop(bufs);
        pool.shrink_to(2);
        let s = pool.stats();
        assert_eq!(s.free, 2);
        assert_eq!(s.evictions, 3);
        pool.shrink_to(3); // already below: no-op
        assert_eq!(pool.stats().evictions, 3);
    }

    #[test]
    fn quant_pool_shares_the_pool_semantics() {
        let pool = QuantPool::new(6);
        let mut a = pool.checkout();
        assert!(a.iter().all(|&v| v == 0), "i8 checkout is zeroed");
        a.fill(-3);
        drop(a);
        let b = pool.checkout_dirty();
        assert!(b.is_pooled());
        assert_eq!(b.len(), 6);
        drop(b);
        let s = pool.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.allocs, 1, "quant pool must reuse the freed buffer");
        assert_eq!(s.returns, 2);
    }
}
