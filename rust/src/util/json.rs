//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Reads `artifacts/manifest.json` and `configs/*.json`; writes telemetry
//! reports.  Supports the full JSON grammar except `\u` surrogate pairs
//! are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(thiserror::Error, Debug)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that fails loudly with the key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte utf-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    out.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":null,"d":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::parse("\"天算\"").unwrap();
        assert_eq!(j.as_str(), Some("天算"));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn escape_sequences_roundtrip() {
        let j = Json::Str("tab\t\"q\"\u{1}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn req_reports_key() {
        let j = Json::parse("{}").unwrap();
        let err = j.req("grid").unwrap_err().to_string();
        assert!(err.contains("grid"));
    }
}
