//! Tiny argv parser (offline substitute for clap).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — `tokens` excludes argv[0].
    pub fn parse_from(tokens: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn parse() -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_from(&tokens)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // `--verbose` is last (or would need `=`-form): a bare `--name v`
        // pair is always read as an option, the grammar has no flag
        // registry.
        let a = Args::parse_from(&toks("serve --port 8080 extra --verbose"));
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse_from(&toks("run --steps=50"));
        assert_eq!(a.opt_usize("steps", 0), 50);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse_from(&toks("run"));
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_f64("thr", 0.5), 0.5);
        assert!(!a.flag("x"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = Args::parse_from(&toks("x --fast --seed 9"));
        assert!(a.flag("fast"));
        assert_eq!(a.opt_u64("seed", 0), 9);
    }
}
