//! Deterministic PRNG (xoshiro256**) — the single randomness source for
//! the whole simulator, so every experiment is reproducible from a seed.
//!
//! Offline substitute for the `rand` crate (not in the vendor set).

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-satellite / per-tile RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in [0, n).  n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method: unbiased.
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Poisson via Knuth (fine for small lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 1000 {
                return k; // guard against pathological lambda
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_close_to_lambda() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let s: u64 = (0..n).map(|_| r.poisson(1.6)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 1.6).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_uncorrelated() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
