//! Small self-contained utilities standing in for crates that are not in
//! the offline vendor set (rand, serde_json, clap, criterion, tokio).

pub mod bench;
pub mod buffer;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
